//! Heterogeneous planning walk-through on the paper's cluster C: profile
//! (Alg. 1), fit curves, and compare the plans Poplar / DeepSpeed-style
//! uniform / Whale-style FLOPs allocation produce at every ZeRO stage.
//!
//! ```text
//! cargo run --release --example hetero_plan
//! ```

use anyhow::Result;
use poplar::cluster;
use poplar::config::{model::preset, Strategy};
use poplar::coordinator::Leader;
use poplar::metrics::Table;

fn main() -> Result<()> {
    let cluster = cluster::cluster_c();
    let model = preset("llama-0.5b").unwrap();
    let gbs = 2048; // 2M tokens / seq 1024
    println!(
        "planning {} ({:.2}B params) on {} ({} GPUs), gbs = {gbs} samples\n",
        model.name,
        model.param_count() as f64 / 1e9,
        cluster.name,
        cluster.n_gpus()
    );

    let mut leader = Leader::new_simulated(&cluster, &model, 0.015, 42);
    for stage in 0..4u8 {
        let prof = leader.profile(stage)?;
        println!("=== ZeRO-{} ===", prof.stage);
        let mut t = Table::new(&["strategy", "rank0 A800 (b x gas)", "rank4 V100S (b x gas)",
                                 "predicted iter (s)"]);
        for strategy in [Strategy::Uniform, Strategy::Flops, Strategy::Poplar] {
            let plan = leader.plan_from_profile(&prof, strategy, gbs)?;
            let fmt = |i: usize| {
                let r = &plan.ranks[i];
                format!("{} x {} (+{})", r.micro_batch, r.grad_accum_steps, r.last_batch)
            };
            t.row(&[
                strategy.name().to_string(),
                fmt(0),
                fmt(4),
                format!("{:.3}", plan.predicted_iter_s),
            ]);
        }
        println!("{}", t.to_markdown());

        // run one live iteration with the poplar plan
        let plan = leader.plan_from_profile(&prof, Strategy::Poplar, gbs)?;
        let it = leader.run_iteration(&plan)?;
        println!(
            "live poplar iteration: wall {:.3}s, comm {:.3}s, {:.1} TFLOP/s cluster-wide\n",
            it.wall_s, it.comm_s, it.tflops
        );
    }
    leader.shutdown();
    println!("hetero_plan OK");
    Ok(())
}
