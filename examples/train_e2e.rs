//! End-to-end validation (DESIGN.md §6): really train a transformer for
//! a few hundred steps on the bundled corpus, through the full stack —
//! Pallas-kernel HLO artifacts, PJRT execution, and Poplar's
//! heterogeneous profiling + batch allocation over a virtualized
//! 4-GPU cluster (2 fast + 2 slow, memory-capped).
//!
//! ```text
//! make artifacts
//! cargo run --release --example train_e2e            # tiny model, 200 iters
//! POPLAR_E2E_PRESET=e2e-28m POPLAR_E2E_ITERS=300 \
//!   cargo run --release --example train_e2e          # bigger run
//! ```
//!
//! The loss curve is written to `results/e2e_loss.csv` and summarized in
//! EXPERIMENTS.md §E2E.

use anyhow::{anyhow, Context, Result};
use poplar::allocator;
use poplar::cluster::LinkKind;
use poplar::data::corpus::CorpusStream;
use poplar::metrics::flops;
use poplar::netsim::NetSim;
use poplar::runtime::artifacts_dir;
use poplar::train::{Trainer, VirtualGpu};

fn env_or<T: std::str::FromStr>(key: &str, default: T) -> T {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> Result<()> {
    let preset: String = env_or("POPLAR_E2E_PRESET", "tiny".to_string());
    let iters: usize = env_or("POPLAR_E2E_ITERS", 200);
    let gbs: usize = env_or("POPLAR_E2E_GBS", 16);
    let stage: u8 = env_or("POPLAR_E2E_STAGE", 1);

    let dir = artifacts_dir(&preset);
    let mut trainer = Trainer::open(&dir)
        .context("opening artifacts — run `make artifacts` first")?;
    let meta = trainer.engine().meta().clone();
    println!(
        "e2e: preset={} ({} params), seq={}, gbs={} samples, {} iterations, ZeRO-{stage}",
        meta.preset, meta.param_count, meta.seq, gbs, iters
    );

    // virtualized heterogeneous cluster: 2 fast + 2 slow (half memory)
    let max_b = *meta.batch_variants.iter().max().unwrap();
    let vgpus = vec![
        VirtualGpu { name: "fast-0".into(), slowdown: 1.0, max_batch: max_b },
        VirtualGpu { name: "fast-1".into(), slowdown: 1.0, max_batch: max_b },
        VirtualGpu { name: "slow-0".into(), slowdown: 2.4, max_batch: (max_b / 2).max(1) },
        VirtualGpu { name: "slow-1".into(), slowdown: 2.4, max_batch: (max_b / 2).max(1) },
    ];

    // Phase 1: online profiling of the REAL step (Alg. 1's timing loop)
    let mut source = CorpusStream::new(meta.vocab as u32);
    let curves = trainer.profile_virtual(&vgpus, &mut source, 1)?;
    for (g, c) in vgpus.iter().zip(&curves) {
        println!(
            "  profiled {}: mbs={} peak {:.2} samples/s",
            g.name,
            c.mbs(),
            c.peak_speed()
        );
    }

    // Phase 2: offline analyzing (Alg. 2)
    let net = NetSim::from_link(vgpus.len(), LinkKind::Pcie);
    let plan = allocator::plan(&curves, stage, gbs, &net, meta.param_count as u64)
        .map_err(|e| anyhow!("plan: {e}"))?;
    println!("  plan (rank: micro x gas + lbs):");
    for r in &plan.ranks {
        println!(
            "    rank {} [{}]: {} x {} + {}  ({} samples/iter)",
            r.rank, vgpus[r.rank].name, r.micro_batch, r.grad_accum_steps.saturating_sub(1),
            r.last_batch, r.samples_per_iter
        );
    }
    // the fast ranks must carry more than the slow, memory-capped ranks
    assert!(plan.ranks[0].samples_per_iter > plan.ranks[2].samples_per_iter);

    // Phase 3: real heterogeneous data-parallel training
    let logs = trainer.train(&plan, &vgpus, &mut source, iters, 10)?;

    std::fs::create_dir_all("results")?;
    let mut csv = String::from("iter,loss,sim_wall_s,real_wall_s\n");
    for l in &logs {
        csv.push_str(&format!("{},{:.6},{:.6},{:.6}\n", l.iter, l.loss, l.sim_wall_s,
                              l.real_wall_s));
    }
    std::fs::write("results/e2e_loss.csv", &csv)?;

    let first = logs.first().unwrap().loss;
    let last10: f64 =
        logs.iter().rev().take(10).map(|l| l.loss).sum::<f64>() / 10f64.min(logs.len() as f64);
    let spec = meta.model_spec();
    let sim_wall: f64 = logs.iter().map(|l| l.sim_wall_s).sum();
    println!(
        "\ne2e result: loss {first:.4} -> {last10:.4} (mean of last 10) over {} iters",
        logs.len()
    );
    println!(
        "simulated heterogeneous throughput: {:.2} GFLOP/s equivalent",
        flops::tflops(&spec, gbs * logs.len(), sim_wall) * 1000.0
    );
    println!("loss curve written to results/e2e_loss.csv");
    assert!(
        last10 < first - 0.3,
        "training must reduce loss materially ({first:.3} -> {last10:.3})"
    );
    println!("train_e2e OK");
    Ok(())
}
