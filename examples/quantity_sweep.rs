//! Quantity-heterogeneity sweep (the Fig. 5 scenario) through the public
//! API: arbitrary A800:V100S ratios, including the non-uniform counts
//! (4:1, 1:4) that Whale/AMP cannot express.
//!
//! ```text
//! cargo run --release --example quantity_sweep
//! ```

use anyhow::Result;
use poplar::cluster::cluster_c_counts;
use poplar::config::{model::preset, Strategy};
use poplar::exp;
use poplar::metrics::Table;

fn main() -> Result<()> {
    let model = preset("llama-0.5b").unwrap();
    let gbs = exp::gbs_samples(&model);
    let groups: &[(usize, usize)] =
        &[(0, 4), (4, 0), (4, 1), (4, 2), (4, 3), (4, 4), (3, 4), (2, 4), (1, 4)];

    let mut t = Table::new(&["a800", "v100s", "zero1_tflops", "zero3_tflops",
                             "zero3_per_gpu"]);
    for &(na, nv) in groups {
        let cluster = cluster_c_counts(na, nv);
        let z1 = exp::eval_system(&cluster, &model, 1, Strategy::Poplar, gbs, 7)?;
        let z3 = exp::eval_system(&cluster, &model, 3, Strategy::Poplar, gbs, 7)?;
        t.row(&[
            na.to_string(),
            nv.to_string(),
            format!("{:.1}", z1.tflops),
            format!("{:.1}", z3.tflops),
            format!("{:.1}", z3.tflops / (na + nv) as f64),
        ]);
    }
    println!("{}", t.to_markdown());
    println!("note the ZeRO-3 V4A4-vs-V4A3 inversion the paper's appendix discusses:");
    println!("adding the 8th GPU grows communication faster than compute.");
    println!("quantity_sweep OK");
    Ok(())
}
