//! Quickstart: load the AOT artifacts and take a few real train steps.
//!
//! ```text
//! make artifacts                     # python runs ONCE, never again
//! cargo run --release --example quickstart
//! ```
//!
//! Demonstrates the three-layer architecture end to end: the HLO text
//! under `artifacts/tiny` was lowered from the JAX model (L2) whose hot
//! paths are Pallas kernels (L1); this binary (L3) loads and executes it
//! through PJRT with no python anywhere.

use anyhow::{Context, Result};
use poplar::data::corpus::CorpusStream;
use poplar::data::TokenSource;
use poplar::runtime::{artifacts_dir, load_init_params, Engine};

fn main() -> Result<()> {
    let dir = artifacts_dir("tiny");
    let mut engine = Engine::open(&dir)
        .context("opening artifacts/tiny — run `make artifacts` first")?;
    let meta = engine.meta().clone();
    println!(
        "loaded '{}': {} params, seq {}, batch variants {:?}, pallas kernels: {}",
        meta.preset, meta.param_count, meta.seq, meta.batch_variants, meta.use_pallas
    );
    println!("PJRT platform: {}", engine.platform());

    let mut params = load_init_params(&dir, &meta)?;
    let mut momenta: Vec<Vec<f32>> = params.iter().map(|p| vec![0.0; p.len()]).collect();
    let mut source = CorpusStream::new(meta.vocab as u32);

    let b = meta.batch_variants[0];
    println!("\ntaking 5 fused train steps at micro-batch {b}:");
    let mut first = None;
    let mut last = 0.0;
    for step in 0..5 {
        let tokens = source.batch(b, meta.seq + 1);
        let out = engine.run_fused_step(b, &mut params, &mut momenta, &tokens)?;
        println!("  step {step}: loss = {:.4}", out.loss);
        first.get_or_insert(out.loss);
        last = out.loss;
    }
    let first = first.unwrap();
    println!("\nloss moved {first:.4} -> {last:.4}; the model is learning. Quickstart OK.");
    assert!(last < first, "loss should decrease over the first steps");
    Ok(())
}
