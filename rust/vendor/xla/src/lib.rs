//! Offline stub of the `xla` (xla_extension / PJRT) bindings.
//!
//! The original development image carried a vendored `xla_extension`
//! build (see `/opt/xla-example` references in `runtime::engine`); this
//! container does not, and there is no registry to fetch it from. This
//! stub keeps `runtime::engine` compiling with the exact API surface it
//! uses, while failing fast at *runtime*: [`PjRtClient::cpu`] returns an
//! error, so `Engine::open` reports "PJRT runtime unavailable" instead
//! of crashing later. The simulated training/evaluation paths (the
//! paper's figures, the coordinator, the elastic runtime) never touch
//! this crate.
//!
//! Swapping this stub for the real bindings is a one-line change in
//! `rust/Cargo.toml` (point the `xla` path dependency at the vendored
//! build).

/// Error type of the stub — everything fails with `Unavailable`.
#[derive(Debug, Clone)]
pub struct Error {
    msg: String,
}

impl Error {
    fn unavailable() -> Self {
        Error {
            msg: "PJRT runtime unavailable: xla_extension is not vendored in this image \
                  (simulated paths are unaffected; see rust/vendor/xla)"
                .to_string(),
        }
    }
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl std::error::Error for Error {}

/// Result alias matching the real crate's signatures.
pub type Result<T> = std::result::Result<T, Error>;

/// Element types PJRT buffers can hold (subset the engine uses).
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for i32 {}

/// A parsed HLO module (stub).
#[derive(Debug)]
pub struct HloModuleProto(());

impl HloModuleProto {
    /// Parse an HLO text file. Always fails in the stub.
    pub fn from_text_file(_path: &str) -> Result<Self> {
        Err(Error::unavailable())
    }
}

/// An XLA computation (stub).
#[derive(Debug)]
pub struct XlaComputation(());

impl XlaComputation {
    /// Wrap a parsed module.
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation(())
    }
}

/// A device-resident buffer (stub — cannot be constructed).
#[derive(Debug)]
pub struct PjRtBuffer(());

impl PjRtBuffer {
    /// Copy the buffer back to host as a literal.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable())
    }
}

/// A host literal (stub — cannot be constructed).
#[derive(Debug)]
pub struct Literal(());

impl Literal {
    /// Destructure a tuple literal.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(Error::unavailable())
    }

    /// Read the literal as a flat vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(Error::unavailable())
    }

    /// Read the first element.
    pub fn get_first_element<T: NativeType>(&self) -> Result<T> {
        Err(Error::unavailable())
    }
}

/// A compiled executable (stub — cannot be constructed).
#[derive(Debug)]
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    /// Execute with rust-owned buffer arguments.
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable())
    }
}

/// A PJRT client (stub).
#[derive(Debug)]
pub struct PjRtClient(());

impl PjRtClient {
    /// Create the CPU client. Always fails in the stub — the caller
    /// (`Engine::open`) surfaces the message.
    pub fn cpu() -> Result<Self> {
        Err(Error::unavailable())
    }

    /// Platform name for logs.
    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    /// Compile a computation.
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable())
    }

    /// Upload a host buffer.
    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        Err(Error::unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn everything_fails_unavailable() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo").is_err());
        let msg = format!("{}", Error::unavailable());
        assert!(msg.contains("unavailable"));
    }
}
