//! Minimal API-compatible subset of `anyhow` for the offline image.
//!
//! The container has no crates.io registry, so the real crate cannot be
//! fetched; this shim implements exactly the surface the workspace uses:
//!
//! * [`Error`] — a message + cause chain, `{}` prints the top message,
//!   `{:#}` prints the whole chain separated by `": "` (matching anyhow's
//!   alternate formatting);
//! * [`Result<T>`] with `Error` as the default error type;
//! * `?`-conversion from any `std::error::Error` (the blanket `From`);
//! * [`anyhow!`] / [`bail!`] macros;
//! * [`Context`] with `.context(..)` / `.with_context(..)` on `Result`s
//!   whose error is either a std error or already an [`Error`].
//!
//! The impl structure (private `ChainError` trait with a blanket impl for
//! std errors plus a concrete impl for `Error`, and `Error` deliberately
//! NOT implementing `std::error::Error`) mirrors upstream anyhow — it is
//! what makes the blanket `From` and the dual `Context` impls coherent.

/// `Result<T, anyhow::Error>` with the error defaulted.
pub type Result<T, E = Error> = core::result::Result<T, E>;

/// A dynamic error: top-level message plus a chain of causes.
pub struct Error {
    /// `chain[0]` is the top message; the rest are causes, outermost first.
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a displayable message (what `anyhow!` expands to).
    pub fn msg<M: std::fmt::Display>(message: M) -> Self {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: std::fmt::Display>(self, context: C) -> Self {
        let mut chain = vec![context.to_string()];
        chain.extend(self.chain);
        Error { chain }
    }

    /// The cause chain, outermost message first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The top-level message.
    pub fn root_message(&self) -> &str {
        &self.chain[0]
    }
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if f.alternate() {
            // `{:#}`: whole chain, anyhow-style
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl std::fmt::Debug for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, cause) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {cause}")?;
            }
        }
        Ok(())
    }
}

/// Blanket conversion from any std error, capturing its source chain.
/// Coherent with `impl From<T> for T` because `Error` itself does not
/// implement `std::error::Error`.
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

mod private {
    /// Sealed dispatch: "something that can become an [`crate::Error`]".
    pub trait ChainError {
        fn into_chain_error(self) -> crate::Error;
    }

    impl<E> ChainError for E
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        fn into_chain_error(self) -> crate::Error {
            crate::Error::from(self)
        }
    }

    impl ChainError for crate::Error {
        fn into_chain_error(self) -> crate::Error {
            self
        }
    }
}

/// Adds `.context(..)` / `.with_context(..)` to `Result`.
pub trait Context<T> {
    /// Wrap the error with a context message.
    fn context<C: std::fmt::Display>(self, context: C) -> Result<T>;
    /// Wrap the error with a lazily-built context message.
    fn with_context<C: std::fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E> Context<T> for core::result::Result<T, E>
where
    E: private::ChainError,
{
    fn context<C: std::fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into_chain_error().context(context))
    }

    fn with_context<C: std::fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into_chain_error().context(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return core::result::Result::Err($crate::anyhow!($($arg)*).into())
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn display_and_alternate() {
        let e: Error = io_err().into();
        let e = e.context("opening config");
        assert_eq!(format!("{e}"), "opening config");
        assert_eq!(format!("{e:#}"), "opening config: missing file");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<u8> {
            let v: u8 = "300".parse()?;
            Ok(v)
        }
        assert!(f().is_err());
    }

    #[test]
    fn context_on_anyhow_result() {
        let r: Result<()> = Err(anyhow!("inner {}", 3));
        let e = r.context("outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: inner 3");
    }

    #[test]
    fn with_context_on_std_result() {
        let r: core::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.with_context(|| format!("reading {}", "x")).unwrap_err();
        assert_eq!(format!("{e}"), "reading x");
    }

    #[test]
    fn bail_and_to_string() {
        fn f() -> Result<()> {
            bail!("no compiled variant for b={}", 9)
        }
        let e = f().unwrap_err();
        assert!(e.to_string().contains("no compiled variant"));
    }
}
