//! End-to-end benchmark: regenerate every paper table/figure and time
//! each (one bench per table/figure, per the deliverables). The tables
//! themselves are printed so `cargo bench | tee bench_output.txt`
//! doubles as the experiment record.

use poplar::exp;
use poplar::metrics::Timer;

fn main() {
    let runners: Vec<(&str, fn() -> anyhow::Result<poplar::metrics::Table>)> = vec![
        ("fig1_motivation", exp::fig1::run),
        ("fig3_main_abc_x_stages_x_systems", exp::fig3::run),
        ("fig4_models", exp::fig4::run),
        ("fig5_quantity_scaling", exp::fig5::run),
        ("fig6_batch_curves", exp::fig6::run),
        ("fig7_spline_accuracy", exp::fig7::run),
        ("fig8_capability_measurement", exp::fig8::run),
        ("table2_overhead", exp::table2::run),
        ("ablation", exp::ablation::run),
    ];
    for (name, f) in runners {
        let t = Timer::start();
        match f() {
            Ok(table) => {
                println!(
                    "\n### bench {name}: regenerated in {:.3}s ({} rows)\n",
                    t.elapsed_s(),
                    table.len()
                );
                println!("{}", table.to_markdown());
            }
            Err(e) => {
                eprintln!("bench {name} FAILED: {e:#}");
                std::process::exit(1);
            }
        }
    }
    println!("\nall figure benches complete");
}
