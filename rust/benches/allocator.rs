//! Benchmarks for the L3 hot paths: Alg. 1 profiling, curve fitting and
//! Alg. 2 planning — DESIGN.md §Perf targets <10 ms for 8-GPU plans.
//!
//! Built with the in-crate harness (no criterion on this offline image);
//! run with `cargo bench` (all bench targets use `harness = false`).

use poplar::allocator::{self, baselines};
use poplar::cluster::{self, LinkKind};
use poplar::config::model::preset;
use poplar::coordinator::fit_curves;
use poplar::curves::PerfCurve;
use poplar::metrics::bench::{bench, section};
use poplar::netsim::NetSim;
use poplar::profiler::{profile_cluster, Device, SimDevice};

fn devices(n_a: usize, n_v: usize) -> Vec<Box<dyn Device>> {
    let model = preset("llama-0.5b").unwrap();
    let net = NetSim::from_link(n_a + n_v, LinkKind::Ib);
    let mut out: Vec<Box<dyn Device>> = Vec::new();
    for r in 0..(n_a + n_v) {
        let gpu = if r < n_a { "A800-80G" } else { "V100S-32G" };
        out.push(Box::new(SimDevice::new(
            cluster::spec_or_panic(gpu),
            model.clone(),
            r,
            n_a + n_v,
            net.clone(),
            0.01,
            9,
        )));
    }
    out
}

fn curves_for(stage: u8) -> Vec<PerfCurve> {
    let mut devs = devices(4, 4);
    let prof = profile_cluster(&mut devs, stage).unwrap();
    fit_curves(&prof).unwrap()
}

fn main() {
    let model = preset("llama-0.5b").unwrap();
    let psi = model.param_count();
    let net = NetSim::from_link(8, LinkKind::Ib);

    section("profiler (Algorithm 1)");
    let r = bench("profile_cluster/8gpu/zero1", 300, || {
        let mut devs = devices(4, 4);
        profile_cluster(&mut devs, 1).unwrap()
    });
    println!("{}", r.line());

    section("curve fitting");
    let mut devs = devices(4, 4);
    let prof = profile_cluster(&mut devs, 1).unwrap();
    let r = bench("fit_curves/8gpu", 300, || fit_curves(&prof).unwrap());
    println!("{}", r.line());

    section("allocator (Algorithm 2)");
    let c1 = curves_for(1);
    let c3 = curves_for(3);
    let r = bench("plan_zero01/8gpu/gbs2048", 300, || {
        allocator::plan_zero01(&c1, 1, 2048).unwrap()
    });
    println!("{}", r.line());
    let r = bench("plan_zero23/8gpu/gbs2048 (t-sweep)", 300, || {
        allocator::plan_zero23(&c3, 3, 2048, &net, psi).unwrap()
    });
    println!("{}", r.line());
    let r = bench("plan_uniform/8gpu/gbs2048", 300, || {
        baselines::plan_uniform(&c3, 3, 2048, &net, psi).unwrap()
    });
    println!("{}", r.line());

    section("curve queries");
    let r = bench("find(t) x 1000", 200, || {
        let mut acc = 0usize;
        for i in 0..1000 {
            acc += c3[i % c3.len()].find(0.001 * (i % 50) as f64);
        }
        acc
    });
    println!("{}", r.line());

    // perf gate (DESIGN.md §Perf): an 8-GPU plan must be < 10 ms
    let plan_bench = bench("plan_zero23 gate", 200, || {
        allocator::plan_zero23(&c3, 3, 2048, &net, psi).unwrap()
    });
    assert!(
        plan_bench.mean_ns < 10e6,
        "plan_zero23 too slow: {:.2} ms",
        plan_bench.mean_ns / 1e6
    );
    println!("\nperf gate OK: 8-GPU ZeRO-3 plan in {:.2} ms", plan_bench.mean_ns / 1e6);
}
