//! Benchmarks for the joint-round decision engine (`policy::decide_round`)
//! across fleet size x offer-batch size — the scalability claim behind
//! the greedy marginal-contribution search: the exponential mask loop
//! capped real deployments at 6 offers, the greedy path must price a
//! 100-offer batch against a 1000-rank fleet in one call. A trailing
//! case times the measured-fabric loop (`netsim::BwMonitor` warm-up +
//! sustained congestion shift + the replan it triggers) — the leader
//! pays it inline every iteration, so it must stay cheap at fleet scale.
//! A second trailing case times the pipeline-grouping search
//! (`policy::decide_round` with `allow_pipeline` over an all-starved
//! offer pool) — the virtual-rank arm rides the same round call. A
//! third (`round_extend_indexed`) isolates the greedy growth step:
//! one `ElasticPlanner::round_index` build, then a chain of
//! `preview_round_extend_with` delta pricings.
//!
//! Built with the in-crate harness (no criterion on this offline image);
//! run with `cargo bench --bench policy`. Pass `--fast` / `--test` (or
//! set `POPLAR_BENCH_FAST`) for the CI smoke subset.
//!
//! Results are written to `BENCH_policy.json` (package root, committed):
//!
//! ```json
//! {
//!   "format": "poplar-bench-policy/v1",
//!   "mode": "full" | "fast",
//!   "points": [
//!     { "ranks": 8, "offers": 2, "search": "exhaustive",
//!       "mean_ms": 0.8, "p50_ms": 0.7, "p95_ms": 1.1, "samples": 240 }
//!   ]
//! }
//! ```
//!
//! `search` records which path `SearchMode::Auto` dispatched to at that
//! batch size (exhaustive for k <= MAX_EXHAUSTIVE_OFFERS, greedy above).
//! The committed seed may carry an empty `points` list (the build image
//! has no local toolchain and CI regenerates the file on every run); the
//! format line is the contract.

use poplar::autoscale::synthesize_curve;
use poplar::cluster::LinkKind;
use poplar::config::model::preset;
use poplar::elastic::ElasticPlanner;
use poplar::metrics::bench::{bench, section, BenchResult};
use poplar::netsim::{BwMonitor, NetSim};
use poplar::policy::{self, RoundOptions, MAX_EXHAUSTIVE_OFFERS};

const OFFER_POOL: &[&str] = &["A800-80G", "V100S-32G", "T4", "RTX4090"];

/// An alternating A800/V100S fleet of `n` ranks, profiled and planned at
/// ZeRO-1, with every offer-pool type pre-cached at the stage (the bench
/// measures the search, not profiling round-trips).
fn fleet(n: usize) -> (ElasticPlanner, NetSim) {
    let m = preset("llama-0.5b").unwrap();
    let stage = 1u8;
    let mut p = ElasticPlanner::new(stage, 8 * n, &m.name, m.param_count(), 64);
    for i in 0..n {
        let gpu = if i % 2 == 0 { "A800-80G" } else { "V100S-32G" };
        let slot = p.add_slot(gpu);
        if p.slots()[slot].curve.is_none() {
            let c = synthesize_curve(gpu, &m, stage, n).unwrap();
            p.install_curve(slot, c, false).unwrap();
        }
    }
    for gpu in OFFER_POOL {
        let c = synthesize_curve(gpu, &m, stage, n).unwrap();
        p.install_stage_curve(gpu, stage, c).unwrap();
    }
    let net = NetSim::from_link(n, LinkKind::Ib);
    p.replan(&net).unwrap();
    (p, net)
}

fn offer_batch(k: usize) -> Vec<String> {
    (0..k).map(|i| OFFER_POOL[i % OFFER_POOL.len()].to_string()).collect()
}

fn json_point(ranks: usize, offers: usize, search: &str, r: &BenchResult) -> String {
    format!(
        "    {{ \"ranks\": {ranks}, \"offers\": {offers}, \"search\": \"{search}\", \
         \"mean_ms\": {:.6}, \"p50_ms\": {:.6}, \"p95_ms\": {:.6}, \"samples\": {} }}",
        r.mean_ns / 1e6,
        r.p50_ns / 1e6,
        r.p95_ns / 1e6,
        r.samples
    )
}

fn main() {
    let fast = std::env::args().any(|a| a == "--test" || a == "--fast")
        || std::env::var("POPLAR_BENCH_FAST").is_ok();
    let mode = if fast { "fast" } else { "full" };
    let (sizes, batches, target_ms): (&[usize], &[usize], u64) = if fast {
        (&[8, 64], &[2, 6, 32], 30)
    } else {
        (&[8, 64, 1000], &[2, 6, 32, 100], 200)
    };

    let m = preset("llama-0.5b").unwrap();
    let mut points = Vec::new();
    for &n in sizes {
        section(&format!("decide_round @ {n} ranks"));
        let (p, net) = fleet(n);
        for &k in batches {
            let offers = offer_batch(k);
            let search = if k <= MAX_EXHAUSTIVE_OFFERS { "exhaustive" } else { "greedy" };
            let opts = RoundOptions::default();
            let name = format!("decide_round/{n}ranks/{k}offers/{search}");
            let r = bench(&name, target_ms, || {
                policy::decide_round(&p, &net, &m, &offers, &opts).unwrap()
            });
            println!("{}", r.line());
            assert!(r.mean_ns > 0.0);
            points.push(json_point(n, k, search, &r));
        }
    }

    // the measured-fabric hot path: one monitor warm-up, a sustained
    // congestion shift, and the replan it triggers — the latency budget
    // of the leader's per-iteration step (5b) plus the next replan
    section("bw monitor + replan trigger");
    {
        let n = if fast { 64 } else { 1000 };
        let (mut p, net) = fleet(n);
        let name = format!("bw_monitor_replan/{n}ranks");
        let r = bench(&name, target_ms, || {
            let mut mon = BwMonitor::new(LinkKind::Ib);
            for _ in 0..3 {
                mon.observe(net.bw_gbs);
            }
            let mut shifted = false;
            for _ in 0..4 {
                shifted |= mon.observe(net.bw_gbs * 0.2).is_some();
            }
            assert!(shifted, "sustained congestion must signal");
            let snap = mon.snapshot(n);
            p.mark_dirty();
            p.replan(&snap).unwrap().total_samples()
        });
        println!("{}", r.line());
        assert!(r.mean_ns > 0.0);
        points.push(json_point(n, 0, "bw-monitor", &r));
    }

    // the greedy growth step in isolation: one round-scoped index built
    // up front, then a chain of `preview_round_extend_with` calls — the
    // delta path every greedy admission pays per candidate. This is the
    // number the round-index refactor moves: no per-candidate manifest
    // re-validation, no per-candidate incumbent re-scan.
    section("round extend (indexed delta path)");
    {
        let n = if fast { 64 } else { 1000 };
        let (p, net) = fleet(n);
        let tys: Vec<poplar::intern::TypeId> =
            OFFER_POOL.iter().map(|g| poplar::intern::intern(g)).collect();
        let k = tys.len();
        let name = format!("round_extend_indexed/{n}ranks/{k}steps");
        let r = bench(&name, target_ms, || {
            let idx = p.round_index().unwrap();
            let mut pv = p
                .preview_round_at_with(&idx, 1, &tys[..1], &[None], &net)
                .unwrap();
            for &t in &tys[1..] {
                pv = p.preview_round_extend_with(&idx, &pv, t, None, &net).unwrap();
            }
            pv.curves.len()
        });
        println!("{}", r.line());
        assert!(r.mean_ns > 0.0);
        points.push(json_point(n, k, "extend-indexed", &r));
    }

    // the virtual-rank arm: every offer is memory-starved at every ZeRO
    // stage, so decide_round runs the full grouping search (starvation
    // scan, anchor-first packing, per-group layer partition + composed
    // curve, delta-priced preview) on top of the ordinary per-offer
    // pricing — the leader pays this inline whenever `allow_pipeline`
    // is armed, so it must stay in the same budget as a plain round
    section("grouping search (pipeline virtual ranks)");
    {
        let lm = preset("longctx-0.4b").unwrap();
        let gbs = poplar::exp::gbs_samples(&lm);
        let net = NetSim::from_link(2, LinkKind::Ib);
        let plans = poplar::exp::fig_pipeline::bootstrap_groups(&net).unwrap();
        let mut p = ElasticPlanner::new(3, gbs, &lm.name, lm.param_count(), 32);
        for gp in &plans {
            p.add_group_slot(gp);
        }
        p.replan(&net).unwrap();
        let offers: Vec<String> = poplar::exp::fig_pipeline::POOL
            .iter()
            .map(|s| s.to_string())
            .collect();
        let k = offers.len();
        let opts =
            RoundOptions { allow_pipeline: true, min_gain: 0.01, ..Default::default() };
        let name = format!("grouping_search/{}vranks/{k}offers", plans.len());
        let r = bench(&name, target_ms, || {
            let round = policy::decide_round(&p, &net, &lm, &offers, &opts).unwrap();
            assert!(round.grouping.is_some(), "starved pool must yield a group");
            round.offers.len()
        });
        println!("{}", r.line());
        assert!(r.mean_ns > 0.0);
        points.push(json_point(plans.len(), k, "grouping", &r));
    }

    let json = format!(
        "{{\n  \"format\": \"poplar-bench-policy/v1\",\n  \"mode\": \"{mode}\",\n  \
         \"points\": [\n{}\n  ]\n}}\n",
        points.join(",\n")
    );
    std::fs::write("BENCH_policy.json", &json).expect("write BENCH_policy.json");
    println!("\nwrote BENCH_policy.json ({} points, {mode} mode)", points.len());
}
