//! Benchmarks for the coordinator's steady-state hot loop — the two
//! per-iteration costs a long-running job pays at fleet scale:
//!
//! * `run_iteration`: one live BSP iteration end-to-end (command
//!   fan-out, O(1) slot-indexed reply matching, single-pass timeline
//!   reconstruction — no per-micro-step transposed allocation) at
//!   ZeRO-2, where the step-max sweep dominates;
//! * `replan`: the leader-side replan loop (`plan_from_profile`:
//!   curve fitting + Algorithm 2) over the same fleet — the cost of
//!   every membership/drift-triggered replan.
//!
//! Sizes follow the scalability grid of `benches/policy.rs`: 8 and 64
//! ranks in the CI smoke subset, 1000 in the full run. Built with the
//! in-crate harness (no criterion on this offline image); run with
//! `cargo bench --bench leader`. Pass `--fast` / `--test` (or set
//! `POPLAR_BENCH_FAST`) for the CI smoke subset.
//!
//! Results are written to `BENCH_leader.json` (package root, committed):
//!
//! ```json
//! {
//!   "format": "poplar-bench-leader/v1",
//!   "mode": "full" | "fast",
//!   "points": [
//!     { "ranks": 8, "case": "run_iteration",
//!       "mean_ms": 0.9, "p50_ms": 0.8, "p95_ms": 1.2, "samples": 240 }
//!   ]
//! }
//! ```
//!
//! The committed seed may carry an empty `points` list (the build image
//! has no local toolchain and CI regenerates the file on every run); the
//! format line is the contract.

use poplar::cluster::{ClusterSpec, LinkKind};
use poplar::config::model::preset;
use poplar::config::Strategy;
use poplar::coordinator::Leader;
use poplar::metrics::bench::{bench, section, BenchResult};

/// A half-A800 / half-V100S fleet of `n` ranks on the cluster-C links —
/// heterogeneous enough that the allocator's split is non-trivial, with
/// noise off so every sample prices the same timeline.
fn fleet(n: usize) -> ClusterSpec {
    ClusterSpec::new(
        "bench-fleet",
        &[
            ("A800-80G", n / 2, LinkKind::Pcie),
            ("V100S-32G", n - n / 2, LinkKind::Pcie),
        ],
        LinkKind::Ib,
    )
}

fn json_point(ranks: usize, case: &str, r: &BenchResult) -> String {
    format!(
        "    {{ \"ranks\": {ranks}, \"case\": \"{case}\", \
         \"mean_ms\": {:.6}, \"p50_ms\": {:.6}, \"p95_ms\": {:.6}, \"samples\": {} }}",
        r.mean_ns / 1e6,
        r.p50_ns / 1e6,
        r.p95_ns / 1e6,
        r.samples
    )
}

fn main() {
    let fast = std::env::args().any(|a| a == "--test" || a == "--fast")
        || std::env::var("POPLAR_BENCH_FAST").is_ok();
    let mode = if fast { "fast" } else { "full" };
    let (sizes, target_ms): (&[usize], u64) =
        if fast { (&[8, 64], 30) } else { (&[8, 64, 1000], 200) };

    let model = preset("llama-0.5b").unwrap();
    let mut points = Vec::new();
    for &n in sizes {
        section(&format!("leader hot loop @ {n} ranks"));
        let cluster = fleet(n);
        let mut leader = Leader::new_simulated(&cluster, &model, 0.0, 7);
        // ZeRO-2: the timeline reconstruction takes the step-max arm
        // (grad bucketing => per-micro-step barrier), the heavier path
        let profile = leader.profile(2).unwrap();
        let gbs = 8 * n;

        let name = format!("replan/{n}ranks");
        let r = bench(&name, target_ms, || {
            leader.plan_from_profile(&profile, Strategy::Poplar, gbs).unwrap()
        });
        println!("{}", r.line());
        assert!(r.mean_ns > 0.0);
        points.push(json_point(n, "replan", &r));

        let plan = leader.plan_from_profile(&profile, Strategy::Poplar, gbs).unwrap();
        let name = format!("run_iteration/{n}ranks");
        let r = bench(&name, target_ms, || {
            let it = leader.run_iteration(&plan).unwrap();
            assert!(it.wall_s > 0.0);
            it.wall_s
        });
        println!("{}", r.line());
        assert!(r.mean_ns > 0.0);
        points.push(json_point(n, "run_iteration", &r));

        leader.shutdown();
    }

    let json = format!(
        "{{\n  \"format\": \"poplar-bench-leader/v1\",\n  \"mode\": \"{mode}\",\n  \
         \"points\": [\n{}\n  ]\n}}\n",
        points.join(",\n")
    );
    std::fs::write("BENCH_leader.json", &json).expect("write BENCH_leader.json");
    println!("\nwrote BENCH_leader.json ({} points, {mode} mode)", points.len());
}
