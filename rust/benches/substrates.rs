//! Benchmarks for the substrates: spline, device model, collectives,
//! memory model, data loader, ZeRO iteration simulation.

use poplar::allocator;
use poplar::cluster::{self, LinkKind};
use poplar::config::model::preset;
use poplar::coordinator::fit_curves;
use poplar::data::{DynamicLoader, SyntheticStream};
use poplar::memmodel;
use poplar::metrics::bench::{bench, section};
use poplar::netsim::{Collective, NetSim};
use poplar::profiler::{profile_cluster, Device, SimDevice};
use poplar::spline::CubicSpline;
use poplar::zero::{simulate_iteration, DeviceOracle};

fn main() {
    section("spline");
    let xs: Vec<f64> = (1..=64).map(|i| i as f64).collect();
    let ys: Vec<f64> = xs.iter().map(|x| x / (x + 3.0)).collect();
    let r = bench("fit/64 knots", 200, || CubicSpline::fit(&xs, &ys).unwrap());
    println!("{}", r.line());
    let s = CubicSpline::fit(&xs, &ys).unwrap();
    let r = bench("eval x 1000", 200, || {
        let mut acc = 0.0;
        for i in 0..1000 {
            acc += s.eval(1.0 + (i % 630) as f64 * 0.1);
        }
        acc
    });
    println!("{}", r.line());

    section("device model");
    let spec = cluster::spec_or_panic("A100-80G");
    let model = preset("llama-0.5b").unwrap();
    let fpt = model.flops_per_token();
    let r = bench("compute_time x 1000", 200, || {
        let mut acc = 0.0;
        for b in 1..=1000u64 {
            acc += spec.compute_time((b * 1024) as f64, fpt, 24);
        }
        acc
    });
    println!("{}", r.line());

    section("netsim collectives");
    let net = NetSim::from_link(8, LinkKind::Ib);
    let r = bench("allreduce cost x 1000", 200, || {
        let mut acc = 0.0;
        for i in 0..1000u64 {
            acc += net.time(Collective::AllReduce, i << 20);
        }
        acc
    });
    println!("{}", r.line());

    section("memory model");
    let psi = model.param_count();
    let r = bench("true_mbs x 1000", 200, || {
        let mut acc = 0usize;
        for s in 0..4u8 {
            for _ in 0..250 {
                acc += memmodel::true_mbs(&model, psi, s, 8, 80 << 30);
            }
        }
        acc
    });
    println!("{}", r.line());

    section("data loader");
    let mut devs: Vec<Box<dyn Device>> = (0..8)
        .map(|r| {
            let gpu = if r < 4 { "A800-80G" } else { "V100S-32G" };
            Box::new(SimDevice::new(
                cluster::spec_or_panic(gpu),
                model.clone(),
                r,
                8,
                net.clone(),
                0.0,
                1,
            )) as Box<dyn Device>
        })
        .collect();
    let prof = profile_cluster(&mut devs, 1).unwrap();
    let curves = fit_curves(&prof).unwrap();
    let plan = allocator::plan_zero01(&curves, 1, 512).unwrap();
    let r = bench("iteration batches/512 samples seq64", 300, || {
        let mut dl = DynamicLoader::new(SyntheticStream::new(3, 1024), 64);
        dl.iteration(&plan)
    });
    println!("{}", r.line());

    section("zero iteration simulation");
    let specs = (0..8)
        .map(|r| cluster::spec_or_panic(if r < 4 { "A800-80G" } else { "V100S-32G" }))
        .collect();
    let oracle = DeviceOracle { specs, model: &model };
    let r = bench("simulate_iteration/8gpu", 300, || {
        simulate_iteration(&plan, &oracle, &net, &model).unwrap()
    });
    println!("{}", r.line());
}
