//! Crate-wide string interning for GPU/model type names.
//!
//! The planner hot paths (round previews, manifest builds, curve-cache
//! lookups) used to shuttle GPU type names around as `String`s — ~90
//! `clone()` sites across `elastic`/`ckpt`/`policy`, each a heap
//! round-trip inside loops that run once per candidate per round. A
//! [`TypeId`] is a `Copy` handle into a process-global append-only name
//! table: comparisons are one `u32` compare, moves are free, and the
//! display string is resolved only at report/CLI boundaries.
//!
//! Design rules:
//!
//! * **Identity**: `intern(name)` returns the same id for the same
//!   string for the lifetime of the process; ids are dense and small.
//! * **Ordering is lexicographic**, not insertion order — `TypeId`
//!   sorts exactly like the `String` it replaced, so every sorted
//!   report, BTreeMap key and tie-break stays byte-identical.
//! * **`Debug` matches `String`'s** (quoted), so derived `Debug` output
//!   of structs that swapped `String` → `TypeId` does not change.
//! * The table only grows; leaked names are bounded by the set of
//!   distinct GPU/model names ever seen (a handful in practice). The
//!   running total is exposed as [`stats`]`().bytes_interned` so tests
//!   can pin that hot paths stop re-interning.

use std::collections::HashMap;
use std::fmt;
use std::ops::Deref;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};

/// Interned name handle: `Copy`, 4 bytes, O(1) equality. Obtain via
/// [`intern`]; resolve via [`TypeId::as_str`] / `Display` / `Deref`.
#[derive(Clone, Copy, PartialEq, Eq, Hash)]
pub struct TypeId(u32);

struct Interner {
    /// id -> leaked name, append-only.
    names: Vec<&'static str>,
    /// name -> id reverse map.
    ids: HashMap<&'static str, u32>,
}

fn table() -> &'static Mutex<Interner> {
    static TABLE: OnceLock<Mutex<Interner>> = OnceLock::new();
    TABLE.get_or_init(|| Mutex::new(Interner { names: Vec::new(), ids: HashMap::new() }))
}

/// Total bytes of distinct names leaked into the table so far (the
/// `bytes_interned` perf counter: flat once the working set of type
/// names has been seen — hot paths must not mint new strings).
static BYTES_INTERNED: AtomicU64 = AtomicU64::new(0);

/// Intern `name`, returning its stable process-wide [`TypeId`].
pub fn intern(name: &str) -> TypeId {
    let mut t = table().lock().unwrap_or_else(|e| e.into_inner());
    if let Some(&id) = t.ids.get(name) {
        return TypeId(id);
    }
    let leaked: &'static str = Box::leak(name.to_string().into_boxed_str());
    let id = t.names.len() as u32;
    t.names.push(leaked);
    t.ids.insert(leaked, id);
    BYTES_INTERNED.fetch_add(name.len() as u64, Ordering::Relaxed);
    TypeId(id)
}

/// Intern-table statistics (perf counters for complexity tests).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct InternStats {
    /// Distinct names resident in the table.
    pub types: usize,
    /// Total bytes of distinct names interned since process start.
    pub bytes_interned: u64,
}

/// Current table statistics. `bytes_interned` is monotone; a hot loop
/// that keeps minting new names shows up as growth between snapshots.
pub fn stats() -> InternStats {
    let t = table().lock().unwrap_or_else(|e| e.into_inner());
    InternStats { types: t.names.len(), bytes_interned: BYTES_INTERNED.load(Ordering::Relaxed) }
}

impl TypeId {
    /// Resolve the interned name. The returned `&'static str` outlives
    /// every borrow, so callers can hold it across planner mutations.
    pub fn as_str(self) -> &'static str {
        let t = table().lock().unwrap_or_else(|e| e.into_inner());
        t.names[self.0 as usize]
    }

    /// Raw table index (diagnostics only — dense, insertion-ordered).
    pub fn index(self) -> u32 {
        self.0
    }
}

impl Deref for TypeId {
    type Target = str;
    fn deref(&self) -> &'static str {
        self.as_str()
    }
}

impl AsRef<str> for TypeId {
    fn as_ref(&self) -> &str {
        self.as_str()
    }
}

impl fmt::Display for TypeId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

// `Debug` delegates to the *string's* Debug (quoted) so structs that
// swapped a `String` field for `TypeId` keep byte-identical derived
// Debug output.
impl fmt::Debug for TypeId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Debug::fmt(self.as_str(), f)
    }
}

// Lexicographic order — identical to the `String` ordering this type
// replaces, so sorted tables and BTreeMap iteration stay byte-identical.
impl Ord for TypeId {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        if self.0 == other.0 {
            std::cmp::Ordering::Equal
        } else {
            self.as_str().cmp(other.as_str())
        }
    }
}

impl PartialOrd for TypeId {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl From<&str> for TypeId {
    fn from(s: &str) -> Self {
        intern(s)
    }
}

impl From<&String> for TypeId {
    fn from(s: &String) -> Self {
        intern(s)
    }
}

impl PartialEq<str> for TypeId {
    fn eq(&self, other: &str) -> bool {
        self.as_str() == other
    }
}

impl PartialEq<&str> for TypeId {
    fn eq(&self, other: &&str) -> bool {
        self.as_str() == *other
    }
}

impl PartialEq<String> for TypeId {
    fn eq(&self, other: &String) -> bool {
        self.as_str() == other.as_str()
    }
}

impl PartialEq<TypeId> for str {
    fn eq(&self, other: &TypeId) -> bool {
        self == other.as_str()
    }
}

impl PartialEq<TypeId> for &str {
    fn eq(&self, other: &TypeId) -> bool {
        *self == other.as_str()
    }
}

impl PartialEq<TypeId> for String {
    fn eq(&self, other: &TypeId) -> bool {
        self.as_str() == other.as_str()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_name_same_id_and_bytes_flat() {
        let a = intern("intern-test-A800");
        let b = intern("intern-test-A800");
        assert_eq!(a, b);
        assert_eq!(a.as_str(), "intern-test-A800");
        let before = stats().bytes_interned;
        // re-interning an existing name must not grow the table
        for _ in 0..100 {
            let _ = intern("intern-test-A800");
        }
        assert_eq!(stats().bytes_interned, before);
    }

    #[test]
    fn distinct_names_distinct_ids() {
        let a = intern("intern-test-x1");
        let b = intern("intern-test-x2");
        assert_ne!(a, b);
        assert_ne!(a.as_str(), b.as_str());
    }

    #[test]
    fn ordering_is_lexicographic_like_string() {
        // interned in reverse lexicographic order on purpose: the Ord
        // impl must still sort by name, not by table index
        let z = intern("intern-test-zzz");
        let a = intern("intern-test-aaa");
        let mut v = vec![z, a];
        v.sort();
        assert_eq!(v, vec![a, z]);
        assert!(a < z);
        let mut s = vec!["intern-test-zzz".to_string(), "intern-test-aaa".to_string()];
        s.sort();
        assert_eq!(v.iter().map(|t| t.to_string()).collect::<Vec<_>>(), s);
    }

    #[test]
    fn debug_matches_string_debug_and_display_is_bare() {
        let t = intern("intern-test-T4");
        assert_eq!(format!("{t:?}"), format!("{:?}", "intern-test-T4"));
        assert_eq!(format!("{t}"), "intern-test-T4");
    }

    #[test]
    fn cross_type_equality_both_ways() {
        let t = intern("intern-test-V100");
        assert_eq!(t, "intern-test-V100");
        assert_eq!("intern-test-V100", t);
        assert_eq!(t, "intern-test-V100".to_string());
        assert_eq!("intern-test-V100".to_string(), t);
        assert!(t != "intern-test-other");
    }

    #[test]
    fn deref_and_as_ref_reach_str_methods() {
        let t = intern("intern-test-RTX");
        assert_eq!(t.len(), "intern-test-RTX".len());
        fn takes_str(s: &str) -> usize {
            s.len()
        }
        assert_eq!(takes_str(&t), t.len());
        assert_eq!(t.as_ref() as &str, "intern-test-RTX");
    }
}
