//! Minimal micro-benchmark harness (the offline image carries no
//! criterion). Auto-calibrates iteration counts to a target runtime and
//! reports mean / p50 / p95 like criterion's summary line.

use std::time::Instant;

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// Benchmark name.
    pub name: String,
    /// Measured samples.
    pub samples: usize,
    /// Mean nanoseconds per iteration.
    pub mean_ns: f64,
    /// Median nanoseconds.
    pub p50_ns: f64,
    /// 95th-percentile nanoseconds.
    pub p95_ns: f64,
}

impl BenchResult {
    /// criterion-style one-liner.
    pub fn line(&self) -> String {
        format!(
            "{:<44} time: [{} {} {}]  ({} samples)",
            self.name,
            fmt_ns(self.mean_ns),
            fmt_ns(self.p50_ns),
            fmt_ns(self.p95_ns),
            self.samples
        )
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Run `f` repeatedly for ~`target_ms` and report statistics. The
/// closure's return value is black-boxed to keep the optimizer honest.
pub fn bench<T, F: FnMut() -> T>(name: &str, target_ms: u64, mut f: F) -> BenchResult {
    // warm-up + calibration
    let t0 = Instant::now();
    std::hint::black_box(f());
    let once = t0.elapsed().as_nanos().max(1) as f64;
    let budget_ns = (target_ms as f64) * 1e6;
    let samples = ((budget_ns / once) as usize).clamp(5, 10_000);

    let mut times = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t = Instant::now();
        std::hint::black_box(f());
        times.push(t.elapsed().as_nanos() as f64);
    }
    // total_cmp: timing samples are always finite, but a comparator that
    // can panic has no place in a measurement harness
    times.sort_by(f64::total_cmp);
    let mean = times.iter().sum::<f64>() / times.len() as f64;
    let p50 = times[times.len() / 2];
    let p95 = times[((times.len() as f64 * 0.95) as usize).min(times.len() - 1)];
    BenchResult { name: name.to_string(), samples, mean_ns: mean, p50_ns: p50, p95_ns: p95 }
}

/// Print a section header.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_stats() {
        let r = bench("noop", 5, || 1 + 1);
        assert!(r.samples >= 5);
        assert!(r.mean_ns > 0.0);
        assert!(r.p95_ns >= r.p50_ns * 0.5);
        assert!(r.line().contains("noop"));
    }

    #[test]
    fn fmt_scales() {
        assert!(fmt_ns(10.0).contains("ns"));
        assert!(fmt_ns(10_000.0).contains("µs"));
        assert!(fmt_ns(10_000_000.0).contains("ms"));
        assert!(fmt_ns(2e9).contains(" s"));
    }
}
