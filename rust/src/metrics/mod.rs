//! Metrics: FLOPs accounting, timers, report tables, and the built-in
//! micro-benchmark harness ([`bench`]).

pub mod bench;

use std::fmt::Write as _;
use std::time::Instant;

/// FLOPs accounting helpers (the Fig. 3-5 "TFLOPs" metric).
pub mod flops {
    use crate::config::model::ModelSpec;

    /// Total fwd+bwd FLOPs to process `samples` sequences.
    pub fn total(model: &ModelSpec, samples: usize) -> f64 {
        model.flops_per_sample() * samples as f64
    }

    /// Cluster TFLOP/s given a wall time.
    pub fn tflops(model: &ModelSpec, samples: usize, wall_s: f64) -> f64 {
        if wall_s <= 0.0 {
            return 0.0;
        }
        total(model, samples) / wall_s / 1e12
    }
}

/// A simple scoped wall-clock timer.
pub struct Timer {
    start: Instant,
}

impl Timer {
    /// Start timing.
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }

    /// Seconds since start.
    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

impl Default for Timer {
    fn default() -> Self {
        Self::start()
    }
}

/// Accumulates labelled rows and renders a GitHub-markdown table.
#[derive(Debug, Clone, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// Create a table with the given column headers.
    pub fn new(header: &[&str]) -> Self {
        Table { header: header.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    /// Append a row (must match the header arity).
    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    /// Convenience: append a row of displayables.
    pub fn rowf(&mut self, cells: &[&dyn std::fmt::Display]) -> &mut Self {
        let v: Vec<String> = cells.iter().map(|c| c.to_string()).collect();
        self.row(&v)
    }

    /// Number of data rows.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// True when the table has no data rows.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Render as GitHub markdown.
    pub fn to_markdown(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "| {} |", self.header.join(" | "));
        let _ = writeln!(
            s,
            "|{}|",
            self.header.iter().map(|_| "---").collect::<Vec<_>>().join("|")
        );
        for r in &self.rows {
            let _ = writeln!(s, "| {} |", r.join(" | "));
        }
        s
    }

    /// Render as CSV.
    pub fn to_csv(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "{}", self.header.join(","));
        for r in &self.rows {
            let _ = writeln!(s, "{}", r.join(","));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::model::preset;

    #[test]
    fn flops_accounting() {
        let m = preset("llama-0.5b").unwrap();
        let t = flops::total(&m, 10);
        assert!(t > 10.0 * 6.0 * m.param_count() as f64 * m.seq as f64 * 0.99);
        assert!((flops::tflops(&m, 10, 2.0) - t / 2.0 / 1e12).abs() < 1e-9);
        assert_eq!(flops::tflops(&m, 10, 0.0), 0.0);
    }

    #[test]
    fn table_roundtrip() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["1".into(), "2".into()]);
        t.rowf(&[&3, &4.5]);
        let md = t.to_markdown();
        assert!(md.contains("| a | b |"));
        assert!(md.contains("| 3 | 4.5 |"));
        let csv = t.to_csv();
        assert!(csv.starts_with("a,b\n"));
        assert_eq!(t.len(), 2);
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn table_arity_checked() {
        Table::new(&["a"]).row(&["1".into(), "2".into()]);
    }

    #[test]
    fn timer_advances() {
        let t = Timer::start();
        std::thread::sleep(std::time::Duration::from_millis(2));
        assert!(t.elapsed_s() > 0.0);
    }
}
