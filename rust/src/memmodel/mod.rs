//! ZeRO per-stage memory accounting.
//!
//! Model-state memory follows the ZeRO paper's mixed-precision layout:
//! fp16 parameters (2ψ) + fp16 gradients (2ψ) + fp32 optimizer states
//! (parameter copy + momentum + variance = 12ψ), partitioned per stage:
//!
//! | stage | resident per rank |
//! |---|---|
//! | 0 | 16ψ |
//! | 1 | 4ψ + 12ψ/n |
//! | 2 | 2ψ + 2ψ/n + 12ψ/n |
//! | 3 | 16ψ/n |
//!
//! Activation memory is linear in the micro-batch size (the linearity
//! Alg. 1's one-batch estimate exploits), with a transient spike the
//! *estimate* does not see — which is exactly why the paper's linear
//! estimate over-predicts `mbs` and needs the binary-search refinement.

use crate::config::model::ModelSpec;

/// Bytes reserved by the framework/context before any tensor (CUDA
/// context, NCCL buffers, allocator pools).
pub const FRAMEWORK_RESERVE_BYTES: u64 = 1_500_000_000;

/// Fraction of activation memory transiently over-allocated at peak
/// (temporaries inside attention/softmax) — invisible to the
/// before/after-forward probe of Alg. 1.
pub const TRANSIENT_FACTOR: f64 = 0.12;

/// Model-state bytes resident on one rank for a ZeRO stage.
///
/// Every public entry point (allocator, profiler, leader, config)
/// rejects stages outside 0..=3 with a typed error before memory
/// accounting runs; if a bad stage slips past them anyway it is priced
/// as ZeRO-0's full replication — the conservative maximum, so the
/// derived `mbs` can only under-estimate, never OOM.
pub fn model_state_bytes(param_count: u64, stage: u8, n_ranks: usize) -> u64 {
    debug_assert!(stage <= 3, "stage {stage} should have been rejected upstream");
    let psi = param_count as f64;
    let n = n_ranks.max(1) as f64;
    let bytes = match stage {
        1 => 4.0 * psi + 12.0 * psi / n,
        2 => 2.0 * psi + 2.0 * psi / n + 12.0 * psi / n,
        3 => 16.0 * psi / n,
        _ => 16.0 * psi,
    };
    bytes as u64
}

/// Steady-state activation bytes for a micro-batch of `batch` samples.
pub fn activation_bytes(model: &ModelSpec, batch: usize) -> u64 {
    model.activation_bytes_per_sample() * batch as u64
}

/// Peak (transient-inclusive) bytes for a step at `batch`.
pub fn peak_bytes(model: &ModelSpec, param_count: u64, stage: u8, n_ranks: usize,
                  batch: usize) -> u64 {
    let act = activation_bytes(model, batch) as f64;
    model_state_bytes(param_count, stage, n_ranks)
        + FRAMEWORK_RESERVE_BYTES
        + (act * (1.0 + TRANSIENT_FACTOR)) as u64
}

/// True maximum batch size that fits in `capacity` bytes (transient
/// included) — the ground truth Alg. 1 searches for.
pub fn true_mbs(model: &ModelSpec, param_count: u64, stage: u8, n_ranks: usize,
                capacity: u64) -> usize {
    let fixed = model_state_bytes(param_count, stage, n_ranks) + FRAMEWORK_RESERVE_BYTES;
    if capacity <= fixed {
        return 0;
    }
    let per = model.activation_bytes_per_sample() as f64 * (1.0 + TRANSIENT_FACTOR);
    ((capacity - fixed) as f64 / per).floor() as usize
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::model::preset;

    #[test]
    fn stage_memory_strictly_decreasing() {
        let psi = 500_000_000;
        let n = 8;
        let m: Vec<u64> = (0..4).map(|s| model_state_bytes(psi, s, n)).collect();
        assert!(m[0] > m[1] && m[1] > m[2] && m[2] > m[3]);
    }

    #[test]
    fn stage0_is_16_psi() {
        assert_eq!(model_state_bytes(100, 0, 8), 1600);
    }

    #[test]
    fn stage3_divides_everything() {
        let psi = 1_000_000_000u64;
        assert_eq!(model_state_bytes(psi, 3, 4), 16 * psi / 4);
    }

    #[test]
    fn single_rank_stages_equal() {
        let psi = 12345678;
        for s in 0..4 {
            assert_eq!(model_state_bytes(psi, s, 1), 16 * psi);
        }
    }

    #[test]
    fn activation_linear_in_batch() {
        let m = preset("llama-0.5b").unwrap();
        assert_eq!(activation_bytes(&m, 8), 8 * activation_bytes(&m, 1));
    }

    #[test]
    fn true_mbs_monotone_in_capacity_and_stage() {
        let m = preset("llama-0.5b").unwrap();
        let psi = m.param_count();
        let cap40 = 40 * (1u64 << 30);
        let cap80 = 80 * (1u64 << 30);
        for s in 0..4 {
            assert!(true_mbs(&m, psi, s, 8, cap80) >= true_mbs(&m, psi, s, 8, cap40));
        }
        // higher stage frees memory -> larger mbs
        assert!(true_mbs(&m, psi, 3, 8, cap40) > true_mbs(&m, psi, 0, 8, cap40));
    }

    #[test]
    fn paper_scenario_0p5b_fits_differently_on_a100_variants() {
        // cluster-A premise: A100-80G supports a larger mbs than A100-40G
        // at the same compute.
        let m = preset("llama-0.5b").unwrap();
        let psi = m.param_count();
        let mbs80 = true_mbs(&m, psi, 1, 8, 80 * (1 << 30));
        let mbs40 = true_mbs(&m, psi, 1, 8, 40 * (1 << 30));
        assert!(mbs80 > mbs40, "{mbs80} vs {mbs40}");
        assert!(mbs40 > 0);
    }

    #[test]
    fn oom_when_states_exceed_capacity() {
        let m = preset("llama-1.1b").unwrap();
        let psi = m.param_count();
        // 1.1B * 16 bytes > 16GB: stage 0 cannot run on a T4
        assert_eq!(true_mbs(&m, psi, 0, 4, 16 * (1 << 30)), 0);
        // stage 3 on 4 ranks fits
        assert!(true_mbs(&m, psi, 3, 4, 16 * (1 << 30)) > 0);
    }
}
