//! `poplar` — CLI for the heterogeneity-aware ZeRO training system.
//!
//! ```text
//! poplar profile   --cluster cluster-C --model llama-0.5b [--stage 1]
//! poplar plan      --cluster cluster-C --model llama-0.5b --gbs-tokens 2097152
//!                  [--stage 2] [--strategy poplar|uniform|flops]
//! poplar simulate  --config job.toml            # profile+plan+iterate (sim)
//! poplar train     --artifacts artifacts/tiny --iters 100 [--gbs 16]
//!                  [--cluster-sim 2xfast+2xslow]  # real PJRT training
//! poplar elastic   --cluster cluster-C --model llama-0.5b [--stage 1]
//!                  [--iters 12] [--events "4:lost:7,6:slow:0:2.5,8:join:A800-80G,9:bw:ib:0.2"]
//!                  [--seed-schedule 7] [--ckpt-dir artifacts/ckpt]
//!                  [--horizon 300] [--min-gain 0.02]   # enables the offer policy
//!                  [--allow-stage-change]   # replan-time ZeRO-stage re-selection
//!                  [--allow-pipeline] [--max-group-size 4]  # virtual-rank pipeline groups
//! poplar autoscale --offer A800-80G,T4[,...] [--cluster cluster-C]
//!                  [--model llama-0.5b] [--stage 1] [--gbs-tokens N]
//!                  [--horizon 300] [--min-gain 0.02] [--noise 0.015]
//!                  [--joint]     # joint subset round (policy::decide_round)
//!                  [--release]   # also consider scale-down (implies round mode)
//!                  [--max-admit N]  # soft cap on offers admitted per round
//! poplar ckpt      save    --cluster cluster-C --model llama-0.5b [--stage 1]
//!                          [--dir artifacts/ckpt] [--snapshot 0]
//! poplar ckpt      inspect [--dir artifacts/ckpt | --path FILE]
//! poplar ckpt      restore --cluster cluster-C --model llama-0.5b
//!                          [--dir artifacts/ckpt | --path FILE] [--lost 7,3]
//!                          [--stage N]   # != checkpoint stage: cross-stage migration
//! poplar exp       <fig1|fig3|fig4|fig5|fig6|fig7|fig8|fig_elastic|fig_autoscale|
//!                   fig_stage_migration|fig_joint_admission|fig_bw_adaptation|
//!                   fig_pipeline|table2|ablation|all>
//!                  [--out results]
//! poplar lint      [--format json] [--write-baseline]   # in-crate invariant analyzer
//! ```
//!
//! Arg parsing is hand-rolled: the offline image carries no clap.

use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

use poplar::cluster::{self, ClusterSpec};
use poplar::config::{model as model_cfg, JobConfig, Strategy};
use poplar::coordinator::Leader;
use poplar::data::corpus::CorpusStream;
use poplar::exp;
use poplar::metrics::Table;
use poplar::train::{Trainer, VirtualGpu};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Split `args` into positionals and `--key value` flags.
fn parse_flags(args: &[String]) -> Result<(Vec<String>, HashMap<String, String>)> {
    let mut pos = Vec::new();
    let mut flags = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(key) = args[i].strip_prefix("--") {
            let val = args
                .get(i + 1)
                .ok_or_else(|| anyhow!("flag --{key} needs a value"))?;
            flags.insert(key.to_string(), val.clone());
            i += 2;
        } else {
            pos.push(args[i].clone());
            i += 1;
        }
    }
    Ok((pos, flags))
}

/// Remove a bare boolean flag (one that takes no value) from the arg
/// list before [`parse_flags`] sees it; returns whether it was present.
fn take_bare_flag(args: &mut Vec<String>, name: &str) -> bool {
    let before = args.len();
    args.retain(|a| a != name);
    args.len() != before
}

/// Parse the `--stage` flag with the 0..=3 bound enforced *here* — a
/// plain `u8` parse accepts 0..=255, and every stage-typed boundary
/// behind the CLI (planner, profiler, manifest builder) must only ever
/// see a validated stage.
fn parse_stage(f: &HashMap<String, String>, default: u8) -> Result<u8> {
    let stage: u8 = match f.get("stage") {
        Some(s) => s
            .parse()
            .map_err(|_| anyhow!("--stage must be an integer in 0..=3, got {s:?}"))?,
        None => default,
    };
    if stage > 3 {
        bail!("invalid ZeRO stage {stage} (want 0..=3)");
    }
    Ok(stage)
}

fn resolve_cluster(name: &str) -> Result<ClusterSpec> {
    match name {
        "cluster-A" => Ok(cluster::cluster_a()),
        "cluster-B" => Ok(cluster::cluster_b()),
        "cluster-C" => Ok(cluster::cluster_c()),
        other => bail!("unknown cluster {other:?} (use cluster-A/B/C or a config file)"),
    }
}

fn dispatch(args: &[String]) -> Result<()> {
    let Some(cmd) = args.first() else {
        print_help();
        return Ok(());
    };
    let rest = &args[1..];
    match cmd.as_str() {
        "profile" => cmd_profile(rest),
        "plan" => cmd_plan(rest),
        "simulate" => cmd_simulate(rest),
        "train" => cmd_train(rest),
        "elastic" => cmd_elastic(rest),
        "autoscale" => cmd_autoscale(rest),
        "ckpt" => cmd_ckpt(rest),
        "exp" => cmd_exp(rest),
        "lint" => cmd_lint(rest),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => bail!("unknown command {other:?}; try `poplar help`"),
    }
}

fn print_help() {
    println!(
        "poplar — heterogeneity-aware ZeRO training (AAAI'25 reproduction)\n\n\
         commands:\n\
         \x20 profile   --cluster cluster-C --model llama-0.5b [--stage N] [--noise S]\n\
         \x20 plan      --cluster C --model M --gbs-tokens N [--stage N] [--strategy poplar]\n\
         \x20 simulate  --config job.toml\n\
         \x20 train     --artifacts artifacts/tiny [--iters 100] [--gbs 16] [--stage 1]\n\
         \x20 elastic   --cluster C --model M [--stage N] [--iters 12]\n\
         \x20           [--events \"4:lost:7,6:slow:0:2.5,8:join:A800-80G,9:bw:ib:0.2\"]\n\
         \x20           # event kinds: ITER:lost:SLOT | ITER:join:GPU | ITER:slow:SLOT:FACTOR | ITER:bw:LINK:FACTOR\n\
         \x20           [--seed-schedule 7]\n\
         \x20           [--ckpt-dir artifacts/ckpt] [--horizon 300] [--min-gain 0.02]\n\
         \x20           [--allow-stage-change]  # replan-time ZeRO-stage re-selection\n\
         \x20           [--allow-pipeline] [--max-group-size 4]  # group memory-starved offers\n\
         \x20 autoscale --offer A800-80G,T4[,...] [--cluster C] [--model M] [--stage N]\n\
         \x20           [--gbs-tokens N] [--horizon 300] [--min-gain 0.02] [--noise S]\n\
         \x20           [--joint]    # joint offer-subset round (one shared stall)\n\
         \x20           [--release]  # also consider scale-down (implies round mode)\n\
         \x20 ckpt      save --cluster C --model M [--stage N] [--dir artifacts/ckpt]\n\
         \x20 ckpt      inspect [--dir artifacts/ckpt | --path FILE]\n\
         \x20 ckpt      restore --cluster C --model M [--lost 7,3] [--stage N]  # cross-stage migrates\n\
         \x20 exp       <fig1|fig3|fig4|fig5|fig6|fig7|fig8|fig_elastic|fig_autoscale|fig_stage_migration|fig_joint_admission|fig_bw_adaptation|fig_pipeline|table2|ablation|all> [--out results]\n\
         \x20 lint      [--format json] [--write-baseline]  # invariant analyzer (src/lint/README.md)\n"
    );
}

fn cmd_profile(args: &[String]) -> Result<()> {
    let (_, f) = parse_flags(args)?;
    let cluster = resolve_cluster(f.get("cluster").map(String::as_str).unwrap_or("cluster-C"))?;
    let model = model_cfg::preset(f.get("model").map(String::as_str).unwrap_or("llama-0.5b"))
        .ok_or_else(|| anyhow!("unknown model preset"))?;
    let stage = parse_stage(&f, 0)?;
    let noise: f64 = f.get("noise").map(|s| s.parse()).transpose()?.unwrap_or(0.015);

    let mut leader = Leader::new_simulated(&cluster, &model, noise, 42);
    let prof = leader.profile(stage)?;
    println!("cluster {} model {} — profiled at ZeRO-{}", cluster.name, model.name, prof.stage);
    let mut t = Table::new(&["rank", "gpu", "mbs", "peak_speed", "probe_steps", "probe_s"]);
    let curves = poplar::coordinator::fit_curves(&prof)?;
    for (r, c) in prof.ranks.iter().zip(&curves) {
        t.row(&[
            r.rank.to_string(),
            r.name.clone(),
            r.mbs.to_string(),
            format!("{:.3}", c.peak_speed()),
            r.probe_steps.to_string(),
            format!("{:.1}", r.probe_time_s),
        ]);
    }
    println!("{}", t.to_markdown());
    leader.shutdown();
    Ok(())
}

fn cmd_plan(args: &[String]) -> Result<()> {
    let (_, f) = parse_flags(args)?;
    let cluster = resolve_cluster(f.get("cluster").map(String::as_str).unwrap_or("cluster-C"))?;
    let model = model_cfg::preset(f.get("model").map(String::as_str).unwrap_or("llama-0.5b"))
        .ok_or_else(|| anyhow!("unknown model preset"))?;
    let stage = parse_stage(&f, 0)?;
    let gbs_tokens: u64 = f
        .get("gbs-tokens")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(2 * 1024 * 1024);
    let gbs = (gbs_tokens / model.seq) as usize;
    let strategy = Strategy::parse(f.get("strategy").map(String::as_str).unwrap_or("poplar"))
        .ok_or_else(|| anyhow!("unknown strategy"))?;

    let mut leader = Leader::new_simulated(&cluster, &model, 0.015, 42);
    let prof = leader.profile(stage)?;
    let plan = leader.plan_from_profile(&prof, strategy, gbs)?;
    println!(
        "plan: strategy={} stage=ZeRO-{} gbs={} samples, predicted iter {:.3}s",
        plan.strategy, plan.stage, plan.gbs, plan.predicted_iter_s
    );
    let mut t = Table::new(&["rank", "gpu", "micro_batch", "samples/iter", "gas", "lbs"]);
    let insts = cluster.instances();
    for r in &plan.ranks {
        t.row(&[
            r.rank.to_string(),
            insts[r.rank].spec.name.clone(),
            r.micro_batch.to_string(),
            r.samples_per_iter.to_string(),
            r.grad_accum_steps.to_string(),
            r.last_batch.to_string(),
        ]);
    }
    println!("{}", t.to_markdown());
    leader.shutdown();
    Ok(())
}

fn cmd_simulate(args: &[String]) -> Result<()> {
    let (_, f) = parse_flags(args)?;
    let path = f.get("config").ok_or_else(|| anyhow!("--config job.toml required"))?;
    let cfg = JobConfig::load(Path::new(path)).map_err(|e| anyhow!("{e}"))?;
    let gbs = cfg.gbs_samples();
    let mut leader = Leader::new_simulated(
        &cfg.cluster,
        &cfg.model,
        cfg.training.noise_sigma,
        cfg.training.seed,
    );
    let rep = leader.run_job(
        cfg.training.zero_stage,
        cfg.training.strategy,
        gbs,
        cfg.training.iterations,
    )?;
    println!(
        "simulate: {} on {} — ZeRO-{} strategy={} gbs={} — mean {:.1} TFLOP/s over {} iters",
        cfg.model.name,
        cfg.cluster.name,
        rep.stage,
        cfg.training.strategy.name(),
        gbs,
        rep.tflops_mean,
        rep.iterations.len()
    );
    leader.shutdown();
    Ok(())
}

fn cmd_train(args: &[String]) -> Result<()> {
    let (_, f) = parse_flags(args)?;
    let dir = PathBuf::from(
        f.get("artifacts").map(String::as_str).unwrap_or("artifacts/tiny"),
    );
    let iters: usize = f.get("iters").map(|s| s.parse()).transpose()?.unwrap_or(50);
    let gbs: usize = f.get("gbs").map(|s| s.parse()).transpose()?.unwrap_or(16);
    let stage = parse_stage(&f, 1)?;
    let log_every: usize = f.get("log-every").map(|s| s.parse()).transpose()?.unwrap_or(10);

    let mut trainer = Trainer::open(&dir).context("opening artifacts (run `make artifacts`)")?;
    let meta = trainer.engine().meta().clone();
    println!(
        "train: preset={} params={} seq={} variants={:?} pallas={}",
        meta.preset, meta.param_count, meta.seq, meta.batch_variants, meta.use_pallas
    );

    // virtual heterogeneous cluster: 2 fast + 2 slow (DESIGN.md §6)
    let max_b = *meta
        .batch_variants
        .iter()
        .max()
        .ok_or_else(|| anyhow!("artifact metadata lists no batch variants"))?;
    let vgpus = vec![
        VirtualGpu { name: "fast-0".into(), slowdown: 1.0, max_batch: max_b },
        VirtualGpu { name: "fast-1".into(), slowdown: 1.0, max_batch: max_b },
        VirtualGpu { name: "slow-0".into(), slowdown: 2.4, max_batch: max_b.div_ceil(2) },
        VirtualGpu { name: "slow-1".into(), slowdown: 2.4, max_batch: max_b.div_ceil(2) },
    ];

    let mut source = CorpusStream::new(meta.vocab as u32);
    let curves = trainer.profile_virtual(&vgpus, &mut source, 1)?;
    let net = poplar::netsim::NetSim::from_link(vgpus.len(), cluster::LinkKind::Pcie);
    let plan = poplar::allocator::plan(&curves, stage, gbs, &net,
                                       meta.param_count as u64)
        .map_err(|e| anyhow!("plan: {e}"))?;
    println!("plan: {:?}", plan.ranks.iter().map(|r| (r.micro_batch, r.grad_accum_steps,
             r.last_batch)).collect::<Vec<_>>());

    let logs = trainer.train(&plan, &vgpus, &mut source, iters, log_every)?;
    let first = logs.first().map(|l| l.loss).unwrap_or(0.0);
    let last = logs.last().map(|l| l.loss).unwrap_or(0.0);
    println!("loss: {first:.4} -> {last:.4} over {} iterations", logs.len());
    Ok(())
}

fn cmd_elastic(args: &[String]) -> Result<()> {
    // --allow-stage-change / --allow-pipeline are bare flags (no
    // value): strip them before the `--key value` parser sees them
    let mut args = args.to_vec();
    let stage_change_flag = take_bare_flag(&mut args, "--allow-stage-change");
    let pipeline_flag = take_bare_flag(&mut args, "--allow-pipeline");
    let (_, f) = parse_flags(&args)?;
    // validated here, before any simulation: a singleton "group" can
    // never pipeline, so the knob is rejected at the entry point
    let max_group_size: Option<usize> =
        f.get("max-group-size").map(|s| s.parse()).transpose()?;
    if let Some(cap) = max_group_size {
        if cap < poplar::pipeline::MIN_GROUP_SIZE {
            bail!(
                "--max-group-size must be at least {}, got {cap}",
                poplar::pipeline::MIN_GROUP_SIZE
            );
        }
    }

    // config-file path: `[elastic]` section drives everything
    // (--ckpt-dir still overrides the `[ckpt]` section either way, and
    // the bare flag turns the stage search on over the config)
    let ckpt_dir_flag = f.get("ckpt-dir").map(PathBuf::from);
    if let Some(path) = f.get("config") {
        let cfg = JobConfig::load(Path::new(path)).map_err(|e| anyhow!("{e}"))?;
        let ecfg = cfg
            .elastic
            .clone()
            .ok_or_else(|| anyhow!("config has no [elastic] section"))?;
        let mut leader = Leader::new_simulated(
            &cfg.cluster,
            &cfg.model,
            cfg.training.noise_sigma,
            cfg.training.seed,
        );
        let opts = poplar::coordinator::ElasticOptions {
            drift_threshold: ecfg.drift_threshold,
            ckpt_dir: ckpt_dir_flag.or_else(|| cfg.ckpt.as_ref().map(|c| c.dir.clone())),
            autoscale: cfg.autoscale.clone(),
            allow_stage_change: ecfg.allow_stage_change || stage_change_flag,
            policy_horizon_s: cfg.policy.as_ref().map(|p| p.horizon_s),
            max_offers_per_round: cfg.policy.as_ref().map(|p| p.max_offers_per_round),
            // presence of [pipeline] arms the grouping arm; the CLI
            // flag can arm it over a config that lacks the table
            allow_pipeline: cfg.pipeline.is_some() || pipeline_flag,
            pipeline_max_group_size: max_group_size
                .or_else(|| cfg.pipeline.as_ref().map(|p| p.max_group_size))
                .unwrap_or(poplar::pipeline::DEFAULT_MAX_GROUP_SIZE),
            ..Default::default()
        };
        let rep = leader.run_elastic_job(
            cfg.training.zero_stage,
            cfg.gbs_samples(),
            cfg.training.iterations,
            &ecfg.events,
            &opts,
        )?;
        print_elastic_report(&rep);
        leader.shutdown();
        return Ok(());
    }

    // flag path
    let cluster = resolve_cluster(f.get("cluster").map(String::as_str).unwrap_or("cluster-C"))?;
    let model = model_cfg::preset(f.get("model").map(String::as_str).unwrap_or("llama-0.5b"))
        .ok_or_else(|| anyhow!("unknown model preset"))?;
    let stage = parse_stage(&f, 1)?;
    let iters: usize = f.get("iters").map(|s| s.parse()).transpose()?.unwrap_or(12);
    let gbs_tokens: u64 = f
        .get("gbs-tokens")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(2 * 1024 * 1024);
    let gbs = (gbs_tokens / model.seq) as usize;
    let noise: f64 = f.get("noise").map(|s| s.parse()).transpose()?.unwrap_or(0.015);
    let threshold: f64 = f
        .get("drift-threshold")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(poplar::elastic::DEFAULT_DRIFT_THRESHOLD);

    let schedule = if let Some(spec) = f.get("events") {
        poplar::elastic::parse_schedule(spec).map_err(|e| anyhow!("{e}"))?
    } else {
        let seed: u64 =
            f.get("seed-schedule").map(|s| s.parse()).transpose()?.unwrap_or(7);
        poplar::elastic::seeded_schedule(
            seed,
            iters,
            cluster.n_gpus(),
            &["A800-80G", "V100S-32G", "T4"],
        )
    };

    // presence of --horizon or --min-gain enables the offer policy
    let autoscale = parse_autoscale_flags(&f)?;
    let mut leader = Leader::new_simulated(&cluster, &model, noise, 42);
    let opts = poplar::coordinator::ElasticOptions {
        drift_threshold: threshold,
        ckpt_dir: ckpt_dir_flag,
        autoscale,
        allow_stage_change: stage_change_flag,
        allow_pipeline: pipeline_flag,
        pipeline_max_group_size: max_group_size
            .unwrap_or(poplar::pipeline::DEFAULT_MAX_GROUP_SIZE),
        ..Default::default()
    };
    let rep = leader.run_elastic_job(stage, gbs, iters, &schedule, &opts)?;
    print_elastic_report(&rep);
    leader.shutdown();
    Ok(())
}

fn print_elastic_report(rep: &poplar::coordinator::ElasticJobReport) {
    let stage_span = if rep.final_stage == rep.stage {
        format!("ZeRO-{}", rep.stage)
    } else {
        format!("ZeRO-{}->{}", rep.stage, rep.final_stage)
    };
    println!(
        "elastic: {stage_span} gbs={} — {} replans, curve cache {} hits / {} misses",
        rep.gbs, rep.replans, rep.cache_hits, rep.cache_misses
    );
    let mut t = Table::new(&[
        "iter", "events", "ranks", "stage", "wall_s", "tflops", "bw_gbs", "replanned",
        "reprofiled", "reshard_s", "moved_mb",
    ]);
    for it in &rep.iterations {
        t.row(&[
            it.iter.to_string(),
            if it.events.is_empty() { "-".into() } else { it.events.join("; ") },
            it.n_ranks.to_string(),
            it.stage.to_string(),
            format!("{:.3}", it.wall_s),
            format!("{:.1}", it.tflops),
            format!("{:.2}", it.bw_gbs),
            if it.replanned { "yes".into() } else { "-".into() },
            if it.reprofiled_slots.is_empty() {
                "-".into()
            } else {
                format!("{:?}", it.reprofiled_slots)
            },
            format!("{:.3}", it.reshard_penalty_s),
            format!("{:.1}", it.reshard_bytes as f64 / 1e6),
        ]);
    }
    println!("{}", t.to_markdown());
}

/// Parse the optional `--horizon` / `--min-gain` pair: either flag turns
/// the cost-aware offer policy on.
fn parse_autoscale_flags(
    f: &HashMap<String, String>,
) -> Result<Option<poplar::autoscale::AutoscaleOptions>> {
    let horizon = f.get("horizon").map(|s| s.parse::<f64>()).transpose()?;
    let min_gain = f.get("min-gain").map(|s| s.parse::<f64>()).transpose()?;
    if horizon.is_none() && min_gain.is_none() {
        return Ok(None);
    }
    Ok(Some(poplar::autoscale::AutoscaleOptions {
        horizon_s: horizon.unwrap_or(poplar::autoscale::DEFAULT_HORIZON_S),
        min_gain: min_gain.unwrap_or(poplar::autoscale::DEFAULT_MIN_GAIN),
        prices: Vec::new(),
    }))
}

fn cmd_autoscale(args: &[String]) -> Result<()> {
    // --joint / --release are bare flags (no value): strip them before
    // the `--key value` parser sees them. --joint prices the offer
    // batch through the unified round engine (`policy::decide_round`,
    // one shared stall per round) instead of one offer at a time;
    // --release additionally considers scale-down.
    let mut args = args.to_vec();
    let joint = take_bare_flag(&mut args, "--joint");
    let release = take_bare_flag(&mut args, "--release");
    let (_, f) = parse_flags(&args)?;
    let offers: Vec<String> = f
        .get("offer")
        .map(|s| {
            s.split(',')
                .map(|x| x.trim().to_string())
                .filter(|x| !x.is_empty())
                .collect()
        })
        .unwrap_or_default();
    if offers.is_empty() && !release {
        bail!("--offer GPU[,GPU...] required (e.g. --offer A800-80G,T4) unless --release");
    }
    let cluster = resolve_cluster(f.get("cluster").map(String::as_str).unwrap_or("cluster-C"))?;
    let model = model_cfg::preset(f.get("model").map(String::as_str).unwrap_or("llama-0.5b"))
        .ok_or_else(|| anyhow!("unknown model preset"))?;
    let stage = parse_stage(&f, 1)?;
    let gbs_tokens: u64 = f
        .get("gbs-tokens")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(2 * 1024 * 1024);
    let gbs = (gbs_tokens / model.seq) as usize;
    let noise: f64 = f.get("noise").map(|s| s.parse()).transpose()?.unwrap_or(0.015);
    let max_admit: Option<usize> = f.get("max-admit").map(|s| s.parse()).transpose()?;
    let opts = parse_autoscale_flags(&f)?.unwrap_or_default();

    // profile the running cluster once (Alg. 1), then every offer is
    // decided analytically — cached types with zero further profiling
    let mut leader = Leader::new_simulated(&cluster, &model, noise, 42);
    let prof = leader.profile(stage)?;
    let stage = prof.stage;
    let curves = poplar::coordinator::fit_curves(&prof)?;
    let mut planner = poplar::elastic::ElasticPlanner::new(
        stage,
        gbs,
        &model.name,
        model.param_count(),
        32,
    );
    for (r, c) in prof.ranks.iter().zip(curves) {
        let slot = planner.add_slot(&r.name);
        planner
            .install_curve(slot, c, false)
            .map_err(|e| anyhow!("installing slot {slot} curve: {e}"))?;
    }
    let net = leader.net().clone();
    planner.replan(&net).map_err(|e| anyhow!("plan: {e}"))?;
    leader.shutdown();

    if joint || release {
        let mut ropts = poplar::policy::RoundOptions {
            consider_release: release,
            // the operator-facing table shows the greedy replay
            with_sequential: true,
            ..poplar::policy::RoundOptions::from_autoscale(&opts)
        };
        if let Some(cap) = max_admit {
            ropts.max_offers_per_round = cap;
        }
        let round = poplar::policy::decide_round(&planner, &net, &model, &offers, &ropts)
            .map_err(|e| anyhow!("{e}"))?;
        print_round_plan(&round, &model.name, &cluster.name, stage);
        return Ok(());
    }
    let rep = poplar::autoscale::evaluate_offers(&planner, &net, &model, &offers, &opts)
        .map_err(|e| anyhow!("{e}"))?;
    print_autoscale_report(&rep, &model.name, &cluster.name, stage);
    Ok(())
}

fn describe_action(a: &poplar::policy::Action) -> String {
    use poplar::policy::Action;
    match a {
        Action::Admit { gpu } => format!("admit {gpu}"),
        Action::Defer { gpu } => format!("defer {gpu} (profile before committing)"),
        Action::Decline { gpu } => format!("decline {gpu}"),
        Action::Release { slot } => format!("release slot {slot}"),
        Action::StageMigrate { from, to } => format!("migrate ZeRO-{from} -> ZeRO-{to}"),
        Action::Stay => "stay".to_string(),
    }
}

fn print_round_plan(
    rep: &poplar::policy::RoundPlan,
    model: &str,
    cluster: &str,
    stage: u8,
) {
    println!(
        "autoscale round: {model} on {cluster} at ZeRO-{stage} — horizon {:.0}s, \
         min gain {:.1}%",
        rep.horizon_s,
        rep.min_gain * 100.0
    );
    // same rendering as exp::fig_joint_admission — one source of truth
    let mut t = Table::new(poplar::policy::ROUND_COLUMNS);
    for row in poplar::policy::round_rows(rep) {
        t.row(&row);
    }
    println!("{}", t.to_markdown());
    for a in &rep.actions {
        println!("  -> {}", describe_action(a));
    }
}

fn print_autoscale_report(
    rep: &poplar::autoscale::AutoscaleReport,
    model: &str,
    cluster: &str,
    stage: u8,
) {
    println!(
        "autoscale: {model} on {cluster} at ZeRO-{stage} — horizon {:.0}s, min gain {:.1}%",
        rep.horizon_s,
        rep.min_gain * 100.0
    );
    // same rendering as exp::fig_autoscale — one source of truth
    println!("{}", poplar::autoscale::report_table(rep).to_markdown());
    for d in &rep.decisions {
        println!("  {} -> {}: {}", d.gpu, d.decision.label(), d.reason);
    }
}

/// Slot list of a cluster spec: `(rank, interned gpu type)` in rank
/// order — the shape [`poplar::ckpt::ShardManifest::build`] consumes.
fn cluster_slots(cluster: &ClusterSpec) -> Vec<(usize, poplar::intern::TypeId)> {
    cluster
        .instances()
        .iter()
        .map(|inst| (inst.rank, poplar::intern::intern(&inst.spec.name)))
        .collect()
}

fn print_manifest(m: &poplar::ckpt::ShardManifest) {
    println!(
        "manifest v{}: model={} ZeRO-{} ψ={} snapshot={} ({} ranks)",
        m.version, m.model, m.stage, m.param_count, m.snapshot, m.shards.len()
    );
    let mut t = Table::new(&["slot", "gpu", "lo", "hi", "params", "state_mb"]);
    for e in &m.shards {
        t.row(&[
            e.slot.to_string(),
            e.gpu.to_string(),
            e.range.lo.to_string(),
            e.range.hi.to_string(),
            e.range.len().to_string(),
            format!(
                "{:.1}",
                (e.range.len() * poplar::zero::OPTIMIZER_BYTES_PER_PARAM) as f64 / 1e6
            ),
        ]);
    }
    println!("{}", t.to_markdown());
}

fn cmd_ckpt(args: &[String]) -> Result<()> {
    use poplar::ckpt::{migrate, ReshardPlan, ShardManifest};

    let Some(sub) = args.first() else {
        bail!("usage: poplar ckpt <save|restore|inspect> …  (see `poplar help`)");
    };
    let (_, f) = parse_flags(&args[1..])?;
    let dir = PathBuf::from(f.get("dir").map(String::as_str).unwrap_or("artifacts/ckpt"));
    let load = |f: &HashMap<String, String>| -> Result<ShardManifest> {
        match f.get("path") {
            Some(p) => ShardManifest::load(Path::new(p)).map_err(|e| anyhow!("{e}")),
            None => ShardManifest::load_latest(&dir)
                .map_err(|e| anyhow!("{e} (no --path given, tried {}/LATEST)", dir.display())),
        }
    };

    match sub.as_str() {
        "save" => {
            let cluster =
                resolve_cluster(f.get("cluster").map(String::as_str).unwrap_or("cluster-C"))?;
            let model = model_cfg::preset(
                f.get("model").map(String::as_str).unwrap_or("llama-0.5b"),
            )
            .ok_or_else(|| anyhow!("unknown model preset"))?;
            let stage = parse_stage(&f, 1)?;
            let snapshot: usize =
                f.get("snapshot").map(|s| s.parse()).transpose()?.unwrap_or(0);
            let m = ShardManifest::build(
                &model.name,
                stage,
                model.param_count(),
                snapshot,
                &cluster_slots(&cluster),
            )
            .map_err(|e| anyhow!("{e}"))?;
            let path = m.save(&dir).map_err(|e| anyhow!("{e}"))?;
            println!("saved {}", path.display());
            print_manifest(&m);
        }
        "inspect" => {
            let m = load(&f)?;
            m.validate().map_err(|e| anyhow!("{e}"))?;
            print_manifest(&m);
        }
        "restore" => {
            let old = load(&f)?;
            let cluster =
                resolve_cluster(f.get("cluster").map(String::as_str).unwrap_or("cluster-C"))?;
            // default to the checkpoint's own recorded model (like stage):
            // any other default would just fail the compatibility check
            let model_name = f.get("model").map(String::as_str).unwrap_or(&old.model);
            let model = model_cfg::preset(model_name).ok_or_else(|| {
                anyhow!("model {model_name:?} is not a known preset; pass --model")
            })?;
            // the restored layout keeps the checkpoint's stage unless
            // --stage asks for a cross-stage migration (ckpt::migrate
            // prices the re-layout; 0..=3 enforced before any builder)
            let stage = match f.get("stage") {
                Some(_) => parse_stage(&f, old.stage)?,
                None => old.stage,
            };
            let mut slots = cluster_slots(&cluster);
            if let Some(lost) = f.get("lost") {
                for part in lost.split(',').filter(|s| !s.trim().is_empty()) {
                    let slot: usize = part
                        .trim()
                        .parse()
                        .map_err(|_| anyhow!("bad --lost entry {part:?}"))?;
                    let before = slots.len();
                    slots.retain(|(s, _)| *s != slot);
                    if slots.len() == before {
                        bail!("--lost {slot}: no such rank in the cluster");
                    }
                }
            }
            let new = ShardManifest::build(
                &model.name,
                stage,
                model.param_count(),
                old.snapshot + 1,
                &slots,
            )
            .map_err(|e| anyhow!("{e}"))?;
            let plan = migrate(&old, &new).map_err(|e| anyhow!("{e}"))?;
            // transfer pricing is point-to-point: only the bottleneck
            // link's bw/latency matter, not the group size
            let net = poplar::netsim::NetSim::from_cluster(&cluster);
            let recompute = ReshardPlan::full_restore(&new);
            if plan.is_migration() {
                println!(
                    "cross-stage migration ZeRO-{} -> ZeRO-{}",
                    plan.from_stage, plan.stage
                );
            }
            println!(
                "restore onto {} ranks: {} moves — {:.1} MB moved ({:.1} MB off the checkpoint, \
                 {:.1} MB retained in place)",
                slots.len(),
                plan.moves.len(),
                plan.bytes_moved() as f64 / 1e6,
                plan.bytes_from_checkpoint() as f64 / 1e6,
                plan.bytes_retained() as f64 / 1e6,
            );
            println!(
                "measured reshard {:.3}s vs full-restore recompute {:.3}s",
                plan.transfer_time_s(&net),
                recompute.transfer_time_s(&net)
            );
            let mut t = Table::new(&["to_slot", "source", "lo", "hi", "mb"]);
            for mv in &plan.moves {
                t.row(&[
                    mv.to_slot.to_string(),
                    match mv.from_slot {
                        Some(s) => format!("slot {s}"),
                        None => "checkpoint".into(),
                    },
                    mv.range.lo.to_string(),
                    mv.range.hi.to_string(),
                    format!(
                        "{:.1}",
                        (mv.range.len() * poplar::zero::OPTIMIZER_BYTES_PER_PARAM) as f64 / 1e6
                    ),
                ]);
            }
            println!("{}", t.to_markdown());
            print_manifest(&new);
        }
        other => bail!("unknown ckpt subcommand {other:?} (want save|restore|inspect)"),
    }
    Ok(())
}

fn cmd_lint(args: &[String]) -> Result<()> {
    // --write-baseline is a bare flag (no value): strip it before the
    // `--key value` parser sees it
    let mut args = args.to_vec();
    let write = take_bare_flag(&mut args, "--write-baseline");
    let (_, f) = parse_flags(&args)?;
    let json = match f.get("format").map(String::as_str) {
        None | Some("text") => false,
        Some("json") => true,
        Some(other) => bail!("unknown --format {other:?} (want text|json)"),
    };
    let root = lint_root()?;

    if write {
        let scan = poplar::lint::scan_crate(&root)?;
        let entries = poplar::lint::write_baseline(&root, &scan.diagnostics)?;
        println!(
            "wrote {} ({entries} entries from {} files)",
            root.join(poplar::lint::BASELINE_FILE).display(),
            scan.files_scanned
        );
        return Ok(());
    }

    let report = poplar::lint::run_crate(&root)?;
    if json {
        print!("{}", report.to_json());
    } else {
        for d in &report.new {
            println!("{d}");
        }
        for s in &report.stale {
            println!(
                "stale baseline: {} {} freezes {} but {} remain — rerun with --write-baseline",
                s.rule, s.path, s.frozen, s.actual
            );
        }
        println!(
            "lint: {} files scanned, {} new, {} baselined, {} stale",
            report.files_scanned,
            report.new.len(),
            report.baselined,
            report.stale.len()
        );
    }
    if !report.is_clean() {
        bail!(
            "lint failed: {} new violation(s), {} stale baseline entries",
            report.new.len(),
            report.stale.len()
        );
    }
    Ok(())
}

/// Crate-root autodetection so `poplar lint` works both from `rust/`
/// (the cargo working dir) and from the repo root.
fn lint_root() -> Result<PathBuf> {
    for cand in [".", "rust"] {
        let root = PathBuf::from(cand);
        if root.join("src").join("lib.rs").is_file() {
            return Ok(root);
        }
    }
    bail!("cannot find the crate root (run from rust/ or the repo root)")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(v: &[&str]) -> Vec<String> {
        v.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn every_entry_point_rejects_stage_out_of_range() {
        // `--stage` parses as u8 (accepts 0..=255), so the 0..=3 bound
        // must be enforced at each entry point before any planner,
        // profiler or manifest builder sees the value — one check per
        // CLI entry point that takes the flag
        let assert_stage_err = |r: Result<()>| {
            let e = format!("{:#}", r.unwrap_err());
            assert!(e.contains("ZeRO stage") || e.contains("--stage"), "{e}");
        };
        assert_stage_err(cmd_profile(&args(&["--stage", "4"])));
        assert_stage_err(cmd_plan(&args(&["--stage", "200"])));
        assert_stage_err(cmd_elastic(&args(&["--stage", "9"])));
        assert_stage_err(cmd_autoscale(&args(&["--offer", "T4", "--stage", "255"])));
        assert_stage_err(cmd_train(&args(&["--stage", "17"])));
        assert_stage_err(cmd_ckpt(&args(&["save", "--stage", "42"])));
        // non-numeric is rejected with the same guidance
        assert_stage_err(cmd_profile(&args(&["--stage", "two"])));
        // and a u8-overflowing value cannot wrap into range
        assert_stage_err(cmd_plan(&args(&["--stage", "256"])));
    }

    #[test]
    fn autoscale_joint_and_release_are_bare_flags() {
        let mut a = args(&["--joint", "--release", "--offer", "T4"]);
        assert!(take_bare_flag(&mut a, "--joint"));
        assert!(take_bare_flag(&mut a, "--release"));
        assert_eq!(a, args(&["--offer", "T4"]), "only the bare flags are removed");
        // without --release, an empty offer list is still an error
        let e = format!("{:#}", cmd_autoscale(&args(&[])).unwrap_err());
        assert!(e.contains("--offer"), "{e}");
        let e = format!("{:#}", cmd_autoscale(&args(&["--joint"])).unwrap_err());
        assert!(e.contains("--offer"), "{e}");
    }

    #[test]
    fn allow_pipeline_is_a_bare_flag_with_a_validated_cap() {
        let mut a = args(&["--allow-pipeline", "--iters", "2"]);
        assert!(take_bare_flag(&mut a, "--allow-pipeline"));
        assert_eq!(a, args(&["--iters", "2"]), "only the bare flag is removed");
        // a singleton "group" is rejected before any simulation runs
        for cap in ["1", "0"] {
            let e = format!(
                "{:#}",
                cmd_elastic(&args(&["--allow-pipeline", "--max-group-size", cap]))
                    .unwrap_err()
            );
            assert!(e.contains("max-group-size"), "cap {cap}: {e}");
        }
    }

    #[test]
    fn allow_stage_change_is_a_bare_flag() {
        let mut a = args(&["--allow-stage-change", "--iters", "2"]);
        assert!(take_bare_flag(&mut a, "--allow-stage-change"));
        assert_eq!(a, args(&["--iters", "2"]), "only the bare flag is removed");
        assert!(!take_bare_flag(&mut a, "--allow-stage-change"));
        // and parse_flags still sees well-formed pairs afterwards
        let (pos, f) = parse_flags(&a).unwrap();
        assert!(pos.is_empty());
        assert_eq!(f.get("iters").map(String::as_str), Some("2"));
    }
}

fn cmd_exp(args: &[String]) -> Result<()> {
    let (pos, f) = parse_flags(args)?;
    let which = pos.first().map(String::as_str).unwrap_or("all");
    let out = PathBuf::from(f.get("out").map(String::as_str).unwrap_or("results"));
    let one = |name: &str, title: &str, f: fn() -> Result<Table>| -> Result<()> {
        let t = f()?;
        println!("\n## {title}\n\n{}", t.to_markdown());
        exp::write_result(&out, name, title, &t)
    };
    match which {
        "all" => exp::run_all(&out)?,
        "fig1" => one("fig1", "Fig. 1 — motivation", exp::fig1::run)?,
        "fig3" => one("fig3", "Fig. 3 — main result", exp::fig3::run)?,
        "fig4" => one("fig4", "Fig. 4 — models", exp::fig4::run)?,
        "fig5" => one("fig5", "Fig. 5 — quantities", exp::fig5::run)?,
        "fig6" => one("fig6", "Fig. 6 — batch curves", exp::fig6::run)?,
        "fig7" => one("fig7", "Fig. 7 — spline accuracy", exp::fig7::run)?,
        "fig8" => one("fig8", "Fig. 8 — capability measurement", exp::fig8::run)?,
        "table2" => one("table2", "Table 2 — overhead", exp::table2::run)?,
        "ablation" => one("ablation", "Ablation", exp::ablation::run)?,
        "fig_elastic" => one(
            "fig_elastic",
            "Elasticity — throughput recovery after membership changes",
            exp::fig_elastic::run,
        )?,
        "fig_autoscale" => one(
            "fig_autoscale",
            "Autoscaling — cost/throughput frontier of candidate offers",
            exp::fig_autoscale::run,
        )?,
        "fig_stage_migration" => one(
            "fig_stage_migration",
            "Stage migration — replan-time ZeRO-stage re-selection",
            exp::fig_stage_migration::run,
        )?,
        "fig_bw_adaptation" => one(
            "fig_bw_adaptation",
            "Bandwidth adaptation — measured fabric flips and restores a replan",
            exp::fig_bw_adaptation::run,
        )?,
        "fig_joint_admission" => one(
            "fig_joint_admission",
            "Joint admission + scale-down — the unified decision round",
            exp::fig_joint_admission::run,
        )?,
        "fig_pipeline" => one(
            "fig_pipeline",
            "Pipeline grouping — virtual DP ranks from memory-starved GPUs",
            exp::fig_pipeline::run,
        )?,
        other => bail!("unknown experiment {other:?}"),
    }
    Ok(())
}
