//! ZeRO-0..3 BSP iteration engine (simulation).
//!
//! Executes a [`Plan`] against per-rank ground-truth timing (device
//! model without noise) and the collective cost model, reproducing the
//! synchronization structure of each stage (paper §"Time Consumed
//! Estimation" + appendix "Details about ZeRO"):
//!
//! * **ZeRO-0/1** — ranks run their whole gradient-accumulation schedule
//!   independently, then meet at one sync point (gradient all-reduce /
//!   reduce-scatter + param all-gather), then the optimizer steps.
//! * **ZeRO-2** — every micro-step's backward ends in a gradient
//!   reduce-scatter: a BSP barrier per micro-step; param all-gather once
//!   per iteration after the optimizer.
//! * **ZeRO-3** — additionally all-gathers weights in every forward and
//!   backward; nothing at iteration end.
//!
//! The report carries per-rank busy/idle, the Eq. 1-4 quantities, and
//! cluster TFLOPs — the metric of Figs. 3-5.

use crate::allocator::{Plan, PlanError};
use crate::config::model::ModelSpec;
use crate::netsim::NetSim;

/// Bytes of fp32 optimizer state per parameter in the ZeRO mixed-precision
/// layout: fp32 master copy + momentum + variance (the paper's `12ψ`).
pub const OPTIMIZER_BYTES_PER_PARAM: u64 = 12;

/// Optimizer-state ownership ranges `[lo, hi)` per compact rank for a
/// ZeRO stage — the partition layout `ckpt::ShardManifest` is keyed by.
///
/// * ZeRO-0 replicates: every rank owns the full `[0, ψ)`.
/// * ZeRO-1..3 partition contiguously: `ψ/n` each, remainder spread over
///   the first ranks (matching [`crate::memmodel::model_state_bytes`]'s
///   `12ψ/n` per-rank accounting).
///
/// Returns `None` for an invalid stage or an empty group.
pub fn optimizer_shard_ranges(stage: u8, param_count: u64, n: usize) -> Option<Vec<(u64, u64)>> {
    if n == 0 || stage > 3 {
        return None;
    }
    if stage == 0 {
        return Some(vec![(0, param_count); n]);
    }
    let n64 = n as u64;
    let base = param_count / n64;
    let rem = param_count % n64;
    let mut out = Vec::with_capacity(n);
    let mut lo = 0u64;
    for i in 0..n64 {
        let len = base + u64::from(i < rem);
        out.push((lo, lo + len));
        lo += len;
    }
    Some(out)
}

/// Per-rank outcome of one simulated iteration.
#[derive(Debug, Clone)]
pub struct RankReport {
    /// Global rank.
    pub rank: usize,
    /// Seconds spent computing.
    pub busy_s: f64,
    /// Seconds spent waiting at sync points (the paper's `δt_i`).
    pub idle_s: f64,
}

/// Outcome of one simulated training iteration.
#[derive(Debug, Clone)]
pub struct IterationReport {
    /// Iteration wall time (Eq. 1 plus communication).
    pub wall_s: f64,
    /// Total time spent in collectives.
    pub comm_s: f64,
    /// Per-rank busy/idle breakdown.
    pub ranks: Vec<RankReport>,
    /// Eq. 4 objective `Σ δt_i · p_i` achieved by this plan.
    pub objective: f64,
    /// End-to-end cluster throughput in TFLOP/s (the Fig. 3-5 metric).
    pub tflops: f64,
    /// Samples processed (== gbs).
    pub samples: usize,
}

/// Ground-truth per-rank timing oracle used by the engine.
///
/// `time(rank, batch)` returns the true compute time of one micro-step;
/// `speed(rank)` the rank's peak throughput (for Eq. 4 weights).
pub trait TimeOracle {
    /// True compute seconds for `batch` samples on `rank`.
    fn time(&self, rank: usize, batch: usize) -> f64;
    /// Peak samples/second of `rank` (Eq. 4 weight `p_i`).
    fn speed(&self, rank: usize) -> f64;
}

/// Oracle backed by the calibrated device model.
pub struct DeviceOracle<'a> {
    /// Per-rank GPU specs.
    pub specs: Vec<crate::cluster::GpuSpec>,
    /// The model being trained.
    pub model: &'a ModelSpec,
}

impl TimeOracle for DeviceOracle<'_> {
    fn time(&self, rank: usize, batch: usize) -> f64 {
        if batch == 0 {
            return 0.0;
        }
        let tokens = (batch as u64 * self.model.seq) as f64;
        self.specs[rank].compute_time(
            tokens,
            self.model.flops_per_token(),
            self.model.n_layers as usize,
        )
    }

    fn speed(&self, rank: usize) -> f64 {
        // peak speed: large-batch asymptote at 64 samples
        let b = 64usize;
        b as f64 / self.time(rank, b)
    }
}

/// Oracle wrapper that replays per-rank slowdowns over a base oracle —
/// the ground-truth side of the elastic runtime's `RankSlowed` events.
/// `factors[rank] > 1.0` stretches that rank's compute time (and shrinks
/// its Eq. 4 peak-speed weight accordingly); ranks beyond the factor
/// vector run at full speed.
pub struct DriftOracle<O: TimeOracle> {
    /// The healthy-cluster oracle.
    pub inner: O,
    /// Per-rank compute-time multipliers.
    pub factors: Vec<f64>,
}

impl<O: TimeOracle> DriftOracle<O> {
    /// Wrap `inner` with no slowdown on any of the `n` ranks.
    pub fn healthy(inner: O, n: usize) -> Self {
        DriftOracle { inner, factors: vec![1.0; n] }
    }

    /// Set one rank's slowdown factor.
    pub fn slow(mut self, rank: usize, factor: f64) -> Self {
        if rank < self.factors.len() {
            self.factors[rank] = factor;
        }
        self
    }

    fn factor(&self, rank: usize) -> f64 {
        self.factors.get(rank).copied().unwrap_or(1.0)
    }
}

impl<O: TimeOracle> TimeOracle for DriftOracle<O> {
    fn time(&self, rank: usize, batch: usize) -> f64 {
        self.inner.time(rank, batch) * self.factor(rank)
    }

    fn speed(&self, rank: usize) -> f64 {
        self.inner.speed(rank) / self.factor(rank)
    }
}

/// Simulate one iteration of `plan` and report timings + TFLOPs.
///
/// `Plan.stage` is a `pub` field, so a corrupt stage can reach the
/// engine from outside the validated planners — it surfaces as
/// [`PlanError::InvalidStage`], never a panic.
pub fn simulate_iteration(
    plan: &Plan,
    oracle: &dyn TimeOracle,
    net: &NetSim,
    model: &ModelSpec,
) -> Result<IterationReport, PlanError> {
    let n = plan.ranks.len();
    let psi = model.param_count();
    let stage = plan.stage;
    let mut busy = vec![0.0f64; n];
    let mut idle = vec![0.0f64; n];
    let mut comm = 0.0f64;
    let mut wall = 0.0f64;

    match stage {
        0 | 1 => {
            // independent compute, one sync point
            let times: Vec<f64> = plan
                .ranks
                .iter()
                .map(|r| {
                    if r.grad_accum_steps == 0 {
                        return 0.0;
                    }
                    (r.grad_accum_steps - 1) as f64 * oracle.time(r.rank, r.micro_batch)
                        + oracle.time(r.rank, r.last_batch)
                })
                .collect();
            let t_max = times.iter().cloned().fold(0.0, f64::max);
            for i in 0..n {
                busy[i] += times[i];
                idle[i] += t_max - times[i];
            }
            let c = net.iteration_comm_time(stage, psi)?;
            comm += c;
            wall = t_max + c;
        }
        2 | 3 => {
            // BSP barrier every micro-step
            let gas = plan
                .ranks
                .iter()
                .map(|r| r.grad_accum_steps)
                .max()
                .unwrap_or(0);
            let c_step = net.per_microstep_comm_time(stage, psi)?;
            for step in 0..gas {
                let batches: Vec<usize> = plan
                    .ranks
                    .iter()
                    .map(|r| {
                        if step + 1 > r.grad_accum_steps {
                            0
                        } else if step + 1 == r.grad_accum_steps {
                            r.last_batch
                        } else {
                            r.micro_batch
                        }
                    })
                    .collect();
                let times: Vec<f64> =
                    (0..n).map(|i| oracle.time(i, batches[i])).collect();
                let t_max = times.iter().cloned().fold(0.0, f64::max);
                for i in 0..n {
                    busy[i] += times[i];
                    idle[i] += t_max - times[i];
                }
                wall += t_max + c_step;
                comm += c_step;
            }
            let c_iter = net.iteration_comm_time(stage, psi)?;
            comm += c_iter;
            wall += c_iter;
        }
        s => return Err(PlanError::InvalidStage(s)),
    }

    let speeds: Vec<f64> = (0..n).map(|i| oracle.speed(i)).collect();
    let objective: f64 = idle.iter().zip(&speeds).map(|(d, p)| d * p).sum();

    let samples: usize = plan.total_samples();
    let total_flops = samples as f64 * model.flops_per_sample();
    let tflops = if wall > 0.0 { total_flops / wall / 1e12 } else { 0.0 };

    Ok(IterationReport {
        wall_s: wall,
        comm_s: comm,
        ranks: (0..n)
            .map(|i| RankReport { rank: i, busy_s: busy[i], idle_s: idle[i] })
            .collect(),
        objective,
        tflops,
        samples,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::allocator::{self, baselines};
    use crate::cluster::{self, catalog};
    use crate::config::model::preset;
    use crate::curves::{PerfCurve, ProfiledPoint};

    fn curve_for(gpu: &str, model: &ModelSpec, mbs: usize) -> PerfCurve {
        let g = catalog::spec_or_panic(gpu);
        let pts: Vec<ProfiledPoint> = (1..=mbs)
            .map(|b| ProfiledPoint {
                batch: b,
                step_time_s: g.compute_time(
                    (b as u64 * model.seq) as f64,
                    model.flops_per_token(),
                    model.n_layers as usize,
                ),
            })
            .collect();
        PerfCurve::fit(pts, mbs).unwrap()
    }

    fn cluster_c_setup() -> (Vec<PerfCurve>, Vec<f64>, DeviceOracle<'static>, NetSim) {
        let model: &'static ModelSpec =
            Box::leak(Box::new(preset("llama-0.5b").unwrap()));
        let mut curves = vec![];
        let mut flops = vec![];
        let mut specs = vec![];
        for _ in 0..4 {
            curves.push(curve_for("A800-80G", model, 48));
            flops.push(312.0);
            specs.push(catalog::spec_or_panic("A800-80G"));
        }
        for _ in 0..4 {
            curves.push(curve_for("V100S-32G", model, 16));
            flops.push(130.0);
            specs.push(catalog::spec_or_panic("V100S-32G"));
        }
        let net = NetSim::from_cluster(&cluster::cluster_c());
        (curves, flops, DeviceOracle { specs, model }, net)
    }

    #[test]
    fn poplar_beats_uniform_on_cluster_c() {
        let (curves, _, oracle, net) = cluster_c_setup();
        let model = oracle.model;
        for stage in 0..4u8 {
            let pop = allocator::plan(&curves, stage, 512, &net, model.param_count()).unwrap();
            let uni = baselines::plan_uniform(&curves, stage, 512, &net,
                                              model.param_count()).unwrap();
            let r_pop = simulate_iteration(&pop, &oracle, &net, model).unwrap();
            let r_uni = simulate_iteration(&uni, &oracle, &net, model).unwrap();
            assert!(
                r_pop.tflops >= r_uni.tflops * 0.999,
                "stage {stage}: poplar {:.1} vs uniform {:.1}",
                r_pop.tflops,
                r_uni.tflops
            );
        }
    }

    #[test]
    fn poplar_beats_flops_proportional_somewhere() {
        let (curves, flops, oracle, net) = cluster_c_setup();
        let model = oracle.model;
        let mut any_win = false;
        for stage in 0..4u8 {
            let pop = allocator::plan(&curves, stage, 512, &net, model.param_count()).unwrap();
            let whale = baselines::plan_flops_proportional(
                &curves, &flops, stage, 512, &net, model.param_count()).unwrap();
            let r_pop = simulate_iteration(&pop, &oracle, &net, model).unwrap();
            let r_whale = simulate_iteration(&whale, &oracle, &net, model).unwrap();
            assert!(r_pop.tflops >= r_whale.tflops * 0.98, "stage {stage}");
            if r_pop.tflops > r_whale.tflops * 1.02 {
                any_win = true;
            }
        }
        assert!(any_win, "poplar should clearly beat whale in some stage");
    }

    #[test]
    fn idle_time_definition_eq2() {
        let (curves, _, oracle, net) = cluster_c_setup();
        let model = oracle.model;
        let plan = allocator::plan(&curves, 0, 256, &net, model.param_count()).unwrap();
        let r = simulate_iteration(&plan, &oracle, &net, model).unwrap();
        // some rank must have ~zero idle (the slowest one)
        let min_idle = r.ranks.iter().map(|x| x.idle_s).fold(f64::MAX, f64::min);
        assert!(min_idle < 1e-9);
    }

    #[test]
    fn tflops_accounting_consistent() {
        let (curves, _, oracle, net) = cluster_c_setup();
        let model = oracle.model;
        let plan = allocator::plan(&curves, 1, 512, &net, model.param_count()).unwrap();
        let r = simulate_iteration(&plan, &oracle, &net, model).unwrap();
        let expect = 512.0 * model.flops_per_sample() / r.wall_s / 1e12;
        assert!((r.tflops - expect).abs() < 1e-9);
        assert_eq!(r.samples, 512);
    }

    #[test]
    fn zero3_wall_time_includes_per_step_comm() {
        let (curves, _, oracle, net) = cluster_c_setup();
        let model = oracle.model;
        let p2 = allocator::plan(&curves, 2, 256, &net, model.param_count()).unwrap();
        let p3 = allocator::plan(&curves, 3, 256, &net, model.param_count()).unwrap();
        let r2 = simulate_iteration(&p2, &oracle, &net, model).unwrap();
        let r3 = simulate_iteration(&p3, &oracle, &net, model).unwrap();
        // z3 moves ~3x the per-step volume of z2's RS
        assert!(r3.comm_s > r2.comm_s);
    }

    #[test]
    fn drift_oracle_slows_one_rank_and_raises_wall() {
        let (curves, _, oracle, net) = cluster_c_setup();
        let model = oracle.model;
        let plan = allocator::plan(&curves, 1, 256, &net, model.param_count()).unwrap();
        let healthy = simulate_iteration(&plan, &oracle, &net, model).unwrap();
        let slowed = DriftOracle::healthy(oracle, 8).slow(0, 2.5);
        assert!((slowed.time(0, 4) - slowed.inner.time(0, 4) * 2.5).abs() < 1e-12);
        assert!((slowed.time(1, 4) - slowed.inner.time(1, 4)).abs() < 1e-15);
        assert!(slowed.speed(0) < slowed.inner.speed(0));
        let drifted = simulate_iteration(&plan, &slowed, &net, slowed.inner.model).unwrap();
        assert!(drifted.wall_s > healthy.wall_s, "straggler must stretch the iteration");
        assert_eq!(drifted.samples, healthy.samples);
    }

    #[test]
    fn shard_ranges_tile_or_replicate_per_stage() {
        // partitioned stages tile [0, ψ) exactly, remainder first
        for stage in 1..=3u8 {
            let r = optimizer_shard_ranges(stage, 1001, 4).unwrap();
            assert_eq!(r.len(), 4);
            assert_eq!(r[0], (0, 251));
            assert_eq!(r[3].1, 1001);
            let mut cursor = 0;
            for (lo, hi) in r {
                assert_eq!(lo, cursor);
                cursor = hi;
            }
        }
        // stage 0 replicates
        let r = optimizer_shard_ranges(0, 1001, 3).unwrap();
        assert!(r.iter().all(|&x| x == (0, 1001)));
        // invalid inputs
        assert!(optimizer_shard_ranges(4, 1001, 3).is_none());
        assert!(optimizer_shard_ranges(1, 1001, 0).is_none());
    }

    #[test]
    fn corrupt_stage_is_typed_error_not_panic() {
        // Plan.stage is pub: a corrupt value must surface, not panic
        let (curves, _, oracle, net) = cluster_c_setup();
        let model = oracle.model;
        let mut plan = allocator::plan(&curves, 1, 256, &net, model.param_count()).unwrap();
        plan.stage = 11;
        assert_eq!(
            simulate_iteration(&plan, &oracle, &net, model).unwrap_err(),
            PlanError::InvalidStage(11)
        );
    }

    #[test]
    fn balanced_plan_has_lower_objective_than_uniform() {
        let (curves, _, oracle, net) = cluster_c_setup();
        let model = oracle.model;
        let pop = allocator::plan(&curves, 1, 512, &net, model.param_count()).unwrap();
        let uni = baselines::plan_uniform(&curves, 1, 512, &net, model.param_count()).unwrap();
        let r_pop = simulate_iteration(&pop, &oracle, &net, model).unwrap();
        let r_uni = simulate_iteration(&uni, &oracle, &net, model).unwrap();
        assert!(r_pop.objective <= r_uni.objective);
    }
}
