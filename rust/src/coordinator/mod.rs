//! L3 coordination: the paper's system contribution as a leader/worker
//! runtime.
//!
//! * [`messages`] — the command/reply protocol;
//! * [`worker`] — one thread per (simulated or real) GPU;
//! * [`leader`] — Fig. 2's pipeline: online profiling → offline
//!   analyzing → training, with automatic ZeRO-stage escalation.

pub mod leader;
pub mod messages;
pub mod worker;

pub use leader::{fit_curves, JobReport, Leader, LiveIteration};
pub use messages::{WorkerCmd, WorkerReply};
pub use worker::worker_loop;
