//! L3 coordination: the paper's system contribution as a leader/worker
//! runtime.
//!
//! * [`messages`] — the command/reply protocol (incl. elastic
//!   membership/drift commands);
//! * [`worker`] — one thread per (simulated or real) GPU, wrapped in a
//!   [`worker::DriftDevice`] so slowdowns apply to steps *and* re-profiles;
//! * [`leader`] — Fig. 2's pipeline: online profiling → offline
//!   analyzing → training, with automatic ZeRO-stage escalation, plus
//!   the elastic job loop (`run_elastic_job`).

pub mod leader;
pub mod messages;
pub mod worker;

pub use leader::{
    fit_curves, ElasticIterationReport, ElasticJobReport, ElasticOptions, JobReport, Leader,
    LiveIteration,
};
pub use messages::{WorkerCmd, WorkerReply};
pub use worker::{worker_loop, DriftDevice};
