//! Leader: the full Poplar pipeline over a set of worker threads.
//!
//! Mirrors the paper's Fig. 2 workflow:
//!
//! 1. **Online profiling** — broadcast `Profile{stage}` to all workers
//!    (Alg. 1 runs in parallel, one OS thread per GPU); if any worker
//!    reports that batch 1 OOMs, escalate the ZeRO stage and retry.
//! 2. **Offline analyzing** — fit [`PerfCurve`]s from the profiled
//!    points, run the selected allocator (Alg. 2 or a baseline).
//! 3. **Training** — per iteration, dispatch each rank's schedule and
//!    reconstruct the BSP timeline from the returned per-micro-step
//!    times (barrier per micro-step for ZeRO-2/3, one sync for 0/1).
//!
//! On top of the static pipeline sits the **elastic runtime**
//! ([`Leader::run_elastic_job`]): workers can leave (`RankLost`), join
//! (`RankJoined`, re-using cached curves for known GPU types) or
//! silently slow down (`RankSlowed`, discovered by drift detection and
//! answered with an incremental re-profile of only the affected ranks),
//! with Algorithm 2 re-run over the surviving curve set. Every replan
//! also rebuilds the optimizer-shard layout (`ckpt::ShardManifest`,
//! snapshotted to disk when `ElasticOptions::ckpt_dir` is set) and
//! charges the *measured* minimal shard-movement cost — bytes that
//! actually changed owner, lost shards restored from the checkpoint —
//! once to the next iteration.

use std::sync::mpsc::{self, Receiver, Sender};
use std::thread::JoinHandle;

use anyhow::{anyhow, bail, Result};

use super::messages::{WorkerCmd, WorkerReply};
use super::worker::worker_loop;
use crate::allocator::{self, baselines, Plan};
use crate::cluster::{catalog, ClusterSpec};
use crate::config::model::ModelSpec;
use crate::config::Strategy;
use crate::curves::PerfCurve;
use crate::elastic::{self, ElasticEvent, ElasticPlanner, ScheduledEvent};
use crate::memmodel;
use crate::metrics::flops;
use crate::netsim::{BwMonitor, NetSim};
use crate::profiler::{ClusterProfile, Device, ProfileResult, SimDevice};

/// Live (worker-measured) timing of one iteration.
#[derive(Debug, Clone)]
pub struct LiveIteration {
    /// Wall time reconstructed from the BSP barriers.
    pub wall_s: f64,
    /// Per-rank busy seconds.
    pub busy_s: Vec<f64>,
    /// Per-rank idle seconds.
    pub idle_s: Vec<f64>,
    /// Collective seconds.
    pub comm_s: f64,
    /// What the collectives *would* have cost at spec bandwidth — the
    /// prediction baseline the comm-drift detector and the bandwidth
    /// monitor's sample inversion compare `comm_s` against.
    pub comm_pred_spec_s: f64,
    /// Bandwidth-independent (α-term) share of the collective time.
    pub comm_alpha_s: f64,
    /// Cluster TFLOP/s for this iteration.
    pub tflops: f64,
    /// Raw per-rank micro-step compute times (compact rank order) — the
    /// drift detector's input.
    pub per_rank_steps: Vec<Vec<f64>>,
}

/// Everything `run_job` produces.
#[derive(Debug)]
pub struct JobReport {
    /// Stage actually used (after auto-escalation).
    pub stage: u8,
    /// Per-rank profiling results.
    pub profile: Vec<ProfileResult>,
    /// The allocation decision.
    pub plan: Plan,
    /// Per-iteration live timings.
    pub iterations: Vec<LiveIteration>,
    /// Mean TFLOP/s across iterations.
    pub tflops_mean: f64,
}

/// Knobs of the elastic runtime.
#[derive(Debug, Clone)]
pub struct ElasticOptions {
    /// Relative deviation (observed vs predicted micro-step time) beyond
    /// which a rank is re-profiled.
    pub drift_threshold: f64,
    /// Curve-cache capacity (number of `(gpu, model, stage)` curves).
    pub cache_cap: usize,
    /// Directory to snapshot the optimizer-shard manifest into after
    /// every plan (`[ckpt] dir` in config; `None` disables persistence).
    pub ckpt_dir: Option<std::path::PathBuf>,
    /// Cost-aware admission policy (`[autoscale]` in config). When set,
    /// `RankJoined` events become *offers* and each iteration's batch
    /// is priced JOINTLY by the unified engine
    /// (`crate::policy::decide_round`): one combined reshard stall per
    /// round, so a weak offer with a positive marginal contribution is
    /// admitted alongside a strong batch-mate that the old
    /// one-at-a-time rule would decline. A declined offer never
    /// mutates the planner or spawns a worker. `None` keeps the PR 1
    /// behaviour: every join is admitted.
    pub autoscale: Option<crate::autoscale::AutoscaleOptions>,
    /// Make the ZeRO stage a replan-time decision (`[elastic]
    /// allow_stage_change` / `poplar elastic --allow-stage-change`):
    /// after membership events the stage search re-checks every stage's
    /// Alg. 1 memory bound at the new group size, profiles only missing
    /// `(type, stage)` curve pairs, and migrates the optimizer-shard
    /// layout (`ckpt::migrate`, charged like a reshard) when the
    /// amortized gain beats the incumbent. `false` keeps the stage
    /// fixed after the initial escalation.
    pub allow_stage_change: bool,
    /// Shared amortization horizon from the `[policy]` config section.
    /// Used for the stage search when `[autoscale]` is not configured
    /// (with `[autoscale]` present, its — possibly `[policy]`-inherited
    /// — horizon wins, keeping the two searches consistent).
    pub policy_horizon_s: Option<f64>,
    /// Soft cap on offers admitted per joint round (`[policy]
    /// max_offers_per_round`); `None` keeps the engine default
    /// (`crate::policy::DEFAULT_MAX_OFFERS_PER_ROUND`). Batches of any
    /// size are priced — the cap only bounds the chosen subset.
    pub max_offers_per_round: Option<usize>,
    /// Let the round engine propose *pipeline groupings* (`[pipeline]`
    /// config section / `poplar elastic --allow-pipeline`): offer
    /// batches whose members are infeasible at EVERY ZeRO stage solo
    /// are packed into virtual DP ranks (`crate::pipeline`) and priced
    /// as one composed-curve admission in the same round
    /// (`RoundPlan::grouping`). Pricing-only in this runtime for now:
    /// the sim leader spawns one worker per *physical replica*, so a
    /// priced grouping is reported as advisory rather than spawned as
    /// a live pipeline — `exp::fig_pipeline` realizes admissions on
    /// the planner directly via `ElasticPlanner::add_group_slot`.
    pub allow_pipeline: bool,
    /// Ceiling on members per proposed pipeline group (`[pipeline]
    /// max_group_size`, CLI `--max-group-size`; parse enforces >= 2).
    pub pipeline_max_group_size: usize,
}

impl Default for ElasticOptions {
    fn default() -> Self {
        ElasticOptions {
            drift_threshold: elastic::DEFAULT_DRIFT_THRESHOLD,
            cache_cap: 32,
            ckpt_dir: None,
            autoscale: None,
            allow_stage_change: false,
            policy_horizon_s: None,
            max_offers_per_round: None,
            allow_pipeline: false,
            pipeline_max_group_size: crate::pipeline::DEFAULT_MAX_GROUP_SIZE,
        }
    }
}

/// One iteration of an elastic job.
#[derive(Debug, Clone)]
pub struct ElasticIterationReport {
    /// Iteration index.
    pub iter: usize,
    /// Events applied (or skipped, with a reason) before this iteration.
    pub events: Vec<String>,
    /// Live rank count during this iteration.
    pub n_ranks: usize,
    /// ZeRO stage this iteration ran at (moves when
    /// [`ElasticOptions::allow_stage_change`] lets a replan migrate).
    pub stage: u8,
    /// Wall seconds, including any one-shot resharding penalty.
    pub wall_s: f64,
    /// Cluster TFLOP/s of this iteration.
    pub tflops: f64,
    /// Whether Algorithm 2 re-ran before this iteration.
    pub replanned: bool,
    /// Slots (re-)profiled before this iteration (joins + drifters).
    pub reprofiled_slots: Vec<usize>,
    /// One-shot optimizer-state resharding cost charged here — measured
    /// from the minimal shard-movement set, not a full-state constant.
    pub reshard_penalty_s: f64,
    /// Optimizer-state bytes that changed owner in that reshard.
    pub reshard_bytes: u64,
    /// Fabric bandwidth estimate (GB/s) after this iteration's
    /// observation — the next replan prices collectives with it.
    pub bw_gbs: f64,
}

/// Everything `run_elastic_job` produces.
#[derive(Debug)]
pub struct ElasticJobReport {
    /// ZeRO stage the job *started* at (after the initial escalation).
    /// Fixed for the whole job unless
    /// [`ElasticOptions::allow_stage_change`] is set — then
    /// [`ElasticJobReport::final_stage`] and the per-iteration `stage`
    /// fields track the migrations.
    pub stage: u8,
    /// ZeRO stage active after the last iteration.
    pub final_stage: u8,
    /// Global batch size every plan covered.
    pub gbs: usize,
    /// Per-iteration timeline.
    pub iterations: Vec<ElasticIterationReport>,
    /// Total Algorithm 2 runs (initial plan included).
    pub replans: usize,
    /// Curve-cache hits after the initial profile — i.e. re-joins that
    /// skipped Alg. 1 (the initial build's per-duplicate-type hits are
    /// excluded).
    pub cache_hits: u64,
    /// Curve-cache misses after the initial profile.
    pub cache_misses: u64,
    /// The plan active after the last iteration.
    pub final_plan: Plan,
    /// The optimizer-shard layout of the final plan.
    pub final_manifest: crate::ckpt::ShardManifest,
}

struct WorkerHandle {
    cmd: Sender<WorkerCmd>,
    thread: Option<JoinHandle<()>>,
    alive: bool,
}

/// The coordinator leader.
pub struct Leader {
    workers: Vec<WorkerHandle>,
    replies: Receiver<WorkerReply>,
    rep_tx: Sender<WorkerReply>,
    model: ModelSpec,
    /// The planner-facing cost model: bandwidth is the *monitor's
    /// current estimate* (refreshed from `fabric` on sustained shifts),
    /// `n` tracks membership.
    net: NetSim,
    /// Measured-bandwidth estimator for the bottleneck link. The sim
    /// substrate's ground-truth fabric is `fabric.ground_truth(n,
    /// bw_factor)`; the monitor only ever sees collective times.
    fabric: BwMonitor,
    /// Ground-truth bandwidth multiplier injected by `bw:<link>:<factor>`
    /// events — like a `RankSlowed` factor, the planner is never told.
    bw_factor: f64,
    noise_sigma: f64,
    seed: u64,
}

impl Leader {
    /// Spawn one simulated worker per GPU of `cluster`.
    pub fn new_simulated(
        cluster: &ClusterSpec,
        model: &ModelSpec,
        noise_sigma: f64,
        seed: u64,
    ) -> Self {
        let fabric = BwMonitor::new(cluster.bottleneck_link());
        let net = fabric.snapshot(cluster.n_gpus());
        let instances = cluster.instances();
        let devices: Vec<Box<dyn Device>> = instances
            .iter()
            .map(|inst| {
                Box::new(SimDevice::new(
                    inst.spec.clone(),
                    model.clone(),
                    inst.rank,
                    instances.len(),
                    net.clone(),
                    noise_sigma,
                    seed,
                )) as Box<dyn Device>
            })
            .collect();
        let mut leader = Self::with_devices(devices, model.clone(), net);
        leader.fabric = fabric; // cluster-aware monitor (named link)
        leader.noise_sigma = noise_sigma;
        leader.seed = seed;
        leader
    }

    /// Spawn workers over caller-provided devices (e.g. real PJRT-backed
    /// devices from `train`).
    pub fn with_devices(devices: Vec<Box<dyn Device>>, model: ModelSpec, net: NetSim) -> Self {
        let (rep_tx, rep_rx) = mpsc::channel();
        let workers = devices
            .into_iter()
            .map(|dev| {
                let (cmd_tx, cmd_rx) = mpsc::channel();
                let tx = rep_tx.clone();
                let thread = std::thread::spawn(move || worker_loop(dev, cmd_rx, tx));
                WorkerHandle { cmd: cmd_tx, thread: Some(thread), alive: true }
            })
            .collect();
        let fabric = BwMonitor::from_netsim(&net);
        Leader {
            workers,
            replies: rep_rx,
            rep_tx,
            model,
            net,
            fabric,
            bw_factor: 1.0,
            noise_sigma: 0.0,
            seed: 0,
        }
    }

    /// Number of live ranks.
    pub fn n_ranks(&self) -> usize {
        self.active_ranks().len()
    }

    /// Live worker slots in rank order.
    pub fn active_ranks(&self) -> Vec<usize> {
        self.workers
            .iter()
            .enumerate()
            .filter(|(_, w)| w.alive)
            .map(|(i, _)| i)
            .collect()
    }

    /// The collective cost model in use: its `n` tracks membership and
    /// its bandwidth is the monitor's current *estimate*, not the spec.
    pub fn net(&self) -> &NetSim {
        &self.net
    }

    /// The measured-bandwidth estimator for the bottleneck link.
    pub fn fabric(&self) -> &BwMonitor {
        &self.fabric
    }

    /// Inject a ground-truth fabric bandwidth shift (elastic `BwDrift`):
    /// the named link's effective bandwidth becomes `factor × spec`.
    /// Symmetric to [`Leader::set_slowdown`], the planner is *not* told —
    /// only the monitor's observed collective times can discover it. An
    /// event naming a link other than the fabric bottleneck is rejected
    /// (nothing in this job's ring crosses it).
    pub fn set_bw_factor(&mut self, link: &str, factor: f64) -> Result<()> {
        if !factor.is_finite() || factor <= 0.0 {
            bail!("bandwidth factor must be finite and > 0, got {factor}");
        }
        if link != self.fabric.link_name() {
            bail!(
                "link {link:?} is not this job's bottleneck fabric ({:?})",
                self.fabric.link_name()
            );
        }
        self.bw_factor = factor;
        Ok(())
    }

    /// Receive one worker reply. The leader holds a clone of the reply
    /// sender (needed to spawn joiners), so a dead worker can never close
    /// the channel — a timeout stands in for "worker thread died".
    fn recv_reply(&self) -> Result<WorkerReply> {
        self.replies
            .recv_timeout(std::time::Duration::from_secs(120))
            .map_err(|e| anyhow!("no worker reply within 120s ({e}); worker thread died?"))
    }

    /// Tell every live worker the new data-parallel group size (their
    /// ZeRO shard sizes — and hence memory budgets — move with it).
    fn broadcast_group_size(&self) {
        let n = self.n_ranks();
        for w in self.workers.iter().filter(|w| w.alive) {
            let _ = w.cmd.send(WorkerCmd::SetGroupSize { n });
        }
    }

    /// Remove a live rank from the job (elastic `RankLost`): shuts the
    /// worker down, joins its thread, shrinks the collective group.
    pub fn remove_rank(&mut self, slot: usize) -> Result<()> {
        if !self.workers.get(slot).is_some_and(|w| w.alive) {
            bail!("slot {slot} is not a live rank");
        }
        if self.n_ranks() <= 1 {
            bail!("cannot remove the last live rank");
        }
        let w = &mut self.workers[slot];
        let _ = w.cmd.send(WorkerCmd::Shutdown);
        if let Some(t) = w.thread.take() {
            let _ = t.join();
        }
        w.alive = false;
        self.net.n = self.n_ranks();
        self.broadcast_group_size();
        Ok(())
    }

    /// Add a fresh simulated rank of catalog type `gpu` (elastic
    /// `RankJoined`); returns the new slot id.
    pub fn add_simulated_rank(&mut self, gpu: &str) -> Result<usize> {
        let spec = catalog::spec(gpu).ok_or_else(|| anyhow!("unknown GPU type {gpu:?}"))?;
        let slot = self.workers.len();
        let n_after = self.n_ranks() + 1;
        let mut dev_net = self.net.clone();
        dev_net.n = n_after;
        let dev: Box<dyn Device> = Box::new(SimDevice::new(
            spec,
            self.model.clone(),
            slot,
            n_after,
            dev_net,
            self.noise_sigma,
            self.seed,
        ));
        let (cmd_tx, cmd_rx) = mpsc::channel();
        let tx = self.rep_tx.clone();
        let thread = std::thread::spawn(move || worker_loop(dev, cmd_rx, tx));
        self.workers.push(WorkerHandle { cmd: cmd_tx, thread: Some(thread), alive: true });
        self.net.n = n_after;
        self.broadcast_group_size();
        Ok(slot)
    }

    /// Inject a compute slowdown on a live rank (elastic `RankSlowed`).
    pub fn set_slowdown(&mut self, slot: usize, factor: f64) -> Result<()> {
        if !factor.is_finite() || factor <= 0.0 {
            bail!("slowdown factor must be finite and > 0, got {factor}");
        }
        let w = self
            .workers
            .get(slot)
            .filter(|w| w.alive)
            .ok_or_else(|| anyhow!("slot {slot} is not a live rank"))?;
        w.cmd
            .send(WorkerCmd::SetSlowdown { factor })
            .map_err(|_| anyhow!("worker died"))?;
        Ok(())
    }

    /// Phase 1: parallel Alg. 1 with automatic stage escalation, over the
    /// live ranks.
    pub fn profile(&mut self, requested_stage: u8) -> Result<ClusterProfile> {
        // user-controlled via CLI/config: an error, never a panic
        if requested_stage >= 4 {
            bail!("invalid ZeRO stage {requested_stage} (want 0..=3)");
        }
        let active = self.active_ranks();
        'stage: for stage in requested_stage..4 {
            let results = self.profile_slots(&active, stage)?;
            let mut ranks = Vec::with_capacity(results.len());
            for result in results {
                match result {
                    Some(r) => ranks.push(r),
                    None => {
                        // some rank cannot fit a single sample: escalate
                        if stage == 3 {
                            bail!("model does not fit a single sample even at ZeRO-3");
                        }
                        continue 'stage;
                    }
                }
            }
            return Ok(ClusterProfile { stage, ranks });
        }
        unreachable!()
    }

    /// Incremental Alg. 1: profile only `slots`, at an explicit stage —
    /// the elastic runtime calls this at the job's current stage for
    /// joins/drift and at *candidate* stages for the stage search's
    /// missing `(type, stage)` pairs. Results come back in `slots`
    /// order; `None` means the rank cannot fit a single sample at this
    /// stage — the caller decides whether that is fatal (a survivor),
    /// grounds for eviction (a hopeful joiner), or merely disqualifies
    /// a candidate stage (a speculative probe).
    pub fn profile_slots(
        &mut self,
        slots: &[usize],
        stage: u8,
    ) -> Result<Vec<Option<ProfileResult>>> {
        // validate before any worker sees the command: an invalid stage
        // must not assert inside a worker thread
        if stage >= 4 {
            bail!("invalid ZeRO stage {stage} (want 0..=3)");
        }
        for &slot in slots {
            let w = self
                .workers
                .get(slot)
                .filter(|w| w.alive)
                .ok_or_else(|| anyhow!("slot {slot} is not a live rank"))?;
            w.cmd
                .send(WorkerCmd::Profile { stage })
                .map_err(|_| anyhow!("worker died"))?;
        }
        let mut results: Vec<Option<ProfileResult>> = (0..slots.len()).map(|_| None).collect();
        // slot -> request position, built once (every slot was validated
        // against `workers` above): O(1) reply matching instead of a
        // per-reply scan over the request list
        let mut slot_pos: Vec<Option<usize>> = vec![None; self.workers.len()];
        for (i, &slot) in slots.iter().enumerate() {
            slot_pos[slot] = Some(i);
        }
        for _ in 0..slots.len() {
            match self.recv_reply()? {
                WorkerReply::Profiled { rank, result } => {
                    let pos = slot_pos
                        .get(rank)
                        .copied()
                        .flatten()
                        .ok_or_else(|| anyhow!("profile reply from unexpected slot {rank}"))?;
                    results[pos] = result.map(|r| *r);
                }
                other => bail!("unexpected reply during incremental profile: {other:?}"),
            }
        }
        Ok(results)
    }

    /// Phase 2: fit curves + run the selected allocator.
    pub fn plan_from_profile(
        &self,
        profile: &ClusterProfile,
        strategy: Strategy,
        gbs: usize,
    ) -> Result<Plan> {
        let curves = fit_curves(profile)?;
        let psi = self.model.param_count();
        let plan = match strategy {
            Strategy::Poplar => {
                allocator::plan(&curves, profile.stage, gbs, &self.net, psi)
                    .map_err(|e| anyhow!("poplar plan: {e}"))?
            }
            Strategy::Uniform => {
                baselines::plan_uniform(&curves, profile.stage, gbs, &self.net, psi)
                    .map_err(|e| anyhow!("uniform plan: {e}"))?
            }
            Strategy::Flops => {
                let flops: Vec<f64> = profile.ranks.iter().map(|r| r.flops_rating).collect();
                baselines::plan_flops_proportional(
                    &curves, &flops, profile.stage, gbs, &self.net, psi,
                )
                .map_err(|e| anyhow!("flops plan: {e}"))?
            }
        };
        plan.validate().map_err(|e| anyhow!("invalid plan: {e}"))?;
        Ok(plan)
    }

    /// Phase 3: run one iteration and reconstruct the BSP timeline.
    /// `plan.ranks[i]` executes on the i-th *live* slot.
    pub fn run_iteration(&mut self, plan: &Plan) -> Result<LiveIteration> {
        let active = self.active_ranks();
        if plan.ranks.len() != active.len() {
            bail!(
                "plan covers {} ranks but {} are live — replan after membership changes",
                plan.ranks.len(),
                active.len()
            );
        }
        for (&slot, r) in active.iter().zip(&plan.ranks) {
            self.workers[slot]
                .cmd
                .send(WorkerCmd::RunSchedule {
                    stage: plan.stage,
                    micro_batch: r.micro_batch,
                    grad_accum_steps: r.grad_accum_steps,
                    last_batch: r.last_batch,
                })
                .map_err(|_| anyhow!("worker died"))?;
        }
        let n = active.len();
        // slot -> compact rank index, built once: replies arrive in
        // arbitrary order, and a per-reply `position()` scan is O(n^2)
        // per iteration at the 1000-rank scale the leader bench drives
        let mut rank_pos: Vec<Option<usize>> = vec![None; self.workers.len()];
        for (i, &slot) in active.iter().enumerate() {
            rank_pos[slot] = Some(i);
        }
        let mut per_rank: Vec<Vec<f64>> = vec![Vec::new(); n];
        let mut samples = 0usize;
        for _ in 0..n {
            match self.recv_reply()? {
                WorkerReply::ScheduleDone { rank, step_times, samples: s, oom_at } => {
                    if let Some(b) = oom_at {
                        bail!("rank {rank} OOMed at batch {b} — planner bug");
                    }
                    let idx = rank_pos
                        .get(rank)
                        .copied()
                        .flatten()
                        .ok_or_else(|| anyhow!("schedule reply from unknown slot {rank}"))?;
                    per_rank[idx] = step_times;
                    samples += s;
                }
                other => bail!("unexpected reply during iteration: {other:?}"),
            }
        }

        let psi = self.model.param_count();
        let gas = per_rank.iter().map(Vec::len).max().unwrap_or(0);
        let mut busy = vec![0.0f64; n];
        let mut idle = vec![0.0f64; n];
        let mut wall = 0.0f64;
        let mut comm = 0.0f64;
        let mut comm_pred_spec = 0.0f64;
        let mut comm_alpha = 0.0f64;
        // the collectives run on the *ground-truth* fabric (spec bandwidth
        // × injected drift factor); the spec-priced twin and its α-only
        // share are accumulated alongside so the bandwidth monitor can
        // invert the observed time back into an effective-bandwidth sample
        let truth = self.fabric.ground_truth(n, self.bw_factor);
        let spec = self.fabric.spec_snapshot(n);
        match plan.stage {
            0 | 1 => {
                // one sync point at the end
                let totals: Vec<f64> =
                    per_rank.iter().map(|ts| ts.iter().sum::<f64>()).collect();
                let t_max = totals.iter().cloned().fold(0.0, f64::max);
                for i in 0..n {
                    busy[i] = totals[i];
                    idle[i] = t_max - totals[i];
                }
                let c = truth
                    .iteration_comm_time(plan.stage, psi)
                    .map_err(|e| anyhow!("{e}"))?;
                comm += c;
                wall = t_max + c;
                comm_pred_spec += spec
                    .iteration_comm_time(plan.stage, psi)
                    .map_err(|e| anyhow!("{e}"))?;
                comm_alpha += spec
                    .iteration_comm_time(plan.stage, 0)
                    .map_err(|e| anyhow!("{e}"))?;
            }
            2 | 3 => {
                let c_step = truth
                    .per_microstep_comm_time(plan.stage, psi)
                    .map_err(|e| anyhow!("{e}"))?;
                let c_step_spec = spec
                    .per_microstep_comm_time(plan.stage, psi)
                    .map_err(|e| anyhow!("{e}"))?;
                let c_step_alpha = spec
                    .per_microstep_comm_time(plan.stage, 0)
                    .map_err(|e| anyhow!("{e}"))?;
                // no per-step transposed Vec: one rank-major max sweep
                // (same rank-ascending max order as the old per-step
                // fold), then per-rank accumulation in step order — the
                // FP accumulation order, and hence every golden table,
                // is bit-identical to the transposing loop it replaces
                let mut step_max = vec![0.0f64; gas];
                for ts in &per_rank {
                    for (step, m) in step_max.iter_mut().enumerate() {
                        *m = f64::max(*m, ts.get(step).copied().unwrap_or(0.0));
                    }
                }
                for (i, ts) in per_rank.iter().enumerate() {
                    for (step, &m) in step_max.iter().enumerate() {
                        let t = ts.get(step).copied().unwrap_or(0.0);
                        busy[i] += t;
                        idle[i] += m - t;
                    }
                }
                for &m in &step_max {
                    wall += m + c_step;
                    comm += c_step;
                    comm_pred_spec += c_step_spec;
                    comm_alpha += c_step_alpha;
                }
                let c = truth
                    .iteration_comm_time(plan.stage, psi)
                    .map_err(|e| anyhow!("{e}"))?;
                comm += c;
                wall += c;
                comm_pred_spec += spec
                    .iteration_comm_time(plan.stage, psi)
                    .map_err(|e| anyhow!("{e}"))?;
                comm_alpha += spec
                    .iteration_comm_time(plan.stage, 0)
                    .map_err(|e| anyhow!("{e}"))?;
            }
            s => bail!("invalid stage {s}"),
        }

        Ok(LiveIteration {
            wall_s: wall,
            busy_s: busy,
            idle_s: idle,
            comm_s: comm,
            comm_pred_spec_s: comm_pred_spec,
            comm_alpha_s: comm_alpha,
            tflops: flops::tflops(&self.model, samples, wall),
            per_rank_steps: per_rank,
        })
    }

    /// The full pipeline: profile → plan → `iterations` timed runs.
    pub fn run_job(
        &mut self,
        requested_stage: u8,
        strategy: Strategy,
        gbs: usize,
        iterations: usize,
    ) -> Result<JobReport> {
        let profile = self.profile(requested_stage)?;
        let plan = self.plan_from_profile(&profile, strategy, gbs)?;
        let mut iters = Vec::with_capacity(iterations);
        for _ in 0..iterations {
            iters.push(self.run_iteration(&plan)?);
        }
        let tflops_mean =
            iters.iter().map(|i| i.tflops).sum::<f64>() / iters.len().max(1) as f64;
        Ok(JobReport { stage: profile.stage, profile: profile.ranks, plan,
                       iterations: iters, tflops_mean })
    }

    /// The elastic pipeline: profile → plan → iterate, applying the
    /// event `schedule` as it fires.
    ///
    /// Per iteration the loop (1) applies due events (losses shut the
    /// worker down, joins spawn one — re-using the curve cache for known
    /// GPU types — and slowdowns are injected silently), (2) profiles
    /// only ranks without a usable curve — and, with
    /// [`ElasticOptions::allow_stage_change`], the candidate-stage
    /// `(type, stage)` pairs the stage search still needs —
    /// (3) re-runs Algorithm 2 if membership or curves changed (the
    /// replan may migrate the ZeRO stage; the `ckpt::migrate` movement
    /// is charged exactly like a reshard and logged as a stage-change
    /// event), charging the measured minimal shard-movement cost and
    /// snapshotting the shard manifest when persistence is on, (4) runs
    /// the iteration live and (5) compares observed micro-step times
    /// against the curves: drifted ranks are re-profiled incrementally
    /// and the next iteration replans. A fabric twin (5b) checks the
    /// observed collective time the same way and feeds the bandwidth
    /// monitor: sustained shifts (never a single sample) log a
    /// `bw-drift:<link>:<factor>` event, refresh the cost-model snapshot
    /// to the new estimate and mark the plan stale.
    pub fn run_elastic_job(
        &mut self,
        requested_stage: u8,
        gbs: usize,
        iterations: usize,
        schedule: &[ScheduledEvent],
        opts: &ElasticOptions,
    ) -> Result<ElasticJobReport> {
        let active = self.active_ranks();
        if active != (0..self.workers.len()).collect::<Vec<_>>() {
            bail!("run_elastic_job must start from a fresh leader (no departed ranks)");
        }

        // initial full profile + plan
        let profile = self.profile(requested_stage)?;
        let initial_stage = profile.stage;
        let mut planner = ElasticPlanner::new(
            initial_stage,
            gbs,
            &self.model.name,
            self.model.param_count(),
            opts.cache_cap,
        );
        if opts.allow_stage_change {
            // same horizon semantics as autoscale: the expected time
            // until the next membership event re-prices everything
            // ([autoscale] horizon wins, then the shared [policy] one)
            planner.set_stage_policy(Some(elastic::StagePolicy {
                horizon_s: opts
                    .autoscale
                    .as_ref()
                    .map(|a| a.horizon_s)
                    .or(opts.policy_horizon_s)
                    .unwrap_or(crate::autoscale::DEFAULT_HORIZON_S),
            }));
        }
        let curves = fit_curves(&profile)?;
        for (r, c) in profile.ranks.iter().zip(curves) {
            let slot = planner.add_slot(&r.name);
            planner
                .install_curve(slot, c, false)
                .map_err(|e| anyhow!("installing initial curve for slot {slot}: {e}"))?;
        }
        self.net.n = planner.active_slots().len();
        planner.replan(&self.net).map_err(|e| anyhow!("initial plan: {e}"))?;
        if let Some(dir) = &opts.ckpt_dir {
            if let Some(m) = planner.manifest() {
                // this run now owns the directory: repoint LATEST even if
                // a previous (longer) run left a higher ordinal behind
                m.save_with(dir, true).map_err(|e| anyhow!("ckpt snapshot: {e}"))?;
            }
        }
        // report cache traffic relative to this point: the initial build
        // scores a hit per duplicate GPU type, which is not a re-join
        let (hits0, misses0) = (planner.cache().hits(), planner.cache().misses());

        // pre-index the schedule by firing iteration: the per-iteration
        // due scan was O(iterations × |schedule|); events past the last
        // iteration never fired before and still don't
        let mut due_index: Vec<Vec<&ScheduledEvent>> = vec![Vec::new(); iterations];
        for ev in schedule {
            if ev.at_iter < iterations {
                due_index[ev.at_iter].push(ev);
            }
        }

        let mut reports = Vec::with_capacity(iterations);
        for iter in 0..iterations {
            let mut events = Vec::new();
            let mut reprofiled = Vec::new();
            let mut membership_changed = false;

            // (1) apply due events. Losses and slowdowns first (in
            // schedule order), then joins as a batch: with `[autoscale]`
            // configured the batch is one joint *round*
            // (`policy::decide_round`) evaluated against the
            // pre-admission state — one combined reshard stall, so an
            // earlier deferred (not yet profiled) joiner can neither
            // make its batch-mates unevaluable nor charge them a second
            // stall. Declining touches nothing.
            let due = &due_index[iter];
            for ev in due {
                let outcome: Result<String, String> = match &ev.event {
                    ElasticEvent::RankJoined { .. } => continue, // second pass
                    ElasticEvent::RankLost { slot } => planner
                        .lose_slot(*slot)
                        .map_err(|e| e.to_string())
                        .and_then(|()| self.remove_rank(*slot).map_err(|e| e.to_string()))
                        .map(|()| {
                            membership_changed = true;
                            ev.event.label()
                        }),
                    ElasticEvent::RankSlowed { slot, factor } => planner
                        .apply(&ev.event)
                        .map_err(|e| e.to_string())
                        .and_then(|()| {
                            self.set_slowdown(*slot, *factor).map_err(|e| e.to_string())
                        })
                        .map(|()| ev.event.label()),
                    // ground-truth fabric shift: validated no-op on the
                    // planner (symmetric to RankSlowed — the monitor, not
                    // an announcement, must discover it from collectives)
                    ElasticEvent::BwDrift { link, factor } => planner
                        .apply(&ev.event)
                        .map_err(|e| e.to_string())
                        .and_then(|()| {
                            self.set_bw_factor(link, *factor).map_err(|e| e.to_string())
                        })
                        .map(|()| ev.event.label()),
                };
                match outcome {
                    Ok(label) => events.push(label),
                    Err(e) => events.push(format!("skipped {}: {e}", ev.event.label())),
                }
            }
            // evaluate every offer of the batch before admitting any —
            // jointly, through the unified round engine
            // (`policy::decide_round`): the whole batch is priced as ONE
            // admission paying ONE reshard, so an offer with a positive
            // marginal contribution is admitted even when the
            // one-at-a-time rule would decline it solo. Declining still
            // touches nothing.
            let join_events: Vec<&ScheduledEvent> = due
                .iter()
                .filter(|ev| matches!(ev.event, ElasticEvent::RankJoined { .. }))
                .copied()
                .collect();
            let round = match &opts.autoscale {
                Some(a) if !join_events.is_empty() => {
                    let offers: Vec<String> = join_events
                        .iter()
                        .map(|ev| match &ev.event {
                            ElasticEvent::RankJoined { gpu } => gpu.clone(),
                            _ => unreachable!("filtered above"),
                        })
                        .collect();
                    let mut ropts = crate::policy::RoundOptions::from_autoscale(a);
                    if let Some(cap) = opts.max_offers_per_round {
                        ropts.max_offers_per_round = cap;
                    }
                    ropts.allow_pipeline = opts.allow_pipeline;
                    ropts.max_group_size = opts.pipeline_max_group_size;
                    Some(crate::policy::decide_round(
                        &planner, &self.net, &self.model, &offers, &ropts,
                    ))
                }
                _ => None,
            };
            // a round that could not be priced at all degrades to the
            // PR-3 per-offer rule below — label it loudly so a degraded
            // round is never indistinguishable from a deliberate greedy
            // one in the event log
            if let Some(Err(e)) = &round {
                events.push(format!("round-fallback:{e}"));
            }
            // a priced pipeline grouping is surfaced in the event log —
            // membership ops stay physical (per-GPU verdicts below),
            // the plan-level virtual rank is advisory here (see
            // `ElasticOptions::allow_pipeline`)
            if let Some(Ok(r)) = &round {
                if let Some(g) = &r.grouping {
                    events.push(format!(
                        "pipeline-group:{} rate {:.2} samples/s",
                        g.label, g.rate
                    ));
                }
            }
            enum JoinVerdict {
                Admit(&'static str),
                Decline(String),
                Skip(String),
            }
            // decide phase (read-only), then act phase (mutating) — the
            // decisions come from the joint round; if the round itself
            // could not be priced (e.g. a planner state the baseline
            // cannot rate, or an unknown offer type), fall back to the
            // PR-3 per-offer rule instead of dropping the batch
            let verdicts: Vec<JoinVerdict> = join_events
                .iter()
                .enumerate()
                .map(|(j, ev)| {
                    let ElasticEvent::RankJoined { gpu } = &ev.event else {
                        unreachable!("joins only")
                    };
                    match &round {
                        None => JoinVerdict::Admit(""),
                        Some(Ok(r)) => match &r.offers[j].action {
                            crate::policy::Action::Decline { .. } => {
                                JoinVerdict::Decline(r.offers[j].reason.clone())
                            }
                            crate::policy::Action::Defer { .. } => {
                                JoinVerdict::Admit("deferred->profiling ")
                            }
                            _ => JoinVerdict::Admit("accepted "),
                        },
                        Some(Err(e)) => {
                            match crate::autoscale::evaluate_offer(
                                &planner,
                                &self.net,
                                &self.model,
                                gpu,
                                // lint:allow(panic-path) -- a round ran, so autoscale is Some
                                opts.autoscale.as_ref().expect("a round implies autoscale"),
                            ) {
                                Err(pe) => JoinVerdict::Skip(format!(
                                    "offer evaluation failed: {e}; solo fallback: {pe}"
                                )),
                                Ok(d) => match d.decision {
                                    crate::autoscale::Decision::Reject => {
                                        JoinVerdict::Decline(d.reason)
                                    }
                                    crate::autoscale::Decision::Defer => {
                                        JoinVerdict::Admit("deferred->profiling ")
                                    }
                                    crate::autoscale::Decision::Accept => {
                                        JoinVerdict::Admit("accepted ")
                                    }
                                },
                            }
                        }
                    }
                })
                .collect();
            if let Some(Ok(r)) = &round {
                // the round's stage choice is advisory pricing: the
                // replan below re-runs its own (kernel-identical) stage
                // search over the admitted membership, and that search
                // is what actually migrates — surface the divergence
                // point in the log
                if r.stage != r.stage_before && !r.admitted.is_empty() {
                    events.push(format!(
                        "offer round priced at ZeRO-{} (the replan's stage search \
                         performs the migration)",
                        r.stage
                    ));
                }
            }
            for (ev, verdict) in join_events.iter().zip(verdicts) {
                let ElasticEvent::RankJoined { gpu } = &ev.event else {
                    unreachable!("joins only")
                };
                let outcome: Result<String, String> = match verdict {
                    // declined: no worker spawned, no planner slot, no
                    // cache traffic
                    JoinVerdict::Decline(reason) => {
                        Ok(format!("declined {}: {reason}", ev.event.label()))
                    }
                    JoinVerdict::Skip(reason) => Err(reason),
                    JoinVerdict::Admit(prefix) => self
                        .add_simulated_rank(gpu)
                        .map_err(|e| e.to_string())
                        .map(|slot| {
                            let pslot = planner.add_slot(gpu);
                            debug_assert_eq!(slot, pslot, "leader/planner slots diverged");
                            membership_changed = true;
                            format!("{prefix}{}", ev.event.label())
                        }),
                };
                match outcome {
                    Ok(label) => events.push(label),
                    Err(e) => events.push(format!("skipped {}: {e}", ev.event.label())),
                }
            }

            // (2a) incremental profiling: only ranks without a usable
            // curve (fresh joins), at the job's *current* stage. A
            // joiner that cannot fit a single sample there is NOT
            // evicted up front when the stage search is on — the search
            // evaluates its admission at every feasible measured stage
            // and the replan below migrates there (it is evicted only
            // if no such stage exists). Without the search, eviction as
            // before.
            let stage_now = planner.stage();
            let need = planner.needs_profile();
            let mut homeless: Vec<usize> = Vec::new();
            if !need.is_empty() {
                let results = self.profile_slots(&need, stage_now)?;
                for (&slot, result) in need.iter().zip(results) {
                    match result {
                        Some(r) => {
                            let curve = PerfCurve::fit(r.points.clone(), r.mbs)
                                .map_err(|e| anyhow!("slot {slot} curve: {e}"))?;
                            planner
                                .install_curve(slot, curve, false)
                                .map_err(|e| anyhow!("installing slot {slot} curve: {e}"))?;
                            reprofiled.push(slot);
                        }
                        None if opts.allow_stage_change => {
                            homeless.push(slot);
                            events.push(format!(
                                "slot {slot} cannot fit a sample at ZeRO-{stage_now}: \
                                 the stage search decides its admission stage"
                            ));
                        }
                        None => {
                            planner
                                .lose_slot(slot)
                                .map_err(|e| anyhow!("evicting slot {slot}: {e}"))?;
                            self.remove_rank(slot)?;
                            membership_changed = true;
                            events.push(format!(
                                "evicted joined slot {slot}: cannot fit a sample at \
                                 ZeRO-{stage_now}"
                            ));
                        }
                    }
                }
            }

            // (2b) group size moved: ZeRO shard sizes changed under every
            // survivor, so cached/old curves carry an `mbs` from a
            // different memory budget — too big risks OOM, too small
            // (a curve cached at a larger group) wastes throughput.
            // Alg. 1 discovers the exact OOM boundary on the simulated
            // substrate, so any mismatch with the memory model's bound at
            // the new `n` marks the curve stale; re-profile only those.
            // Gated on membership events, not `n_now != n_prev`: a loss
            // and a join in the same iteration leave `n` unchanged but
            // still swap in curves from a different group size.
            let mut n_now = planner.active_slots().len();
            // survivors that stopped fitting the incumbent stage: only a
            // stage migration can rescue them — tracked so a replan that
            // fails to migrate is a hard error, not a silent OOM-to-be
            let mut stuck_slots: Vec<usize> = Vec::new();
            if membership_changed {
                let psi = self.model.param_count();
                let stale: Vec<usize> = planner
                    .slots()
                    .iter()
                    .filter(|s| s.alive)
                    .filter(|s| match (&s.curve, catalog::spec(&s.gpu)) {
                        (Some(c), Some(spec)) => {
                            c.mbs()
                                != memmodel::true_mbs(
                                    &self.model,
                                    psi,
                                    stage_now,
                                    n_now,
                                    spec.mem_bytes(),
                                )
                        }
                        _ => false,
                    })
                    .map(|s| s.slot)
                    .collect();
                if !stale.is_empty() {
                    let results = self.profile_slots(&stale, stage_now)?;
                    for (&slot, result) in stale.iter().zip(results) {
                        let r = match result {
                            Some(r) => r,
                            // with the stage search on, a survivor that
                            // no longer fits at the incumbent stage is
                            // not fatal *yet*: its memory bound is
                            // broken, and the search below must escalate
                            // away (the old curve stays as planning
                            // input until the switch replaces it; if no
                            // migration happens, the replan below bails)
                            None if opts.allow_stage_change => {
                                stuck_slots.push(slot);
                                events.push(format!(
                                    "slot {slot} no longer fits at ZeRO-{stage_now}: \
                                     stage search must migrate"
                                ));
                                continue;
                            }
                            None => {
                                bail!(
                                    "survivor slot {slot} cannot fit a sample at \
                                     ZeRO-{stage_now} after the membership change"
                                )
                            }
                        };
                        let curve = PerfCurve::fit(r.points.clone(), r.mbs)
                            .map_err(|e| anyhow!("slot {slot} curve: {e}"))?;
                        // a straggler's re-measured curve must stay a
                        // rank-local override, not a cached type curve
                        let drifted = planner.slots()[slot].drifted;
                        planner
                            .install_curve(slot, curve, drifted)
                            .map_err(|e| anyhow!("installing stale slot {slot} curve: {e}"))?;
                        reprofiled.push(slot);
                    }
                }
            }

            // (2c) stage-search inputs: profile only the missing
            // (type, stage) pairs the search deems worth measuring —
            // candidate stages that pass the memory bound at the new
            // group size and whose estimated amortized score beats the
            // incumbent (or every feasible stage when the incumbent's
            // own bound broke). Cached pairs cost nothing, so this is
            // incremental exactly like (2a). Gated on membership events:
            // they are what re-prices the stage decision (drift replans
            // still re-run the search over already-measured stages).
            if opts.allow_stage_change && membership_changed {
                // batch the requests per candidate stage: one
                // leader-worker profiling round per stage, not per pair
                let mut by_stage: std::collections::BTreeMap<u8, Vec<usize>> =
                    std::collections::BTreeMap::new();
                for (slot, cand_stage) in planner.stage_profile_requests(&self.net) {
                    by_stage.entry(cand_stage).or_default().push(slot);
                }
                for (cand_stage, slots_for_stage) in by_stage {
                    let results = self.profile_slots(&slots_for_stage, cand_stage)?;
                    for (&slot, result) in slots_for_stage.iter().zip(results) {
                        match result {
                            // a 1-sample-only result cannot fit a curve:
                            // the pair stays uncached and the search
                            // skips the stage — a speculative probe must
                            // never be fatal
                            Some(r) => match PerfCurve::fit(r.points.clone(), r.mbs) {
                                Ok(curve) => {
                                    let gpu = planner.slots()[slot].gpu;
                                    planner
                                        .install_stage_curve(&gpu, cand_stage, curve)
                                        .map_err(|e| {
                                            anyhow!("caching {gpu} ZeRO-{cand_stage}: {e}")
                                        })?;
                                    reprofiled.push(slot);
                                    events.push(format!(
                                        "profiled {gpu} at ZeRO-{cand_stage} for the stage \
                                         search"
                                    ));
                                }
                                Err(e) => events.push(format!(
                                    "slot {slot} ZeRO-{cand_stage} curve unusable: {e}"
                                )),
                            },
                            // the memory model over-promised: leave the
                            // pair uncached, the search skips
                            // estimate-only stages
                            None => events.push(format!(
                                "slot {slot} cannot fit a sample at candidate \
                                 ZeRO-{cand_stage}"
                            )),
                        }
                    }
                }
            }

            // (3) replan over the surviving curve set. The replan also
            // rebuilds the optimizer-shard layout, so the one-shot
            // penalty is *measured* from the bytes whose owner actually
            // changed (zero for pure drift replans: same membership,
            // same layout), with lost ranks' shards restored from the
            // checkpoint instead of recomputed.
            debug_assert_eq!(self.net.n, n_now, "remove/add_rank maintain net.n");
            let mut penalty = 0.0;
            let mut reshard_bytes = 0u64;
            let mut replanned = false;
            if planner.dirty() {
                if let Err(e) = planner.replan(&self.net) {
                    // the stage search found no feasible measured stage
                    // for the homeless joiner(s): evict them now — a
                    // joiner is optional (a survivor in this state is
                    // fatal below) — and replan over the rest
                    let evictable = matches!(
                        &e,
                        elastic::ElasticError::MissingCurves(slots)
                            if !homeless.is_empty()
                                && slots.iter().all(|s| homeless.contains(s))
                    );
                    if !evictable {
                        return Err(anyhow!("replan at iter {iter}: {e}"));
                    }
                    for &slot in &homeless {
                        planner
                            .lose_slot(slot)
                            .map_err(|e| anyhow!("evicting slot {slot}: {e}"))?;
                        self.remove_rank(slot)?;
                        events.push(format!(
                            "evicted joined slot {slot}: no feasible measured \
                             admission stage"
                        ));
                    }
                    n_now = planner.active_slots().len();
                    planner
                        .replan(&self.net)
                        .map_err(|e| anyhow!("replan at iter {iter}: {e}"))?;
                }
                // a survivor stopped fitting the incumbent stage and the
                // search found nowhere feasible+measured to migrate: the
                // job cannot run without violating the memory bound —
                // fail loudly (the pre-stage-search behaviour), never
                // iterate on a plan the hardware cannot hold
                if !stuck_slots.is_empty() && planner.last_stage_change().is_none() {
                    bail!(
                        "slot(s) {stuck_slots:?} cannot fit a sample at ZeRO-{} after the \
                         membership change, and the stage search found no feasible \
                         measured stage to migrate to",
                        planner.stage()
                    );
                }
                // honest pricing: minimal movement only if the shards are
                // actually persisted — otherwise a loss forces the
                // full-restore baseline. A stage migration's movement is
                // folded into the same plan and charged identically.
                let checkpointed = opts.ckpt_dir.is_some();
                penalty = planner.reshard_penalty_s(&self.net, checkpointed);
                reshard_bytes = planner.reshard_bytes(checkpointed);
                replanned = true;
                if let Some(ch) = planner.last_stage_change() {
                    events.push(format!(
                        "stage ZeRO-{}->ZeRO-{} (migrated {:.1} MB)",
                        ch.from,
                        ch.to,
                        ch.migration_bytes as f64 / 1e6
                    ));
                }
                if let Some(dir) = &opts.ckpt_dir {
                    if let Some(m) = planner.manifest() {
                        m.save(dir).map_err(|e| anyhow!("ckpt snapshot: {e}"))?;
                    }
                }
            }

            // (4) run the iteration live
            let plan = planner
                .plan()
                .ok_or_else(|| anyhow!("iteration {iter}: replan left the planner with no plan"))?
                .clone();
            let live = self.run_iteration(&plan)?;
            let wall = live.wall_s + penalty;

            // (5) drift detection → incremental re-profile of stragglers.
            // Skipped on the final iteration: its output could only feed
            // a replan that will never run, and Alg. 1 is the job's most
            // expensive operation (Table 2).
            if iter + 1 < iterations {
                let curves_now = planner.active_curves().map_err(|e| anyhow!("{e}"))?;
                let drifted = elastic::detect_drift(
                    &plan,
                    &curves_now,
                    &live.per_rank_steps,
                    opts.drift_threshold,
                );
                if !drifted.is_empty() {
                    let slots: Vec<usize> =
                        drifted.iter().map(|&i| planner.slot_map()[i]).collect();
                    let results = self.profile_slots(&slots, planner.stage())?;
                    for (&slot, result) in slots.iter().zip(results) {
                        let r = result.ok_or_else(|| {
                            anyhow!(
                                "drifted slot {slot} can no longer fit a sample at ZeRO-{}",
                                planner.stage()
                            )
                        })?;
                        let curve = PerfCurve::fit(r.points.clone(), r.mbs)
                            .map_err(|e| anyhow!("slot {slot} drift curve: {e}"))?;
                        planner
                            .install_curve(slot, curve, true)
                            .map_err(|e| anyhow!("installing drift slot {slot} curve: {e}"))?;
                    }
                    // install_curve marked the planner dirty: the next
                    // iteration replans around the re-measured stragglers
                    reprofiled.extend(slots);
                }
            }

            // (5b) comm-drift — the fabric twin of (5). The quick check
            // compares this iteration's observed collective time against
            // the prediction at the *current estimate* (symmetric to the
            // compute path, same threshold); every iteration's
            // effective-bandwidth sample then feeds the monitor, whose
            // Startup/Degrade/Steady/Probe machine decides when a shift
            // is sustained — a single noisy collective never replans.
            // Skipped on the final iteration like (5): the replan it
            // would arm can never run.
            if iter + 1 < iterations {
                let pred_est_s = if live.comm_pred_spec_s > live.comm_alpha_s
                    && self.net.bw_gbs > 0.0
                {
                    live.comm_alpha_s
                        + (live.comm_pred_spec_s - live.comm_alpha_s)
                            * (self.fabric.spec_gbs() / self.net.bw_gbs)
                } else {
                    live.comm_pred_spec_s
                };
                if let Some(ratio) =
                    elastic::detect_comm_drift(pred_est_s, live.comm_s, opts.drift_threshold)
                {
                    events.push(format!("comm-drift:observed/predicted={ratio:.2}"));
                }
                if let Some(sample) = self.fabric.sample_from_comm_times(
                    live.comm_pred_spec_s,
                    live.comm_alpha_s,
                    live.comm_s,
                ) {
                    if let Some(shift) = self.fabric.observe(sample) {
                        events.push(format!("bw-drift:{}:{:.2}", shift.link, shift.factor));
                        // re-price everything at the new estimate: the
                        // next iteration's replan, reshard/migration
                        // stalls and offer rounds all consume this
                        // snapshot, so a reshard that was cheap at spec
                        // bandwidth is correctly vetoed mid-congestion
                        self.net = self.fabric.snapshot(self.net.n);
                        planner.mark_dirty();
                    }
                }
            }

            reports.push(ElasticIterationReport {
                iter,
                events,
                n_ranks: n_now,
                stage: plan.stage,
                wall_s: wall,
                tflops: flops::tflops(&self.model, plan.total_samples(), wall),
                replanned,
                reprofiled_slots: reprofiled,
                reshard_penalty_s: penalty,
                reshard_bytes,
                bw_gbs: self.fabric.estimate_gbs(),
            });
        }

        // the loop above replans (or reuses a plan) every iteration, but
        // a zero-iteration job or a future refactor could get here
        // planless — that is a typed failure, not a crash
        let final_plan = planner
            .plan()
            .ok_or_else(|| anyhow!("job finished without a final plan"))?
            .clone();
        let final_manifest = planner
            .manifest()
            .ok_or_else(|| anyhow!("job finished without a shard manifest"))?
            .clone();
        Ok(ElasticJobReport {
            stage: initial_stage,
            final_stage: planner.stage(),
            gbs,
            replans: planner.replans(),
            cache_hits: planner.cache().hits() - hits0,
            cache_misses: planner.cache().misses() - misses0,
            final_plan,
            final_manifest,
            iterations: reports,
        })
    }

    /// Stop all workers and join their threads.
    pub fn shutdown(mut self) {
        for w in &self.workers {
            let _ = w.cmd.send(WorkerCmd::Shutdown);
        }
        for w in &mut self.workers {
            if let Some(t) = w.thread.take() {
                let _ = t.join();
            }
        }
    }
}

impl Drop for Leader {
    fn drop(&mut self) {
        for w in &self.workers {
            let _ = w.cmd.send(WorkerCmd::Shutdown);
        }
        for w in &mut self.workers {
            if let Some(t) = w.thread.take() {
                let _ = t.join();
            }
        }
    }
}

/// Fit per-rank performance curves from a cluster profile.
///
/// Measurements are pooled across ranks with the same GPU model *and*
/// the same discovered `mbs`: identical silicon gives identical true
/// curves, so averaging the probes divides measurement noise by √k —
/// which matters on homogeneous-compute clusters (cluster A) where a
/// 1% noise overfit directly costs throughput.
pub fn fit_curves(profile: &ClusterProfile) -> Result<Vec<PerfCurve>> {
    use std::collections::HashMap;
    // (name, mbs) -> batch -> (sum_time, count)
    let mut pools: HashMap<(String, usize), HashMap<usize, (f64, usize)>> = HashMap::new();
    for r in &profile.ranks {
        let pool = pools.entry((r.name.clone(), r.mbs)).or_default();
        for p in &r.points {
            let e = pool.entry(p.batch).or_insert((0.0, 0));
            e.0 += p.step_time_s;
            e.1 += 1;
        }
    }
    profile
        .ranks
        .iter()
        .map(|r| {
            let pool = &pools[&(r.name.clone(), r.mbs)];
            let points: Vec<crate::curves::ProfiledPoint> = r
                .points
                .iter()
                .map(|p| {
                    let (sum, n) = pool[&p.batch];
                    crate::curves::ProfiledPoint {
                        batch: p.batch,
                        step_time_s: sum / n as f64,
                    }
                })
                .collect();
            PerfCurve::fit(points, r.mbs)
                .map_err(|e| anyhow!("rank {} curve: {e}", r.rank))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster;
    use crate::config::model::preset;

    fn leader_c(noise: f64) -> Leader {
        Leader::new_simulated(&cluster::cluster_c(), &preset("llama-0.5b").unwrap(), noise, 11)
    }

    #[test]
    fn full_job_poplar_cluster_c() {
        let mut l = leader_c(0.01);
        let rep = l.run_job(1, Strategy::Poplar, 256, 3).unwrap();
        assert_eq!(rep.stage, 1);
        assert_eq!(rep.profile.len(), 8);
        assert_eq!(rep.plan.total_samples(), 256);
        assert_eq!(rep.iterations.len(), 3);
        assert!(rep.tflops_mean > 0.0);
        l.shutdown();
    }

    #[test]
    fn poplar_beats_uniform_live() {
        let mut l = leader_c(0.0);
        let pop = l.run_job(2, Strategy::Poplar, 256, 2).unwrap();
        let uni = l.run_job(2, Strategy::Uniform, 256, 2).unwrap();
        assert!(
            pop.tflops_mean >= uni.tflops_mean * 0.999,
            "poplar {:.1} vs uniform {:.1}",
            pop.tflops_mean,
            uni.tflops_mean
        );
        l.shutdown();
    }

    #[test]
    fn stage_escalation_through_leader() {
        // llama-1.1b at ZeRO-0 does not fit V100-16G: must escalate.
        let mut l = Leader::new_simulated(
            &cluster::cluster_b(),
            &preset("llama-1.1b").unwrap(),
            0.0,
            3,
        );
        let prof = l.profile(0).unwrap();
        assert!(prof.stage > 0);
        l.shutdown();
    }

    #[test]
    fn live_iteration_idle_matches_barrier_structure() {
        let mut l = leader_c(0.0);
        let prof = l.profile(1).unwrap();
        let plan = l.plan_from_profile(&prof, Strategy::Uniform, 128).unwrap();
        let it = l.run_iteration(&plan).unwrap();
        // uniform on heterogeneous GPUs: A800 ranks idle, V100S ranks not
        let min_idle = it.idle_s.iter().cloned().fold(f64::MAX, f64::min);
        let max_idle = it.idle_s.iter().cloned().fold(0.0, f64::max);
        assert!(min_idle < 1e-9);
        assert!(max_idle > 0.0);
        l.shutdown();
    }

    #[test]
    fn flops_strategy_runs() {
        let mut l = leader_c(0.0);
        let rep = l.run_job(3, Strategy::Flops, 128, 1).unwrap();
        assert_eq!(rep.plan.strategy, "flops-proportional");
        l.shutdown();
    }

    // ---------------- elastic runtime ----------------

    use crate::elastic::{ElasticEvent, ScheduledEvent};

    fn sched(evs: Vec<(usize, ElasticEvent)>) -> Vec<ScheduledEvent> {
        evs.into_iter().map(|(at_iter, event)| ScheduledEvent { at_iter, event }).collect()
    }

    #[test]
    fn elastic_rank_lost_replans_and_covers_gbs() {
        let mut l = leader_c(0.01);
        let schedule = sched(vec![(2, ElasticEvent::RankLost { slot: 7 })]);
        let rep = l
            .run_elastic_job(1, 256, 5, &schedule, &ElasticOptions::default())
            .unwrap();
        assert_eq!(rep.iterations.len(), 5);
        assert_eq!(rep.iterations[1].n_ranks, 8);
        assert_eq!(rep.iterations[2].n_ranks, 7);
        assert!(rep.iterations[2].replanned, "loss must trigger a replan");
        assert!(rep.iterations[2].reshard_penalty_s > 0.0);
        assert_eq!(rep.final_plan.total_samples(), 256);
        assert_eq!(rep.final_plan.ranks.len(), 7);
        rep.final_plan.validate().unwrap();
        // recovery: post-loss throughput stays close to pre-loss (we lost
        // 1 of 4 V100S — the weakest 7% of cluster compute)
        let pre = rep.iterations[1].tflops;
        let post = rep.iterations[4].tflops;
        assert!(post > pre * 0.85, "pre {pre:.1} post {post:.1}");
        l.shutdown();
    }

    #[test]
    fn elastic_rejoin_hits_curve_cache() {
        let mut l = leader_c(0.01);
        let schedule = sched(vec![
            (1, ElasticEvent::RankLost { slot: 6 }),
            (3, ElasticEvent::RankJoined { gpu: "V100S-32G".into() }),
        ]);
        let rep = l
            .run_elastic_job(1, 256, 5, &schedule, &ElasticOptions::default())
            .unwrap();
        assert_eq!(rep.iterations[3].n_ranks, 8);
        assert!(rep.cache_hits >= 1, "re-join of known type must hit the cache");
        // the join must NOT have re-profiled: cache covered it
        assert!(rep.iterations[3].reprofiled_slots.is_empty());
        assert_eq!(rep.final_plan.total_samples(), 256);
        l.shutdown();
    }

    #[test]
    fn elastic_join_of_unknown_type_reprofiles_incrementally() {
        let mut l = Leader::new_simulated(
            &cluster::cluster_b(),
            &preset("llama-0.5b").unwrap(),
            0.0,
            9,
        );
        let schedule = sched(vec![(2, ElasticEvent::RankJoined { gpu: "A100-40G".into() })]);
        let rep = l
            .run_elastic_job(1, 64, 4, &schedule, &ElasticOptions::default())
            .unwrap();
        // the new slot (4) was profiled, and only it
        assert_eq!(rep.iterations[2].reprofiled_slots, vec![4]);
        assert!(rep.iterations[2].replanned);
        assert_eq!(rep.iterations[2].n_ranks, 5);
        assert_eq!(rep.final_plan.total_samples(), 64);
        l.shutdown();
    }

    #[test]
    fn elastic_drift_detected_and_rebalanced() {
        let mut l = leader_c(0.0);
        let schedule = sched(vec![(1, ElasticEvent::RankSlowed { slot: 0, factor: 2.5 })]);
        let rep = l
            .run_elastic_job(1, 512, 5, &schedule, &ElasticOptions::default())
            .unwrap();
        // iteration 1 runs on the stale plan and observes the straggler
        assert!(
            rep.iterations[1].reprofiled_slots.contains(&0),
            "drift must re-profile the straggler: {:?}",
            rep.iterations[1]
        );
        // iteration 2 replans with the slowed curve: slot 0's share drops
        assert!(rep.iterations[2].replanned);
        let pre_share = rep.iterations[1].tflops; // stale plan pays the straggler
        let post_share = rep.iterations[3].tflops; // rebalanced
        assert!(
            post_share > pre_share,
            "rebalancing must recover throughput: {pre_share:.1} -> {post_share:.1}"
        );
        assert_eq!(rep.final_plan.total_samples(), 512);
        l.shutdown();
    }

    #[test]
    fn invalid_stage_is_error_not_panic() {
        let mut l = leader_c(0.0);
        assert!(l.profile(4).is_err());
        assert!(l.run_job(9, Strategy::Poplar, 64, 1).is_err());
        l.shutdown();
    }

    #[test]
    fn elastic_reshard_penalty_is_measured_not_full_state() {
        // with persistence on, losing 1 of 8 ranks must cost strictly
        // less than moving the whole 12ψ optimizer state (the PR 1
        // constant it replaces)
        let dir = std::env::temp_dir()
            .join(format!("poplar-leader-measured-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut l = leader_c(0.0);
        let schedule = sched(vec![(1, ElasticEvent::RankLost { slot: 7 })]);
        let opts = ElasticOptions { ckpt_dir: Some(dir.clone()), ..Default::default() };
        let rep = l.run_elastic_job(1, 256, 3, &schedule, &opts).unwrap();
        let it = &rep.iterations[1];
        assert!(it.reshard_penalty_s > 0.0);
        assert!(it.reshard_bytes > 0);
        let psi = preset("llama-0.5b").unwrap().param_count();
        assert!(
            it.reshard_bytes < 12 * psi,
            "moved {} of the full {} state bytes",
            it.reshard_bytes,
            12 * psi
        );
        // quiet iterations charge nothing
        assert_eq!(rep.iterations[2].reshard_penalty_s, 0.0);
        assert_eq!(rep.iterations[2].reshard_bytes, 0);
        // final layout covers the 7 survivors
        rep.final_manifest.validate().unwrap();
        assert_eq!(rep.final_manifest.shards.len(), 7);
        assert!(!rep.final_manifest.has_slot(7));
        let _ = std::fs::remove_dir_all(&dir);
        l.shutdown();
    }

    #[test]
    fn elastic_loss_without_persistence_pays_full_restore() {
        // persistence off (the default): a departed rank's shard has no
        // source, so the honest charge is the full 12ψ rebuild
        let mut l = leader_c(0.0);
        let schedule = sched(vec![(1, ElasticEvent::RankLost { slot: 7 })]);
        let rep = l
            .run_elastic_job(1, 256, 3, &schedule, &ElasticOptions::default())
            .unwrap();
        let psi = preset("llama-0.5b").unwrap().param_count();
        assert_eq!(rep.iterations[1].reshard_bytes, 12 * psi);
        assert!(rep.iterations[1].reshard_penalty_s > 0.0);
        l.shutdown();
    }

    #[test]
    fn elastic_job_snapshots_manifest_each_plan() {
        let dir = std::env::temp_dir()
            .join(format!("poplar-leader-ckpt-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut l = leader_c(0.0);
        let schedule = sched(vec![(1, ElasticEvent::RankLost { slot: 6 })]);
        let opts = ElasticOptions { ckpt_dir: Some(dir.clone()), ..Default::default() };
        let rep = l.run_elastic_job(1, 256, 3, &schedule, &opts).unwrap();
        // initial plan + post-loss replan = two snapshots on disk
        let latest = crate::ckpt::ShardManifest::load_latest(&dir).unwrap();
        assert_eq!(latest, rep.final_manifest);
        let n_snaps = std::fs::read_dir(&dir)
            .unwrap()
            .filter(|e| {
                e.as_ref()
                    .unwrap()
                    .file_name()
                    .to_string_lossy()
                    .ends_with(".ckpt")
            })
            .count();
        assert_eq!(n_snaps, rep.replans);
        let _ = std::fs::remove_dir_all(&dir);
        l.shutdown();
    }

    #[test]
    fn elastic_infeasible_join_is_evicted_not_fatal() {
        // llama-1.1b at ZeRO-0 fits an A100-80G (16ψ ≈ 20 GB) but not a
        // V100-16G: the joiner must be evicted, not kill the job.
        let c = cluster::ClusterSpec::new(
            "2xA100",
            &[("A100-80G", 2, cluster::LinkKind::Nvlink)],
            cluster::LinkKind::Ib,
        );
        let mut l = Leader::new_simulated(&c, &preset("llama-1.1b").unwrap(), 0.0, 5);
        let schedule = sched(vec![(1, ElasticEvent::RankJoined { gpu: "V100-16G".into() })]);
        let rep = l
            .run_elastic_job(0, 32, 3, &schedule, &ElasticOptions::default())
            .unwrap();
        assert!(
            rep.iterations[1].events.iter().any(|e| e.contains("evicted")),
            "events: {:?}",
            rep.iterations[1].events
        );
        assert_eq!(rep.iterations[1].n_ranks, 2);
        assert_eq!(rep.final_plan.ranks.len(), 2);
        assert_eq!(rep.final_plan.total_samples(), 32);
        l.shutdown();
    }

    #[test]
    fn elastic_autoscale_declines_weak_offer_and_accepts_cached_one() {
        let mut l = leader_c(0.0);
        let schedule = sched(vec![
            // a weak consumer card whose admission cannot amortize inside
            // a 30 s tenure (its curve is uncached, so it would also pay
            // Alg. 1 before the first productive iteration)
            (1, ElasticEvent::RankJoined { gpu: "RTX3060".into() }),
            // a known type: cached curve, zero profiling, clear gain
            (2, ElasticEvent::RankJoined { gpu: "V100S-32G".into() }),
        ]);
        let opts = ElasticOptions {
            autoscale: Some(crate::autoscale::AutoscaleOptions {
                horizon_s: 30.0,
                ..Default::default()
            }),
            ..Default::default()
        };
        let rep = l.run_elastic_job(1, 256, 4, &schedule, &opts).unwrap();
        // declined: no worker spawned, no planner slot, no replan
        assert!(
            rep.iterations[1].events.iter().any(|e| e.starts_with("declined")),
            "events: {:?}",
            rep.iterations[1].events
        );
        assert_eq!(rep.iterations[1].n_ranks, 8);
        assert!(!rep.iterations[1].replanned, "a declined offer must not replan");
        assert_eq!(rep.iterations[1].reshard_penalty_s, 0.0);
        // accepted: rank joined off the cached curve, no Alg. 1 run
        assert!(
            rep.iterations[2].events.iter().any(|e| e.starts_with("accepted")),
            "events: {:?}",
            rep.iterations[2].events
        );
        assert_eq!(rep.iterations[2].n_ranks, 9);
        assert!(
            rep.iterations[2].reprofiled_slots.is_empty(),
            "cached offer must be admitted with zero profiling calls: {:?}",
            rep.iterations[2].reprofiled_slots
        );
        assert!(rep.iterations[2].replanned);
        assert_eq!(rep.final_plan.ranks.len(), 9);
        assert_eq!(rep.final_plan.total_samples(), 256);
        rep.final_plan.validate().unwrap();
        l.shutdown();
    }

    #[test]
    fn elastic_degraded_round_is_labeled_round_fallback() {
        // an offer type outside the catalog makes the joint round
        // unpriceable; the leader degrades to the per-offer rule but
        // must say so in the event log — a degraded round may never
        // masquerade as a deliberate greedy one
        let mut l = leader_c(0.0);
        let schedule = sched(vec![(1, ElasticEvent::RankJoined { gpu: "H100".into() })]);
        let opts = ElasticOptions {
            autoscale: Some(crate::autoscale::AutoscaleOptions {
                horizon_s: 30.0,
                ..Default::default()
            }),
            ..Default::default()
        };
        let rep = l.run_elastic_job(1, 256, 3, &schedule, &opts).unwrap();
        assert!(
            rep.iterations[1].events.iter().any(|e| e.starts_with("round-fallback:")),
            "events: {:?}",
            rep.iterations[1].events
        );
        // the solo fallback cannot price it either: skipped, fleet intact
        assert!(
            rep.iterations[1].events.iter().any(|e| e.starts_with("skipped")),
            "events: {:?}",
            rep.iterations[1].events
        );
        assert_eq!(rep.iterations[1].n_ranks, 8);
        l.shutdown();
    }

    #[test]
    fn elastic_stage_change_de_escalates_after_join() {
        // the job is pinned at ZeRO-3 by the operator; once a join makes
        // the fleet re-plannable, the stage search measures the other
        // stages ((2c), incremental per (type, stage) pair) and migrates
        // to ZeRO-1 — dropping the per-micro-step collective traffic
        let mut l = leader_c(0.0);
        let schedule = sched(vec![(1, ElasticEvent::RankJoined { gpu: "V100S-32G".into() })]);
        let opts = ElasticOptions { allow_stage_change: true, ..Default::default() };
        let rep = l.run_elastic_job(3, 2048, 4, &schedule, &opts).unwrap();
        assert_eq!(rep.stage, 3, "initial escalation result is recorded");
        assert_eq!(rep.iterations[0].stage, 3);
        assert_eq!(rep.final_stage, 1, "sync-once stage must win on this fabric");
        assert!(
            rep.iterations[1]
                .events
                .iter()
                .any(|e| e.contains("stage ZeRO-3->ZeRO-1")),
            "events: {:?}",
            rep.iterations[1].events
        );
        assert!(rep.iterations[1].replanned);
        assert_eq!(rep.iterations[1].stage, 1);
        // the candidate stages were measured incrementally, not assumed
        assert!(
            rep.iterations[1]
                .events
                .iter()
                .any(|e| e.contains("for the stage search")),
            "events: {:?}",
            rep.iterations[1].events
        );
        // partitioned -> partitioned migration with a join: bytes move
        // (the tiling shifted), but far fewer than the full 12ψ state
        assert!(rep.iterations[1].reshard_bytes > 0);
        let psi = preset("llama-0.5b").unwrap().param_count();
        assert!(rep.iterations[1].reshard_bytes < 12 * psi);
        // post-migration iterations run faster than the pinned stage
        assert!(
            rep.iterations[3].tflops > rep.iterations[0].tflops,
            "{} -> {}",
            rep.iterations[0].tflops,
            rep.iterations[3].tflops
        );
        rep.final_plan.validate().unwrap();
        assert_eq!(rep.final_plan.stage, 1);
        assert_eq!(rep.final_plan.total_samples(), 2048);
        rep.final_manifest.validate().unwrap();
        assert_eq!(rep.final_manifest.stage, 1);
        l.shutdown();
    }

    #[test]
    fn elastic_homeless_joiner_migrates_stage_instead_of_eviction() {
        // bert-1.1b replicated (ZeRO-0) cannot fit a T4: PR 4 evicted
        // such joiners before the stage search ran. With the search on,
        // (2c) measures the candidate stages and the replan admits the
        // joiner at one of them instead.
        let cluster = cluster::ClusterSpec {
            name: "homeless-test".into(),
            groups: vec![cluster::NodeGroup {
                gpu: "A100-80G".into(),
                count: 2,
                intra_link: cluster::LinkKind::Ib,
            }],
            inter_link: cluster::LinkKind::Ib,
        };
        let mut l = Leader::new_simulated(&cluster, &preset("bert-1.1b").unwrap(), 0.0, 5);
        let schedule = sched(vec![(1, ElasticEvent::RankJoined { gpu: "T4".into() })]);
        let opts = ElasticOptions { allow_stage_change: true, ..Default::default() };
        let rep = l.run_elastic_job(0, 32, 3, &schedule, &opts).unwrap();
        assert_eq!(rep.stage, 0, "the big cards fit replicated ZeRO-0");
        assert!(rep.final_stage > 0, "must migrate to admit the joiner");
        assert_eq!(
            rep.iterations[1].n_ranks, 3,
            "the joiner is admitted, not evicted: {:?}",
            rep.iterations[1].events
        );
        assert!(
            rep.iterations[1]
                .events
                .iter()
                .any(|e| e.contains("the stage search decides")),
            "events: {:?}",
            rep.iterations[1].events
        );
        assert!(
            rep.iterations
                .iter()
                .all(|it| it.events.iter().all(|e| !e.contains("evicted"))),
            "no eviction anywhere: {:?}",
            rep.iterations
        );
        assert_eq!(rep.final_plan.ranks.len(), 3);
        rep.final_plan.validate().unwrap();
        assert_eq!(rep.final_manifest.stage, rep.final_stage);
        l.shutdown();
    }

    #[test]
    fn elastic_stage_fixed_without_the_flag() {
        // the default keeps the PR 1-3 contract: the stage never moves
        let mut l = leader_c(0.0);
        let schedule = sched(vec![(1, ElasticEvent::RankJoined { gpu: "V100S-32G".into() })]);
        let rep = l
            .run_elastic_job(3, 512, 3, &schedule, &ElasticOptions::default())
            .unwrap();
        assert_eq!(rep.final_stage, 3);
        assert!(rep.iterations.iter().all(|it| it.stage == 3));
        assert!(rep
            .iterations
            .iter()
            .all(|it| it.events.iter().all(|e| !e.contains("stage ZeRO"))));
        l.shutdown();
    }

    #[test]
    fn elastic_without_autoscale_admits_unconditionally() {
        // the PR 1 behaviour is preserved when no policy is configured:
        // the same weak offer that autoscale declines is admitted
        let mut l = leader_c(0.0);
        let schedule = sched(vec![(1, ElasticEvent::RankJoined { gpu: "RTX3060".into() })]);
        let rep = l
            .run_elastic_job(1, 256, 3, &schedule, &ElasticOptions::default())
            .unwrap();
        assert_eq!(rep.iterations[1].n_ranks, 9);
        assert!(rep.iterations[1].events.iter().any(|e| e == "joined(RTX3060)"));
        l.shutdown();
    }

    #[test]
    fn elastic_infeasible_events_are_skipped_not_fatal() {
        let mut l = Leader::new_simulated(
            &cluster::cluster_b(),
            &preset("llama-0.5b").unwrap(),
            0.0,
            2,
        );
        let schedule = sched(vec![
            (1, ElasticEvent::RankLost { slot: 99 }),
            (1, ElasticEvent::RankSlowed { slot: 50, factor: 2.0 }),
        ]);
        let rep = l
            .run_elastic_job(0, 32, 3, &schedule, &ElasticOptions::default())
            .unwrap();
        assert!(rep.iterations[1].events.iter().all(|e| e.starts_with("skipped")));
        assert_eq!(rep.iterations[2].n_ranks, 4);
        l.shutdown();
    }

    // ---------------- measured fabric (bw drift) ----------------

    #[test]
    fn elastic_bw_congestion_detected_and_replanned() {
        // ZeRO-2 on cluster_c: per-micro-step reduce-scatters make the
        // collective share large enough to dominate the iteration
        let mut l = leader_c(0.0);
        let schedule =
            sched(vec![(1, ElasticEvent::BwDrift { link: "ib".into(), factor: 0.1 })]);
        let rep = l
            .run_elastic_job(2, 256, 8, &schedule, &ElasticOptions::default())
            .unwrap();
        // the ground-truth event is announced (a validated no-op)...
        assert!(
            rep.iterations[1].events.iter().any(|e| e == "bw:ib:0.10"),
            "events: {:?}",
            rep.iterations[1].events
        );
        // ...and the observed collective time immediately looks wrong
        // against the current-estimate prediction...
        assert!(
            rep.iterations[1].events.iter().any(|e| e.starts_with("comm-drift:")),
            "events: {:?}",
            rep.iterations[1].events
        );
        // ...but the *plan* only moves once the monitor calls the shift
        // sustained — never on the event or a single sample
        assert!(!rep.iterations[1].replanned);
        let drift_iter = rep
            .iterations
            .iter()
            .position(|it| it.events.iter().any(|e| e.starts_with("bw-drift:ib:")))
            .unwrap_or_else(|| panic!("no bw-drift signal: {:?}", rep.iterations));
        assert!(drift_iter > 1, "a signal needs more than one observed sample");
        assert!(
            rep.iterations[drift_iter + 1].replanned,
            "a signalled shift must replan: {:?}",
            rep.iterations[drift_iter + 1]
        );
        // the estimate converges onto the congested truth (0.1 x spec)
        // and the congested iterations really are slower end to end
        let spec = l.fabric().spec_gbs();
        let last = rep.iterations.last().unwrap();
        assert!(last.bw_gbs < 0.25 * spec, "estimate {} still near spec", last.bw_gbs);
        assert!(
            last.wall_s > 2.0 * rep.iterations[0].wall_s,
            "congestion must show in wall time: {} vs {}",
            rep.iterations[0].wall_s,
            last.wall_s
        );
        assert_eq!(rep.final_plan.total_samples(), 256);
        rep.final_plan.validate().unwrap();
        l.shutdown();
    }

    #[test]
    fn elastic_bw_recovery_probes_back_to_spec() {
        // full round trip: congestion at iter 1, fabric recovers at iter
        // 8; the monitor must signal both directions and end near spec
        let mut l = leader_c(0.0);
        let schedule = sched(vec![
            (1, ElasticEvent::BwDrift { link: "ib".into(), factor: 0.1 }),
            (8, ElasticEvent::BwDrift { link: "ib".into(), factor: 1.0 }),
        ]);
        let rep = l
            .run_elastic_job(2, 256, 18, &schedule, &ElasticOptions::default())
            .unwrap();
        let factors: Vec<f64> = rep
            .iterations
            .iter()
            .flat_map(|it| {
                it.events
                    .iter()
                    .filter_map(|e| e.strip_prefix("bw-drift:ib:").and_then(|f| f.parse().ok()))
            })
            .collect();
        let down = factors
            .iter()
            .position(|&f| f < 0.25)
            .unwrap_or_else(|| panic!("no congestion signal: {factors:?}"));
        assert!(
            factors[down..].iter().any(|&f| f > 0.8),
            "no recovery signal after the congested one: {factors:?}"
        );
        // pricing is restored: the final estimate is back near spec
        let last = rep.iterations.last().unwrap();
        assert!(
            last.bw_gbs > 0.9 * l.fabric().spec_gbs(),
            "probe never climbed back, estimate stuck at {}",
            last.bw_gbs
        );
        assert_eq!(rep.final_plan.total_samples(), 256);
        rep.final_plan.validate().unwrap();
        l.shutdown();
    }

    #[test]
    fn elastic_bw_event_on_non_bottleneck_link_is_skipped() {
        // cluster_c's whole-group collectives price at the IB inter-node
        // link; congesting the (unused) socket kind must change nothing
        let mut l = leader_c(0.0);
        let schedule =
            sched(vec![(1, ElasticEvent::BwDrift { link: "socket".into(), factor: 0.5 })]);
        let rep = l
            .run_elastic_job(2, 256, 4, &schedule, &ElasticOptions::default())
            .unwrap();
        assert!(
            rep.iterations[1].events.iter().any(|e| e.starts_with("skipped bw:socket:")),
            "events: {:?}",
            rep.iterations[1].events
        );
        assert!(rep.iterations.iter().skip(1).all(|it| !it.replanned));
        let spec = l.fabric().spec_gbs();
        assert!(
            rep.iterations.iter().all(|it| (it.bw_gbs - spec).abs() < 1e-9),
            "estimate must stay at spec: {:?}",
            rep.iterations.iter().map(|it| it.bw_gbs).collect::<Vec<_>>()
        );
        l.shutdown();
    }
}
