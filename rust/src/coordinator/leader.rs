//! Leader: the full Poplar pipeline over a set of worker threads.
//!
//! Mirrors the paper's Fig. 2 workflow:
//!
//! 1. **Online profiling** — broadcast `Profile{stage}` to all workers
//!    (Alg. 1 runs in parallel, one OS thread per GPU); if any worker
//!    reports that batch 1 OOMs, escalate the ZeRO stage and retry.
//! 2. **Offline analyzing** — fit [`PerfCurve`]s from the profiled
//!    points, run the selected allocator (Alg. 2 or a baseline).
//! 3. **Training** — per iteration, dispatch each rank's schedule and
//!    reconstruct the BSP timeline from the returned per-micro-step
//!    times (barrier per micro-step for ZeRO-2/3, one sync for 0/1).

use std::sync::mpsc::{self, Receiver, Sender};
use std::thread::JoinHandle;

use anyhow::{anyhow, bail, Result};

use super::messages::{WorkerCmd, WorkerReply};
use super::worker::worker_loop;
use crate::allocator::{self, baselines, Plan};
use crate::cluster::ClusterSpec;
use crate::config::model::ModelSpec;
use crate::config::Strategy;
use crate::curves::PerfCurve;
use crate::metrics::flops;
use crate::netsim::NetSim;
use crate::profiler::{ClusterProfile, Device, ProfileResult, SimDevice};

/// Live (worker-measured) timing of one iteration.
#[derive(Debug, Clone)]
pub struct LiveIteration {
    /// Wall time reconstructed from the BSP barriers.
    pub wall_s: f64,
    /// Per-rank busy seconds.
    pub busy_s: Vec<f64>,
    /// Per-rank idle seconds.
    pub idle_s: Vec<f64>,
    /// Collective seconds.
    pub comm_s: f64,
    /// Cluster TFLOP/s for this iteration.
    pub tflops: f64,
}

/// Everything `run_job` produces.
#[derive(Debug)]
pub struct JobReport {
    /// Stage actually used (after auto-escalation).
    pub stage: u8,
    /// Per-rank profiling results.
    pub profile: Vec<ProfileResult>,
    /// The allocation decision.
    pub plan: Plan,
    /// Per-iteration live timings.
    pub iterations: Vec<LiveIteration>,
    /// Mean TFLOP/s across iterations.
    pub tflops_mean: f64,
}

struct WorkerHandle {
    cmd: Sender<WorkerCmd>,
    thread: Option<JoinHandle<()>>,
}

/// The coordinator leader.
pub struct Leader {
    workers: Vec<WorkerHandle>,
    replies: Receiver<WorkerReply>,
    model: ModelSpec,
    net: NetSim,
    n: usize,
}

impl Leader {
    /// Spawn one simulated worker per GPU of `cluster`.
    pub fn new_simulated(
        cluster: &ClusterSpec,
        model: &ModelSpec,
        noise_sigma: f64,
        seed: u64,
    ) -> Self {
        let net = NetSim::from_cluster(cluster);
        let instances = cluster.instances();
        let devices: Vec<Box<dyn Device>> = instances
            .iter()
            .map(|inst| {
                Box::new(SimDevice::new(
                    inst.spec.clone(),
                    model.clone(),
                    inst.rank,
                    instances.len(),
                    net.clone(),
                    noise_sigma,
                    seed,
                )) as Box<dyn Device>
            })
            .collect();
        Self::with_devices(devices, model.clone(), net)
    }

    /// Spawn workers over caller-provided devices (e.g. real PJRT-backed
    /// devices from `train`).
    pub fn with_devices(devices: Vec<Box<dyn Device>>, model: ModelSpec, net: NetSim) -> Self {
        let n = devices.len();
        let (rep_tx, rep_rx) = mpsc::channel();
        let workers = devices
            .into_iter()
            .map(|dev| {
                let (cmd_tx, cmd_rx) = mpsc::channel();
                let tx = rep_tx.clone();
                let thread = std::thread::spawn(move || worker_loop(dev, cmd_rx, tx));
                WorkerHandle { cmd: cmd_tx, thread: Some(thread) }
            })
            .collect();
        Leader { workers, replies: rep_rx, model, net, n }
    }

    /// Number of ranks.
    pub fn n_ranks(&self) -> usize {
        self.n
    }

    /// The collective cost model in use.
    pub fn net(&self) -> &NetSim {
        &self.net
    }

    /// Phase 1: parallel Alg. 1 with automatic stage escalation.
    pub fn profile(&mut self, requested_stage: u8) -> Result<ClusterProfile> {
        assert!(requested_stage < 4);
        'stage: for stage in requested_stage..4 {
            for w in &self.workers {
                w.cmd
                    .send(WorkerCmd::Profile { stage })
                    .map_err(|_| anyhow!("worker died"))?;
            }
            let mut results: Vec<Option<ProfileResult>> = (0..self.n).map(|_| None).collect();
            let mut escalate = false;
            for _ in 0..self.n {
                match self.replies.recv().map_err(|_| anyhow!("reply channel closed"))? {
                    WorkerReply::Profiled { rank, result } => {
                        match result {
                            Some(r) => results[rank] = Some(*r),
                            None => escalate = true,
                        }
                    }
                    other => bail!("unexpected reply during profile: {other:?}"),
                }
            }
            if escalate {
                if stage == 3 {
                    bail!("model does not fit a single sample even at ZeRO-3");
                }
                continue 'stage;
            }
            let ranks: Vec<ProfileResult> =
                results.into_iter().map(Option::unwrap).collect();
            return Ok(ClusterProfile { stage, ranks });
        }
        unreachable!()
    }

    /// Phase 2: fit curves + run the selected allocator.
    pub fn plan_from_profile(
        &self,
        profile: &ClusterProfile,
        strategy: Strategy,
        gbs: usize,
    ) -> Result<Plan> {
        let curves = fit_curves(profile)?;
        let psi = self.model.param_count();
        let plan = match strategy {
            Strategy::Poplar => {
                allocator::plan(&curves, profile.stage, gbs, &self.net, psi)
                    .map_err(|e| anyhow!("poplar plan: {e}"))?
            }
            Strategy::Uniform => {
                baselines::plan_uniform(&curves, profile.stage, gbs, &self.net, psi)
                    .map_err(|e| anyhow!("uniform plan: {e}"))?
            }
            Strategy::Flops => {
                let flops: Vec<f64> = profile.ranks.iter().map(|r| r.flops_rating).collect();
                baselines::plan_flops_proportional(
                    &curves, &flops, profile.stage, gbs, &self.net, psi,
                )
                .map_err(|e| anyhow!("flops plan: {e}"))?
            }
        };
        plan.validate().map_err(|e| anyhow!("invalid plan: {e}"))?;
        Ok(plan)
    }

    /// Phase 3: run one iteration and reconstruct the BSP timeline.
    pub fn run_iteration(&mut self, plan: &Plan) -> Result<LiveIteration> {
        for (w, r) in self.workers.iter().zip(&plan.ranks) {
            w.cmd
                .send(WorkerCmd::RunSchedule {
                    stage: plan.stage,
                    micro_batch: r.micro_batch,
                    grad_accum_steps: r.grad_accum_steps,
                    last_batch: r.last_batch,
                })
                .map_err(|_| anyhow!("worker died"))?;
        }
        let mut per_rank: Vec<Vec<f64>> = vec![Vec::new(); self.n];
        let mut samples = 0usize;
        for _ in 0..self.n {
            match self.replies.recv().map_err(|_| anyhow!("reply channel closed"))? {
                WorkerReply::ScheduleDone { rank, step_times, samples: s, oom_at } => {
                    if let Some(b) = oom_at {
                        bail!("rank {rank} OOMed at batch {b} — planner bug");
                    }
                    per_rank[rank] = step_times;
                    samples += s;
                }
                other => bail!("unexpected reply during iteration: {other:?}"),
            }
        }

        let psi = self.model.param_count();
        let gas = per_rank.iter().map(Vec::len).max().unwrap_or(0);
        let mut busy = vec![0.0f64; self.n];
        let mut idle = vec![0.0f64; self.n];
        let mut wall = 0.0f64;
        let mut comm = 0.0f64;
        match plan.stage {
            0 | 1 => {
                // one sync point at the end
                let totals: Vec<f64> =
                    per_rank.iter().map(|ts| ts.iter().sum::<f64>()).collect();
                let t_max = totals.iter().cloned().fold(0.0, f64::max);
                for i in 0..self.n {
                    busy[i] = totals[i];
                    idle[i] = t_max - totals[i];
                }
                let c = self.net.iteration_comm_time(plan.stage, psi);
                comm += c;
                wall = t_max + c;
            }
            2 | 3 => {
                let c_step = self.net.per_microstep_comm_time(plan.stage, psi);
                for step in 0..gas {
                    let times: Vec<f64> = per_rank
                        .iter()
                        .map(|ts| ts.get(step).copied().unwrap_or(0.0))
                        .collect();
                    let t_max = times.iter().cloned().fold(0.0, f64::max);
                    for i in 0..self.n {
                        busy[i] += times[i];
                        idle[i] += t_max - times[i];
                    }
                    wall += t_max + c_step;
                    comm += c_step;
                }
                let c = self.net.iteration_comm_time(plan.stage, psi);
                comm += c;
                wall += c;
            }
            s => bail!("invalid stage {s}"),
        }

        Ok(LiveIteration {
            wall_s: wall,
            busy_s: busy,
            idle_s: idle,
            comm_s: comm,
            tflops: flops::tflops(&self.model, samples, wall),
        })
    }

    /// The full pipeline: profile → plan → `iterations` timed runs.
    pub fn run_job(
        &mut self,
        requested_stage: u8,
        strategy: Strategy,
        gbs: usize,
        iterations: usize,
    ) -> Result<JobReport> {
        let profile = self.profile(requested_stage)?;
        let plan = self.plan_from_profile(&profile, strategy, gbs)?;
        let mut iters = Vec::with_capacity(iterations);
        for _ in 0..iterations {
            iters.push(self.run_iteration(&plan)?);
        }
        let tflops_mean =
            iters.iter().map(|i| i.tflops).sum::<f64>() / iters.len().max(1) as f64;
        Ok(JobReport { stage: profile.stage, profile: profile.ranks, plan,
                       iterations: iters, tflops_mean })
    }

    /// Stop all workers and join their threads.
    pub fn shutdown(mut self) {
        for w in &self.workers {
            let _ = w.cmd.send(WorkerCmd::Shutdown);
        }
        for w in &mut self.workers {
            if let Some(t) = w.thread.take() {
                let _ = t.join();
            }
        }
    }
}

impl Drop for Leader {
    fn drop(&mut self) {
        for w in &self.workers {
            let _ = w.cmd.send(WorkerCmd::Shutdown);
        }
        for w in &mut self.workers {
            if let Some(t) = w.thread.take() {
                let _ = t.join();
            }
        }
    }
}

/// Fit per-rank performance curves from a cluster profile.
///
/// Measurements are pooled across ranks with the same GPU model *and*
/// the same discovered `mbs`: identical silicon gives identical true
/// curves, so averaging the probes divides measurement noise by √k —
/// which matters on homogeneous-compute clusters (cluster A) where a
/// 1% noise overfit directly costs throughput.
pub fn fit_curves(profile: &ClusterProfile) -> Result<Vec<PerfCurve>> {
    use std::collections::HashMap;
    // (name, mbs) -> batch -> (sum_time, count)
    let mut pools: HashMap<(String, usize), HashMap<usize, (f64, usize)>> = HashMap::new();
    for r in &profile.ranks {
        let pool = pools.entry((r.name.clone(), r.mbs)).or_default();
        for p in &r.points {
            let e = pool.entry(p.batch).or_insert((0.0, 0));
            e.0 += p.step_time_s;
            e.1 += 1;
        }
    }
    profile
        .ranks
        .iter()
        .map(|r| {
            let pool = &pools[&(r.name.clone(), r.mbs)];
            let points: Vec<crate::curves::ProfiledPoint> = r
                .points
                .iter()
                .map(|p| {
                    let (sum, n) = pool[&p.batch];
                    crate::curves::ProfiledPoint {
                        batch: p.batch,
                        step_time_s: sum / n as f64,
                    }
                })
                .collect();
            PerfCurve::fit(points, r.mbs)
                .map_err(|e| anyhow!("rank {} curve: {e}", r.rank))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster;
    use crate::config::model::preset;

    fn leader_c(noise: f64) -> Leader {
        Leader::new_simulated(&cluster::cluster_c(), &preset("llama-0.5b").unwrap(), noise, 11)
    }

    #[test]
    fn full_job_poplar_cluster_c() {
        let mut l = leader_c(0.01);
        let rep = l.run_job(1, Strategy::Poplar, 256, 3).unwrap();
        assert_eq!(rep.stage, 1);
        assert_eq!(rep.profile.len(), 8);
        assert_eq!(rep.plan.total_samples(), 256);
        assert_eq!(rep.iterations.len(), 3);
        assert!(rep.tflops_mean > 0.0);
        l.shutdown();
    }

    #[test]
    fn poplar_beats_uniform_live() {
        let mut l = leader_c(0.0);
        let pop = l.run_job(2, Strategy::Poplar, 256, 2).unwrap();
        let uni = l.run_job(2, Strategy::Uniform, 256, 2).unwrap();
        assert!(
            pop.tflops_mean >= uni.tflops_mean * 0.999,
            "poplar {:.1} vs uniform {:.1}",
            pop.tflops_mean,
            uni.tflops_mean
        );
        l.shutdown();
    }

    #[test]
    fn stage_escalation_through_leader() {
        // llama-1.1b at ZeRO-0 does not fit V100-16G: must escalate.
        let mut l = Leader::new_simulated(
            &cluster::cluster_b(),
            &preset("llama-1.1b").unwrap(),
            0.0,
            3,
        );
        let prof = l.profile(0).unwrap();
        assert!(prof.stage > 0);
        l.shutdown();
    }

    #[test]
    fn live_iteration_idle_matches_barrier_structure() {
        let mut l = leader_c(0.0);
        let prof = l.profile(1).unwrap();
        let plan = l.plan_from_profile(&prof, Strategy::Uniform, 128).unwrap();
        let it = l.run_iteration(&plan).unwrap();
        // uniform on heterogeneous GPUs: A800 ranks idle, V100S ranks not
        let min_idle = it.idle_s.iter().cloned().fold(f64::MAX, f64::min);
        let max_idle = it.idle_s.iter().cloned().fold(0.0, f64::max);
        assert!(min_idle < 1e-9);
        assert!(max_idle > 0.0);
        l.shutdown();
    }

    #[test]
    fn flops_strategy_runs() {
        let mut l = leader_c(0.0);
        let rep = l.run_job(3, Strategy::Flops, 128, 1).unwrap();
        assert_eq!(rep.plan.strategy, "flops-proportional");
        l.shutdown();
    }
}
