//! Leader↔worker message protocol.
//!
//! One mpsc command channel per worker, one shared reply channel back to
//! the leader. Workers never talk to each other: collectives are costed
//! by `netsim` (sim path) or performed by the leader's weighted gradient
//! average (real path in `train`).

use crate::profiler::ProfileResult;

/// Commands the leader sends to a worker.
#[derive(Debug)]
pub enum WorkerCmd {
    /// Run Algorithm 1 at the given ZeRO stage.
    Profile {
        /// ZeRO stage to profile under.
        stage: u8,
    },
    /// Execute one iteration's schedule: `(grad_accum_steps - 1)` full
    /// micro-steps of `micro_batch` plus one of `last_batch`, at `stage`.
    RunSchedule {
        /// ZeRO stage (decides which collectives the device times).
        stage: u8,
        /// Steady-state micro-batch size.
        micro_batch: usize,
        /// Micro-step count.
        grad_accum_steps: usize,
        /// Final micro-step batch size.
        last_batch: usize,
    },
    /// Exit the worker loop.
    Shutdown,
}

/// Replies a worker sends to the leader.
#[derive(Debug)]
pub enum WorkerReply {
    /// Algorithm 1 finished.
    Profiled {
        /// Worker rank.
        rank: usize,
        /// `Some` on success, `None` when even batch 1 OOMs (leader
        /// escalates the ZeRO stage).
        result: Option<Box<ProfileResult>>,
    },
    /// Schedule finished.
    ScheduleDone {
        /// Worker rank.
        rank: usize,
        /// Per-micro-step compute time (collectives excluded), so the
        /// leader can reconstruct the BSP barriers of ZeRO-2/3.
        step_times: Vec<f64>,
        /// Samples processed.
        samples: usize,
        /// `Some(batch)` if a step OOMed (plan bug — should not happen).
        oom_at: Option<usize>,
    },
}
