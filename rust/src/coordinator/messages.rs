//! Leader↔worker message protocol.
//!
//! One mpsc command channel per worker, one shared reply channel back to
//! the leader. Workers never talk to each other: collectives are costed
//! by `netsim` (sim path) or performed by the leader's weighted gradient
//! average (real path in `train`).

use crate::profiler::ProfileResult;

/// Commands the leader sends to a worker.
#[derive(Debug)]
pub enum WorkerCmd {
    /// Run Algorithm 1 at the given ZeRO stage.
    Profile {
        /// ZeRO stage to profile under.
        stage: u8,
    },
    /// Execute one iteration's schedule: `(grad_accum_steps - 1)` full
    /// micro-steps of `micro_batch` plus one of `last_batch`, at `stage`.
    RunSchedule {
        /// ZeRO stage (decides which collectives the device times).
        stage: u8,
        /// Steady-state micro-batch size.
        micro_batch: usize,
        /// Micro-step count.
        grad_accum_steps: usize,
        /// Final micro-step batch size.
        last_batch: usize,
    },
    /// Inject (or clear, with `factor = 1.0`) a compute slowdown on the
    /// worker's device — the elastic runtime's straggler model. Applies
    /// to every subsequent step *and* re-profile, so drift-aware
    /// re-profiling measures the slowed device, not the healthy one.
    SetSlowdown {
        /// Compute-time multiplier (`> 1.0` = slower). No reply.
        factor: f64,
    },
    /// Announce the new data-parallel group size after a membership
    /// change. ZeRO shards model/optimizer state across the group, so
    /// every survivor's memory budget (and hence its true `mbs`) moves
    /// with `n` — subsequent steps and re-profiles must see it.
    SetGroupSize {
        /// Live rank count. No reply.
        n: usize,
    },
    /// Exit the worker loop.
    Shutdown,
}

/// Replies a worker sends to the leader.
#[derive(Debug)]
pub enum WorkerReply {
    /// Algorithm 1 finished.
    Profiled {
        /// Worker rank.
        rank: usize,
        /// `Some` on success, `None` when even batch 1 OOMs (leader
        /// escalates the ZeRO stage).
        result: Option<Box<ProfileResult>>,
    },
    /// Schedule finished.
    ScheduleDone {
        /// Worker rank.
        rank: usize,
        /// Per-micro-step compute time (collectives excluded), so the
        /// leader can reconstruct the BSP barriers of ZeRO-2/3.
        step_times: Vec<f64>,
        /// Samples processed.
        samples: usize,
        /// `Some(batch)` if a step OOMed (plan bug — should not happen).
        oom_at: Option<usize>,
    },
}
