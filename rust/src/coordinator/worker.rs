//! Worker: owns one [`Device`] and serves leader commands on a thread.

use std::sync::mpsc::{Receiver, Sender};

use super::messages::{WorkerCmd, WorkerReply};
use crate::profiler::{self, Device, DeviceOutcome, StepError, StepTiming};

/// Device wrapper that stretches compute time by a mutable factor — the
/// worker-side realization of `RankSlowed`. Because the profiler runs
/// against the *wrapped* device, a drift-triggered re-profile measures
/// the straggler as it actually is.
pub struct DriftDevice {
    inner: Box<dyn Device>,
    factor: f64,
}

impl DriftDevice {
    /// Wrap a device at full speed.
    pub fn new(inner: Box<dyn Device>) -> Self {
        DriftDevice { inner, factor: 1.0 }
    }

    /// Update the compute-time multiplier (`1.0` = healthy).
    pub fn set_factor(&mut self, factor: f64) {
        assert!(factor > 0.0, "slowdown factor must be positive");
        self.factor = factor;
    }

    /// Current multiplier.
    pub fn factor(&self) -> f64 {
        self.factor
    }
}

impl Device for DriftDevice {
    fn name(&self) -> &str {
        self.inner.name()
    }

    fn rank(&self) -> usize {
        self.inner.rank()
    }

    fn mem_total(&self) -> u64 {
        self.inner.mem_total()
    }

    fn mem_allocated(&self) -> u64 {
        self.inner.mem_allocated()
    }

    fn flops_rating(&self) -> f64 {
        self.inner.flops_rating()
    }

    fn set_stage(&mut self, stage: u8) {
        self.inner.set_stage(stage)
    }

    fn forward(&mut self, batch: usize) -> Result<(), StepError> {
        self.inner.forward(batch)
    }

    fn step(&mut self, batch: usize) -> Result<StepTiming, StepError> {
        let mut t = self.inner.step(batch)?;
        t.forward_s *= self.factor;
        t.backward_s *= self.factor;
        Ok(t)
    }

    fn reset(&mut self) {
        self.inner.reset()
    }

    fn set_group_size(&mut self, n: usize) {
        self.inner.set_group_size(n)
    }
}

/// Run the worker loop until `Shutdown`. Designed to be spawned with
/// `std::thread::spawn` (the offline image has no tokio; OS threads are
/// the right tool for a handful of CPU-bound workers anyway).
pub fn worker_loop(
    device: Box<dyn Device>,
    cmds: Receiver<WorkerCmd>,
    replies: Sender<WorkerReply>,
) {
    let mut device = DriftDevice::new(device);
    let rank = device.rank();
    while let Ok(cmd) = cmds.recv() {
        match cmd {
            WorkerCmd::Profile { stage } => {
                let result = match profiler::profile_device(&mut device, stage) {
                    DeviceOutcome::Ok(r) => Some(Box::new(r)),
                    DeviceOutcome::NeedsHigherStage => None,
                };
                if replies.send(WorkerReply::Profiled { rank, result }).is_err() {
                    return;
                }
            }
            WorkerCmd::RunSchedule { stage, micro_batch, grad_accum_steps, last_batch } => {
                device.set_stage(stage);
                device.reset();
                let mut step_times = Vec::with_capacity(grad_accum_steps);
                let mut samples = 0usize;
                let mut oom_at = None;
                for step in 0..grad_accum_steps {
                    let b = if step + 1 == grad_accum_steps { last_batch } else { micro_batch };
                    if b == 0 {
                        step_times.push(0.0);
                        continue;
                    }
                    match device.step(b) {
                        Ok(t) => {
                            step_times.push(t.time_consumed(stage));
                            samples += b;
                        }
                        Err(_) => {
                            oom_at = Some(b);
                            break;
                        }
                    }
                }
                if replies
                    .send(WorkerReply::ScheduleDone { rank, step_times, samples, oom_at })
                    .is_err()
                {
                    return;
                }
            }
            WorkerCmd::SetSlowdown { factor } => device.set_factor(factor),
            WorkerCmd::SetGroupSize { n } => device.set_group_size(n),
            WorkerCmd::Shutdown => return,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{catalog, LinkKind};
    use crate::config::model::preset;
    use crate::netsim::NetSim;
    use crate::profiler::SimDevice;
    use std::sync::mpsc;

    fn spawn_worker(gpu: &str) -> (Sender<WorkerCmd>, Receiver<WorkerReply>) {
        let dev: Box<dyn Device> = Box::new(SimDevice::new(
            catalog::spec_or_panic(gpu),
            preset("llama-0.5b").unwrap(),
            0,
            4,
            NetSim::from_link(4, LinkKind::Ib),
            0.0,
            7,
        ));
        let (cmd_tx, cmd_rx) = mpsc::channel();
        let (rep_tx, rep_rx) = mpsc::channel();
        std::thread::spawn(move || worker_loop(dev, cmd_rx, rep_tx));
        (cmd_tx, rep_rx)
    }

    #[test]
    fn profile_roundtrip() {
        let (tx, rx) = spawn_worker("A100-80G");
        tx.send(WorkerCmd::Profile { stage: 1 }).unwrap();
        match rx.recv().unwrap() {
            WorkerReply::Profiled { rank: 0, result: Some(r) } => {
                assert!(r.mbs > 0);
                assert!(r.points.len() >= 2);
            }
            other => panic!("unexpected {other:?}"),
        }
        tx.send(WorkerCmd::Shutdown).unwrap();
    }

    #[test]
    fn schedule_roundtrip() {
        let (tx, rx) = spawn_worker("V100S-32G");
        tx.send(WorkerCmd::RunSchedule {
            stage: 1,
            micro_batch: 2,
            grad_accum_steps: 3,
            last_batch: 1,
        })
        .unwrap();
        match rx.recv().unwrap() {
            WorkerReply::ScheduleDone { rank: 0, step_times, samples, oom_at } => {
                assert_eq!(step_times.len(), 3);
                assert!(step_times.iter().all(|&t| t > 0.0));
                assert_eq!(samples, 5);
                assert_eq!(oom_at, None);
            }
            other => panic!("unexpected {other:?}"),
        }
        tx.send(WorkerCmd::Shutdown).unwrap();
    }

    #[test]
    fn slowdown_scales_steps_and_reprofiles() {
        let (tx, rx) = spawn_worker("A100-80G");
        let run = |tx: &Sender<WorkerCmd>, rx: &Receiver<WorkerReply>| -> f64 {
            tx.send(WorkerCmd::RunSchedule {
                stage: 1,
                micro_batch: 2,
                grad_accum_steps: 2,
                last_batch: 2,
            })
            .unwrap();
            match rx.recv().unwrap() {
                WorkerReply::ScheduleDone { step_times, .. } => step_times.iter().sum(),
                other => panic!("unexpected {other:?}"),
            }
        };
        let healthy = run(&tx, &rx);
        tx.send(WorkerCmd::SetSlowdown { factor: 2.0 }).unwrap();
        let slowed = run(&tx, &rx);
        assert!((slowed / healthy - 2.0).abs() < 1e-9, "{healthy} vs {slowed}");
        // a re-profile under slowdown must see the slower device
        tx.send(WorkerCmd::Profile { stage: 1 }).unwrap();
        match rx.recv().unwrap() {
            WorkerReply::Profiled { result: Some(r), .. } => {
                let p = r.points.iter().find(|p| p.batch == 2).unwrap();
                assert!((p.step_time_s - slowed / 2.0).abs() / p.step_time_s < 1e-9);
            }
            other => panic!("unexpected {other:?}"),
        }
        tx.send(WorkerCmd::Shutdown).unwrap();
    }

    #[test]
    fn oom_schedule_reported() {
        let (tx, rx) = spawn_worker("T4");
        tx.send(WorkerCmd::RunSchedule {
            stage: 0,
            micro_batch: 100_000,
            grad_accum_steps: 1,
            last_batch: 100_000,
        })
        .unwrap();
        match rx.recv().unwrap() {
            WorkerReply::ScheduleDone { oom_at, .. } => assert_eq!(oom_at, Some(100_000)),
            other => panic!("unexpected {other:?}"),
        }
        tx.send(WorkerCmd::Shutdown).unwrap();
    }
}
