//! The `Device` abstraction the online profiler runs against.
//!
//! Alg. 1 only needs memory probes and timed training steps; anything
//! providing those can be profiled. Two implementations exist:
//!
//! * [`SimDevice`] — the calibrated device model (DESIGN.md §2
//!   substitution for physical GPUs), with measurement noise and the
//!   transient-memory spike that makes the linear estimate of Alg. 1
//!   optimistic (exactly the paper's motivation for the binary search);
//! * `runtime::RealDevice` — wraps a PJRT executable so the same
//!   profiler can time real CPU execution in the e2e example.

use crate::cluster::gpu::{GpuSpec, NoiseModel};
use crate::config::model::ModelSpec;
use crate::memmodel;
use crate::netsim::NetSim;

/// Step failure modes surfaced to the profiler.
#[derive(Debug, Clone, PartialEq)]
pub enum StepError {
    /// The step did not fit in device memory.
    Oom {
        /// Bytes the step needed at peak.
        needed: u64,
        /// Device capacity.
        capacity: u64,
    },
}

impl std::fmt::Display for StepError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            StepError::Oom { needed, capacity } => {
                write!(f, "OOM: needed {needed} B of {capacity} B")
            }
        }
    }
}

impl std::error::Error for StepError {}

/// Timing breakdown of one training step, as a runtime monitor would
/// report it. Collective entries *include* the idle time of early
/// arrivers (the paper's observation: faster GPUs start the collective
/// sooner and wait inside it).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct StepTiming {
    /// Forward compute, seconds.
    pub forward_s: f64,
    /// Backward compute, seconds.
    pub backward_s: f64,
    /// Optimizer update, seconds.
    pub optimizer_s: f64,
    /// ZeRO-3 forward all-gather (0 otherwise).
    pub fwd_allgather_s: f64,
    /// ZeRO-3 backward all-gather (0 otherwise).
    pub bwd_allgather_s: f64,
    /// ZeRO-2/3 backward reduce-scatter (0 otherwise).
    pub bwd_reducescatter_s: f64,
}

impl StepTiming {
    /// Wall time of the whole step.
    pub fn total(&self) -> f64 {
        self.forward_s
            + self.backward_s
            + self.optimizer_s
            + self.fwd_allgather_s
            + self.bwd_allgather_s
            + self.bwd_reducescatter_s
    }

    /// The paper's `TimeConsumedDuringStep` for a ZeRO stage: pure
    /// compute, collectives subtracted (§"Time Consumed Estimation").
    ///
    /// * ZeRO-0/1 — forward + backward (sync happens after backward;
    ///   optimizer time is "very short, and even equal" across ranks).
    /// * ZeRO-2 — forward + (backward − reduce-scatter).
    /// * ZeRO-3 — total − (fwd all-gather + bwd all-gather + bwd
    ///   reduce-scatter) − optimizer.
    ///
    /// Every collective component is recorded in its own field, so the
    /// compute remainder is the same expression at all stages — the
    /// `stage` parameter documents intent and keeps the call sites
    /// aligned with the paper's per-stage definitions.
    pub fn time_consumed(&self, _stage: u8) -> f64 {
        self.forward_s + self.backward_s
    }
}

/// Anything Alg. 1 can profile.
pub trait Device: Send {
    /// Catalog / display name.
    fn name(&self) -> &str;
    /// Global rank.
    fn rank(&self) -> usize;
    /// Total device memory (bytes).
    fn mem_total(&self) -> u64;
    /// Currently allocated bytes (the `CurrentMemoryAlloced()` probe).
    fn mem_allocated(&self) -> u64;
    /// Single-number FLOPs rating (what Whale's cost model uses).
    fn flops_rating(&self) -> f64;
    /// Select the ZeRO stage for subsequent calls.
    fn set_stage(&mut self, stage: u8);
    /// Forward pass only — updates `mem_allocated`. Used by the linear
    /// memory estimate.
    fn forward(&mut self, batch: usize) -> Result<(), StepError>;
    /// One full training step at `batch`, returning the monitor timing.
    fn step(&mut self, batch: usize) -> Result<StepTiming, StepError>;
    /// Free activations (between probes).
    fn reset(&mut self);
    /// Announce a new data-parallel group size (elastic membership
    /// change). Default is a no-op for devices whose memory model does
    /// not depend on the group.
    fn set_group_size(&mut self, _n: usize) {}
}

/// Simulated GPU backed by the calibrated device model.
pub struct SimDevice {
    spec: GpuSpec,
    model: ModelSpec,
    rank: usize,
    n_ranks: usize,
    stage: u8,
    net: NetSim,
    noise: NoiseModel,
    allocated: u64,
    param_count: u64,
}

impl SimDevice {
    /// Create a simulated device for `rank` of an `n_ranks` job.
    pub fn new(
        spec: GpuSpec,
        model: ModelSpec,
        rank: usize,
        n_ranks: usize,
        net: NetSim,
        noise_sigma: f64,
        seed: u64,
    ) -> Self {
        let param_count = model.param_count();
        SimDevice {
            spec,
            model,
            rank,
            n_ranks,
            stage: 0,
            net,
            noise: NoiseModel::new(seed.wrapping_add(rank as u64 * 7919), noise_sigma),
            allocated: 0,
            param_count,
        }
    }

    fn fixed_bytes(&self) -> u64 {
        memmodel::model_state_bytes(self.param_count, self.stage, self.n_ranks)
            + memmodel::FRAMEWORK_RESERVE_BYTES
    }

    fn peak_bytes(&self, batch: usize) -> u64 {
        memmodel::peak_bytes(&self.model, self.param_count, self.stage, self.n_ranks, batch)
    }

    /// Ground-truth compute time (no noise) — used by the evaluation
    /// harness to score plans against "reality".
    pub fn true_step_compute_time(&self, batch: usize) -> f64 {
        let tokens = (batch as u64 * self.model.seq) as f64;
        self.spec
            .compute_time(tokens, self.model.flops_per_token(), self.model.n_layers as usize)
    }

    /// Ground-truth maximum batch size for the current stage.
    pub fn true_mbs(&self) -> usize {
        memmodel::true_mbs(
            &self.model,
            self.param_count,
            self.stage,
            self.n_ranks,
            self.spec.mem_bytes(),
        )
    }

    /// The device specification.
    pub fn spec(&self) -> &GpuSpec {
        &self.spec
    }
}

impl Device for SimDevice {
    fn name(&self) -> &str {
        &self.spec.name
    }

    fn rank(&self) -> usize {
        self.rank
    }

    fn mem_total(&self) -> u64 {
        self.spec.mem_bytes()
    }

    fn mem_allocated(&self) -> u64 {
        self.allocated
    }

    fn flops_rating(&self) -> f64 {
        self.spec.flops_rating()
    }

    fn set_stage(&mut self, stage: u8) {
        assert!(stage < 4, "invalid ZeRO stage {stage}");
        self.stage = stage;
        self.allocated = self.fixed_bytes();
    }

    fn forward(&mut self, batch: usize) -> Result<(), StepError> {
        // steady-state allocation is linear in batch; the transient spike
        // decides OOM but is invisible to the post-forward probe
        let peak = self.peak_bytes(batch);
        if peak > self.mem_total() {
            return Err(StepError::Oom { needed: peak, capacity: self.mem_total() });
        }
        self.allocated = self.fixed_bytes() + memmodel::activation_bytes(&self.model, batch);
        Ok(())
    }

    fn step(&mut self, batch: usize) -> Result<StepTiming, StepError> {
        let peak = self.peak_bytes(batch);
        if peak > self.mem_total() {
            return Err(StepError::Oom { needed: peak, capacity: self.mem_total() });
        }
        self.allocated = self.fixed_bytes() + memmodel::activation_bytes(&self.model, batch);

        let compute = self.true_step_compute_time(batch) * self.noise.factor();
        // the canonical 1/3 forward, 2/3 backward split
        let fwd = compute / 3.0;
        let bwd = compute * 2.0 / 3.0;
        // optimizer: bandwidth-bound over the rank's optimizer shard;
        // "very short, and even equal" across ranks (paper)
        let shard = self.param_count as f64 / self.n_ranks.max(1) as f64;
        let opt = 12.0 * shard / (self.spec.mem_bw_gbs * 1e9);

        let mut t = StepTiming {
            forward_s: fwd,
            backward_s: bwd,
            optimizer_s: opt,
            ..Default::default()
        };
        match self.stage {
            0 | 1 => {}
            2 => {
                // the ZeRO-2 per-micro-step cost is exactly one gradient
                // reduce-scatter (composed directly: `set_stage` bounds
                // the stage, so no fallible dispatch is needed here)
                t.bwd_reducescatter_s = self.net.time(
                    crate::netsim::Collective::ReduceScatter,
                    2 * self.param_count,
                );
            }
            3 => {
                let ag = self.net.time(
                    crate::netsim::Collective::AllGather,
                    2 * self.param_count,
                );
                let rs = self.net.time(
                    crate::netsim::Collective::ReduceScatter,
                    2 * self.param_count,
                );
                t.fwd_allgather_s = ag;
                t.bwd_allgather_s = ag;
                t.bwd_reducescatter_s = rs;
            }
            _ => unreachable!(),
        }
        Ok(t)
    }

    fn reset(&mut self) {
        self.allocated = self.fixed_bytes();
    }

    fn set_group_size(&mut self, n: usize) {
        assert!(n >= 1, "group size must be >= 1");
        self.n_ranks = n;
        self.net.n = n;
        self.allocated = self.fixed_bytes();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{catalog, LinkKind};
    use crate::config::model::preset;

    fn dev(gpu: &str, stage: u8) -> SimDevice {
        dev_model(gpu, stage, "llama-0.5b")
    }

    fn dev_model(gpu: &str, stage: u8, model: &str) -> SimDevice {
        let mut d = SimDevice::new(
            catalog::spec_or_panic(gpu),
            preset(model).unwrap(),
            0,
            8,
            NetSim::from_link(8, LinkKind::Ib),
            0.0,
            42,
        );
        d.set_stage(stage);
        d
    }

    #[test]
    fn forward_updates_allocation_linearly() {
        let mut d = dev("A100-80G", 1);
        d.forward(1).unwrap();
        let a1 = d.mem_allocated() - d.fixed_bytes();
        d.reset();
        d.forward(4).unwrap();
        let a4 = d.mem_allocated() - d.fixed_bytes();
        assert_eq!(a4, 4 * a1);
    }

    #[test]
    fn oom_beyond_true_mbs() {
        let mut d = dev("V100-16G", 1);
        let mbs = d.true_mbs();
        assert!(mbs > 0);
        assert!(d.step(mbs).is_ok());
        assert!(matches!(d.step(mbs + 1), Err(StepError::Oom { .. })));
    }

    #[test]
    fn stage3_step_has_collective_components() {
        let mut d = dev("A100-80G", 3);
        let t = d.step(2).unwrap();
        assert!(t.fwd_allgather_s > 0.0);
        assert!(t.bwd_allgather_s > 0.0);
        assert!(t.bwd_reducescatter_s > 0.0);
        let mut d01 = dev("A100-80G", 0);
        let t0 = d01.step(2).unwrap();
        assert_eq!(t0.fwd_allgather_s, 0.0);
        assert_eq!(t0.bwd_reducescatter_s, 0.0);
    }

    #[test]
    fn time_consumed_excludes_collectives() {
        let mut d = dev("A100-80G", 3);
        let t = d.step(2).unwrap();
        assert!(t.time_consumed(3) < t.total());
        let recon = t.time_consumed(3)
            + t.optimizer_s
            + t.fwd_allgather_s
            + t.bwd_allgather_s
            + t.bwd_reducescatter_s;
        assert!((recon - t.total()).abs() < 1e-12);
    }

    #[test]
    fn noiseless_step_deterministic() {
        let mut d1 = dev("T4", 1);
        let mut d2 = dev("T4", 1);
        assert_eq!(d1.step(2).unwrap(), d2.step(2).unwrap());
    }

    #[test]
    fn group_size_change_moves_mbs_for_sharded_stages() {
        // fewer ranks -> bigger per-rank shard -> smaller true mbs
        let mut d = dev_model("V100-16G", 3, "llama-1.1b");
        let mbs8 = d.true_mbs();
        d.set_group_size(2);
        let mbs2 = d.true_mbs();
        assert!(mbs2 < mbs8, "{mbs2} vs {mbs8}");
        // stage 0 replicates: group size is irrelevant
        let mut d0 = dev_model("A100-80G", 0, "llama-0.5b");
        let a = d0.true_mbs();
        d0.set_group_size(2);
        assert_eq!(d0.true_mbs(), a);
    }

    #[test]
    fn higher_stage_raises_mbs() {
        // model states must dominate for the stage to matter: 1.1B on 16G
        let d1 = dev_model("V100-16G", 1, "llama-1.1b");
        let d3 = dev_model("V100-16G", 3, "llama-1.1b");
        assert!(d3.true_mbs() > d1.true_mbs(), "{} vs {}", d3.true_mbs(), d1.true_mbs());
    }
}
