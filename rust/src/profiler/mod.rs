//! Online profiling of GPUs — the paper's Algorithm 1.
//!
//! For every GPU, in parallel and in one shot per ZeRO stage:
//!
//! 1. **Linear memory estimate** — run one forward at batch 1, read the
//!    allocator before/after, and extrapolate the theoretical max batch
//!    size (activation memory is linear in batch). This over-estimates:
//!    transient peaks are invisible to the probe.
//! 2. **Exponential probe** — step the model at b = 1, 2, 4, … up to the
//!    estimate (or first OOM), recording `TimeConsumedDuringStep` at
//!    every probe (stage-aware: collectives subtracted, see
//!    [`device::StepTiming::time_consumed`]).
//! 3. **Binary search** — refine the exact `mbs` between the last good
//!    and first failing batch.
//!
//! If even batch 1 OOMs, the stage is escalated (0 → 1 → 2 → 3), the
//! paper's automatic stage selection.

pub mod device;

pub use device::{Device, SimDevice, StepError, StepTiming};

use crate::curves::ProfiledPoint;

/// Timing measurements per probe point. The paper averages several
/// iterations per batch size ("each GPU performs five iterations at its
/// respective mbs, and we compute the average"); 3 keeps the overhead of
/// Table 2 realistic while suppressing most measurement noise.
pub const PROBE_REPS: usize = 3;

/// Everything Alg. 1 learns about one GPU.
#[derive(Debug, Clone)]
pub struct ProfileResult {
    /// Global rank.
    pub rank: usize,
    /// Device name.
    pub name: String,
    /// Discovered maximum batch size (no OOM).
    pub mbs: usize,
    /// `(batch, TimeConsumedDuringStep)` samples for curve fitting.
    pub points: Vec<ProfiledPoint>,
    /// The device's FLOPs rating (Whale baseline input).
    pub flops_rating: f64,
    /// Number of `model.step` invocations spent probing.
    pub probe_steps: usize,
    /// Simulated wall time spent probing (Table 2's overhead).
    pub probe_time_s: f64,
}

/// Cluster-level profiling outcome: the stage actually used (after
/// escalation) and the per-rank results.
#[derive(Debug, Clone)]
pub struct ClusterProfile {
    /// ZeRO stage the profile is valid for.
    pub stage: u8,
    /// Per-rank results, rank order.
    pub ranks: Vec<ProfileResult>,
}

/// Profiling failure.
#[derive(Debug, PartialEq)]
pub enum ProfileError {
    /// Batch 1 OOMs on some rank even at ZeRO-3.
    ModelTooLarge {
        /// Rank that cannot fit a single sample.
        rank: usize,
    },
    /// Requested ZeRO stage outside 0..=3 (user-controlled via
    /// config/CLI — an error, never a panic).
    InvalidStage(u8),
}

impl std::fmt::Display for ProfileError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ProfileError::ModelTooLarge { rank } => {
                write!(f, "model does not fit a single sample on rank {rank} even at ZeRO-3")
            }
            ProfileError::InvalidStage(s) => {
                write!(f, "invalid ZeRO stage {s} (want 0..=3)")
            }
        }
    }
}

impl std::error::Error for ProfileError {}

/// Outcome of profiling one device at a fixed stage.
pub enum DeviceOutcome {
    /// Profiling succeeded.
    Ok(ProfileResult),
    /// Even batch 1 OOMs — escalate the stage.
    NeedsHigherStage,
}

/// Measure one probe point with `PROBE_REPS`-fold averaging. The first
/// call decides OOM; repeats can only succeed once it did.
fn measure(dev: &mut dyn Device, batch: usize, stage: u8, points: &mut Vec<ProfiledPoint>,
           steps: &mut usize, probe_time: &mut f64) -> Result<(), StepError> {
    let first = dev.step(batch)?;
    let mut sum = first.time_consumed(stage);
    *probe_time += first.total();
    *steps += 1;
    for _ in 1..PROBE_REPS {
        if let Ok(t) = dev.step(batch) {
            sum += t.time_consumed(stage);
            *probe_time += t.total();
            *steps += 1;
        }
    }
    points.push(ProfiledPoint { batch, step_time_s: sum / PROBE_REPS as f64 });
    Ok(())
}

/// Algorithm 1 for a single device at a fixed ZeRO stage (the unit the
/// coordinator's workers run in parallel).
pub fn profile_device(dev: &mut dyn Device, stage: u8) -> DeviceOutcome {
    dev.set_stage(stage);
    dev.reset();

    let mut points: Vec<ProfiledPoint> = Vec::new();
    let mut probe_steps = 0usize;
    let mut probe_time = 0.0f64;

    // -- step 1: linear estimate from a single forward ---------------------
    let bf = dev.mem_allocated();
    if dev.forward(1).is_err() {
        return DeviceOutcome::NeedsHigherStage;
    }
    let af = dev.mem_allocated();
    let per_batch = (af - bf).max(1);
    let headroom = dev.mem_total().saturating_sub(bf);
    let mbs_estimate = (headroom / per_batch).max(1) as usize;
    dev.reset();

    // -- step 2: exponential probe -----------------------------------------
    let mut last_ok = 0usize;
    let mut first_fail: Option<usize> = None;
    let mut b = 1usize;
    while b <= mbs_estimate {
        match measure(dev, b, stage, &mut points, &mut probe_steps, &mut probe_time) {
            Ok(()) => last_ok = b,
            Err(StepError::Oom { .. }) => {
                first_fail = Some(b);
                break;
            }
        }
        if b == mbs_estimate {
            break;
        }
        b = (b * 2).min(mbs_estimate);
    }
    if last_ok == 0 {
        return DeviceOutcome::NeedsHigherStage;
    }

    // -- step 3: binary search between last_ok and the upper bound ---------
    let mut lo = last_ok;
    let mut hi = first_fail.map(|f| f - 1).unwrap_or(mbs_estimate);
    while lo < hi {
        let mid = (lo + hi + 1) / 2;
        match measure(dev, mid, stage, &mut points, &mut probe_steps, &mut probe_time) {
            Ok(()) => lo = mid,
            Err(StepError::Oom { .. }) => {
                hi = mid - 1;
                probe_steps += 1; // OOM attempts cost a step too
            }
        }
    }
    let mbs = lo;

    // make sure the curve has its endpoint measured
    if !points.iter().any(|p| p.batch == mbs) {
        let _ = measure(dev, mbs, stage, &mut points, &mut probe_steps, &mut probe_time);
    }
    // a second interior point guarantees >= 2 knots even when mbs == 1
    if points.len() < 2 && mbs >= 1 {
        let _ = measure(dev, 1, stage, &mut points, &mut probe_steps, &mut probe_time);
    }

    points.sort_by_key(|p| p.batch);
    points.dedup_by_key(|p| p.batch);

    DeviceOutcome::Ok(ProfileResult {
        rank: dev.rank(),
        name: dev.name().to_string(),
        mbs,
        points,
        flops_rating: dev.flops_rating(),
        probe_steps,
        probe_time_s: probe_time,
    })
}

/// Profile a cluster at `requested_stage`, escalating the ZeRO stage
/// whenever any rank cannot fit a single sample (paper: "starting from
/// ZeRO-0, if Poplar find that the current stage cannot even run a
/// single batch, it will automatically increase the ZeRO stage").
pub fn profile_cluster(
    devices: &mut [Box<dyn Device>],
    requested_stage: u8,
) -> Result<ClusterProfile, ProfileError> {
    if requested_stage >= 4 {
        return Err(ProfileError::InvalidStage(requested_stage));
    }
    'stage: for stage in requested_stage..4 {
        let mut results = Vec::with_capacity(devices.len());
        for dev in devices.iter_mut() {
            match profile_device(dev.as_mut(), stage) {
                DeviceOutcome::Ok(r) => results.push(r),
                DeviceOutcome::NeedsHigherStage => {
                    if stage == 3 {
                        return Err(ProfileError::ModelTooLarge { rank: dev.rank() });
                    }
                    continue 'stage;
                }
            }
        }
        return Ok(ClusterProfile { stage, ranks: results });
    }
    unreachable!("loop covers stages 0..=3")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{catalog, LinkKind};
    use crate::config::model::preset;
    use crate::netsim::NetSim;

    fn sim(gpu: &str, model: &str, rank: usize, n: usize, sigma: f64) -> Box<dyn Device> {
        Box::new(SimDevice::new(
            catalog::spec_or_panic(gpu),
            preset(model).unwrap(),
            rank,
            n,
            NetSim::from_link(n, LinkKind::Ib),
            sigma,
            1234,
        ))
    }

    fn true_mbs(gpu: &str, model: &str, stage: u8, n: usize) -> usize {
        let mut d = SimDevice::new(
            catalog::spec_or_panic(gpu),
            preset(model).unwrap(),
            0,
            n,
            NetSim::from_link(n, LinkKind::Ib),
            0.0,
            1,
        );
        d.set_stage(stage);
        d.true_mbs()
    }

    #[test]
    fn finds_exact_mbs() {
        // The discovered mbs must equal the ground-truth OOM boundary —
        // the paper's "no OOM in later training" guarantee.
        for gpu in ["A100-80G", "A100-40G", "V100-16G", "T4"] {
            let mut devs = vec![sim(gpu, "llama-0.5b", 0, 8, 0.0)];
            let prof = profile_cluster(&mut devs, 1).unwrap();
            assert_eq!(prof.stage, 1);
            assert_eq!(prof.ranks[0].mbs, true_mbs(gpu, "llama-0.5b", 1, 8), "{gpu}");
        }
    }

    #[test]
    fn probe_count_is_logarithmic() {
        let mut devs = vec![sim("A100-80G", "llama-0.5b", 0, 8, 0.0)];
        let prof = profile_cluster(&mut devs, 1).unwrap();
        let mbs = prof.ranks[0].mbs;
        // (exp probe ~log2(mbs) + binary search ~log2(mbs) + endpoint)
        // points, each measured PROBE_REPS times
        let budget = PROBE_REPS * (2 * (mbs as f64).log2().ceil() as usize + 6);
        assert!(
            prof.ranks[0].probe_steps <= budget,
            "{} probes for mbs={mbs} (budget {budget})",
            prof.ranks[0].probe_steps
        );
    }

    #[test]
    fn stage_escalation_when_model_too_big() {
        // llama-1.1b stage-0 needs 16ψ ≈ 17.6 GB > V100-16G: escalate.
        let mut devs: Vec<Box<dyn Device>> = (0..4)
            .map(|r| sim("V100-16G", "llama-1.1b", r, 4, 0.0))
            .collect();
        let prof = profile_cluster(&mut devs, 0).unwrap();
        assert!(prof.stage > 0, "stage should escalate, got {}", prof.stage);
        for r in &prof.ranks {
            assert!(r.mbs >= 1);
        }
    }

    #[test]
    fn invalid_stage_is_typed_error() {
        let mut devs = vec![sim("T4", "llama-0.5b", 0, 1, 0.0)];
        assert_eq!(
            profile_cluster(&mut devs, 4).unwrap_err(),
            ProfileError::InvalidStage(4)
        );
    }

    #[test]
    fn model_too_large_error() {
        // llama-7b can't fit a single sample on one T4 even at ZeRO-3.
        let mut devs = vec![sim("T4", "llama-7b", 0, 1, 0.0)];
        let err = profile_cluster(&mut devs, 0).unwrap_err();
        assert_eq!(err, ProfileError::ModelTooLarge { rank: 0 });
    }

    #[test]
    fn points_cover_endpoint_and_are_sorted() {
        let mut devs = vec![sim("V100S-32G", "llama-0.5b", 0, 8, 0.0)];
        let prof = profile_cluster(&mut devs, 2).unwrap();
        let r = &prof.ranks[0];
        assert!(r.points.len() >= 2);
        assert!(r.points.windows(2).all(|w| w[0].batch < w[1].batch));
        assert_eq!(r.points.last().unwrap().batch, r.mbs);
    }

    #[test]
    fn noisy_profile_still_finds_boundary() {
        let mut devs = vec![sim("A100-40G", "llama-0.5b", 0, 8, 0.02)];
        let prof = profile_cluster(&mut devs, 1).unwrap();
        // OOM boundary is noise-free in the sim; must still be exact
        assert_eq!(prof.ranks[0].mbs, true_mbs("A100-40G", "llama-0.5b", 1, 8));
    }

    #[test]
    fn heterogeneous_cluster_profiles_all_ranks() {
        let mut devs: Vec<Box<dyn Device>> = vec![
            sim("A800-80G", "llama-0.5b", 0, 4, 0.01),
            sim("A800-80G", "llama-0.5b", 1, 4, 0.01),
            sim("V100S-32G", "llama-0.5b", 2, 4, 0.01),
            sim("V100S-32G", "llama-0.5b", 3, 4, 0.01),
        ];
        let prof = profile_cluster(&mut devs, 1).unwrap();
        assert_eq!(prof.ranks.len(), 4);
        // 80G rank must discover a larger mbs than 32G rank
        assert!(prof.ranks[0].mbs > prof.ranks[2].mbs);
        // and its measured speed at equal batch must be higher
        let a = &prof.ranks[0];
        let v = &prof.ranks[2];
        let t_a = a.points.iter().find(|p| p.batch == 4).map(|p| p.step_time_s);
        let t_v = v.points.iter().find(|p| p.batch == 4).map(|p| p.step_time_s);
        if let (Some(ta), Some(tv)) = (t_a, t_v) {
            assert!(ta < tv);
        }
    }

    #[test]
    fn probe_time_accumulates() {
        let mut devs = vec![sim("T4", "llama-0.5b", 0, 4, 0.0)];
        let prof = profile_cluster(&mut devs, 2).unwrap();
        assert!(prof.ranks[0].probe_time_s > 0.0);
    }
}
