//! Unified amortized-decision engine: ONE scoring kernel for every
//! heterogeneity decision the runtime makes.
//!
//! Poplar's value is making *every* decision — admit a candidate, evict
//! or release a paid rank, re-stage the optimizer layout — from measured
//! curves with honest stall accounting (PAPER.md §Batch Allocation,
//! Table 2). After PRs 3–4 the repo had three near-duplicate amortized
//! scorers (`autoscale::decide_offer`, the elastic stage search, the
//! leader's offer loop), and the two remaining autoscale capabilities —
//! scale-down and joint multi-offer admission — could not be expressed
//! in the one-offer-at-a-time shape at all. This module owns:
//!
//! * the **scoring kernel** [`amortized_score`] — the one place in the
//!   crate where the amortization formula lives (CI greps for strays):
//!   `score = rate · max(0, horizon − stall.total()) / horizon`, i.e.
//!   the effective samples/s over the decision's expected tenure after
//!   paying the one-shot stall up front;
//! * the typed [`StallLedger`] itemizing that stall: reshard transfer
//!   (membership movement), migration transfer (cross-stage re-layout)
//!   and Algorithm 1 profiling estimates;
//! * the [`Action`] vocabulary shared by every caller: `Admit`,
//!   `Defer`, `Decline`, `Release`, `StageMigrate`, `Stay`;
//! * the **joint round search** [`decide_round`]: instead of pricing
//!   offers one at a time against the current state (the PR-3 greedy
//!   rule), it evaluates offer *subsets* × candidate ZeRO stage
//!   together — one admission round pays ONE reshard, so a weak offer
//!   with a positive marginal contribution rides along with a strong
//!   batch-mate that the sequential rule would have declined — and
//!   additionally considers [`Action::Release`]-ing a paid rank when
//!   the cost-adjusted (samples per dollar) frontier says dropping it
//!   wins. Batches of at most [`MAX_EXHAUSTIVE_OFFERS`] offers are
//!   enumerated exactly (`2^k` subsets); larger batches go through a
//!   **marginal-contribution greedy search** (seed from the best
//!   singleton, repeatedly add the offer with the highest marginal
//!   amortized gain against an incrementally extended round preview,
//!   stop when no addition improves the score), bounded by the
//!   config-validated soft cap [`RoundOptions::max_offers_per_round`].
//!   The equivalence suite pins the greedy result to the exhaustive
//!   optimum on every batch small enough to enumerate.
//! * the **pipeline grouping arm** (`[pipeline]` config /
//!   `poplar elastic --allow-pipeline`): offers that no ZeRO stage can
//!   host solo are hard declines for the subset search — the memory
//!   bound fails everywhere. With [`RoundOptions::allow_pipeline`] the
//!   round packs exactly those offers into candidate pipeline groups
//!   ([`crate::pipeline::pack_groups`]), prices each composed virtual
//!   DP rank through the same preview + kernel, and reports the winner
//!   advisorily as [`RoundPlan::grouping`].
//!
//! `autoscale` and `elastic::stage` keep their public APIs as thin
//! adapters over this kernel; `Leader::run_elastic_job` evaluates each
//! iteration's offer batch through [`decide_round`];
//! `poplar autoscale --joint` / `--release` expose the round search on
//! the CLI and `exp::fig_joint_admission` snapshots it.

use crate::allocator::{self, predicted_wall_s};
use crate::autoscale::{
    self, profile_cost_estimate_s, synthesize_curve, AutoscaleError, AutoscaleOptions,
    Decision, OfferDecision, DEFAULT_HORIZON_S, DEFAULT_MIN_GAIN,
};
use crate::cluster::catalog;
use crate::config::model::ModelSpec;
use crate::curves::PerfCurve;
use crate::elastic::{CurveKey, ElasticPlanner, RoundIndex, RoundPreview};
use crate::intern::{self, TypeId};
use crate::netsim::NetSim;

/// Batch size at or below which [`decide_round`] enumerates every offer
/// subset exactly (`2^k` masks). Above this bound the greedy
/// marginal-contribution search takes over (see [`SearchMode`]); the
/// equivalence tests assert the greedy score stays within
/// [`GREEDY_BOUND`] of the exhaustive optimum on every batch this bound
/// still covers.
pub const MAX_EXHAUSTIVE_OFFERS: usize = 6;

/// Documented quality bound of the greedy search: on every batch small
/// enough to enumerate, `greedy_score >= GREEDY_BOUND *
/// exhaustive_score` (the equivalence suite asserts this; in practice
/// the two agree almost everywhere because offers of one GPU type price
/// identically).
pub const GREEDY_BOUND: f64 = 0.9;

/// Default soft cap on how many offers one joint round may admit
/// (`[policy] max_offers_per_round`,
/// [`RoundOptions::max_offers_per_round`]). Unlike the PR-5 hard
/// `MAX_OFFERS_PER_ROUND` error this caps the *chosen subset*, never
/// the batch: any number of offers is priced, the round just stops
/// growing its admission set at the cap.
pub const DEFAULT_MAX_OFFERS_PER_ROUND: usize = 64;

/// Which subset-search strategy [`decide_round`] runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SearchMode {
    /// Exhaustive enumeration for batches of at most
    /// [`MAX_EXHAUSTIVE_OFFERS`] offers, greedy above — the only mode
    /// callers normally want.
    #[default]
    Auto,
    /// Force the exact `2^k` enumeration; batches above
    /// [`MAX_EXHAUSTIVE_OFFERS`] are a typed `BadOptions` error (the
    /// equivalence tests use this arm).
    Exhaustive,
    /// Force the greedy marginal-contribution search regardless of
    /// batch size (the equivalence tests use this arm).
    Greedy,
}

/// Typed itemization of the one-shot stall a decision pays before its
/// first productive iteration. The kernel only ever consumes
/// [`StallLedger::total`]; the items exist so reports can say *why* a
/// decision stalls (membership reshard vs stage re-layout vs Alg. 1).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct StallLedger {
    /// Optimizer-shard movement from the membership change (seconds).
    pub reshard_transfer_s: f64,
    /// Cross-stage re-layout movement (`ckpt::migrate`), seconds.
    pub migration_transfer_s: f64,
    /// Estimated Algorithm 1 cost for uncached `(type, stage)` pairs.
    pub profiling_est_s: f64,
}

impl StallLedger {
    /// Ledger with only a membership-reshard item.
    pub fn reshard(s: f64) -> Self {
        StallLedger { reshard_transfer_s: s, ..Default::default() }
    }

    /// Ledger with only a cross-stage migration item.
    pub fn migration(s: f64) -> Self {
        StallLedger { migration_transfer_s: s, ..Default::default() }
    }

    /// Ledger with only an Alg. 1 profiling estimate.
    pub fn profiling(s: f64) -> Self {
        StallLedger { profiling_est_s: s, ..Default::default() }
    }

    /// Total stall the kernel amortizes.
    pub fn total(&self) -> f64 {
        self.reshard_transfer_s + self.migration_transfer_s + self.profiling_est_s
    }
}

/// THE scoring kernel: effective samples/s of an operating point over
/// the amortization horizon, after paying the ledger's one-shot stall.
/// A stall at or beyond the horizon scores zero (the tenure ends before
/// the first productive step); a non-positive or non-finite horizon
/// scores zero. Every amortized decision in the crate — offer
/// admission, scale-down, stage migration — is a comparison of values
/// of this function.
pub fn amortized_score(rate_sps: f64, horizon_s: f64, stall: &StallLedger) -> f64 {
    if !horizon_s.is_finite() || horizon_s <= 0.0 {
        return 0.0;
    }
    rate_sps * (horizon_s - stall.total()).max(0.0) / horizon_s
}

/// Net samples gained over the horizon by moving from `pre_rate` (no
/// stall) to `post_rate` (paying `stall` first) — the quantity the
/// autoscale adapter reports as `gain_samples`.
pub fn amortized_gain_samples(
    pre_rate: f64,
    post_rate: f64,
    horizon_s: f64,
    stall: &StallLedger,
) -> f64 {
    (amortized_score(post_rate, horizon_s, stall) - pre_rate) * horizon_s
}

/// The shared decision vocabulary. Every engine verdict is one of
/// these; adapters translate to their legacy enums where needed.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Action {
    /// Admit this offer on a measured curve.
    Admit {
        /// Catalog GPU type admitted.
        gpu: String,
    },
    /// Admit looks worthwhile on a catalog estimate: profile first.
    Defer {
        /// Catalog GPU type deferred.
        gpu: String,
    },
    /// Decline this offer.
    Decline {
        /// Catalog GPU type declined.
        gpu: String,
    },
    /// Release a paid rank (scale-down).
    Release {
        /// Leader slot id released.
        slot: usize,
    },
    /// Migrate the ZeRO stage as part of the round.
    StageMigrate {
        /// Stage before.
        from: u8,
        /// Stage after.
        to: u8,
    },
    /// Keep the cluster exactly as it is.
    Stay,
}

impl Action {
    /// Short display label.
    pub fn label(&self) -> &'static str {
        match self {
            Action::Admit { .. } => "admit",
            Action::Defer { .. } => "defer",
            Action::Decline { .. } => "decline",
            Action::Release { .. } => "release",
            Action::StageMigrate { .. } => "migrate",
            Action::Stay => "stay",
        }
    }
}

/// Knobs of the round engine (`[policy]` + `[autoscale]` in config).
#[derive(Debug, Clone)]
pub struct RoundOptions {
    /// Amortization horizon in seconds (shared `[policy] horizon_s`).
    pub horizon_s: f64,
    /// Minimum amortized relative gain for the round to act.
    pub min_gain: f64,
    /// Per-type $/hr overrides of the built-in price table.
    pub prices: Vec<(String, f64)>,
    /// Consider releasing a paid rank when samples/$ says dropping it
    /// wins (`poplar autoscale --release`).
    pub consider_release: bool,
    /// Also replay the offers through the sequential greedy rule for
    /// comparison ([`RoundPlan::sequential`]). Report-only and not
    /// free (a planner clone plus one replan per admitted offer), so
    /// the leader leaves it off; the CLI and the figure turn it on.
    pub with_sequential: bool,
    /// Soft cap on the offers one round may admit (`[policy]
    /// max_offers_per_round`). Validated to be at least 1; batches of
    /// any size are priced, the chosen subset just never exceeds this.
    pub max_offers_per_round: usize,
    /// Subset-search strategy ([`SearchMode::Auto`] unless a test pins
    /// one arm).
    pub search: SearchMode,
    /// Consider pipeline-grouping offers that no ZeRO stage can host
    /// solo into one virtual DP rank (`[pipeline]` config table /
    /// `poplar elastic --allow-pipeline`). Off by default: the arm only
    /// pays for itself when the fleet actually sees memory-starved
    /// offers.
    pub allow_pipeline: bool,
    /// Largest pipeline group the round may propose (`[pipeline]
    /// max_group_size`, at least [`crate::pipeline::MIN_GROUP_SIZE`]
    /// whenever the arm is on).
    pub max_group_size: usize,
}

impl Default for RoundOptions {
    fn default() -> Self {
        RoundOptions {
            horizon_s: DEFAULT_HORIZON_S,
            min_gain: DEFAULT_MIN_GAIN,
            prices: Vec::new(),
            consider_release: false,
            with_sequential: false,
            max_offers_per_round: DEFAULT_MAX_OFFERS_PER_ROUND,
            search: SearchMode::Auto,
            allow_pipeline: false,
            max_group_size: crate::pipeline::DEFAULT_MAX_GROUP_SIZE,
        }
    }
}

impl RoundOptions {
    /// Round options inheriting an autoscale adapter's knobs.
    pub fn from_autoscale(a: &AutoscaleOptions) -> Self {
        RoundOptions {
            horizon_s: a.horizon_s,
            min_gain: a.min_gain,
            prices: a.prices.clone(),
            ..Default::default()
        }
    }

    /// The equivalent per-offer adapter options (solo verdicts).
    pub fn to_autoscale(&self) -> AutoscaleOptions {
        AutoscaleOptions {
            horizon_s: self.horizon_s,
            min_gain: self.min_gain,
            prices: self.prices.clone(),
        }
    }

    /// Effective $/hr for a GPU type (override, builtin, then $0) —
    /// the same resolution rule as the autoscale adapter.
    pub fn price_per_hour(&self, gpu: &str) -> f64 {
        autoscale::price_lookup(&self.prices, gpu)
    }
}

/// One offer's verdict inside a round, with the greedy one-at-a-time
/// verdict alongside so reports can show where joint pricing diverges.
#[derive(Debug, Clone)]
pub struct OfferVerdict {
    /// Catalog GPU type offered.
    pub gpu: String,
    /// The round engine's verdict for this offer.
    pub action: Action,
    /// What the PR-3 greedy rule (each offer priced alone against the
    /// pre-admission state) decides for the same offer. `None` when the
    /// solo evaluation is inapplicable (the offer cannot fit the
    /// incumbent stage) or skipped — batches above
    /// [`MAX_EXHAUSTIVE_OFFERS`] omit the comparison data rather than
    /// pay one full preview per offer.
    pub solo: Option<OfferDecision>,
    /// One-line justification.
    pub reason: String,
}

/// A paid rank the round decided to release (scale-down).
#[derive(Debug, Clone)]
pub struct ReleaseDecision {
    /// Leader slot id released.
    pub slot: usize,
    /// Catalog GPU type of the released rank.
    pub gpu: String,
    /// Steady samples/s after the release.
    pub rate_after: f64,
    /// Amortized effective samples/s after the release (kernel value).
    pub score_after: f64,
    /// The release's one-shot stall (survivors absorb the shard).
    pub stall: StallLedger,
    /// Cluster $/hr before / after.
    pub price_before_per_hour: f64,
    /// Cluster $/hr after the release.
    pub price_after_per_hour: f64,
    /// $ per 1000 samples before the release.
    pub cost_per_ksample_before: f64,
    /// $ per 1000 samples after (amortized rate).
    pub cost_per_ksample_after: f64,
    /// Relative samples-per-dollar improvement (strictly positive and
    /// at least `min_gain` whenever a release fires).
    pub rel_gain_per_dollar: f64,
    /// One-line justification.
    pub reason: String,
}

/// Outcome of replaying the offers through the *sequential* greedy
/// rule: admit-or-decline one at a time, each admission re-pricing the
/// state and paying its own stall. The joint round is never worse.
#[derive(Debug, Clone)]
pub struct SequentialOutcome {
    /// Offers admitted, in evaluation order.
    pub admitted: Vec<String>,
    /// Per-offer verdicts in evaluation order.
    pub decisions: Vec<(String, Action)>,
    /// Steady samples/s of the sequential end state.
    pub rate: f64,
    /// Kernel score of the end state with the summed per-step stalls.
    pub score: f64,
    /// `score / pre_rate - 1`.
    pub rel_gain: f64,
}

/// The round's pipeline-grouping verdict: offers that no ZeRO stage can
/// host alone, combined into ONE virtual DP rank over a contiguous
/// layer split (ROADMAP item 3's whimpy-GPU arm). Advisory like
/// [`RoundPlan::stage`]: the round never mutates the planner, so a
/// caller realizes the admission with
/// [`crate::elastic::ElasticPlanner::add_group_slot`] — the simulated
/// leader only reports it, since its worker substrate spawns one worker
/// per physical replica.
#[derive(Debug, Clone)]
pub struct GroupAdmission {
    /// Virtual-rank label (`pg(a+b+c)`); the slot `gpu` name on
    /// admission.
    pub label: String,
    /// Physical members in pipeline-stage order.
    pub members: Vec<String>,
    /// Contiguous layers per member, `members` order.
    pub ks: Vec<u64>,
    /// Samples per pipeline micro-batch.
    pub chunk: usize,
    /// ZeRO stage the group was priced at (always the incumbent).
    pub stage: u8,
    /// Steady samples/s of the fleet with the group admitted.
    pub rate: f64,
    /// Kernel score of that configuration.
    pub score: f64,
    /// `score / pre_rate - 1`; at least `min_gain` whenever this fires.
    pub rel_gain: f64,
    /// The admission's one-shot stall: optimizer-shard reshard to the
    /// widened membership. No Alg. 1 item — the composed curve prices
    /// from member catalog curves.
    pub ledger: StallLedger,
}

/// Everything one joint decision round concluded.
#[derive(Debug, Clone)]
pub struct RoundPlan {
    /// Horizon the round amortized over.
    pub horizon_s: f64,
    /// Acceptance bar used.
    pub min_gain: f64,
    /// Steady samples/s of the keep-as-is baseline.
    pub pre_rate: f64,
    /// Steady samples/s of the chosen configuration.
    pub post_rate: f64,
    /// ZeRO stage before the round.
    pub stage_before: u8,
    /// ZeRO stage the chosen configuration runs at.
    pub stage: u8,
    /// The chosen configuration's one-shot stall, itemized.
    pub ledger: StallLedger,
    /// Kernel score of the chosen configuration.
    pub score: f64,
    /// `score / pre_rate - 1`.
    pub rel_gain: f64,
    /// Per-offer verdicts, offer order.
    pub offers: Vec<OfferVerdict>,
    /// Offers the round admits (measured curves) or defers (estimates),
    /// i.e. the chosen subset in offer order.
    pub admitted: Vec<String>,
    /// Scale-down decision, when one fired.
    pub release: Option<ReleaseDecision>,
    /// Pipeline-grouping verdict for memory-starved offers, when
    /// [`RoundOptions::allow_pipeline`] is set and a packed group
    /// cleared the bar. Advisory: member offers stay `Decline` in
    /// [`RoundPlan::offers`] — they join as one virtual rank, not as
    /// solo ranks.
    pub grouping: Option<GroupAdmission>,
    /// The sequential greedy replay, for comparison — present only
    /// when [`RoundOptions::with_sequential`] was set (and the replay
    /// itself succeeded; it can never veto the round).
    pub sequential: Option<SequentialOutcome>,
    /// $ per 1000 samples before the round.
    pub cost_per_ksample_before: f64,
    /// $ per 1000 samples of the chosen configuration (amortized rate).
    pub cost_per_ksample_after: f64,
    /// Flat action summary (stage change first, then offers, then any
    /// release; `Stay` when the round changes nothing).
    pub actions: Vec<Action>,
}

fn cluster_price_per_hour(planner: &ElasticPlanner, opts: &RoundOptions) -> f64 {
    planner
        .slots()
        .iter()
        .filter(|s| s.alive)
        .map(|s| opts.price_per_hour(&s.gpu))
        .sum()
}

fn cost_per_ksample(price_per_hour: f64, rate: f64) -> f64 {
    if rate <= 0.0 {
        return f64::INFINITY;
    }
    price_per_hour / 3600.0 / rate * 1000.0
}

/// Baseline steady rate of the planner as it stands.
fn baseline_rate(planner: &ElasticPlanner, net: &NetSim) -> Result<f64, AutoscaleError> {
    let curves = planner.active_curves()?;
    let psi = planner.param_count();
    let mut net0 = net.clone();
    net0.n = curves.len();
    let plan = allocator::plan(&curves, planner.stage(), planner.gbs(), &net0, psi)?;
    let wall = predicted_wall_s(&plan, &curves, &net0, psi)?;
    if !(wall.is_finite() && wall > 0.0) {
        return Err(AutoscaleError::BadOptions(format!(
            "baseline wall time is not positive: {wall}"
        )));
    }
    Ok(planner.gbs() as f64 / wall)
}

/// One evaluated `(offer subset, stage)` point of the round search.
/// `members` indexes into the offer batch (any size — no bitmask, so no
/// 64-offer ceiling) in *evaluation* order; `member_cached` is parallel
/// to it.
struct Candidate {
    members: Vec<usize>,
    stage: u8,
    rate: f64,
    ledger: StallLedger,
    score: f64,
    /// Per-member measured flag, `members` order.
    member_cached: Vec<bool>,
}

impl Candidate {
    fn keep(stage0: u8, pre_rate: f64, pre_score: f64) -> Self {
        Candidate {
            members: Vec::new(),
            stage: stage0,
            rate: pre_rate,
            ledger: StallLedger::default(),
            score: pre_score,
            member_cached: Vec::new(),
        }
    }
}

fn validate(opts: &RoundOptions) -> Result<(), AutoscaleError> {
    // one rule for the whole crate: delegate to the adapter's validator
    // (prices left empty — they carry no range constraints here)
    AutoscaleOptions {
        horizon_s: opts.horizon_s,
        min_gain: opts.min_gain,
        prices: Vec::new(),
    }
    .validate()?;
    if opts.max_offers_per_round == 0 {
        return Err(AutoscaleError::BadOptions(
            "max_offers_per_round must be at least 1".to_string(),
        ));
    }
    if opts.allow_pipeline && opts.max_group_size < crate::pipeline::MIN_GROUP_SIZE {
        return Err(AutoscaleError::BadOptions(format!(
            "max_group_size must be at least {} when pipeline grouping is on, got {}",
            crate::pipeline::MIN_GROUP_SIZE,
            opts.max_group_size
        )));
    }
    Ok(())
}

/// Immutable inputs shared by every subset evaluation of one round.
struct RoundCtx<'a> {
    planner: &'a ElasticPlanner,
    net: &'a NetSim,
    model: &'a ModelSpec,
    /// Offer batch, interned once at round entry — subset previews copy
    /// handles instead of cloning `String`s.
    offers: Vec<TypeId>,
    /// Round-scoped migration index over the incumbent manifest: built
    /// once, priced against by every candidate preview of the round.
    idx: RoundIndex<'a>,
    opts: &'a RoundOptions,
    /// The planner's model preset, when it names one (stage-feasibility
    /// checks need the memory model).
    model_spec: Option<ModelSpec>,
    psi: u64,
    gbs: f64,
    stage0: u8,
    n_live: usize,
}

/// One priced subset: the preview is kept so the greedy search can
/// extend it by one joiner instead of re-evaluating from scratch.
struct SubsetEval {
    rate: f64,
    ledger: StallLedger,
    score: f64,
    member_cached: Vec<bool>,
    preview: RoundPreview,
}

/// Stage-eligibility rules of the round search (identical for the
/// exhaustive and greedy paths): non-incumbent stages need a stage
/// policy, a model preset, the memory bound, and measured-at-`n_after`
/// coverage of every involved type; the incumbent stage needs only the
/// memory bound.
fn stage_eligible(ctx: &RoundCtx, stage: u8, n_after: usize, subset_refs: &[&str]) -> bool {
    if stage != ctx.stage0 {
        if ctx.planner.stage_policy().is_none() {
            return false;
        }
        let Some(mspec) = &ctx.model_spec else { return false };
        if !ctx.planner.stage_feasible_with(mspec, stage, n_after, subset_refs) {
            return false;
        }
        let measured = |g: &str| ctx.planner.measured_at(g, stage, n_after).is_some();
        ctx.planner.slots().iter().filter(|s| s.alive).all(|s| measured(&s.gpu))
            && subset_refs.iter().all(|g| measured(g))
    } else if let Some(mspec) = &ctx.model_spec {
        // incumbent stage: the memory bound must still hold for the
        // post-admission group (a member that cannot fit here is
        // evaluated at the other stages instead)
        ctx.planner.stage_feasible_with(mspec, stage, n_after, subset_refs)
    } else {
        true
    }
}

/// Catalog fallback estimate for one member uncached at `stage`
/// (`Ok(None)` = cached; `Err(())` = not admissible at this stage).
fn member_fallback(
    ctx: &RoundCtx,
    stage: u8,
    n_after: usize,
    gpu: TypeId,
) -> Result<Option<PerfCurve>, ()> {
    let key = CurveKey::of(gpu, ctx.planner.model_id(), stage);
    if ctx.planner.cache().peek(&key).is_some() {
        Ok(None)
    } else if stage == ctx.stage0 {
        synthesize_curve(&gpu, ctx.model, stage, n_after).map(Some).map_err(|_| ())
    } else {
        // unreachable given the measured() precheck
        Err(())
    }
}

/// Score one priced preview: steady rate, itemized stall ledger, kernel
/// score. `None` when the wall prediction is unusable.
fn score_preview(
    ctx: &RoundCtx,
    pv: &RoundPreview,
    subset: &[TypeId],
) -> Option<(f64, StallLedger, f64)> {
    let wall = predicted_wall_s(&pv.plan, &pv.curves, &pv.net, ctx.psi).ok()?;
    if !(wall.is_finite() && wall > 0.0) {
        return None;
    }
    let rate = ctx.gbs / wall;
    // one Alg. 1 per uncached member *type* — joint admission amortizes
    // the reshard, not the profiling
    let mut profiling = 0.0;
    let mut priced: Vec<&str> = Vec::new();
    for (i, gpu) in subset.iter().enumerate() {
        if !pv.joiner_cached[i] && !priced.contains(&gpu.as_str()) {
            let idx = pv.curves.len() - subset.len() + i;
            profiling += profile_cost_estimate_s(&pv.curves[idx]);
            priced.push(gpu.as_str());
        }
    }
    let migration = pv.migration_only_s.min(pv.reshard_penalty_s);
    let ledger = StallLedger {
        reshard_transfer_s: (pv.reshard_penalty_s - migration).max(0.0),
        migration_transfer_s: migration,
        profiling_est_s: profiling,
    };
    let score = amortized_score(rate, ctx.opts.horizon_s, &ledger);
    Some((rate, ledger, score))
}

/// Price one `(subset, stage)` configuration from scratch. `None` when
/// the configuration is ineligible or unplannable — the search just
/// skips it, exactly like the PR-5 mask loop's `continue`s.
fn eval_subset(ctx: &RoundCtx, stage: u8, members: &[usize]) -> Option<SubsetEval> {
    let subset: Vec<TypeId> = members.iter().map(|&i| ctx.offers[i]).collect();
    let subset_refs: Vec<&str> = subset.iter().map(|t| t.as_str()).collect();
    let n_after = ctx.n_live + subset.len();
    if !stage_eligible(ctx, stage, n_after, &subset_refs) {
        return None;
    }
    let mut fallbacks: Vec<Option<PerfCurve>> = Vec::with_capacity(subset.len());
    for &gpu in &subset {
        fallbacks.push(member_fallback(ctx, stage, n_after, gpu).ok()?);
    }
    let pv =
        ctx.planner.preview_round_at_with(&ctx.idx, stage, &subset, &fallbacks, ctx.net).ok()?;
    let (rate, ledger, score) = score_preview(ctx, &pv, &subset)?;
    Some(SubsetEval { rate, ledger, score, member_cached: pv.joiner_cached.clone(), preview: pv })
}

/// Price `prev ∪ {new_member}` by extending the prior preview one
/// joiner at a time (`ElasticPlanner::preview_round_extend`) instead of
/// rebuilding it — the delta path that makes the greedy search cheap.
/// Falls back to a from-scratch evaluation when the prior subset
/// carries a synthesized fallback curve (those are sized at the
/// admission-time group size, so the cached prefix would be stale).
fn eval_extend(
    ctx: &RoundCtx,
    stage: u8,
    prev: &SubsetEval,
    prev_members: &[usize],
    new_member: usize,
) -> Option<SubsetEval> {
    let mut members = prev_members.to_vec();
    members.push(new_member);
    if prev.member_cached.iter().any(|c| !c) {
        return eval_subset(ctx, stage, &members);
    }
    let subset: Vec<TypeId> = members.iter().map(|&i| ctx.offers[i]).collect();
    let subset_refs: Vec<&str> = subset.iter().map(|t| t.as_str()).collect();
    let n_after = ctx.n_live + subset.len();
    if !stage_eligible(ctx, stage, n_after, &subset_refs) {
        return None;
    }
    let gpu = ctx.offers[new_member];
    let fallback = member_fallback(ctx, stage, n_after, gpu).ok()?;
    let pv = ctx
        .planner
        .preview_round_extend_with(&ctx.idx, &prev.preview, gpu, fallback.as_ref(), ctx.net)
        .ok()?;
    let (rate, ledger, score) = score_preview(ctx, &pv, &subset)?;
    Some(SubsetEval { rate, ledger, score, member_cached: pv.joiner_cached.clone(), preview: pv })
}

/// The exact `2^k` enumeration (batches of at most
/// [`MAX_EXHAUSTIVE_OFFERS`] offers): every subset × every eligible
/// stage, best kernel score wins.
fn search_exhaustive(ctx: &RoundCtx, best: &mut Candidate) {
    let k = ctx.offers.len();
    for mask in 1usize..(1usize << k) {
        let members: Vec<usize> = (0..k).filter(|i| mask & (1 << i) != 0).collect();
        if members.len() > ctx.opts.max_offers_per_round {
            continue;
        }
        for stage in (0..=3u8).rev() {
            if let Some(ev) = eval_subset(ctx, stage, &members) {
                if ev.score > best.score {
                    *best = Candidate {
                        members: members.clone(),
                        stage,
                        rate: ev.rate,
                        ledger: ev.ledger,
                        score: ev.score,
                        member_cached: ev.member_cached,
                    };
                }
            }
        }
    }
}

/// The marginal-contribution greedy search, per candidate stage: seed
/// from the best singleton, then repeatedly add the unused offer with
/// the highest marginal amortized gain against the incrementally
/// extended preview, stopping when no addition strictly improves the
/// score or the soft cap is reached. Offers of one GPU type price
/// identically, so each growth step evaluates one representative per
/// distinct unused type — `O(cap · T)` previews per stage, `T` =
/// distinct offer types, instead of `2^k`.
fn search_greedy(ctx: &RoundCtx, best: &mut Candidate) {
    let k = ctx.offers.len();
    let cap = ctx.opts.max_offers_per_round.min(k);
    for stage in (0..=3u8).rev() {
        // representative offer index per distinct type: the singleton
        // seeds, and (filtered to unused) the growth candidates
        let mut members: Vec<usize> = Vec::new();
        let mut cur: Option<SubsetEval> = None;
        for _ in 0..cap {
            let mut step: Option<(usize, SubsetEval)> = None;
            let mut seen_types: Vec<&str> = Vec::new();
            for i in 0..k {
                if members.contains(&i) {
                    continue;
                }
                let ty = ctx.offers[i].as_str();
                if seen_types.contains(&ty) {
                    continue;
                }
                seen_types.push(ty);
                let ev = match &cur {
                    None => eval_subset(ctx, stage, &[i]),
                    Some(prev) => eval_extend(ctx, stage, prev, &members, i),
                };
                if let Some(ev) = ev {
                    // must strictly beat both the incumbent subset and
                    // the best addition found so far this step
                    let bar = cur
                        .as_ref()
                        .map(|c| c.score)
                        .unwrap_or(f64::NEG_INFINITY)
                        .max(step.as_ref().map(|(_, s)| s.score).unwrap_or(f64::NEG_INFINITY));
                    if ev.score > bar {
                        step = Some((i, ev));
                    }
                }
            }
            // stop when no addition strictly improves the score
            let Some((i, ev)) = step else { break };
            members.push(i);
            cur = Some(ev);
            // lint:allow(panic-path) -- `cur` is set to Some on the line above
            let cur_ref = cur.as_ref().expect("just set");
            if cur_ref.score > best.score {
                *best = Candidate {
                    members: members.clone(),
                    stage,
                    rate: cur_ref.rate,
                    ledger: cur_ref.ledger.clone(),
                    score: cur_ref.score,
                    member_cached: cur_ref.member_cached.clone(),
                };
            }
        }
    }
}

/// The joint decision round: search offer subsets × eligible ZeRO
/// stages with ONE combined stall per configuration, pick the
/// kernel-score maximum, and (with `consider_release`) check whether
/// releasing a paid rank wins on the samples-per-dollar axis.
///
/// Batches of at most [`MAX_EXHAUSTIVE_OFFERS`] offers are enumerated
/// exactly; larger batches (any size — the PR-5 hard error is gone) go
/// through the marginal-contribution greedy search, which admits at
/// most [`RoundOptions::max_offers_per_round`] offers per round and is
/// pinned by tests to within [`GREEDY_BOUND`] of the exhaustive optimum
/// wherever both run. Greedy previews are priced incrementally
/// ([`ElasticPlanner::preview_round_extend`]), so a 100-offer round
/// over a 1000-rank fleet completes in one planner pass per growth
/// step.
///
/// Decision rule: the round acts only when the best configuration's
/// amortized relative gain clears `min_gain` against the keep-as-is
/// baseline; within an acting round, every subset member with a
/// positive marginal contribution is admitted — the bar prices the
/// *round's* disruption, not each member's (that is exactly what the
/// greedy one-at-a-time rule gets wrong). Candidate stages other than
/// the incumbent are searched only when the planner carries a
/// [`crate::elastic::StagePolicy`] and every involved type is measured
/// there (the defer rule); offers that cannot fit the incumbent stage
/// are still evaluated at every feasible admission stage instead of
/// being dropped. A release is considered only in rounds that admit
/// nothing (one manifest movement per round) and fires only with a
/// strictly positive amortized samples-per-dollar gain of at least
/// `min_gain`.
///
/// Pure: the planner (cache counters and LRU order included) is
/// untouched whatever the verdict — previews go through the
/// non-mutating `preview_round_at` / `preview_release` primitives.
/// [`RoundPlan::stage`] is therefore *advisory pricing* for callers
/// that replan with their own [`crate::elastic::StagePolicy`]: the
/// replan's (kernel-identical) stage search over the post-admission
/// membership performs any actual migration.
pub fn decide_round(
    planner: &ElasticPlanner,
    net: &NetSim,
    model: &ModelSpec,
    offers: &[String],
    opts: &RoundOptions,
) -> Result<RoundPlan, AutoscaleError> {
    validate(opts)?;
    for gpu in offers {
        if catalog::spec(gpu).is_none() {
            return Err(AutoscaleError::UnknownGpu(gpu.clone()));
        }
    }

    let stage0 = planner.stage();
    let pre_rate = baseline_rate(planner, net)?;
    let pre_score = amortized_score(pre_rate, opts.horizon_s, &StallLedger::default());
    // intern the batch once and index the incumbent manifest once: every
    // subset × stage preview of this round prices against `idx` instead
    // of re-validating + re-scanning the manifest per candidate
    let offers_t: Vec<TypeId> = offers.iter().map(|g| intern::intern(g)).collect();
    let idx = planner.round_index().map_err(AutoscaleError::Elastic)?;
    let ctx = RoundCtx {
        planner,
        net,
        model,
        offers: offers_t,
        idx,
        opts,
        model_spec: crate::config::model::preset(planner.model()),
        psi: planner.param_count(),
        gbs: planner.gbs() as f64,
        stage0,
        n_live: planner.active_slots().len(),
    };

    // which search runs: exact enumeration for small batches, greedy
    // above (a forced-exhaustive large batch is the one BadOptions left)
    let k = offers.len();
    let exhaustive = match opts.search {
        SearchMode::Exhaustive => {
            if k > MAX_EXHAUSTIVE_OFFERS {
                return Err(AutoscaleError::BadOptions(format!(
                    "exhaustive subset search is capped at {MAX_EXHAUSTIVE_OFFERS} offers, \
                     got {k}; use SearchMode::Auto or SearchMode::Greedy"
                )));
            }
            true
        }
        SearchMode::Greedy => false,
        SearchMode::Auto => k <= MAX_EXHAUSTIVE_OFFERS,
    };

    // greedy one-at-a-time verdicts (the PR-3 rule) for comparison —
    // comparison data only, so large batches skip it rather than pay
    // one full preview per offer
    let aopts = opts.to_autoscale();
    let mut solo: Vec<Option<OfferDecision>> = Vec::with_capacity(offers.len());
    if k <= MAX_EXHAUSTIVE_OFFERS {
        for gpu in offers {
            match autoscale::evaluate_offer(planner, net, model, gpu, &aopts) {
                Ok(d) => solo.push(Some(d)),
                // a candidate that cannot fit at the incumbent stage is a
                // greedy decline, not a round-killing error — the joint
                // search may still place it at another stage
                Err(AutoscaleError::NoCapacity(_)) | Err(AutoscaleError::Elastic(_)) => {
                    solo.push(None)
                }
                Err(e) => return Err(e),
            }
        }
    } else {
        solo.resize(k, None);
    }

    // ---- subset x stage search ----
    let mut best = Candidate::keep(stage0, pre_rate, pre_score);
    if exhaustive {
        search_exhaustive(&ctx, &mut best);
    } else {
        search_greedy(&ctx, &mut best);
    }

    // gate: an acting round must clear the bar; otherwise keep as-is
    let mut rel_gain = if pre_rate > 0.0 { best.score / pre_rate - 1.0 } else { 0.0 };
    if (!best.members.is_empty() || best.stage != stage0) && rel_gain < opts.min_gain {
        best = Candidate::keep(stage0, pre_rate, pre_score);
        rel_gain = if pre_rate > 0.0 { best.score / pre_rate - 1.0 } else { 0.0 };
    }

    // ---- pipeline grouping arm ----
    // offers the subset search could never place (the memory bound
    // fails at every ZeRO stage) get one more chance as a GROUP: one
    // virtual DP rank over a contiguous layer split, priced through the
    // same preview + kernel as everything else
    let grouping = if opts.allow_pipeline { decide_grouping(&ctx, pre_rate) } else { None };

    // per-offer verdicts
    let mut verdicts: Vec<OfferVerdict> = Vec::with_capacity(k);
    let mut admitted: Vec<String> = Vec::new();
    for (i, gpu) in offers.iter().enumerate() {
        // `members` is in evaluation order (greedy insertion order), so
        // look the offer up by position to index `member_cached`
        let member_pos = best.members.iter().position(|&m| m == i);
        let (action, reason) = if let Some(pos) = member_pos {
            let cached = best.member_cached.get(pos).copied().unwrap_or(true);
            admitted.push(gpu.clone());
            if cached {
                (
                    Action::Admit { gpu: gpu.clone() },
                    format!(
                        "in the round's best batch at ZeRO-{} (round gain {:+.1}% over \
                         {:.0}s, one shared stall {:.3}s)",
                        best.stage,
                        rel_gain * 100.0,
                        opts.horizon_s,
                        best.ledger.total()
                    ),
                )
            } else {
                (
                    Action::Defer { gpu: gpu.clone() },
                    "in the round's best batch on a catalog estimate: profile before \
                     committing"
                        .to_string(),
                )
            }
        } else if let Some(gr) =
            grouping.as_ref().filter(|gr| gr.members.iter().any(|m| m == gpu))
        {
            (
                Action::Decline { gpu: gpu.clone() },
                format!(
                    "no ZeRO stage can host this card alone; proposed as a member of \
                     pipeline group {} instead",
                    gr.label
                ),
            )
        } else {
            (
                Action::Decline { gpu: gpu.clone() },
                "no subset containing this offer beats the round's best configuration"
                    .to_string(),
            )
        };
        verdicts.push(OfferVerdict { gpu: gpu.clone(), action, solo: solo[i].clone(), reason });
    }

    // ---- scale-down ----
    let price_pre = cluster_price_per_hour(planner, opts);
    let cost_pre = cost_per_ksample(price_pre, pre_rate);
    let release = if opts.consider_release && best.members.is_empty() && best.stage == stage0 {
        decide_release(planner, net, opts, pre_rate, price_pre, cost_pre)?
    } else {
        None
    };

    let price_post: f64 = price_pre
        + admitted.iter().map(|g| opts.price_per_hour(g)).sum::<f64>()
        - release.as_ref().map_or(0.0, |r| opts.price_per_hour(&r.gpu));
    let (post_rate, score, ledger, stage, cost_post) = match &release {
        Some(r) => (
            r.rate_after,
            r.score_after,
            r.stall.clone(),
            stage0,
            r.cost_per_ksample_after,
        ),
        None => (
            best.rate,
            best.score,
            best.ledger.clone(),
            best.stage,
            cost_per_ksample(price_post, best.score),
        ),
    };
    let rel_gain = if pre_rate > 0.0 { score / pre_rate - 1.0 } else { 0.0 };

    // the sequential replay is report-only comparison data: opt-in,
    // skipped for offer-less rounds, and a failure inside it can never
    // veto an otherwise-successful joint decision
    let sequential = if opts.with_sequential && !offers.is_empty() {
        sequential_round_inner(planner, net, model, offers, opts, pre_rate).ok()
    } else {
        None
    };

    let mut actions: Vec<Action> = Vec::new();
    if stage != stage0 {
        actions.push(Action::StageMigrate { from: stage0, to: stage });
    }
    for v in &verdicts {
        actions.push(v.action.clone());
    }
    if let Some(r) = &release {
        actions.push(Action::Release { slot: r.slot });
    }
    if actions.iter().all(|a| matches!(a, Action::Decline { .. })) {
        actions.push(Action::Stay);
    }

    Ok(RoundPlan {
        horizon_s: opts.horizon_s,
        min_gain: opts.min_gain,
        pre_rate,
        post_rate,
        stage_before: stage0,
        stage,
        ledger,
        score,
        rel_gain,
        offers: verdicts,
        admitted,
        release,
        grouping,
        sequential,
        cost_per_ksample_before: cost_pre,
        cost_per_ksample_after: cost_post,
        actions,
    })
}

/// The pipeline-grouping arm of [`decide_round`]: collect the offers
/// that are solo-infeasible at EVERY ZeRO stage, pack them anchor-first
/// ([`crate::pipeline::pack_groups`]), and price each candidate group
/// as one joining virtual rank at the incumbent stage. The first group
/// to clear `min_gain` wins — packing emits strongest-anchored groups
/// first. `None` when grouping cannot help (no model preset, fewer than
/// [`crate::pipeline::MIN_GROUP_SIZE`] starved offers, or no group
/// clears the bar).
fn decide_grouping(ctx: &RoundCtx, pre_rate: f64) -> Option<GroupAdmission> {
    let mspec = ctx.model_spec.as_ref()?;
    // the group joins as ONE virtual rank: shards size at n_live + 1
    let n_joined = ctx.n_live + 1;
    let starved: Vec<TypeId> = ctx
        .offers
        .iter()
        .filter(|gpu| {
            catalog::spec(gpu.as_str()).is_some_and(|spec| {
                (0u8..=3).all(|stage| {
                    crate::memmodel::true_mbs(mspec, ctx.psi, stage, n_joined, spec.mem_bytes())
                        == 0
                })
            })
        })
        .copied()
        .collect();
    if starved.len() < crate::pipeline::MIN_GROUP_SIZE {
        return None;
    }
    let (groups, _leftovers) =
        crate::pipeline::pack_groups(&starved, mspec, ctx.psi, ctx.stage0, ctx.opts.max_group_size);
    for members in &groups {
        let Ok(gp) =
            crate::pipeline::plan_group(members, mspec, ctx.psi, ctx.stage0, n_joined, ctx.net)
        else {
            continue;
        };
        let labels = [intern::intern(&gp.label)];
        let fallbacks = [Some(gp.curve.clone())];
        let Ok(pv) =
            ctx.planner.preview_round_at_with(&ctx.idx, ctx.stage0, &labels, &fallbacks, ctx.net)
        else {
            continue;
        };
        let Ok(wall) = predicted_wall_s(&pv.plan, &pv.curves, &pv.net, ctx.psi) else {
            continue;
        };
        if !(wall.is_finite() && wall > 0.0) {
            continue;
        }
        let rate = ctx.gbs / wall;
        let migration = pv.migration_only_s.min(pv.reshard_penalty_s);
        let ledger = StallLedger {
            reshard_transfer_s: (pv.reshard_penalty_s - migration).max(0.0),
            migration_transfer_s: migration,
            // the composed curve prices from member catalog curves, not
            // a fresh Alg. 1 run per member
            profiling_est_s: 0.0,
        };
        let score = amortized_score(rate, ctx.opts.horizon_s, &ledger);
        let rel_gain = if pre_rate > 0.0 { score / pre_rate - 1.0 } else { 0.0 };
        if rel_gain < ctx.opts.min_gain {
            continue;
        }
        return Some(GroupAdmission {
            label: gp.label,
            members: gp.members,
            ks: gp.ks,
            chunk: gp.chunk,
            stage: gp.stage,
            rate,
            score,
            rel_gain,
            ledger,
        });
    }
    None
}

/// The scale-down arm: release the live rank whose removal most
/// improves amortized samples per dollar, if any clears `min_gain`.
fn decide_release(
    planner: &ElasticPlanner,
    net: &NetSim,
    opts: &RoundOptions,
    pre_rate: f64,
    price_pre: f64,
    cost_pre: f64,
) -> Result<Option<ReleaseDecision>, AutoscaleError> {
    if !(price_pre.is_finite() && price_pre > 0.0 && pre_rate > 0.0) {
        // unpriced fleet: the cost axis is meaningless, never release
        return Ok(None);
    }
    let psi = planner.param_count();
    let gbs = planner.gbs() as f64;
    let value_pre = pre_rate / price_pre;
    let model_spec = crate::config::model::preset(planner.model());
    let n_after = planner.active_slots().len().saturating_sub(1);
    let mut best: Option<ReleaseDecision> = None;
    for sl in planner.slots().iter().filter(|s| s.alive) {
        // the Alg. 1 memory bound must hold for every SURVIVOR at the
        // shrunken group size: optimizer shards grow to 12ψ/(n-1), and
        // a release that would OOM a survivor can never win — the
        // survivors' curves were measured at n, so only the memory
        // model can veto this (the leader's (2b) staleness pass
        // re-measures them after an actual release)
        if let Some(m) = &model_spec {
            let survivors_fit = planner
                .slots()
                .iter()
                .filter(|s| s.alive && s.slot != sl.slot)
                .all(|s| {
                    if s.members.is_empty() {
                        catalog::spec(&s.gpu).is_some_and(|spec| {
                            crate::memmodel::true_mbs(
                                m,
                                psi,
                                planner.stage(),
                                n_after,
                                spec.mem_bytes(),
                            ) >= 1
                        })
                    } else {
                        // a pipeline-group survivor re-checks the
                        // group-aware bound at the shrunken group size
                        crate::pipeline::group_feasible(
                            &s.members,
                            m,
                            psi,
                            planner.stage(),
                            n_after,
                        )
                    }
                });
            if !survivors_fit {
                continue;
            }
        }
        let Ok(pv) = planner.preview_release(sl.slot, net) else { continue };
        let Ok(wall) = predicted_wall_s(&pv.plan, &pv.curves, &pv.net, psi) else {
            continue;
        };
        if !(wall.is_finite() && wall > 0.0) {
            continue;
        }
        let rate_after = gbs / wall;
        let stall = StallLedger::reshard(pv.reshard_penalty_s);
        let score_after = amortized_score(rate_after, opts.horizon_s, &stall);
        let price_after = price_pre - opts.price_per_hour(&sl.gpu);
        if !(price_after.is_finite() && price_after > 0.0) {
            continue;
        }
        let rel = (score_after / price_after) / value_pre - 1.0;
        if rel <= 0.0 || rel < opts.min_gain {
            continue;
        }
        if best.as_ref().is_some_and(|b| b.rel_gain_per_dollar >= rel) {
            continue;
        }
        best = Some(ReleaseDecision {
            slot: sl.slot,
            gpu: sl.gpu.to_string(),
            rate_after,
            score_after,
            stall,
            price_before_per_hour: price_pre,
            price_after_per_hour: price_after,
            cost_per_ksample_before: cost_pre,
            cost_per_ksample_after: cost_per_ksample(price_after, score_after),
            rel_gain_per_dollar: rel,
            reason: format!(
                "releasing slot {} ({}) raises amortized samples/$ by {:+.1}% \
                 (rate {:.1}->{:.1} sps, ${:.2}->${:.2}/hr, stall {:.3}s)",
                sl.slot,
                sl.gpu,
                rel * 100.0,
                pre_rate,
                rate_after,
                price_pre,
                price_after,
                pv.reshard_penalty_s
            ),
        });
    }
    Ok(best)
}

/// Replay the offers through the sequential greedy rule (public for
/// tests and the figure; [`decide_round`] embeds the result).
pub fn sequential_round(
    planner: &ElasticPlanner,
    net: &NetSim,
    model: &ModelSpec,
    offers: &[String],
    opts: &RoundOptions,
) -> Result<SequentialOutcome, AutoscaleError> {
    validate(opts)?;
    let pre_rate = baseline_rate(planner, net)?;
    sequential_round_inner(planner, net, model, offers, opts, pre_rate)
}

fn sequential_round_inner(
    planner: &ElasticPlanner,
    net: &NetSim,
    model: &ModelSpec,
    offers: &[String],
    opts: &RoundOptions,
    pre_rate: f64,
) -> Result<SequentialOutcome, AutoscaleError> {
    let aopts = opts.to_autoscale();
    let psi = planner.param_count();
    let gbs = planner.gbs() as f64;
    let mut sim = planner.clone();
    let mut sim_net = net.clone();
    let mut decisions: Vec<(String, Action)> = Vec::new();
    let mut admitted: Vec<String> = Vec::new();
    let mut ledger = StallLedger::default();
    for gpu in offers {
        sim_net.n = sim.active_slots().len();
        let d = match autoscale::evaluate_offer(&sim, &sim_net, model, gpu, &aopts) {
            Ok(d) => d,
            Err(AutoscaleError::NoCapacity(_)) | Err(AutoscaleError::Elastic(_)) => {
                decisions.push((gpu.clone(), Action::Decline { gpu: gpu.clone() }));
                continue;
            }
            Err(e) => return Err(e),
        };
        if d.decision == Decision::Reject {
            decisions.push((gpu.clone(), Action::Decline { gpu: gpu.clone() }));
            continue;
        }
        // admit on the simulation clone, paying this step's own stall.
        // A deferred (uncached) admission implies an Alg. 1 run before
        // the next offer is seen — on the simulated substrate the
        // catalog synthesizer IS what that run would measure, so
        // installing it as a measured type curve (from_drift=false)
        // models the post-profiling state; the profiling time itself is
        // charged to the ledger below.
        let slot = sim.add_slot(gpu);
        if sim.needs_profile().contains(&slot) {
            let n_after = sim.active_slots().len();
            match synthesize_curve(gpu, model, sim.stage(), n_after) {
                Ok(c) => sim.install_curve(slot, c, false)?,
                Err(_) => {
                    let _ = sim.lose_slot(slot);
                    decisions.push((gpu.clone(), Action::Decline { gpu: gpu.clone() }));
                    continue;
                }
            }
        }
        sim_net.n = sim.active_slots().len();
        sim.replan(&sim_net)?;
        ledger.reshard_transfer_s += d.reshard_penalty_s;
        ledger.profiling_est_s += d.profile_est_s;
        admitted.push(gpu.clone());
        decisions.push((
            gpu.clone(),
            if d.decision == Decision::Accept {
                Action::Admit { gpu: gpu.clone() }
            } else {
                Action::Defer { gpu: gpu.clone() }
            },
        ));
    }
    let curves = sim.active_curves()?;
    sim_net.n = curves.len();
    let plan = match sim.plan() {
        Some(p) if !sim.dirty() => p.clone(),
        _ => allocator::plan(&curves, sim.stage(), sim.gbs(), &sim_net, psi)?,
    };
    let wall = predicted_wall_s(&plan, &curves, &sim_net, psi)?;
    let rate = if wall.is_finite() && wall > 0.0 { gbs / wall } else { 0.0 };
    let score = amortized_score(rate, opts.horizon_s, &ledger);
    Ok(SequentialOutcome {
        admitted,
        decisions,
        rate,
        score,
        rel_gain: if pre_rate > 0.0 { score / pre_rate - 1.0 } else { 0.0 },
    })
}

/// Shared rendering of a round: column headers…
pub const ROUND_COLUMNS: &[&str] = &[
    "subject",
    "solo",
    "joint",
    "rate_sps",
    "gain_pct",
    "stall_s",
    "usd_per_ksample",
    "note",
];

/// …and one row vector per line — baseline, one per offer, the chosen
/// round, any pipeline-group admission, the sequential replay, and any
/// release. Shared by
/// `poplar autoscale --joint` and `exp::fig_joint_admission` so the two
/// can never drift apart.
pub fn round_rows(rep: &RoundPlan) -> Vec<Vec<String>> {
    let mut rows = Vec::new();
    rows.push(vec![
        "(baseline)".to_string(),
        "-".to_string(),
        "keep".to_string(),
        format!("{:.1}", rep.pre_rate),
        "-".to_string(),
        "-".to_string(),
        format!("{:.4}", rep.cost_per_ksample_before),
        format!("ZeRO-{}", rep.stage_before),
    ]);
    for v in &rep.offers {
        let (solo_label, solo_gain) = match &v.solo {
            Some(d) => (d.decision.label().to_string(), format!("{:+.1}", d.rel_gain * 100.0)),
            None => ("decline".to_string(), "-".to_string()),
        };
        rows.push(vec![
            v.gpu.clone(),
            format!("{solo_label} ({solo_gain}%)"),
            v.action.label().to_string(),
            v.solo
                .as_ref()
                .map_or("-".to_string(), |d| format!("{:.1}", d.post_rate)),
            solo_gain,
            v.solo
                .as_ref()
                .map_or("-".to_string(), |d| {
                    format!("{:.3}", d.reshard_penalty_s + d.profile_est_s)
                }),
            "-".to_string(),
            v.reason.clone(),
        ]);
    }
    let (joint_label, note) = if let Some(r) = &rep.release {
        (
            format!("release slot {}", r.slot),
            format!("scale-down: releases {} for amortized samples/$", r.gpu),
        )
    } else if rep.admitted.is_empty() && rep.stage == rep.stage_before {
        ("stay".to_string(), "keeps the cluster as-is".to_string())
    } else {
        (
            format!("admit {} @ ZeRO-{}", rep.admitted.len(), rep.stage),
            format!("jointly admits [{}]", rep.admitted.join(", ")),
        )
    };
    rows.push(vec![
        "(round)".to_string(),
        "-".to_string(),
        joint_label,
        format!("{:.1}", rep.post_rate),
        format!("{:+.1}", rep.rel_gain * 100.0),
        format!("{:.3}", rep.ledger.total()),
        format!("{:.4}", rep.cost_per_ksample_after),
        note,
    ]);
    if let Some(gr) = &rep.grouping {
        rows.push(vec![
            gr.label.clone(),
            "-".to_string(),
            "group-admit".to_string(),
            format!("{:.2}", gr.rate),
            format!("{:+.1}", gr.rel_gain * 100.0),
            format!("{:.3}", gr.ledger.total()),
            "-".to_string(),
            format!(
                "one virtual DP rank at ZeRO-{}: layers [{}], chunk {}",
                gr.stage,
                gr.ks.iter().map(|x| x.to_string()).collect::<Vec<_>>().join("+"),
                gr.chunk
            ),
        ]);
    }
    if let Some(seq) = &rep.sequential {
        rows.push(vec![
            "(sequential)".to_string(),
            format!("admits [{}]", seq.admitted.join(", ")),
            "-".to_string(),
            format!("{:.1}", seq.rate),
            format!("{:+.1}", seq.rel_gain * 100.0),
            "-".to_string(),
            "-".to_string(),
            "one-at-a-time replay, each admission pays its own stall".to_string(),
        ]);
    }
    if let Some(r) = &rep.release {
        rows.push(vec![
            format!("slot {} ({})", r.slot, r.gpu),
            "-".to_string(),
            "release".to_string(),
            format!("{:.1}", r.rate_after),
            format!("{:+.1}", r.rel_gain_per_dollar * 100.0),
            format!("{:.3}", r.stall.total()),
            format!("{:.4}", r.cost_per_ksample_after),
            r.reason.clone(),
        ]);
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::LinkKind;
    use crate::config::model::preset;

    fn truth(gpu: &str, stage: u8, n: usize) -> PerfCurve {
        let m = preset("llama-0.5b").unwrap();
        synthesize_curve(gpu, &m, stage, n).unwrap()
    }

    fn planner_c() -> (ElasticPlanner, NetSim) {
        let m = preset("llama-0.5b").unwrap();
        let mut p = ElasticPlanner::new(1, 2048, &m.name, m.param_count(), 32);
        for gpu in [
            "A800-80G", "A800-80G", "A800-80G", "A800-80G", "V100S-32G", "V100S-32G",
            "V100S-32G", "V100S-32G",
        ] {
            let slot = p.add_slot(gpu);
            if p.slots()[slot].curve.is_none() {
                p.install_curve(slot, truth(gpu, 1, 8), false).unwrap();
            }
        }
        let net = NetSim::from_link(8, LinkKind::Ib);
        p.replan(&net).unwrap();
        (p, net)
    }

    #[test]
    fn kernel_amortizes_the_ledger_total() {
        let l = StallLedger {
            reshard_transfer_s: 2.0,
            migration_transfer_s: 3.0,
            profiling_est_s: 5.0,
        };
        assert_eq!(l.total(), 10.0);
        assert_eq!(amortized_score(100.0, 100.0, &l), 90.0);
        // stall at or past the horizon: zero effective throughput
        assert_eq!(amortized_score(100.0, 10.0, &l), 0.0);
        assert_eq!(amortized_score(100.0, 5.0, &l), 0.0);
        // degenerate horizons score zero instead of dividing by zero
        assert_eq!(amortized_score(100.0, 0.0, &l), 0.0);
        assert_eq!(amortized_score(100.0, f64::NAN, &l), 0.0);
        // empty ledger: the steady rate itself
        let none = StallLedger::default();
        assert_eq!(amortized_score(7.0, 300.0, &none), 7.0);
        // the gain helper is the kernel difference scaled by the horizon
        let g = amortized_gain_samples(90.0, 100.0, 100.0, &l);
        assert!((g - (90.0 - 90.0) * 100.0).abs() < 1e-9);
        // constructors itemize
        assert_eq!(StallLedger::reshard(1.5).total(), 1.5);
        assert_eq!(StallLedger::migration(2.5).migration_transfer_s, 2.5);
        assert_eq!(StallLedger::profiling(3.5).profiling_est_s, 3.5);
    }

    #[test]
    fn single_offer_round_matches_the_greedy_adapter() {
        // a one-offer round must agree with the PR-3 per-offer rule:
        // same accept/decline verdicts, since the joint search over one
        // offer IS the solo evaluation
        let (p, net) = planner_c();
        let m = preset("llama-0.5b").unwrap();
        // bars chosen far from each offer's gain so solo and joint can
        // never disagree by a rounding ulp exactly at the threshold
        for (gpu, min_gain) in [("A800-80G", 0.02), ("RTX3060", 0.10)] {
            let opts = RoundOptions { min_gain, ..Default::default() };
            let round =
                decide_round(&p, &net, &m, &[gpu.to_string()], &opts).unwrap();
            let solo = round.offers[0].solo.as_ref().unwrap();
            match solo.decision {
                Decision::Accept => {
                    assert!(
                        matches!(round.offers[0].action, Action::Admit { .. }),
                        "{gpu}: joint must admit what solo accepts"
                    )
                }
                Decision::Reject => assert!(
                    matches!(round.offers[0].action, Action::Decline { .. }),
                    "{gpu}: joint must decline what solo rejects"
                ),
                Decision::Defer => assert!(
                    matches!(round.offers[0].action, Action::Defer { .. }),
                    "{gpu}: joint must defer what solo defers"
                ),
            }
        }
    }

    #[test]
    fn weak_offer_rides_along_with_a_strong_batch_mate() {
        // T4 cached but tiny: solo it cannot clear a 5% bar; jointly
        // with an A800 the round pays ONE stall and the T4's marginal
        // contribution is positive, so both are admitted
        let (mut p, net) = planner_c();
        let m = preset("llama-0.5b").unwrap();
        p.install_stage_curve("T4", 1, truth("T4", 1, 10)).unwrap();
        let opts =
            RoundOptions { min_gain: 0.05, with_sequential: true, ..Default::default() };
        let offers = vec!["A800-80G".to_string(), "T4".to_string()];
        let round = decide_round(&p, &net, &m, &offers, &opts).unwrap();
        // greedy splits: accept the A800, reject the T4
        assert_eq!(round.offers[0].solo.as_ref().unwrap().decision, Decision::Accept);
        assert_eq!(round.offers[1].solo.as_ref().unwrap().decision, Decision::Reject);
        // joint admits both
        assert!(matches!(round.offers[0].action, Action::Admit { .. }));
        assert!(
            matches!(round.offers[1].action, Action::Admit { .. }),
            "{}",
            round.offers[1].reason
        );
        assert_eq!(round.admitted.len(), 2);
        assert!(round.rel_gain >= opts.min_gain);
        // the sequential replay splits too — joint is strictly better
        let seq = round.sequential.as_ref().expect("with_sequential was set");
        assert_eq!(seq.admitted, vec!["A800-80G".to_string()]);
        assert!(round.score > seq.score);
    }

    #[test]
    fn round_without_offers_or_release_stays() {
        let (p, net) = planner_c();
        let m = preset("llama-0.5b").unwrap();
        let round = decide_round(&p, &net, &m, &[], &RoundOptions::default()).unwrap();
        assert!(round.admitted.is_empty());
        assert!(round.release.is_none());
        assert_eq!(round.actions, vec![Action::Stay]);
        assert!((round.score - round.pre_rate).abs() < 1e-9);
        assert_eq!(round.stage, round.stage_before);
        assert!(round.sequential.is_none(), "replay is opt-in and offer-less here");
        // rendering covers the baseline + round rows
        assert_eq!(round_rows(&round).len(), 2);
    }

    #[test]
    fn release_fires_only_on_a_dominated_paid_rank() {
        // 4x A800 + 1x V100S whose spot price spiked: dropping it wins
        // on samples per dollar even after the reshard stall
        let m = preset("llama-0.5b").unwrap();
        let mut p = ElasticPlanner::new(1, 2048, &m.name, m.param_count(), 32);
        for gpu in ["A800-80G", "A800-80G", "A800-80G", "A800-80G", "V100S-32G"] {
            let slot = p.add_slot(gpu);
            if p.slots()[slot].curve.is_none() {
                p.install_curve(slot, truth(gpu, 1, 5), false).unwrap();
            }
        }
        let net = NetSim::from_link(5, LinkKind::Ib);
        p.replan(&net).unwrap();
        let opts = RoundOptions {
            consider_release: true,
            prices: vec![("V100S-32G".to_string(), 6.0)],
            ..Default::default()
        };
        let round = decide_round(&p, &net, &m, &[], &opts).unwrap();
        let r = round.release.as_ref().expect("the spiked rank must be released");
        assert_eq!(r.gpu, "V100S-32G");
        assert!(r.rel_gain_per_dollar > 0.0, "release only on strictly positive gain");
        assert!(r.rel_gain_per_dollar >= opts.min_gain);
        assert!(r.cost_per_ksample_after < r.cost_per_ksample_before);
        assert!(r.rate_after < round.pre_rate, "scale-down trades rate for $");
        assert!(round.actions.contains(&Action::Release { slot: r.slot }));

        // at fair prices the V100S is only marginally per-dollar
        // dominated (~3%): a 10% bar keeps every rank
        let fair = RoundOptions {
            consider_release: true,
            min_gain: 0.10,
            ..Default::default()
        };
        let round = decide_round(&p, &net, &m, &[], &fair).unwrap();
        assert!(round.release.is_none(), "no rank is 10% dominated at fair prices");
        assert_eq!(round.actions, vec![Action::Stay]);
    }

    /// A fleet that hosts longctx-0.4b solo (2x A800-80G at ZeRO-3),
    /// about to see offers that no ZeRO stage can host alone.
    fn planner_longctx() -> (ElasticPlanner, NetSim) {
        let m = preset("longctx-0.4b").unwrap();
        let mut p = ElasticPlanner::new(3, 512, &m.name, m.param_count(), 32);
        for gpu in ["A800-80G", "A800-80G"] {
            let slot = p.add_slot(gpu);
            if p.slots()[slot].curve.is_none() {
                p.install_curve(slot, synthesize_curve(gpu, &m, 3, 2).unwrap(), false)
                    .unwrap();
            }
        }
        let net = NetSim::from_link(2, LinkKind::Ib);
        p.replan(&net).unwrap();
        (p, net)
    }

    #[test]
    fn starved_offers_form_a_pipeline_group_when_allowed() {
        let (p, net) = planner_longctx();
        let m = preset("longctx-0.4b").unwrap();
        let offers: Vec<String> =
            ["T4", "T4", "T4", "V100S-32G"].iter().map(|s| s.to_string()).collect();
        // arm off (the default): memory-starved cards are hard declines
        let off = RoundOptions { min_gain: 0.001, ..Default::default() };
        let round = decide_round(&p, &net, &m, &offers, &off).unwrap();
        assert!(round.grouping.is_none());
        assert!(round.admitted.is_empty(), "no stage hosts these cards solo");
        // arm on: the round proposes ONE virtual DP rank over the quad
        let on =
            RoundOptions { min_gain: 0.001, allow_pipeline: true, ..Default::default() };
        let round = decide_round(&p, &net, &m, &offers, &on).unwrap();
        let gr = round.grouping.as_ref().expect("the starved quad must group");
        assert!(crate::pipeline::is_group_label(&gr.label));
        assert_eq!(gr.members.len(), 4);
        assert_eq!(gr.stage, 3, "priced at the incumbent stage");
        assert_eq!(gr.ks.iter().sum::<u64>(), m.n_layers);
        assert!(gr.rate > 0.0);
        assert!(gr.rel_gain >= on.min_gain);
        assert!(gr.ledger.profiling_est_s == 0.0, "composed curves need no Alg. 1");
        // advisory: member offers stay declined as solo ranks, but the
        // reason points at the group they would join
        assert!(round.admitted.is_empty());
        for v in &round.offers {
            assert!(matches!(v.action, Action::Decline { .. }));
            assert!(v.reason.contains(&gr.label), "reason must name the group: {}", v.reason);
        }
        // rendering gains the grouping row
        let rows = round_rows(&round);
        assert!(rows.iter().any(|r| r[0] == gr.label && r[2] == "group-admit"));
    }

    #[test]
    fn grouping_arm_is_inert_on_a_solo_feasible_fleet() {
        // singleton identity: when every offer fits some stage alone,
        // turning the pipeline arm on must not perturb the round at all
        let (p, net) = planner_c();
        let m = preset("llama-0.5b").unwrap();
        let offers = vec!["A800-80G".to_string(), "T4".to_string()];
        let off = RoundOptions { with_sequential: true, ..Default::default() };
        let on = RoundOptions {
            allow_pipeline: true,
            with_sequential: true,
            ..Default::default()
        };
        let r_off = decide_round(&p, &net, &m, &offers, &off).unwrap();
        let r_on = decide_round(&p, &net, &m, &offers, &on).unwrap();
        assert!(r_on.grouping.is_none(), "no starved offers, nothing to group");
        assert_eq!(round_rows(&r_off), round_rows(&r_on));
    }

    #[test]
    fn bad_options_and_unknown_types_are_typed_errors() {
        let (p, net) = planner_c();
        let m = preset("llama-0.5b").unwrap();
        let bad = RoundOptions { horizon_s: 0.0, ..Default::default() };
        assert!(matches!(
            decide_round(&p, &net, &m, &[], &bad),
            Err(AutoscaleError::BadOptions(_))
        ));
        let no_cap = RoundOptions { max_offers_per_round: 0, ..Default::default() };
        assert!(matches!(
            decide_round(&p, &net, &m, &[], &no_cap),
            Err(AutoscaleError::BadOptions(_))
        ));
        // forcing exhaustive enumeration past its bound is the one
        // oversize error left
        let forced = RoundOptions { search: SearchMode::Exhaustive, ..Default::default() };
        let many: Vec<String> =
            (0..=MAX_EXHAUSTIVE_OFFERS).map(|_| "T4".to_string()).collect();
        assert!(matches!(
            decide_round(&p, &net, &m, &many, &forced),
            Err(AutoscaleError::BadOptions(_))
        ));
        assert!(matches!(
            decide_round(&p, &net, &m, &["H100".to_string()], &RoundOptions::default()),
            Err(AutoscaleError::UnknownGpu(_))
        ));
        // a singleton "group" can never pipeline — reject the knob
        let tiny =
            RoundOptions { allow_pipeline: true, max_group_size: 1, ..Default::default() };
        assert!(matches!(
            decide_round(&p, &net, &m, &[], &tiny),
            Err(AutoscaleError::BadOptions(_))
        ));
    }

    #[test]
    fn oversized_batches_route_through_the_greedy_search() {
        // the PR-5 hard error is gone: a batch past the exhaustive bound
        // gets a verdict per offer (solo comparisons skipped), and the
        // strong members are still admitted
        let (mut p, net) = planner_c();
        let m = preset("llama-0.5b").unwrap();
        p.install_stage_curve("T4", 1, truth("T4", 1, 12)).unwrap();
        let offers: Vec<String> = ["A800-80G", "T4", "A800-80G", "T4", "A800-80G", "T4", "A800-80G"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let round =
            decide_round(&p, &net, &m, &offers, &RoundOptions::default()).unwrap();
        assert_eq!(round.offers.len(), offers.len());
        assert!(round.offers.iter().all(|v| v.solo.is_none()), "solo skipped on big batches");
        assert!(!round.admitted.is_empty(), "strong A800 offers must be admitted");
        assert!(round.rel_gain >= round.min_gain);
        // the soft cap bounds the admission set, never errors
        let capped = RoundOptions { max_offers_per_round: 2, ..Default::default() };
        let round = decide_round(&p, &net, &m, &offers, &capped).unwrap();
        assert!(round.admitted.len() <= 2);
        assert!(!round.admitted.is_empty());
    }
}
