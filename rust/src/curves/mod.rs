//! Per-GPU performance curves (paper §"Offline Analyzing").
//!
//! From the profiled `(batch, step_time)` points Poplar builds a
//! continuous speed-vs-batch curve with cubic-spline interpolation
//! (Fig. 7), then derives everything Alg. 2 needs:
//!
//! * `speed_at(b)` / `time_at(b)` — interpolated throughput / step time;
//! * `peak_speed()` and the *peak range* — the batch interval where the
//!   GPU is within `PEAK_THETA` of its best throughput (Poplar tries to
//!   keep every rank inside its range);
//! * `find(t)` — the paper's `find(g_i, t)`: the largest batch the GPU
//!   finishes within `t` seconds (ZeRO-2/3 t-sweep inner loop).

use crate::spline::CubicSpline;

/// Batch sizes within `PEAK_THETA * peak_speed` count as "at peak".
pub const PEAK_THETA: f64 = 0.95;

/// One profiled measurement.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ProfiledPoint {
    /// Micro-batch size.
    pub batch: usize,
    /// Measured (stage-aware) compute time for one step, seconds.
    pub step_time_s: f64,
}

/// Errors from curve fitting.
#[derive(Debug, PartialEq, Eq)]
pub enum CurveError {
    /// Need at least two distinct batch sizes.
    TooFewPoints,
    /// A non-positive time or batch was supplied.
    InvalidPoint,
}

impl std::fmt::Display for CurveError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CurveError::TooFewPoints => write!(f, "need >= 2 profiled points"),
            CurveError::InvalidPoint => write!(f, "batch and time must be positive"),
        }
    }
}

impl std::error::Error for CurveError {}

/// Interpolated speed-vs-batch performance curve for one GPU.
#[derive(Debug, Clone)]
pub struct PerfCurve {
    points: Vec<ProfiledPoint>,
    /// Maximum batch size that does not OOM (from Alg. 1).
    mbs: usize,
    speed: CubicSpline,
    peak_speed: f64,
    peak_lo: usize,
}

impl PerfCurve {
    /// Fit a curve from profiled points (sorted/deduped internally) and
    /// the discovered `mbs`.
    pub fn fit(mut points: Vec<ProfiledPoint>, mbs: usize) -> Result<Self, CurveError> {
        points.retain(|p| p.batch > 0 && p.batch <= mbs.max(1));
        points.sort_by_key(|p| p.batch);
        points.dedup_by_key(|p| p.batch);
        if points.len() < 2 {
            return Err(CurveError::TooFewPoints);
        }
        if points.iter().any(|p| p.step_time_s <= 0.0 || !p.step_time_s.is_finite()) {
            return Err(CurveError::InvalidPoint);
        }
        let xs: Vec<f64> = points.iter().map(|p| p.batch as f64).collect();
        let ys: Vec<f64> = points.iter().map(|p| p.batch as f64 / p.step_time_s).collect();
        let speed = CubicSpline::fit(&xs, &ys).map_err(|_| CurveError::InvalidPoint)?;

        // len >= 2 is checked above, so last() always yields a point
        let mbs = mbs.max(points.last().map_or(0, |p| p.batch));
        let mut peak_speed: f64 = 0.0;
        for b in 1..=mbs {
            peak_speed = peak_speed.max(Self::eval_speed(&speed, b as f64));
        }
        let mut peak_lo = mbs;
        for b in 1..=mbs {
            if Self::eval_speed(&speed, b as f64) >= PEAK_THETA * peak_speed {
                peak_lo = b;
                break;
            }
        }
        Ok(PerfCurve { points, mbs, speed, peak_speed, peak_lo })
    }

    fn eval_speed(spline: &CubicSpline, b: f64) -> f64 {
        // Clamp to the profiled domain: outside it the boundary cubic is
        // not trustworthy for a saturating curve.
        let (lo, hi) = spline.domain();
        spline.eval(b.clamp(lo, hi)).max(1e-9)
    }

    /// Interpolated throughput (samples/sec) at batch `b`.
    pub fn speed_at(&self, b: f64) -> f64 {
        Self::eval_speed(&self.speed, b)
    }

    /// Interpolated step time (seconds) at batch `b` (`b / speed(b)`,
    /// with the batch-proportional extension below the first knot).
    pub fn time_at(&self, b: f64) -> f64 {
        if b <= 0.0 {
            return 0.0;
        }
        b / self.speed_at(b)
    }

    /// Maximum batch size without OOM (Alg. 1 result).
    pub fn mbs(&self) -> usize {
        self.mbs
    }

    /// Best throughput over `1..=mbs` (the paper's `max(p_i)`).
    pub fn peak_speed(&self) -> f64 {
        self.peak_speed
    }

    /// `[lo, mbs]`: batch sizes within `PEAK_THETA` of peak throughput.
    pub fn peak_range(&self) -> (usize, usize) {
        (self.peak_lo, self.mbs)
    }

    /// The paper's `find(g, t)`: largest `b <= mbs` with `time(b) <= t`,
    /// or 0 if even batch 1 exceeds `t`. Linear scan — `mbs` is at most a
    /// few thousand and the sweep calls this with monotone-ish curves.
    pub fn find(&self, t: f64) -> usize {
        // time_at is (near-)monotone; binary search with a verification
        // scan at the boundary handles any spline wiggle.
        let (mut lo, mut hi) = (0usize, self.mbs);
        while lo < hi {
            let mid = (lo + hi + 1) / 2;
            if self.time_at(mid as f64) <= t {
                lo = mid;
            } else {
                hi = mid - 1;
            }
        }
        // guard against non-monotone wiggle: ensure chosen b really fits
        while lo > 0 && self.time_at(lo as f64) > t {
            lo -= 1;
        }
        lo
    }

    /// The profiled points the curve was fitted from.
    pub fn points(&self) -> &[ProfiledPoint] {
        &self.points
    }

    /// Root-mean-square relative error of the spline against a dense set
    /// of ground-truth `(batch, time)` pairs (Fig. 7's gap metric).
    pub fn rms_rel_error(&self, truth: &[(usize, f64)]) -> f64 {
        let mut acc = 0.0;
        let mut n = 0usize;
        for &(b, t_true) in truth {
            if b == 0 || b > self.mbs {
                continue;
            }
            let t_est = self.time_at(b as f64);
            acc += ((t_est - t_true) / t_true).powi(2);
            n += 1;
        }
        if n == 0 { 0.0 } else { (acc / n as f64).sqrt() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::catalog;
    use crate::config::model::preset;

    /// Ground-truth points from the device model (no noise).
    fn device_points(gpu: &str, every: usize, mbs: usize) -> Vec<ProfiledPoint> {
        let g = catalog::spec(gpu).unwrap();
        let m = preset("llama-0.5b").unwrap();
        (1..=mbs)
            .step_by(every)
            .map(|b| ProfiledPoint {
                batch: b,
                step_time_s: g.compute_time(
                    (b as u64 * m.seq) as f64,
                    m.flops_per_token(),
                    m.n_layers as usize,
                ),
            })
            .collect()
    }

    #[test]
    fn interpolates_profiled_points() {
        let pts = device_points("A100-80G", 3, 32);
        let c = PerfCurve::fit(pts.clone(), 32).unwrap();
        for p in &pts {
            let t = c.time_at(p.batch as f64);
            assert!((t - p.step_time_s).abs() / p.step_time_s < 1e-9);
        }
    }

    #[test]
    fn spline_close_to_truth_between_points_fig7() {
        // Fig. 7: gap between interpolated and actual ≈ 0.
        let sparse = device_points("A800-80G", 4, 48);
        let c = PerfCurve::fit(sparse, 48).unwrap();
        let dense: Vec<(usize, f64)> = device_points("A800-80G", 1, 48)
            .into_iter()
            .map(|p| (p.batch, p.step_time_s))
            .collect();
        let err = c.rms_rel_error(&dense);
        assert!(err < 0.02, "rms rel err {err}");
    }

    #[test]
    fn peak_range_is_at_the_top() {
        let pts = device_points("V100S-32G", 2, 40);
        let c = PerfCurve::fit(pts, 40).unwrap();
        let (lo, hi) = c.peak_range();
        assert_eq!(hi, 40);
        assert!(lo > 1, "saturating curve peaks late, lo={lo}");
        assert!(c.speed_at(lo as f64) >= PEAK_THETA * c.peak_speed() * 0.999);
    }

    #[test]
    fn find_inverts_time() {
        let pts = device_points("T4", 1, 24);
        let c = PerfCurve::fit(pts, 24).unwrap();
        for b in [1usize, 4, 9, 17, 24] {
            let t = c.time_at(b as f64);
            assert_eq!(c.find(t * 1.0001), b);
        }
        assert_eq!(c.find(1e-9), 0, "no batch fits an impossible budget");
        assert_eq!(c.find(1e9), 24, "everything fits a huge budget");
    }

    #[test]
    fn speed_monotone_for_saturating_device() {
        let pts = device_points("A100-80G", 2, 32);
        let c = PerfCurve::fit(pts, 32).unwrap();
        let mut prev = 0.0;
        for b in 1..=32 {
            let s = c.speed_at(b as f64);
            assert!(s >= prev * 0.995, "speed dip at b={b}");
            prev = s;
        }
    }

    #[test]
    fn rejects_degenerate_input() {
        assert_eq!(
            PerfCurve::fit(vec![ProfiledPoint { batch: 1, step_time_s: 0.1 }], 4).unwrap_err(),
            CurveError::TooFewPoints
        );
        let bad = vec![
            ProfiledPoint { batch: 1, step_time_s: -0.1 },
            ProfiledPoint { batch: 2, step_time_s: 0.2 },
        ];
        assert_eq!(PerfCurve::fit(bad, 4).unwrap_err(), CurveError::InvalidPoint);
    }

    #[test]
    fn out_of_domain_clamps() {
        let pts = device_points("A100-80G", 4, 32);
        let c = PerfCurve::fit(pts, 32).unwrap();
        // beyond mbs the speed stays at the boundary value
        let s32 = c.speed_at(32.0);
        assert_eq!(c.speed_at(100.0), s32);
    }
}
