//! Real heterogeneous data-parallel training over the PJRT runtime.
//!
//! This is the end-to-end validation path (DESIGN.md §6): the actual
//! JAX→HLO train step executes on the CPU PJRT client, while GPU
//! heterogeneity is *virtualized* — each rank has a slowdown factor and
//! a memory cap, and its measured wall time is scaled accordingly, so
//! Poplar's profiler/allocator see exactly the heterogeneous timings
//! they would on real mixed hardware (same code path, DESIGN.md §2).
//!
//! Numerics are genuinely data-parallel: every rank computes raw
//! gradients on its own micro-batches (`grad_b{B}` executable), the
//! leader weight-averages them by batch share (`Σ (b_i / gbs) · g_i` —
//! the exact gradient of the global mean loss; see
//! `test_weighted_grad_average_is_linear` in python), and one
//! `apply_update` steps the shared parameters.

use anyhow::{anyhow, bail, Result};
use std::path::Path;

use crate::allocator::Plan;
use crate::curves::{PerfCurve, ProfiledPoint};
use crate::data::{MicroBatch, TokenSource};
use crate::metrics::Timer;
use crate::runtime::{load_init_params, Engine};

/// A virtualized heterogeneous GPU on top of the real CPU executor.
#[derive(Debug, Clone)]
pub struct VirtualGpu {
    /// Display name (e.g. `"A800-80G(virt)"`).
    pub name: String,
    /// Wall-time multiplier vs the raw CPU step (>= 1 = slower GPU).
    pub slowdown: f64,
    /// Maximum micro-batch this virtual device may run (its memory cap).
    pub max_batch: usize,
}

/// Per-iteration training record.
#[derive(Debug, Clone)]
pub struct IterationLog {
    /// Iteration index.
    pub iter: usize,
    /// Global (batch-share-weighted) training loss.
    pub loss: f64,
    /// Simulated heterogeneous wall time (slowdown-scaled BSP max).
    pub sim_wall_s: f64,
    /// Real CPU seconds spent.
    pub real_wall_s: f64,
}

/// Decompose a batch into compiled variants, largest-first (PJRT
/// executables are shape-specialized; a rank whose plan says `b = 3`
/// runs `2 + 1` when only {1, 2, 4, 8} were compiled).
pub fn decompose_batch(b: usize, variants: &[usize]) -> Vec<usize> {
    let mut sorted: Vec<usize> = variants.to_vec();
    sorted.sort_unstable_by(|a, b| b.cmp(a));
    let mut rest = b;
    let mut out = Vec::new();
    for &v in &sorted {
        while rest >= v {
            out.push(v);
            rest -= v;
        }
    }
    assert_eq!(rest, 0, "variants must include 1 to decompose any batch");
    out
}

/// The real trainer: one PJRT engine + shared parameters.
pub struct Trainer {
    engine: Engine,
    params: Vec<Vec<f32>>,
    momenta: Vec<Vec<f32>>,
}

impl Trainer {
    /// Open artifacts and load the initial parameters.
    pub fn open(artifacts_dir: &Path) -> Result<Self> {
        let engine = Engine::open(artifacts_dir)?;
        let params = load_init_params(artifacts_dir, engine.meta())?;
        let momenta: Vec<Vec<f32>> = params.iter().map(|p| vec![0.0; p.len()]).collect();
        Ok(Trainer { engine, params, momenta })
    }

    /// The runtime engine (for metadata).
    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    /// Current parameters (ABI order).
    pub fn params(&self) -> &[Vec<f32>] {
        &self.params
    }

    /// Profile the *real* step time per compiled batch variant, scaled
    /// by each virtual GPU's slowdown — the e2e stand-in for Alg. 1's
    /// timing loop (the mbs search is the `max_batch` cap here).
    pub fn profile_virtual(
        &mut self,
        vgpus: &[VirtualGpu],
        source: &mut dyn TokenSource,
        reps: usize,
    ) -> Result<Vec<PerfCurve>> {
        let variants = self.engine.meta().batch_variants.clone();
        let seq1 = self.engine.meta().seq + 1;
        // measure raw CPU time once per variant, then scale per vgpu
        let mut raw: Vec<(usize, f64)> = Vec::new();
        for &b in &variants {
            // warm-up compiles the executable so timing is steady-state
            let tokens = source.batch(b, seq1);
            self.engine.run_grad_step(b, &self.params, &tokens)?;
            let t = Timer::start();
            for _ in 0..reps.max(1) {
                let tokens = source.batch(b, seq1);
                self.engine.run_grad_step(b, &self.params, &tokens)?;
            }
            raw.push((b, t.elapsed_s() / reps.max(1) as f64));
        }
        vgpus
            .iter()
            .map(|g| {
                let pts: Vec<ProfiledPoint> = raw
                    .iter()
                    .filter(|(b, _)| *b <= g.max_batch)
                    .map(|&(b, t)| ProfiledPoint { batch: b, step_time_s: t * g.slowdown })
                    .collect();
                if pts.len() < 2 {
                    bail!("vgpu {} has fewer than 2 feasible variants", g.name);
                }
                // non-empty (checked above), so max() always yields
                let mbs = pts.iter().map(|p| p.batch).max().unwrap_or(0);
                PerfCurve::fit(pts, mbs).map_err(|e| anyhow!("{}: {e}", g.name))
            })
            .collect()
    }

    /// One data-parallel iteration under `plan`: per-rank grad steps,
    /// weighted average, single optimizer update. Returns the global
    /// loss and the simulated heterogeneous wall time.
    pub fn train_iteration(
        &mut self,
        plan: &Plan,
        vgpus: &[VirtualGpu],
        batches: &[MicroBatch],
    ) -> Result<(f64, f64, f64)> {
        let n_params = self.params.len();
        let gbs: usize = batches.iter().map(|m| m.batch_size).sum();
        if gbs == 0 {
            bail!("empty iteration");
        }
        let variants = self.engine.meta().batch_variants.clone();
        let seq1 = self.engine.meta().seq + 1;

        let mut acc: Vec<Vec<f32>> =
            self.params.iter().map(|p| vec![0.0f32; p.len()]).collect();
        let mut loss_acc = 0.0f64;
        let mut rank_real: Vec<f64> = vec![0.0; plan.ranks.len()];
        let real_timer = Timer::start();

        // §Perf: parameters are frozen within the iteration — upload the
        // device buffers once and reuse them for every micro-step
        // (POPLAR_NO_DEVICE_PARAMS=1 restores the literal-per-step path
        // for A/B measurement; see EXPERIMENTS.md §Perf).
        let use_device_params = std::env::var_os("POPLAR_NO_DEVICE_PARAMS").is_none();
        let dev_params = if use_device_params {
            Some(self.engine.upload_params(&self.params)?)
        } else {
            None
        };

        for mb in batches {
            // shape-specialize: split into compiled variants
            let mut offset = 0usize;
            for b in decompose_batch(mb.batch_size, &variants) {
                let slice = &mb.tokens[offset * seq1..(offset + b) * seq1];
                let t = Timer::start();
                let out = match &dev_params {
                    Some(dp) => self.engine.run_grad_step_device(b, dp, slice)?,
                    None => self.engine.run_grad_step(b, &self.params, slice)?,
                };
                rank_real[mb.rank] += t.elapsed_s();
                let w = b as f32 / gbs as f32;
                for (a, g) in acc.iter_mut().zip(&out.grads) {
                    debug_assert_eq!(a.len(), g.len());
                    for (x, y) in a.iter_mut().zip(g) {
                        *x += w * y;
                    }
                }
                loss_acc += f64::from(out.loss) * f64::from(w);
                offset += b;
            }
        }

        self.engine
            .run_apply_update(&mut self.params, &mut self.momenta, &acc)?;
        debug_assert_eq!(acc.len(), n_params);

        // simulated heterogeneous wall: each rank's real time scaled by
        // its virtual slowdown, BSP max across ranks
        let sim_wall = rank_real
            .iter()
            .enumerate()
            .map(|(i, t)| t * vgpus.get(i).map_or(1.0, |g| g.slowdown))
            .fold(0.0, f64::max);
        Ok((loss_acc, sim_wall, real_timer.elapsed_s()))
    }

    /// Full training run: `iterations` iterations of `plan` over
    /// `source`, returning the loss curve.
    pub fn train(
        &mut self,
        plan: &Plan,
        vgpus: &[VirtualGpu],
        source: &mut dyn TokenSource,
        iterations: usize,
        log_every: usize,
    ) -> Result<Vec<IterationLog>> {
        let seq = self.engine.meta().seq;
        let mut loader = crate::data::DynamicLoader::new(AdapterSource(source), seq);
        let mut logs = Vec::with_capacity(iterations);
        for iter in 0..iterations {
            let batches = loader.iteration(plan);
            let (loss, sim_wall, real_wall) =
                self.train_iteration(plan, vgpus, &batches)?;
            if log_every > 0 && iter % log_every == 0 {
                eprintln!(
                    "[train] iter {iter:4}  loss {loss:.4}  sim_wall {sim_wall:.3}s  real {real_wall:.2}s"
                );
            }
            logs.push(IterationLog { iter, loss, sim_wall_s: sim_wall, real_wall_s: real_wall });
        }
        Ok(logs)
    }
}

/// Borrow-adapter so `DynamicLoader` can wrap a `&mut dyn TokenSource`.
struct AdapterSource<'a>(&'a mut dyn TokenSource);

impl TokenSource for AdapterSource<'_> {
    fn batch(&mut self, batch: usize, seq_plus_1: usize) -> Vec<i32> {
        self.0.batch(batch, seq_plus_1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decompose_greedy() {
        assert_eq!(decompose_batch(7, &[1, 2, 4]), vec![4, 2, 1]);
        assert_eq!(decompose_batch(4, &[1, 2, 4]), vec![4]);
        assert_eq!(decompose_batch(3, &[1, 2, 4, 8]), vec![2, 1]);
        assert_eq!(decompose_batch(0, &[1, 2]), Vec::<usize>::new());
    }

    #[test]
    #[should_panic(expected = "variants must include 1")]
    fn decompose_needs_unit() {
        decompose_batch(3, &[2]);
    }
}
