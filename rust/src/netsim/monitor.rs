//! Measured fabric bandwidth: EWMA per-link estimator + adaptation
//! state machine (AWStream-style Startup/Degrade/Steady/Probe).
//!
//! The spec sheet ([`LinkKind::bandwidth_gbs`]) is only a *prior*: real
//! clusters see contention, and a plan optimal at 10 GB/s is wrong at
//! 2 GB/s. [`BwMonitor`] owns the live estimate. It is fed one
//! effective-bandwidth sample per iteration, inverted from the observed
//! collective wall time via the α-β model (the α terms are
//! bandwidth-independent, so `bw = β_seconds_at_spec * spec / (observed
//! - α)` is exact, not a ratio heuristic — see
//! [`BwMonitor::sample_from_comm_times`]).
//!
//! State machine:
//!
//! * **Startup** — the first [`STARTUP_SAMPLES`] observations converge
//!   the estimate quickly off the spec prior (fast EWMA).
//! * **Steady** — in-band samples track with a slow EWMA. A single
//!   out-of-band sample moves *nothing*: only [`SUSTAIN_STREAK`]
//!   consecutive low samples count as congestion.
//! * **Degrade** — entered on sustained congestion; the estimate snaps
//!   down to the observed level immediately (stalls priced at stale
//!   bandwidth are how replans go wrong, so degrading is urgent).
//! * **Probe** — entered when sustained high samples say the fabric is
//!   recovering, or periodically (every [`PROBE_INTERVAL`] steady
//!   ticks) while the estimate sits below spec; climbs back toward
//!   spec with a fast EWMA, falling back to Degrade if contradicted.
//!
//! The estimate is invariant-bounded to `[min observed, spec]` — the
//! monitor never prices the fabric above the spec sheet and never below
//! the worst sample it has actually seen.
//!
//! Consumers never read the estimate directly on the replan path: they
//! take a [`NetSim`] snapshot via [`BwMonitor::snapshot`] (CI greps
//! that no raw `NetSim` literal exists outside `src/netsim/`).

use super::NetSim;
use crate::cluster::LinkKind;

/// Samples consumed by the fast-converging startup phase.
pub const STARTUP_SAMPLES: usize = 3;
/// Relative tolerance band around the estimate; a sample inside the band
/// is "in agreement". Matches `elastic::DEFAULT_DRIFT_THRESHOLD` so the
/// comm path reacts at the same sensitivity as the compute path.
pub const BW_TOLERANCE: f64 = 0.15;
/// Consecutive out-of-band samples required before the state machine
/// reacts — one noisy sample never moves the estimate or triggers a replan.
pub const SUSTAIN_STREAK: usize = 3;
/// Steady ticks below spec between optimistic upward probes.
pub const PROBE_INTERVAL: usize = 4;
/// Slow EWMA weight of the newest sample (Steady/Degrade tracking).
pub const EWMA_ALPHA: f64 = 0.3;
/// Fast EWMA weight (Startup convergence, Probe climb).
pub const FAST_ALPHA: f64 = 0.5;
/// Relative estimate shift (vs the last-signalled value) that emits a
/// [`BwShift`] — i.e. asks the consumer to replan.
pub const SHIFT_THRESHOLD: f64 = 0.15;

/// Adaptation state of the bandwidth estimator.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BwState {
    /// Converging off the spec prior (first few samples).
    Startup,
    /// Estimate agrees with recent samples; slow tracking.
    Steady,
    /// Sustained congestion detected; estimate snapped down, watching.
    Degrade,
    /// Optimistically climbing back toward spec bandwidth.
    Probe,
}

impl BwState {
    /// Stable lowercase name for tables and event logs.
    pub fn name(self) -> &'static str {
        match self {
            BwState::Startup => "startup",
            BwState::Steady => "steady",
            BwState::Degrade => "degrade",
            BwState::Probe => "probe",
        }
    }
}

/// A sustained bandwidth shift the consumer should replan on.
#[derive(Debug, Clone, PartialEq)]
pub struct BwShift {
    /// Link name ([`LinkKind::name`]) the estimate belongs to.
    pub link: String,
    /// New estimate relative to spec bandwidth (1.0 = at spec).
    pub factor: f64,
    /// New estimate in GB/s.
    pub est_gbs: f64,
}

/// EWMA bandwidth estimator + Startup/Degrade/Steady/Probe state machine
/// for one (bottleneck) link. See the module docs for the semantics.
#[derive(Debug, Clone)]
pub struct BwMonitor {
    link: String,
    spec_gbs: f64,
    alpha_s: f64,
    est_gbs: f64,
    min_observed_gbs: f64,
    state: BwState,
    samples: usize,
    low_streak: usize,
    high_streak: usize,
    in_band_streak: usize,
    steady_ticks: usize,
    signalled_gbs: f64,
}

impl BwMonitor {
    /// Monitor a link, seeding the estimate from its spec bandwidth.
    pub fn new(link: LinkKind) -> Self {
        Self::from_parts(link.bandwidth_gbs(), link.latency_s(), link.name())
    }

    /// Monitor an anonymous fabric given explicit spec numbers (the
    /// real-device path, where no `LinkKind` is known).
    pub fn from_parts(spec_gbs: f64, alpha_s: f64, link: &str) -> Self {
        BwMonitor {
            link: link.to_string(),
            spec_gbs,
            alpha_s,
            est_gbs: spec_gbs,
            min_observed_gbs: spec_gbs,
            state: BwState::Startup,
            samples: 0,
            low_streak: 0,
            high_streak: 0,
            in_band_streak: 0,
            steady_ticks: 0,
            signalled_gbs: spec_gbs,
        }
    }

    /// Derive a monitor from an existing cost-model snapshot (treats its
    /// bandwidth as the spec prior).
    pub fn from_netsim(net: &NetSim) -> Self {
        Self::from_parts(net.bw_gbs, net.alpha_s, "fabric")
    }

    /// Name of the monitored link (matches `bw:<link>:<factor>` events).
    pub fn link_name(&self) -> &str {
        &self.link
    }

    /// Spec-sheet bandwidth (the prior and the upper bound), GB/s.
    pub fn spec_gbs(&self) -> f64 {
        self.spec_gbs
    }

    /// Current bandwidth estimate, GB/s.
    pub fn estimate_gbs(&self) -> f64 {
        self.est_gbs
    }

    /// Lowest effective bandwidth ever observed (the lower bound), GB/s.
    pub fn min_observed_gbs(&self) -> f64 {
        self.min_observed_gbs
    }

    /// Current adaptation state.
    pub fn state(&self) -> BwState {
        self.state
    }

    /// Samples consumed so far.
    pub fn samples(&self) -> usize {
        self.samples
    }

    /// The planner-facing cost model at the *current estimate*.
    pub fn snapshot(&self, n: usize) -> NetSim {
        NetSim { n, bw_gbs: self.est_gbs, alpha_s: self.alpha_s }
    }

    /// The cost model at spec bandwidth (prediction baseline for sample
    /// inversion, and the sim substrate's pre-drift ground truth).
    pub fn spec_snapshot(&self, n: usize) -> NetSim {
        NetSim { n, bw_gbs: self.spec_gbs, alpha_s: self.alpha_s }
    }

    /// The sim substrate's ground-truth fabric: spec bandwidth scaled by
    /// the injected drift factor. Lives here so the sim never constructs
    /// a raw `NetSim` literal outside `src/netsim/`.
    pub fn ground_truth(&self, n: usize, factor: f64) -> NetSim {
        NetSim { n, bw_gbs: self.spec_gbs * factor, alpha_s: self.alpha_s }
    }

    /// Invert one iteration's collective wall time into an effective
    /// bandwidth sample. `pred_spec_s` is the predicted collective time
    /// at spec bandwidth, `alpha_s` its bandwidth-independent α share
    /// (both from [`BwMonitor::spec_snapshot`] pricing), `observed_s`
    /// the measured time. Exact under the α-β model:
    /// `observed = β/bw + α` with `β = (pred_spec - α) * spec`.
    ///
    /// Returns `None` when the iteration carries no byte term (ZeRO-3
    /// single rank, degenerate timings) — nothing to learn from.
    pub fn sample_from_comm_times(
        &self,
        pred_spec_s: f64,
        alpha_s: f64,
        observed_s: f64,
    ) -> Option<f64> {
        if !pred_spec_s.is_finite() || !alpha_s.is_finite() || !observed_s.is_finite() {
            return None;
        }
        let beta_s = pred_spec_s - alpha_s; // seconds the bytes take at spec
        let stretched = observed_s - alpha_s;
        if beta_s <= 0.0 || stretched <= 0.0 {
            return None;
        }
        Some(self.spec_gbs * beta_s / stretched)
    }

    /// Feed one effective-bandwidth sample (GB/s). Returns a [`BwShift`]
    /// when the estimate has moved enough (sustained, per the state
    /// machine) that incumbent plans should be re-priced.
    pub fn observe(&mut self, sample_gbs: f64) -> Option<BwShift> {
        if !sample_gbs.is_finite() || sample_gbs <= 0.0 {
            return None;
        }
        // the spec sheet is a hard ceiling: a "faster than spec" sample is
        // measurement noise, not capacity
        let sample = sample_gbs.min(self.spec_gbs);
        self.min_observed_gbs = self.min_observed_gbs.min(sample);
        self.samples += 1;

        let low = sample < self.est_gbs * (1.0 - BW_TOLERANCE);
        let high = sample > self.est_gbs * (1.0 + BW_TOLERANCE);
        self.low_streak = if low { self.low_streak + 1 } else { 0 };
        self.high_streak = if high { self.high_streak + 1 } else { 0 };
        self.in_band_streak = if low || high { 0 } else { self.in_band_streak + 1 };

        match self.state {
            BwState::Startup => {
                self.est_gbs = ewma(self.est_gbs, sample, FAST_ALPHA);
                if self.samples >= STARTUP_SAMPLES {
                    self.state = BwState::Steady;
                }
            }
            BwState::Steady => {
                if self.low_streak >= SUSTAIN_STREAK {
                    // sustained congestion: degrade to the observed level now
                    self.state = BwState::Degrade;
                    self.est_gbs = sample;
                    self.steady_ticks = 0;
                } else if !low && !high {
                    self.est_gbs = ewma(self.est_gbs, sample, EWMA_ALPHA);
                }
                // while parked below spec, probe upward on a fixed cadence
                if self.state == BwState::Steady
                    && self.est_gbs < self.spec_gbs * (1.0 - BW_TOLERANCE)
                {
                    self.steady_ticks += 1;
                    if self.steady_ticks >= PROBE_INTERVAL {
                        self.state = BwState::Probe;
                        self.steady_ticks = 0;
                    }
                } else {
                    self.steady_ticks = 0;
                }
            }
            BwState::Degrade => {
                self.est_gbs = ewma(self.est_gbs, sample, EWMA_ALPHA);
                if self.high_streak >= SUSTAIN_STREAK {
                    self.state = BwState::Probe; // fabric is recovering
                } else if self.in_band_streak >= SUSTAIN_STREAK {
                    self.state = BwState::Steady; // converged on the new level
                }
            }
            BwState::Probe => {
                // climb fast toward what the samples support…
                self.est_gbs = ewma(self.est_gbs, sample, FAST_ALPHA);
                if self.low_streak >= SUSTAIN_STREAK {
                    // …but a contradicted probe degrades right back
                    self.state = BwState::Degrade;
                    self.est_gbs = sample;
                } else if self.in_band_streak >= SUSTAIN_STREAK {
                    self.state = BwState::Steady;
                }
            }
        }

        // invariant: spec prior above, worst observation below
        self.est_gbs = self.est_gbs.clamp(self.min_observed_gbs, self.spec_gbs);

        // signal only when the estimate moved materially since the last
        // signal — the replan trigger, decoupled from per-sample jitter
        let rel = (self.est_gbs - self.signalled_gbs).abs() / self.signalled_gbs;
        if rel > SHIFT_THRESHOLD && self.state != BwState::Startup {
            self.signalled_gbs = self.est_gbs;
            return Some(BwShift {
                link: self.link.clone(),
                factor: self.est_gbs / self.spec_gbs,
                est_gbs: self.est_gbs,
            });
        }
        None
    }
}

fn ewma(prev: f64, sample: f64, alpha: f64) -> f64 {
    (1.0 - alpha) * prev + alpha * sample
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> f64 {
        LinkKind::Socket.bandwidth_gbs()
    }

    fn warmed() -> BwMonitor {
        let mut m = BwMonitor::new(LinkKind::Socket);
        for _ in 0..STARTUP_SAMPLES {
            m.observe(spec());
        }
        assert_eq!(m.state(), BwState::Steady);
        m
    }

    #[test]
    fn single_outlier_never_moves_estimate_or_signals() {
        let mut m = warmed();
        let before = m.estimate_gbs();
        assert!(m.observe(spec() * 0.1).is_none(), "one noisy sample must not signal");
        assert_eq!(m.estimate_gbs(), before, "one noisy sample must not move the estimate");
        assert!(m.observe(spec()).is_none());
        assert_eq!(m.state(), BwState::Steady);
    }

    #[test]
    fn sustained_congestion_degrades_and_signals() {
        let mut m = warmed();
        let mut shift = None;
        for _ in 0..SUSTAIN_STREAK {
            if let Some(s) = m.observe(spec() * 0.2) {
                shift = Some(s);
            }
        }
        let s = shift.expect("sustained congestion must signal a shift");
        assert_eq!(m.state(), BwState::Degrade);
        assert_eq!(s.link, "socket");
        assert!((s.factor - 0.2).abs() < 1e-9, "snap to observed level, got {}", s.factor);
        assert!((m.estimate_gbs() - spec() * 0.2).abs() < 1e-9);
    }

    #[test]
    fn recovery_probes_back_to_spec() {
        let mut m = warmed();
        for _ in 0..SUSTAIN_STREAK {
            m.observe(spec() * 0.2);
        }
        assert_eq!(m.state(), BwState::Degrade);
        // recovery: spec-level samples drive Degrade -> Probe -> Steady
        let mut signalled_up = false;
        for _ in 0..12 {
            if let Some(s) = m.observe(spec()) {
                signalled_up = signalled_up || s.factor > 0.2;
            }
        }
        assert!(signalled_up, "recovery must signal a replan");
        assert_eq!(m.state(), BwState::Steady);
        assert!(
            m.estimate_gbs() > spec() * (1.0 - BW_TOLERANCE),
            "probe should climb back near spec, got {}",
            m.estimate_gbs()
        );
    }

    #[test]
    fn steady_below_spec_probes_on_cadence() {
        let mut m = warmed();
        for _ in 0..SUSTAIN_STREAK {
            m.observe(spec() * 0.3);
        }
        // settle into Steady at the congested level
        for _ in 0..SUSTAIN_STREAK {
            m.observe(spec() * 0.3);
        }
        assert_eq!(m.state(), BwState::Steady);
        // keep feeding the congested level: the cadence alone must re-probe
        let mut probed = false;
        for _ in 0..(2 * PROBE_INTERVAL) {
            m.observe(spec() * 0.3);
            probed = probed || m.state() == BwState::Probe;
        }
        assert!(probed, "steady-below-spec must probe every {PROBE_INTERVAL} ticks");
    }

    #[test]
    fn estimate_bounded_by_min_observed_and_spec() {
        let mut m = BwMonitor::new(LinkKind::Ib);
        for s in [25.0, 3.0, 0.5, 40.0, 1.0, 19.0, 2.0, 0.7, 20.0] {
            m.observe(s);
            assert!(
                m.estimate_gbs() <= m.spec_gbs() + 1e-12
                    && m.estimate_gbs() >= m.min_observed_gbs() - 1e-12,
                "estimate {} outside [{}, {}]",
                m.estimate_gbs(),
                m.min_observed_gbs(),
                m.spec_gbs()
            );
        }
        // above-spec samples clamp: min_observed never exceeds spec
        assert!(m.min_observed_gbs() <= m.spec_gbs());
    }

    #[test]
    fn bad_samples_are_ignored() {
        let mut m = warmed();
        let before = m.estimate_gbs();
        for s in [f64::NAN, f64::INFINITY, -1.0, 0.0] {
            assert!(m.observe(s).is_none());
        }
        assert_eq!(m.estimate_gbs(), before);
        assert_eq!(m.samples(), STARTUP_SAMPLES);
    }

    #[test]
    fn sample_inversion_recovers_true_bandwidth() {
        let m = BwMonitor::new(LinkKind::Socket);
        let spec_net = m.spec_snapshot(8);
        let truth = m.ground_truth(8, 0.25);
        let p = 500_000_000u64;
        let pred = spec_net.iteration_comm_time(1, p).unwrap();
        let alpha = spec_net.iteration_comm_time(1, 0).unwrap(); // α-only share
        let obs = truth.iteration_comm_time(1, p).unwrap();
        let est = m.sample_from_comm_times(pred, alpha, obs).unwrap();
        assert!(
            (est - m.spec_gbs() * 0.25).abs() < 1e-9,
            "α-β inversion must be exact, got {est}"
        );
    }

    #[test]
    fn sample_inversion_rejects_degenerate_inputs() {
        let m = BwMonitor::new(LinkKind::Ib);
        assert_eq!(m.sample_from_comm_times(0.0, 0.0, 1.0), None); // no byte term
        assert_eq!(m.sample_from_comm_times(1.0, 0.1, 0.05), None); // obs < α
        assert_eq!(m.sample_from_comm_times(f64::NAN, 0.0, 1.0), None);
    }

    #[test]
    fn snapshot_carries_estimate_not_spec() {
        let mut m = warmed();
        for _ in 0..SUSTAIN_STREAK {
            m.observe(spec() * 0.2);
        }
        let snap = m.snapshot(8);
        assert_eq!(snap.n, 8);
        assert!((snap.bw_gbs - spec() * 0.2).abs() < 1e-9);
        assert_eq!(snap.alpha_s, LinkKind::Socket.latency_s());
        assert_eq!(m.spec_snapshot(8).bw_gbs, spec());
    }
}
