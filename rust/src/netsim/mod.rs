//! Network + collective-communication substrate.
//!
//! Replaces NCCL over NVLink/PCIe/IB with α-β ring cost models (Patarasuk
//! & Yuan 2009; Thakur et al. 2005) — the same models the paper's
//! analysis assumes. A collective over the whole data-parallel group is
//! bottlenecked by the slowest link on the ring (paper appendix).
//!
//! The paper's ZeRO-3 FFN communication identity
//! `Comm_volume = 24 d h^2` (all-gather fwd + all-gather bwd +
//! reduce-scatter bwd over the two h×4h matrices) is reproduced by
//! [`zero3_ffn_comm_volume`] and unit-tested below.
//!
//! Bandwidth is a *measured* quantity, not a construction-time constant:
//! [`BwMonitor`] (see [`monitor`]) owns a drifting per-link estimate fed
//! by observed collective times, and `NetSim` is the snapshot consumers
//! price with ([`BwMonitor::snapshot`]). Construct `NetSim` only through
//! `from_cluster` / `from_link` / the monitor — CI rejects raw literals
//! outside this directory.

use crate::allocator::PlanError;
use crate::cluster::{ClusterSpec, LinkKind};

pub mod monitor;

pub use monitor::{BwMonitor, BwShift, BwState};


/// Collective operation kinds used by ZeRO stages.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Collective {
    /// Ring all-reduce: reduce-scatter + all-gather (ZeRO-0 gradients).
    AllReduce,
    /// Ring all-gather (ZeRO-1/2 param refresh, ZeRO-3 weight fetch).
    AllGather,
    /// Ring reduce-scatter (ZeRO-2/3 gradient partitioning).
    ReduceScatter,
    /// One-to-all broadcast (plan distribution — tiny).
    Broadcast,
}

/// Cost model for collectives over a cluster.
#[derive(Debug, Clone)]
pub struct NetSim {
    /// Number of ranks in the data-parallel group.
    pub n: usize,
    /// Effective unidirectional bandwidth of the bottleneck link (GB/s).
    pub bw_gbs: f64,
    /// Per-hop latency of the bottleneck link (seconds).
    pub alpha_s: f64,
}

impl NetSim {
    /// Build the cost model from a cluster spec.
    ///
    /// **Bottleneck-link rule** (paper appendix): a ring collective over
    /// the whole data-parallel group crosses every link on the ring, so
    /// the *slowest* one prices the collective — the inter-node link
    /// when the cluster spans ≥ 2 non-empty groups (regardless of how
    /// fast any intra-node NVLink is), else the single group's
    /// intra-node link. See [`ClusterSpec::bottleneck_link`]; pinned by
    /// `mixed_nvlink_socket_prices_at_socket` below.
    pub fn from_cluster(cluster: &ClusterSpec) -> Self {
        let link = cluster.bottleneck_link();
        NetSim::from_link(cluster.n_gpus(), link)
    }

    /// Build from an explicit rank count and link kind.
    pub fn from_link(n: usize, link: LinkKind) -> Self {
        NetSim { n, bw_gbs: link.bandwidth_gbs(), alpha_s: link.latency_s() }
    }

    /// Time (seconds) for a point-to-point transfer of `bytes` between
    /// two adjacent ranks: one link crossing, one α. This is the hop
    /// cost the `pipeline` module charges for forwarding boundary
    /// activations between adjacent pipeline stages inside a virtual
    /// rank — no ring term, because a pipeline hop is a single edge,
    /// not a whole-group collective.
    pub fn p2p_time(&self, bytes: u64) -> f64 {
        bytes as f64 / (self.bw_gbs * 1e9) + self.alpha_s
    }

    /// Time (seconds) for a collective moving `bytes` of payload.
    ///
    /// Ring costs for n ranks (V = payload bytes):
    ///   all-gather / reduce-scatter: (n-1)/n * V / BW  + (n-1) α
    ///   all-reduce:                2 (n-1)/n * V / BW + 2 (n-1) α
    ///   broadcast (tree):            V / BW * ceil(log2 n) + α log2 n
    pub fn time(&self, op: Collective, bytes: u64) -> f64 {
        let n = self.n as f64;
        if self.n <= 1 {
            return 0.0;
        }
        let v = bytes as f64;
        let bw = self.bw_gbs * 1e9;
        match op {
            Collective::AllGather | Collective::ReduceScatter => {
                (n - 1.0) / n * v / bw + (n - 1.0) * self.alpha_s
            }
            Collective::AllReduce => {
                2.0 * (n - 1.0) / n * v / bw + 2.0 * (n - 1.0) * self.alpha_s
            }
            Collective::Broadcast => {
                // tree depth, computed once for both the byte and α terms
                let hops = n.log2().ceil();
                v / bw * hops + self.alpha_s * hops
            }
        }
    }

    /// Per-micro-step communication time for a ZeRO stage, given the
    /// model's parameter count (fp16 wire format, 2 bytes/param):
    ///
    /// * ZeRO-0/1 communicate only once per *iteration* (gradient
    ///   all-reduce / reduce-scatter+all-gather at the sync point) —
    ///   returns 0 here; use [`iteration_comm_time`].
    /// * ZeRO-2: each micro-step's backward ends in a gradient
    ///   reduce-scatter.
    /// * ZeRO-3: all-gather (fwd) + all-gather (bwd) + reduce-scatter
    ///   (bwd) per micro-step.
    ///
    /// A stage outside 0..=3 is a typed error, mirroring the allocator:
    /// the stage reaches here from config/CLI via `Plan.stage` (a `pub`
    /// field), so a corrupt value must surface, not panic mid-job.
    pub fn per_microstep_comm_time(&self, stage: u8, param_count: u64) -> Result<f64, PlanError> {
        let bytes = 2 * param_count; // fp16 wire
        match stage {
            0 | 1 => Ok(0.0),
            2 => Ok(self.time(Collective::ReduceScatter, bytes)),
            3 => Ok(2.0 * self.time(Collective::AllGather, bytes)
                + self.time(Collective::ReduceScatter, bytes)),
            _ => Err(PlanError::InvalidStage(stage)),
        }
    }

    /// Per-iteration (sync-point) communication time for a ZeRO stage.
    ///
    /// * ZeRO-0: gradient all-reduce.
    /// * ZeRO-1: gradient reduce-scatter at sync + param all-gather after
    ///   the optimizer step (equivalent volume to all-reduce).
    /// * ZeRO-2: param all-gather after the optimizer step (the gradient
    ///   reduce-scatter already happened per micro-step).
    /// * ZeRO-3: nothing extra (params stay sharded).
    ///
    /// Invalid stages error like [`NetSim::per_microstep_comm_time`].
    pub fn iteration_comm_time(&self, stage: u8, param_count: u64) -> Result<f64, PlanError> {
        let bytes = 2 * param_count;
        match stage {
            0 => Ok(self.time(Collective::AllReduce, bytes)),
            1 => Ok(self.time(Collective::ReduceScatter, bytes)
                + self.time(Collective::AllGather, bytes)),
            2 => Ok(self.time(Collective::AllGather, bytes)),
            3 => Ok(0.0),
            _ => Err(PlanError::InvalidStage(stage)),
        }
    }
}

/// The paper's appendix identity: ZeRO-3 communication volume for one FFN
/// with hidden size `h`, intermediate `4h`, over `d` devices, in elements:
/// `24 * d * h^2`.
pub fn zero3_ffn_comm_volume(h: u64, d: u64) -> u64 {
    let w = 2 * (h * 4 * h); // the two weight matrices, elements
    let all_gather_fwd = w * d;
    let all_gather_bwd = w * d;
    let reduce_scatter_bwd = w * d;
    all_gather_fwd + all_gather_bwd + reduce_scatter_bwd
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster;

    #[test]
    fn paper_ffn_comm_identity() {
        // Comm_volume = 24 d h^2 (paper appendix)
        for (h, d) in [(1024u64, 4u64), (2048, 8), (4096, 3)] {
            assert_eq!(zero3_ffn_comm_volume(h, d), 24 * d * h * h);
        }
    }

    #[test]
    fn allreduce_is_rs_plus_ag() {
        let net = NetSim::from_link(8, LinkKind::Pcie);
        let v = 1 << 30;
        let ar = net.time(Collective::AllReduce, v);
        let rs = net.time(Collective::ReduceScatter, v);
        let ag = net.time(Collective::AllGather, v);
        assert!((ar - (rs + ag)).abs() < 1e-12);
    }

    #[test]
    fn p2p_is_one_link_crossing() {
        // a pipeline hop pays exactly bytes/BW + one α — no (n-1)/n ring
        // term, and no dependence on the group size at all
        let v: u64 = 1 << 30;
        let net2 = NetSim::from_link(2, LinkKind::Ib);
        let net8 = NetSim::from_link(8, LinkKind::Ib);
        assert_eq!(net2.p2p_time(v), net8.p2p_time(v));
        let expect = v as f64 / (LinkKind::Ib.bandwidth_gbs() * 1e9) + LinkKind::Ib.latency_s();
        assert!((net2.p2p_time(v) - expect).abs() < 1e-12);
        // and it undercuts the same payload's all-gather on the ring
        assert!(net8.p2p_time(v) < net8.time(Collective::AllGather, v));
    }

    #[test]
    fn single_rank_is_free() {
        let net = NetSim::from_link(1, LinkKind::Socket);
        assert_eq!(net.time(Collective::AllReduce, 1 << 30), 0.0);
    }

    #[test]
    fn comm_time_scales_with_bytes_and_inversely_with_bw() {
        let fast = NetSim::from_link(4, LinkKind::Nvlink);
        let slow = NetSim::from_link(4, LinkKind::Socket);
        let v = 1 << 28;
        assert!(slow.time(Collective::AllGather, v) > fast.time(Collective::AllGather, v) * 10.0);
        assert!(
            fast.time(Collective::AllGather, 2 * v) > fast.time(Collective::AllGather, v) * 1.9
        );
    }

    #[test]
    fn ring_term_grows_with_ranks() {
        let v = 1 << 30;
        let t4 = NetSim::from_link(4, LinkKind::Pcie).time(Collective::AllGather, v);
        let t8 = NetSim::from_link(8, LinkKind::Pcie).time(Collective::AllGather, v);
        // (n-1)/n grows: 0.75 -> 0.875
        assert!(t8 > t4);
    }

    #[test]
    fn stage_comm_structure() {
        let net = NetSim::from_link(8, LinkKind::Ib);
        let p = 500_000_000;
        // per-micro-step: z3 > z2 > z1 = z0 = 0
        assert_eq!(net.per_microstep_comm_time(0, p).unwrap(), 0.0);
        assert_eq!(net.per_microstep_comm_time(1, p).unwrap(), 0.0);
        let z2 = net.per_microstep_comm_time(2, p).unwrap();
        let z3 = net.per_microstep_comm_time(3, p).unwrap();
        assert!(z3 > 2.5 * z2, "z3 should be ~3x z2's RS cost");
        // per-iteration: z0 = AR, z3 = 0
        assert!(net.iteration_comm_time(0, p).unwrap() > 0.0);
        assert_eq!(net.iteration_comm_time(3, p).unwrap(), 0.0);
    }

    #[test]
    fn cluster_bottleneck_feeds_netsim() {
        let net = NetSim::from_cluster(&cluster::cluster_a());
        assert_eq!(net.n, 8);
        assert_eq!(net.bw_gbs, LinkKind::Ib.bandwidth_gbs());
    }

    #[test]
    fn mixed_nvlink_socket_prices_at_socket() {
        // The bottleneck-link rule: two NVLink islands joined by sockets
        // price every whole-group collective at the socket link — 300 GB/s
        // inside the nodes buys nothing on the ring.
        let c = ClusterSpec::new(
            "nvlink-islands",
            &[("A100-80G", 4, LinkKind::Nvlink), ("A100-80G", 4, LinkKind::Nvlink)],
            LinkKind::Socket,
        );
        assert_eq!(c.bottleneck_link(), LinkKind::Socket);
        let net = NetSim::from_cluster(&c);
        assert_eq!(net.bw_gbs, LinkKind::Socket.bandwidth_gbs());
        assert_eq!(net.alpha_s, LinkKind::Socket.latency_s());
        // and the pricing really is socket-grade: ~150x slower than the
        // same collective would be at NVLink bandwidth
        let v = 1 << 30;
        let nv = NetSim::from_link(8, LinkKind::Nvlink).time(Collective::AllGather, v);
        assert!(net.time(Collective::AllGather, v) > 100.0 * nv);
    }

    #[test]
    fn invalid_stage_is_typed_error_not_panic() {
        // the same input the allocator rejects with PlanError::InvalidStage
        // must not panic here either (PR 2 hardened the allocator; this
        // closes the netsim half)
        let net = NetSim::from_link(4, LinkKind::Ib);
        for bad in [4u8, 7, 255] {
            assert_eq!(
                net.per_microstep_comm_time(bad, 1).unwrap_err(),
                PlanError::InvalidStage(bad)
            );
            assert_eq!(
                net.iteration_comm_time(bad, 1).unwrap_err(),
                PlanError::InvalidStage(bad)
            );
        }
    }
}
