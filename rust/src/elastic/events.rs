//! Elastic cluster events and deterministic schedules.
//!
//! Events model the membership/behaviour changes a heterogeneous fleet
//! actually exhibits mid-training: preemption (`RankLost`), capacity
//! arriving (`RankJoined`), stragglers (`RankSlowed`) and fabric
//! congestion (`BwDrift`). Schedules are either written explicitly
//! (config / CLI) or generated from a seed — both paths are fully
//! deterministic so every elastic run is replayable.

use crate::cluster::LinkKind;

/// One elastic cluster event.
#[derive(Debug, Clone, PartialEq)]
pub enum ElasticEvent {
    /// The worker at `slot` leaves the job (preemption, crash).
    RankLost {
        /// Leader slot id of the departing rank.
        slot: usize,
    },
    /// A GPU of catalog type `gpu` joins the job as a new rank.
    RankJoined {
        /// Catalog GPU name, e.g. `"V100S-32G"`.
        gpu: String,
    },
    /// The worker at `slot` silently slows down by `factor` (thermal
    /// throttling, a noisy neighbour). Deliberately *not* announced to
    /// the planner: only drift detection can discover it.
    RankSlowed {
        /// Leader slot id of the straggler.
        slot: usize,
        /// Compute-time multiplier, `> 1.0` means slower.
        factor: f64,
    },
    /// The named fabric link's effective bandwidth shifts to
    /// `factor × spec` (congestion when `< 1.0`, recovery when back at
    /// `1.0`). Like `RankSlowed`, this is ground truth the planner is
    /// *not* told about: only the `netsim::BwMonitor`'s observed
    /// collective times can discover it.
    BwDrift {
        /// Link name per `LinkKind::name`, e.g. `"socket"`.
        link: String,
        /// Bandwidth multiplier vs spec, `(0, 1]` in practice.
        factor: f64,
    },
}

impl ElasticEvent {
    /// Short human-readable label for reports.
    pub fn label(&self) -> String {
        match self {
            ElasticEvent::RankLost { slot } => format!("lost(slot={slot})"),
            ElasticEvent::RankJoined { gpu } => format!("joined({gpu})"),
            ElasticEvent::RankSlowed { slot, factor } => {
                format!("slowed(slot={slot},x{factor:.2})")
            }
            ElasticEvent::BwDrift { link, factor } => format!("bw:{link}:{factor:.2}"),
        }
    }
}

/// An event pinned to a training iteration (applied before it runs).
#[derive(Debug, Clone, PartialEq)]
pub struct ScheduledEvent {
    /// Iteration index the event fires before.
    pub at_iter: usize,
    /// The event.
    pub event: ElasticEvent,
}

/// Deterministic xorshift generator (same discipline as the property
/// tests: replayable from a single seed).
#[derive(Debug, Clone)]
pub struct XorShift(u64);

impl XorShift {
    /// Seeded generator; any seed works, including 0.
    pub fn new(seed: u64) -> Self {
        XorShift(seed.wrapping_mul(0x9E3779B97F4A7C15) | 1)
    }

    /// Next raw 64-bit value.
    #[allow(clippy::should_implement_trait)]
    pub fn next(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.0 = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform integer in `[lo, hi]`.
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.next() % (hi - lo + 1)
    }

    /// Uniform float in `[0, 1)`.
    pub fn uniform(&mut self) -> f64 {
        (self.next() >> 11) as f64 / (1u64 << 53) as f64
    }
}

/// Generate a seeded random event schedule over `iters` iterations for a
/// job that starts with slots `0..n_slots`. Guarantees:
///
/// * at most one event per iteration, none before iteration 1;
/// * never loses a slot that a previous event already lost;
/// * never schedules losses that would leave fewer than 2 live ranks;
/// * joined GPUs are drawn from `gpu_pool`.
pub fn seeded_schedule(
    seed: u64,
    iters: usize,
    n_slots: usize,
    gpu_pool: &[&str],
) -> Vec<ScheduledEvent> {
    let mut rng = XorShift::new(seed);
    let mut out = Vec::new();
    let mut alive: Vec<usize> = (0..n_slots).collect();
    let mut next_slot = n_slots;
    for at_iter in 1..iters {
        if alive.is_empty() || rng.uniform() > 0.35 {
            continue; // quiet iteration
        }
        let kind = rng.range(0, 2);
        match kind {
            0 if alive.len() > 2 => {
                let idx = rng.range(0, alive.len() as u64 - 1) as usize;
                let slot = alive.remove(idx);
                out.push(ScheduledEvent { at_iter, event: ElasticEvent::RankLost { slot } });
            }
            1 if !gpu_pool.is_empty() => {
                let gpu = gpu_pool[(rng.next() as usize) % gpu_pool.len()].to_string();
                alive.push(next_slot);
                next_slot += 1;
                out.push(ScheduledEvent { at_iter, event: ElasticEvent::RankJoined { gpu } });
            }
            _ => {
                let idx = rng.range(0, alive.len() as u64 - 1) as usize;
                let factor = 1.5 + rng.uniform() * 2.0;
                out.push(ScheduledEvent {
                    at_iter,
                    event: ElasticEvent::RankSlowed { slot: alive[idx], factor },
                });
            }
        }
    }
    out
}

/// Parse a compact CLI schedule: comma-separated
/// `ITER:lost:SLOT | ITER:join:GPU | ITER:slow:SLOT:FACTOR |
/// ITER:bw:LINK:FACTOR`.
pub fn parse_schedule(s: &str) -> Result<Vec<ScheduledEvent>, String> {
    let mut out = Vec::new();
    for item in s.split(',').filter(|x| !x.trim().is_empty()) {
        let parts: Vec<&str> = item.trim().split(':').collect();
        let bad = || {
            format!(
                "bad event {item:?} (want ITER:lost:SLOT, ITER:join:GPU, \
                 ITER:slow:SLOT:FACTOR or ITER:bw:LINK:FACTOR)"
            )
        };
        if parts.len() < 3 {
            return Err(bad());
        }
        let at_iter: usize = parts[0].parse().map_err(|_| bad())?;
        let event = match parts[1] {
            "lost" => ElasticEvent::RankLost { slot: parts[2].parse().map_err(|_| bad())? },
            "join" => ElasticEvent::RankJoined { gpu: parts[2].to_string() },
            "slow" => {
                if parts.len() != 4 {
                    return Err(bad());
                }
                let factor: f64 = parts[3].parse().map_err(|_| bad())?;
                if !factor.is_finite() || factor <= 0.0 {
                    return Err(format!("slowdown factor must be finite and > 0, got {factor}"));
                }
                ElasticEvent::RankSlowed { slot: parts[2].parse().map_err(|_| bad())?, factor }
            }
            "bw" => {
                if parts.len() != 4 {
                    return Err(bad());
                }
                if LinkKind::parse(parts[2]).is_none() {
                    return Err(format!(
                        "unknown link kind {:?} in bw event (want nvlink, nvlink-capped, \
                         pcie, ib or socket)",
                        parts[2]
                    ));
                }
                let factor: f64 = parts[3].parse().map_err(|_| bad())?;
                if !factor.is_finite() || factor <= 0.0 {
                    return Err(format!("bandwidth factor must be finite and > 0, got {factor}"));
                }
                ElasticEvent::BwDrift { link: parts[2].to_string(), factor }
            }
            _ => return Err(bad()),
        };
        out.push(ScheduledEvent { at_iter, event });
    }
    out.sort_by_key(|e| e.at_iter);
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seeded_schedule_is_deterministic() {
        let a = seeded_schedule(7, 20, 4, &["T4", "A800-80G"]);
        let b = seeded_schedule(7, 20, 4, &["T4", "A800-80G"]);
        assert_eq!(a, b);
        let c = seeded_schedule(8, 20, 4, &["T4", "A800-80G"]);
        assert!(a != c || a.is_empty());
    }

    #[test]
    fn seeded_schedule_never_double_loses() {
        for seed in 0..50u64 {
            let sched = seeded_schedule(seed, 40, 5, &["T4"]);
            let mut lost = std::collections::HashSet::new();
            for ev in &sched {
                if let ElasticEvent::RankLost { slot } = ev.event {
                    assert!(lost.insert(slot), "seed {seed}: slot {slot} lost twice");
                }
            }
        }
    }

    #[test]
    fn parse_schedule_roundtrip() {
        let s = parse_schedule("4:lost:7, 6:slow:0:2.5 ,8:join:A800-80G").unwrap();
        assert_eq!(s.len(), 3);
        assert_eq!(s[0], ScheduledEvent { at_iter: 4, event: ElasticEvent::RankLost { slot: 7 } });
        assert_eq!(
            s[1],
            ScheduledEvent {
                at_iter: 6,
                event: ElasticEvent::RankSlowed { slot: 0, factor: 2.5 }
            }
        );
        assert_eq!(
            s[2],
            ScheduledEvent { at_iter: 8, event: ElasticEvent::RankJoined { gpu: "A800-80G".into() } }
        );
        assert!(parse_schedule("nope").is_err());
        assert!(parse_schedule("1:slow:0").is_err());
        assert!(parse_schedule("1:slow:0:0").is_err(), "zero factor would panic the worker");
        assert!(parse_schedule("1:slow:0:-2").is_err());
        assert!(parse_schedule("1:slow:0:nan").is_err());
        assert!(parse_schedule("").unwrap().is_empty());
    }

    #[test]
    fn parse_schedule_bw_events() {
        let s = parse_schedule("3:bw:socket:0.25, 9:bw:ib:1.0").unwrap();
        assert_eq!(
            s[0],
            ScheduledEvent {
                at_iter: 3,
                event: ElasticEvent::BwDrift { link: "socket".into(), factor: 0.25 }
            }
        );
        assert_eq!(
            s[1],
            ScheduledEvent {
                at_iter: 9,
                event: ElasticEvent::BwDrift { link: "ib".into(), factor: 1.0 }
            }
        );
        assert_eq!(s[0].event.label(), "bw:socket:0.25");
    }

    #[test]
    fn parse_schedule_rejects_bad_bw_events() {
        // bandwidth factors validated exactly like slowdown factors
        assert!(parse_schedule("1:bw:socket:0").is_err());
        assert!(parse_schedule("1:bw:socket:-0.5").is_err());
        assert!(parse_schedule("1:bw:socket:nan").is_err());
        assert!(parse_schedule("1:bw:socket:inf").is_err());
        assert!(parse_schedule("1:bw:socket").is_err(), "missing factor");
        assert!(parse_schedule("1:bw:ethernet:0.5").is_err(), "unknown link kind");
    }
}
