//! Replan-time ZeRO-stage re-selection (stage migration).
//!
//! Poplar's Alg. 1/2 pick a ZeRO stage once (escalating only when batch
//! 1 OOMs) and never revisit it — but the elastic runtime changes the
//! fleet underneath that choice. After a membership event the stage the
//! job escalated to at startup can be either *infeasible* (a loss grows
//! every survivor's `12ψ/n` optimizer shard past its memory) or
//! *needlessly slow* (a high-memory join lets ZeRO-3 de-escalate to
//! ZeRO-1 and drop the per-micro-step collective traffic entirely).
//!
//! With a [`StagePolicy`] installed, every replan re-decides the stage:
//!
//! * **candidates** — each stage 0..=3 is checked against the Alg. 1
//!   memory bound at the *new* group size (every live rank must fit at
//!   least one sample, [`crate::memmodel::true_mbs`]);
//! * **curves** — the `(gpu, model, stage)` cache is already
//!   stage-keyed: cached curves are reused as-is, and only missing
//!   `(type, stage)` pairs need an incremental Alg. 1 run
//!   ([`ElasticPlanner::stage_profile_requests`] names them; until
//!   they are measured, a catalog-FLOPs estimate scores the candidate
//!   — estimate-based stages are never switched to outright, mirroring
//!   the autoscale defer rule);
//! * **decision** — the shared amortized-scoring kernel
//!   ([`crate::policy::amortized_score`]): each candidate's stall
//!   ledger itemizes the `ckpt::migrate` transfer plus the estimated
//!   Alg. 1 cost of its uncached `(type, stage)` pairs, the kernel
//!   turns that into effective samples/s over the candidate's expected
//!   tenure, and the job migrates only on a strict improvement over
//!   the incumbent. Between the partitioned
//!   stages the optimizer tiling is identical, so a 3→1 de-escalation
//!   costs only the membership reshard; escalating *to* ZeRO-0 pays the
//!   full replication broadcast ([`crate::ckpt::migrate`]).
//!
//! An infeasible incumbent (the "loss shrank aggregate memory" case)
//! scores below every feasible candidate, so the search escalates away
//! from it as soon as any measured alternative exists.
//!
//! Straggler caveat: drift overrides are rank-local curves measured at
//! the *current* stage; candidate stages are scored with healthy
//! type-level curves, so a heavily drifted rank biases the comparison
//! in the candidates' favor until its drift is re-measured there. On an
//! actual switch, though, the live drift factor is carried over: the
//! straggler's slot gets the new stage's healthy curve scaled by its
//! observed slowdown (still flagged as an override), not a silent reset
//! to the healthy type curve.

use crate::allocator::{self, predicted_wall_s};
use crate::autoscale::{profile_cost_estimate_s, synthesize_curve, DEFAULT_HORIZON_S};
use crate::ckpt::{self, ShardManifest};
use crate::policy::{amortized_score, StallLedger};
use crate::cluster::catalog;
use crate::config::model::{preset, ModelSpec};
use crate::curves::PerfCurve;
use crate::memmodel;
use crate::netsim::NetSim;

use super::{CurveKey, ElasticError, ElasticPlanner};

/// Knobs of the replan-time stage search (`[elastic] allow_stage_change`
/// turns it on; the horizon follows `[autoscale] horizon_s` when both
/// are configured).
#[derive(Debug, Clone, PartialEq)]
pub struct StagePolicy {
    /// Amortization horizon in seconds: the expected time until the next
    /// membership event re-prices everything (same semantics as
    /// `[autoscale] horizon_s`).
    pub horizon_s: f64,
}

impl Default for StagePolicy {
    fn default() -> Self {
        StagePolicy { horizon_s: DEFAULT_HORIZON_S }
    }
}

/// A stage migration the latest replan performed.
#[derive(Debug, Clone, PartialEq)]
pub struct StageChange {
    /// Stage before the replan.
    pub from: u8,
    /// Stage after the replan.
    pub to: u8,
    /// Priced one-shot migration transfer (seconds, membership movement
    /// folded in).
    pub migration_s: f64,
    /// Optimizer-state bytes the migration moves.
    pub migration_bytes: u64,
}

/// One evaluated candidate stage of the replan-time search.
#[derive(Debug, Clone)]
pub struct StageCandidate {
    /// ZeRO stage evaluated.
    pub stage: u8,
    /// True for the incumbent (the stage the job currently runs at).
    pub current: bool,
    /// Alg. 1 memory bound holds for every live rank at the group size.
    pub feasible: bool,
    /// Every live type has a *measured* curve at this stage (the
    /// incumbent always does); false means the rate is a catalog-FLOPs
    /// estimate and the stage is never switched to before profiling.
    pub curves_cached: bool,
    /// Predicted steady-state samples/s (0 when not plannable).
    pub rate_sps: f64,
    /// One-shot `ckpt::migrate` transfer from the current layout (s).
    pub migration_s: f64,
    /// Optimizer-state bytes that migration moves.
    pub migration_bytes: u64,
    /// Estimated Alg. 1 cost for the uncached `(type, stage)` pairs (0
    /// when fully cached).
    pub profile_est_s: f64,
    /// Effective samples/s over the horizon — the
    /// [`crate::policy::amortized_score`] kernel over the migration +
    /// profiling stall ledger.
    pub score: f64,
}

/// The selection rule over one candidate set: start from the incumbent
/// and require a *strict* score improvement; a candidate is switchable
/// only when memory-feasible, plannable and fully measured (cached).
/// Iteration is stage-descending so an exact tie between two eligible
/// stages resolves to the higher (lower-memory) one. An infeasible
/// incumbent scores below everything, so the first eligible candidate
/// takes over — the escalate-away-from-a-broken-bound case.
pub fn choose_stage(cands: &[StageCandidate]) -> u8 {
    let Some(inc) = cands.iter().find(|c| c.current) else {
        return cands.first().map_or(0, |c| c.stage);
    };
    let mut best_stage = inc.stage;
    let mut best_score = if inc.feasible { inc.score } else { f64::NEG_INFINITY };
    for c in cands.iter().rev() {
        if c.current || !c.feasible || !c.curves_cached {
            continue;
        }
        if !(c.rate_sps.is_finite() && c.rate_sps > 0.0) {
            continue;
        }
        if c.score > best_score {
            best_score = c.score;
            best_stage = c.stage;
        }
    }
    best_stage
}

impl ElasticPlanner {
    /// The resolved model spec behind this job's preset name, if it is a
    /// known preset — the stage search needs it for the memory bound and
    /// the catalog-FLOPs curve estimates.
    fn model_spec(&self) -> Option<ModelSpec> {
        preset(&self.model)
    }

    /// The leader's (2b) staleness rule applied to a candidate-stage
    /// cache entry: a curve whose `mbs` disagrees with the memory model
    /// at the *current* group size was measured under a different shard
    /// budget — too big risks OOM after a loss, too small wastes
    /// throughput after a join — so it must be re-measured before the
    /// stage is switchable. Unverifiable (non-preset model or
    /// non-catalog GPU) trusts the cache, matching the (2b) guard.
    pub(super) fn stage_curve_stale(
        &self,
        model_spec: Option<&ModelSpec>,
        gpu: &str,
        curve: &PerfCurve,
        stage: u8,
        n: usize,
    ) -> bool {
        match (model_spec, catalog::spec(gpu)) {
            (Some(m), Some(spec)) => {
                curve.mbs()
                    != memmodel::true_mbs(m, self.param_count, stage, n, spec.mem_bytes())
            }
            _ => false,
        }
    }

    /// True when every live rank (plus `extra_gpu`, if given) fits at
    /// least one sample at `stage` with `n` total ranks — the Alg. 1
    /// memory bound the paper's escalation loop enforces.
    pub(super) fn stage_feasible(
        &self,
        model: &ModelSpec,
        stage: u8,
        n: usize,
        extra_gpu: Option<&str>,
    ) -> bool {
        match extra_gpu {
            Some(g) => self.stage_feasible_with(model, stage, n, &[g]),
            None => self.stage_feasible_with(model, stage, n, &[]),
        }
    }

    /// The batch form of the Alg. 1 memory bound: every live rank plus
    /// each of `extra_gpus` (an admission batch; duplicates allowed)
    /// fits at least one sample at `stage` with `n` total ranks. The
    /// joint round engine (`crate::policy::decide_round`) checks
    /// candidate `(subset, stage)` points with this.
    ///
    /// A *virtual* rank (a slot carrying pipeline-group members) is
    /// checked with the group form of the bound — every member's layer
    /// share must fit at its 1F1B in-flight depth
    /// (`pipeline::group_feasible`) — while single-GPU slots and the
    /// extras keep the whole-model `true_mbs` check.
    pub fn stage_feasible_with(
        &self,
        model: &ModelSpec,
        stage: u8,
        n: usize,
        extra_gpus: &[&str],
    ) -> bool {
        let fits = |gpu: &str| {
            catalog::spec(gpu).is_some_and(|spec| {
                memmodel::true_mbs(model, self.param_count, stage, n, spec.mem_bytes()) >= 1
            })
        };
        let slot_fits = |s: &super::SlotState| {
            if s.members.is_empty() {
                fits(&s.gpu)
            } else {
                crate::pipeline::group_feasible(&s.members, model, self.param_count, stage, n)
            }
        };
        self.slots.iter().filter(|s| s.alive).all(|s| slot_fits(s))
            && extra_gpus.iter().all(|g| fits(g))
    }

    /// The cached curve for `(gpu, stage)` *usable at group size `n`*:
    /// a cache hit that also passes the (2b) staleness rule (its `mbs`
    /// matches the memory model at `n`). `None` when uncached or stale —
    /// the measured-coverage test every cross-stage decision
    /// (`preview_join`, the stage search, the joint round engine) runs
    /// before trusting a curve.
    pub fn measured_at(&self, gpu: &str, stage: u8, n: usize) -> Option<&PerfCurve> {
        let model_spec = self.model_spec();
        self.cache
            .peek(&CurveKey::new(gpu, &self.model, stage))
            .filter(|c| !self.stage_curve_stale(model_spec.as_ref(), gpu, c, stage, n))
    }

    /// Evaluate every candidate stage 0..=3 for the *current* membership
    /// against the current layout. Pure: no planner state moves (curve
    /// lookups go through `CurveCache::peek`). Requires every live slot
    /// profiled, like `replan` ([`ElasticError::MissingCurves`]) — with
    /// ONE exception: when the incumbent stage's memory bound is broken
    /// for the current membership (a joiner that cannot fit — and so
    /// cannot be profiled — at the current stage), missing curves are
    /// tolerated and the incumbent simply scores as unplannable, so the
    /// search can admit the joiner at a feasible measured stage instead
    /// of the leader evicting it before the search ever runs.
    pub fn stage_candidates(&self, net: &NetSim) -> Result<Vec<StageCandidate>, ElasticError> {
        let missing = self.needs_profile();
        if !missing.is_empty() {
            let incumbent_broken = self.model_spec().is_some_and(|m| {
                !self.stage_feasible(&m, self.stage, self.active_slots().len(), None)
            });
            if !incumbent_broken {
                return Err(ElasticError::MissingCurves(missing));
            }
        }
        let horizon = self
            .policy
            .as_ref()
            .map_or(DEFAULT_HORIZON_S, |p| p.horizon_s);
        let model_spec = self.model_spec();
        let n = self.active_slots().len();
        Ok((0..=3u8)
            .map(|s| self.evaluate_stage(s, net, horizon, model_spec.as_ref(), n))
            .collect())
    }

    fn evaluate_stage(
        &self,
        stage: u8,
        net: &NetSim,
        horizon: f64,
        model_spec: Option<&ModelSpec>,
        n: usize,
    ) -> StageCandidate {
        let current = stage == self.stage;
        // unknown (non-preset) model: the bound cannot be verified, so
        // only the incumbent stands
        let feasible = match model_spec {
            Some(m) => self.stage_feasible(m, stage, n, None),
            None => current,
        };

        // curve set at this stage: incumbent uses the live slot curves
        // (drift overrides included); others use cached type curves, and
        // fall back to catalog-FLOPs estimates priced with the Alg. 1
        // cost they would have to pay before the first productive step
        let mut curves: Vec<PerfCurve> = Vec::new();
        let mut curves_cached = true;
        let mut profile_est_s = 0.0;
        let mut estimated: Vec<crate::intern::TypeId> = Vec::new();
        let mut plannable = true;
        for sl in self.slots.iter().filter(|s| s.alive) {
            let curve = if current {
                sl.curve.clone()
            } else {
                match self.cache.peek(&CurveKey::of(sl.gpu, self.model, stage)) {
                    // a cached curve measured at a *different* group size
                    // counts as missing: its mbs is from another memory
                    // budget and must be re-measured (the leader's (2b)
                    // staleness rule, applied to candidate stages)
                    Some(c) if !self.stage_curve_stale(model_spec, &sl.gpu, c, stage, n) => {
                        Some(c.clone())
                    }
                    _ => {
                        curves_cached = false;
                        let synth = model_spec
                            .and_then(|m| synthesize_curve(&sl.gpu, m, stage, n).ok());
                        if let Some(c) = &synth {
                            if !estimated.contains(&sl.gpu) {
                                profile_est_s += profile_cost_estimate_s(c);
                                estimated.push(sl.gpu);
                            }
                        }
                        synth
                    }
                }
            };
            match curve {
                Some(c) => curves.push(c),
                None => {
                    plannable = false;
                    break;
                }
            }
        }

        let rate_sps = if plannable {
            allocator::plan(&curves, stage, self.gbs, net, self.param_count)
                .ok()
                .and_then(|p| predicted_wall_s(&p, &curves, net, self.param_count).ok())
                .map_or(0.0, |w| if w > 0.0 { self.gbs as f64 / w } else { 0.0 })
        } else {
            0.0
        };

        // one-shot migration from the current layout (membership
        // movement folded in; zero on the initial plan)
        let (migration_s, migration_bytes) = match &self.manifest {
            Some(old) => {
                let live: Vec<(usize, crate::intern::TypeId)> = self
                    .slots
                    .iter()
                    .filter(|s| s.alive)
                    .map(|s| (s.slot, s.gpu))
                    .collect();
                ShardManifest::build(&self.model, stage, self.param_count, self.replans, &live)
                    .and_then(|m| ckpt::migrate(old, &m))
                    .map(|p| (p.transfer_time_s(net), p.bytes_moved()))
                    // a corrupt layout can never win the search
                    .unwrap_or((f64::INFINITY, u64::MAX))
            }
            None => (0.0, 0),
        };

        // the shared amortized-scoring kernel over a migration +
        // profiling ledger (one formula for the whole crate)
        let score = amortized_score(
            rate_sps,
            horizon,
            &StallLedger {
                migration_transfer_s: migration_s,
                profiling_est_s: profile_est_s,
                ..Default::default()
            },
        );
        StageCandidate {
            stage,
            current,
            feasible,
            curves_cached: curves_cached || current,
            rate_sps,
            migration_s,
            migration_bytes,
            profile_est_s,
            score,
        }
    }

    /// The incremental profiling the stage search is still missing:
    /// `(slot, stage)` pairs — one representative live slot per uncached
    /// `(gpu type, stage)` pair — for every candidate stage that passes
    /// the memory bound and whose *estimated* score beats the incumbent
    /// (or for every feasible stage when the incumbent's own bound is
    /// broken and the job must move somewhere). The leader profiles
    /// these and installs the curves via
    /// [`ElasticPlanner::install_stage_curve`] before replanning;
    /// everything already cached costs nothing, so after the first
    /// migration a stage flip-flop is free of Alg. 1 runs.
    pub fn stage_profile_requests(&self, net: &NetSim) -> Vec<(usize, u8)> {
        if self.policy.is_none() {
            return Vec::new();
        }
        let Ok(cands) = self.stage_candidates(net) else {
            return Vec::new();
        };
        let Some(inc) = cands.iter().find(|c| c.current) else {
            return Vec::new();
        };
        let must_move = !inc.feasible;
        let inc_score = if inc.feasible { inc.score } else { f64::NEG_INFINITY };
        let model_spec = self.model_spec();
        let n = self.active_slots().len();
        let mut reqs: Vec<(usize, u8)> = Vec::new();
        for c in &cands {
            if c.current || !c.feasible || c.curves_cached {
                continue;
            }
            if !(c.score > inc_score || must_move) {
                continue;
            }
            let mut seen: Vec<&str> = Vec::new();
            for sl in self.slots.iter().filter(|s| s.alive) {
                if seen.iter().any(|g| *g == sl.gpu) {
                    continue;
                }
                seen.push(&sl.gpu);
                // missing OR stale (measured at another group size):
                // both need a fresh Alg. 1 run before the switch
                let usable = self
                    .cache
                    .peek(&CurveKey::new(&sl.gpu, &self.model, c.stage))
                    .is_some_and(|cv| {
                        !self.stage_curve_stale(model_spec.as_ref(), &sl.gpu, cv, c.stage, n)
                    });
                if !usable {
                    reqs.push((sl.slot, c.stage));
                }
            }
        }
        reqs
    }

    /// Run the stage search and return the chosen stage plus the full
    /// candidate table (diagnostics / `exp::fig_stage_migration`).
    pub(super) fn select_stage(
        &self,
        net: &NetSim,
    ) -> Result<(u8, Vec<StageCandidate>), ElasticError> {
        let cands = self.stage_candidates(net)?;
        let chosen = choose_stage(&cands);
        Ok((chosen, cands))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::LinkKind;
    use crate::curves::ProfiledPoint;
    use crate::elastic::ElasticPlanner;

    /// Ground-truth curve for a GPU at the memory-model mbs of
    /// `(model, stage, n)` — what Alg. 1 would measure noise-free. On
    /// the simulated substrate the catalog-FLOPs synthesizer IS the
    /// ground truth (the SimDevice times the same device model).
    fn truth_curve(gpu: &str, model: &ModelSpec, stage: u8, n: usize) -> Option<PerfCurve> {
        synthesize_curve(gpu, model, stage, n).ok()
    }

    /// A z3 planner on a socket link: 2× A800 + 2× V100S, all-stage
    /// curves cached as measured at group size `seed_n` (the group size
    /// the test will run the search at — stale entries are ineligible).
    /// ZeRO-3's per-micro-step collectives are brutal at 2 GB/s, so
    /// de-escalation is clearly profitable.
    fn socket_planner(policy: Option<StagePolicy>, seed_n: usize) -> (ElasticPlanner, NetSim) {
        let m = preset("llama-0.5b").unwrap();
        let mut p = ElasticPlanner::new(3, 2048, &m.name, m.param_count(), 32);
        for gpu in ["A800-80G", "A800-80G", "V100S-32G", "V100S-32G"] {
            let slot = p.add_slot(gpu);
            if p.slots()[slot].curve.is_none() {
                p.install_curve(slot, truth_curve(gpu, &m, 3, 4).unwrap(), false)
                    .unwrap();
            }
        }
        for s in 0..=3u8 {
            for gpu in ["A800-80G", "V100S-32G"] {
                if let Some(c) = truth_curve(gpu, &m, s, seed_n) {
                    p.install_stage_curve(gpu, s, c).unwrap();
                }
            }
        }
        p.set_stage_policy(policy);
        (p, NetSim::from_link(4, LinkKind::Socket))
    }

    #[test]
    fn search_de_escalates_z3_to_z1_when_join_makes_it_cheap() {
        // pin the initial plan at z3 with the policy off — the state a
        // startup escalation leaves behind — then enable the search and
        // let a membership event trigger the re-decision (the search
        // runs at n=5, so the cache is seeded as-measured-at-5)
        let (mut p, net) = socket_planner(None, 5);
        p.replan(&net).unwrap();
        assert_eq!(p.stage(), 3);
        p.set_stage_policy(Some(StagePolicy::default()));
        p.add_slot("V100S-32G");
        let net5 = NetSim::from_link(5, LinkKind::Socket);
        p.replan(&net5).unwrap();
        // on a 2 GB/s link ZeRO-1 drops ~all collective traffic: the
        // search must have de-escalated to the partitioned sync-once stage
        assert_eq!(p.stage(), 1);
        let ch = p.last_stage_change().expect("a stage change must be recorded").clone();
        assert_eq!((ch.from, ch.to), (3, 1));
        // the plan, the manifest and every slot curve moved with it
        assert_eq!(p.plan().unwrap().stage, 1);
        assert_eq!(p.manifest().unwrap().stage, 1);
        assert_eq!(p.plan().unwrap().total_samples(), 2048);
        p.plan().unwrap().validate().unwrap();
        for sl in p.slots().iter().filter(|s| s.alive) {
            assert!(sl.curve.is_some());
            assert!(!sl.drifted, "stage switch installs healthy type curves");
        }
    }

    #[test]
    fn candidates_report_rates_and_migration_costs() {
        // the candidate table is read at n=4: seed the cache at 4 so
        // nothing is staleness-disqualified
        let (mut p, net) = socket_planner(Some(StagePolicy::default()), 4);
        // pin at z3 without policy interference for the candidate table
        p.set_stage_policy(None);
        p.replan(&net).unwrap();
        p.set_stage_policy(Some(StagePolicy::default()));
        let cands = p.stage_candidates(&net).unwrap();
        assert_eq!(cands.len(), 4);
        let by = |s: u8| cands.iter().find(|c| c.stage == s).unwrap();
        assert!(by(3).current);
        // llama-0.5b fits every catalog card at every stage
        assert!(cands.iter().all(|c| c.feasible));
        // all cached (pre-seeded): no profiling estimates anywhere
        assert!(cands.iter().all(|c| c.curves_cached));
        assert!(cands.iter().all(|c| c.profile_est_s == 0.0));
        // ZeRO-1 beats ZeRO-3 on a socket link by a wide margin
        assert!(
            by(1).rate_sps > by(3).rate_sps * 1.5,
            "z1 {} vs z3 {}",
            by(1).rate_sps,
            by(3).rate_sps
        );
        // partitioned -> partitioned with unchanged membership: free
        assert_eq!(by(1).migration_bytes, 0);
        assert_eq!(by(2).migration_bytes, 0);
        // partitioned -> replicated: the full broadcast is priced
        let m = preset("llama-0.5b").unwrap();
        assert!(by(0).migration_bytes >= 9 * m.param_count());
        assert!(by(0).migration_s > 0.0);
    }

    #[test]
    fn infeasible_incumbent_escalates_to_a_measured_stage() {
        // bert-1.1b replicated (ZeRO-0) needs 16ψ ≈ 21 GB + reserve: a
        // T4 (16 GiB) violates the bound outright, so the incumbent must
        // move — here to the only cached alternative, ZeRO-3
        let m = preset("bert-1.1b").unwrap();
        let mut p = ElasticPlanner::new(0, 16, &m.name, m.param_count(), 16);
        for gpu in ["A100-80G", "T4"] {
            let slot = p.add_slot(gpu);
            // fabricated z0 curves: the state machine does not care that
            // a T4 could never really have produced one
            let pts = vec![
                ProfiledPoint { batch: 1, step_time_s: 0.1 },
                ProfiledPoint { batch: 2, step_time_s: 0.19 },
            ];
            p.install_curve(slot, PerfCurve::fit(pts, 2).unwrap(), false).unwrap();
        }
        for gpu in ["A100-80G", "T4"] {
            let c = truth_curve(gpu, &m, 3, 2).expect("z3 fits both cards");
            p.install_stage_curve(gpu, 3, c).unwrap();
        }
        p.set_stage_policy(Some(StagePolicy::default()));
        let net = NetSim::from_link(2, LinkKind::Ib);
        let cands = p.stage_candidates(&net).unwrap();
        let z0 = cands.iter().find(|c| c.stage == 0).unwrap();
        assert!(!z0.feasible, "16ψ must not fit a 16 GiB card");
        assert!(z0.current);
        p.replan(&net).unwrap();
        assert_eq!(p.stage(), 3, "must escalate off the broken bound");
        assert_eq!(p.last_stage_change().unwrap().from, 0);
        p.plan().unwrap().validate().unwrap();
    }

    #[test]
    fn uncached_candidate_is_scored_but_never_switched_to() {
        // ONLY z3 cached: the de-escalation is visibly better on
        // estimates, but the planner alone cannot profile, so it must
        // stay (the leader profiles via stage_profile_requests)
        let m = preset("llama-0.5b").unwrap();
        let mut cold = ElasticPlanner::new(3, 2048, &m.name, m.param_count(), 32);
        for gpu in ["A800-80G", "A800-80G", "V100S-32G", "V100S-32G"] {
            let slot = cold.add_slot(gpu);
            if cold.slots()[slot].curve.is_none() {
                cold.install_curve(slot, truth_curve(gpu, &m, 3, 4).unwrap(), false)
                    .unwrap();
            }
        }
        cold.set_stage_policy(Some(StagePolicy::default()));
        let net = NetSim::from_link(4, LinkKind::Socket);
        cold.replan(&net).unwrap();
        assert_eq!(cold.stage(), 3, "estimate-based stages are defer-only");
        let cands = cold.stage_candidates(&net).unwrap();
        let z1 = cands.iter().find(|c| c.stage == 1).unwrap();
        assert!(!z1.curves_cached);
        assert!(z1.profile_est_s > 0.0, "uncached pairs price Alg. 1");
        assert!(z1.rate_sps > 0.0, "estimate still predicts a rate");
        // and the work list names exactly the missing (type, stage) pairs
        let reqs = cold.stage_profile_requests(&net);
        assert!(!reqs.is_empty());
        assert!(reqs.iter().all(|&(_, s)| s != 3), "z3 is already measured");
        let mut pairs: Vec<(String, u8)> = reqs
            .iter()
            .map(|&(slot, s)| (cold.slots()[slot].gpu.to_string(), s))
            .collect();
        let before = pairs.len();
        pairs.sort();
        pairs.dedup();
        assert_eq!(pairs.len(), before, "one request per (type, stage) pair");
    }

    #[test]
    fn drift_override_carries_across_a_stage_switch() {
        // regression (PR-4 gap): a straggler's slowdown used to be
        // silently reset to the healthy type curve on migration. Now the
        // live drift factor is re-applied to the new stage's curve and
        // the slot stays flagged until drift detection re-measures it.
        let (mut p, net) = socket_planner(None, 5);
        p.replan(&net).unwrap();
        let m = preset("llama-0.5b").unwrap();
        let healthy = truth_curve("A800-80G", &m, 3, 4).unwrap();
        let slow: Vec<ProfiledPoint> = healthy
            .points()
            .iter()
            .map(|pt| ProfiledPoint { batch: pt.batch, step_time_s: pt.step_time_s * 2.0 })
            .collect();
        let mbs = healthy.mbs();
        p.install_curve(0, PerfCurve::fit(slow, mbs).unwrap(), true).unwrap();
        assert!(p.slots()[0].drifted);
        p.set_stage_policy(Some(StagePolicy::default()));
        p.add_slot("V100S-32G");
        let net5 = NetSim::from_link(5, LinkKind::Socket);
        p.replan(&net5).unwrap();
        assert_eq!(p.stage(), 1, "the migration itself must still happen");
        // slot 0 kept its override: still flagged, ~2x slower than the
        // healthy ZeRO-1 curve its twin (slot 1) received
        assert!(p.slots()[0].drifted, "drift must survive the migration");
        assert!(!p.slots()[1].drifted);
        let s0 = p.slots()[0].curve.as_ref().unwrap().peak_speed();
        let s1 = p.slots()[1].curve.as_ref().unwrap().peak_speed();
        let ratio = s1 / s0;
        assert!(
            (ratio - 2.0).abs() < 0.3,
            "carried factor must stay ~2x, got {ratio:.3}"
        );
        p.plan().unwrap().validate().unwrap();
    }

    #[test]
    fn homeless_joiner_is_admitted_at_a_feasible_measured_stage() {
        // regression (PR-4 gap): bert-1.1b replicated (ZeRO-0) cannot
        // fit a T4, and such joiners used to be evicted before the
        // search ran. The search now runs first: with ZeRO-3 measured
        // for every type at the new group size, the replan migrates and
        // the joiner's curve comes straight off the stage-keyed cache.
        let m = preset("bert-1.1b").unwrap();
        let mut p = ElasticPlanner::new(0, 16, &m.name, m.param_count(), 16);
        for _ in 0..2 {
            let slot = p.add_slot("A100-80G");
            if p.slots()[slot].curve.is_none() {
                let pts = vec![
                    ProfiledPoint { batch: 1, step_time_s: 0.1 },
                    ProfiledPoint { batch: 2, step_time_s: 0.19 },
                ];
                p.install_curve(slot, PerfCurve::fit(pts, 2).unwrap(), false).unwrap();
            }
        }
        let net2 = NetSim::from_link(2, LinkKind::Ib);
        p.replan(&net2).unwrap();
        p.set_stage_policy(Some(StagePolicy::default()));
        for gpu in ["A100-80G", "T4"] {
            let c = truth_curve(gpu, &m, 3, 3).expect("z3 fits both cards at n=3");
            p.install_stage_curve(gpu, 3, c).unwrap();
        }
        let slot = p.add_slot("T4");
        assert!(p.needs_profile().contains(&slot), "no T4 ZeRO-0 curve can exist");
        let net3 = NetSim::from_link(3, LinkKind::Ib);
        p.replan(&net3).unwrap();
        assert_eq!(p.stage(), 3, "must escalate off the broken bound");
        assert!(p.needs_profile().is_empty(), "joiner curve came from the cache");
        assert_eq!(p.plan().unwrap().ranks.len(), 3, "admitted, not evicted");
        p.plan().unwrap().validate().unwrap();
        assert_eq!(p.manifest().unwrap().stage, 3);
        assert_eq!(p.last_stage_change().unwrap().from, 0);
    }

    #[test]
    fn merely_unprofiled_fleet_still_errors_missing_curves() {
        // the homeless-joiner tolerance must NOT swallow the ordinary
        // precondition: a joiner that FITS the incumbent stage but has
        // no curve yet still fails replan with MissingCurves (the
        // leader profiles it first), even with measured alternatives
        // cached — no overeager migration away from profiling
        let m = preset("llama-0.5b").unwrap();
        let mut p = ElasticPlanner::new(3, 256, &m.name, m.param_count(), 16);
        for gpu in ["A800-80G", "V100S-32G"] {
            let slot = p.add_slot(gpu);
            p.install_curve(slot, truth_curve(gpu, &m, 3, 2).unwrap(), false).unwrap();
        }
        let net = NetSim::from_link(2, LinkKind::Ib);
        p.replan(&net).unwrap();
        p.set_stage_policy(Some(StagePolicy::default()));
        for gpu in ["A800-80G", "V100S-32G", "T4"] {
            if let Some(c) = truth_curve(gpu, &m, 1, 3) {
                p.install_stage_curve(gpu, 1, c).unwrap();
            }
        }
        let slot = p.add_slot("T4"); // fits ZeRO-3 fine, just unprofiled
        assert!(matches!(
            p.replan(&NetSim::from_link(3, LinkKind::Ib)),
            Err(ElasticError::MissingCurves(s)) if s == vec![slot]
        ));
        assert_eq!(p.stage(), 3, "no migration happened");
    }

    #[test]
    fn short_horizon_keeps_the_stage_when_profiling_cannot_amortize() {
        // same cold cache, but a 4 s expected tenure: the estimated
        // Alg. 1 stall zeroes out every uncached candidate's score, so
        // nothing is even worth profiling — the stall makes staying
        // optimal although ZeRO-1's raw rate is higher
        let m = preset("llama-0.5b").unwrap();
        let mut p = ElasticPlanner::new(3, 2048, &m.name, m.param_count(), 32);
        for gpu in ["A800-80G", "A800-80G", "V100S-32G", "V100S-32G"] {
            let slot = p.add_slot(gpu);
            if p.slots()[slot].curve.is_none() {
                p.install_curve(slot, truth_curve(gpu, &m, 3, 4).unwrap(), false)
                    .unwrap();
            }
        }
        p.set_stage_policy(Some(StagePolicy { horizon_s: 4.0 }));
        let net = NetSim::from_link(4, LinkKind::Socket);
        p.replan(&net).unwrap();
        let cands = p.stage_candidates(&net).unwrap();
        let (z1, z3) = (
            cands.iter().find(|c| c.stage == 1).unwrap(),
            cands.iter().find(|c| c.stage == 3).unwrap(),
        );
        assert!(z1.rate_sps > z3.rate_sps, "z1 is genuinely faster…");
        assert!(z1.score < z3.score, "…but the stall makes staying optimal");
        assert_eq!(z1.score, 0.0, "profiling alone exceeds the 4 s tenure");
        assert!(p.stage_profile_requests(&net).is_empty(), "not worth profiling");
        p.add_slot("A800-80G");
        p.replan(&NetSim::from_link(5, LinkKind::Socket)).unwrap();
        assert_eq!(p.stage(), 3, "stays at the incumbent");
        assert!(p.last_stage_change().is_none());
    }
}
