//! Elastic cluster runtime: mid-training membership changes, drift-aware
//! re-profiling and automatic re-allocation.
//!
//! The paper profiles the cluster once (Alg. 1), plans once (Alg. 2) and
//! assumes the fleet never changes. Real heterogeneous fleets change
//! constantly: spot ranks are preempted, warm GPUs join, stragglers
//! appear. This module makes the whole pipeline incremental:
//!
//! * [`events`] — the event model (`RankLost` / `RankJoined` /
//!   `RankSlowed`), explicit or seeded-deterministic schedules;
//! * [`cache`] — a `(gpu, model, stage)`-keyed curve cache, so a re-join
//!   of a known GPU type skips Algorithm 1 entirely;
//! * [`ElasticPlanner`] — the membership/curve state machine: tracks
//!   live slots, reuses cached curves, asks for incremental re-profiles
//!   only where needed, and re-runs Algorithm 2 on the surviving curve
//!   set via [`allocator::replan`];
//! * [`detect_drift`] — compares observed micro-step times against the
//!   fitted curves; ranks beyond the threshold are re-profiled (only
//!   them — the rest of the cluster keeps training on known curves);
//! * [`detect_comm_drift`] — the symmetric *fabric* check: observed vs
//!   predicted collective time per iteration. A flagged iteration feeds
//!   the `netsim::BwMonitor`, whose sustained-shift state machine (not
//!   the single sample) decides when the incumbent plan goes stale;
//! * every replan also rebuilds the optimizer-shard layout
//!   ([`crate::ckpt::ShardManifest`]) and computes the minimal
//!   shard-movement set against the previous layout, so
//!   [`ElasticPlanner::reshard_penalty_s`] is *measured* from the bytes
//!   that actually change owner — not the one-shot `12ψ` constant PR 1
//!   charged;
//! * [`stage`] — with a [`StagePolicy`] installed, the ZeRO stage itself
//!   is a replan-time decision: each replan re-checks every stage's
//!   Alg. 1 memory bound at the new group size and migrates
//!   (`ckpt::migrate`, charged like a reshard) when the amortized gain
//!   beats the incumbent.
//!
//! The live driver is `coordinator::Leader::run_elastic_job`; the
//! analytic comparison (static plan vs re-allocation) is
//! `exp::fig_elastic`.

pub mod cache;
pub mod events;
pub mod stage;

pub use cache::{CurveCache, CurveKey};
pub use events::{parse_schedule, seeded_schedule, ElasticEvent, ScheduledEvent, XorShift};
pub use stage::{choose_stage, StageCandidate, StageChange, StagePolicy};

use std::cell::Cell;

use crate::allocator::{self, Plan, PlanError};
use crate::ckpt::{self, MigrationIndex, ReshardPlan, ShardManifest};
use crate::curves::{PerfCurve, ProfiledPoint};
use crate::intern::{self, TypeId};
use crate::netsim::NetSim;
use crate::policy::StallLedger;

/// Default relative drift threshold: re-profile a rank when its observed
/// micro-step time deviates from the curve prediction by more than 15%
/// (an order of magnitude above the profiling noise floor).
pub const DEFAULT_DRIFT_THRESHOLD: f64 = 0.15;

/// Errors from the elastic state machine.
#[derive(Debug, PartialEq)]
pub enum ElasticError {
    /// Event referenced a slot that does not exist.
    UnknownSlot(usize),
    /// Event referenced a slot that already left the job.
    DeadSlot(usize),
    /// Losing this slot would leave the job with no ranks.
    LastRank,
    /// Replan was asked for while some live rank has no curve yet
    /// (call [`ElasticPlanner::needs_profile`] first).
    MissingCurves(Vec<usize>),
    /// A join preview needs a curve, but the type-level cache has none
    /// and the caller supplied no estimate.
    NoCurve(String),
    /// A round preview was called with a `fallbacks` slice whose length
    /// does not match `gpus` — a caller bug that in release builds used
    /// to silently read missing entries as "no fallback" and flip a
    /// priced estimate into [`ElasticError::NoCurve`].
    FallbackLen {
        /// Number of joiner GPU types passed.
        gpus: usize,
        /// Number of fallback entries passed.
        fallbacks: usize,
    },
    /// The allocator rejected the surviving curve set.
    Plan(PlanError),
    /// The checkpoint subsystem rejected the shard layout (message form:
    /// `CkptError` is not `PartialEq`).
    Ckpt(String),
    /// A `BwDrift` event carried an unusable link name or factor.
    BwDrift(String),
    /// A pipeline-group operation failed (message form:
    /// `pipeline::PipelineError` semantics, e.g. an op on a slot that
    /// carries no members, or a model with no preset to bound against).
    Pipeline(String),
}

impl std::fmt::Display for ElasticError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ElasticError::UnknownSlot(s) => write!(f, "unknown slot {s}"),
            ElasticError::DeadSlot(s) => write!(f, "slot {s} already left the job"),
            ElasticError::LastRank => write!(f, "cannot lose the last live rank"),
            ElasticError::MissingCurves(s) => {
                write!(f, "slots {s:?} need profiling before replan")
            }
            ElasticError::NoCurve(gpu) => {
                write!(f, "no cached curve for GPU type {gpu:?} and no estimate supplied")
            }
            ElasticError::FallbackLen { gpus, fallbacks } => write!(
                f,
                "fallbacks must be parallel to gpus: got {fallbacks} entries for {gpus} joiners"
            ),
            ElasticError::Plan(e) => write!(f, "replan failed: {e}"),
            ElasticError::Ckpt(e) => write!(f, "shard layout: {e}"),
            ElasticError::BwDrift(e) => write!(f, "bw drift event: {e}"),
            ElasticError::Pipeline(e) => write!(f, "pipeline group: {e}"),
        }
    }
}

impl std::error::Error for ElasticError {}

/// Per-slot planner state. A slot is one *virtual DP rank*: either a
/// single physical GPU (`members` empty — every pre-pipeline path) or a
/// pipeline group of physical GPUs acting as one participant
/// (`members` lists them in stage order and `gpu` carries the
/// `pipeline::group_label`).
#[derive(Debug, Clone)]
pub struct SlotState {
    /// Leader slot id (stable across membership changes).
    pub slot: usize,
    /// Interned catalog GPU name — or the group label for a pipeline
    /// group. Resolve with `as_str()` at report boundaries only.
    pub gpu: TypeId,
    /// False once the slot left the job.
    pub alive: bool,
    /// Fitted performance curve, if known (the composed group curve for
    /// a pipeline group).
    pub curve: Option<PerfCurve>,
    /// True when the current curve is a rank-local drift override (a
    /// straggler's curve) rather than the healthy type-level curve — such
    /// curves are kept out of the shared cache.
    pub drifted: bool,
    /// Physical members of a pipeline group (interned), in
    /// pipeline-stage order (ascending memory). Empty for an ordinary
    /// single-GPU slot. Plans address the *slot*; membership events
    /// address these GPUs — losing one degrades this group, not the
    /// fleet.
    pub members: Vec<TypeId>,
}

/// Interior-mutability perf counters over the preview hot paths: the
/// complexity tests pin *counts*, not timings (they run under tier-1
/// with no profiler). `Cell` because previews take `&self`.
#[derive(Debug, Clone, Default)]
pub struct PerfCounters {
    manifests_built: Cell<u64>,
    previews_priced: Cell<u64>,
}

impl PerfCounters {
    /// `ShardManifest::build` calls issued by replan/preview paths.
    pub fn manifests_built(&self) -> u64 {
        self.manifests_built.get()
    }

    /// Shard-movement pricings (`migrate`/index pricing) issued by
    /// replan/preview paths.
    pub fn previews_priced(&self) -> u64 {
        self.previews_priced.get()
    }
}

/// Membership/curve state machine behind the elastic runtime.
///
/// Slots are append-only: a lost slot keeps its id forever (so reports
/// stay unambiguous) and joined ranks get fresh ids. Plans are always
/// expressed over the *compact* live-rank order; [`ElasticPlanner::slot_map`]
/// gives the compact-index → slot-id mapping for the current plan.
#[derive(Debug, Clone)]
pub struct ElasticPlanner {
    /// *Current* ZeRO stage: fixed for the whole job unless a
    /// [`StagePolicy`] is installed, in which case every replan may
    /// migrate it.
    stage: u8,
    gbs: usize,
    model: TypeId,
    param_count: u64,
    slots: Vec<SlotState>,
    cache: CurveCache,
    plan: Option<Plan>,
    slot_map: Vec<usize>,
    dirty: bool,
    replans: usize,
    manifest: Option<ShardManifest>,
    last_reshard: Option<ReshardPlan>,
    policy: Option<StagePolicy>,
    last_stage_change: Option<StageChange>,
    perf: PerfCounters,
}

impl ElasticPlanner {
    /// New planner for a fixed `(stage, gbs, model)` job. `cache_cap`
    /// bounds the curve cache (counting curves, not bytes).
    pub fn new(stage: u8, gbs: usize, model: &str, param_count: u64, cache_cap: usize) -> Self {
        ElasticPlanner {
            stage,
            gbs,
            model: intern::intern(model),
            param_count,
            slots: Vec::new(),
            cache: CurveCache::new(cache_cap),
            plan: None,
            slot_map: Vec::new(),
            dirty: true,
            replans: 0,
            manifest: None,
            last_reshard: None,
            policy: None,
            last_stage_change: None,
            perf: PerfCounters::default(),
        }
    }

    /// Preview/replan perf counters (complexity tests, diagnostics).
    pub fn perf(&self) -> &PerfCounters {
        &self.perf
    }

    /// ZeRO stage the job currently runs at (may move between replans
    /// when a [`StagePolicy`] is installed).
    pub fn stage(&self) -> u8 {
        self.stage
    }

    /// Install (or remove) the replan-time stage search.
    pub fn set_stage_policy(&mut self, policy: Option<StagePolicy>) {
        self.policy = policy;
    }

    /// The active stage policy, if any.
    pub fn stage_policy(&self) -> Option<&StagePolicy> {
        self.policy.as_ref()
    }

    /// The stage migration the latest replan performed (`None` when the
    /// stage was kept).
    pub fn last_stage_change(&self) -> Option<&StageChange> {
        self.last_stage_change.as_ref()
    }

    /// Insert a measured curve for a `(gpu type, stage)` pair into the
    /// shared cache without touching any slot — the install path for
    /// [`ElasticPlanner::stage_profile_requests`] results. Does not mark
    /// the planner dirty: stage-search inputs only matter to a replan
    /// that is already pending.
    pub fn install_stage_curve(
        &mut self,
        gpu: &str,
        stage: u8,
        curve: PerfCurve,
    ) -> Result<(), ElasticError> {
        if stage > 3 {
            return Err(ElasticError::Plan(PlanError::InvalidStage(stage)));
        }
        let live = self.live_keys();
        self.cache
            .insert(CurveKey::of(intern::intern(gpu), self.model, stage), curve, &live);
        Ok(())
    }

    /// Global batch size the plans must cover.
    pub fn gbs(&self) -> usize {
        self.gbs
    }

    /// Model preset name the job trains.
    pub fn model(&self) -> &str {
        &self.model
    }

    /// Interned handle of the model preset name — the zero-alloc key
    /// half for [`CurveKey::of`] on preview hot paths.
    pub fn model_id(&self) -> TypeId {
        self.model
    }

    /// Total parameter count `ψ` of the model.
    pub fn param_count(&self) -> u64 {
        self.param_count
    }

    /// Register a new rank; returns its slot id. If the cache knows this
    /// `(gpu, model, stage)` the curve is installed immediately and the
    /// rank needs no profiling.
    pub fn add_slot(&mut self, gpu: &str) -> usize {
        let slot = self.slots.len();
        let gpu = intern::intern(gpu);
        let curve = self.cache.get(&CurveKey::of(gpu, self.model, self.stage));
        self.slots.push(SlotState {
            slot,
            gpu,
            alive: true,
            curve,
            drifted: false,
            members: Vec::new(),
        });
        self.dirty = true;
        slot
    }

    /// Register a *pipeline group* as one virtual DP rank; returns its
    /// slot id. The slot's `gpu` is the group label, its curve is the
    /// composed group curve from [`crate::pipeline::plan_group`], and
    /// `members` records the physical GPUs in stage order. The curve is
    /// slot-local (never inserted into the type-level cache): a composed
    /// curve is a property of this exact membership, not of a GPU type.
    pub fn add_group_slot(&mut self, plan: &crate::pipeline::GroupPlan) -> usize {
        let slot = self.slots.len();
        self.slots.push(SlotState {
            slot,
            gpu: intern::intern(&plan.label),
            alive: true,
            curve: Some(plan.curve.clone()),
            drifted: false,
            members: plan.members.iter().map(|m| intern::intern(m)).collect(),
        });
        self.dirty = true;
        slot
    }

    /// A physical member of a pipeline group died. The group — not the
    /// fleet — degrades: the survivors are re-planned as a smaller
    /// pipeline at the current stage and virtual-rank count. When the
    /// smaller group still satisfies every member's memory bound, the
    /// slot stays alive with a freshly composed curve (and possibly a
    /// new layer partition) and `Ok(Some(new_plan))` reports the new
    /// shape; when it cannot, the whole slot is dissolved via
    /// [`ElasticPlanner::lose_slot`] and `Ok(None)` reports the
    /// eviction. `member` indexes [`SlotState::members`].
    pub fn lose_group_member(
        &mut self,
        slot: usize,
        member: usize,
        net: &NetSim,
    ) -> Result<Option<crate::pipeline::GroupPlan>, ElasticError> {
        let n_virtual = self.active_slots().len();
        let s = self.slots.get(slot).ok_or(ElasticError::UnknownSlot(slot))?;
        if !s.alive {
            return Err(ElasticError::DeadSlot(slot));
        }
        if s.members.is_empty() {
            return Err(ElasticError::Pipeline(format!(
                "slot {slot} ({}) is not a pipeline group",
                s.gpu
            )));
        }
        if member >= s.members.len() {
            return Err(ElasticError::Pipeline(format!(
                "slot {slot} has {} members, no index {member}",
                s.members.len()
            )));
        }
        let mut survivors = s.members.clone();
        survivors.remove(member);
        let model_spec = crate::config::model::preset(&self.model).ok_or_else(|| {
            ElasticError::Pipeline(format!("no model preset {:?} to bound against", self.model))
        })?;
        match crate::pipeline::plan_group(
            &survivors,
            &model_spec,
            self.param_count,
            self.stage,
            n_virtual,
            net,
        ) {
            Ok(plan) => {
                let s = &mut self.slots[slot];
                s.gpu = intern::intern(&plan.label);
                s.members = plan.members.iter().map(|m| intern::intern(m)).collect();
                s.curve = Some(plan.curve.clone());
                s.drifted = false;
                self.dirty = true;
                Ok(Some(plan))
            }
            // the shrunken group no longer holds the model (too few
            // members, or the bound breaks): the virtual rank leaves the
            // job as one unit
            Err(_) => {
                self.lose_slot(slot)?;
                Ok(None)
            }
        }
    }

    /// Apply a membership event. `RankSlowed` and `BwDrift` are
    /// deliberately validated no-ops here: stragglers and fabric
    /// congestion are *not announced* — compute-drift detection and the
    /// `netsim::BwMonitor` respectively must discover them from
    /// observations.
    pub fn apply(&mut self, event: &ElasticEvent) -> Result<(), ElasticError> {
        match event {
            ElasticEvent::RankLost { slot } => self.lose_slot(*slot),
            ElasticEvent::RankJoined { gpu } => {
                self.add_slot(gpu);
                Ok(())
            }
            ElasticEvent::RankSlowed { slot, .. } => {
                let s = self.slots.get(*slot).ok_or(ElasticError::UnknownSlot(*slot))?;
                if !s.alive {
                    return Err(ElasticError::DeadSlot(*slot));
                }
                Ok(())
            }
            ElasticEvent::BwDrift { link, factor } => {
                if crate::cluster::LinkKind::parse(link).is_none() {
                    return Err(ElasticError::BwDrift(format!("unknown link kind {link:?}")));
                }
                if !factor.is_finite() || *factor <= 0.0 {
                    return Err(ElasticError::BwDrift(format!(
                        "factor must be finite and > 0, got {factor}"
                    )));
                }
                Ok(())
            }
        }
    }

    /// Remove a slot from the job.
    pub fn lose_slot(&mut self, slot: usize) -> Result<(), ElasticError> {
        let n_alive = self.active_slots().len();
        let s = self.slots.get_mut(slot).ok_or(ElasticError::UnknownSlot(slot))?;
        if !s.alive {
            return Err(ElasticError::DeadSlot(slot));
        }
        if n_alive <= 1 {
            return Err(ElasticError::LastRank);
        }
        s.alive = false;
        self.dirty = true;
        Ok(())
    }

    /// Install a freshly fitted curve for a slot. `from_drift` marks a
    /// rank-local straggler curve: it is used for planning but kept out
    /// of the shared type-level cache.
    ///
    /// A dead slot is rejected with [`ElasticError::DeadSlot`]: a late
    /// profile reply for a rank that already left the job must be
    /// dropped, not poison the shared type-level cache and force a
    /// spurious replan (the departed rank may have been re-measured
    /// mid-failure, so its curve is the *least* trustworthy sample of
    /// its type).
    pub fn install_curve(
        &mut self,
        slot: usize,
        curve: PerfCurve,
        from_drift: bool,
    ) -> Result<(), ElasticError> {
        let live: Vec<CurveKey> = self.live_keys();
        let model = self.model;
        let stage = self.stage;
        let s = self.slots.get_mut(slot).ok_or(ElasticError::UnknownSlot(slot))?;
        if !s.alive {
            return Err(ElasticError::DeadSlot(slot));
        }
        if !from_drift {
            self.cache
                .insert(CurveKey::of(s.gpu, model, stage), curve.clone(), &live);
        }
        s.curve = Some(curve);
        s.drifted = from_drift;
        self.dirty = true;
        Ok(())
    }

    fn live_keys(&self) -> Vec<CurveKey> {
        self.slots
            .iter()
            .filter(|s| s.alive)
            .map(|s| CurveKey::of(s.gpu, self.model, self.stage))
            .collect()
    }

    /// Live slots whose curve is unknown — the incremental re-profiling
    /// work list (empty when the cache covered everything).
    pub fn needs_profile(&self) -> Vec<usize> {
        self.slots
            .iter()
            .filter(|s| s.alive && s.curve.is_none())
            .map(|s| s.slot)
            .collect()
    }

    /// Live slot ids in compact-rank order.
    pub fn active_slots(&self) -> Vec<usize> {
        self.slots.iter().filter(|s| s.alive).map(|s| s.slot).collect()
    }

    /// Compact-index → slot-id mapping of the *current plan*.
    pub fn slot_map(&self) -> &[usize] {
        &self.slot_map
    }

    /// Curves of the live slots in compact-rank order (requires all
    /// profiles present). Single pass: collects curves until the first
    /// gap, then keeps scanning only to report EVERY missing slot in the
    /// typed error (same contract as [`ElasticPlanner::needs_profile`]).
    pub fn active_curves(&self) -> Result<Vec<PerfCurve>, ElasticError> {
        let mut curves = Vec::new();
        let mut missing = Vec::new();
        for s in self.slots.iter().filter(|s| s.alive) {
            match &s.curve {
                Some(c) if missing.is_empty() => curves.push(c.clone()),
                Some(_) => {}
                None => missing.push(s.slot),
            }
        }
        if !missing.is_empty() {
            return Err(ElasticError::MissingCurves(missing));
        }
        Ok(curves)
    }

    /// True when membership or curves changed since the last replan.
    pub fn dirty(&self) -> bool {
        self.dirty
    }

    /// Force a replan on the next [`ElasticPlanner::replan`] call.
    pub fn mark_dirty(&mut self) {
        self.dirty = true;
    }

    /// Re-run Algorithm 2 over the surviving curve set. Fitted curves are
    /// reused as-is — no re-profiling happens here. Also rebuilds the
    /// optimizer-shard layout and computes the minimal shard-movement set
    /// against the previous layout ([`ElasticPlanner::last_reshard`]).
    ///
    /// With a [`StagePolicy`] installed the ZeRO stage itself is
    /// re-decided first: each candidate stage is checked against the
    /// Alg. 1 memory bound at the new group size and scored with the
    /// amortized migration stall ([`ElasticPlanner::stage_candidates`]);
    /// on a strict win over the incumbent the job migrates — the stage,
    /// every live slot's curve (from the stage-keyed cache; only
    /// fully-measured stages are eligible) and the shard layout all move
    /// together, and the movement is priced by [`ckpt::migrate`] exactly
    /// like a reshard.
    pub fn replan(&mut self, net: &NetSim) -> Result<&Plan, ElasticError> {
        self.last_stage_change = None;

        // the stage search runs BEFORE the all-curves precondition: a
        // joiner that cannot fit (and so cannot be profiled) at the
        // incumbent stage may still be admissible at a measured feasible
        // stage — the search migrates there and the joiner's curve comes
        // from the stage-keyed cache. stage_candidates itself only
        // tolerates missing curves when the incumbent's memory bound is
        // broken, so a merely-unprofiled fleet still errors below.
        if self.policy.is_some() {
            let (chosen, cands) = self.select_stage(net)?;
            if chosen != self.stage {
                // switch only with full measured coverage: collect every
                // live slot's cached curve at the new stage up front so a
                // partial switch can never happen
                let mut swapped: Vec<(usize, PerfCurve)> = Vec::new();
                let mut complete = true;
                for sl in self.slots.iter().filter(|s| s.alive) {
                    match self.cache.peek(&CurveKey::of(sl.gpu, self.model, chosen)) {
                        Some(c) => swapped.push((sl.slot, c.clone())),
                        None => {
                            complete = false;
                            break;
                        }
                    }
                }
                // `chosen` always comes from the candidate set, so the
                // find can only miss on an internal invariant break — in
                // which case the incumbent stage is simply kept
                let chosen_cand = cands.iter().find(|c| c.stage == chosen);
                if let (true, Some(c)) = (complete, chosen_cand) {
                    let from = self.stage;
                    self.stage = chosen;
                    for (slot, healthy_new) in swapped {
                        // carry the live drift factor across the switch:
                        // a straggler's slowdown is a property of the
                        // rank, not of the ZeRO stage — scale the healthy
                        // type curve at the new stage by the observed
                        // factor and keep the override flagged until
                        // drift detection re-measures it there
                        let factor = {
                            let sl = &self.slots[slot];
                            if sl.drifted {
                                let healthy_old =
                                    self.cache.peek(&CurveKey::of(sl.gpu, self.model, from));
                                match (&sl.curve, healthy_old) {
                                    (Some(d), Some(h))
                                        if d.peak_speed() > 0.0 && h.peak_speed() > 0.0 =>
                                    {
                                        h.peak_speed() / d.peak_speed()
                                    }
                                    _ => 1.0,
                                }
                            } else {
                                1.0
                            }
                        };
                        let scaled = if (factor - 1.0).abs() > 1e-9 {
                            scale_curve(&healthy_new, factor)
                        } else {
                            None
                        };
                        let sl = &mut self.slots[slot];
                        match scaled {
                            Some(c) => {
                                sl.curve = Some(c);
                                sl.drifted = true;
                            }
                            None => {
                                sl.curve = Some(healthy_new);
                                sl.drifted = false;
                            }
                        }
                    }
                    self.last_stage_change = Some(StageChange {
                        from,
                        to: chosen,
                        migration_s: c.migration_s,
                        migration_bytes: c.migration_bytes,
                    });
                }
            }
        }
        let curves = self.active_curves()?;

        let plan = match &self.plan {
            Some(prev) => {
                allocator::replan_with_stage(prev, &curves, self.stage, net, self.param_count)
            }
            None => allocator::plan(&curves, self.stage, self.gbs, net, self.param_count),
        }
        .map_err(ElasticError::Plan)?;
        self.slot_map = self.active_slots();

        // shard layout for the new membership (at the possibly new
        // stage), and the movement set from the previous layout (None on
        // the initial plan: the optimizer state is born sharded, nothing
        // moves). `migrate` handles same-stage reshards and cross-stage
        // re-layouts alike.
        let live: Vec<(usize, TypeId)> = self
            .slots
            .iter()
            .filter(|s| s.alive)
            .map(|s| (s.slot, s.gpu))
            .collect();
        let new_manifest =
            ShardManifest::build(&self.model, self.stage, self.param_count, self.replans, &live)
                .map_err(|e| ElasticError::Ckpt(e.to_string()))?;
        self.perf.manifests_built.set(self.perf.manifests_built.get() + 1);
        self.last_reshard = match &self.manifest {
            Some(old) => Some(
                ckpt::migrate(old, &new_manifest)
                    .map_err(|e| ElasticError::Ckpt(e.to_string()))?,
            ),
            None => None,
        };
        self.manifest = Some(new_manifest);

        self.dirty = false;
        self.replans += 1;
        Ok(self.plan.insert(plan))
    }

    /// Would-be outcome of admitting one rank of `gpu`, computed WITHOUT
    /// mutating any planner state — no slot is created, the cache
    /// counters and LRU order stay untouched (curve lookup goes through
    /// [`CurveCache::peek`]), and no manifest or plan is installed. This
    /// is the primitive the autoscale policy (`crate::autoscale`)
    /// evaluates offers with.
    ///
    /// The candidate's curve comes from the type-level cache when
    /// present (`JoinPreview::curve_cached`, zero profiling); otherwise
    /// `fallback` must supply an estimate or the preview fails with
    /// [`ElasticError::NoCurve`].
    ///
    /// `net` is the *current* cost model; the preview re-prices
    /// collectives at the post-admission group size internally
    /// (`JoinPreview::net`). The reshard penalty is measured against the
    /// manifest of the latest replan; any membership events applied
    /// since then are folded into the same hypothetical movement set.
    ///
    /// With a [`StagePolicy`] installed the preview also runs the stage
    /// search over the post-admission fleet: when a fully-measured
    /// candidate stage amortizes better (a high-memory join letting
    /// ZeRO-3 de-escalate, say), the returned preview is priced *at that
    /// stage* (`JoinPreview::stage`), stage-migration movement folded
    /// into its reshard penalty — which can make offers acceptable that
    /// are stall-bound rejects at the incumbent stage.
    pub fn preview_join(
        &self,
        gpu: &str,
        fallback: Option<&PerfCurve>,
        net: &NetSim,
    ) -> Result<JoinPreview, ElasticError> {
        let base = self.preview_join_at(self.stage, gpu, fallback, net)?;
        let Some(policy) = &self.policy else {
            return Ok(base);
        };
        let Some(model_spec) = crate::config::model::preset(&self.model) else {
            return Ok(base);
        };
        let horizon = policy.horizon_s;
        let n_after = self.active_slots().len() + 1;
        // the shared amortized-scoring kernel over a reshard-only ledger
        // (the preview's penalty already folds any stage re-layout in)
        let score = |pv: &JoinPreview| -> f64 {
            let wall = allocator::predicted_wall_s(
                &pv.plan,
                &pv.curves,
                &pv.net,
                self.param_count,
            );
            match wall {
                Ok(w) if w > 0.0 => crate::policy::amortized_score(
                    self.gbs as f64 / w,
                    horizon,
                    &StallLedger::reshard(pv.reshard_penalty_s),
                ),
                _ => 0.0,
            }
        };
        let mut best = base;
        let mut best_score = score(&best);
        // descending like choose_stage: exact ties resolve to the higher
        // (lower-memory) stage; estimate-based stages are never chosen —
        // every type, joiner included, must be measured at the candidate
        for s in (0..=3u8).rev() {
            if s == self.stage {
                continue;
            }
            if !self.stage_feasible(&model_spec, s, n_after, Some(gpu)) {
                continue;
            }
            // cache-only, and only curves measured at the post-admission
            // group size: a preview can neither profile nor tolerate a
            // stale mbs (the (2b) staleness rule, via `measured_at`)
            let measured = |g: &str| self.measured_at(g, s, n_after).is_some();
            if !self.slots.iter().filter(|sl| sl.alive).all(|sl| measured(&sl.gpu))
                || !measured(gpu)
            {
                continue;
            }
            let Ok(pv) = self.preview_join_at(s, gpu, None, net) else {
                continue;
            };
            let sc = score(&pv);
            if sc > best_score {
                best_score = sc;
                best = pv;
            }
        }
        Ok(best)
    }

    /// The single-stage preview primitive behind
    /// [`ElasticPlanner::preview_join`]: admit one rank of `gpu` and
    /// plan at `stage`. A thin wrapper over the batch primitive
    /// [`ElasticPlanner::preview_round_at`]; for the current stage the
    /// live slot curves are used as-is and `fallback` may stand in for
    /// an uncached joiner; for any other stage *every* type must have a
    /// cached curve (`NoCurve` otherwise — estimates are the caller's
    /// policy decision, not this primitive's).
    pub fn preview_join_at(
        &self,
        stage: u8,
        gpu: &str,
        fallback: Option<&PerfCurve>,
        net: &NetSim,
    ) -> Result<JoinPreview, ElasticError> {
        let t = intern::intern(gpu);
        let fallbacks = [fallback.cloned()];
        let rp = self.preview_round_at(stage, &[t], &fallbacks, net)?;
        // the batch primitive appended exactly one joiner curve, so the
        // last entry always exists — but a typed error beats a panic path
        let curve = rp
            .curves
            .last()
            .cloned()
            .ok_or_else(|| ElasticError::NoCurve(gpu.to_string()))?;
        Ok(JoinPreview {
            gpu: t,
            stage,
            curve,
            curve_cached: rp.joiner_cached[0],
            curves: rp.curves,
            plan: rp.plan,
            net: rp.net,
            reshard_penalty_s: rp.reshard_penalty_s,
            reshard_bytes: rp.reshard_bytes,
        })
    }

    /// The batch admission preview: admit one rank of *each* entry of
    /// `gpus` (duplicates allowed) and plan at `stage` — the primitive
    /// behind both [`ElasticPlanner::preview_join_at`] and the joint
    /// round engine (`crate::policy::decide_round`). The whole batch is
    /// admitted in ONE replan, so the shard movement is priced as a
    /// single combined `ckpt::migrate` — which is exactly why a joint
    /// round can afford an offer the sequential rule declines.
    ///
    /// `fallbacks` is parallel to `gpus`: an estimate standing in for a
    /// type uncached at the *current* stage (ignored elsewhere — at a
    /// non-incumbent stage every type must be cached). Pure like
    /// `preview_join`: no planner or cache state moves.
    pub fn preview_round_at(
        &self,
        stage: u8,
        gpus: &[TypeId],
        fallbacks: &[Option<PerfCurve>],
        net: &NetSim,
    ) -> Result<RoundPreview, ElasticError> {
        self.preview_round_at_with(&self.round_index()?, stage, gpus, fallbacks, net)
    }

    /// Build the round-scoped pricing index ONCE per decision round: the
    /// incumbent manifest is validated and interval-indexed a single
    /// time, and the live `(slot, gpu)` snapshot becomes the shared
    /// scratch prefix every candidate layout copies from (a memcpy of
    /// `Copy` pairs — no per-slot heap traffic). Hand the result to
    /// [`ElasticPlanner::preview_round_at_with`] /
    /// [`ElasticPlanner::preview_round_extend_with`] for every candidate
    /// of the round; it goes stale on any planner mutation.
    pub fn round_index(&self) -> Result<RoundIndex<'_>, ElasticError> {
        let mig = match &self.manifest {
            Some(m) => {
                Some(MigrationIndex::new(m).map_err(|e| ElasticError::Ckpt(e.to_string()))?)
            }
            None => None,
        };
        Ok(RoundIndex {
            mig,
            live: self
                .slots
                .iter()
                .filter(|s| s.alive)
                .map(|s| (s.slot, s.gpu))
                .collect(),
            next_slot: self.slots.len(),
            relayout: std::cell::RefCell::new(Vec::new()),
        })
    }

    /// [`ElasticPlanner::preview_round_at`] against a prebuilt
    /// [`RoundIndex`] — the round engine prices every candidate of one
    /// round through the same index instead of re-validating and
    /// re-scanning the incumbent manifest per preview. Byte-identical
    /// results (the property suite pins it).
    pub fn preview_round_at_with(
        &self,
        idx: &RoundIndex<'_>,
        stage: u8,
        gpus: &[TypeId],
        fallbacks: &[Option<PerfCurve>],
        net: &NetSim,
    ) -> Result<RoundPreview, ElasticError> {
        if gpus.len() != fallbacks.len() {
            return Err(ElasticError::FallbackLen {
                gpus: gpus.len(),
                fallbacks: fallbacks.len(),
            });
        }
        let mut curves = if stage == self.stage {
            self.active_curves()?
        } else {
            // stage-keyed cache lookup per live slot; missing coverage is
            // a typed error the stage-search wrapper skips over
            let _ = self.active_curves()?;
            self.slots
                .iter()
                .filter(|s| s.alive)
                .map(|s| {
                    self.cache
                        .peek(&CurveKey::of(s.gpu, self.model, stage))
                        .cloned()
                        .ok_or_else(|| ElasticError::NoCurve(s.gpu.to_string()))
                })
                .collect::<Result<Vec<_>, _>>()?
        };
        let mut joiner_cached = Vec::with_capacity(gpus.len());
        for (i, &gpu) in gpus.iter().enumerate() {
            let key = CurveKey::of(gpu, self.model, stage);
            let (curve, cached) = match self.cache.peek(&key) {
                Some(c) => (c.clone(), true),
                None => match fallbacks
                    .get(i)
                    .and_then(|f| f.as_ref())
                    .filter(|_| stage == self.stage)
                {
                    Some(c) => ((*c).clone(), false),
                    None => return Err(ElasticError::NoCurve(gpu.to_string())),
                },
            };
            joiner_cached.push(cached);
            curves.push(curve);
        }

        let mut net_after = net.clone();
        net_after.n = curves.len();
        let plan = match &self.plan {
            Some(prev) => {
                allocator::replan_with_stage(prev, &curves, stage, &net_after, self.param_count)
            }
            None => allocator::plan(&curves, stage, self.gbs, &net_after, self.param_count),
        }
        .map_err(ElasticError::Plan)?;

        // hypothetical shard layout: the shared live snapshot plus the
        // joiners at the slot ids consecutive add_slot() calls would
        // assign
        let mut live = idx.live.clone();
        live.reserve(gpus.len());
        for (i, &gpu) in gpus.iter().enumerate() {
            live.push((idx.next_slot + i, gpu));
        }
        let manifest =
            ShardManifest::build(&self.model, stage, self.param_count, self.replans, &live)
                .map_err(|e| ElasticError::Ckpt(e.to_string()))?;
        self.perf.manifests_built.set(self.perf.manifests_built.get() + 1);
        let (reshard_penalty_s, reshard_bytes, migration_only_s) = match &idx.mig {
            Some(ix) => {
                // indexed migrate: folds a cross-stage re-layout and the
                // batch's membership movement into one priced set
                let r = ix
                    .migrate_to(&manifest)
                    .map_err(|e| ElasticError::Ckpt(e.to_string()))?;
                let total = r.transfer_time_s(&net_after);
                // itemize the pure stage re-layout (same membership, new
                // stage) so the stall ledger can say why the round stalls
                let mig = idx.migration_only_s(stage, &net_after).min(total);
                (total, r.bytes_moved(), mig)
            }
            // no plan yet: the state would be born sharded, nothing moves
            None => (0.0, 0, 0.0),
        };
        self.perf.previews_priced.set(self.perf.previews_priced.get() + 1);

        Ok(RoundPreview {
            stage,
            gpus: gpus.to_vec(),
            joiner_cached,
            curves,
            plan,
            net: net_after,
            manifest,
            reshard_penalty_s,
            reshard_bytes,
            migration_only_s,
        })
    }

    /// Extend a prior [`RoundPreview`] by ONE more joiner — the delta
    /// path behind the round engine's greedy search. Instead of
    /// re-walking the slot table, re-peeking every prior joiner's curve
    /// and re-deriving the predicted slot list, it reuses the prior
    /// preview's curves, joiner flags and manifest slot order, appends
    /// the one new member (at the next predicted slot id), and re-prices
    /// the movement set. The result is *identical* to calling
    /// [`ElasticPlanner::preview_round_at`] on the grown batch — the
    /// equivalence property tests pin bytes, seconds and the manifest —
    /// because shard tiling boundaries shift for every rank when the
    /// group grows, so the plan, manifest and movement set are recomputed
    /// from the reused inputs rather than patched.
    ///
    /// `prev` must come from this planner in its current state with the
    /// same `stage` semantics (callers re-evaluate from scratch across
    /// planner mutations); `fallback` plays the same role as one
    /// `fallbacks` entry of the batch primitive.
    pub fn preview_round_extend(
        &self,
        prev: &RoundPreview,
        gpu: impl Into<TypeId>,
        fallback: Option<&PerfCurve>,
        net: &NetSim,
    ) -> Result<RoundPreview, ElasticError> {
        self.preview_round_extend_with(&self.round_index()?, prev, gpu.into(), fallback, net)
    }

    /// [`ElasticPlanner::preview_round_extend`] against a prebuilt
    /// [`RoundIndex`] — the delta path the greedy round search actually
    /// runs, one indexed pricing per growth step.
    pub fn preview_round_extend_with(
        &self,
        idx: &RoundIndex<'_>,
        prev: &RoundPreview,
        gpu: TypeId,
        fallback: Option<&PerfCurve>,
        net: &NetSim,
    ) -> Result<RoundPreview, ElasticError> {
        let stage = prev.stage;
        let key = CurveKey::of(gpu, self.model, stage);
        let (curve, cached) = match self.cache.peek(&key) {
            Some(c) => (c.clone(), true),
            None => match fallback.filter(|_| stage == self.stage) {
                Some(c) => ((*c).clone(), false),
                None => return Err(ElasticError::NoCurve(gpu.to_string())),
            },
        };
        let mut gpus = prev.gpus.clone();
        gpus.push(gpu);
        let mut joiner_cached = prev.joiner_cached.clone();
        joiner_cached.push(cached);
        let mut curves = prev.curves.clone();
        curves.push(curve);

        let mut net_after = net.clone();
        net_after.n = curves.len();
        let plan = match &self.plan {
            Some(p) => {
                allocator::replan_with_stage(p, &curves, stage, &net_after, self.param_count)
            }
            None => allocator::plan(&curves, stage, self.gbs, &net_after, self.param_count),
        }
        .map_err(ElasticError::Plan)?;

        // the prior preview's manifest already lists live slots + prior
        // joiners in slot order; the new joiner takes the next id the
        // batch path would predict
        let mut live: Vec<(usize, TypeId)> =
            prev.manifest.shards.iter().map(|e| (e.slot, e.gpu)).collect();
        live.push((idx.next_slot + prev.gpus.len(), gpu));
        let manifest =
            ShardManifest::build(&self.model, stage, self.param_count, self.replans, &live)
                .map_err(|e| ElasticError::Ckpt(e.to_string()))?;
        self.perf.manifests_built.set(self.perf.manifests_built.get() + 1);
        let (reshard_penalty_s, reshard_bytes, migration_only_s) = match &idx.mig {
            Some(ix) => {
                let r = ix
                    .migrate_to(&manifest)
                    .map_err(|e| ElasticError::Ckpt(e.to_string()))?;
                let total = r.transfer_time_s(&net_after);
                let mig = idx.migration_only_s(stage, &net_after).min(total);
                (total, r.bytes_moved(), mig)
            }
            None => (0.0, 0, 0.0),
        };
        self.perf.previews_priced.set(self.perf.previews_priced.get() + 1);

        Ok(RoundPreview {
            stage,
            gpus,
            joiner_cached,
            curves,
            plan,
            net: net_after,
            manifest,
            reshard_penalty_s,
            reshard_bytes,
            migration_only_s,
        })
    }

    /// Pure what-if of *releasing* a live rank (scale-down): the plan
    /// over the survivors at the current stage, plus the measured cost
    /// of re-absorbing the released rank's optimizer shard. The round
    /// engine's `Release` arm prices candidates with this; nothing in
    /// the planner moves.
    pub fn preview_release(
        &self,
        slot: usize,
        net: &NetSim,
    ) -> Result<ReleasePreview, ElasticError> {
        let s = self.slots.get(slot).ok_or(ElasticError::UnknownSlot(slot))?;
        if !s.alive {
            return Err(ElasticError::DeadSlot(slot));
        }
        let gpu = s.gpu;
        let mut curves = Vec::new();
        let mut live: Vec<(usize, TypeId)> = Vec::new();
        for sl in self.slots.iter().filter(|x| x.alive && x.slot != slot) {
            match &sl.curve {
                Some(c) => curves.push(c.clone()),
                None => return Err(ElasticError::MissingCurves(vec![sl.slot])),
            }
            live.push((sl.slot, sl.gpu));
        }
        if curves.is_empty() {
            return Err(ElasticError::LastRank);
        }
        let mut net_after = net.clone();
        net_after.n = curves.len();
        let plan = match &self.plan {
            Some(prev) => allocator::replan_with_stage(
                prev,
                &curves,
                self.stage,
                &net_after,
                self.param_count,
            ),
            None => {
                allocator::plan(&curves, self.stage, self.gbs, &net_after, self.param_count)
            }
        }
        .map_err(ElasticError::Plan)?;
        let manifest =
            ShardManifest::build(&self.model, self.stage, self.param_count, self.replans, &live)
                .map_err(|e| ElasticError::Ckpt(e.to_string()))?;
        self.perf.manifests_built.set(self.perf.manifests_built.get() + 1);
        let (reshard_penalty_s, reshard_bytes) = match &self.manifest {
            Some(old) => {
                let r = ckpt::migrate(old, &manifest)
                    .map_err(|e| ElasticError::Ckpt(e.to_string()))?;
                (r.transfer_time_s(&net_after), r.bytes_moved())
            }
            None => (0.0, 0),
        };
        self.perf.previews_priced.set(self.perf.previews_priced.get() + 1);
        Ok(ReleasePreview {
            slot,
            gpu,
            curves,
            plan,
            net: net_after,
            reshard_penalty_s,
            reshard_bytes,
        })
    }

    /// The optimizer-shard layout of the current plan.
    pub fn manifest(&self) -> Option<&ShardManifest> {
        self.manifest.as_ref()
    }

    /// The shard-movement set computed by the latest replan (`None` on
    /// the initial plan).
    pub fn last_reshard(&self) -> Option<&ReshardPlan> {
        self.last_reshard.as_ref()
    }

    /// The movement the latest replan actually pays, honest about
    /// checkpoint availability: the minimal set when every byte has a
    /// source (`checkpointed`, or nothing was lost), else the
    /// full-restore baseline — without a persisted checkpoint a departed
    /// rank's shard is unrecoverable in place and the whole state must
    /// be rebuilt. Borrows in the common case; only the fallback builds
    /// a plan.
    fn effective_reshard(&self, checkpointed: bool) -> Option<std::borrow::Cow<'_, ReshardPlan>> {
        let r = self.last_reshard.as_ref()?;
        if !checkpointed && r.bytes_from_checkpoint() > 0 {
            let full = ReshardPlan::full_restore(self.manifest.as_ref()?);
            return Some(std::borrow::Cow::Owned(full));
        }
        Some(std::borrow::Cow::Borrowed(r))
    }

    /// Measured one-shot resharding cost of the latest replan: derived
    /// from the bytes that actually changed owner, zero when the layout
    /// is unchanged (pure drift replans) or on the initial plan.
    /// `checkpointed` says whether shard manifests are persisted — when
    /// they are not and the change lost bytes, the cost falls back to
    /// the full-restore baseline instead of pricing restores off a
    /// checkpoint that does not exist.
    pub fn reshard_penalty_s(&self, net: &NetSim, checkpointed: bool) -> f64 {
        self.effective_reshard(checkpointed).map_or(0.0, |r| r.transfer_time_s(net))
    }

    /// Optimizer-state bytes the latest replan actually moves, under the
    /// same checkpoint-availability rule as
    /// [`ElasticPlanner::reshard_penalty_s`].
    pub fn reshard_bytes(&self, checkpointed: bool) -> u64 {
        self.effective_reshard(checkpointed).map_or(0, |r| r.bytes_moved())
    }

    /// The current plan, if one was computed.
    pub fn plan(&self) -> Option<&Plan> {
        self.plan.as_ref()
    }

    /// Number of (re)plans so far.
    pub fn replans(&self) -> usize {
        self.replans
    }

    /// Slot states (diagnostics / tests).
    pub fn slots(&self) -> &[SlotState] {
        &self.slots
    }

    /// The shared curve cache (diagnostics / tests).
    pub fn cache(&self) -> &CurveCache {
        &self.cache
    }
}

/// Round-scoped pricing index: the incumbent-side state every preview
/// of ONE decision round shares, built once by
/// [`ElasticPlanner::round_index`]. Holds the validated interval index
/// over the incumbent manifest, the live `(slot, gpu)` scratch prefix
/// candidate layouts copy from, and a per-stage memo of the pure
/// cross-stage re-layout plan (so `migration_only_s` itemization stops
/// re-deriving a manifest per preview). Stale after any planner
/// mutation — rebuild per round.
#[derive(Debug)]
pub struct RoundIndex<'a> {
    mig: Option<MigrationIndex<'a>>,
    /// Live `(slot, gpu)` pairs in slot order.
    live: Vec<(usize, TypeId)>,
    /// Slot id the next joiner would be assigned (slots are
    /// append-only).
    next_slot: usize,
    /// Per-stage memo of the pure re-layout plan (≤ 4 entries, linear
    /// scan; `None` payload = the re-layout itself failed, priced 0).
    relayout: std::cell::RefCell<Vec<(u8, Option<ReshardPlan>)>>,
}

impl RoundIndex<'_> {
    /// The pure cross-stage re-layout (same membership, new stage)
    /// priced alone at `net_after` — 0 at the incumbent stage. The plan
    /// is derived once per (round, stage) and memoized; only the
    /// group-size-dependent transfer time is recomputed per preview.
    fn migration_only_s(&self, stage: u8, net_after: &NetSim) -> f64 {
        let Some(ix) = &self.mig else { return 0.0 };
        let old = ix.old();
        if stage == old.stage {
            return 0.0;
        }
        let mut memo = self.relayout.borrow_mut();
        if !memo.iter().any(|(s, _)| *s == stage) {
            let plan = old.migrate(stage).map(|(_, p)| p).ok();
            memo.push((stage, plan));
        }
        memo.iter()
            .find(|(s, _)| *s == stage)
            .and_then(|(_, p)| p.as_ref())
            .map(|p| p.transfer_time_s(net_after))
            .unwrap_or(0.0)
    }
}

/// Everything [`ElasticPlanner::preview_join`] predicts about admitting
/// one candidate rank — a pure what-if: nothing in the planner moved.
#[derive(Debug, Clone)]
pub struct JoinPreview {
    /// Interned catalog GPU type of the candidate.
    pub gpu: TypeId,
    /// ZeRO stage the preview is priced at — the planner's current stage
    /// unless a [`StagePolicy`] found a better one for the
    /// post-admission fleet.
    pub stage: u8,
    /// The candidate's curve the prediction used (cached or
    /// caller-supplied), at [`JoinPreview::stage`].
    pub curve: PerfCurve,
    /// True when the curve came from the type-level cache — the
    /// candidate could be admitted with zero profiling calls.
    pub curve_cached: bool,
    /// The full post-admission curve set in plan-rank order (live ranks
    /// then the candidate), all at [`JoinPreview::stage`] — what wall
    /// predictions over [`JoinPreview::plan`] must use.
    pub curves: Vec<PerfCurve>,
    /// The would-be Algorithm 2 plan over live ranks + the candidate.
    pub plan: Plan,
    /// Collective cost model at the post-admission group size.
    pub net: NetSim,
    /// Measured one-shot optimizer-state movement cost of the admission
    /// (`ckpt::migrate` against the current layout — any stage change
    /// the preview selected is folded in).
    pub reshard_penalty_s: f64,
    /// Optimizer-state bytes that movement touches.
    pub reshard_bytes: u64,
}

/// Everything [`ElasticPlanner::preview_round_at`] predicts about
/// admitting a batch of candidate ranks in one replan — a pure what-if.
#[derive(Debug, Clone)]
pub struct RoundPreview {
    /// ZeRO stage the preview is priced at.
    pub stage: u8,
    /// Interned catalog GPU types of the batch, input order.
    pub gpus: Vec<TypeId>,
    /// Per-joiner: true when the curve came from the type-level cache
    /// (admissible with zero profiling calls), parallel to `gpus`.
    pub joiner_cached: Vec<bool>,
    /// The full post-admission curve set in plan-rank order (live ranks
    /// then the joiners in batch order).
    pub curves: Vec<PerfCurve>,
    /// The would-be Algorithm 2 plan over live ranks + the batch.
    pub plan: Plan,
    /// Collective cost model at the post-admission group size.
    pub net: NetSim,
    /// The predicted post-admission shard layout (live slots, then the
    /// joiners at the slot ids consecutive `add_slot()` calls would
    /// assign) — the layout `reshard_penalty_s` was priced against, and
    /// exactly what the planner builds after admitting this batch (a
    /// property test pins the equality).
    pub manifest: ShardManifest,
    /// Measured one-shot movement cost of the whole batch admission
    /// (ONE combined `ckpt::migrate`, any stage re-layout folded in).
    pub reshard_penalty_s: f64,
    /// Optimizer-state bytes that movement touches.
    pub reshard_bytes: u64,
    /// The pure cross-stage re-layout priced alone (0 at the incumbent
    /// stage) — the stall ledger's migration item; the membership share
    /// is `reshard_penalty_s - migration_only_s`.
    pub migration_only_s: f64,
}

/// Everything [`ElasticPlanner::preview_release`] predicts about
/// releasing one paid rank — a pure what-if.
#[derive(Debug, Clone)]
pub struct ReleasePreview {
    /// Leader slot id of the released rank.
    pub slot: usize,
    /// Interned catalog GPU type of the released rank.
    pub gpu: TypeId,
    /// Survivor curves in plan-rank order.
    pub curves: Vec<PerfCurve>,
    /// The would-be Algorithm 2 plan over the survivors.
    pub plan: Plan,
    /// Collective cost model at the post-release group size.
    pub net: NetSim,
    /// Measured one-shot cost of re-absorbing the released shard.
    pub reshard_penalty_s: f64,
    /// Optimizer-state bytes that movement touches.
    pub reshard_bytes: u64,
}

/// Scale a performance curve's step times by `factor` (finite, > 0) and
/// refit — used to carry a rank-local drift override across a stage
/// switch instead of silently resetting the straggler to the healthy
/// type curve. `None` when the factor is unusable or the refit fails.
fn scale_curve(c: &PerfCurve, factor: f64) -> Option<PerfCurve> {
    if !(factor.is_finite() && factor > 0.0) {
        return None;
    }
    let pts: Vec<ProfiledPoint> = c
        .points()
        .iter()
        .map(|p| ProfiledPoint { batch: p.batch, step_time_s: p.step_time_s * factor })
        .collect();
    PerfCurve::fit(pts, c.mbs()).ok()
}

/// Compare observed per-micro-step compute times against the fitted
/// curves and return the *compact* rank indices whose relative deviation
/// exceeds `threshold`. Ranks that processed no samples are skipped.
///
/// `curves` and `per_rank_steps` must be parallel to `plan.ranks`: a
/// length mismatch is a wiring bug upstream (the caller zipped state
/// from two different plans), not a rank to silently ignore — it
/// debug-asserts, and in release builds the affected ranks are skipped
/// with a logged warning so one bad report cannot take the job down.
pub fn detect_drift(
    plan: &Plan,
    curves: &[PerfCurve],
    per_rank_steps: &[Vec<f64>],
    threshold: f64,
) -> Vec<usize> {
    debug_assert!(
        curves.len() == plan.ranks.len() && per_rank_steps.len() == plan.ranks.len(),
        "detect_drift wiring bug: {} ranks but {} curves / {} step reports",
        plan.ranks.len(),
        curves.len(),
        per_rank_steps.len()
    );
    let mut drifted = Vec::new();
    for (i, r) in plan.ranks.iter().enumerate() {
        if i >= curves.len() || i >= per_rank_steps.len() {
            eprintln!(
                "[elastic] detect_drift: skipping rank {i} — only {} curves / {} step \
                 reports for a {}-rank plan (stale wiring upstream)",
                curves.len(),
                per_rank_steps.len(),
                plan.ranks.len()
            );
            continue;
        }
        if r.grad_accum_steps == 0 {
            continue;
        }
        let predicted = allocator::rank_compute_time(r, &curves[i]);
        let observed: f64 = per_rank_steps[i].iter().sum();
        if predicted <= 0.0 {
            continue;
        }
        let ratio = observed / predicted;
        if (ratio - 1.0).abs() > threshold {
            drifted.push(i);
        }
    }
    drifted
}

/// The fabric-side twin of [`detect_drift`]: compare one iteration's
/// *observed* collective time against the prediction at the planner's
/// current bandwidth estimate. Returns `Some(observed / predicted)` when
/// the relative deviation exceeds `threshold` (use
/// [`DEFAULT_DRIFT_THRESHOLD`] for symmetry with the compute path).
///
/// A flagged iteration is a *hint*, not a replan: callers feed the
/// sample to `netsim::BwMonitor::observe`, whose Startup/Degrade/Steady/
/// Probe state machine only marks the plan stale on a sustained shift —
/// a single noisy collective never replans.
pub fn detect_comm_drift(predicted_s: f64, observed_s: f64, threshold: f64) -> Option<f64> {
    if !predicted_s.is_finite() || !observed_s.is_finite() || predicted_s <= 0.0 || observed_s < 0.0
    {
        return None;
    }
    let ratio = observed_s / predicted_s;
    ((ratio - 1.0).abs() > threshold).then_some(ratio)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::catalog;
    use crate::cluster::LinkKind;
    use crate::config::model::preset;
    use crate::curves::ProfiledPoint;

    #[test]
    fn comm_drift_fires_symmetrically_and_guards_degenerates() {
        // congestion (slower than predicted) and recovery (faster than the
        // degraded prediction) both flag — the detector is symmetric
        let r = detect_comm_drift(1.0, 1.5, DEFAULT_DRIFT_THRESHOLD).unwrap();
        assert!((r - 1.5).abs() < 1e-12);
        let r = detect_comm_drift(1.0, 0.5, DEFAULT_DRIFT_THRESHOLD).unwrap();
        assert!((r - 0.5).abs() < 1e-12);
        // inside the band: quiet
        assert_eq!(detect_comm_drift(1.0, 1.1, DEFAULT_DRIFT_THRESHOLD), None);
        assert_eq!(detect_comm_drift(1.0, 0.9, DEFAULT_DRIFT_THRESHOLD), None);
        // degenerate inputs never flag (ZeRO-3 has zero sync-point comm)
        assert_eq!(detect_comm_drift(0.0, 1.0, DEFAULT_DRIFT_THRESHOLD), None);
        assert_eq!(detect_comm_drift(-1.0, 1.0, DEFAULT_DRIFT_THRESHOLD), None);
        assert_eq!(detect_comm_drift(f64::NAN, 1.0, DEFAULT_DRIFT_THRESHOLD), None);
        assert_eq!(detect_comm_drift(1.0, f64::INFINITY, DEFAULT_DRIFT_THRESHOLD), None);
    }

    #[test]
    fn bw_drift_event_is_validated_noop_on_planner() {
        let mut p = ElasticPlanner::new(1, 64, "llama-0.5b", 500_000_000, 16);
        p.add_slot("A800-80G");
        p.add_slot("V100S-32G");
        let before_dirty = p.dirty();
        // valid event: accepted, membership untouched, does not re-dirty
        p.apply(&ElasticEvent::BwDrift { link: "socket".into(), factor: 0.25 }).unwrap();
        assert_eq!(p.active_slots().len(), 2);
        assert_eq!(p.dirty(), before_dirty);
        // invalid factor / link: typed errors
        for bad in [0.0, -1.0, f64::NAN, f64::INFINITY] {
            assert!(matches!(
                p.apply(&ElasticEvent::BwDrift { link: "socket".into(), factor: bad }),
                Err(ElasticError::BwDrift(_))
            ));
        }
        assert!(matches!(
            p.apply(&ElasticEvent::BwDrift { link: "ethernet".into(), factor: 0.5 }),
            Err(ElasticError::BwDrift(_))
        ));
    }

    fn device_curve(gpu: &str, mbs: usize) -> PerfCurve {
        let g = catalog::spec_or_panic(gpu);
        let m = preset("llama-0.5b").unwrap();
        let pts: Vec<ProfiledPoint> = (1..=mbs)
            .map(|b| ProfiledPoint {
                batch: b,
                step_time_s: g.compute_time(
                    (b as u64 * m.seq) as f64,
                    m.flops_per_token(),
                    m.n_layers as usize,
                ),
            })
            .collect();
        PerfCurve::fit(pts, mbs).unwrap()
    }

    fn planner_with(gpus: &[(&str, usize)]) -> ElasticPlanner {
        let m = preset("llama-0.5b").unwrap();
        let mut p = ElasticPlanner::new(1, 256, &m.name, m.param_count(), 16);
        for &(gpu, mbs) in gpus {
            let slot = p.add_slot(gpu);
            if p.needs_profile().contains(&slot) {
                p.install_curve(slot, device_curve(gpu, mbs), false).unwrap();
            }
        }
        p
    }

    #[test]
    fn initial_plan_covers_gbs() {
        let mut p = planner_with(&[("A800-80G", 48), ("V100S-32G", 16)]);
        let net = NetSim::from_link(2, LinkKind::Ib);
        let plan = p.replan(&net).unwrap();
        assert_eq!(plan.total_samples(), 256);
        plan.validate().unwrap();
        assert_eq!(p.slot_map(), &[0, 1]);
    }

    #[test]
    fn lost_rank_leaves_plan_over_survivors() {
        let mut p = planner_with(&[("A800-80G", 48), ("A800-80G", 48), ("V100S-32G", 16)]);
        let net = NetSim::from_link(3, LinkKind::Ib);
        p.replan(&net).unwrap();
        p.apply(&ElasticEvent::RankLost { slot: 1 }).unwrap();
        assert!(p.dirty());
        let plan = p.replan(&NetSim::from_link(2, LinkKind::Ib)).unwrap();
        assert_eq!(plan.ranks.len(), 2);
        assert_eq!(plan.total_samples(), 256);
        assert_eq!(p.slot_map(), &[0, 2], "compact ranks map to surviving slots");
    }

    #[test]
    fn rejoin_of_known_type_hits_cache() {
        let mut p = planner_with(&[("A800-80G", 48), ("V100S-32G", 16)]);
        p.lose_slot(1).unwrap();
        let slot = p.add_slot("V100S-32G");
        assert_eq!(slot, 2);
        assert!(p.needs_profile().is_empty(), "cached curve must skip re-profiling");
        assert!(p.cache().hits() >= 1);
    }

    #[test]
    fn join_of_unknown_type_needs_profile() {
        let mut p = planner_with(&[("A800-80G", 48)]);
        let slot = p.add_slot("T4");
        assert_eq!(p.needs_profile(), vec![slot]);
        let net = NetSim::from_link(2, LinkKind::Ib);
        assert!(matches!(p.replan(&net), Err(ElasticError::MissingCurves(_))));
        p.install_curve(slot, device_curve("T4", 8), false).unwrap();
        p.replan(&net).unwrap();
    }

    #[test]
    fn cannot_lose_last_rank_or_dead_slot() {
        let mut p = planner_with(&[("A800-80G", 48), ("V100S-32G", 16)]);
        p.lose_slot(0).unwrap();
        assert_eq!(p.lose_slot(0), Err(ElasticError::DeadSlot(0)));
        assert_eq!(p.lose_slot(1), Err(ElasticError::LastRank));
        assert_eq!(p.lose_slot(9), Err(ElasticError::UnknownSlot(9)));
    }

    #[test]
    fn drift_curve_stays_out_of_cache() {
        let mut p = planner_with(&[("A800-80G", 48), ("A800-80G", 48)]);
        // install a straggler override for slot 0
        let slow: Vec<ProfiledPoint> = device_curve("A800-80G", 48)
            .points()
            .iter()
            .map(|pt| ProfiledPoint { batch: pt.batch, step_time_s: pt.step_time_s * 2.0 })
            .collect();
        p.install_curve(0, PerfCurve::fit(slow, 48).unwrap(), true).unwrap();
        assert!(p.slots()[0].drifted);
        // a fresh join of the same type must get the healthy cached curve
        let slot = p.add_slot("A800-80G");
        assert!(p.needs_profile().is_empty());
        let joined_peak = p.slots()[slot].curve.as_ref().unwrap().peak_speed();
        let straggler_peak = p.slots()[0].curve.as_ref().unwrap().peak_speed();
        assert!(joined_peak > straggler_peak * 1.5, "cache must keep the healthy curve");
    }

    #[test]
    fn detect_drift_flags_only_the_straggler() {
        let curves = vec![device_curve("A800-80G", 48), device_curve("V100S-32G", 16)];
        let net = NetSim::from_link(2, LinkKind::Ib);
        let m = preset("llama-0.5b").unwrap();
        let plan = allocator::plan(&curves, 1, 256, &net, m.param_count()).unwrap();
        // observed = predicted for rank 1; rank 0 runs 2x slow
        let steps: Vec<Vec<f64>> = plan
            .ranks
            .iter()
            .enumerate()
            .map(|(i, r)| {
                let scale = if i == 0 { 2.0 } else { 1.0 };
                let mut v = vec![curves[i].time_at(r.micro_batch as f64) * scale;
                                 r.grad_accum_steps.saturating_sub(1)];
                v.push(curves[i].time_at(r.last_batch as f64) * scale);
                v
            })
            .collect();
        assert_eq!(detect_drift(&plan, &curves, &steps, 0.15), vec![0]);
        assert!(detect_drift(&plan, &curves, &steps, 1.5).is_empty());
    }

    #[test]
    fn measured_reshard_penalty_only_on_membership_change() {
        let mut p = planner_with(&[
            ("A800-80G", 48),
            ("A800-80G", 48),
            ("V100S-32G", 16),
            ("V100S-32G", 16),
        ]);
        let net4 = NetSim::from_link(4, LinkKind::Ib);
        p.replan(&net4).unwrap();
        // initial plan: the state is born sharded, nothing moves
        assert!(p.last_reshard().is_none());
        assert_eq!(p.reshard_penalty_s(&net4, true), 0.0);
        let m0 = p.manifest().unwrap().clone();
        m0.validate().unwrap();
        assert_eq!(m0.shards.len(), 4);

        // pure drift replan: same membership, same layout, zero penalty
        p.mark_dirty();
        p.replan(&net4).unwrap();
        assert!(p.last_reshard().unwrap().is_noop());
        assert_eq!(p.reshard_penalty_s(&net4, true), 0.0);
        assert_eq!(p.reshard_penalty_s(&net4, false), 0.0, "nothing lost: no fallback");

        // a loss moves only the bytes whose owner changed — strictly
        // cheaper than the full-restore recompute baseline
        p.lose_slot(3).unwrap();
        let net3 = NetSim::from_link(3, LinkKind::Ib);
        p.replan(&net3).unwrap();
        let reshard = p.last_reshard().unwrap();
        assert!(!reshard.is_noop());
        assert!(p.reshard_penalty_s(&net3, true) > 0.0);
        let recompute = crate::ckpt::ReshardPlan::full_restore(p.manifest().unwrap());
        assert!(reshard.bytes_moved() < recompute.bytes_moved());
        assert!(reshard.transfer_time_s(&net3) < recompute.transfer_time_s(&net3));
        // the lost slot's shard comes off the checkpoint, not a peer
        assert!(reshard.bytes_from_checkpoint() > 0);
        // without a persisted checkpoint those bytes are unrecoverable:
        // the honest price is the full-restore baseline
        assert_eq!(p.reshard_bytes(false), recompute.bytes_moved());
        assert!(p.reshard_penalty_s(&net3, false) >= p.reshard_penalty_s(&net3, true));
        // with one, the minimal measured set applies
        assert_eq!(p.reshard_bytes(true), reshard.bytes_moved());
    }

    #[test]
    fn late_profile_reply_for_departed_rank_is_dropped() {
        // regression: install_curve used to accept a dead slot silently —
        // inserting into the shared type-level cache, marking the planner
        // dirty and forcing a spurious replan
        let mut p = planner_with(&[("A800-80G", 48), ("V100S-32G", 16)]);
        let net = NetSim::from_link(2, LinkKind::Ib);
        p.replan(&net).unwrap();
        assert!(!p.dirty());
        p.lose_slot(1).unwrap();
        p.replan(&NetSim::from_link(1, LinkKind::Ib)).unwrap();
        assert!(!p.dirty());
        let (hits0, misses0) = (p.cache().hits(), p.cache().misses());
        let cache_len0 = p.cache().len();

        // the departed rank's profile reply arrives now: a poisoned curve
        // (say the rank was dying while it measured — 10x slow)
        let slow: Vec<ProfiledPoint> = device_curve("V100S-32G", 16)
            .points()
            .iter()
            .map(|pt| ProfiledPoint { batch: pt.batch, step_time_s: pt.step_time_s * 10.0 })
            .collect();
        let poisoned = PerfCurve::fit(slow, 16).unwrap();
        assert_eq!(
            p.install_curve(1, poisoned.clone(), false),
            Err(ElasticError::DeadSlot(1))
        );
        assert_eq!(p.install_curve(99, poisoned, false), Err(ElasticError::UnknownSlot(99)));

        // nothing changed: no dirty flag, no spurious replan pending, the
        // cached V100S curve is still the healthy one
        assert!(!p.dirty(), "a dropped reply must not force a replan");
        assert_eq!(p.cache().len(), cache_len0);
        assert_eq!((p.cache().hits(), p.cache().misses()), (hits0, misses0));
        let slot = p.add_slot("V100S-32G");
        let rejoined_peak = p.slots()[slot].curve.as_ref().unwrap().peak_speed();
        let healthy_peak = device_curve("V100S-32G", 16).peak_speed();
        assert!(
            (rejoined_peak - healthy_peak).abs() / healthy_peak < 1e-9,
            "cache must still hold the healthy curve"
        );
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "detect_drift wiring bug")]
    fn detect_drift_length_mismatch_is_a_wiring_bug() {
        let curves = vec![device_curve("A800-80G", 48), device_curve("V100S-32G", 16)];
        let net = NetSim::from_link(2, LinkKind::Ib);
        let m = preset("llama-0.5b").unwrap();
        let plan = allocator::plan(&curves, 1, 256, &net, m.param_count()).unwrap();
        // a curve vector from some other plan: one entry short
        detect_drift(&plan, &curves[..1], &[vec![0.1], vec![0.1]], 0.15);
    }

    #[test]
    fn preview_join_predicts_without_mutating() {
        let mut p = planner_with(&[("A800-80G", 48), ("V100S-32G", 16)]);
        let net = NetSim::from_link(2, LinkKind::Ib);
        p.replan(&net).unwrap();
        let slots0 = p.slots().len();
        let (hits0, misses0) = (p.cache().hits(), p.cache().misses());
        let lru0: Vec<CurveKey> = p.cache().lru_order().to_vec();
        let replans0 = p.replans();
        let manifest0 = p.manifest().unwrap().clone();

        // cached type: preview works with zero profiling and no fallback
        let pv = p.preview_join("A800-80G", None, &net).unwrap();
        assert!(pv.curve_cached);
        assert_eq!(pv.plan.ranks.len(), 3);
        assert_eq!(pv.plan.total_samples(), 256);
        assert_eq!(pv.net.n, 3);
        // a join moves the joiner's shard: measured, non-zero, less than
        // the full state
        assert!(pv.reshard_penalty_s > 0.0);
        assert!(pv.reshard_bytes > 0);
        let m = preset("llama-0.5b").unwrap();
        assert!(pv.reshard_bytes < 12 * m.param_count());

        // unknown type without an estimate: typed error
        assert!(matches!(
            p.preview_join("T4", None, &net),
            Err(ElasticError::NoCurve(g)) if g == "T4"
        ));
        // with an estimate it previews, flagged as such
        let est = device_curve("T4", 8);
        let pv2 = p.preview_join("T4", Some(&est), &net).unwrap();
        assert!(!pv2.curve_cached);
        assert_eq!(pv2.plan.ranks.len(), 3);

        // NOTHING moved: no slots, no replans, no cache traffic, no LRU
        // reordering, same manifest
        assert_eq!(p.slots().len(), slots0);
        assert_eq!(p.replans(), replans0);
        assert!(!p.dirty());
        assert_eq!((p.cache().hits(), p.cache().misses()), (hits0, misses0));
        assert_eq!(p.cache().lru_order(), lru0.as_slice());
        assert_eq!(p.manifest().unwrap(), &manifest0);
    }

    #[test]
    fn preview_release_predicts_without_mutating() {
        let mut p = planner_with(&[("A800-80G", 48), ("A800-80G", 48), ("V100S-32G", 16)]);
        let net = NetSim::from_link(3, LinkKind::Ib);
        p.replan(&net).unwrap();
        let manifest0 = p.manifest().unwrap().clone();
        let (hits0, misses0) = (p.cache().hits(), p.cache().misses());
        let pv = p.preview_release(2, &net).unwrap();
        assert_eq!(pv.gpu, "V100S-32G");
        assert_eq!(pv.slot, 2);
        assert_eq!(pv.plan.ranks.len(), 2);
        assert_eq!(pv.plan.total_samples(), 256);
        assert_eq!(pv.net.n, 2);
        // the released rank's shard must be re-absorbed: bytes move
        assert!(pv.reshard_penalty_s > 0.0);
        assert!(pv.reshard_bytes > 0);
        // pure: nothing in the planner moved
        assert!(p.slots()[2].alive);
        assert!(!p.dirty());
        assert_eq!(p.manifest().unwrap(), &manifest0);
        assert_eq!((p.cache().hits(), p.cache().misses()), (hits0, misses0));
        // typed errors for unknown and departed slots
        assert_eq!(p.preview_release(9, &net).unwrap_err(), ElasticError::UnknownSlot(9));
        p.lose_slot(1).unwrap();
        assert_eq!(p.preview_release(1, &net).unwrap_err(), ElasticError::DeadSlot(1));
    }

    #[test]
    fn preview_join_re_stages_when_policy_allows() {
        // ZeRO-3 on a 2 GB/s socket link pays three collectives per
        // micro-step; once ZeRO-1 is measured for every type, a policy'd
        // preview prices the admission at the better stage
        let m = preset("llama-0.5b").unwrap();
        let mut p = ElasticPlanner::new(3, 2048, &m.name, m.param_count(), 32);
        for (gpu, mbs) in [("A800-80G", 24), ("V100S-32G", 9)] {
            let slot = p.add_slot(gpu);
            p.install_curve(slot, device_curve(gpu, mbs), false).unwrap();
        }
        // ZeRO-1 curves as Alg. 1 would measure them at the
        // post-admission group size (n=3) — anything else is
        // staleness-disqualified by the preview's (2b) rule
        for gpu in ["A800-80G", "V100S-32G"] {
            let c = crate::autoscale::synthesize_curve(gpu, &m, 1, 3).unwrap();
            p.install_stage_curve(gpu, 1, c).unwrap();
        }
        let net = NetSim::from_link(2, crate::cluster::LinkKind::Socket);
        p.replan(&net).unwrap();

        // without the policy the preview stays at the incumbent stage
        let pv = p.preview_join("V100S-32G", None, &net).unwrap();
        assert_eq!(pv.stage, 3);
        assert_eq!(pv.plan.stage, 3);

        // with it, the post-admission fleet re-stages to ZeRO-1
        p.set_stage_policy(Some(StagePolicy::default()));
        let fingerprint = (p.replans(), p.cache().hits(), p.cache().misses());
        let pv = p.preview_join("V100S-32G", None, &net).unwrap();
        assert_eq!(pv.stage, 1, "socket link: the sync-once stage must win");
        assert!(pv.curve_cached, "re-staging requires measured curves");
        assert_eq!(pv.plan.stage, 1);
        assert_eq!(pv.plan.ranks.len(), 3);
        assert_eq!(pv.curves.len(), 3, "curve set matches the plan ranks");
        assert_eq!(pv.plan.total_samples(), 2048);
        // still a pure what-if: nothing in the planner moved
        assert_eq!((p.replans(), p.cache().hits(), p.cache().misses()), fingerprint);
        assert_eq!(p.stage(), 3);
        assert!(!p.dirty());
    }

    #[test]
    fn preview_join_invalid_stage_is_typed_error() {
        // a corrupt stage must surface as PlanError::InvalidStage through
        // the preview path too, not panic in netsim
        let m = preset("llama-0.5b").unwrap();
        let mut p = ElasticPlanner::new(9, 256, &m.name, m.param_count(), 16);
        let slot = p.add_slot("A800-80G");
        p.install_curve(slot, device_curve("A800-80G", 48), false).unwrap();
        let net = NetSim::from_link(1, LinkKind::Ib);
        assert_eq!(
            p.replan(&net).unwrap_err(),
            ElasticError::Plan(PlanError::InvalidStage(9))
        );
        let est = device_curve("V100S-32G", 16);
        assert!(matches!(
            p.preview_join("V100S-32G", Some(&est), &net),
            Err(ElasticError::Plan(PlanError::InvalidStage(9)))
        ));
    }
}
