//! Curve cache keyed by `(gpu_name, model, stage)`.
//!
//! Profiling is the expensive part of Poplar's pipeline (Table 2), and a
//! performance curve depends only on the GPU type, the model and the
//! ZeRO stage — not on *which* rank holds the GPU. When a known GPU type
//! re-joins an elastic job, the cached curve is reused and Algorithm 1
//! is skipped entirely for that rank.
//!
//! Eviction is LRU with one hard rule: a curve currently backing a live
//! rank is never evicted, no matter how cold — dropping it would force a
//! re-profile of a rank that is actively training.

use std::collections::HashMap;

use crate::curves::PerfCurve;
use crate::intern::{self, TypeId};

/// Cache key: the triple that fully determines a performance curve.
/// `Copy` — gpu and model are interned [`TypeId`]s, so keys move for
/// free on the preview hot paths instead of cloning two `String`s.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct CurveKey {
    /// Interned catalog GPU name, e.g. `"A800-80G"`.
    pub gpu: TypeId,
    /// Interned model preset name, e.g. `"llama-0.5b"`.
    pub model: TypeId,
    /// ZeRO stage the curve was profiled under.
    pub stage: u8,
}

impl CurveKey {
    /// Convenience constructor from display names (interns both).
    pub fn new(gpu: &str, model: &str, stage: u8) -> Self {
        CurveKey { gpu: intern::intern(gpu), model: intern::intern(model), stage }
    }

    /// Zero-intern constructor for hot paths that already hold handles.
    pub fn of(gpu: TypeId, model: TypeId, stage: u8) -> Self {
        CurveKey { gpu, model, stage }
    }
}

/// LRU curve cache with live-rank pinning.
#[derive(Debug, Clone)]
pub struct CurveCache {
    cap: usize,
    map: HashMap<CurveKey, PerfCurve>,
    /// Recency order, oldest first.
    lru: Vec<CurveKey>,
    hits: u64,
    misses: u64,
    evictions: u64,
}

impl CurveCache {
    /// Create a cache holding at most `cap` curves (`cap >= 1`).
    pub fn new(cap: usize) -> Self {
        CurveCache {
            cap: cap.max(1),
            map: HashMap::new(),
            lru: Vec::new(),
            hits: 0,
            misses: 0,
            evictions: 0,
        }
    }

    fn touch(&mut self, key: &CurveKey) {
        if let Some(pos) = self.lru.iter().position(|k| k == key) {
            let k = self.lru.remove(pos);
            self.lru.push(k);
        }
    }

    /// Look up a curve, counting the hit/miss and refreshing recency.
    pub fn get(&mut self, key: &CurveKey) -> Option<PerfCurve> {
        if let Some(c) = self.map.get(key).cloned() {
            self.hits += 1;
            self.touch(key);
            Some(c)
        } else {
            self.misses += 1;
            None
        }
    }

    /// Peek without touching recency or counters.
    pub fn contains(&self, key: &CurveKey) -> bool {
        self.map.contains_key(key)
    }

    /// Read a curve without touching recency or counters — the lookup
    /// for *hypothetical* membership questions (`preview_join`, the
    /// autoscale policy): a declined offer must leave no trace in the
    /// cache statistics or the LRU order.
    pub fn peek(&self, key: &CurveKey) -> Option<&PerfCurve> {
        self.map.get(key)
    }

    /// Current recency order, oldest first (diagnostics / tests).
    pub fn lru_order(&self) -> &[CurveKey] {
        &self.lru
    }

    /// Insert (or refresh) a curve. `live` lists the keys currently
    /// backing live ranks: they are exempt from eviction. If every
    /// resident key is live and the cache is full, the cache grows past
    /// `cap` rather than dropping a live curve.
    pub fn insert(&mut self, key: CurveKey, curve: PerfCurve, live: &[CurveKey]) {
        if self.map.insert(key.clone(), curve).is_none() {
            self.lru.push(key.clone());
        } else {
            self.touch(&key);
        }
        while self.map.len() > self.cap {
            // oldest key that is neither live nor the one just inserted
            let victim = self
                .lru
                .iter()
                .find(|k| !live.contains(k) && **k != key)
                .cloned();
            match victim {
                Some(v) => {
                    self.map.remove(&v);
                    self.lru.retain(|k| *k != v);
                    self.evictions += 1;
                }
                None => break, // everything resident is live: grow instead
            }
        }
    }

    /// Resident curve count.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    /// True when nothing is cached.
    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Cache hits so far.
    pub fn hits(&self) -> u64 {
        self.hits
    }

    /// Cache misses so far.
    pub fn misses(&self) -> u64 {
        self.misses
    }

    /// Evictions so far.
    pub fn evictions(&self) -> u64 {
        self.evictions
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::curves::ProfiledPoint;

    fn curve(scale: f64) -> PerfCurve {
        let pts: Vec<ProfiledPoint> = (1..=8)
            .map(|b| ProfiledPoint { batch: b, step_time_s: scale * (0.05 + 0.01 * b as f64) })
            .collect();
        PerfCurve::fit(pts, 8).unwrap()
    }

    #[test]
    fn hit_on_same_gpu_model_stage() {
        let mut c = CurveCache::new(4);
        c.insert(CurveKey::new("A800-80G", "llama-0.5b", 1), curve(1.0), &[]);
        assert!(c.get(&CurveKey::new("A800-80G", "llama-0.5b", 1)).is_some());
        assert_eq!(c.hits(), 1);
        assert_eq!(c.misses(), 0);
    }

    #[test]
    fn miss_on_stage_change() {
        let mut c = CurveCache::new(4);
        c.insert(CurveKey::new("A800-80G", "llama-0.5b", 1), curve(1.0), &[]);
        assert!(c.get(&CurveKey::new("A800-80G", "llama-0.5b", 2)).is_none());
        assert_eq!(c.misses(), 1);
    }

    #[test]
    fn miss_on_model_or_gpu_change() {
        let mut c = CurveCache::new(4);
        c.insert(CurveKey::new("A800-80G", "llama-0.5b", 1), curve(1.0), &[]);
        assert!(c.get(&CurveKey::new("A800-80G", "llama-1.1b", 1)).is_none());
        assert!(c.get(&CurveKey::new("V100S-32G", "llama-0.5b", 1)).is_none());
    }

    #[test]
    fn lru_eviction_drops_oldest_unpinned() {
        let mut c = CurveCache::new(2);
        let k1 = CurveKey::new("T4", "llama-0.5b", 0);
        let k2 = CurveKey::new("V100-16G", "llama-0.5b", 0);
        let k3 = CurveKey::new("A100-80G", "llama-0.5b", 0);
        c.insert(k1.clone(), curve(3.0), &[]);
        c.insert(k2.clone(), curve(2.0), &[]);
        c.insert(k3.clone(), curve(1.0), &[]);
        assert_eq!(c.len(), 2);
        assert!(!c.contains(&k1), "oldest should be evicted");
        assert!(c.contains(&k2));
        assert!(c.contains(&k3));
        assert_eq!(c.evictions(), 1);
    }

    #[test]
    fn eviction_never_drops_live_curves() {
        let mut c = CurveCache::new(2);
        let live1 = CurveKey::new("A800-80G", "llama-0.5b", 1);
        let live2 = CurveKey::new("V100S-32G", "llama-0.5b", 1);
        let cold = CurveKey::new("T4", "llama-0.5b", 1);
        c.insert(live1.clone(), curve(1.0), &[]);
        c.insert(live2.clone(), curve(2.0), &[]);
        let live = vec![live1.clone(), live2.clone()];
        // over capacity while everything resident is live: grows, drops nothing
        c.insert(cold.clone(), curve(3.0), &live);
        assert!(c.contains(&live1));
        assert!(c.contains(&live2));
        // the cold entry is the only eviction candidate on the next insert
        let k4 = CurveKey::new("A100-40G", "llama-0.5b", 1);
        c.insert(k4.clone(), curve(4.0), &live);
        assert!(c.contains(&live1) && c.contains(&live2), "live curves must survive");
        assert!(!c.contains(&cold), "cold entry should be evicted first");
    }

    #[test]
    fn peek_does_not_touch_counters_or_lru_order() {
        let mut c = CurveCache::new(4);
        let k1 = CurveKey::new("T4", "llama-0.5b", 0);
        let k2 = CurveKey::new("V100-16G", "llama-0.5b", 0);
        c.insert(k1.clone(), curve(1.0), &[]);
        c.insert(k2.clone(), curve(2.0), &[]);
        let order: Vec<CurveKey> = c.lru_order().to_vec();
        // peek the oldest entry and a miss: nothing may move or count
        assert!(c.peek(&k1).is_some());
        assert!(c.peek(&CurveKey::new("A100-80G", "llama-0.5b", 0)).is_none());
        assert_eq!(c.hits(), 0);
        assert_eq!(c.misses(), 0);
        assert_eq!(c.lru_order(), order.as_slice());
        // a real get() DOES refresh recency — peek is the exception
        assert!(c.get(&k1).is_some());
        assert_eq!(c.lru_order().last(), Some(&k1));
        assert_eq!(c.hits(), 1);
    }

    #[test]
    fn reinsert_refreshes_instead_of_duplicating() {
        let mut c = CurveCache::new(2);
        let k = CurveKey::new("T4", "llama-0.5b", 0);
        c.insert(k.clone(), curve(1.0), &[]);
        c.insert(k.clone(), curve(2.0), &[]);
        assert_eq!(c.len(), 1);
    }
}
