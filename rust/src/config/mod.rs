//! Configuration system: one TOML file describes a training job.
//!
//! ```toml
//! [model]
//! preset = "llama-0.5b"        # or inline fields (vocab, d_model, ...)
//!
//! [cluster]
//! preset = "cluster-C"         # or explicit [[cluster.groups]]
//!
//! [training]
//! zero_stage = 2
//! global_batch_tokens = 2097152   # the paper's 2M tokens
//! iterations = 50
//! strategy = "poplar"          # poplar | uniform | flops
//! noise_sigma = 0.015
//! seed = 42
//!
//! # optional: persist optimizer-shard manifests across membership
//! # changes (poplar elastic --config … / poplar ckpt …)
//! [ckpt]
//! dir = "artifacts/ckpt"
//!
//! # optional: shared knobs of the unified decision engine — every
//! # amortized decision (admission, scale-down, stage migration) reads
//! # the same horizon unless [autoscale] overrides it
//! [policy]
//! horizon_s = 300          # expected tenure (amortization window)
//! max_offers_per_round = 64  # soft cap on offers admitted per round
//!
//! # optional: arm pipeline grouping — offers no ZeRO stage can host
//! # solo may join as ONE virtual DP rank (a layer-split group)
//! [pipeline]
//! max_group_size = 4       # at least 2
//!
//! # optional: cost-aware admission policy — `RankJoined` events become
//! # offers the policy may decline (poplar elastic / poplar autoscale)
//! [autoscale]
//! horizon_s = 300          # defaults to [policy] horizon_s when set
//! min_gain = 0.02          # minimum amortized relative gain to admit
//! [[autoscale.prices]]     # $/hr overrides of the built-in price table
//! gpu = "A800-80G"
//! usd_per_hour = 2.95
//!
//! # optional: elastic membership schedule (poplar elastic --config …)
//! [elastic]
//! drift_threshold = 0.15
//! allow_stage_change = true   # replan-time ZeRO-stage re-selection
//! [[elastic.events]]
//! at = 4
//! kind = "lost"                # lost | joined | slowed | bw
//! rank = 7
//! [[elastic.events]]
//! at = 6
//! kind = "slowed"
//! rank = 0
//! factor = 2.5
//! [[elastic.events]]
//! at = 8
//! kind = "joined"
//! gpu = "A800-80G"
//! [[elastic.events]]
//! at = 10
//! kind = "bw"                  # fabric congestion: link drops to
//! link = "socket"              # factor x spec bandwidth (recovery: 1.0)
//! factor = 0.25
//! ```
//!
//! Parsed with the in-crate [`toml_mini`] subset parser (offline image —
//! see Cargo.toml note).

pub mod model;
pub mod toml_mini;

use crate::autoscale::AutoscaleOptions;
use crate::cluster::{self, ClusterSpec, LinkKind, NodeGroup};
use crate::elastic::{ElasticEvent, ScheduledEvent, DEFAULT_DRIFT_THRESHOLD};
use model::ModelSpec;
use toml_mini::Doc;

/// Allocation strategy selector.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Strategy {
    /// The paper's heterogeneity-aware allocator (Alg. 2).
    Poplar,
    /// Uniform micro-batches (DeepSpeed-like baseline).
    Uniform,
    /// FLOPs-proportional (Whale-like baseline).
    Flops,
}

impl Strategy {
    /// Parse from the config string.
    pub fn parse(s: &str) -> Option<Strategy> {
        match s {
            "poplar" => Some(Strategy::Poplar),
            "uniform" | "deepspeed" => Some(Strategy::Uniform),
            "flops" | "whale" => Some(Strategy::Flops),
            _ => None,
        }
    }

    /// Display name.
    pub fn name(&self) -> &'static str {
        match self {
            Strategy::Poplar => "poplar",
            Strategy::Uniform => "uniform",
            Strategy::Flops => "flops",
        }
    }
}

/// Training-run section.
#[derive(Debug, Clone)]
pub struct TrainingConfig {
    /// ZeRO stage to request (may auto-escalate).
    pub zero_stage: u8,
    /// Global batch size in tokens (divided by `seq` into samples).
    pub global_batch_tokens: u64,
    /// Iterations to run/simulate.
    pub iterations: usize,
    /// Allocator to use.
    pub strategy: Strategy,
    /// Profiling measurement noise (std-dev, multiplicative).
    pub noise_sigma: f64,
    /// RNG seed for noise and data.
    pub seed: u64,
}

/// Elastic-run section: a deterministic membership/drift schedule.
#[derive(Debug, Clone)]
pub struct ElasticConfig {
    /// Relative micro-step-time deviation that triggers re-profiling.
    pub drift_threshold: f64,
    /// Make the ZeRO stage a replan-time decision: after membership
    /// events the stage search may migrate the optimizer-shard layout
    /// to a better stage (`ckpt::migrate`, charged like a reshard).
    pub allow_stage_change: bool,
    /// Events in iteration order.
    pub events: Vec<ScheduledEvent>,
}

/// Shared knobs of the unified decision engine (`[policy]`): the one
/// amortization horizon every decision — offer admission, scale-down,
/// stage migration — reads unless `[autoscale]` overrides it.
#[derive(Debug, Clone)]
pub struct PolicyConfig {
    /// Amortization horizon in seconds (expected tenure before the next
    /// membership event re-prices everything).
    pub horizon_s: f64,
    /// Soft cap on offers one joint round may admit (at least 1).
    /// Batches of any size are priced; the cap only bounds the chosen
    /// admission subset.
    pub max_offers_per_round: usize,
}

impl Default for PolicyConfig {
    fn default() -> Self {
        PolicyConfig {
            horizon_s: crate::autoscale::DEFAULT_HORIZON_S,
            max_offers_per_round: crate::policy::DEFAULT_MAX_OFFERS_PER_ROUND,
        }
    }
}

/// Pipeline-grouping section (`[pipeline]`): presence of the table arms
/// the decision engine's virtual-rank arm — offers that no ZeRO stage
/// can host solo may be combined into one pipeline-grouped DP rank
/// ([`crate::pipeline`]; `poplar elastic --allow-pipeline` is the flag
/// equivalent).
#[derive(Debug, Clone)]
pub struct PipelineConfig {
    /// Largest group the planner may propose (at least
    /// [`crate::pipeline::MIN_GROUP_SIZE`]; longer pipelines amortize
    /// badly — the bubble term grows with group depth).
    pub max_group_size: usize,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig { max_group_size: crate::pipeline::DEFAULT_MAX_GROUP_SIZE }
    }
}

/// Checkpoint section: where optimizer-shard manifests persist so a
/// `RankLost` costs resharding, not recomputation.
#[derive(Debug, Clone)]
pub struct CkptConfig {
    /// Snapshot directory (versioned manifest files + `LATEST` pointer).
    pub dir: std::path::PathBuf,
}

impl Default for CkptConfig {
    fn default() -> Self {
        CkptConfig { dir: std::path::PathBuf::from("artifacts/ckpt") }
    }
}

/// Top-level job configuration.
#[derive(Debug, Clone)]
pub struct JobConfig {
    /// Resolved model spec.
    pub model: ModelSpec,
    /// Resolved cluster spec.
    pub cluster: ClusterSpec,
    /// Run parameters.
    pub training: TrainingConfig,
    /// Optional elastic schedule (`poplar elastic --config …`).
    pub elastic: Option<ElasticConfig>,
    /// Optional checkpoint persistence (`[ckpt]` section).
    pub ckpt: Option<CkptConfig>,
    /// Optional cost-aware admission policy (`[autoscale]` section):
    /// when present, elastic `RankJoined` events become offers.
    pub autoscale: Option<AutoscaleOptions>,
    /// Optional shared decision-engine knobs (`[policy]` section).
    pub policy: Option<PolicyConfig>,
    /// Optional pipeline-grouping arm (`[pipeline]` section): `Some`
    /// arms virtual-rank admission for memory-starved offers.
    pub pipeline: Option<PipelineConfig>,
}

/// Errors from loading/validating a config.
#[derive(Debug)]
pub enum ConfigError {
    /// I/O failure reading the file.
    Io(std::io::Error),
    /// TOML syntax error.
    Parse(toml_mini::ParseError),
    /// Semantic validation failure.
    Invalid(String),
}

impl std::fmt::Display for ConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ConfigError::Io(e) => write!(f, "config io: {e}"),
            ConfigError::Parse(e) => write!(f, "config parse: {e}"),
            ConfigError::Invalid(s) => write!(f, "config invalid: {s}"),
        }
    }
}

impl std::error::Error for ConfigError {}

fn invalid(msg: impl Into<String>) -> ConfigError {
    ConfigError::Invalid(msg.into())
}

fn parse_link(s: &str) -> Result<LinkKind, ConfigError> {
    LinkKind::parse(s).ok_or_else(|| invalid(format!("unknown link kind {s:?}")))
}

impl JobConfig {
    /// Parse and validate a TOML string.
    pub fn from_toml(s: &str) -> Result<Self, ConfigError> {
        let d = Doc::parse(s).map_err(ConfigError::Parse)?;

        // ---- model ----
        let model = if let Some(p) = d.str("model.preset") {
            model::preset(p).ok_or_else(|| invalid(format!("unknown model preset {p:?}")))?
        } else if d.has_table("model") {
            ModelSpec {
                name: d.str("model.name").unwrap_or("custom").to_string(),
                arch: d.str("model.arch").unwrap_or("llama").to_string(),
                vocab: d.int("model.vocab").ok_or_else(|| invalid("model.vocab"))? as u64,
                d_model: d.int("model.d_model").ok_or_else(|| invalid("model.d_model"))? as u64,
                n_layers: d.int("model.n_layers").ok_or_else(|| invalid("model.n_layers"))?
                    as u64,
                n_heads: d.int("model.n_heads").ok_or_else(|| invalid("model.n_heads"))? as u64,
                d_ff: d.int("model.d_ff").ok_or_else(|| invalid("model.d_ff"))? as u64,
                seq: d.int("model.seq").ok_or_else(|| invalid("model.seq"))? as u64,
            }
        } else {
            return Err(invalid("missing [model] section"));
        };
        if model.d_model % model.n_heads != 0 {
            return Err(invalid("d_model must be divisible by n_heads"));
        }

        // ---- cluster ----
        let cluster = if let Some(p) = d.str("cluster.preset") {
            match p {
                "cluster-A" => cluster::cluster_a(),
                "cluster-B" => cluster::cluster_b(),
                "cluster-C" => cluster::cluster_c(),
                other => return Err(invalid(format!("unknown cluster preset {other:?}"))),
            }
        } else {
            let n = d.array_len("cluster.groups");
            if n == 0 {
                return Err(invalid("cluster: need preset or [[cluster.groups]]"));
            }
            let mut groups = Vec::with_capacity(n);
            for i in 0..n {
                let gpu = d
                    .str(&format!("cluster.groups.{i}.gpu"))
                    .ok_or_else(|| invalid(format!("cluster.groups.{i}.gpu")))?;
                let count = d
                    .int(&format!("cluster.groups.{i}.count"))
                    .ok_or_else(|| invalid(format!("cluster.groups.{i}.count")))?;
                if count < 0 {
                    return Err(invalid("group count must be >= 0"));
                }
                let link = match d.str(&format!("cluster.groups.{i}.intra_link")) {
                    Some(s) => parse_link(s)?,
                    None => LinkKind::Pcie,
                };
                groups.push(NodeGroup { gpu: gpu.to_string(), count: count as usize,
                                        intra_link: link });
            }
            let inter = match d.str("cluster.inter_link") {
                Some(s) => parse_link(s)?,
                None => LinkKind::Ib,
            };
            ClusterSpec { name: "custom".into(), groups, inter_link: inter }
        };
        cluster.validate().map_err(ConfigError::Invalid)?;

        // ---- training ----
        let zero_stage = d.int("training.zero_stage").unwrap_or(0);
        if !(0..=3).contains(&zero_stage) {
            return Err(invalid(format!("zero_stage must be 0..=3, got {zero_stage}")));
        }
        let gbt = d
            .int("training.global_batch_tokens")
            .ok_or_else(|| invalid("training.global_batch_tokens required"))?;
        if gbt <= 0 {
            return Err(invalid("global_batch_tokens must be positive"));
        }
        let strategy = match d.str("training.strategy") {
            Some(s) => Strategy::parse(s)
                .ok_or_else(|| invalid(format!("unknown strategy {s:?}")))?,
            None => Strategy::Poplar,
        };
        let noise_sigma = d.float("training.noise_sigma").unwrap_or(0.015);
        if !(0.0..0.5).contains(&noise_sigma) {
            return Err(invalid("noise_sigma must be in [0, 0.5)"));
        }
        let training = TrainingConfig {
            zero_stage: zero_stage as u8,
            global_batch_tokens: gbt as u64,
            iterations: d.int("training.iterations").unwrap_or(50).max(1) as usize,
            strategy,
            noise_sigma,
            seed: d.int("training.seed").unwrap_or(42) as u64,
        };

        // ---- elastic (optional) ----
        let elastic = if d.has_table("elastic") {
            let drift_threshold =
                d.float("elastic.drift_threshold").unwrap_or(DEFAULT_DRIFT_THRESHOLD);
            if !(0.0..1.0).contains(&drift_threshold) || drift_threshold == 0.0 {
                return Err(invalid("elastic.drift_threshold must be in (0, 1)"));
            }
            let allow_stage_change = match d.get("elastic.allow_stage_change") {
                None => false,
                Some(v) => v.as_bool().ok_or_else(|| {
                    invalid("elastic.allow_stage_change must be a boolean")
                })?,
            };
            let n = d.array_len("elastic.events");
            let mut events = Vec::with_capacity(n);
            for i in 0..n {
                let at = d
                    .int(&format!("elastic.events.{i}.at"))
                    .ok_or_else(|| invalid(format!("elastic.events.{i}.at")))?;
                if at < 0 {
                    return Err(invalid("elastic event iteration must be >= 0"));
                }
                let kind = d
                    .str(&format!("elastic.events.{i}.kind"))
                    .ok_or_else(|| invalid(format!("elastic.events.{i}.kind")))?;
                let rank_of = |d: &Doc| -> Result<usize, ConfigError> {
                    let r = d
                        .int(&format!("elastic.events.{i}.rank"))
                        .ok_or_else(|| invalid(format!("elastic.events.{i}.rank")))?;
                    if r < 0 {
                        return Err(invalid("elastic event rank must be >= 0"));
                    }
                    Ok(r as usize)
                };
                let event = match kind {
                    "lost" => ElasticEvent::RankLost { slot: rank_of(&d)? },
                    "slowed" => {
                        let factor = d
                            .float(&format!("elastic.events.{i}.factor"))
                            .ok_or_else(|| invalid(format!("elastic.events.{i}.factor")))?;
                        if !factor.is_finite() || factor <= 0.0 {
                            return Err(invalid("elastic slowdown factor must be finite and > 0"));
                        }
                        ElasticEvent::RankSlowed { slot: rank_of(&d)?, factor }
                    }
                    "joined" => {
                        let gpu = d
                            .str(&format!("elastic.events.{i}.gpu"))
                            .ok_or_else(|| invalid(format!("elastic.events.{i}.gpu")))?;
                        if cluster::spec(gpu).is_none() {
                            return Err(invalid(format!("unknown GPU type {gpu:?} in elastic event")));
                        }
                        ElasticEvent::RankJoined { gpu: gpu.to_string() }
                    }
                    "bw" => {
                        let link = d
                            .str(&format!("elastic.events.{i}.link"))
                            .ok_or_else(|| invalid(format!("elastic.events.{i}.link")))?;
                        parse_link(link)?;
                        let factor = d
                            .float(&format!("elastic.events.{i}.factor"))
                            .ok_or_else(|| invalid(format!("elastic.events.{i}.factor")))?;
                        // validated exactly like slowdown factors: a zero or
                        // NaN factor would poison every collective price
                        if !factor.is_finite() || factor <= 0.0 {
                            return Err(invalid("elastic bandwidth factor must be finite and > 0"));
                        }
                        ElasticEvent::BwDrift { link: link.to_string(), factor }
                    }
                    other => {
                        return Err(invalid(format!(
                            "elastic.events.{i}.kind {other:?} (want lost|joined|slowed|bw)"
                        )))
                    }
                };
                events.push(ScheduledEvent { at_iter: at as usize, event });
            }
            events.sort_by_key(|e| e.at_iter);
            Some(ElasticConfig { drift_threshold, allow_stage_change, events })
        } else {
            None
        };

        // ---- policy (optional, shared) ----
        let policy = if d.has_table("policy") {
            let horizon_s =
                d.float("policy.horizon_s").unwrap_or(crate::autoscale::DEFAULT_HORIZON_S);
            if !horizon_s.is_finite() || horizon_s <= 0.0 {
                return Err(invalid("policy.horizon_s must be finite and > 0"));
            }
            let max_offers = d
                .int("policy.max_offers_per_round")
                .unwrap_or(crate::policy::DEFAULT_MAX_OFFERS_PER_ROUND as i64);
            if max_offers < 1 {
                return Err(invalid("policy.max_offers_per_round must be at least 1"));
            }
            Some(PolicyConfig { horizon_s, max_offers_per_round: max_offers as usize })
        } else {
            None
        };

        // ---- autoscale (optional; horizon_s defaults to [policy]'s so
        // every amortized decision shares one window unless overridden) ----
        let autoscale = if d.has_table("autoscale") {
            let horizon_s = d.float("autoscale.horizon_s").unwrap_or_else(|| {
                policy
                    .as_ref()
                    .map(|p| p.horizon_s)
                    .unwrap_or(crate::autoscale::DEFAULT_HORIZON_S)
            });
            if !horizon_s.is_finite() || horizon_s <= 0.0 {
                return Err(invalid("autoscale.horizon_s must be finite and > 0"));
            }
            let min_gain =
                d.float("autoscale.min_gain").unwrap_or(crate::autoscale::DEFAULT_MIN_GAIN);
            if !min_gain.is_finite() || !(0.0..1.0).contains(&min_gain) {
                return Err(invalid("autoscale.min_gain must be in [0, 1)"));
            }
            let n = d.array_len("autoscale.prices");
            let mut prices = Vec::with_capacity(n);
            for i in 0..n {
                let gpu = d
                    .str(&format!("autoscale.prices.{i}.gpu"))
                    .ok_or_else(|| invalid(format!("autoscale.prices.{i}.gpu")))?;
                if cluster::spec(gpu).is_none() {
                    return Err(invalid(format!(
                        "unknown GPU type {gpu:?} in autoscale.prices"
                    )));
                }
                let usd = d
                    .float(&format!("autoscale.prices.{i}.usd_per_hour"))
                    .ok_or_else(|| invalid(format!("autoscale.prices.{i}.usd_per_hour")))?;
                if !usd.is_finite() || usd < 0.0 {
                    return Err(invalid("autoscale price must be finite and >= 0"));
                }
                prices.push((gpu.to_string(), usd));
            }
            Some(AutoscaleOptions { horizon_s, min_gain, prices })
        } else {
            None
        };

        // ---- pipeline (optional) ----
        let pipeline = if d.has_table("pipeline") {
            let max_group_size = d
                .int("pipeline.max_group_size")
                .unwrap_or(crate::pipeline::DEFAULT_MAX_GROUP_SIZE as i64);
            if max_group_size < crate::pipeline::MIN_GROUP_SIZE as i64 {
                return Err(invalid(format!(
                    "pipeline.max_group_size must be at least {}, got {max_group_size}",
                    crate::pipeline::MIN_GROUP_SIZE
                )));
            }
            Some(PipelineConfig { max_group_size: max_group_size as usize })
        } else {
            None
        };

        // ---- ckpt (optional) ----
        let ckpt = if d.has_table("ckpt") {
            let dir = d.str("ckpt.dir").unwrap_or("artifacts/ckpt");
            if dir.trim().is_empty() {
                return Err(invalid("ckpt.dir must not be empty"));
            }
            Some(CkptConfig { dir: std::path::PathBuf::from(dir) })
        } else {
            None
        };

        let cfg =
            JobConfig { model, cluster, training, elastic, ckpt, autoscale, policy, pipeline };
        if cfg.gbs_samples() == 0 {
            return Err(invalid("global_batch_tokens smaller than one sequence"));
        }
        Ok(cfg)
    }

    /// Load from a file path.
    pub fn load(path: &std::path::Path) -> Result<Self, ConfigError> {
        let s = std::fs::read_to_string(path).map_err(ConfigError::Io)?;
        Self::from_toml(&s)
    }

    /// Global batch size in samples for the resolved model.
    pub fn gbs_samples(&self) -> usize {
        (self.training.global_batch_tokens / self.model.seq) as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const GOOD: &str = r#"
        [model]
        preset = "llama-0.5b"

        [cluster]
        preset = "cluster-C"

        [training]
        zero_stage = 2
        global_batch_tokens = 2097152
    "#;

    #[test]
    fn parses_preset_config() {
        let cfg = JobConfig::from_toml(GOOD).unwrap();
        assert_eq!(cfg.model.name, "llama-0.5b");
        assert_eq!(cfg.cluster.n_gpus(), 8);
        assert_eq!(cfg.gbs_samples(), 2048);
        assert_eq!(cfg.training.strategy, Strategy::Poplar);
        assert_eq!(cfg.training.iterations, 50);
    }

    #[test]
    fn parses_explicit_cluster_and_model() {
        let cfg = JobConfig::from_toml(
            r#"
            [model]
            name = "custom"
            vocab = 1000
            d_model = 128
            n_layers = 2
            n_heads = 2
            d_ff = 512
            seq = 128

            [cluster]
            inter_link = "socket"
            [[cluster.groups]]
            gpu = "T4"
            count = 2
            intra_link = "pcie"

            [training]
            global_batch_tokens = 131072
            strategy = "whale"
        "#,
        )
        .unwrap();
        assert_eq!(cfg.model.d_model, 128);
        assert_eq!(cfg.cluster.n_gpus(), 2);
        assert_eq!(cfg.cluster.inter_link, LinkKind::Socket);
        assert_eq!(cfg.gbs_samples(), 1024);
        assert_eq!(cfg.training.strategy, Strategy::Flops);
    }

    #[test]
    fn rejects_bad_stage() {
        let bad = GOOD.replace("zero_stage = 2", "zero_stage = 4");
        assert!(JobConfig::from_toml(&bad).is_err());
    }

    #[test]
    fn rejects_unknown_presets() {
        assert!(JobConfig::from_toml(&GOOD.replace("llama-0.5b", "gpt6")).is_err());
        assert!(JobConfig::from_toml(&GOOD.replace("cluster-C", "cluster-Z")).is_err());
    }

    #[test]
    fn rejects_tiny_gbs() {
        let bad = GOOD.replace("2097152", "100");
        assert!(JobConfig::from_toml(&bad).is_err());
    }

    #[test]
    fn rejects_missing_sections() {
        assert!(JobConfig::from_toml("[model]\npreset = \"tiny\"").is_err());
        assert!(JobConfig::from_toml("").is_err());
    }

    #[test]
    fn parses_elastic_section() {
        let toml = format!(
            "{GOOD}\n\
             [elastic]\n\
             drift_threshold = 0.2\n\
             [[elastic.events]]\n\
             at = 4\n\
             kind = \"lost\"\n\
             rank = 7\n\
             [[elastic.events]]\n\
             at = 2\n\
             kind = \"slowed\"\n\
             rank = 0\n\
             factor = 2.5\n\
             [[elastic.events]]\n\
             at = 6\n\
             kind = \"joined\"\n\
             gpu = \"A800-80G\"\n\
             [[elastic.events]]\n\
             at = 9\n\
             kind = \"bw\"\n\
             link = \"socket\"\n\
             factor = 0.25\n"
        );
        let cfg = JobConfig::from_toml(&toml).unwrap();
        let e = cfg.elastic.unwrap();
        assert_eq!(e.drift_threshold, 0.2);
        assert_eq!(e.events.len(), 4);
        // sorted by iteration
        assert_eq!(e.events[0].at_iter, 2);
        assert_eq!(
            e.events[0].event,
            crate::elastic::ElasticEvent::RankSlowed { slot: 0, factor: 2.5 }
        );
        assert_eq!(e.events[2].event,
                   crate::elastic::ElasticEvent::RankJoined { gpu: "A800-80G".into() });
        assert_eq!(
            e.events[3].event,
            crate::elastic::ElasticEvent::BwDrift { link: "socket".into(), factor: 0.25 }
        );
    }

    #[test]
    fn no_elastic_section_is_none() {
        assert!(JobConfig::from_toml(GOOD).unwrap().elastic.is_none());
    }

    #[test]
    fn ckpt_section_parses_with_defaults() {
        assert!(JobConfig::from_toml(GOOD).unwrap().ckpt.is_none());
        // bare [ckpt] means the default directory
        let cfg = JobConfig::from_toml(&format!("{GOOD}\n[ckpt]\n")).unwrap();
        assert_eq!(
            cfg.ckpt.unwrap().dir,
            std::path::PathBuf::from("artifacts/ckpt")
        );
        let cfg = JobConfig::from_toml(&format!("{GOOD}\n[ckpt]\ndir = \"/tmp/ck\"\n")).unwrap();
        assert_eq!(cfg.ckpt.unwrap().dir, std::path::PathBuf::from("/tmp/ck"));
        assert!(JobConfig::from_toml(&format!("{GOOD}\n[ckpt]\ndir = \"\"\n")).is_err());
    }

    #[test]
    fn bare_elastic_section_means_all_defaults() {
        // just drift detection, no scheduled events
        let cfg = JobConfig::from_toml(&format!("{GOOD}\n[elastic]\n")).unwrap();
        let e = cfg.elastic.unwrap();
        assert_eq!(e.drift_threshold, crate::elastic::DEFAULT_DRIFT_THRESHOLD);
        assert!(e.events.is_empty());
    }

    #[test]
    fn elastic_allow_stage_change_parses_and_defaults_off() {
        let cfg = JobConfig::from_toml(&format!("{GOOD}\n[elastic]\n")).unwrap();
        assert!(!cfg.elastic.unwrap().allow_stage_change, "must default off");
        let on = format!("{GOOD}\n[elastic]\nallow_stage_change = true\n");
        assert!(JobConfig::from_toml(&on).unwrap().elastic.unwrap().allow_stage_change);
        let off = format!("{GOOD}\n[elastic]\nallow_stage_change = false\n");
        assert!(!JobConfig::from_toml(&off).unwrap().elastic.unwrap().allow_stage_change);
        // a non-boolean is a config error, not a silent default
        let bad = format!("{GOOD}\n[elastic]\nallow_stage_change = 1\n");
        assert!(JobConfig::from_toml(&bad).is_err());
    }

    #[test]
    fn rejects_bad_elastic_events() {
        let bad_kind = format!(
            "{GOOD}\n[elastic]\n[[elastic.events]]\nat = 1\nkind = \"exploded\"\nrank = 0\n"
        );
        assert!(JobConfig::from_toml(&bad_kind).is_err());
        let bad_gpu = format!(
            "{GOOD}\n[elastic]\n[[elastic.events]]\nat = 1\nkind = \"joined\"\ngpu = \"H100\"\n"
        );
        assert!(JobConfig::from_toml(&bad_gpu).is_err());
        let bad_thresh = format!("{GOOD}\n[elastic]\ndrift_threshold = 1.5\n");
        assert!(JobConfig::from_toml(&bad_thresh).is_err());
    }

    #[test]
    fn rejects_bad_bw_events() {
        // bandwidth factors are validated exactly like slowdown factors
        for factor in ["0", "-0.5", "nan", "inf"] {
            let bad = format!(
                "{GOOD}\n[elastic]\n[[elastic.events]]\nat = 1\nkind = \"bw\"\n\
                 link = \"socket\"\nfactor = {factor}\n"
            );
            assert!(JobConfig::from_toml(&bad).is_err(), "factor {factor} must be rejected");
        }
        let bad_link = format!(
            "{GOOD}\n[elastic]\n[[elastic.events]]\nat = 1\nkind = \"bw\"\n\
             link = \"ethernet\"\nfactor = 0.5\n"
        );
        assert!(JobConfig::from_toml(&bad_link).is_err());
        let no_link =
            format!("{GOOD}\n[elastic]\n[[elastic.events]]\nat = 1\nkind = \"bw\"\nfactor = 0.5\n");
        assert!(JobConfig::from_toml(&no_link).is_err());
        let no_factor = format!(
            "{GOOD}\n[elastic]\n[[elastic.events]]\nat = 1\nkind = \"bw\"\nlink = \"socket\"\n"
        );
        assert!(JobConfig::from_toml(&no_factor).is_err());
    }

    #[test]
    fn autoscale_section_parses_with_defaults_and_overrides() {
        assert!(JobConfig::from_toml(GOOD).unwrap().autoscale.is_none());
        // bare [autoscale] means all defaults
        let cfg = JobConfig::from_toml(&format!("{GOOD}\n[autoscale]\n")).unwrap();
        let a = cfg.autoscale.unwrap();
        assert_eq!(a.horizon_s, crate::autoscale::DEFAULT_HORIZON_S);
        assert_eq!(a.min_gain, crate::autoscale::DEFAULT_MIN_GAIN);
        assert!(a.prices.is_empty());
        // explicit knobs + a price override (integer horizon coerces)
        let toml = format!(
            "{GOOD}\n\
             [autoscale]\n\
             horizon_s = 600\n\
             min_gain = 0.05\n\
             [[autoscale.prices]]\n\
             gpu = \"A800-80G\"\n\
             usd_per_hour = 2.95\n"
        );
        let a = JobConfig::from_toml(&toml).unwrap().autoscale.unwrap();
        assert_eq!(a.horizon_s, 600.0);
        assert_eq!(a.min_gain, 0.05);
        assert_eq!(a.price_per_hour("A800-80G"), 2.95);
        // un-overridden types still hit the built-in table
        assert!(a.price_per_hour("T4") > 0.0);
    }

    #[test]
    fn policy_section_parses_and_shares_its_horizon() {
        assert!(JobConfig::from_toml(GOOD).unwrap().policy.is_none());
        // bare [policy] means the default horizon
        let cfg = JobConfig::from_toml(&format!("{GOOD}\n[policy]\n")).unwrap();
        assert_eq!(
            cfg.policy.unwrap().horizon_s,
            crate::autoscale::DEFAULT_HORIZON_S
        );
        // [autoscale] without its own horizon inherits [policy]'s…
        let toml = format!("{GOOD}\n[policy]\nhorizon_s = 120\n[autoscale]\n");
        let cfg = JobConfig::from_toml(&toml).unwrap();
        assert_eq!(cfg.policy.as_ref().unwrap().horizon_s, 120.0);
        assert_eq!(cfg.autoscale.unwrap().horizon_s, 120.0);
        // …while an explicit [autoscale] horizon_s is still accepted
        let toml =
            format!("{GOOD}\n[policy]\nhorizon_s = 120\n[autoscale]\nhorizon_s = 600\n");
        assert_eq!(JobConfig::from_toml(&toml).unwrap().autoscale.unwrap().horizon_s, 600.0);
        // invalid horizons are config errors
        let bad = format!("{GOOD}\n[policy]\nhorizon_s = 0\n");
        assert!(JobConfig::from_toml(&bad).is_err());
        let bad = format!("{GOOD}\n[policy]\nhorizon_s = -3\n");
        assert!(JobConfig::from_toml(&bad).is_err());
    }

    #[test]
    fn policy_round_cap_parses_and_validates() {
        // bare [policy] carries the engine default soft cap
        let cfg = JobConfig::from_toml(&format!("{GOOD}\n[policy]\n")).unwrap();
        assert_eq!(
            cfg.policy.unwrap().max_offers_per_round,
            crate::policy::DEFAULT_MAX_OFFERS_PER_ROUND
        );
        // explicit value parses
        let toml = format!("{GOOD}\n[policy]\nmax_offers_per_round = 8\n");
        let cfg = JobConfig::from_toml(&toml).unwrap();
        assert_eq!(cfg.policy.unwrap().max_offers_per_round, 8);
        // a cap below 1 is a config error, not a silent clamp
        let bad = format!("{GOOD}\n[policy]\nmax_offers_per_round = 0\n");
        assert!(JobConfig::from_toml(&bad).is_err());
    }

    #[test]
    fn pipeline_section_parses_and_rejects_tiny_groups() {
        // absent table: the arm stays off
        assert!(JobConfig::from_toml(GOOD).unwrap().pipeline.is_none());
        // bare [pipeline] arms the default cap
        let cfg = JobConfig::from_toml(&format!("{GOOD}\n[pipeline]\n")).unwrap();
        assert_eq!(
            cfg.pipeline.unwrap().max_group_size,
            crate::pipeline::DEFAULT_MAX_GROUP_SIZE
        );
        // explicit cap parses
        let toml = format!("{GOOD}\n[pipeline]\nmax_group_size = 3\n");
        assert_eq!(JobConfig::from_toml(&toml).unwrap().pipeline.unwrap().max_group_size, 3);
        // a singleton "group" can never pipeline — parse-time rejection
        for cap in ["1", "0", "-2"] {
            let bad = format!("{GOOD}\n[pipeline]\nmax_group_size = {cap}\n");
            assert!(JobConfig::from_toml(&bad).is_err(), "cap {cap} must be rejected");
        }
    }

    #[test]
    fn rejects_bad_autoscale_sections() {
        let bad_h = format!("{GOOD}\n[autoscale]\nhorizon_s = 0\n");
        assert!(JobConfig::from_toml(&bad_h).is_err());
        let bad_gain = format!("{GOOD}\n[autoscale]\nmin_gain = 1.5\n");
        assert!(JobConfig::from_toml(&bad_gain).is_err());
        let bad_gpu = format!(
            "{GOOD}\n[autoscale]\n[[autoscale.prices]]\ngpu = \"H100\"\nusd_per_hour = 9.0\n"
        );
        assert!(JobConfig::from_toml(&bad_gpu).is_err());
        let bad_price = format!(
            "{GOOD}\n[autoscale]\n[[autoscale.prices]]\ngpu = \"T4\"\nusd_per_hour = -1.0\n"
        );
        assert!(JobConfig::from_toml(&bad_price).is_err());
    }

    #[test]
    fn strategy_aliases() {
        assert_eq!(Strategy::parse("deepspeed"), Some(Strategy::Uniform));
        assert_eq!(Strategy::parse("whale"), Some(Strategy::Flops));
        assert_eq!(Strategy::parse("x"), None);
        assert_eq!(Strategy::Poplar.name(), "poplar");
    }
}
