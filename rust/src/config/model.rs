//! Model architecture specification (mirrors `python/compile/model.py`).



/// Transformer architecture hyper-parameters.
///
/// `param_count()` and `flops_per_token()` must stay in sync with
/// `ModelConfig` in `python/compile/model.py` — the pytest/cargo suites
/// both pin the paper-preset sizes.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelSpec {
    /// Preset name, e.g. `"llama-0.5b"`.
    pub name: String,
    /// `"llama"` (decoder, causal) or `"bert"` (encoder, bidirectional).
    pub arch: String,
    /// Vocabulary size.
    pub vocab: u64,
    /// Hidden size h.
    pub d_model: u64,
    /// Number of transformer layers.
    pub n_layers: u64,
    /// Attention heads.
    pub n_heads: u64,
    /// FFN intermediate size.
    pub d_ff: u64,
    /// Training sequence length.
    pub seq: u64,
}

impl ModelSpec {
    /// Total parameter count (embed + per-layer attn/ffn/norms + head).
    pub fn param_count(&self) -> u64 {
        let (d, f, v) = (self.d_model, self.d_ff, self.vocab);
        let per_layer = 2 * d        // two norms
            + 4 * d * d              // wq wk wv wo
            + 3 * d * f; // w1 w3 w2
        v * d + self.n_layers * per_layer + d + d * v
    }

    /// Approximate fwd+bwd FLOPs per token (6N rule + attention term).
    /// Mirrors `ModelConfig.flops_per_token` in python.
    pub fn flops_per_token(&self) -> f64 {
        let n = self.param_count() as f64;
        let attn = (12 * self.n_layers * self.d_model * self.seq) as f64;
        6.0 * n + attn
    }

    /// FLOPs for one sample (sequence) — fwd+bwd.
    pub fn flops_per_sample(&self) -> f64 {
        self.flops_per_token() * self.seq as f64
    }

    /// Activation memory per sample in bytes, fp16 with no recompute
    /// (Megatron-style estimate: `s·h·L·(34 + 5·a·s/h)` bytes).
    pub fn activation_bytes_per_sample(&self) -> u64 {
        let (s, h, l, a) = (
            self.seq as f64,
            self.d_model as f64,
            self.n_layers as f64,
            self.n_heads as f64,
        );
        (s * h * l * (34.0 + 5.0 * a * s / h)) as u64
    }
}

/// Paper model presets (analytic path) + the e2e validation models.
pub fn preset(name: &str) -> Option<ModelSpec> {
    let m = match name {
        "tiny" => ModelSpec {
            name: "tiny".into(), arch: "llama".into(),
            vocab: 2048, d_model: 256, n_layers: 4, n_heads: 4, d_ff: 1024, seq: 256,
        },
        "e2e-28m" => ModelSpec {
            name: "e2e-28m".into(), arch: "llama".into(),
            vocab: 8192, d_model: 512, n_layers: 6, n_heads: 8, d_ff: 1536, seq: 256,
        },
        "e2e-110m" => ModelSpec {
            name: "e2e-110m".into(), arch: "llama".into(),
            vocab: 16384, d_model: 768, n_layers: 12, n_heads: 12, d_ff: 2304, seq: 256,
        },
        "llama-0.5b" => ModelSpec {
            name: "llama-0.5b".into(), arch: "llama".into(),
            vocab: 32000, d_model: 1024, n_layers: 24, n_heads: 16, d_ff: 4096, seq: 1024,
        },
        "llama-1.1b" => ModelSpec {
            name: "llama-1.1b".into(), arch: "llama".into(),
            vocab: 32000, d_model: 2048, n_layers: 22, n_heads: 32, d_ff: 5632, seq: 1024,
        },
        "bert-1.1b" => ModelSpec {
            name: "bert-1.1b".into(), arch: "bert".into(),
            vocab: 30522, d_model: 1792, n_layers: 24, n_heads: 28, d_ff: 7168, seq: 512,
        },
        // appendix Fig. 6 extras
        "gpt2-345m" => ModelSpec {
            name: "gpt2-345m".into(), arch: "llama".into(),
            vocab: 50257, d_model: 1024, n_layers: 24, n_heads: 16, d_ff: 4096, seq: 1024,
        },
        "llama-7b" => ModelSpec {
            name: "llama-7b".into(), arch: "llama".into(),
            vocab: 32000, d_model: 4096, n_layers: 32, n_heads: 32, d_ff: 11008, seq: 2048,
        },
        // long-context stressor for the pipeline-grouping figure: a
        // modest parameter count whose seq-4096 activations overflow
        // every mid-tier card at ANY ZeRO stage — only a layer split
        // across a pipeline group (or an 80G card) can host it
        "longctx-0.4b" => ModelSpec {
            name: "longctx-0.4b".into(), arch: "llama".into(),
            vocab: 32000, d_model: 1024, n_layers: 21, n_heads: 16, d_ff: 4096, seq: 4096,
        },
        _ => return None,
    };
    Some(m)
}

/// Like [`preset`] but with a typed error — the no-panic entry point
/// for exp runners and the CLI.
pub fn require(name: &str) -> Result<ModelSpec, super::ConfigError> {
    preset(name)
        .ok_or_else(|| super::ConfigError::Invalid(format!("unknown model preset {name:?}")))
}

/// All preset names usable with [`preset`].
pub const PRESET_NAMES: &[&str] = &[
    "tiny", "e2e-28m", "e2e-110m", "llama-0.5b", "llama-1.1b", "bert-1.1b",
    "gpt2-345m", "llama-7b", "longctx-0.4b",
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_presets_resolve() {
        for n in PRESET_NAMES {
            let m = preset(n).expect(n);
            assert!(m.param_count() > 0);
            assert!(m.flops_per_token() > 6.0 * m.param_count() as f64 - 1.0);
        }
    }

    #[test]
    fn paper_preset_sizes() {
        let n = |s: &str| preset(s).unwrap().param_count() as f64;
        assert!(n("llama-0.5b") > 0.3e9 && n("llama-0.5b") < 0.7e9);
        assert!(n("llama-1.1b") > 0.9e9 && n("llama-1.1b") < 1.4e9);
        assert!(n("bert-1.1b") > 0.9e9 && n("bert-1.1b") < 1.4e9);
        assert!(n("llama-7b") > 6.0e9 && n("llama-7b") < 8.0e9);
    }

    #[test]
    fn activation_memory_scales_with_seq_squared_term() {
        let base = preset("llama-0.5b").unwrap();
        let mut long = base.clone();
        long.seq *= 2;
        // attention term grows superlinearly
        assert!(
            long.activation_bytes_per_sample() > 2 * base.activation_bytes_per_sample()
        );
    }

    #[test]
    fn unknown_preset_none() {
        assert!(preset("gpt5").is_none());
    }
}
