//! Minimal TOML-subset parser (offline image has no `toml` crate).
//!
//! Supports exactly what Poplar job files need:
//!
//! * `[section]` and `[section.sub]` tables;
//! * `[[section.array]]` arrays of tables;
//! * `key = value` with strings (`"…"`), integers, floats, booleans;
//! * `#` comments and blank lines.
//!
//! Values are exposed through a flat path map: `training.zero_stage`,
//! `cluster.groups.0.gpu`, …

use std::collections::BTreeMap;

/// A parsed scalar value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    /// Quoted string.
    Str(String),
    /// Integer literal.
    Int(i64),
    /// Float literal.
    Float(f64),
    /// `true` / `false`.
    Bool(bool),
}

impl Value {
    /// As string, if it is one.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    /// As integer (floats with zero fraction coerce).
    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            Value::Float(f) if f.fract() == 0.0 => Some(*f as i64),
            _ => None,
        }
    }

    /// As float (ints coerce).
    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    /// As bool, if it is one.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// Parsed document: flat `path -> value` map plus array-of-table counts.
#[derive(Debug, Clone, Default)]
pub struct Doc {
    map: BTreeMap<String, Value>,
    array_len: BTreeMap<String, usize>,
    /// Every `[name]` / `[[name]]` header seen, so empty sections (e.g. a
    /// bare `[elastic]` requesting all-default behaviour) still register.
    tables: std::collections::BTreeSet<String>,
}

/// Parse error with a line number.
#[derive(Debug, PartialEq)]
pub struct ParseError {
    /// 1-based line.
    pub line: usize,
    /// Description.
    pub msg: String,
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "toml line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl Doc {
    /// Parse a TOML-subset string.
    pub fn parse(input: &str) -> Result<Doc, ParseError> {
        let mut doc = Doc::default();
        let mut prefix = String::new();
        for (ln, raw) in input.lines().enumerate() {
            let line = ln + 1;
            let s = strip_comment(raw).trim().to_string();
            if s.is_empty() {
                continue;
            }
            if let Some(name) = s.strip_prefix("[[").and_then(|x| x.strip_suffix("]]")) {
                let name = name.trim();
                check_key(name, line)?;
                let idx = *doc.array_len.entry(name.to_string()).or_insert(0);
                doc.array_len.insert(name.to_string(), idx + 1);
                doc.tables.insert(name.to_string());
                prefix = format!("{name}.{idx}");
            } else if let Some(name) = s.strip_prefix('[').and_then(|x| x.strip_suffix(']')) {
                let name = name.trim();
                check_key(name, line)?;
                doc.tables.insert(name.to_string());
                prefix = name.to_string();
            } else if let Some(eq) = s.find('=') {
                let key = s[..eq].trim();
                check_key(key, line)?;
                let val = parse_value(s[eq + 1..].trim(), line)?;
                let path = if prefix.is_empty() {
                    key.to_string()
                } else {
                    format!("{prefix}.{key}")
                };
                if doc.map.insert(path.clone(), val).is_some() {
                    return Err(ParseError { line, msg: format!("duplicate key {path:?}") });
                }
            } else {
                return Err(ParseError { line, msg: format!("unparseable line {s:?}") });
            }
        }
        Ok(doc)
    }

    /// Look up a value by dotted path.
    pub fn get(&self, path: &str) -> Option<&Value> {
        self.map.get(path)
    }

    /// String at path.
    pub fn str(&self, path: &str) -> Option<&str> {
        self.get(path).and_then(Value::as_str)
    }

    /// Integer at path.
    pub fn int(&self, path: &str) -> Option<i64> {
        self.get(path).and_then(Value::as_int)
    }

    /// Float at path.
    pub fn float(&self, path: &str) -> Option<f64> {
        self.get(path).and_then(Value::as_float)
    }

    /// Bool at path.
    pub fn bool(&self, path: &str) -> Option<bool> {
        self.get(path).and_then(Value::as_bool)
    }

    /// Number of `[[path]]` tables seen.
    pub fn array_len(&self, path: &str) -> usize {
        self.array_len.get(path).copied().unwrap_or(0)
    }

    /// True when the table was declared (even empty) or any key exists
    /// under the given prefix.
    pub fn has_table(&self, prefix: &str) -> bool {
        let p = format!("{prefix}.");
        self.tables.iter().any(|t| t == prefix || t.starts_with(&p))
            || self.map.keys().any(|k| k.starts_with(&p))
    }
}

fn strip_comment(s: &str) -> &str {
    // a '#' inside a quoted string does not start a comment
    let mut in_str = false;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &s[..i],
            _ => {}
        }
    }
    s
}

fn check_key(k: &str, line: usize) -> Result<(), ParseError> {
    if k.is_empty()
        || !k.chars().all(|c| c.is_ascii_alphanumeric() || "._-".contains(c))
    {
        return Err(ParseError { line, msg: format!("bad key {k:?}") });
    }
    Ok(())
}

fn parse_value(v: &str, line: usize) -> Result<Value, ParseError> {
    if let Some(s) = v.strip_prefix('"').and_then(|x| x.strip_suffix('"')) {
        return Ok(Value::Str(s.to_string()));
    }
    match v {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    let clean = v.replace('_', "");
    if let Ok(i) = clean.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = clean.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(ParseError { line, msg: format!("unparseable value {v:?}") })
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
        # a job file
        title = "demo"

        [model]
        preset = "llama-0.5b"   # inline comment

        [training]
        zero_stage = 2
        global_batch_tokens = 2_097_152
        noise_sigma = 0.015
        verbose = true

        [[cluster.groups]]
        gpu = "A800-80G"
        count = 4

        [[cluster.groups]]
        gpu = "V100S-32G"
        count = 4
    "#;

    #[test]
    fn parses_sample() {
        let d = Doc::parse(SAMPLE).unwrap();
        assert_eq!(d.str("title"), Some("demo"));
        assert_eq!(d.str("model.preset"), Some("llama-0.5b"));
        assert_eq!(d.int("training.zero_stage"), Some(2));
        assert_eq!(d.int("training.global_batch_tokens"), Some(2_097_152));
        assert_eq!(d.float("training.noise_sigma"), Some(0.015));
        assert_eq!(d.bool("training.verbose"), Some(true));
        assert_eq!(d.array_len("cluster.groups"), 2);
        assert_eq!(d.str("cluster.groups.0.gpu"), Some("A800-80G"));
        assert_eq!(d.int("cluster.groups.1.count"), Some(4));
    }

    #[test]
    fn comment_inside_string_kept() {
        let d = Doc::parse(r##"x = "a#b""##).unwrap();
        assert_eq!(d.str("x"), Some("a#b"));
    }

    #[test]
    fn duplicate_key_rejected() {
        let e = Doc::parse("a = 1\na = 2").unwrap_err();
        assert!(e.msg.contains("duplicate"));
        assert_eq!(e.line, 2);
    }

    #[test]
    fn bad_lines_rejected() {
        assert!(Doc::parse("not a kv").is_err());
        assert!(Doc::parse("x = @@@").is_err());
        assert!(Doc::parse("[bad key]").is_err());
    }

    #[test]
    fn missing_lookups_are_none() {
        let d = Doc::parse("a = 1").unwrap();
        assert_eq!(d.int("b"), None);
        assert_eq!(d.str("a"), None); // wrong type
        assert_eq!(d.array_len("xs"), 0);
        assert!(!d.has_table("t"));
    }

    #[test]
    fn empty_table_header_still_registers() {
        let d = Doc::parse("[elastic]\n").unwrap();
        assert!(d.has_table("elastic"));
        assert!(!d.has_table("training"));
        // nested headers register their parents too
        let d = Doc::parse("[a.b]\nx = 1").unwrap();
        assert!(d.has_table("a"));
        assert!(d.has_table("a.b"));
    }

    #[test]
    fn int_float_coercion() {
        let d = Doc::parse("i = 3\nf = 3.5\nz = 4.0").unwrap();
        assert_eq!(d.float("i"), Some(3.0));
        assert_eq!(d.int("f"), None);
        assert_eq!(d.int("z"), Some(4));
    }
}
