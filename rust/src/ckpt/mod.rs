//! Checkpoint/restore of ZeRO optimizer shards across membership changes.
//!
//! Poplar's elastic runtime (PR 1) replans batches after every
//! `RankLost`/`RankJoined`, but it priced the optimizer-state movement
//! with a one-shot constant (a full `12ψ` all-gather). This module makes
//! that cost *measured*: it tracks which rank owns which contiguous
//! parameter range per ZeRO stage ([`ShardManifest`]), persists that
//! layout in a versioned on-disk format under `artifacts/ckpt/`
//! ([`format`]), and computes the **minimal shard-movement set** between
//! two layouts ([`reshard`]) — so a membership change costs only the
//! bytes whose owner actually changed, with lost ranks' shards restored
//! from the checkpoint instead of recomputed. A *stage* change is a
//! [`migrate`]: the partition rule itself is rewritten, priced from the
//! bytes that change owner under the new rule (partition↔partition and
//! replicate→partition are cheap overlaps; partition→replicate is a
//! priced broadcast).
//!
//! Layout rules (from [`crate::zero::optimizer_shard_ranges`]):
//!
//! * **ZeRO-0** — optimizer states are replicated: every rank owns the
//!   full `[0, ψ)` range; only joiners move bytes (a full fetch from the
//!   lowest surviving peer, or the checkpoint if nobody survived).
//! * **ZeRO-1..3** — states are partitioned contiguously: rank `i` of
//!   `n` owns `ψ/n` parameters (remainder to the first ranks). Slots are
//!   identified by their *stable leader slot id*, so a survivor's
//!   retained range is the overlap of its old and new intervals.
//!
//! The recompute baseline ([`ReshardPlan::full_restore`]) prices the
//! naive alternative — every rank refetches its entire shard — and is
//! what `exp::fig_elastic`'s `recompute_s` column reports against the
//! measured `reshard_s`.

pub mod format;

use std::collections::BTreeMap;

use crate::intern::TypeId;
use crate::netsim::NetSim;
use crate::zero::{optimizer_shard_ranges, OPTIMIZER_BYTES_PER_PARAM};

/// On-disk format version this build reads and writes. Policy: readers
/// reject any other version with [`CkptError::VersionMismatch`] — the
/// format has no forward-compatibility window, so any field change
/// (addition included) must bump this constant and keep a loader for the
/// old version only if a migration is shipped alongside it.
pub const FORMAT_VERSION: u32 = 1;

/// Half-open parameter-index interval `[lo, hi)`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardRange {
    /// First parameter index owned.
    pub lo: u64,
    /// One past the last parameter index owned.
    pub hi: u64,
}

impl ShardRange {
    /// Construct (empty ranges are allowed and have `len() == 0`).
    pub fn new(lo: u64, hi: u64) -> Self {
        ShardRange { lo, hi: hi.max(lo) }
    }

    /// Number of parameters in the range.
    pub fn len(&self) -> u64 {
        self.hi - self.lo
    }

    /// True when the range holds no parameters.
    pub fn is_empty(&self) -> bool {
        self.hi <= self.lo
    }

    /// Intersection with another range, if non-empty.
    pub fn intersect(&self, other: &ShardRange) -> Option<ShardRange> {
        let lo = self.lo.max(other.lo);
        let hi = self.hi.min(other.hi);
        if lo < hi {
            Some(ShardRange { lo, hi })
        } else {
            None
        }
    }
}

/// One rank's shard assignment.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardEntry {
    /// Stable leader slot id (survives membership changes).
    pub slot: usize,
    /// Interned catalog GPU name (diagnostics only — not part of the
    /// layout key; resolve with `as_str()` at report boundaries).
    pub gpu: TypeId,
    /// Owned parameter range.
    pub range: ShardRange,
}

/// The partition layout of the optimizer state at one point in time:
/// which slot owns which parameter range, for a `(model, stage, ψ)` job.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardManifest {
    /// On-disk format version ([`FORMAT_VERSION`] for in-memory builds).
    pub version: u32,
    /// Model name the state belongs to.
    pub model: String,
    /// ZeRO stage of the layout (0 replicates, 1..3 partition).
    pub stage: u8,
    /// Total parameter count `ψ`.
    pub param_count: u64,
    /// Snapshot ordinal (the plan/iteration the layout was active for).
    pub snapshot: usize,
    /// Per-rank assignments in slot order.
    pub shards: Vec<ShardEntry>,
}

/// Errors from the checkpoint subsystem.
#[derive(Debug)]
pub enum CkptError {
    /// Stage outside 0..=3.
    InvalidStage(u8),
    /// [`reshard`] was asked to cross ZeRO stages — that is a *migration*
    /// (the layout rule itself changes), priced by [`migrate`].
    CrossStage {
        /// Stage of the old layout.
        from: u8,
        /// Stage of the new layout.
        to: u8,
    },
    /// A manifest over zero ranks.
    EmptyGroup,
    /// On-disk version this build cannot read.
    VersionMismatch {
        /// Version found in the file.
        found: u32,
        /// Version this build supports.
        supported: u32,
    },
    /// Structurally invalid file or manifest.
    Corrupt(String),
    /// Two manifests that do not describe the same optimizer state.
    Incompatible(String),
    /// Filesystem failure.
    Io(std::io::Error),
}

impl std::fmt::Display for CkptError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CkptError::InvalidStage(s) => write!(f, "invalid ZeRO stage {s} (want 0..=3)"),
            CkptError::CrossStage { from, to } => write!(
                f,
                "layouts cross ZeRO stages ({from} -> {to}): a stage change is a \
                 migration, not a reshard — use ckpt::migrate"
            ),
            CkptError::EmptyGroup => write!(f, "manifest needs at least one rank"),
            CkptError::VersionMismatch { found, supported } => write!(
                f,
                "checkpoint format v{found} is not readable by this build (supports v{supported}); \
                 re-snapshot with the matching binary"
            ),
            CkptError::Corrupt(m) => write!(f, "corrupt checkpoint: {m}"),
            CkptError::Incompatible(m) => write!(f, "incompatible manifests: {m}"),
            CkptError::Io(e) => write!(f, "checkpoint io: {e}"),
        }
    }
}

impl std::error::Error for CkptError {}

impl From<std::io::Error> for CkptError {
    fn from(e: std::io::Error) -> Self {
        CkptError::Io(e)
    }
}

impl ShardManifest {
    /// Build the layout for `slots` (stable slot id + GPU name, compact
    /// rank order) at a ZeRO stage, via the partition rule in
    /// [`crate::zero::optimizer_shard_ranges`].
    pub fn build(
        model: &str,
        stage: u8,
        param_count: u64,
        snapshot: usize,
        slots: &[(usize, TypeId)],
    ) -> Result<Self, CkptError> {
        if slots.is_empty() {
            return Err(CkptError::EmptyGroup);
        }
        let ranges = optimizer_shard_ranges(stage, param_count, slots.len())
            .ok_or(CkptError::InvalidStage(stage))?;
        let shards = slots
            .iter()
            .zip(ranges)
            .map(|(&(slot, gpu), (lo, hi))| ShardEntry {
                slot,
                gpu,
                range: ShardRange::new(lo, hi),
            })
            .collect();
        Ok(ShardManifest {
            version: FORMAT_VERSION,
            model: model.to_string(),
            stage,
            param_count,
            snapshot,
            shards,
        })
    }

    /// The range owned by `slot`, if the slot is in the manifest.
    pub fn shard_of(&self, slot: usize) -> Option<ShardRange> {
        self.shards.iter().find(|e| e.slot == slot).map(|e| e.range)
    }

    /// True when `slot` appears in the manifest.
    pub fn has_slot(&self, slot: usize) -> bool {
        self.shards.iter().any(|e| e.slot == slot)
    }

    /// Structural validation: version, stage, non-empty, and (for the
    /// partitioned stages) that the ranges tile `[0, ψ)` exactly.
    pub fn validate(&self) -> Result<(), CkptError> {
        if self.version != FORMAT_VERSION {
            return Err(CkptError::VersionMismatch {
                found: self.version,
                supported: FORMAT_VERSION,
            });
        }
        if self.stage > 3 {
            return Err(CkptError::InvalidStage(self.stage));
        }
        if self.shards.is_empty() {
            return Err(CkptError::EmptyGroup);
        }
        let mut seen = std::collections::HashSet::new();
        for e in &self.shards {
            if !seen.insert(e.slot) {
                return Err(CkptError::Corrupt(format!("slot {} listed twice", e.slot)));
            }
            if e.range.hi > self.param_count {
                return Err(CkptError::Corrupt(format!(
                    "slot {} range [{}, {}) exceeds ψ={}",
                    e.slot, e.range.lo, e.range.hi, self.param_count
                )));
            }
        }
        match self.stage {
            0 => {
                for e in &self.shards {
                    if e.range != ShardRange::new(0, self.param_count) {
                        return Err(CkptError::Corrupt(format!(
                            "ZeRO-0 replicates: slot {} must own [0, ψ)",
                            e.slot
                        )));
                    }
                }
            }
            _ => {
                // contiguous tiling of [0, ψ) in shard order
                let mut cursor = 0u64;
                for e in &self.shards {
                    if e.range.lo != cursor {
                        return Err(CkptError::Corrupt(format!(
                            "gap or overlap at parameter {cursor} (slot {} starts at {})",
                            e.slot, e.range.lo
                        )));
                    }
                    cursor = e.range.hi;
                }
                if cursor != self.param_count {
                    return Err(CkptError::Corrupt(format!(
                        "layout covers {cursor} of ψ={} parameters",
                        self.param_count
                    )));
                }
            }
        }
        Ok(())
    }

    /// Check that `other` describes the same optimizer state (same model
    /// and ψ) so a re-layout between the two is meaningful. The stage may
    /// differ — that is exactly what [`migrate`] prices; [`reshard`]
    /// additionally insists on equal stages.
    fn check_compatible(&self, other: &ShardManifest) -> Result<(), CkptError> {
        if self.model != other.model {
            return Err(CkptError::Incompatible(format!(
                "model {:?} vs {:?}",
                self.model, other.model
            )));
        }
        if self.param_count != other.param_count {
            return Err(CkptError::Incompatible(format!(
                "ψ {} vs {}",
                self.param_count, other.param_count
            )));
        }
        Ok(())
    }

    /// Re-layout this manifest's slots at `new_stage` and price the
    /// cross-stage movement: returns the new manifest (same slots,
    /// `snapshot + 1`) plus the [`ReshardPlan`] taking the optimizer
    /// state there. See [`migrate`] for the pricing rules.
    pub fn migrate(&self, new_stage: u8) -> Result<(ShardManifest, ReshardPlan), CkptError> {
        let slots: Vec<(usize, TypeId)> =
            self.shards.iter().map(|e| (e.slot, e.gpu)).collect();
        let new = ShardManifest::build(
            &self.model,
            new_stage,
            self.param_count,
            self.snapshot + 1,
            &slots,
        )?;
        let plan = migrate(self, &new)?;
        Ok((new, plan))
    }
}

/// One shard transfer: `to_slot` receives `range`, either from a peer
/// (`from_slot = Some`) or restored off the checkpoint (`None` — the old
/// owner left the job, which is exactly what persistence is for).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardMove {
    /// Receiving slot.
    pub to_slot: usize,
    /// Sending slot, or `None` for a checkpoint restore.
    pub from_slot: Option<usize>,
    /// Parameter range transferred.
    pub range: ShardRange,
}

/// A retained region: `slot` already holds `range` and moves nothing.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RetainedShard {
    /// Owning slot.
    pub slot: usize,
    /// Parameter range kept in place.
    pub range: ShardRange,
}

/// The minimal shard-movement set between two layouts.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ReshardPlan {
    /// ZeRO stage of the *destination* layout.
    pub stage: u8,
    /// ZeRO stage of the source layout (`== stage` for a same-stage
    /// reshard; differs for a cross-stage migration).
    pub from_stage: u8,
    /// Total parameter count `ψ`.
    pub param_count: u64,
    /// Transfers, destination slot order.
    pub moves: Vec<ShardMove>,
    /// Regions that stay where they are.
    pub retained: Vec<RetainedShard>,
}

impl ReshardPlan {
    /// Optimizer-state bytes that must move (peer + checkpoint sources).
    pub fn bytes_moved(&self) -> u64 {
        self.moves.iter().map(|m| m.range.len() * OPTIMIZER_BYTES_PER_PARAM).sum()
    }

    /// Bytes restored from the checkpoint (no surviving owner).
    pub fn bytes_from_checkpoint(&self) -> u64 {
        self.moves
            .iter()
            .filter(|m| m.from_slot.is_none())
            .map(|m| m.range.len() * OPTIMIZER_BYTES_PER_PARAM)
            .sum()
    }

    /// Bytes that stay in place.
    pub fn bytes_retained(&self) -> u64 {
        self.retained.iter().map(|r| r.range.len() * OPTIMIZER_BYTES_PER_PARAM).sum()
    }

    /// True when nothing moves (layout unchanged).
    pub fn is_noop(&self) -> bool {
        self.moves.is_empty()
    }

    /// True when the plan crosses ZeRO stages (a migration).
    pub fn is_migration(&self) -> bool {
        self.stage != self.from_stage
    }

    /// Measured one-shot transfer time: point-to-point shard moves run in
    /// parallel, so the wall time is the most-loaded endpoint's
    /// `bytes / bw` plus a per-transfer latency — not a full-volume
    /// collective. Checkpoint restores are charged to the receiving rank
    /// at the same link bandwidth (the checkpoint store sits on the same
    /// fabric). Thin wrapper over [`EndpointLoads`], which incremental
    /// callers (the round engine's delta previews) can also fold moves
    /// into one at a time.
    pub fn transfer_time_s(&self, net: &NetSim) -> f64 {
        if self.moves.is_empty() {
            return 0.0;
        }
        let mut loads = EndpointLoads::default();
        for m in &self.moves {
            loads.add(m);
        }
        loads.time_s(net)
    }

    /// The recompute baseline: every rank of `new` refetches its entire
    /// shard from the checkpoint, retaining nothing — what a restart
    /// without shard-aware resharding pays.
    pub fn full_restore(new: &ShardManifest) -> ReshardPlan {
        ReshardPlan {
            stage: new.stage,
            from_stage: new.stage,
            param_count: new.param_count,
            moves: new
                .shards
                .iter()
                .filter(|e| !e.range.is_empty())
                .map(|e| ShardMove { to_slot: e.slot, from_slot: None, range: e.range })
                .collect(),
            retained: Vec::new(),
        }
    }
}

/// Per-endpoint transfer-load accumulator behind
/// [`ReshardPlan::transfer_time_s`]: fold [`ShardMove`]s in one at a
/// time, read the wall time whenever needed. Exists as its own type so
/// incremental pricing (the round engine's delta previews) updates
/// endpoint loads move-by-move instead of re-walking the whole plan.
#[derive(Debug, Clone, Default)]
pub struct EndpointLoads {
    /// Per-slot `(bytes sent, bytes received, transfer count)`.
    load: BTreeMap<usize, (u64, u64, u64)>,
}

impl EndpointLoads {
    /// Fold one move into the per-endpoint loads.
    pub fn add(&mut self, m: &ShardMove) {
        let bytes = m.range.len() * OPTIMIZER_BYTES_PER_PARAM;
        let d = self.load.entry(m.to_slot).or_insert((0, 0, 0));
        d.1 += bytes;
        d.2 += 1;
        if let Some(src) = m.from_slot {
            let s = self.load.entry(src).or_insert((0, 0, 0));
            s.0 += bytes;
            s.2 += 1;
        }
    }

    /// Wall time of the folded moves: the most-loaded endpoint's
    /// `bytes / bw` plus a per-transfer latency (0 when nothing was
    /// folded).
    pub fn time_s(&self, net: &NetSim) -> f64 {
        let bw = net.bw_gbs * 1e9;
        self.load
            .values()
            .map(|&(sent, recv, count)| {
                sent.max(recv) as f64 / bw + count as f64 * net.alpha_s
            })
            .fold(0.0, f64::max)
    }
}

/// Compute the minimal shard-movement set taking the optimizer state
/// from layout `old` to layout `new` at the *same* ZeRO stage.
///
/// Cross-stage layouts are rejected with [`CkptError::CrossStage`]: a
/// stage change rewrites the partition rule itself and is priced by the
/// typed migration path, [`migrate`].
pub fn reshard(old: &ShardManifest, new: &ShardManifest) -> Result<ReshardPlan, CkptError> {
    if old.stage != new.stage {
        return Err(CkptError::CrossStage { from: old.stage, to: new.stage });
    }
    migrate(old, new)
}

/// Compute the shard-movement set taking the optimizer state from
/// layout `old` to layout `new`, stage change allowed.
///
/// Every destination's new range is split into (a) the overlap with its
/// *own* old range — retained, zero cost — and (b) the rest, sourced
/// from each sub-interval's old owner if that owner survived, else from
/// the checkpoint. The stage only changes where bytes *live*:
///
/// * **partition → partition** (stages 1..=3 in any direction) — the
///   optimizer tiling rule is identical across the partitioned stages,
///   so with unchanged membership the migration is free; otherwise it
///   costs exactly the membership reshard (cheap overlaps).
/// * **replicate → partition** (0 → 1..=3) — every surviving slot
///   already holds the full state, so it retains its whole new shard;
///   only joiners fetch (from a round-robin surviving replica).
/// * **partition → replicate** (1..=3 → 0) — every slot must end with
///   the full `[0, ψ)`: each retains its old shard and fetches the rest
///   from the other owners — a priced all-gather-shaped broadcast, the
///   one genuinely expensive direction.
pub fn migrate(old: &ShardManifest, new: &ShardManifest) -> Result<ReshardPlan, CkptError> {
    MigrationIndex::new(old)?.migrate_to(new)
}

/// A reusable pricing index over one *incumbent* manifest.
///
/// `decide_round` prices every `(offer subset, stage)` candidate
/// against the SAME incumbent layout, and the plain [`migrate`] path
/// re-validated it and re-ran linear `shard_of` scans on every call —
/// O(candidates · n) redundant work per round. The index validates the
/// incumbent ONCE and keeps a slot-sorted interval table, so each
/// candidate pays only its own destination sweep: `shard_of` is a
/// binary search, and destination membership (`new.has_slot`) is
/// resolved through one sorted slot list per call instead of a linear
/// scan per overlap piece. Output is byte-identical to
/// [`migrate_reference`] (the property suite pins it).
#[derive(Debug)]
pub struct MigrationIndex<'a> {
    old: &'a ShardManifest,
    /// `(slot, index into old.shards)`, sorted by slot id.
    by_slot: Vec<(usize, usize)>,
}

impl<'a> MigrationIndex<'a> {
    /// Validate `old` once and build the slot index.
    pub fn new(old: &'a ShardManifest) -> Result<Self, CkptError> {
        old.validate()?;
        let mut by_slot: Vec<(usize, usize)> =
            old.shards.iter().enumerate().map(|(i, e)| (e.slot, i)).collect();
        by_slot.sort_unstable();
        Ok(MigrationIndex { old, by_slot })
    }

    /// The incumbent manifest the index was built over.
    pub fn old(&self) -> &ShardManifest {
        self.old
    }

    /// The incumbent range owned by `slot`, by binary search.
    pub fn shard_of(&self, slot: usize) -> Option<ShardRange> {
        self.by_slot
            .binary_search_by_key(&slot, |&(s, _)| s)
            .ok()
            .map(|i| self.old.shards[self.by_slot[i].1].range)
    }

    /// [`Self::migrate_to`] plus the transfer wall time ([`EndpointLoads`]
    /// pricing) in one call — what round previews actually consume.
    pub fn migrate_to_priced(
        &self,
        new: &ShardManifest,
        net: &NetSim,
    ) -> Result<(ReshardPlan, f64), CkptError> {
        let plan = self.migrate_to(new)?;
        let time_s = plan.transfer_time_s(net);
        Ok((plan, time_s))
    }

    /// Price the movement from the incumbent layout to `new` (stage
    /// change allowed) — [`migrate`] with the incumbent-side work
    /// amortized across calls. See [`migrate`] for the pricing rules.
    pub fn migrate_to(&self, new: &ShardManifest) -> Result<ReshardPlan, CkptError> {
        let old = self.old;
        new.validate()?;
        old.check_compatible(new)?;

        // one sorted destination-slot list per call: has_slot becomes a
        // binary search instead of a linear scan per overlap piece
        let mut new_slots: Vec<usize> = new.shards.iter().map(|e| e.slot).collect();
        new_slots.sort_unstable();
        let in_new = |slot: usize| new_slots.binary_search(&slot).is_ok();

        let mut moves = Vec::new();
        let mut retained = Vec::new();

        // when the old layout replicates (ZeRO-0), any gap has *every*
        // surviving old slot as a possible source: round-robin the
        // fetches over them so a multi-join batch does not serialize on
        // one donor
        let donors: Vec<usize> = if old.stage == 0 {
            old.shards.iter().map(|e| e.slot).filter(|&s| in_new(s)).collect()
        } else {
            Vec::new()
        };
        let mut k = 0usize;

        for e in &new.shards {
            if e.range.is_empty() {
                continue;
            }
            let kept = self.shard_of(e.slot).and_then(|o| o.intersect(&e.range));
            if let Some(kr) = kept {
                retained.push(RetainedShard { slot: e.slot, range: kr });
            }
            // the (up to two) gaps of e.range not covered by `kept`
            let gaps: Vec<ShardRange> = match kept {
                None => vec![e.range],
                Some(kr) => {
                    let mut g = Vec::new();
                    if e.range.lo < kr.lo {
                        g.push(ShardRange::new(e.range.lo, kr.lo));
                    }
                    if kr.hi < e.range.hi {
                        g.push(ShardRange::new(kr.hi, e.range.hi));
                    }
                    g
                }
            };
            for gap in gaps {
                if old.stage == 0 {
                    // replicated source: one donor serves the whole gap
                    let from_slot = if donors.is_empty() {
                        None
                    } else {
                        k += 1;
                        Some(donors[(k - 1) % donors.len()])
                    };
                    moves.push(ShardMove { to_slot: e.slot, from_slot, range: gap });
                } else {
                    // partitioned source tiles [0, ψ) contiguously in
                    // shard order (validate() enforced it), so
                    // binary-search the first overlapping owner and sweep
                    // linearly from there — emission order is identical
                    // to the full scan
                    let start = old.shards.partition_point(|o| o.range.hi <= gap.lo);
                    for o in &old.shards[start..] {
                        if o.range.lo >= gap.hi {
                            break;
                        }
                        if let Some(piece) = o.range.intersect(&gap) {
                            let from_slot =
                                if in_new(o.slot) { Some(o.slot) } else { None };
                            moves.push(ShardMove { to_slot: e.slot, from_slot, range: piece });
                        }
                    }
                }
            }
        }

        Ok(ReshardPlan {
            stage: new.stage,
            from_stage: old.stage,
            param_count: old.param_count,
            moves,
            retained,
        })
    }
}

/// The pre-index reference implementation of [`migrate`], retained
/// verbatim so the equivalence property suite can pin the indexed path
/// byte-identical to it on random layout pairs. Not a hot path — do not
/// call it outside tests/benches.
pub fn migrate_reference(
    old: &ShardManifest,
    new: &ShardManifest,
) -> Result<ReshardPlan, CkptError> {
    old.validate()?;
    new.validate()?;
    old.check_compatible(new)?;

    let mut moves = Vec::new();
    let mut retained = Vec::new();

    let donors: Vec<usize> = if old.stage == 0 {
        old.shards
            .iter()
            .map(|e| e.slot)
            .filter(|&s| new.has_slot(s))
            .collect()
    } else {
        Vec::new()
    };
    let mut k = 0usize;

    for e in &new.shards {
        if e.range.is_empty() {
            continue;
        }
        let kept = old.shard_of(e.slot).and_then(|o| o.intersect(&e.range));
        if let Some(kr) = kept {
            retained.push(RetainedShard { slot: e.slot, range: kr });
        }
        let gaps: Vec<ShardRange> = match kept {
            None => vec![e.range],
            Some(kr) => {
                let mut g = Vec::new();
                if e.range.lo < kr.lo {
                    g.push(ShardRange::new(e.range.lo, kr.lo));
                }
                if kr.hi < e.range.hi {
                    g.push(ShardRange::new(kr.hi, e.range.hi));
                }
                g
            }
        };
        for gap in gaps {
            if old.stage == 0 {
                let from_slot = if donors.is_empty() {
                    None
                } else {
                    k += 1;
                    Some(donors[(k - 1) % donors.len()])
                };
                moves.push(ShardMove { to_slot: e.slot, from_slot, range: gap });
            } else {
                let start = old.shards.partition_point(|o| o.range.hi <= gap.lo);
                for o in &old.shards[start..] {
                    if o.range.lo >= gap.hi {
                        break;
                    }
                    if let Some(piece) = o.range.intersect(&gap) {
                        let from_slot = if new.has_slot(o.slot) {
                            Some(o.slot)
                        } else {
                            None
                        };
                        moves.push(ShardMove { to_slot: e.slot, from_slot, range: piece });
                    }
                }
            }
        }
    }

    Ok(ReshardPlan {
        stage: new.stage,
        from_stage: old.stage,
        param_count: old.param_count,
        moves,
        retained,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::LinkKind;

    fn slots(ids: &[usize]) -> Vec<(usize, crate::intern::TypeId)> {
        ids.iter().map(|&i| (i, crate::intern::intern(&format!("G{i}")))).collect()
    }

    fn manifest(stage: u8, psi: u64, ids: &[usize], snapshot: usize) -> ShardManifest {
        ShardManifest::build("m", stage, psi, snapshot, &slots(ids)).unwrap()
    }

    #[test]
    fn build_tiles_param_space_for_partitioned_stages() {
        for stage in 1..=3u8 {
            let m = manifest(stage, 1003, &[0, 1, 2, 3], 0);
            m.validate().unwrap();
            assert_eq!(m.shards[0].range.lo, 0);
            assert_eq!(m.shards.last().unwrap().range.hi, 1003);
            let total: u64 = m.shards.iter().map(|e| e.range.len()).sum();
            assert_eq!(total, 1003);
            // remainder goes to the first ranks
            assert_eq!(m.shards[0].range.len(), 251);
            assert_eq!(m.shards[3].range.len(), 250);
        }
    }

    #[test]
    fn build_replicates_for_stage0() {
        let m = manifest(0, 500, &[0, 1], 0);
        m.validate().unwrap();
        for e in &m.shards {
            assert_eq!(e.range, ShardRange::new(0, 500));
        }
    }

    #[test]
    fn build_rejects_bad_inputs() {
        assert!(matches!(
            ShardManifest::build("m", 4, 100, 0, &slots(&[0])),
            Err(CkptError::InvalidStage(4))
        ));
        assert!(matches!(
            ShardManifest::build("m", 1, 100, 0, &[]),
            Err(CkptError::EmptyGroup)
        ));
    }

    #[test]
    fn noop_reshard_when_layout_unchanged() {
        let a = manifest(1, 1000, &[0, 1, 2], 0);
        let b = manifest(1, 1000, &[0, 1, 2], 1);
        let plan = reshard(&a, &b).unwrap();
        assert!(plan.is_noop());
        assert_eq!(plan.bytes_moved(), 0);
        assert_eq!(plan.bytes_retained(), 1000 * OPTIMIZER_BYTES_PER_PARAM);
        assert_eq!(plan.transfer_time_s(&NetSim::from_link(3, LinkKind::Ib)), 0.0);
    }

    #[test]
    fn lost_rank_restores_only_its_shard_from_checkpoint() {
        // 4 ranks -> slot 3 lost -> 3 ranks: survivors grow, and the
        // bytes with no surviving owner come off the checkpoint. A
        // realistic ψ keeps the comparison bandwidth-bound (at toy sizes
        // per-transfer latency dominates and the ordering is undefined).
        let psi = 1_200_000_000u64;
        let old = manifest(1, psi, &[0, 1, 2, 3], 0);
        let new = manifest(1, psi, &[0, 1, 2], 1);
        let plan = reshard(&old, &new).unwrap();
        assert!(!plan.is_noop());
        // moved + retained exactly cover each destination's new range
        for e in &new.shards {
            let got: u64 = plan
                .moves
                .iter()
                .filter(|m| m.to_slot == e.slot)
                .map(|m| m.range.len())
                .chain(
                    plan.retained
                        .iter()
                        .filter(|r| r.slot == e.slot)
                        .map(|r| r.range.len()),
                )
                .sum();
            assert_eq!(got, e.range.len(), "slot {}", e.slot);
        }
        // slot 3 owned the last quarter: exactly those params come from disk
        assert_eq!(plan.bytes_from_checkpoint(), (psi / 4) * OPTIMIZER_BYTES_PER_PARAM);
        // minimal movement beats the full-restore recompute baseline
        let recompute = ReshardPlan::full_restore(&new);
        assert!(plan.bytes_moved() < recompute.bytes_moved());
        let net = NetSim::from_link(3, LinkKind::Ib);
        assert!(plan.transfer_time_s(&net) < recompute.transfer_time_s(&net));
    }

    #[test]
    fn join_moves_only_the_new_shard() {
        let old = manifest(2, 1200, &[0, 1, 2], 0);
        let new = manifest(2, 1200, &[0, 1, 2, 7], 1);
        let plan = reshard(&old, &new).unwrap();
        // every byte has a surviving owner: nothing comes off the disk
        assert_eq!(plan.bytes_from_checkpoint(), 0);
        // the joiner receives its whole shard from peers
        let joiner_bytes: u64 = plan
            .moves
            .iter()
            .filter(|m| m.to_slot == 7)
            .map(|m| m.range.len())
            .sum();
        assert_eq!(joiner_bytes, new.shard_of(7).unwrap().len());
        assert!(plan.moves.iter().all(|m| m.from_slot.is_some()));
    }

    #[test]
    fn stage0_join_fetches_full_copy_and_losses_are_free() {
        let old = manifest(0, 800, &[0, 1], 0);
        let lost = manifest(0, 800, &[0], 1);
        assert!(reshard(&old, &lost).unwrap().is_noop());
        let joined = manifest(0, 800, &[0, 1, 2], 1);
        let plan = reshard(&old, &joined).unwrap();
        assert_eq!(plan.moves.len(), 1);
        assert_eq!(plan.moves[0].to_slot, 2);
        assert_eq!(plan.moves[0].from_slot, Some(0));
        assert_eq!(plan.moves[0].range.len(), 800);
    }

    #[test]
    fn stage0_multi_join_spreads_donors() {
        let old = manifest(0, 800, &[0, 1], 0);
        let joined = manifest(0, 800, &[0, 1, 2, 3, 4], 1);
        let plan = reshard(&old, &joined).unwrap();
        let sources: Vec<Option<usize>> =
            plan.moves.iter().map(|m| m.from_slot).collect();
        // three joiners over two donors: round-robin 0, 1, 0
        assert_eq!(sources, vec![Some(0), Some(1), Some(0)]);
    }

    #[test]
    fn incompatible_manifests_rejected() {
        let a = manifest(1, 1000, &[0, 1], 0);
        // a stage change is no longer a generic incompatibility: it is
        // the typed cross-stage path, pointing at migrate()
        let b = manifest(2, 1000, &[0, 1], 0);
        assert!(matches!(
            reshard(&a, &b),
            Err(CkptError::CrossStage { from: 1, to: 2 })
        ));
        assert!(migrate(&a, &b).is_ok());
        // model/ψ mismatches stay hard errors on both paths
        let c = manifest(1, 999, &[0, 1], 0);
        assert!(matches!(reshard(&a, &c), Err(CkptError::Incompatible(_))));
        assert!(matches!(migrate(&a, &c), Err(CkptError::Incompatible(_))));
    }

    #[test]
    fn partition_to_partition_migration_is_free_with_same_membership() {
        // stages 1..=3 share the optimizer tiling rule: changing between
        // them moves zero optimizer bytes when the membership is stable
        let psi = 1_000_000u64;
        for (from, to) in [(1u8, 2u8), (2, 3), (3, 1), (1, 3)] {
            let old = manifest(from, psi, &[0, 1, 2], 0);
            let (new, plan) = old.migrate(to).unwrap();
            assert_eq!(new.stage, to);
            assert_eq!(new.snapshot, old.snapshot + 1);
            new.validate().unwrap();
            assert!(plan.is_noop(), "{from}->{to} moved bytes");
            assert!(plan.is_migration());
            assert_eq!(plan.from_stage, from);
            assert_eq!(plan.stage, to);
            assert_eq!(plan.bytes_retained(), psi * OPTIMIZER_BYTES_PER_PARAM);
        }
    }

    #[test]
    fn replicate_to_partition_migration_is_free_for_survivors() {
        // de-escalating from ZeRO-0: every slot already holds the full
        // state, so it retains its new shard in place
        let old = manifest(0, 900, &[0, 1, 2], 0);
        let (new, plan) = old.migrate(3).unwrap();
        assert!(plan.is_noop());
        assert_eq!(plan.bytes_retained(), 900 * OPTIMIZER_BYTES_PER_PARAM);
        // a joiner alongside the stage change still fetches its shard
        let joined = ShardManifest::build("m", 3, 900, 1, &slots(&[0, 1, 2, 7])).unwrap();
        let plan = migrate(&old, &joined).unwrap();
        let joiner: u64 = plan
            .moves
            .iter()
            .filter(|m| m.to_slot == 7)
            .map(|m| m.range.len())
            .sum();
        assert_eq!(joiner, joined.shard_of(7).unwrap().len());
        assert!(plan.moves.iter().all(|m| m.from_slot.is_some()));
    }

    #[test]
    fn partition_to_replicate_migration_prices_the_broadcast() {
        // escalation to ZeRO-0 replication: every rank must end with the
        // full [0, ψ) — the one genuinely expensive direction
        let psi = 1_200_000u64;
        let old = manifest(2, psi, &[0, 1, 2, 3], 0);
        let (new, plan) = old.migrate(0).unwrap();
        new.validate().unwrap();
        assert!(!plan.is_noop());
        // each of the 4 ranks retains its own quarter and fetches the
        // other three quarters: 4 * (3/4)ψ moved, 4 * (1/4)ψ retained
        assert_eq!(plan.bytes_moved(), 3 * psi * OPTIMIZER_BYTES_PER_PARAM);
        assert_eq!(plan.bytes_retained(), psi * OPTIMIZER_BYTES_PER_PARAM);
        // every byte has a surviving owner: nothing off the checkpoint
        assert_eq!(plan.bytes_from_checkpoint(), 0);
        // and the broadcast costs real time
        let net = NetSim::from_link(4, LinkKind::Ib);
        assert!(plan.transfer_time_s(&net) > 0.0);
    }

    #[test]
    fn migration_combined_with_loss_sources_from_checkpoint() {
        // slot 3 departs in the same event as a 1 -> 2 stage change: the
        // bytes only it owned must come off the checkpoint
        let psi = 1_000_000u64;
        let old = manifest(1, psi, &[0, 1, 2, 3], 0);
        let new = ShardManifest::build("m", 2, psi, 1, &slots(&[0, 1, 2])).unwrap();
        let plan = migrate(&old, &new).unwrap();
        assert!(plan.is_migration());
        assert!(plan.bytes_from_checkpoint() > 0);
        // destinations are covered exactly
        for e in &new.shards {
            let got: u64 = plan
                .moves
                .iter()
                .filter(|m| m.to_slot == e.slot)
                .map(|m| m.range.len())
                .chain(
                    plan.retained
                        .iter()
                        .filter(|r| r.slot == e.slot)
                        .map(|r| r.range.len()),
                )
                .sum();
            assert_eq!(got, e.range.len(), "slot {}", e.slot);
        }
    }

    #[test]
    fn validate_catches_corrupt_layouts() {
        let mut m = manifest(1, 1000, &[0, 1], 0);
        m.shards[1].range.lo += 1; // gap
        assert!(matches!(m.validate(), Err(CkptError::Corrupt(_))));
        let mut m = manifest(1, 1000, &[0, 1], 0);
        m.shards[1].slot = 0; // duplicate
        assert!(matches!(m.validate(), Err(CkptError::Corrupt(_))));
        let mut m = manifest(1, 1000, &[0, 1], 0);
        m.version = 99;
        assert!(matches!(m.validate(), Err(CkptError::VersionMismatch { .. })));
    }

    #[test]
    fn transfer_time_scales_with_bytes_and_link() {
        let old = manifest(3, 4_000_000, &[0, 1, 2, 3], 0);
        let new = manifest(3, 4_000_000, &[0, 1], 1);
        let plan = reshard(&old, &new).unwrap();
        let fast = plan.transfer_time_s(&NetSim::from_link(2, LinkKind::Nvlink));
        let slow = plan.transfer_time_s(&NetSim::from_link(2, LinkKind::Socket));
        assert!(slow > fast);
        assert!(fast > 0.0);
    }
}
