//! Versioned on-disk serialization of [`ShardManifest`]s.
//!
//! Plain line-oriented text (the offline image has no serde), written
//! under the checkpoint directory (default `artifacts/ckpt/`):
//!
//! ```text
//! poplar-ckpt v1
//! model llama-0.5b
//! stage 1
//! params 468377600
//! snapshot 12
//! shards 8
//! shard 0 A800-80G 0 58547200
//! ...
//! end
//! ```
//!
//! Version policy (recorded in ROADMAP): the header carries the format
//! version; loaders accept exactly [`FORMAT_VERSION`] and fail with
//! [`CkptError::VersionMismatch`] otherwise. Any field change — adding
//! one included — bumps the version; there is no silent
//! forward-compatibility. The `end` trailer guards against truncated
//! writes. Each snapshot is one file (`manifest-NNNNNN.ckpt`); `LATEST`
//! holds the newest file name so restore never scans the directory.

use std::path::{Path, PathBuf};

use super::{CkptError, ShardEntry, ShardManifest, ShardRange, FORMAT_VERSION};

/// Magic first token of every checkpoint file.
pub const MAGIC: &str = "poplar-ckpt";

/// Name of the pointer file holding the newest snapshot's file name.
pub const LATEST: &str = "LATEST";

fn corrupt(msg: impl Into<String>) -> CkptError {
    CkptError::Corrupt(msg.into())
}

impl ShardManifest {
    /// Render to the versioned text format.
    pub fn to_text(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!("{MAGIC} v{}\n", self.version));
        s.push_str(&format!("model {}\n", self.model));
        s.push_str(&format!("stage {}\n", self.stage));
        s.push_str(&format!("params {}\n", self.param_count));
        s.push_str(&format!("snapshot {}\n", self.snapshot));
        s.push_str(&format!("shards {}\n", self.shards.len()));
        for e in &self.shards {
            s.push_str(&format!("shard {} {} {} {}\n", e.slot, e.gpu, e.range.lo, e.range.hi));
        }
        s.push_str("end\n");
        s
    }

    /// Parse the text format, validating structure and version.
    pub fn from_text(s: &str) -> Result<Self, CkptError> {
        let mut lines = s.lines();
        let header = lines.next().ok_or_else(|| corrupt("empty file"))?;
        let version = header
            .strip_prefix(MAGIC)
            .map(str::trim)
            .and_then(|v| v.strip_prefix('v'))
            .and_then(|v| v.parse::<u32>().ok())
            .ok_or_else(|| corrupt(format!("bad header {header:?}")))?;
        if version != FORMAT_VERSION {
            return Err(CkptError::VersionMismatch { found: version, supported: FORMAT_VERSION });
        }

        fn field<'a>(
            lines: &mut std::str::Lines<'a>,
            key: &str,
        ) -> Result<&'a str, CkptError> {
            let line = lines.next().ok_or_else(|| corrupt(format!("missing {key}")))?;
            line.strip_prefix(key)
                .and_then(|v| v.strip_prefix(' '))
                .ok_or_else(|| corrupt(format!("expected {key:?}, got {line:?}")))
        }

        let model = field(&mut lines, "model")?.to_string();
        let stage: u8 = field(&mut lines, "stage")?
            .parse()
            .map_err(|_| corrupt("stage not a number"))?;
        let param_count: u64 = field(&mut lines, "params")?
            .parse()
            .map_err(|_| corrupt("params not a number"))?;
        let snapshot: usize = field(&mut lines, "snapshot")?
            .parse()
            .map_err(|_| corrupt("snapshot not a number"))?;
        let n: usize = field(&mut lines, "shards")?
            .parse()
            .map_err(|_| corrupt("shards not a number"))?;

        // the count is untrusted input: never let it size an allocation
        // (a corrupt `shards 1844…` line must error, not abort), and the
        // loop below errors naturally when the lines run out
        let mut shards = Vec::with_capacity(n.min(1024));
        for _ in 0..n {
            let line = field(&mut lines, "shard")?;
            let parts: Vec<&str> = line.split_whitespace().collect();
            if parts.len() != 4 {
                return Err(corrupt(format!("bad shard line {line:?}")));
            }
            let slot: usize = parts[0].parse().map_err(|_| corrupt("shard slot"))?;
            let lo: u64 = parts[2].parse().map_err(|_| corrupt("shard lo"))?;
            let hi: u64 = parts[3].parse().map_err(|_| corrupt("shard hi"))?;
            if hi < lo {
                return Err(corrupt(format!("shard range [{lo}, {hi}) inverted")));
            }
            shards.push(ShardEntry {
                slot,
                gpu: crate::intern::intern(parts[1]),
                range: ShardRange::new(lo, hi),
            });
        }
        if lines.next() != Some("end") {
            return Err(corrupt("missing end trailer (truncated write?)"));
        }
        if lines.next().is_some() {
            return Err(corrupt("trailing content after the end trailer"));
        }

        let m = ShardManifest { version, model, stage, param_count, snapshot, shards };
        m.validate()?;
        Ok(m)
    }

    /// File name this snapshot serializes to.
    pub fn file_name(&self) -> String {
        format!("manifest-{:06}.ckpt", self.snapshot)
    }

    /// Write the snapshot under `dir` (created if absent) and update the
    /// `LATEST` pointer. Both writes go through a temp-file + rename so
    /// a crash mid-write can never leave `LATEST` pointing at a
    /// truncated snapshot (renames are atomic on POSIX filesystems), and
    /// `LATEST` only ever advances — re-saving an older ordinal (e.g. a
    /// manual `poplar ckpt save` into a live run's directory) cannot
    /// silently roll the restore point backwards. A run that *owns* the
    /// directory repoints `LATEST` unconditionally on its first snapshot
    /// via [`ShardManifest::save_with`]. Returns the snapshot's path.
    pub fn save(&self, dir: &Path) -> Result<PathBuf, CkptError> {
        self.save_with(dir, false)
    }

    /// [`ShardManifest::save`] with control over the pointer:
    /// `force_latest` repoints `LATEST` at this snapshot even when an
    /// older run left a higher ordinal behind — `run_elastic_job` uses
    /// it for the first snapshot of a run so a reused directory tracks
    /// the *current* run instead of a dead one's tail.
    pub fn save_with(&self, dir: &Path, force_latest: bool) -> Result<PathBuf, CkptError> {
        self.validate()?;
        std::fs::create_dir_all(dir)?;
        let name = self.file_name();
        let path = dir.join(&name);
        let tmp = dir.join(format!("{name}.tmp"));
        std::fs::write(&tmp, self.to_text())?;
        std::fs::rename(&tmp, &path)?;
        // compare parsed ordinals, not names: the {:06} padding does not
        // truncate, so string order breaks past 999999 snapshots
        let current_ord = std::fs::read_to_string(dir.join(LATEST))
            .ok()
            .and_then(|s| {
                s.trim()
                    .strip_prefix("manifest-")?
                    .strip_suffix(".ckpt")?
                    .parse::<u64>()
                    .ok()
            });
        let advance = match current_ord {
            Some(c) => c < self.snapshot as u64,
            None => true,
        };
        if force_latest || advance {
            let latest_tmp = dir.join(format!("{LATEST}.tmp"));
            std::fs::write(&latest_tmp, format!("{name}\n"))?;
            std::fs::rename(latest_tmp, dir.join(LATEST))?;
        }
        Ok(path)
    }

    /// Load one snapshot file.
    pub fn load(path: &Path) -> Result<Self, CkptError> {
        let s = std::fs::read_to_string(path)?;
        Self::from_text(&s)
    }

    /// Load the newest snapshot in `dir` via the `LATEST` pointer.
    pub fn load_latest(dir: &Path) -> Result<Self, CkptError> {
        let name = std::fs::read_to_string(dir.join(LATEST))?;
        Self::load(&dir.join(name.trim()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> ShardManifest {
        ShardManifest::build(
            "llama-0.5b",
            1,
            1003,
            7,
            &[(0, "A800-80G".into()), (2, "V100S-32G".into()), (5, "T4".into())],
        )
        .unwrap()
    }

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("poplar-ckpt-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn text_roundtrip_is_identity() {
        let m = sample();
        let back = ShardManifest::from_text(&m.to_text()).unwrap();
        assert_eq!(back, m);
    }

    #[test]
    fn disk_roundtrip_and_latest_pointer() {
        let dir = tmpdir("disk");
        let mut m = sample();
        m.save(&dir).unwrap();
        m.snapshot = 8;
        let p = m.save(&dir).unwrap();
        assert!(p.ends_with("manifest-000008.ckpt"));
        let latest = ShardManifest::load_latest(&dir).unwrap();
        assert_eq!(latest, m);
        // the older snapshot is still loadable directly
        let old = ShardManifest::load(&dir.join("manifest-000007.ckpt")).unwrap();
        assert_eq!(old.snapshot, 7);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn latest_pointer_only_advances() {
        let dir = tmpdir("advance");
        let mut m = sample();
        m.snapshot = 9;
        m.save(&dir).unwrap();
        // a re-save of an older ordinal must not roll LATEST back
        m.snapshot = 3;
        m.save(&dir).unwrap();
        let latest = ShardManifest::load_latest(&dir).unwrap();
        assert_eq!(latest.snapshot, 9);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn force_latest_repoints_for_a_new_run() {
        let dir = tmpdir("force");
        let mut m = sample();
        m.snapshot = 9; // a dead run's tail
        m.save(&dir).unwrap();
        m.snapshot = 0; // a fresh run claims the directory
        m.save_with(&dir, true).unwrap();
        assert_eq!(ShardManifest::load_latest(&dir).unwrap().snapshot, 0);
        // subsequent advance-only saves track the new run
        m.snapshot = 1;
        m.save(&dir).unwrap();
        assert_eq!(ShardManifest::load_latest(&dir).unwrap().snapshot, 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn huge_shard_count_is_error_not_panic() {
        let txt = sample()
            .to_text()
            .replace("shards 3", "shards 18446744073709551615");
        assert!(matches!(ShardManifest::from_text(&txt), Err(CkptError::Corrupt(_))));
    }

    #[test]
    fn trailing_content_rejected() {
        let full = sample().to_text();
        let doubled = format!("{full}{full}");
        assert!(matches!(ShardManifest::from_text(&doubled), Err(CkptError::Corrupt(_))));
        let tail = format!("{full}stray\n");
        assert!(matches!(ShardManifest::from_text(&tail), Err(CkptError::Corrupt(_))));
    }

    #[test]
    fn unknown_version_rejected() {
        let txt = sample().to_text().replace("poplar-ckpt v1", "poplar-ckpt v2");
        assert!(matches!(
            ShardManifest::from_text(&txt),
            Err(CkptError::VersionMismatch { found: 2, supported: 1 })
        ));
    }

    #[test]
    fn truncated_or_mangled_files_rejected() {
        let full = sample().to_text();
        let truncated: String = full.lines().take(5).map(|l| format!("{l}\n")).collect();
        assert!(matches!(ShardManifest::from_text(&truncated), Err(CkptError::Corrupt(_))));
        let no_end = full.replace("end\n", "");
        assert!(matches!(ShardManifest::from_text(&no_end), Err(CkptError::Corrupt(_))));
        assert!(matches!(ShardManifest::from_text(""), Err(CkptError::Corrupt(_))));
        assert!(matches!(
            ShardManifest::from_text("not-a-ckpt v1\n"),
            Err(CkptError::Corrupt(_))
        ));
    }

    #[test]
    fn missing_latest_is_io_error() {
        let dir = tmpdir("empty");
        assert!(matches!(ShardManifest::load_latest(&dir), Err(CkptError::Io(_))));
    }
}
