//! `poplar lint` — in-crate invariant analyzer.
//!
//! Replaces the CI shell greps with a real pass over the crate's own
//! source: [`lexer`] masks comments / literal payloads and tracks
//! `#[cfg(test)]` spans, [`rules`] runs substring checks over the
//! masked code, and this module owns file walking, the allow
//! mechanism (`lint:allow` + `(rule)` + ` -- reason`, reason
//! mandatory), the `lint-baseline.txt` ratchet, and the JSON report.
//!
//! Wired three ways so it cannot rot: the `poplar lint` CLI
//! subcommand, the `tests/lint_gate.rs` tier-1 integration test, and
//! the CI lint step (which uploads `lint-report.json` as an artifact).
//!
//! The ratchet is exact-match per `(rule, path)`: more diagnostics
//! than the frozen count fail as new violations, and *fewer* fail as
//! stale entries — the fix is rerunning `--write-baseline`, so the
//! committed baseline only ever shrinks.

pub mod lexer;
pub mod rules;

use std::collections::BTreeMap;
use std::fmt;
use std::fs;
use std::path::{Path, PathBuf};

use lexer::SourceFile;

/// Committed ratchet file, relative to the crate root.
pub const BASELINE_FILE: &str = "lint-baseline.txt";

/// Scanned roots and whether their files compile only into test
/// binaries (which exempts them from `panic-path`).
const ROOTS: &[(&str, bool)] = &[("src", false), ("tests", true), ("benches", true)];

/// Frozen `(rule, path) -> count` entries from [`BASELINE_FILE`].
pub type Baseline = BTreeMap<(String, String), usize>;

/// One finding, rendered as `path:line: rule: message`.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
pub struct Diagnostic {
    /// Crate-root-relative path with `/` separators.
    pub path: String,
    /// 1-based source line.
    pub line: usize,
    /// Rule id from [`rules::ALL`].
    pub rule: &'static str,
    /// Human-facing explanation.
    pub message: String,
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: {}: {}", self.path, self.line, self.rule, self.message)
    }
}

/// A baseline entry whose frozen count no longer matches reality.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StaleEntry {
    pub rule: String,
    pub path: String,
    /// Count frozen in the committed baseline.
    pub frozen: usize,
    /// Count the analyzer actually sees now.
    pub actual: usize,
}

/// Analyzer failure: not a diagnostic, the run itself broke.
#[derive(Debug)]
pub enum LintError {
    /// Filesystem failure while walking or reading sources.
    Io(String),
    /// Malformed [`BASELINE_FILE`].
    Baseline(String),
}

impl fmt::Display for LintError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            LintError::Io(m) => write!(f, "lint i/o error: {m}"),
            LintError::Baseline(m) => write!(f, "lint baseline error: {m}"),
        }
    }
}

impl std::error::Error for LintError {}

/// Raw scan output, before the baseline is applied.
#[derive(Debug)]
pub struct Scan {
    /// Number of `.rs` files lexed.
    pub files_scanned: usize,
    /// Every diagnostic that survived the allow mechanism.
    pub diagnostics: Vec<Diagnostic>,
}

/// Final verdict after the baseline ratchet.
#[derive(Debug)]
pub struct LintReport {
    pub files_scanned: usize,
    /// Diagnostics not absorbed by the baseline — the build breakers.
    pub new: Vec<Diagnostic>,
    /// Diagnostics absorbed as frozen debt.
    pub baselined: usize,
    /// Baseline entries that over- or under-count reality.
    pub stale: Vec<StaleEntry>,
}

impl LintReport {
    /// Clean means mergeable: no new violations, no stale entries.
    pub fn is_clean(&self) -> bool {
        self.new.is_empty() && self.stale.is_empty()
    }

    /// Render the machine-readable report uploaded by CI.
    pub fn to_json(&self) -> String {
        let mut out = String::from("{\n");
        out.push_str(&format!("  \"clean\": {},\n", self.is_clean()));
        out.push_str(&format!("  \"files_scanned\": {},\n", self.files_scanned));
        out.push_str(&format!("  \"baselined\": {},\n", self.baselined));
        out.push_str("  \"new_violations\": [");
        for (i, d) in self.new.iter().enumerate() {
            out.push_str(if i == 0 { "\n" } else { ",\n" });
            out.push_str(&format!(
                "    {{\"path\": \"{}\", \"line\": {}, \"rule\": \"{}\", \"message\": \"{}\"}}",
                json_escape(&d.path),
                d.line,
                d.rule,
                json_escape(&d.message)
            ));
        }
        out.push_str(if self.new.is_empty() { "],\n" } else { "\n  ],\n" });
        out.push_str("  \"stale_baseline\": [");
        for (i, e) in self.stale.iter().enumerate() {
            out.push_str(if i == 0 { "\n" } else { ",\n" });
            out.push_str(&format!(
                "    {{\"rule\": \"{}\", \"path\": \"{}\", \"frozen\": {}, \"actual\": {}}}",
                json_escape(&e.rule),
                json_escape(&e.path),
                e.frozen,
                e.actual
            ));
        }
        out.push_str(if self.stale.is_empty() { "]\n" } else { "\n  ]\n" });
        out.push_str("}\n");
        out
    }
}

/// Lex + rule-check one source text. Entry point for fixture tests;
/// `tests/` and `benches/` path prefixes mark the whole file as test
/// code, mirroring [`scan_crate`].
pub fn check_source(path: &str, text: &str) -> Vec<Diagnostic> {
    let all_test = path.starts_with("tests/") || path.starts_with("benches/");
    check_with_allows(&lexer::lex(path, text, all_test))
}

/// Run the rules over a lexed file, then apply its allow directives.
/// A reasoned allow naming a known rule suppresses that rule on its
/// own line (inline) or the next line (standalone comment). Malformed
/// directives become `allow-directive` diagnostics and are themselves
/// unsuppressable.
pub fn check_with_allows(f: &SourceFile) -> Vec<Diagnostic> {
    let mut diags = rules::check_file(f);
    let mut suppressed: Vec<(usize, &str)> = Vec::new();
    for a in &f.allows {
        if rules::is_known(&a.rule) && a.has_reason {
            let target = if a.inline { a.line } else { a.line + 1 };
            suppressed.push((target, a.rule.as_str()));
        }
    }
    diags.retain(|d| !suppressed.iter().any(|(l, r)| *l == d.line && *r == d.rule));
    for a in &f.allows {
        if !rules::is_known(&a.rule) {
            diags.push(Diagnostic {
                path: f.path.clone(),
                line: a.line,
                rule: rules::ALLOW_DIRECTIVE,
                message: format!("allow directive names unknown rule {:?}", a.rule),
            });
        } else if !a.has_reason {
            diags.push(Diagnostic {
                path: f.path.clone(),
                line: a.line,
                rule: rules::ALLOW_DIRECTIVE,
                message: format!(
                    "allow for `{}` has no reason — append `-- <why this is sound>`",
                    a.rule
                ),
            });
        }
    }
    diags.sort();
    diags
}

/// Walk every scanned root under `root` and rule-check each `.rs`
/// file. Deterministic: files are visited in sorted path order.
pub fn scan_crate(root: &Path) -> Result<Scan, LintError> {
    let mut files_scanned = 0;
    let mut diagnostics = Vec::new();
    for (dir, all_test) in ROOTS {
        let base = root.join(dir);
        if !base.is_dir() {
            continue;
        }
        let mut files = Vec::new();
        collect_rs(&base, &mut files)?;
        files.sort();
        for path in files {
            let text = fs::read_to_string(&path)
                .map_err(|e| LintError::Io(format!("read {}: {e}", path.display())))?;
            let rel = rel_path(root, &path);
            diagnostics.extend(check_with_allows(&lexer::lex(&rel, &text, *all_test)));
            files_scanned += 1;
        }
    }
    Ok(Scan { files_scanned, diagnostics })
}

/// Scan, load the committed baseline, and apply the ratchet. What the
/// CLI subcommand and the `lint_gate` test both call.
pub fn run_crate(root: &Path) -> Result<LintReport, LintError> {
    let scan = scan_crate(root)?;
    let baseline = load_baseline(root)?;
    Ok(apply_baseline(scan, &baseline))
}

/// Apply the exact-match ratchet: per `(rule, path)`, actual == frozen
/// absorbs, actual < frozen is stale (regenerate to shrink), actual >
/// frozen resurfaces the whole group as new violations.
/// `allow-directive` diagnostics are never baselinable.
pub fn apply_baseline(scan: Scan, baseline: &Baseline) -> LintReport {
    let mut groups: BTreeMap<(String, String), Vec<Diagnostic>> = BTreeMap::new();
    let mut new = Vec::new();
    for d in scan.diagnostics {
        if d.rule == rules::ALLOW_DIRECTIVE {
            new.push(d);
        } else {
            groups.entry((d.rule.to_string(), d.path.clone())).or_default().push(d);
        }
    }
    let present: Vec<(String, String)> = groups.keys().cloned().collect();
    let mut baselined = 0;
    let mut stale = Vec::new();
    for (key, diags) in groups {
        let frozen = baseline.get(&key).copied().unwrap_or(0);
        let actual = diags.len();
        if actual == frozen {
            baselined += actual;
        } else if actual < frozen {
            baselined += actual;
            stale.push(StaleEntry { rule: key.0, path: key.1, frozen, actual });
        } else {
            new.extend(diags);
        }
    }
    for (key, frozen) in baseline {
        if *frozen > 0 && !present.contains(key) {
            stale.push(StaleEntry {
                rule: key.0.clone(),
                path: key.1.clone(),
                frozen: *frozen,
                actual: 0,
            });
        }
    }
    new.sort();
    stale.sort_by(|a, b| (&a.rule, &a.path).cmp(&(&b.rule, &b.path)));
    LintReport { files_scanned: scan.files_scanned, new, baselined, stale }
}

/// Parse baseline text: `# comment` and blank lines skipped, data
/// lines are `<rule> <path> <count>`.
pub fn parse_baseline(text: &str) -> Result<Baseline, LintError> {
    let mut map = Baseline::new();
    for (idx, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut parts = line.split_whitespace();
        let (Some(rule), Some(path), Some(count), None) =
            (parts.next(), parts.next(), parts.next(), parts.next())
        else {
            return Err(LintError::Baseline(format!(
                "line {}: expected `<rule> <path> <count>`, got {line:?}",
                idx + 1
            )));
        };
        if !rules::is_known(rule) {
            return Err(LintError::Baseline(format!("line {}: unknown rule {rule:?}", idx + 1)));
        }
        let count: usize = count
            .parse()
            .map_err(|_| LintError::Baseline(format!("line {}: bad count {count:?}", idx + 1)))?;
        map.insert((rule.to_string(), path.to_string()), count);
    }
    Ok(map)
}

/// Load [`BASELINE_FILE`] from the crate root; a missing file is an
/// empty baseline.
pub fn load_baseline(root: &Path) -> Result<Baseline, LintError> {
    let path = root.join(BASELINE_FILE);
    match fs::read_to_string(&path) {
        Ok(text) => parse_baseline(&text),
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(Baseline::new()),
        Err(e) => Err(LintError::Io(format!("read {}: {e}", path.display()))),
    }
}

/// Render the baseline text for the given diagnostics (grouped and
/// counted; `allow-directive` findings are excluded — fix those, do
/// not freeze them).
pub fn format_baseline(diags: &[Diagnostic]) -> String {
    let mut counts: BTreeMap<(&str, &str), usize> = BTreeMap::new();
    for d in diags {
        if d.rule != rules::ALLOW_DIRECTIVE {
            *counts.entry((d.rule, d.path.as_str())).or_default() += 1;
        }
    }
    let mut out = String::from(
        "# poplar lint baseline — frozen panic-path debt, one `rule path count` per line.\n\
         # Regenerate with `cargo run --bin poplar -- lint --write-baseline` after burning\n\
         # entries down; tests/lint_gate.rs pins that this file only ever shrinks.\n",
    );
    for ((rule, path), count) in counts {
        out.push_str(&format!("{rule} {path} {count}\n"));
    }
    out
}

/// Regenerate [`BASELINE_FILE`] from a fresh scan's diagnostics.
/// Returns the number of entries written.
pub fn write_baseline(root: &Path, diags: &[Diagnostic]) -> Result<usize, LintError> {
    let text = format_baseline(diags);
    let entries = text.lines().filter(|l| !l.starts_with('#')).count();
    fs::write(root.join(BASELINE_FILE), &text)
        .map_err(|e| LintError::Io(format!("write {BASELINE_FILE}: {e}")))?;
    Ok(entries)
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> Result<(), LintError> {
    let entries = fs::read_dir(dir)
        .map_err(|e| LintError::Io(format!("read_dir {}: {e}", dir.display())))?;
    for entry in entries {
        let entry =
            entry.map_err(|e| LintError::Io(format!("read_dir {}: {e}", dir.display())))?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Root-relative path with `/` separators, so diagnostics and baseline
/// entries are portable across hosts.
fn rel_path(root: &Path, path: &Path) -> String {
    let rel = path.strip_prefix(root).unwrap_or(path);
    rel.components().map(|c| c.as_os_str().to_string_lossy()).collect::<Vec<_>>().join("/")
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn diag(rule: &'static str, path: &str, line: usize) -> Diagnostic {
        Diagnostic { path: path.to_string(), line, rule, message: String::from("m") }
    }

    fn scan_of(diags: Vec<Diagnostic>) -> Scan {
        Scan { files_scanned: 2, diagnostics: diags }
    }

    #[test]
    fn baseline_parse_and_format_roundtrip() {
        let d = vec![
            diag(rules::PANIC_PATH, "src/a.rs", 3),
            diag(rules::PANIC_PATH, "src/a.rs", 9),
            diag(rules::PANIC_PATH, "src/b.rs", 1),
        ];
        let text = format_baseline(&d);
        let map = parse_baseline(&text).expect("roundtrip parses");
        assert_eq!(map.get(&("panic-path".into(), "src/a.rs".into())), Some(&2));
        assert_eq!(map.get(&("panic-path".into(), "src/b.rs".into())), Some(&1));
        assert_eq!(map.len(), 2);
    }

    #[test]
    fn baseline_rejects_garbage() {
        assert!(parse_baseline("panic-path src/a.rs").is_err(), "missing count");
        assert!(parse_baseline("panic-path src/a.rs two").is_err(), "bad count");
        assert!(parse_baseline("panic-path src/a.rs 1 extra").is_err(), "trailing token");
        assert!(parse_baseline("bogus-rule src/a.rs 1").is_err(), "unknown rule");
        assert!(parse_baseline("# comment\n\npanic-path src/a.rs 1\n").is_ok());
    }

    #[test]
    fn apply_baseline_exact_match_is_clean() {
        let mut b = Baseline::new();
        b.insert(("panic-path".into(), "src/a.rs".into()), 2);
        let scan = scan_of(vec![
            diag(rules::PANIC_PATH, "src/a.rs", 3),
            diag(rules::PANIC_PATH, "src/a.rs", 9),
        ]);
        let r = apply_baseline(scan, &b);
        assert!(r.is_clean(), "{r:?}");
        assert_eq!(r.baselined, 2);
        assert_eq!(r.files_scanned, 2);
    }

    #[test]
    fn apply_baseline_flags_growth_as_new() {
        let mut b = Baseline::new();
        b.insert(("panic-path".into(), "src/a.rs".into()), 1);
        let scan = scan_of(vec![
            diag(rules::PANIC_PATH, "src/a.rs", 3),
            diag(rules::PANIC_PATH, "src/a.rs", 9),
        ]);
        let r = apply_baseline(scan, &b);
        assert!(!r.is_clean());
        assert_eq!(r.new.len(), 2, "the whole group resurfaces so the dev sees every site");
        assert_eq!(r.baselined, 0);
    }

    #[test]
    fn apply_baseline_flags_shrinkage_and_dead_entries_as_stale() {
        let mut b = Baseline::new();
        b.insert(("panic-path".into(), "src/a.rs".into()), 3);
        b.insert(("panic-path".into(), "src/gone.rs".into()), 2);
        let r = apply_baseline(scan_of(vec![diag(rules::PANIC_PATH, "src/a.rs", 3)]), &b);
        assert!(!r.is_clean(), "shrinkage forces a --write-baseline regen");
        assert_eq!(r.new.len(), 0);
        assert_eq!(r.stale.len(), 2);
        assert_eq!((r.stale[0].frozen, r.stale[0].actual), (3, 1));
        assert_eq!((r.stale[1].frozen, r.stale[1].actual), (2, 0));
    }

    #[test]
    fn allow_directive_findings_are_never_baselined() {
        let mut b = Baseline::new();
        b.insert(("allow-directive".into(), "src/a.rs".into()), 1);
        let r = apply_baseline(scan_of(vec![diag(rules::ALLOW_DIRECTIVE, "src/a.rs", 3)]), &b);
        assert_eq!(r.new.len(), 1, "stays a hard error");
        // and format_baseline refuses to freeze them
        let text = format_baseline(&[diag(rules::ALLOW_DIRECTIVE, "src/a.rs", 3)]);
        assert!(!text.contains("allow-directive"));
    }

    #[test]
    fn json_report_shape_and_escaping() {
        let mut d = diag(rules::PANIC_PATH, "src/a.rs", 3);
        d.message = String::from("quote \" backslash \\ tab \t");
        let r = LintReport {
            files_scanned: 1,
            new: vec![d],
            baselined: 0,
            stale: vec![StaleEntry {
                rule: "panic-path".into(),
                path: "src/b.rs".into(),
                frozen: 2,
                actual: 1,
            }],
        };
        let j = r.to_json();
        assert!(j.contains("\"clean\": false"));
        assert!(j.contains("\\\" backslash \\\\ tab \\t"));
        assert!(j.contains("\"frozen\": 2"));
        let clean = LintReport { files_scanned: 1, new: vec![], baselined: 0, stale: vec![] };
        assert!(clean.to_json().contains("\"new_violations\": []"));
    }

    #[test]
    fn diagnostic_display_matches_contract() {
        let mut d = diag(rules::PANIC_PATH, "src/a.rs", 3);
        d.message = String::from("boom");
        assert_eq!(d.to_string(), "src/a.rs:3: panic-path: boom");
    }
}
