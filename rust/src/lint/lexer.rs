//! Line-oriented Rust lexer for the invariant analyzer.
//!
//! Produces, per source line, the *code-only* text — `//` and nested
//! `/* */` comment bodies, string / raw-string / byte-string payloads
//! and char literals are blanked out — plus the comment text (where
//! `lint:allow` directives live, see `parse_allows`) and whether the
//! line sits inside a `#[cfg(test)]` item. Rules then run as plain
//! substring checks over the code text without ever seeing prose or
//! literal payloads, which is exactly what the old shell greps could
//! not do.
//!
//! This is *not* a full Rust lexer: it understands just enough of the
//! token grammar (escapes, raw-string hash fences, nested block
//! comments, lifetimes vs char literals) to make substring rules sound
//! on this crate. Known limitations are listed in `README.md`.

/// One analyzed line of a source file.
#[derive(Debug, Clone)]
pub struct LineInfo {
    /// Source text with comments and literal payloads blanked out.
    pub code: String,
    /// Concatenated comment text carried by the line.
    pub comment: String,
    /// True when the line lies inside a `#[cfg(test)]` item (or the
    /// whole file is test code, e.g. under `tests/`).
    pub in_test: bool,
}

/// A `lint:allow` directive found in a comment: the rule id sits in
/// parentheses and a non-empty ` -- reason` is mandatory.
#[derive(Debug, Clone, PartialEq)]
pub struct Allow {
    /// 1-based line the directive sits on.
    pub line: usize,
    /// Rule id named between the parentheses (empty when unclosed).
    pub rule: String,
    /// True when a non-empty reason follows `--`.
    pub has_reason: bool,
    /// True when the directive shares its line with code (and applies
    /// to that line); false for a standalone comment line, which
    /// applies to the next line.
    pub inline: bool,
}

/// A fully lexed source file.
#[derive(Debug)]
pub struct SourceFile {
    /// Crate-root-relative path with `/` separators.
    pub path: String,
    /// Per-line analysis; index 0 is line 1.
    pub lines: Vec<LineInfo>,
    /// Every allow directive in the file, in line order.
    pub allows: Vec<Allow>,
}

#[derive(Clone, Copy)]
enum State {
    Code,
    LineComment,
    BlockComment(usize),
    Str,
    RawStr(usize),
}

/// Lex `text` into masked lines, test spans and allow directives.
/// `all_test` marks every line as test code (files under `tests/` or
/// `benches/` compile only into test binaries).
pub fn lex(path: &str, text: &str, all_test: bool) -> SourceFile {
    let raw: Vec<char> = text.chars().collect();
    let mut code_lines: Vec<String> = Vec::new();
    let mut comment_lines: Vec<String> = Vec::new();
    let mut code = String::new();
    let mut comment = String::new();
    let mut state = State::Code;
    let mut i = 0;

    while i < raw.len() {
        let c = raw[i];
        if c == '\n' {
            // newlines are never consumed by a multi-char token below,
            // so line accounting stays exact across every state
            if matches!(state, State::LineComment) {
                state = State::Code;
            }
            code_lines.push(std::mem::take(&mut code));
            comment_lines.push(std::mem::take(&mut comment));
            i += 1;
            continue;
        }
        match state {
            State::Code => {
                let next = raw.get(i + 1).copied();
                let prev_ident = i
                    .checked_sub(1)
                    .and_then(|p| raw.get(p))
                    .is_some_and(|p| p.is_alphanumeric() || *p == '_');
                if (c == 'r' || c == 'b') && !prev_ident {
                    if let Some((skip, hashes)) = raw_string_open(&raw, i) {
                        for _ in 0..skip {
                            code.push(' ');
                        }
                        i += skip;
                        state = State::RawStr(hashes);
                        continue;
                    }
                }
                if c == '"' {
                    code.push('"');
                    state = State::Str;
                    i += 1;
                } else if c == '/' && next == Some('/') {
                    code.push_str("  ");
                    state = State::LineComment;
                    i += 2;
                } else if c == '/' && next == Some('*') {
                    code.push_str("  ");
                    state = State::BlockComment(1);
                    i += 2;
                } else if c == '\'' {
                    i = lex_quote(&raw, i, &mut code);
                } else {
                    code.push(c);
                    i += 1;
                }
            }
            State::LineComment => {
                comment.push(c);
                code.push(' ');
                i += 1;
            }
            State::BlockComment(depth) => {
                let next = raw.get(i + 1).copied();
                if c == '/' && next == Some('*') {
                    state = State::BlockComment(depth + 1);
                    code.push_str("  ");
                    i += 2;
                } else if c == '*' && next == Some('/') {
                    state = if depth == 1 {
                        State::Code
                    } else {
                        State::BlockComment(depth - 1)
                    };
                    code.push_str("  ");
                    i += 2;
                } else {
                    comment.push(c);
                    code.push(' ');
                    i += 1;
                }
            }
            State::Str => {
                if c == '\\' && raw.get(i + 1).is_some_and(|n| *n != '\n') {
                    code.push_str("  ");
                    i += 2;
                } else if c == '"' {
                    code.push('"');
                    state = State::Code;
                    i += 1;
                } else {
                    code.push(' ');
                    i += 1;
                }
            }
            State::RawStr(hashes) => {
                let fence_closed = c == '"'
                    && raw[i + 1..].iter().take(hashes).filter(|h| **h == '#').count() == hashes;
                if fence_closed {
                    code.push('"');
                    for _ in 0..hashes {
                        code.push(' ');
                    }
                    i += 1 + hashes;
                    state = State::Code;
                } else {
                    code.push(' ');
                    i += 1;
                }
            }
        }
    }
    if !code.is_empty() || !comment.is_empty() {
        code_lines.push(code);
        comment_lines.push(comment);
    }

    let mut lines = mark_test_spans(&code_lines, all_test);
    let mut allows = Vec::new();
    for (idx, ctext) in comment_lines.iter().enumerate() {
        let inline = lines.get(idx).is_some_and(|l| !l.code.trim().is_empty());
        parse_allows(ctext, idx + 1, inline, &mut allows);
        if let Some(l) = lines.get_mut(idx) {
            l.comment = ctext.clone();
        }
    }
    SourceFile { path: path.to_string(), lines, allows }
}

/// Handle a `'` in code position: char literal (payload masked) or
/// lifetime tick. Returns the index to resume at.
fn lex_quote(raw: &[char], i: usize, code: &mut String) -> usize {
    let next = raw.get(i + 1).copied();
    if next == Some('\\') {
        // escaped char literal like '\n' or '\u{41}': mask to the
        // closing quote, never crossing a newline
        code.push('\'');
        let mut j = i + 1;
        while j < raw.len() && raw[j] != '\'' && raw[j] != '\n' {
            code.push(' ');
            j += 1;
        }
        if j < raw.len() && raw[j] == '\'' {
            code.push('\'');
            j += 1;
        }
        return j;
    }
    if next.is_some() && next != Some('\'') && raw.get(i + 2).copied() == Some('\'') {
        // one-char literal like 'x' (including '{' and '}', which must
        // not disturb brace-depth tracking)
        code.push('\'');
        code.push(' ');
        code.push('\'');
        return i + 3;
    }
    // lifetime tick
    code.push('\'');
    i + 1
}

/// Detect a raw-string opener at `i`: `r"`, `r#"`, `br"`, … Returns
/// (chars consumed through the opening quote, hash-fence length).
fn raw_string_open(raw: &[char], i: usize) -> Option<(usize, usize)> {
    let mut j = i;
    if raw.get(j) == Some(&'b') {
        j += 1;
    }
    if raw.get(j) != Some(&'r') {
        return None;
    }
    j += 1;
    let mut hashes = 0;
    while raw.get(j + hashes) == Some(&'#') {
        hashes += 1;
    }
    if raw.get(j + hashes) == Some(&'"') {
        Some((j + hashes + 1 - i, hashes))
    } else {
        None
    }
}

/// Walk the masked lines tracking brace depth to mark `#[cfg(test)]`
/// item bodies. An attribute followed by `;` before any `{` (e.g. on a
/// `use` item) is cancelled.
fn mark_test_spans(code_lines: &[String], all_test: bool) -> Vec<LineInfo> {
    let mut lines = Vec::with_capacity(code_lines.len());
    let mut depth: usize = 0;
    let mut pending_attr: Option<usize> = None;
    let mut test_close: Option<usize> = None;
    for code in code_lines {
        let mut in_test = test_close.is_some();
        if test_close.is_none() && code.contains("#[cfg(test)]") {
            pending_attr = Some(depth);
        }
        for ch in code.chars() {
            match ch {
                '{' => {
                    if test_close.is_none() && pending_attr.is_some() {
                        test_close = Some(depth);
                        pending_attr = None;
                        in_test = true;
                    }
                    depth += 1;
                }
                '}' => {
                    depth = depth.saturating_sub(1);
                    if test_close == Some(depth) {
                        test_close = None;
                    }
                }
                ';' => {
                    if pending_attr == Some(depth) {
                        pending_attr = None;
                    }
                }
                _ => {}
            }
        }
        lines.push(LineInfo {
            code: code.clone(),
            comment: String::new(),
            in_test: in_test || all_test,
        });
    }
    lines
}

/// Scan one line's comment text for allow directives. The grammar is
/// `lint:allow` + `(` rule `)` + ` -- ` + reason; the reason must be
/// non-empty for the directive to suppress anything.
fn parse_allows(comment: &str, line: usize, inline: bool, out: &mut Vec<Allow>) {
    let marker = "lint:allow(";
    let mut rest = comment;
    while let Some(pos) = rest.find(marker) {
        let after = &rest[pos + marker.len()..];
        let Some(close) = after.find(')') else {
            out.push(Allow { line, rule: String::new(), has_reason: false, inline });
            return;
        };
        let rule = after[..close].trim().to_string();
        let tail = after[close + 1..].trim_start();
        let has_reason = tail.strip_prefix("--").is_some_and(|r| !r.trim().is_empty());
        out.push(Allow { line, rule, has_reason, inline });
        rest = &after[close + 1..];
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn codes(text: &str) -> Vec<String> {
        lex("src/x.rs", text, false).lines.into_iter().map(|l| l.code).collect()
    }

    #[test]
    fn line_comments_are_masked_and_captured() {
        let f = lex("src/x.rs", "let a = 1; // then .unwrap() it\n", false);
        assert!(!f.lines[0].code.contains("unwrap"));
        assert!(f.lines[0].code.contains("let a = 1;"));
        assert!(f.lines[0].comment.contains(".unwrap()"));
    }

    #[test]
    fn nested_block_comments_and_line_counts() {
        let c = codes("a /* x /* y */ z\nstill comment */ b\nc\n");
        assert_eq!(c.len(), 3);
        assert!(c[0].starts_with('a'));
        assert!(!c[0].contains('x'));
        assert!(!c[1].contains("still"));
        assert!(c[1].contains('b'));
        assert_eq!(c[2].trim(), "c");
    }

    #[test]
    fn string_payloads_masked_quotes_kept() {
        let c = codes("let s = \".unwrap()\";\nlet t = \"a\\\"b\";\n");
        assert!(!c[0].contains("unwrap"));
        assert!(c[0].contains('"'));
        assert!(c[1].ends_with(';'));
    }

    #[test]
    fn raw_strings_span_lines_without_confusing_state() {
        let c = codes("let s = r#\"panic!(\" x \")\"#;\nlet p = q.unwrap();\n");
        assert!(!c[0].contains("panic!"));
        // the `"` inside the raw string does not terminate it early
        assert!(c[1].contains(".unwrap()"));
    }

    #[test]
    fn char_literals_do_not_disturb_brace_depth() {
        let text = "fn f() { let c = '{'; }\n#[cfg(test)]\nmod t {\n    fn g() {}\n}\nfn h() {}\n";
        let f = lex("src/x.rs", text, false);
        assert!(!f.lines[0].in_test);
        assert!(f.lines[3].in_test, "inside the cfg(test) mod");
        assert!(!f.lines[5].in_test, "code after the test mod is live again");
    }

    #[test]
    fn lifetimes_are_not_char_literals() {
        let c = codes("fn f<'a>(x: &'a str) -> &'a str { x }\n");
        assert!(c[0].contains("str"), "code survives: {:?}", c[0]);
        assert!(c[0].contains('{') && c[0].contains('}'));
    }

    #[test]
    fn cfg_test_on_a_use_item_is_cancelled_by_semicolon() {
        let text = "#[cfg(test)]\nuse foo::bar;\nfn f() { x.unwrap(); }\n";
        let f = lex("src/x.rs", text, false);
        assert!(!f.lines[2].in_test, "the fn after the attributed use is live code");
    }

    #[test]
    fn all_test_marks_every_line() {
        let f = lex("tests/x.rs", "fn f() { x.unwrap(); }\n", true);
        assert!(f.lines[0].in_test);
    }

    #[test]
    fn allow_directive_parsing() {
        let f = lex(
            "src/x.rs",
            "// lint:allow(panic-path) -- proven invariant\nx.unwrap();\n",
            false,
        );
        assert_eq!(f.allows.len(), 1);
        let a = &f.allows[0];
        assert_eq!(a.rule, "panic-path");
        assert!(a.has_reason);
        assert!(!a.inline, "standalone comment line applies to the next line");
        assert_eq!(a.line, 1);
    }

    #[test]
    fn inline_allow_and_missing_reason() {
        let f = lex("src/x.rs", "x.unwrap(); // lint:allow(panic-path)\n", false);
        assert_eq!(f.allows.len(), 1);
        assert!(f.allows[0].inline);
        assert!(!f.allows[0].has_reason);
        let f = lex("src/x.rs", "x.unwrap(); // lint:allow(panic-path) --   \n", false);
        assert!(!f.allows[0].has_reason, "whitespace-only reason rejected");
    }

    #[test]
    fn directives_inside_string_literals_are_ignored() {
        let f = lex("src/x.rs", "let s = \"lint:allow(panic-path) -- no\";\n", false);
        assert!(f.allows.is_empty());
    }
}
