//! Rule definitions for the invariant analyzer.
//!
//! Every rule is a substring check over [`lexer::SourceFile`] code
//! lines — the lexer has already removed comments, literal payloads
//! and (for `panic-path`) `#[cfg(test)]` spans, so a match here is a
//! real token in live code, not prose. Path-based confinement (which
//! module *owns* a pattern) is part of each rule.

use super::lexer::SourceFile;
use super::Diagnostic;

/// `unwrap()` / `expect(` / `panic!` / `unimplemented!` / `todo!` in
/// non-test library code. Ratcheted by `lint-baseline.txt`.
pub const PANIC_PATH: &str = "panic-path";

/// `partial_cmp` anywhere — NaN must not panic or destabilize an
/// ordering; use `total_cmp`.
pub const FLOAT_ORDERING: &str = "float-ordering";

/// Raw `NetSim { .. }` struct literal outside `src/netsim/` — snapshots
/// derive from the `BwMonitor` or a `NetSim` constructor.
pub const NETSIM_LITERAL: &str = "netsim-literal";

/// The amortized-score formula shape outside `src/policy/` — adapters
/// call `policy::amortized_score` instead of re-deriving it.
pub const AMORTIZED_FORMULA: &str = "amortized-formula";

/// The pipeline bubble/efficiency formula shape outside `src/pipeline/`
/// — the `(m + g - 1)/m` term is owned by `pipeline::bubble_efficiency`;
/// consumers call it (or price through a composed `PerfCurve`) instead
/// of re-deriving the bubble.
pub const BUBBLE_FORMULA: &str = "bubble-formula";

/// Wall-clock reads outside `metrics`/`profiler`/benches, and
/// iteration-order-unstable maps in `src/exp/` (golden tables).
pub const DETERMINISM: &str = "determinism";

/// Malformed `lint:allow` directives (unknown rule, missing reason).
/// Not suppressible.
pub const ALLOW_DIRECTIVE: &str = "allow-directive";

/// Every rule id the analyzer knows, in reporting order.
pub const ALL: &[&str] = &[
    PANIC_PATH,
    FLOAT_ORDERING,
    NETSIM_LITERAL,
    AMORTIZED_FORMULA,
    BUBBLE_FORMULA,
    DETERMINISM,
    ALLOW_DIRECTIVE,
];

/// True when `rule` is a known id (allow directives must name one).
pub fn is_known(rule: &str) -> bool {
    ALL.contains(&rule)
}

/// First banned panic token on a code line, if any. `unwrap_or*` and
/// `expect_err` deliberately do not match.
fn panic_token(code: &str) -> Option<&'static str> {
    const TOKENS: &[&str] = &[".unwrap()", ".expect(", "panic!", "unimplemented!", "todo!"];
    TOKENS.iter().copied().find(|t| code.contains(t))
}

/// `NetSim` followed (modulo spaces) by `{`, with an identifier
/// boundary on the left. Lines carrying `fn ` or `->` are signature
/// positions (`-> NetSim {`), not literals.
fn netsim_literal(code: &str) -> bool {
    if code.contains("fn ") || code.contains("->") {
        return false;
    }
    let bytes = code.as_bytes();
    let mut start = 0;
    while let Some(pos) = code[start..].find("NetSim") {
        let at = start + pos;
        let boundary = at == 0 || {
            let b = bytes[at - 1];
            !(b.is_ascii_alphanumeric() || b == b'_')
        };
        let mut j = at + "NetSim".len();
        while j < bytes.len() && (bytes[j] == b' ' || bytes[j] == b'\t') {
            j += 1;
        }
        if boundary && j < bytes.len() && bytes[j] == b'{' {
            return true;
        }
        start = at + "NetSim".len();
    }
    false
}

/// Run every rule over one lexed file. Allow directives are applied by
/// the caller (`lint::check_with_allows`), not here.
pub fn check_file(f: &SourceFile) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    let in_src = f.path.starts_with("src/");
    let in_exp = f.path.starts_with("src/exp/");
    let netsim_owner = f.path.starts_with("src/netsim/");
    let policy_owner = f.path.starts_with("src/policy/");
    let pipeline_owner = f.path.starts_with("src/pipeline/");
    let time_owner = f.path.starts_with("src/metrics/")
        || f.path.starts_with("src/profiler/")
        || f.path.starts_with("benches/");
    let push = |line: usize, rule: &'static str, message: String, out: &mut Vec<Diagnostic>| {
        out.push(Diagnostic { path: f.path.clone(), line, rule, message });
    };

    for (idx, l) in f.lines.iter().enumerate() {
        let line = idx + 1;
        let code = l.code.as_str();

        if in_src && !l.in_test {
            if let Some(tok) = panic_token(code) {
                push(
                    line,
                    PANIC_PATH,
                    format!("`{tok}` in non-test library code — return a typed error instead"),
                    &mut out,
                );
            }
        }
        if code.contains("partial_cmp") {
            push(
                line,
                FLOAT_ORDERING,
                "`partial_cmp` is banned — use `total_cmp` so NaN cannot panic or \
                 destabilize an ordering"
                    .to_string(),
                &mut out,
            );
        }
        if !netsim_owner && netsim_literal(code) {
            push(
                line,
                NETSIM_LITERAL,
                "raw `NetSim { .. }` literal outside src/netsim/ — derive snapshots from \
                 the BwMonitor or a NetSim constructor"
                    .to_string(),
                &mut out,
            );
        }
        if !policy_owner
            && code.contains("horizon")
            && (code.contains(".max(0.0)") || code.contains("max(0,"))
        {
            push(
                line,
                AMORTIZED_FORMULA,
                "amortized-score formula shape outside src/policy/ — call \
                 policy::amortized_score"
                    .to_string(),
                &mut out,
            );
        }
        if !pipeline_owner
            && (code.contains("+ g - 1")
                || code.contains("+ group_size - 1")
                || (code.contains("bubble") && (code.contains("/ (") || code.contains("* ("))))
        {
            push(
                line,
                BUBBLE_FORMULA,
                "pipeline bubble/efficiency formula shape outside src/pipeline/ — call \
                 pipeline::bubble_efficiency"
                    .to_string(),
                &mut out,
            );
        }
        if !time_owner && (code.contains("SystemTime::now") || code.contains("Instant::now")) {
            push(
                line,
                DETERMINISM,
                "wall-clock read outside metrics/profiler/benches — replans and golden \
                 tables must be reproducible"
                    .to_string(),
                &mut out,
            );
        }
        if in_exp && (code.contains("HashMap") || code.contains("HashSet")) {
            push(
                line,
                DETERMINISM,
                "hash map in src/exp/ — iteration order feeds golden tables; use \
                 BTreeMap/BTreeSet"
                    .to_string(),
                &mut out,
            );
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::super::check_source;
    use super::*;

    fn rules_of(path: &str, text: &str) -> Vec<&'static str> {
        check_source(path, text).into_iter().map(|d| d.rule).collect()
    }

    // -- panic-path ------------------------------------------------------

    #[test]
    fn panic_path_fires_on_each_token() {
        for snippet in [
            "fn f() { x.unwrap(); }",
            "fn f() { x.expect(\"msg\"); }",
            "fn f() { panic!(\"boom\"); }",
            "fn f() { unimplemented!(); }",
            "fn f() { todo!(); }",
        ] {
            assert_eq!(rules_of("src/a.rs", snippet), vec![PANIC_PATH], "{snippet}");
        }
    }

    #[test]
    fn panic_path_ignores_prose_strings_tests_and_fallbacks() {
        // comment
        assert!(rules_of("src/a.rs", "// fix the .unwrap() later\nfn f() {}\n").is_empty());
        // string literal
        assert!(rules_of("src/a.rs", "fn f() { let s = \".unwrap()\"; }\n").is_empty());
        // cfg(test) module
        let t = "#[cfg(test)]\nmod tests {\n    fn f() { x.unwrap(); }\n}\n";
        assert!(rules_of("src/a.rs", t).is_empty());
        // tests/ and benches/ roots are all-test
        assert!(rules_of("tests/a.rs", "fn f() { x.unwrap(); }").is_empty());
        assert!(rules_of("benches/a.rs", "fn f() { x.unwrap(); }").is_empty());
        // non-panicking cousins
        assert!(rules_of("src/a.rs", "fn f() { x.unwrap_or(0); }").is_empty());
        assert!(rules_of("src/a.rs", "fn f() { x.unwrap_or_else(|| 0); }").is_empty());
        assert!(rules_of("src/a.rs", "fn f() { x.expect_err; }").is_empty());
    }

    #[test]
    fn panic_path_resumes_after_test_mod() {
        let t = "#[cfg(test)]\nmod tests {\n    fn f() { x.unwrap(); }\n}\n\
                 fn live() { y.unwrap(); }\n";
        let d = check_source("src/a.rs", t);
        assert_eq!(d.len(), 1);
        assert_eq!(d[0].line, 5, "only the post-mod line fires: {d:?}");
    }

    #[test]
    fn reasoned_allow_suppresses_reasonless_is_rejected() {
        // standalone reasoned allow covers the next line
        let ok = "fn f() {\n    // lint:allow(panic-path) -- len checked above\n    x.unwrap();\n}";
        assert!(rules_of("src/a.rs", ok).is_empty());
        // inline reasoned allow covers its own line
        let inline = "fn f() { x.unwrap() } // lint:allow(panic-path) -- proven non-empty";
        assert!(rules_of("src/a.rs", inline).is_empty());
        // a reason-less allow suppresses nothing and is itself flagged
        let bad = "fn f() { x.unwrap(); } // lint:allow(panic-path)";
        let got = rules_of("src/a.rs", bad);
        assert!(got.contains(&PANIC_PATH), "{got:?}");
        assert!(got.contains(&ALLOW_DIRECTIVE), "{got:?}");
        // unknown rule ids are flagged too
        let unk = "fn f() {} // lint:allow(bogus-rule) -- whatever";
        assert_eq!(rules_of("src/a.rs", unk), vec![ALLOW_DIRECTIVE]);
    }

    // -- float-ordering --------------------------------------------------

    #[test]
    fn float_ordering_bans_partial_compare_everywhere() {
        let t = "fn f() { xs.sort_by(|a, b| a.partial_cmp(b).unwrap()); }";
        let got = rules_of("src/a.rs", t);
        assert!(got.contains(&FLOAT_ORDERING), "{got:?}");
        // also inside test code and test roots
        let t = "#[cfg(test)]\nmod tests {\n    fn f() { a.partial_cmp(&b); }\n}\n";
        assert_eq!(rules_of("src/a.rs", t), vec![FLOAT_ORDERING]);
        assert_eq!(rules_of("tests/a.rs", "fn f() { a.partial_cmp(&b); }"), vec![FLOAT_ORDERING]);
        // prose does not fire
        assert!(rules_of("src/a.rs", "// partial_cmp was removed in PR 4\n").is_empty());
        // total_cmp does not fire
        assert!(rules_of("src/a.rs", "fn f() { a.total_cmp(&b); }").is_empty());
    }

    // -- netsim-literal --------------------------------------------------

    #[test]
    fn netsim_literal_confined_to_owner() {
        let lit = "fn f() { let n = NetSim { links: vec![] }; }";
        // `fn ` on the same line is a signature filter, so split lines
        let lit2 = "let n = NetSim {\n    links: vec![],\n};\n";
        assert_eq!(rules_of("src/zero/mod.rs", lit2), vec![NETSIM_LITERAL]);
        assert!(rules_of("src/netsim/mod.rs", lit2).is_empty(), "owner module is exempt");
        assert!(rules_of("src/zero/mod.rs", lit).is_empty(), "fn-signature lines skipped");
        // constructor calls and return types do not fire
        assert!(rules_of("src/a.rs", "let n = NetSim::from_link(4, kind);\n").is_empty());
        assert!(rules_of("src/a.rs", ") -> NetSim {\n").is_empty());
        // identifier boundary: MyNetSim is a different type
        assert!(rules_of("src/a.rs", "let n = MyNetSim { x: 1 };\n").is_empty());
        // comments and strings do not fire
        assert!(rules_of("src/a.rs", "// a raw NetSim { .. } would freeze bw\n").is_empty());
        assert!(rules_of("src/a.rs", "let s = \"NetSim { }\";\n").is_empty());
    }

    // -- amortized-formula -----------------------------------------------

    #[test]
    fn amortized_formula_confined_to_policy() {
        let t = "let score = rate * (horizon_s - stall).max(0.0) / horizon_s;\n";
        assert_eq!(rules_of("src/autoscale/mod.rs", t), vec![AMORTIZED_FORMULA]);
        assert!(rules_of("src/policy/mod.rs", t).is_empty(), "owner module is exempt");
        let int_form = "let s = r * max(0, horizon - stall) / horizon;\n";
        assert_eq!(rules_of("src/elastic/mod.rs", int_form), vec![AMORTIZED_FORMULA]);
        // unrelated max over a horizon-free expression is fine
        assert!(rules_of("src/a.rs", "let x = (a - b).max(0.0);\n").is_empty());
        // a clamped horizon without the formula shape is fine
        assert!(rules_of("src/a.rs", "let h = horizon.max(0.1);\n").is_empty());
        // prose does not fire
        assert!(rules_of("src/a.rs", "// max(0, horizon - stall) lives in policy\n").is_empty());
    }

    // -- bubble-formula --------------------------------------------------

    #[test]
    fn bubble_formula_confined_to_pipeline() {
        // the raw bubble step count
        let steps = "let steps = (m + g - 1) as f64;\n";
        assert_eq!(rules_of("src/policy/mod.rs", steps), vec![BUBBLE_FORMULA]);
        assert_eq!(rules_of("src/allocator/mod.rs", steps), vec![BUBBLE_FORMULA]);
        assert!(rules_of("src/pipeline/mod.rs", steps).is_empty(), "owner module is exempt");
        // the spelled-out variant
        let spelled = "let steps = batches + group_size - 1;\n";
        assert_eq!(rules_of("src/elastic/mod.rs", spelled), vec![BUBBLE_FORMULA]);
        // a re-derived efficiency ratio around a bubble-named quantity
        let ratio = "let bubble_eff = m as f64 / (m + k) as f64;\n";
        assert_eq!(rules_of("src/exp/fig9.rs", ratio), vec![BUBBLE_FORMULA]);
        assert!(rules_of("src/pipeline/mod.rs", ratio).is_empty());
        // CALLING the owner is exactly what consumers should do
        let call = "let eff = pipeline::bubble_efficiency(m, g);\n";
        assert!(rules_of("src/exp/fig9.rs", call).is_empty(), "calls are fine");
        // an unrelated subtraction and prose do not fire
        assert!(rules_of("src/a.rs", "let last = n + group - 1;\n").is_empty());
        assert!(rules_of("src/a.rs", "// pays the m + g - 1 bubble\n").is_empty());
        assert!(rules_of("src/a.rs", "let s = \"(m + g - 1)/m bubble\";\n").is_empty());
    }

    // -- determinism -----------------------------------------------------

    #[test]
    fn determinism_time_and_hash_rules() {
        let clock = "fn f() { let t = std::time::Instant::now(); }";
        assert_eq!(rules_of("src/zero/mod.rs", clock), vec![DETERMINISM]);
        assert_eq!(rules_of("tests/a.rs", clock), vec![DETERMINISM]);
        assert!(rules_of("src/metrics/bench.rs", clock).is_empty(), "metrics owns timers");
        assert!(rules_of("src/profiler/mod.rs", clock).is_empty(), "profiler owns timers");
        assert!(rules_of("benches/a.rs", clock).is_empty(), "benches measure time");
        let sys = "fn f() { let t = SystemTime::now(); }";
        assert_eq!(rules_of("src/zero/mod.rs", sys), vec![DETERMINISM]);

        let hash = "use std::collections::HashMap;\n";
        assert_eq!(rules_of("src/exp/fig9.rs", hash), vec![DETERMINISM]);
        assert!(rules_of("src/zero/mod.rs", hash).is_empty(), "only exp feeds golden tables");
        assert!(rules_of("src/exp/fig9.rs", "use std::collections::BTreeMap;\n").is_empty());
        assert_eq!(
            rules_of("src/exp/fig9.rs", "let s: HashSet<u32> = HashSet::new();\n"),
            vec![DETERMINISM]
        );
    }
}
