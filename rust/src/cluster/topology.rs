//! Cluster topology: GPU instances grouped into nodes joined by links.
//!
//! Mirrors the paper's Table 1: each cluster is a set of (GPU type ×
//! count) groups; GPUs inside a node share an intra-node link (NVLink or
//! PCIe), nodes are joined by an inter-node link (IB or Socket). The
//! slowest link on a collective's path bottlenecks the whole ring
//! (paper appendix, "Analysis of Experiments").



use super::catalog;
use super::gpu::GpuSpec;

/// Interconnect type with its effective bandwidth and per-message latency.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LinkKind {
    /// NVLink 3 (intra-node), ~300 GB/s effective per direction.
    Nvlink,
    /// A800's export-capped NVLink, ~200 GB/s.
    NvlinkCapped,
    /// PCIe 4.0 x16, ~20 GB/s effective.
    Pcie,
    /// InfiniBand HDR, ~20 GB/s effective.
    Ib,
    /// TCP sockets over 10-25 GbE, ~2 GB/s effective.
    Socket,
}

impl LinkKind {
    /// Effective unidirectional bandwidth in GB/s.
    pub fn bandwidth_gbs(self) -> f64 {
        match self {
            LinkKind::Nvlink => 300.0,
            LinkKind::NvlinkCapped => 200.0,
            LinkKind::Pcie => 20.0,
            LinkKind::Ib => 20.0,
            LinkKind::Socket => 2.0,
        }
    }

    /// Per-hop message latency (the α in the α-β model), seconds.
    pub fn latency_s(self) -> f64 {
        match self {
            LinkKind::Nvlink | LinkKind::NvlinkCapped => 3e-6,
            LinkKind::Pcie => 8e-6,
            LinkKind::Ib => 5e-6,
            LinkKind::Socket => 5e-5,
        }
    }

    /// Stable lowercase name, the wire/config spelling (`parse` inverts it).
    pub fn name(self) -> &'static str {
        match self {
            LinkKind::Nvlink => "nvlink",
            LinkKind::NvlinkCapped => "nvlink-capped",
            LinkKind::Pcie => "pcie",
            LinkKind::Ib => "ib",
            LinkKind::Socket => "socket",
        }
    }

    /// Parse the config/CLI spelling produced by [`LinkKind::name`].
    pub fn parse(s: &str) -> Option<LinkKind> {
        match s {
            "nvlink" => Some(LinkKind::Nvlink),
            "nvlink-capped" => Some(LinkKind::NvlinkCapped),
            "pcie" => Some(LinkKind::Pcie),
            "ib" => Some(LinkKind::Ib),
            "socket" => Some(LinkKind::Socket),
            _ => None,
        }
    }
}

/// A homogeneous group of GPUs forming one node of the cluster.
#[derive(Debug, Clone)]
pub struct NodeGroup {
    /// Catalog name of the GPU type, e.g. `"A100-80G"`.
    pub gpu: String,
    /// Number of GPUs of this type.
    pub count: usize,
    /// Intra-node interconnect.
    pub intra_link: LinkKind,
}

/// A heterogeneous GPU cluster (the paper's Table 1 rows).
#[derive(Debug, Clone)]
pub struct ClusterSpec {
    /// Human-readable name, e.g. `"cluster-A"`.
    pub name: String,
    /// Node groups in rank order.
    pub groups: Vec<NodeGroup>,
    /// Interconnect between node groups.
    pub inter_link: LinkKind,
}

/// One concrete GPU instance with its global rank.
#[derive(Debug, Clone)]
pub struct GpuInstance {
    /// Global rank in the data-parallel group.
    pub rank: usize,
    /// Device specification from the catalog.
    pub spec: GpuSpec,
    /// Which node group this instance belongs to.
    pub group: usize,
}

impl ClusterSpec {
    /// Build a cluster from `(gpu_name, count, intra_link)` triples.
    pub fn new(
        name: &str,
        groups: &[(&str, usize, LinkKind)],
        inter_link: LinkKind,
    ) -> Self {
        ClusterSpec {
            name: name.into(),
            groups: groups
                .iter()
                .map(|(g, c, l)| NodeGroup { gpu: (*g).into(), count: *c, intra_link: *l })
                .collect(),
            inter_link,
        }
    }

    /// Total GPU count.
    pub fn n_gpus(&self) -> usize {
        self.groups.iter().map(|g| g.count).sum()
    }

    /// Instantiate the GPU list in rank order.
    pub fn instances(&self) -> Vec<GpuInstance> {
        let mut out = Vec::with_capacity(self.n_gpus());
        let mut rank = 0;
        for (gi, g) in self.groups.iter().enumerate() {
            let spec = catalog::spec_or_panic(&g.gpu);
            for _ in 0..g.count {
                out.push(GpuInstance { rank, spec: spec.clone(), group: gi });
                rank += 1;
            }
        }
        out
    }

    /// The slowest link any ring collective over all ranks must cross:
    /// the inter-node link if there are >= 2 non-empty groups, else the
    /// single group's intra-node link.
    pub fn bottleneck_link(&self) -> LinkKind {
        let non_empty = self.groups.iter().filter(|g| g.count > 0).count();
        if non_empty >= 2 {
            self.inter_link
        } else {
            self.groups
                .iter()
                .find(|g| g.count > 0)
                .map(|g| g.intra_link)
                .unwrap_or(self.inter_link)
        }
    }

    /// Validate the spec against the catalog.
    pub fn validate(&self) -> Result<(), String> {
        if self.n_gpus() == 0 {
            return Err(format!("cluster {:?} has no GPUs", self.name));
        }
        for g in &self.groups {
            if catalog::spec(&g.gpu).is_none() {
                return Err(format!("unknown GPU type {:?} in cluster {:?}", g.gpu, self.name));
            }
        }
        Ok(())
    }
}

/// The paper's Table 1 cluster A: 4x A100-80G (NVLink) + 4x A100-40G (PCIe).
pub fn cluster_a() -> ClusterSpec {
    ClusterSpec::new(
        "cluster-A",
        &[("A100-80G", 4, LinkKind::Nvlink), ("A100-40G", 4, LinkKind::Pcie)],
        LinkKind::Ib,
    )
}

/// The paper's Table 1 cluster B: 2x V100-16G + 2x T4, PCIe.
pub fn cluster_b() -> ClusterSpec {
    ClusterSpec::new(
        "cluster-B",
        &[("V100-16G", 2, LinkKind::Pcie), ("T4", 2, LinkKind::Pcie)],
        LinkKind::Ib,
    )
}

/// The paper's Table 1 cluster C: 4x A800-80G + 4x V100S-32G, PCIe.
pub fn cluster_c() -> ClusterSpec {
    ClusterSpec::new(
        "cluster-C",
        &[("A800-80G", 4, LinkKind::Pcie), ("V100S-32G", 4, LinkKind::Pcie)],
        LinkKind::Ib,
    )
}

/// Cluster C with arbitrary counts — the Fig. 5 quantity sweep
/// (`a800 : v100s` of 4:1 … 1:4 plus homogeneous ends).
pub fn cluster_c_counts(n_a800: usize, n_v100s: usize) -> ClusterSpec {
    ClusterSpec::new(
        "cluster-C-var",
        &[
            ("A800-80G", n_a800, LinkKind::Pcie),
            ("V100S-32G", n_v100s, LinkKind::Pcie),
        ],
        LinkKind::Ib,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_clusters_validate() {
        for c in [cluster_a(), cluster_b(), cluster_c()] {
            c.validate().unwrap();
            assert!(c.n_gpus() >= 4);
        }
    }

    #[test]
    fn instances_rank_order_and_grouping() {
        let c = cluster_a();
        let inst = c.instances();
        assert_eq!(inst.len(), 8);
        for (i, g) in inst.iter().enumerate() {
            assert_eq!(g.rank, i);
        }
        assert_eq!(inst[0].spec.name, "A100-80G");
        assert_eq!(inst[4].spec.name, "A100-40G");
        assert_eq!(inst[3].group, 0);
        assert_eq!(inst[4].group, 1);
    }

    #[test]
    fn bottleneck_is_inter_link_for_multi_group() {
        assert_eq!(cluster_a().bottleneck_link(), LinkKind::Ib);
    }

    #[test]
    fn bottleneck_is_intra_for_single_group() {
        let c = cluster_c_counts(4, 0);
        assert_eq!(c.bottleneck_link(), LinkKind::Pcie);
        let c = ClusterSpec::new("x", &[("A100-80G", 4, LinkKind::Nvlink)], LinkKind::Ib);
        assert_eq!(c.bottleneck_link(), LinkKind::Nvlink);
    }

    #[test]
    fn empty_cluster_rejected() {
        let c = cluster_c_counts(0, 0);
        assert!(c.validate().is_err());
    }

    #[test]
    fn unknown_gpu_rejected() {
        let c = ClusterSpec::new("x", &[("H100", 2, LinkKind::Pcie)], LinkKind::Ib);
        assert!(c.validate().is_err());
    }

    #[test]
    fn link_names_roundtrip() {
        for l in [
            LinkKind::Nvlink,
            LinkKind::NvlinkCapped,
            LinkKind::Pcie,
            LinkKind::Ib,
            LinkKind::Socket,
        ] {
            assert_eq!(LinkKind::parse(l.name()), Some(l));
        }
        assert_eq!(LinkKind::parse("ethernet"), None);
        assert_eq!(LinkKind::parse(""), None);
    }

    #[test]
    fn link_speeds_ordered() {
        assert!(LinkKind::Nvlink.bandwidth_gbs() > LinkKind::NvlinkCapped.bandwidth_gbs());
        assert!(LinkKind::NvlinkCapped.bandwidth_gbs() > LinkKind::Pcie.bandwidth_gbs());
        assert!(LinkKind::Pcie.bandwidth_gbs() > LinkKind::Socket.bandwidth_gbs());
    }
}
