//! GPU catalog: the six paper GPUs plus the two consumer cards of Fig. 6.
//!
//! Peak TFLOP/s (dense fp16 tensor), memory and bandwidth come from public
//! spec sheets; `eff_max`, `sat_tokens` and the non-matmul coefficients
//! are calibrated so that (a) relative *wall-time* speeds match the
//! paper's observations (e.g. V100 vs T4 gap larger than FLOPs suggests,
//! A100-40G == A100-80G compute) and (b) mbs gaps match the memory ratios
//! of Table 1 clusters.

use super::gpu::GpuSpec;

/// All known GPU types.
pub const NAMES: &[&str] = &[
    "A100-80G", "A100-40G", "A800-80G", "V100-16G", "V100S-32G", "T4",
    "RTX4090", "RTX3060",
];

/// Look up a catalog entry by name. Returns `None` for unknown names.
pub fn spec(name: &str) -> Option<GpuSpec> {
    let s = match name {
        // Ampere data-center. A100 80G and 40G have identical compute —
        // the cluster-A scenario (same FLOPs, different memory).
        "A100-80G" => GpuSpec {
            name: "A100-80G".into(),
            mem_gib: 80.0,
            peak_tflops: 312.0,
            mem_bw_gbs: 2039.0,
            eff_max: 0.55,
            sat_tokens: 6000.0,
            launch_overhead_s: 9e-4,
            nonmatmul_bytes_per_token_layer: 9000.0,
        },
        "A100-40G" => GpuSpec {
            name: "A100-40G".into(),
            mem_gib: 40.0,
            peak_tflops: 312.0,
            mem_bw_gbs: 1555.0,
            eff_max: 0.55,
            sat_tokens: 6000.0,
            launch_overhead_s: 9e-4,
            nonmatmul_bytes_per_token_layer: 9000.0,
        },
        // A800: export-variant A100 (same compute, capped NVLink).
        "A800-80G" => GpuSpec {
            name: "A800-80G".into(),
            mem_gib: 80.0,
            peak_tflops: 312.0,
            mem_bw_gbs: 2039.0,
            eff_max: 0.55,
            sat_tokens: 6000.0,
            launch_overhead_s: 9e-4,
            nonmatmul_bytes_per_token_layer: 9000.0,
        },
        // Volta: lower peak, lower efficiency ceiling, slower non-matmul.
        "V100-16G" => GpuSpec {
            name: "V100-16G".into(),
            mem_gib: 16.0,
            peak_tflops: 125.0,
            mem_bw_gbs: 900.0,
            eff_max: 0.50,
            sat_tokens: 4500.0,
            launch_overhead_s: 1.1e-3,
            nonmatmul_bytes_per_token_layer: 11000.0,
        },
        "V100S-32G" => GpuSpec {
            name: "V100S-32G".into(),
            mem_gib: 32.0,
            peak_tflops: 130.0,
            mem_bw_gbs: 1134.0,
            eff_max: 0.50,
            sat_tokens: 4500.0,
            launch_overhead_s: 1.1e-3,
            nonmatmul_bytes_per_token_layer: 11000.0,
        },
        // Turing inference card: the cluster-B weak partner. Thermally
        // limited — low eff_max — and bandwidth-starved, so its wall-time
        // gap vs V100 is larger than the FLOPs ratio (Fig. 8).
        "T4" => GpuSpec {
            name: "T4".into(),
            mem_gib: 16.0,
            peak_tflops: 65.0,
            mem_bw_gbs: 300.0,
            eff_max: 0.35,
            sat_tokens: 3500.0,
            launch_overhead_s: 1.3e-3,
            nonmatmul_bytes_per_token_layer: 13000.0,
        },
        // Consumer cards (appendix Fig. 6 sweeps only).
        "RTX4090" => GpuSpec {
            name: "RTX4090".into(),
            mem_gib: 24.0,
            peak_tflops: 165.0,
            mem_bw_gbs: 1008.0,
            eff_max: 0.60,
            sat_tokens: 5000.0,
            launch_overhead_s: 8e-4,
            nonmatmul_bytes_per_token_layer: 9500.0,
        },
        "RTX3060" => GpuSpec {
            name: "RTX3060".into(),
            mem_gib: 12.0,
            peak_tflops: 51.0,
            mem_bw_gbs: 360.0,
            eff_max: 0.45,
            sat_tokens: 3500.0,
            launch_overhead_s: 1.2e-3,
            nonmatmul_bytes_per_token_layer: 12000.0,
        },
        _ => return None,
    };
    Some(s)
}

/// Look up a catalog entry by interned handle — the zero-conversion
/// twin of [`spec`] for the planner hot paths, where GPU types flow as
/// [`crate::intern::TypeId`]s rather than display strings.
pub fn spec_of(t: crate::intern::TypeId) -> Option<GpuSpec> {
    spec(t.as_str())
}

/// Like [`spec`] but panics with a helpful message (config validation
/// should have caught unknown names earlier).
pub fn spec_or_panic(name: &str) -> GpuSpec {
    spec(name).unwrap_or_else(|| {
        // lint:allow(panic-path) -- documented contract; Result-returning callers use spec()
        panic!("unknown GPU type {name:?}; known: {NAMES:?}")
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_names_resolve() {
        for n in NAMES {
            let s = spec(n).expect(n);
            assert_eq!(&s.name, n);
            assert!(s.peak_tflops > 0.0 && s.mem_gib > 0.0 && s.mem_bw_gbs > 0.0);
            assert!(s.eff_max > 0.0 && s.eff_max < 1.0);
        }
    }

    #[test]
    fn unknown_name_is_none() {
        assert!(spec("H100").is_none());
    }

    #[test]
    fn a100_variants_have_equal_compute_different_memory() {
        let a80 = spec("A100-80G").unwrap();
        let a40 = spec("A100-40G").unwrap();
        assert_eq!(a80.peak_tflops, a40.peak_tflops);
        assert_eq!(a80.eff_max, a40.eff_max);
        assert!(a80.mem_gib > a40.mem_gib);
    }

    #[test]
    fn catalog_ordering_sanity() {
        // wall-time speed ordering at a realistic load must be
        // A100 > V100S > V100 > T4
        let tokens = 4096.0;
        let fpt = 3e9;
        let t = |n: &str| spec(n).unwrap().compute_time(tokens, fpt, 24);
        assert!(t("A100-80G") < t("V100S-32G"));
        assert!(t("V100S-32G") < t("V100-16G"));
        assert!(t("V100-16G") < t("T4"));
    }
}
