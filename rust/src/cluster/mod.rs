//! Heterogeneous GPU cluster substrate.
//!
//! The paper's testbed (Table 1) is replaced by a calibrated device model
//! (see DESIGN.md §2). Three pieces:
//!
//! * [`gpu`] — per-type performance model (saturating throughput,
//!   non-matmul bandwidth term, deterministic noise);
//! * [`catalog`] — the six paper GPUs + two consumer cards, calibrated;
//! * [`topology`] — cluster specs: node groups, links, paper presets A/B/C.

pub mod catalog;
pub mod gpu;
pub mod topology;

pub use catalog::{spec, spec_or_panic, NAMES};
pub use gpu::{GpuSpec, NoiseModel};
pub use topology::{
    cluster_a, cluster_b, cluster_c, cluster_c_counts, ClusterSpec, GpuInstance, LinkKind,
    NodeGroup,
};
