//! Calibrated GPU device performance model.
//!
//! Substitution for the paper's physical GPUs (DESIGN.md §2): Poplar's
//! algorithms consume only (a) wall time as a function of micro-batch
//! size and (b) OOM boundaries. This model generates both, including the
//! two effects the paper leans on:
//!
//! * **saturating throughput** (Fig. 6): per-batch speed rises with batch
//!   size then plateaus — modelled as matmul efficiency
//!   `eff(tokens) = eff_max * tokens / (tokens + sat_tokens)` with a mild
//!   tile-quantization staircase;
//! * **FLOPs ≠ wall time** (Fig. 8): a bandwidth-bound non-matmul term
//!   `bytes_per_token / mem_bw` plus a fixed launch overhead, both of
//!   which scale differently across GPU generations than peak FLOPs.
//!
//! All randomness is a deterministic LCG so experiments are reproducible.



/// Static specification of a GPU type (catalog entry).
#[derive(Debug, Clone, PartialEq)]
pub struct GpuSpec {
    /// Marketing name, e.g. `"A100-80G"`.
    pub name: String,
    /// Device memory in GiB.
    pub mem_gib: f64,
    /// Peak dense fp16/bf16 tensor throughput in TFLOP/s.
    pub peak_tflops: f64,
    /// Memory bandwidth in GB/s (drives the non-matmul term).
    pub mem_bw_gbs: f64,
    /// Fraction of peak sustained by large matmuls on this part.
    pub eff_max: f64,
    /// Tokens at which matmul efficiency reaches half of `eff_max`.
    pub sat_tokens: f64,
    /// Fixed per-micro-step launch/dispatch overhead (seconds).
    pub launch_overhead_s: f64,
    /// Bytes touched per token by bandwidth-bound (non-matmul) ops,
    /// per transformer layer.
    pub nonmatmul_bytes_per_token_layer: f64,
}

impl GpuSpec {
    /// Total device memory in bytes.
    pub fn mem_bytes(&self) -> u64 {
        (self.mem_gib * (1u64 << 30) as f64) as u64
    }

    /// The single-number FLOPs rating Whale-style cost models use.
    pub fn flops_rating(&self) -> f64 {
        self.peak_tflops
    }

    /// Matmul efficiency at a given token count (saturating + staircase).
    pub fn matmul_eff(&self, tokens: f64) -> f64 {
        let smooth = self.eff_max * tokens / (tokens + self.sat_tokens);
        // Tile quantization: batches that don't fill the last 128-row tile
        // waste a fraction of one tile's work.
        let tile = 128.0;
        let waste = {
            let rem = tokens % tile;
            if rem == 0.0 {
                0.0
            } else {
                (tile - rem) / (tokens + tile) * 0.5
            }
        };
        smooth * (1.0 - waste)
    }

    /// Pure-compute time (seconds) for `tokens` tokens of a model with
    /// `flops_per_token` (fwd+bwd) and `n_layers` layers.
    pub fn compute_time(&self, tokens: f64, flops_per_token: f64, n_layers: usize) -> f64 {
        if tokens <= 0.0 {
            return 0.0;
        }
        let flops = flops_per_token * tokens;
        let matmul = flops / (self.peak_tflops * 1e12 * self.matmul_eff(tokens));
        let bytes = self.nonmatmul_bytes_per_token_layer * tokens * n_layers as f64;
        let mem = bytes / (self.mem_bw_gbs * 1e9);
        matmul + mem + self.launch_overhead_s
    }
}

/// Deterministic multiplicative measurement noise (LCG-based).
#[derive(Debug, Clone)]
pub struct NoiseModel {
    state: u64,
    /// Standard deviation of the multiplicative noise (e.g. 0.015 = 1.5%).
    pub sigma: f64,
}

impl NoiseModel {
    /// Create a noise source with the given seed and sigma.
    pub fn new(seed: u64, sigma: f64) -> Self {
        NoiseModel { state: seed.wrapping_mul(0x9E3779B97F4A7C15).max(1), sigma }
    }

    fn next_u64(&mut self) -> u64 {
        // xorshift64*
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Multiplicative factor `1 + N(0, sigma)` (Box–Muller).
    pub fn factor(&mut self) -> f64 {
        let u1 = self.uniform().max(1e-12);
        let u2 = self.uniform();
        let z = (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
        (1.0 + self.sigma * z).max(0.5)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::catalog;

    fn a100() -> GpuSpec {
        catalog::spec("A100-80G").unwrap()
    }

    fn t4() -> GpuSpec {
        catalog::spec("T4").unwrap()
    }

    #[test]
    fn efficiency_saturates() {
        let g = a100();
        let e_small = g.matmul_eff(128.0);
        let e_big = g.matmul_eff(128.0 * 2048.0);
        assert!(e_big > e_small);
        assert!(e_big <= g.eff_max);
        // near-plateau: doubling tokens at the top changes eff < 2%
        let e_big2 = g.matmul_eff(128.0 * 4096.0);
        assert!((e_big2 - e_big) / e_big < 0.02);
    }

    #[test]
    fn tile_quantization_staircase() {
        let g = a100();
        // a full tile is more efficient than one extra row
        assert!(g.matmul_eff(1280.0) > g.matmul_eff(1281.0));
    }

    #[test]
    fn compute_time_monotone_in_tokens() {
        let g = a100();
        let mut prev = 0.0;
        for b in 1..64u32 {
            let t = g.compute_time(b as f64 * 1024.0, 3e9, 24);
            assert!(t > prev, "time must strictly grow with batch");
            prev = t;
        }
    }

    #[test]
    fn speed_rises_then_plateaus() {
        // the Fig. 6 shape: tokens/sec increasing, derivative shrinking
        let g = a100();
        let speed =
            |b: f64| b * 1024.0 / g.compute_time(b * 1024.0, 3e9, 24);
        assert!(speed(4.0) > speed(1.0) * 1.5);
        let gain_late = speed(48.0) / speed(32.0);
        assert!(gain_late < 1.08, "late gain {gain_late} should be small");
    }

    #[test]
    fn wall_time_ratio_differs_from_flops_ratio() {
        // The paper's Fig. 8 point: FLOPs ratings mispredict real speed.
        let (a, t) = (a100(), t4());
        let flops_ratio = a.peak_tflops / t.peak_tflops;
        let tokens = 8.0 * 1024.0;
        let wall_ratio =
            t.compute_time(tokens, 3e9, 24) / a.compute_time(tokens, 3e9, 24);
        assert!(
            (wall_ratio - flops_ratio).abs() / flops_ratio > 0.10,
            "wall {wall_ratio:.2} vs flops {flops_ratio:.2} should diverge >10%"
        );
    }

    #[test]
    fn noise_is_deterministic_and_bounded() {
        let mut n1 = NoiseModel::new(7, 0.02);
        let mut n2 = NoiseModel::new(7, 0.02);
        for _ in 0..100 {
            let (a, b) = (n1.factor(), n2.factor());
            assert_eq!(a, b);
            assert!(a > 0.5 && a < 1.5);
        }
    }

    #[test]
    fn noise_mean_near_one() {
        let mut n = NoiseModel::new(42, 0.02);
        let mean: f64 = (0..5000).map(|_| n.factor()).sum::<f64>() / 5000.0;
        assert!((mean - 1.0).abs() < 0.01, "mean {mean}");
    }
}
