//! Natural cubic spline interpolation.
//!
//! Substrate for the paper's performance-curve construction (Appendix
//! "Cubic Spline Interpolation", Fig. 7): Poplar profiles each GPU at a
//! handful of batch sizes and interpolates speed-vs-batch with a natural
//! cubic spline — piecewise cubics `S_i(x) = a_i + b_i dx + c_i dx^2 +
//! d_i dx^3`, C2-continuous at the knots, zero second derivative at the
//! endpoints.
//!
//! The coefficients come from the standard tridiagonal system solved with
//! the Thomas algorithm (O(n)); evaluation is a binary search for the
//! segment plus a Horner step (O(log n)).

/// A natural cubic spline through `n >= 2` strictly-increasing knots.
///
/// Invariant: a constructed spline always holds at least two knots —
/// [`CubicSpline::fit`] is the only constructor and rejects anything
/// smaller with [`SplineError::TooFewPoints`], so [`CubicSpline::domain`]
/// and evaluation can never index an empty knot vector.
#[derive(Debug, Clone)]
pub struct CubicSpline {
    xs: Vec<f64>,
    ys: Vec<f64>,
    /// Second derivatives at the knots (natural boundary: m[0] = m[n-1] = 0).
    m: Vec<f64>,
}

/// Errors from spline construction.
#[derive(Debug, PartialEq, Eq)]
pub enum SplineError {
    /// Fewer than two knots supplied.
    TooFewPoints,
    /// Knot x-values not strictly increasing.
    NotIncreasing,
    /// A coordinate was NaN or infinite.
    NonFinite,
}

impl std::fmt::Display for SplineError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SplineError::TooFewPoints => write!(f, "spline needs at least 2 points"),
            SplineError::NotIncreasing => write!(f, "spline knots must be strictly increasing"),
            SplineError::NonFinite => write!(f, "spline coordinates must be finite"),
        }
    }
}

impl std::error::Error for SplineError {}

impl CubicSpline {
    /// Fit a natural cubic spline through `(xs[i], ys[i])`.
    pub fn fit(xs: &[f64], ys: &[f64]) -> Result<Self, SplineError> {
        let n = xs.len();
        if n < 2 || ys.len() != n {
            return Err(SplineError::TooFewPoints);
        }
        if xs.iter().chain(ys.iter()).any(|v| !v.is_finite()) {
            return Err(SplineError::NonFinite);
        }
        if xs.windows(2).any(|w| w[1] <= w[0]) {
            return Err(SplineError::NotIncreasing);
        }

        // Solve for second derivatives m[1..n-1]:
        //   h[i-1]*m[i-1] + 2(h[i-1]+h[i])*m[i] + h[i]*m[i+1] = 6*(s[i] - s[i-1])
        // where h[i] = x[i+1]-x[i], s[i] = (y[i+1]-y[i])/h[i].
        let mut m = vec![0.0; n];
        if n > 2 {
            let k = n - 2; // interior unknowns
            let h: Vec<f64> = xs.windows(2).map(|w| w[1] - w[0]).collect();
            let s: Vec<f64> = (0..n - 1).map(|i| (ys[i + 1] - ys[i]) / h[i]).collect();
            let mut diag = vec![0.0; k];
            let mut upper = vec![0.0; k];
            let mut lower = vec![0.0; k];
            let mut rhs = vec![0.0; k];
            for i in 0..k {
                diag[i] = 2.0 * (h[i] + h[i + 1]);
                upper[i] = h[i + 1];
                lower[i] = h[i];
                rhs[i] = 6.0 * (s[i + 1] - s[i]);
            }
            // Thomas algorithm (in place).
            for i in 1..k {
                let w = lower[i] / diag[i - 1];
                diag[i] -= w * upper[i - 1];
                rhs[i] -= w * rhs[i - 1];
            }
            m[k] = rhs[k - 1] / diag[k - 1];
            for i in (1..k).rev() {
                m[i] = (rhs[i - 1] - upper[i - 1] * m[i + 1]) / diag[i - 1];
            }
        }
        debug_assert!(n >= 2, "CubicSpline invariant: >= 2 knots after validation");
        Ok(CubicSpline { xs: xs.to_vec(), ys: ys.to_vec(), m })
    }

    /// Number of knots.
    pub fn len(&self) -> usize {
        self.xs.len()
    }

    /// True if the spline has no knots (never constructible — kept for API
    /// completeness; see the `>= 2` knots invariant on the type).
    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }

    /// Domain `[x_min, x_max]` of the knots. Cannot panic: `fit` is the
    /// only constructor and guarantees at least two knots.
    pub fn domain(&self) -> (f64, f64) {
        // lint:allow(panic-path) -- fit() is the only constructor and guarantees >= 2 knots
        let first = *self.xs.first().expect("CubicSpline invariant: >= 2 knots");
        // lint:allow(panic-path) -- fit() is the only constructor and guarantees >= 2 knots
        let last = *self.xs.last().expect("CubicSpline invariant: >= 2 knots");
        (first, last)
    }

    fn segment(&self, x: f64) -> usize {
        // Largest i with xs[i] <= x, clamped to the last segment.
        // total_cmp: knots are finite by construction, but `x` is caller
        // input — a NaN (e.g. a corrupt observed micro-step time flowing
        // through drift detection into curve prediction) must yield a
        // NaN result, not panic the comparator mid-replan. Under
        // total_cmp NaN sorts above every finite knot, so a NaN query
        // lands in the last segment and Horner propagates the NaN.
        match self.xs.binary_search_by(|v| v.total_cmp(&x)) {
            Ok(i) => i.min(self.xs.len() - 2),
            Err(0) => 0,
            Err(i) => (i - 1).min(self.xs.len() - 2),
        }
    }

    /// Evaluate the spline at `x`. Outside the domain, extrapolates the
    /// boundary cubic (callers in `curves` clamp instead). A NaN input
    /// propagates to a NaN output — it never panics.
    pub fn eval(&self, x: f64) -> f64 {
        if x.is_nan() {
            return f64::NAN;
        }
        let i = self.segment(x);
        let h = self.xs[i + 1] - self.xs[i];
        let a = (self.xs[i + 1] - x) / h;
        let b = (x - self.xs[i]) / h;
        a * self.ys[i]
            + b * self.ys[i + 1]
            + ((a.powi(3) - a) * self.m[i] + (b.powi(3) - b) * self.m[i + 1]) * h * h / 6.0
    }

    /// First derivative at `x` (NaN-propagating, like [`CubicSpline::eval`]).
    pub fn deriv(&self, x: f64) -> f64 {
        if x.is_nan() {
            return f64::NAN;
        }
        let i = self.segment(x);
        let h = self.xs[i + 1] - self.xs[i];
        let a = (self.xs[i + 1] - x) / h;
        let b = (x - self.xs[i]) / h;
        (self.ys[i + 1] - self.ys[i]) / h
            + ((3.0 * b * b - 1.0) * self.m[i + 1] - (3.0 * a * a - 1.0) * self.m[i]) * h / 6.0
    }

    /// Maximum of the spline over its domain, by dense sampling refined
    /// with the knots (sufficient for the monotone-ish perf curves).
    pub fn max_over_domain(&self, samples: usize) -> (f64, f64) {
        let (lo, hi) = self.domain();
        let mut best = (lo, self.eval(lo));
        let steps = samples.max(2);
        for k in 0..=steps {
            let x = lo + (hi - lo) * (k as f64) / (steps as f64);
            let y = self.eval(x);
            if y > best.1 {
                best = (x, y);
            }
        }
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn assert_close(a: f64, b: f64, tol: f64) {
        assert!((a - b).abs() <= tol, "{a} vs {b} (tol {tol})");
    }

    #[test]
    fn interpolates_knots_exactly() {
        let xs = [0.0, 1.0, 2.5, 4.0, 7.0];
        let ys = [1.0, 2.0, 0.5, 3.0, -1.0];
        let s = CubicSpline::fit(&xs, &ys).unwrap();
        for (x, y) in xs.iter().zip(ys.iter()) {
            assert_close(s.eval(*x), *y, 1e-12);
        }
    }

    #[test]
    fn reproduces_straight_line_exactly() {
        let xs: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 * x - 2.0).collect();
        let s = CubicSpline::fit(&xs, &ys).unwrap();
        for k in 0..90 {
            let x = k as f64 * 0.1;
            assert_close(s.eval(x), 3.0 * x - 2.0, 1e-10);
            assert_close(s.deriv(x), 3.0, 1e-9);
        }
    }

    #[test]
    fn close_to_smooth_function_between_knots() {
        // The paper's Fig. 7 claim: spline ≈ actual data for smooth curves.
        let xs: Vec<f64> = (1..=16).map(|i| i as f64).collect();
        let f = |x: f64| x / (x + 2.0); // saturating, like speed-vs-batch
        let ys: Vec<f64> = xs.iter().map(|&x| f(x)).collect();
        let s = CubicSpline::fit(&xs, &ys).unwrap();
        // interior points (natural boundary conditions soften the ends)
        for k in 20..=150 {
            let x = k as f64 * 0.1;
            assert_close(s.eval(x), f(x), 3e-3);
        }
    }

    #[test]
    fn natural_boundary_second_derivative_zero() {
        let xs = [0.0, 1.0, 2.0, 3.0];
        let ys = [0.0, 1.0, 4.0, 9.0];
        let s = CubicSpline::fit(&xs, &ys).unwrap();
        assert_close(s.m[0], 0.0, 1e-12);
        assert_close(*s.m.last().unwrap(), 0.0, 1e-12);
    }

    #[test]
    fn c1_continuity_at_knots() {
        let xs = [0.0, 1.0, 2.0, 4.0, 8.0];
        let ys = [0.0, 3.0, -1.0, 2.0, 2.5];
        let s = CubicSpline::fit(&xs, &ys).unwrap();
        for &x in &xs[1..xs.len() - 1] {
            let dl = s.deriv(x - 1e-7);
            let dr = s.deriv(x + 1e-7);
            assert_close(dl, dr, 1e-4);
        }
    }

    #[test]
    fn two_points_is_linear() {
        let s = CubicSpline::fit(&[1.0, 3.0], &[2.0, 6.0]).unwrap();
        assert_close(s.eval(2.0), 4.0, 1e-12);
    }

    #[test]
    fn rejects_bad_input() {
        assert_eq!(CubicSpline::fit(&[1.0], &[1.0]).unwrap_err(), SplineError::TooFewPoints);
        assert_eq!(
            CubicSpline::fit(&[1.0, 1.0], &[1.0, 2.0]).unwrap_err(),
            SplineError::NotIncreasing
        );
        assert_eq!(
            CubicSpline::fit(&[0.0, f64::NAN], &[1.0, 2.0]).unwrap_err(),
            SplineError::NonFinite
        );
    }

    #[test]
    fn nan_eval_propagates_instead_of_panicking() {
        // regression: segment() once unwrapped a partial float compare, so a NaN
        // query (corrupt observed step time through detect_drift / curve
        // prediction) panicked the whole planner
        let s = CubicSpline::fit(&[0.0, 1.0, 2.0], &[0.0, 1.0, 4.0]).unwrap();
        assert!(s.eval(f64::NAN).is_nan());
        assert!(s.deriv(f64::NAN).is_nan());
        // infinities extrapolate the boundary cubic without panicking
        assert!(s.eval(f64::INFINITY).is_infinite() || s.eval(f64::INFINITY).is_nan());
        let _ = s.eval(f64::NEG_INFINITY);
    }

    #[test]
    fn domain_never_panics_on_any_constructible_spline() {
        // regression: domain() indexed xs[0]; the fit-time invariant
        // (>= 2 knots, the only constructor) makes the panic impossible
        let s = CubicSpline::fit(&[1.0, 3.0], &[2.0, 6.0]).unwrap();
        assert!(!s.is_empty(), "fit can never produce an empty spline");
        assert_eq!(s.domain(), (1.0, 3.0));
        assert_eq!(s.len(), 2);
    }

    #[test]
    fn max_over_domain_finds_peak() {
        let xs: Vec<f64> = (0..=20).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|&x| -(x - 12.3).powi(2)).collect();
        let s = CubicSpline::fit(&xs, &ys).unwrap();
        let (x, _) = s.max_over_domain(1000);
        assert_close(x, 12.3, 0.1);
    }
}
