//! Virtual DP ranks: pipeline groups of small-memory GPUs acting as ONE
//! data-parallel participant.
//!
//! Poplar's Alg. 1 assumes every DP rank is a single GPU that can hold
//! the model at *some* ZeRO stage; a card whose per-sample activation
//! footprint alone exceeds its memory is a hard reject at every stage
//! and the leader evicts it. HetPipe and Zorse (PAPERS.md) show that
//! scenario is recoverable throughput: partition the model's layers
//! *within* a group of such cards (intra-group pipeline parallelism),
//! treat the group as one **virtual rank** with one composed performance
//! curve, and let the existing batch allocator schedule it like any
//! other heterogeneous participant. This module owns everything
//! group-shaped:
//!
//! * **per-member memory bound** ([`member_max_layers`]) — the Alg. 1
//!   bound applied to a member's layer share: model-state bytes of its
//!   layer shard at the group's ZeRO stage and *virtual* group size,
//!   plus the in-flight activation working set of 1F1B scheduling
//!   (earlier pipeline stages keep more micro-batches alive);
//! * **layer partitioning** ([`partition_layers`]) — contiguous layer
//!   ranges proportional to each member's bound, weakest member first:
//!   the largest-memory card anchors the LAST pipeline stage, where
//!   exactly one micro-batch is in flight;
//! * **bubble pricing** ([`bubble_efficiency`], [`compose_curve`]) — a
//!   group of `g` members running `m` micro-batches spends `m + g - 1`
//!   slot times per step (the classic pipeline-fill bubble); the
//!   composed curve prices each batch as the *slowest* member's slot
//!   time (stage imbalance is priced, never assumed away) times the
//!   bubble steps, plus [`crate::netsim::NetSim::p2p_time`] activation
//!   hops — and comes out as an ordinary [`PerfCurve`], so the
//!   allocator, the elastic planner and the policy engine consume
//!   groups through the exact same curve interface as physical GPUs;
//! * **grouping proposals** ([`plan_group`], [`pack_groups`]) — the
//!   bounded candidate rule the planner searches: singletons always
//!   stand unchanged, groups are proposed only for cards infeasible at
//!   every ZeRO stage solo, packed anchor-first (largest remaining card
//!   anchors, weakest cards fill) up to `[pipeline] max_group_size`.
//!
//! The `(m + g - 1)/m` bubble/efficiency formula lives HERE and nowhere
//! else — `poplar lint`'s `bubble-formula` rule rejects the shape
//! outside this directory, exactly like the amortized-score confinement
//! in `policy` and the `NetSim` literal confinement in `netsim`.

use crate::cluster::catalog;
use crate::cluster::gpu::GpuSpec;
use crate::config::model::ModelSpec;
use crate::curves::{PerfCurve, ProfiledPoint};
use crate::memmodel;
use crate::netsim::NetSim;

/// A pipeline group needs at least two members — a "group" of one IS the
/// singleton path and must go through the ordinary per-GPU machinery.
pub const MIN_GROUP_SIZE: usize = 2;

/// Default ceiling on members per group (`[pipeline] max_group_size`).
/// Deeper pipelines buy little: the bubble term grows with `g` while the
/// per-member layer share shrinks, and four memory-starved cards already
/// cover every catalog scenario.
pub const DEFAULT_MAX_GROUP_SIZE: usize = 4;

/// Micro-batch slots per chunk in the composed curve: the group's `mbs`
/// is `chunk * MAX_MICRO_BATCHES`, i.e. the curve is profiled out to the
/// point where the bubble is amortized to `MAX_MICRO_BATCHES` fills.
pub const MAX_MICRO_BATCHES: usize = 8;

/// Largest micro-batch chunk size [`plan_group`] searches (descending —
/// the largest feasible chunk wins because bigger micro-batches saturate
/// each member's matmuls; chunk 1 is the most memory-lenient fallback).
pub const MAX_CHUNK: usize = 4;

/// Typed errors of the grouping machinery.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum PipelineError {
    /// A proposed member is not in the GPU catalog.
    UnknownGpu(String),
    /// Fewer than [`MIN_GROUP_SIZE`] members.
    TooSmall(usize),
    /// No contiguous layer partition satisfies every member's Alg. 1
    /// bound at any chunk size.
    Infeasible {
        /// Display label of the rejected group.
        label: String,
        /// ZeRO stage the bound was checked at.
        stage: u8,
        /// Virtual group size the bound was checked at.
        n_virtual: usize,
    },
    /// Composing the group curve failed (degenerate points).
    Curve(String),
}

impl std::fmt::Display for PipelineError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PipelineError::UnknownGpu(g) => write!(f, "unknown GPU type {g:?}"),
            PipelineError::TooSmall(n) => {
                write!(f, "pipeline group needs >= {MIN_GROUP_SIZE} members, got {n}")
            }
            PipelineError::Infeasible { label, stage, n_virtual } => write!(
                f,
                "{label}: no layer partition satisfies the per-member memory bound \
                 at ZeRO-{stage} with {n_virtual} virtual ranks"
            ),
            PipelineError::Curve(e) => write!(f, "composing group curve: {e}"),
        }
    }
}

impl std::error::Error for PipelineError {}

/// One planned pipeline group: the virtual rank the planner addresses.
#[derive(Debug, Clone)]
pub struct GroupPlan {
    /// Display label, e.g. `pg(T4+T4+T4+V100S-32G)` — doubles as the
    /// virtual rank's `gpu` name in slots and manifests.
    pub label: String,
    /// Physical members in pipeline-stage order (ascending memory: the
    /// weakest card runs the first stage with the deepest in-flight
    /// window, the largest anchors the last).
    pub members: Vec<String>,
    /// Contiguous layer count per member, `members` order (sums to the
    /// model's layer count).
    pub ks: Vec<u64>,
    /// Micro-batch chunk size (samples per pipeline micro-batch).
    pub chunk: usize,
    /// ZeRO stage the bound was planned at.
    pub stage: u8,
    /// Virtual group size the bound was planned at.
    pub n_virtual: usize,
    /// The composed speed-vs-batch curve the allocator consumes.
    pub curve: PerfCurve,
}

/// Display label of a member list: `pg(a+b+c)`. Generic over the name
/// representation so interned `TypeId` member lists work unconverted.
pub fn group_label<S: AsRef<str>>(members: &[S]) -> String {
    let names: Vec<&str> = members.iter().map(|m| m.as_ref()).collect();
    format!("pg({})", names.join("+"))
}

/// True when a slot's `gpu` name denotes a pipeline group rather than a
/// catalog card (labels are minted only by [`group_label`]).
pub fn is_group_label(gpu: &str) -> bool {
    gpu.starts_with("pg(") && gpu.ends_with(')')
}

/// Pipeline efficiency of `m` micro-batches over `g` stages: the share
/// of slot times doing useful work once the fill/drain bubble is paid.
/// THE formula — every bubble-shaped expression in the crate must route
/// through here (`bubble-formula` lint rule).
pub fn bubble_efficiency(micro_batches: usize, group_size: usize) -> f64 {
    if micro_batches == 0 || group_size == 0 {
        return 0.0;
    }
    micro_batches as f64 / (micro_batches + group_size - 1) as f64
}

/// Micro-batch count of `batch` samples at `chunk` samples per
/// micro-batch (the `m` of the bubble term).
pub fn micro_batches(batch: usize, chunk: usize) -> usize {
    batch.div_ceil(chunk.max(1))
}

/// Resolve and order a member list for pipeline staging: ascending
/// device memory (ties broken by name for determinism), so the weakest
/// card lands on the first stage and the largest anchors the last.
fn sort_members<S: AsRef<str>>(gpus: &[S]) -> Result<Vec<GpuSpec>, PipelineError> {
    let mut specs = Vec::with_capacity(gpus.len());
    for g in gpus {
        let g = g.as_ref();
        specs.push(catalog::spec(g).ok_or_else(|| PipelineError::UnknownGpu(g.to_string()))?);
    }
    specs.sort_by(|a, b| (a.mem_bytes(), &a.name).cmp(&(b.mem_bytes(), &b.name)));
    Ok(specs)
}

/// The per-member Alg. 1 memory bound: the largest contiguous layer
/// count this member can hold at the group's operating point. The bound
/// charges (a) the model-state bytes of the member's layer shard at
/// `stage`, partitioned across `n_virtual` virtual DP ranks, (b) the
/// framework reserve, and (c) the member's activation working set —
/// `chunk * in_flight` micro-batch samples of its layer share, with the
/// same transient factor Alg. 1's binary search discovers. `in_flight`
/// is the member's 1F1B window: `g` micro-batches on the first pipeline
/// stage down to 1 on the last, which is exactly why the largest card
/// anchors the final stage.
pub fn member_max_layers(
    spec: &GpuSpec,
    model: &ModelSpec,
    param_count: u64,
    stage: u8,
    n_virtual: usize,
    chunk: usize,
    in_flight: usize,
) -> u64 {
    let total_layers = model.n_layers;
    let act_per_sample = model.activation_bytes_per_sample() as f64;
    let cap = spec.mem_bytes();
    let mut best = 0u64;
    for k in 1..=total_layers {
        let share = k as f64 / total_layers as f64;
        let shard_params = (param_count as f64 * share) as u64;
        let state = memmodel::model_state_bytes(shard_params, stage, n_virtual);
        let act = act_per_sample
            * share
            * (chunk * in_flight) as f64
            * (1.0 + memmodel::TRANSIENT_FACTOR);
        let need = state + memmodel::FRAMEWORK_RESERVE_BYTES + act as u64;
        if need <= cap {
            best = k;
        } else {
            break; // the bound is monotone in k
        }
    }
    best
}

/// Contiguous layer partition proportional to each member's bound.
/// `None` when no partition exists: a member bound of zero, more members
/// than layers, or bounds summing below the layer count. Otherwise every
/// member gets at least one layer, no member exceeds its bound, and the
/// counts sum exactly to `total_layers` (slack granted to the member
/// with the most headroom — in an ascending-memory group that is the
/// last-stage anchor).
pub fn partition_layers(maxes: &[u64], total_layers: u64) -> Option<Vec<u64>> {
    let g = maxes.len() as u64;
    if g == 0 || g > total_layers || maxes.iter().any(|&m| m == 0) {
        return None;
    }
    let sum: u64 = maxes.iter().sum();
    if sum < total_layers {
        return None;
    }
    let mut ks: Vec<u64> =
        maxes.iter().map(|&m| (total_layers * m / sum).clamp(1, m)).collect();
    loop {
        let cur: u64 = ks.iter().sum();
        if cur == total_layers {
            return Some(ks);
        }
        if cur < total_layers {
            // grant the deficit to the largest headroom (last index wins
            // ties — the ascending order's last-stage anchor)
            let i = (0..ks.len()).max_by_key(|&i| maxes[i] - ks[i])?;
            if maxes[i] == ks[i] {
                return None; // no headroom anywhere (sum >= total rules this out)
            }
            ks[i] += 1;
        } else {
            // the >=1 clamp overshot on a tiny model: shave the largest
            let i = (0..ks.len()).max_by_key(|&i| ks[i])?;
            if ks[i] == 1 {
                return None;
            }
            ks[i] -= 1;
        }
    }
}

/// Compose the group's speed-vs-batch curve. For each batch `b` the
/// group runs `m = ceil(b / chunk)` micro-batches; one *slot* is the
/// slowest member's compute over its layer share plus the p2p hop that
/// forwards boundary activations (fp16 `seq x d_model` per sample,
/// forward + backward), and a step costs `m + g - 1` slots — the
/// pipeline-fill bubble. Imbalanced partitions price at the straggler
/// stage's slot time, so a group with one overloaded member is honestly
/// slow rather than optimistically averaged.
pub fn compose_curve(
    specs: &[GpuSpec],
    ks: &[u64],
    model: &ModelSpec,
    chunk: usize,
    net: &NetSim,
) -> Result<PerfCurve, PipelineError> {
    if specs.len() < MIN_GROUP_SIZE {
        return Err(PipelineError::TooSmall(specs.len()));
    }
    if specs.len() != ks.len() || chunk == 0 {
        return Err(PipelineError::Curve(format!(
            "{} members vs {} layer counts (chunk {chunk})",
            specs.len(),
            ks.len()
        )));
    }
    let g = specs.len();
    let total_layers = model.n_layers as f64;
    let fpt = model.flops_per_token();
    let mbs = chunk * MAX_MICRO_BATCHES;
    let mut points = Vec::with_capacity(mbs);
    for b in 1..=mbs {
        let m = micro_batches(b, chunk);
        let per = b.div_ceil(m);
        let tokens = (per as u64 * model.seq) as f64;
        // fp16 boundary activations, forward + backward: 2 bytes x 2
        let hop_bytes = 4 * per as u64 * model.seq * model.d_model;
        let mut slot = 0.0f64;
        for (spec, &k) in specs.iter().zip(ks) {
            let mut t = spec.compute_time(tokens, fpt * k as f64 / total_layers, k as usize);
            t += net.p2p_time(hop_bytes);
            slot = slot.max(t);
        }
        let steps = (m + g - 1) as f64;
        points.push(ProfiledPoint { batch: b, step_time_s: steps * slot });
    }
    PerfCurve::fit(points, mbs).map_err(|e| PipelineError::Curve(e.to_string()))
}

/// Plan one pipeline group at an operating point: order the members for
/// staging, search chunk sizes descending ([`MAX_CHUNK`]`..=1`), take
/// the first chunk with a feasible layer partition, and compose the
/// group curve. The largest feasible chunk wins — bigger micro-batches
/// saturate each member's matmuls — and chunk 1 is the memory floor.
pub fn plan_group<S: AsRef<str>>(
    gpus: &[S],
    model: &ModelSpec,
    param_count: u64,
    stage: u8,
    n_virtual: usize,
    net: &NetSim,
) -> Result<GroupPlan, PipelineError> {
    if gpus.len() < MIN_GROUP_SIZE {
        return Err(PipelineError::TooSmall(gpus.len()));
    }
    let specs = sort_members(gpus)?;
    let names: Vec<String> = specs.iter().map(|s| s.name.clone()).collect();
    let g = specs.len();
    for chunk in (1..=MAX_CHUNK).rev() {
        let maxes: Vec<u64> = specs
            .iter()
            .enumerate()
            .map(|(i, spec)| {
                member_max_layers(spec, model, param_count, stage, n_virtual, chunk, g - i)
            })
            .collect();
        let Some(ks) = partition_layers(&maxes, model.n_layers) else { continue };
        let curve = compose_curve(&specs, &ks, model, chunk, net)?;
        return Ok(GroupPlan {
            label: group_label(&names),
            members: names,
            ks,
            chunk,
            stage,
            n_virtual,
            curve,
        });
    }
    Err(PipelineError::Infeasible { label: group_label(&names), stage, n_virtual })
}

/// The feasibility half of [`plan_group`] without curve composition:
/// true when a layer partition satisfying every member's bound exists at
/// chunk 1 (the most lenient chunk). This is the group-aware arm of the
/// Alg. 1 memory bound — `ElasticPlanner::stage_feasible_with` and the
/// release guard call it for slots that carry members.
pub fn group_feasible<S: AsRef<str>>(
    gpus: &[S],
    model: &ModelSpec,
    param_count: u64,
    stage: u8,
    n_virtual: usize,
) -> bool {
    let Ok(specs) = sort_members(gpus) else { return false };
    if specs.len() < MIN_GROUP_SIZE {
        return false;
    }
    let g = specs.len();
    let maxes: Vec<u64> = specs
        .iter()
        .enumerate()
        .map(|(i, spec)| member_max_layers(spec, model, param_count, stage, n_virtual, 1, g - i))
        .collect();
    partition_layers(&maxes, model.n_layers).is_some()
}

/// Anchor-first packing of a card pool into candidate groups — THE
/// grouping decision rule (recorded in ROADMAP item 3): sort ascending
/// by memory, then repeatedly let the **largest remaining card anchor**
/// a group (it takes the last pipeline stage, where one micro-batch is
/// in flight) and fill the remaining seats with the **weakest** cards
/// (they take the early stages with deep in-flight windows, so weak
/// cards carry few layers). Groups that fail [`group_feasible`] at the
/// resulting virtual group count are dissolved back into the leftover
/// pool until a fixed point. Returns `(groups, leftovers)`; members
/// inside each group are in pipeline-stage order.
pub fn pack_groups<S: AsRef<str>>(
    offers: &[S],
    model: &ModelSpec,
    param_count: u64,
    stage: u8,
    max_group_size: usize,
) -> (Vec<Vec<String>>, Vec<String>) {
    let cap = max_group_size.max(MIN_GROUP_SIZE);
    let Ok(specs) = sort_members(offers) else {
        return (Vec::new(), offers.iter().map(|o| o.as_ref().to_string()).collect());
    };
    let mut pool: Vec<String> = specs.iter().map(|s| s.name.clone()).collect();
    let mut groups: Vec<Vec<String>> = Vec::new();
    while pool.len() >= MIN_GROUP_SIZE {
        let anchor = match pool.pop() {
            Some(a) => a,
            None => break,
        };
        let take = (cap - 1).min(pool.len());
        let mut group: Vec<String> = pool.drain(0..take).collect();
        group.push(anchor);
        groups.push(group);
    }
    let mut leftovers = pool;
    loop {
        let n_virtual = groups.len();
        if n_virtual == 0 {
            break;
        }
        let before = groups.len();
        let mut keep = Vec::with_capacity(before);
        for g in groups {
            if group_feasible(&g, model, param_count, stage, n_virtual) {
                keep.push(g);
            } else {
                leftovers.extend(g);
            }
        }
        groups = keep;
        if groups.len() == before {
            break;
        }
    }
    leftovers.sort();
    (groups, leftovers)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::LinkKind;
    use crate::config::model::preset;

    /// The fig_pipeline scenario: a long-context model whose per-sample
    /// activations (~31 GB) exceed every non-A800 card's entire memory,
    /// so ZeRO sharding alone can never admit those cards.
    fn longctx() -> ModelSpec {
        preset("longctx-0.4b").expect("preset must exist")
    }

    fn quad() -> Vec<String> {
        ["T4", "T4", "T4", "V100S-32G"].iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn label_and_detection() {
        let l = group_label(&quad());
        assert_eq!(l, "pg(T4+T4+T4+V100S-32G)");
        assert!(is_group_label(&l));
        assert!(!is_group_label("T4"));
        assert!(!is_group_label("A800-80G"));
    }

    #[test]
    fn bubble_efficiency_shape() {
        // one micro-batch over four stages: only 1 of 4 slots is useful
        assert_eq!(bubble_efficiency(1, 4), 0.25);
        // deep amortization approaches 1
        assert!(bubble_efficiency(8, 4) > 0.7 && bubble_efficiency(8, 4) < 0.73);
        // a single stage has no bubble at all
        assert_eq!(bubble_efficiency(5, 1), 1.0);
        // degenerate inputs are zero, not a division panic
        assert_eq!(bubble_efficiency(0, 4), 0.0);
        assert_eq!(bubble_efficiency(4, 0), 0.0);
        // micro-batch counting rounds up
        assert_eq!(micro_batches(8, 1), 8);
        assert_eq!(micro_batches(5, 2), 3);
    }

    #[test]
    fn partition_respects_bounds_and_sums_exactly() {
        // the verified quad bounds at ZeRO-3, 2 virtual ranks
        let ks = partition_layers(&[2, 3, 4, 18], 21).expect("feasible");
        assert_eq!(ks, vec![1, 2, 3, 15]);
        assert_eq!(ks.iter().sum::<u64>(), 21);
        for (k, m) in ks.iter().zip([2u64, 3, 4, 18]) {
            assert!(*k >= 1 && *k <= m);
        }
        // bounds summing below the layer count: no partition
        assert!(partition_layers(&[2, 2, 2, 7], 21).is_none());
        // a zero bound anywhere kills the group
        assert!(partition_layers(&[0, 10, 20], 21).is_none());
        // more members than layers cannot each hold one layer
        assert!(partition_layers(&[3, 3, 3], 2).is_none());
        // tiny model where the >=1 clamp overshoots still partitions
        let small = partition_layers(&[4, 4, 4], 3).expect("one layer each");
        assert_eq!(small, vec![1, 1, 1]);
    }

    #[test]
    fn member_bound_tracks_memory_and_in_flight_depth() {
        let m = longctx();
        let psi = m.param_count();
        let t4 = catalog::spec("T4").unwrap();
        let v100s = catalog::spec("V100S-32G").unwrap();
        // deeper in-flight windows shrink the bound
        let shallow = member_max_layers(&t4, &m, psi, 3, 2, 1, 1);
        let deep = member_max_layers(&t4, &m, psi, 3, 2, 1, 4);
        assert!(shallow > deep, "{shallow} vs {deep}");
        // a bigger card holds more layers at the same depth
        assert!(
            member_max_layers(&v100s, &m, psi, 3, 2, 1, 1)
                > member_max_layers(&t4, &m, psi, 3, 2, 1, 1)
        );
        // the verified fig_pipeline operating point: quad member bounds
        let quad_bounds: Vec<u64> = [(4, "T4"), (3, "T4"), (2, "T4"), (1, "V100S-32G")]
            .iter()
            .map(|&(depth, gpu)| {
                member_max_layers(&catalog::spec(gpu).unwrap(), &m, psi, 3, 2, 1, depth)
            })
            .collect();
        assert_eq!(quad_bounds, vec![2, 3, 4, 18]);
    }

    #[test]
    fn plan_group_finds_the_verified_quad_partition() {
        let m = longctx();
        let net = NetSim::from_link(2, LinkKind::Ib);
        let plan = plan_group(&quad(), &m, m.param_count(), 3, 2, &net).unwrap();
        assert_eq!(plan.label, "pg(T4+T4+T4+V100S-32G)");
        assert_eq!(plan.ks, vec![1, 2, 3, 15]);
        assert_eq!(plan.ks.iter().sum::<u64>(), m.n_layers);
        assert_eq!(plan.chunk, 1, "chunk 2 activations blow the T4 bound");
        // the composed curve is a real, positive-rate curve the
        // allocator can consume like any GPU's
        assert_eq!(plan.curve.mbs(), MAX_MICRO_BATCHES);
        let peak = plan.curve.peak_speed();
        assert!(peak > 1.5 && peak < 3.0, "quad peak {peak:.3} sps");
        // and deeper batches amortize the bubble: b=8 beats b=1
        assert!(plan.curve.speed_at(8.0) > 2.0 * plan.curve.speed_at(1.0));
    }

    #[test]
    fn infeasible_groups_are_typed_errors() {
        let m = longctx();
        let net = NetSim::from_link(2, LinkKind::Ib);
        let psi = m.param_count();
        // all-T4: bounds sum below the layer count at every chunk
        let t4s: Vec<String> = vec!["T4".into(), "T4".into(), "T4".into(), "T4".into()];
        assert!(matches!(
            plan_group(&t4s, &m, psi, 3, 2, &net),
            Err(PipelineError::Infeasible { .. })
        ));
        assert!(!group_feasible(&t4s, &m, psi, 3, 2));
        // a singleton is not a group
        assert!(matches!(
            plan_group(&["T4".to_string()], &m, psi, 3, 2, &net),
            Err(PipelineError::TooSmall(1))
        ));
        // unknown members are typed, not panics
        assert!(matches!(
            plan_group(
                &["T4".to_string(), "H100".to_string()],
                &m,
                psi,
                3,
                2,
                &net
            ),
            Err(PipelineError::UnknownGpu(_))
        ));
    }

    #[test]
    fn pack_groups_is_anchor_first() {
        // the fig_pipeline bootstrap: 6x T4 + 2x V100S packs into two
        // V100S-anchored quads (an all-T4 group can never be feasible,
        // so prefix packing of the ascending order would deadlock)
        let m = longctx();
        let offers: Vec<String> = ["T4", "T4", "V100S-32G", "T4", "T4", "V100S-32G", "T4", "T4"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let (groups, leftovers) =
            pack_groups(&offers, &m, m.param_count(), 3, DEFAULT_MAX_GROUP_SIZE);
        assert_eq!(groups.len(), 2);
        assert!(leftovers.is_empty());
        for g in &groups {
            assert_eq!(g.len(), 4);
            assert_eq!(g.last().map(String::as_str), Some("V100S-32G"), "anchor last");
            assert!(group_feasible(g, &m, m.param_count(), 3, groups.len()));
        }
        // an all-T4 pool has no feasible anchor: everything is returned
        let t4s: Vec<String> = (0..6).map(|_| "T4".to_string()).collect();
        let (groups, leftovers) =
            pack_groups(&t4s, &m, m.param_count(), 3, DEFAULT_MAX_GROUP_SIZE);
        assert!(groups.is_empty());
        assert_eq!(leftovers.len(), 6);
    }

    #[test]
    fn composed_curve_prices_the_straggler_stage() {
        // an imbalanced partition must price at the overloaded member:
        // shifting layers onto the V100S anchor slows the whole group
        let m = longctx();
        let net = NetSim::from_link(2, LinkKind::Ib);
        let specs: Vec<GpuSpec> = ["T4", "T4", "T4", "V100S-32G"]
            .iter()
            .map(|g| catalog::spec(g).unwrap())
            .collect();
        let balanced = compose_curve(&specs, &[1, 2, 3, 15], &m, 1, &net).unwrap();
        let skewed = compose_curve(&specs, &[1, 1, 1, 18], &m, 1, &net).unwrap();
        assert!(balanced.peak_speed() > skewed.peak_speed());
        // mismatched shapes are typed errors
        assert!(compose_curve(&specs, &[1, 2, 3], &m, 1, &net).is_err());
        assert!(compose_curve(&specs[..1], &[21], &m, 1, &net).is_err());
    }
}
