//! PJRT runtime: load AOT artifacts (HLO text) and execute them from the
//! rust hot path. Python never runs at training time.
//!
//! * [`meta`] — the python↔rust ABI (`meta.txt`, `params_init.bin`);
//! * [`engine`] — PJRT CPU client + per-batch-variant executable cache.

pub mod engine;
pub mod meta;

pub use engine::{DeviceParams, Engine, GradOutput, StepKind, StepOutput};
pub use meta::{load_init_params, ModelMeta, ParamSpec};

use std::path::PathBuf;

/// Default artifacts root (relative to the repo/workspace), overridable
/// with `POPLAR_ARTIFACTS`.
pub fn artifacts_root() -> PathBuf {
    std::env::var_os("POPLAR_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

/// Artifacts directory for a preset.
pub fn artifacts_dir(preset: &str) -> PathBuf {
    artifacts_root().join(preset)
}
