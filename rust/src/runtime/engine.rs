//! PJRT execution engine: load HLO-text artifacts, compile once per
//! micro-batch variant, execute train steps from the rust hot path.
//!
//! Interchange is HLO *text* (xla_extension 0.5.1 rejects jax≥0.5's
//! 64-bit-id protos — see `aot_recipe` in /opt/xla-example/README.md).
//! Executables are cached per `(kind, batch)`: Poplar's heterogeneous
//! plans give every rank its own micro-batch size and PJRT executables
//! are shape-specialized.

use anyhow::{anyhow, bail, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

use super::meta::ModelMeta;

/// Which artifact an executable came from.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum StepKind {
    /// `step_b{B}`: fwd + bwd + fused SGD update (single-rank path).
    Fused,
    /// `grad_b{B}`: fwd + bwd, raw gradients (multi-rank path).
    Grad,
    /// `apply_update`: optimizer step on reduced gradients.
    Apply,
}

impl StepKind {
    fn file(&self, b: usize) -> String {
        match self {
            StepKind::Fused => format!("step_b{b}.hlo.txt"),
            StepKind::Grad => format!("grad_b{b}.hlo.txt"),
            StepKind::Apply => "apply_update.hlo.txt".to_string(),
        }
    }

    fn batched(&self) -> bool {
        matches!(self, StepKind::Fused | StepKind::Grad)
    }
}

/// Outcome of a fused train step.
#[derive(Debug)]
pub struct StepOutput {
    /// Cross-entropy loss of the micro-batch.
    pub loss: f32,
}

/// Outcome of a grad step.
#[derive(Debug)]
pub struct GradOutput {
    /// Per-parameter gradients (ABI order).
    pub grads: Vec<Vec<f32>>,
    /// Cross-entropy loss of the micro-batch.
    pub loss: f32,
}

/// Parameters resident on the PJRT device.
///
/// §Perf optimization: `run_grad_step` re-uploads every parameter
/// literal on every call (~4·ψ bytes per micro-step). Within one
/// iteration the parameters are frozen (gradients only apply at the
/// end), so the coordinator uploads them once per iteration and reuses
/// the device buffers across all micro-steps via
/// [`Engine::run_grad_step_device`].
pub struct DeviceParams {
    bufs: Vec<xla::PjRtBuffer>,
}

impl DeviceParams {
    /// Number of parameter buffers.
    pub fn len(&self) -> usize {
        self.bufs.len()
    }

    /// Whether there are no buffers.
    pub fn is_empty(&self) -> bool {
        self.bufs.is_empty()
    }
}

/// The PJRT engine: one CPU client + executable cache for one model.
pub struct Engine {
    client: xla::PjRtClient,
    meta: ModelMeta,
    dir: PathBuf,
    cache: HashMap<(StepKind, usize), xla::PjRtLoadedExecutable>,
}

impl Engine {
    /// Open the artifacts directory (`artifacts/<preset>`).
    pub fn open(dir: &Path) -> Result<Self> {
        let meta = ModelMeta::load(dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        Ok(Engine { client, meta, dir: dir.to_path_buf(), cache: HashMap::new() })
    }

    /// Artifact metadata.
    pub fn meta(&self) -> &ModelMeta {
        &self.meta
    }

    /// PJRT platform name (for logs).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch from cache) the executable for `(kind, b)`.
    pub fn executable(
        &mut self,
        kind: StepKind,
        b: usize,
    ) -> Result<&xla::PjRtLoadedExecutable> {
        let key = (kind, if kind.batched() { b } else { 0 });
        if !self.cache.contains_key(&key) {
            if kind.batched() && !self.meta.batch_variants.contains(&b) {
                bail!(
                    "no compiled variant for batch {b}; available: {:?}",
                    self.meta.batch_variants
                );
            }
            let path = self.dir.join(kind.file(b));
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {}: {e:?}", path.display()))?;
            self.cache.insert(key, exe);
        }
        self.cache
            .get(&key)
            .ok_or_else(|| anyhow!("executable cache lost freshly inserted entry"))
    }

    /// Upload one parameter set as device buffers (owned by rust).
    ///
    /// NOTE: everything executes through `execute_b` with rust-owned
    /// buffers. The vendored xla crate's `execute()` (Literal path)
    /// LEAKS its input device buffers — `BufferFromHostLiteral` +
    /// `release()` with no delete in xla_rs.cc — ~4ψ bytes per call,
    /// which OOM-killed a 200-iteration training run before this
    /// workaround (EXPERIMENTS.md §Perf).
    fn param_buffers(&self, params: &[Vec<f32>]) -> Result<Vec<xla::PjRtBuffer>> {
        if params.len() != self.meta.params.len() {
            bail!("expected {} params, got {}", self.meta.params.len(), params.len());
        }
        let mut bufs = Vec::with_capacity(params.len());
        for (spec, vals) in self.meta.params.iter().zip(params) {
            if vals.len() != spec.numel() {
                bail!("param {} has {} elements, expected {}", spec.name, vals.len(),
                      spec.numel());
            }
            let buf = self
                .client
                .buffer_from_host_buffer::<f32>(vals, &spec.shape, None)
                .map_err(|e| anyhow!("upload {}: {e:?}", spec.name))?;
            bufs.push(buf);
        }
        Ok(bufs)
    }

    fn token_buffer(&self, tokens: &[i32], b: usize) -> Result<xla::PjRtBuffer> {
        let want = b * (self.meta.seq + 1);
        if tokens.len() != want {
            bail!("tokens: got {} ids, expected {} (b={b}, seq+1={})", tokens.len(), want,
                  self.meta.seq + 1);
        }
        self.client
            .buffer_from_host_buffer::<i32>(tokens, &[b, self.meta.seq + 1], None)
            .map_err(|e| anyhow!("upload tokens: {e:?}"))
    }

    fn run(
        &mut self,
        kind: StepKind,
        b: usize,
        inputs: &[&xla::PjRtBuffer],
    ) -> Result<Vec<xla::Literal>> {
        let exe = self.executable(kind, b)?;
        let result = exe.execute_b(inputs).map_err(|e| anyhow!("execute_b: {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal: {e:?}"))?;
        // aot.py lowers with return_tuple=True: single tuple root
        lit.to_tuple().map_err(|e| anyhow!("untuple: {e:?}"))
    }

    fn read_flat(&self, lit: &xla::Literal, who: &str) -> Result<Vec<f32>> {
        lit.to_vec::<f32>().map_err(|e| anyhow!("read {who}: {e:?}"))
    }

    /// Fused single-rank step: updates `params` and `momenta` in place,
    /// returns the loss.
    pub fn run_fused_step(
        &mut self,
        b: usize,
        params: &mut [Vec<f32>],
        momenta: &mut [Vec<f32>],
        tokens: &[i32],
    ) -> Result<StepOutput> {
        let n = self.meta.params.len();
        let mut inputs = self.param_buffers(params)?;
        inputs.extend(self.param_buffers(momenta)?);
        inputs.push(self.token_buffer(tokens, b)?);
        let refs: Vec<&xla::PjRtBuffer> = inputs.iter().collect();
        let outs = self.run(StepKind::Fused, b, &refs)?;
        if outs.len() != 2 * n + 1 {
            bail!("fused step returned {} outputs, expected {}", outs.len(), 2 * n + 1);
        }
        for i in 0..n {
            params[i] = self.read_flat(&outs[i], "param")?;
            momenta[i] = self.read_flat(&outs[n + i], "momentum")?;
        }
        let loss = outs[2 * n]
            .get_first_element::<f32>()
            .map_err(|e| anyhow!("loss: {e:?}"))?;
        Ok(StepOutput { loss })
    }

    /// Multi-rank grad step: returns raw gradients + loss, leaves params
    /// untouched.
    pub fn run_grad_step(
        &mut self,
        b: usize,
        params: &[Vec<f32>],
        tokens: &[i32],
    ) -> Result<GradOutput> {
        let n = self.meta.params.len();
        let mut inputs = self.param_buffers(params)?;
        inputs.push(self.token_buffer(tokens, b)?);
        let refs: Vec<&xla::PjRtBuffer> = inputs.iter().collect();
        let outs = self.run(StepKind::Grad, b, &refs)?;
        if outs.len() != n + 1 {
            bail!("grad step returned {} outputs, expected {}", outs.len(), n + 1);
        }
        let mut grads = Vec::with_capacity(n);
        for (i, o) in outs.iter().take(n).enumerate() {
            let _ = i;
            grads.push(self.read_flat(o, "grad")?);
        }
        let loss = outs[n]
            .get_first_element::<f32>()
            .map_err(|e| anyhow!("loss: {e:?}"))?;
        Ok(GradOutput { grads, loss })
    }

    /// Upload parameters to device buffers once (see [`DeviceParams`]).
    pub fn upload_params(&self, params: &[Vec<f32>]) -> Result<DeviceParams> {
        Ok(DeviceParams { bufs: self.param_buffers(params)? })
    }

    /// Grad step with device-resident parameters (§Perf hot path): only
    /// the token batch crosses the host↔device boundary on the way in.
    pub fn run_grad_step_device(
        &mut self,
        b: usize,
        params: &DeviceParams,
        tokens: &[i32],
    ) -> Result<GradOutput> {
        let n = self.meta.params.len();
        let tok_buf = self.token_buffer(tokens, b)?;
        let mut args: Vec<&xla::PjRtBuffer> = params.bufs.iter().collect();
        args.push(&tok_buf);
        let outs = self.run(StepKind::Grad, b, &args)?;
        if outs.len() != n + 1 {
            bail!("grad step returned {} outputs, expected {}", outs.len(), n + 1);
        }
        let mut grads = Vec::with_capacity(n);
        for o in outs.iter().take(n) {
            grads.push(self.read_flat(o, "grad")?);
        }
        let loss = outs[n]
            .get_first_element::<f32>()
            .map_err(|e| anyhow!("loss: {e:?}"))?;
        Ok(GradOutput { grads, loss })
    }

    /// Optimizer step on reduced gradients: updates `params`/`momenta`.
    pub fn run_apply_update(
        &mut self,
        params: &mut [Vec<f32>],
        momenta: &mut [Vec<f32>],
        grads: &[Vec<f32>],
    ) -> Result<()> {
        let n = self.meta.params.len();
        let mut inputs = self.param_buffers(params)?;
        inputs.extend(self.param_buffers(momenta)?);
        inputs.extend(self.param_buffers(grads)?);
        let refs: Vec<&xla::PjRtBuffer> = inputs.iter().collect();
        let outs = self.run(StepKind::Apply, 0, &refs)?;
        if outs.len() != 2 * n {
            bail!("apply returned {} outputs, expected {}", outs.len(), 2 * n);
        }
        for i in 0..n {
            params[i] = self.read_flat(&outs[i], "param")?;
            momenta[i] = self.read_flat(&outs[n + i], "momentum")?;
        }
        Ok(())
    }
}
