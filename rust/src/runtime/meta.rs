//! Artifact metadata: the python↔rust ABI (`artifacts/<preset>/meta.txt`).
//!
//! `aot.py` writes a flat-text twin of `meta.json` (the offline image has
//! no JSON crate). Format: one `key value` pair per line, plus one
//! `param <name> <d0,d1,...>` line per parameter in ABI order.

use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::path::Path;

/// Shape and name of one parameter in ABI order.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParamSpec {
    /// Parameter name (`embed`, `layer0.wq`, …).
    pub name: String,
    /// Dimensions (possibly 1-D).
    pub shape: Vec<usize>,
}

impl ParamSpec {
    /// Number of elements.
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Parsed `meta.txt`.
#[derive(Debug, Clone)]
pub struct ModelMeta {
    /// Preset name the artifacts were built from.
    pub preset: String,
    /// `llama` or `bert`.
    pub arch: String,
    /// Vocabulary size.
    pub vocab: usize,
    /// Hidden size.
    pub d_model: usize,
    /// Layers.
    pub n_layers: usize,
    /// Heads.
    pub n_heads: usize,
    /// FFN intermediate size.
    pub d_ff: usize,
    /// Sequence length the step executables are specialized for.
    pub seq: usize,
    /// Learning rate baked into `step`/`apply_update`.
    pub lr: f64,
    /// SGD momentum baked into the update.
    pub momentum: f64,
    /// Total parameter count.
    pub param_count: usize,
    /// fwd+bwd FLOPs per token.
    pub flops_per_token: f64,
    /// Whether the Pallas kernels were used in the forward path.
    pub use_pallas: bool,
    /// Compiled micro-batch-size variants.
    pub batch_variants: Vec<usize>,
    /// Parameter layout in ABI order.
    pub params: Vec<ParamSpec>,
}

impl ModelMeta {
    /// Parse `meta.txt` content.
    pub fn parse(text: &str) -> Result<Self> {
        let mut kv: HashMap<&str, &str> = HashMap::new();
        let mut params = Vec::new();
        for (ln, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let (key, rest) = line
                .split_once(' ')
                .ok_or_else(|| anyhow!("meta.txt line {}: no value", ln + 1))?;
            if key == "param" {
                let (name, dims) = rest
                    .split_once(' ')
                    .ok_or_else(|| anyhow!("meta.txt line {}: bad param", ln + 1))?;
                let shape: Vec<usize> = dims
                    .split(',')
                    .map(|d| d.trim().parse::<usize>())
                    .collect::<std::result::Result<_, _>>()
                    .with_context(|| format!("meta.txt line {}: bad dims", ln + 1))?;
                params.push(ParamSpec { name: name.to_string(), shape });
            } else {
                kv.insert(key, rest);
            }
        }
        let get = |k: &str| kv.get(k).copied().ok_or_else(|| anyhow!("meta.txt missing {k}"));
        let usize_of = |k: &str| -> Result<usize> {
            Ok(get(k)?.parse::<usize>().with_context(|| format!("meta.txt {k}"))?)
        };
        let f64_of = |k: &str| -> Result<f64> {
            Ok(get(k)?.parse::<f64>().with_context(|| format!("meta.txt {k}"))?)
        };
        let batch_variants: Vec<usize> = get("batch_variants")?
            .split(',')
            .filter(|s| !s.is_empty())
            .map(|s| s.trim().parse::<usize>())
            .collect::<std::result::Result<_, _>>()
            .context("meta.txt batch_variants")?;
        if params.is_empty() {
            bail!("meta.txt has no param lines");
        }
        let meta = ModelMeta {
            preset: get("preset")?.to_string(),
            arch: get("arch")?.to_string(),
            vocab: usize_of("vocab")?,
            d_model: usize_of("d_model")?,
            n_layers: usize_of("n_layers")?,
            n_heads: usize_of("n_heads")?,
            d_ff: usize_of("d_ff")?,
            seq: usize_of("seq")?,
            lr: f64_of("lr")?,
            momentum: f64_of("momentum")?,
            param_count: usize_of("param_count")?,
            flops_per_token: f64_of("flops_per_token")?,
            use_pallas: get("use_pallas")? == "1",
            batch_variants,
            params,
        };
        let total: usize = meta.params.iter().map(|p| p.numel()).sum();
        if total != meta.param_count {
            bail!("param shapes sum to {total}, meta says {}", meta.param_count);
        }
        if meta.batch_variants.is_empty() {
            bail!("no batch variants compiled");
        }
        Ok(meta)
    }

    /// Load `<dir>/meta.txt`.
    pub fn load(dir: &Path) -> Result<Self> {
        let p = dir.join("meta.txt");
        let text = std::fs::read_to_string(&p)
            .with_context(|| format!("reading {}", p.display()))?;
        Self::parse(&text)
    }

    /// The equivalent analytic [`crate::config::model::ModelSpec`].
    pub fn model_spec(&self) -> crate::config::model::ModelSpec {
        crate::config::model::ModelSpec {
            name: self.preset.clone(),
            arch: self.arch.clone(),
            vocab: self.vocab as u64,
            d_model: self.d_model as u64,
            n_layers: self.n_layers as u64,
            n_heads: self.n_heads as u64,
            d_ff: self.d_ff as u64,
            seq: self.seq as u64,
        }
    }

    /// Largest compiled batch variant `<= b`, if any.
    pub fn best_variant_for(&self, b: usize) -> Option<usize> {
        self.batch_variants.iter().copied().filter(|&v| v <= b).max()
    }
}

/// Load `<dir>/params_init.bin` (flat little-endian f32 in ABI order)
/// into per-parameter vectors.
pub fn load_init_params(dir: &Path, meta: &ModelMeta) -> Result<Vec<Vec<f32>>> {
    let p = dir.join("params_init.bin");
    let raw = std::fs::read(&p).with_context(|| format!("reading {}", p.display()))?;
    if raw.len() != 4 * meta.param_count {
        bail!("params_init.bin is {} bytes, expected {}", raw.len(), 4 * meta.param_count);
    }
    let mut out = Vec::with_capacity(meta.params.len());
    let mut off = 0usize;
    for spec in &meta.params {
        let n = spec.numel();
        let mut v = Vec::with_capacity(n);
        for i in 0..n {
            let s = off + 4 * i;
            v.push(f32::from_le_bytes([raw[s], raw[s + 1], raw[s + 2], raw[s + 3]]));
        }
        off += 4 * n;
        out.push(v);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
preset tiny
arch llama
vocab 2048
d_model 256
n_layers 4
n_heads 4
d_ff 1024
seq 256
lr 0.003
momentum 0.9
param_count 20
flops_per_token 123.5
abi flat-f32-params-v1
use_pallas 1
batch_variants 1,2,4
param embed 4,4
param lm_head 2,2
";

    #[test]
    fn parses_sample() {
        let m = ModelMeta::parse(SAMPLE).unwrap();
        assert_eq!(m.preset, "tiny");
        assert_eq!(m.seq, 256);
        assert!(m.use_pallas);
        assert_eq!(m.batch_variants, vec![1, 2, 4]);
        assert_eq!(m.params.len(), 2);
        assert_eq!(m.params[0].numel(), 16);
        assert_eq!(m.model_spec().d_model, 256);
    }

    #[test]
    fn best_variant_picks_largest_fitting() {
        let m = ModelMeta::parse(SAMPLE).unwrap();
        assert_eq!(m.best_variant_for(3), Some(2));
        assert_eq!(m.best_variant_for(4), Some(4));
        assert_eq!(m.best_variant_for(100), Some(4));
        assert_eq!(m.best_variant_for(0), None);
    }

    #[test]
    fn rejects_mismatched_param_count() {
        let bad = SAMPLE.replace("param_count 20", "param_count 21");
        assert!(ModelMeta::parse(&bad).is_err());
    }

    #[test]
    fn rejects_missing_fields() {
        let bad = SAMPLE.replace("vocab 2048\n", "");
        assert!(ModelMeta::parse(&bad).is_err());
    }
}
