//! Cost-aware autoscaling policy: is admitting a candidate GPU worth it?
//!
//! The elastic runtime (PR 1/2) *reacts* to membership events — every
//! `RankJoined` is admitted unconditionally. Real heterogeneous fleets
//! face the opposite problem: spot capacity is *offered* and the
//! scheduler must decide whether the extra throughput pays for the
//! disruption — the cost/throughput question schedulers like Zorse and
//! Nie et al.'s optimal-performance framework optimize for. This module
//! closes the ROADMAP item: given the current [`ElasticPlanner`] state,
//! a candidate GPU type and the collective cost model, it predicts the
//! post-admission operating point *without profiling* and prices the
//! admission honestly:
//!
//! * **throughput** — [`ElasticPlanner::preview_join`] re-runs
//!   Algorithm 2 over live curves + the candidate's. When the
//!   `(gpu, model, stage)` curve is cached the prediction costs zero
//!   profiling calls (the lookup goes through `CurveCache::peek`, so
//!   the hit/miss counters and LRU order stay untouched); otherwise a
//!   **catalog-FLOPs-scaled estimate** is synthesized from the GPU's
//!   spec-sheet ratings ([`synthesize_curve`]) and the decision is
//!   flagged as estimate-based;
//! * **disruption** — the *measured* `ckpt::reshard` penalty of moving
//!   the optimizer shards to the post-admission layout, plus (for
//!   uncached types) an Algorithm 1 cost estimate — profiling is the
//!   pipeline's most expensive step (Table 2) and an admission that
//!   triggers it must amortize it too;
//! * **decision** — the gain is amortized over `[autoscale] horizon_s`
//!   (the expected tenure of the candidate before the next membership
//!   change) by the shared scoring kernel
//!   ([`crate::policy::amortized_score`], this module is a thin adapter
//!   over it) with a reshard + profiling stall ledger; the offer is
//!   **accepted** when the amortized relative gain clears `min_gain` on
//!   a cached curve, **deferred** (profile before committing) when only
//!   the synthesized estimate clears the bar, and **rejected**
//!   otherwise. Joint multi-offer rounds and scale-down decisions are
//!   [`crate::policy::decide_round`]'s job — this adapter prices one
//!   offer at a time;
//! * **frontier** — every offer is also placed on the cluster-level
//!   cost/throughput plane (samples/s vs $/sample from per-type $/hr
//!   prices), and the Pareto-optimal set is reported, so an operator
//!   sees not just accept/reject but *which* accepts are efficient.
//!
//! Wired end to end: `Leader::run_elastic_job` treats `RankJoined`
//! events as offers when `[autoscale]` is configured (declined offers
//! never mutate the planner), `poplar autoscale --offer A,B,…` exposes
//! the policy on the CLI, and `exp::fig_autoscale` snapshots the
//! decision table.

use crate::allocator::{self, PlanError};
use crate::cluster::catalog;
use crate::config::model::ModelSpec;
use crate::curves::{PerfCurve, ProfiledPoint};
use crate::elastic::{CurveKey, ElasticError, ElasticPlanner};
use crate::memmodel;
use crate::metrics::Table;
use crate::netsim::NetSim;
use crate::profiler::PROBE_REPS;

/// Default amortization horizon: how long a candidate is expected to
/// stay before the next membership change re-prices everything. Five
/// minutes matches volatile spot fleets — the regime where admission
/// cost actually matters.
pub const DEFAULT_HORIZON_S: f64 = 300.0;

/// Default minimum amortized relative gain to accept an offer.
pub const DEFAULT_MIN_GAIN: f64 = 0.02;

/// Built-in per-type $/hr price table (typical on-demand cloud rates;
/// deterministic constants so figures are reproducible). `[autoscale]`
/// `prices` entries override these; unknown types price as $0/hr —
/// give them an explicit price to make the cost axis meaningful.
pub fn default_price_per_hour(gpu: &str) -> Option<f64> {
    Some(match gpu {
        "A100-80G" => 3.67,
        "A100-40G" => 2.74,
        "A800-80G" => 3.20,
        "V100-16G" => 1.14,
        "V100S-32G" => 1.58,
        "T4" => 0.53,
        "RTX4090" => 0.69,
        "RTX3060" => 0.18,
        _ => return None,
    })
}

/// Policy knobs (`[autoscale]` in config).
#[derive(Debug, Clone)]
pub struct AutoscaleOptions {
    /// Amortization horizon in seconds (expected candidate tenure).
    pub horizon_s: f64,
    /// Minimum amortized relative gain to accept/defer an offer.
    pub min_gain: f64,
    /// Per-type $/hr overrides of [`default_price_per_hour`].
    pub prices: Vec<(String, f64)>,
}

impl Default for AutoscaleOptions {
    fn default() -> Self {
        AutoscaleOptions {
            horizon_s: DEFAULT_HORIZON_S,
            min_gain: DEFAULT_MIN_GAIN,
            prices: Vec::new(),
        }
    }
}

/// Effective $/hr for a GPU type given override pairs: explicit
/// override, then the built-in table, then $0 (unknown types) — the ONE
/// price-resolution rule, shared with the round engine's options.
pub(crate) fn price_lookup(prices: &[(String, f64)], gpu: &str) -> f64 {
    prices
        .iter()
        .find(|(g, _)| g == gpu)
        .map(|(_, p)| *p)
        .or_else(|| default_price_per_hour(gpu))
        .unwrap_or(0.0)
}

impl AutoscaleOptions {
    /// Effective $/hr for a GPU type: explicit override, then the
    /// built-in table, then $0 (unknown types).
    pub fn price_per_hour(&self, gpu: &str) -> f64 {
        price_lookup(&self.prices, gpu)
    }

    pub(crate) fn validate(&self) -> Result<(), AutoscaleError> {
        if !self.horizon_s.is_finite() || self.horizon_s <= 0.0 {
            return Err(AutoscaleError::BadOptions(format!(
                "horizon_s must be finite and > 0, got {}",
                self.horizon_s
            )));
        }
        // same range the config loader enforces: a bar of 1.0 or more
        // (doubling cluster throughput with one rank) can never accept
        if !self.min_gain.is_finite() || !(0.0..1.0).contains(&self.min_gain) {
            return Err(AutoscaleError::BadOptions(format!(
                "min_gain must be in [0, 1), got {}",
                self.min_gain
            )));
        }
        Ok(())
    }
}

/// Errors from the autoscale policy.
#[derive(Debug, PartialEq)]
pub enum AutoscaleError {
    /// Offered GPU type is not in the catalog.
    UnknownGpu(String),
    /// The candidate cannot fit enough samples at this stage to even
    /// estimate a curve.
    NoCapacity(String),
    /// Invalid policy options.
    BadOptions(String),
    /// The elastic planner rejected the preview.
    Elastic(ElasticError),
    /// The allocator rejected a plan (message form).
    Plan(PlanError),
}

impl std::fmt::Display for AutoscaleError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AutoscaleError::UnknownGpu(g) => write!(f, "unknown GPU type {g:?}"),
            AutoscaleError::NoCapacity(g) => {
                write!(f, "candidate {g:?} cannot fit enough samples to estimate a curve")
            }
            AutoscaleError::BadOptions(m) => write!(f, "autoscale options: {m}"),
            AutoscaleError::Elastic(e) => write!(f, "preview: {e}"),
            AutoscaleError::Plan(e) => write!(f, "plan: {e}"),
        }
    }
}

impl std::error::Error for AutoscaleError {}

impl From<ElasticError> for AutoscaleError {
    fn from(e: ElasticError) -> Self {
        AutoscaleError::Elastic(e)
    }
}

impl From<PlanError> for AutoscaleError {
    fn from(e: PlanError) -> Self {
        AutoscaleError::Plan(e)
    }
}

/// The verdict on one offer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Decision {
    /// Admit: measured curve, amortized gain clears the bar.
    Accept,
    /// Promising but estimate-based: profile before committing.
    Defer,
    /// Decline: the admission does not pay for itself.
    Reject,
}

impl Decision {
    /// Display label for reports.
    pub fn label(&self) -> &'static str {
        match self {
            Decision::Accept => "accept",
            Decision::Defer => "defer",
            Decision::Reject => "reject",
        }
    }
}

/// Everything the policy concluded about one offer.
#[derive(Debug, Clone)]
pub struct OfferDecision {
    /// Catalog GPU type offered.
    pub gpu: String,
    /// The verdict.
    pub decision: Decision,
    /// True when the prediction used a cached measured curve (zero
    /// profiling calls); false means a catalog-FLOPs-scaled estimate.
    pub curve_cached: bool,
    /// Predicted samples/s of the current cluster.
    pub pre_rate: f64,
    /// Predicted samples/s after admission.
    pub post_rate: f64,
    /// Measured `ckpt::reshard` cost of the admission (seconds).
    pub reshard_penalty_s: f64,
    /// Optimizer-state bytes that reshard moves.
    pub reshard_bytes: u64,
    /// Estimated Algorithm 1 cost for an uncached type (0 when cached).
    pub profile_est_s: f64,
    /// Net samples gained over the horizon, stall time included.
    pub gain_samples: f64,
    /// `gain_samples / (pre_rate * horizon_s)`.
    pub rel_gain: f64,
    /// Candidate's $/hr.
    pub price_per_hour: f64,
    /// Post-admission cluster $ per 1000 samples.
    pub cost_per_ksample: f64,
    /// True when the post-admission operating point is Pareto-optimal
    /// on the (samples/s, $/sample) plane (set by [`evaluate_offers`]).
    pub on_frontier: bool,
    /// Human-readable one-line justification.
    pub reason: String,
}

/// The full policy output over a batch of offers.
#[derive(Debug, Clone)]
pub struct AutoscaleReport {
    /// Horizon the gains were amortized over.
    pub horizon_s: f64,
    /// Acceptance bar used.
    pub min_gain: f64,
    /// Current cluster samples/s (no admission).
    pub baseline_rate: f64,
    /// Current cluster $ per 1000 samples.
    pub baseline_cost_per_ksample: f64,
    /// Whether keeping the cluster as-is is Pareto-optimal.
    pub baseline_on_frontier: bool,
    /// Per-offer verdicts, offer order.
    pub decisions: Vec<OfferDecision>,
}

/// Re-export of [`allocator::predicted_wall_s`] (the policy's original
/// home — the elastic stage search now shares it, so it lives with the
/// planners).
pub use crate::allocator::predicted_wall_s;

/// Synthesize a catalog-FLOPs-scaled performance curve for an
/// unprofiled GPU type: the calibrated spec-sheet device model
/// (peak TFLOPs, efficiency ceiling, memory bandwidth) evaluated at
/// every feasible batch size, with `mbs` from the ZeRO memory model at
/// the post-admission group size. This is the cost-model analogue of a
/// Whale-style FLOPs rating — available with zero profiling, but an
/// *estimate*: decisions built on it are deferred, never accepted
/// outright.
pub fn synthesize_curve(
    gpu: &str,
    model: &ModelSpec,
    stage: u8,
    n_after: usize,
) -> Result<PerfCurve, AutoscaleError> {
    let spec = catalog::spec(gpu).ok_or_else(|| AutoscaleError::UnknownGpu(gpu.to_string()))?;
    let mbs = memmodel::true_mbs(model, model.param_count(), stage, n_after, spec.mem_bytes());
    if mbs < 2 {
        return Err(AutoscaleError::NoCapacity(gpu.to_string()));
    }
    let pts: Vec<ProfiledPoint> = (1..=mbs)
        .map(|b| ProfiledPoint {
            batch: b,
            step_time_s: spec.compute_time(
                (b as u64 * model.seq) as f64,
                model.flops_per_token(),
                model.n_layers as usize,
            ),
        })
        .collect();
    PerfCurve::fit(pts, mbs).map_err(|_| AutoscaleError::NoCapacity(gpu.to_string()))
}

/// Estimated wall time of Algorithm 1 for a candidate with this curve:
/// the exponential probe (1, 2, 4, … up to `mbs`) plus the
/// binary-search refinement, each point measured `PROBE_REPS` times —
/// the cost structure of `profiler::profile_device`, priced on the
/// candidate's own step times.
pub fn profile_cost_estimate_s(curve: &PerfCurve) -> f64 {
    let mbs = curve.mbs().max(1);
    let mut s = 0.0;
    let mut b = 1usize;
    loop {
        s += curve.time_at(b as f64);
        if b >= mbs {
            break;
        }
        b = (b * 2).min(mbs);
    }
    // binary search probes ~log2(mbs) points near the boundary
    s += (mbs as f64).log2().ceil().max(0.0) * curve.time_at(mbs as f64);
    s * PROBE_REPS as f64
}

/// Pareto flags over (maximize rate, minimize cost) points: `true`
/// where no other point is at least as good on both axes and strictly
/// better on one.
pub fn pareto_flags(points: &[(f64, f64)]) -> Vec<bool> {
    points
        .iter()
        .enumerate()
        .map(|(i, &(r, c))| {
            !points.iter().enumerate().any(|(j, &(rj, cj))| {
                j != i && rj >= r && cj <= c && (rj > r || cj < c)
            })
        })
        .collect()
}

fn cluster_price_per_hour(planner: &ElasticPlanner, opts: &AutoscaleOptions) -> f64 {
    planner
        .slots()
        .iter()
        .filter(|s| s.alive)
        .map(|s| opts.price_per_hour(&s.gpu))
        .sum()
}

fn cost_per_ksample(price_per_hour: f64, rate: f64) -> f64 {
    if rate <= 0.0 {
        return f64::INFINITY;
    }
    price_per_hour / 3600.0 / rate * 1000.0
}

/// Predicted samples/s of the cluster as it stands (membership events
/// applied since the last replan included), plus the live curve set the
/// prediction used.
fn baseline(
    planner: &ElasticPlanner,
    net: &NetSim,
) -> Result<(f64, Vec<PerfCurve>), AutoscaleError> {
    let live_curves = planner.active_curves()?;
    let psi = planner.param_count();
    let mut net0 = net.clone();
    net0.n = live_curves.len();
    let base_plan =
        allocator::plan(&live_curves, planner.stage(), planner.gbs(), &net0, psi)?;
    let pre_wall = predicted_wall_s(&base_plan, &live_curves, &net0, psi)?;
    Ok((planner.gbs() as f64 / pre_wall, live_curves))
}

/// Evaluate one offer against the planner's current state. Pure: the
/// planner, its cache (counters and LRU order included) and the leader
/// are untouched whatever the verdict. `on_frontier` is left `false` —
/// frontier placement needs the whole offer batch
/// ([`evaluate_offers`]).
pub fn evaluate_offer(
    planner: &ElasticPlanner,
    net: &NetSim,
    model: &ModelSpec,
    gpu: &str,
    opts: &AutoscaleOptions,
) -> Result<OfferDecision, AutoscaleError> {
    opts.validate()?;
    let (pre_rate, live_curves) = baseline(planner, net)?;
    decide_offer(planner, net, model, gpu, opts, pre_rate, &live_curves)
}

/// The per-offer decision against an already-computed baseline —
/// `opts` must be validated and `pre_rate`/`live_curves` must come from
/// [`baseline`] on the same planner state.
#[allow(clippy::too_many_arguments)]
fn decide_offer(
    planner: &ElasticPlanner,
    net: &NetSim,
    model: &ModelSpec,
    gpu: &str,
    opts: &AutoscaleOptions,
    pre_rate: f64,
    live_curves: &[PerfCurve],
) -> Result<OfferDecision, AutoscaleError> {
    if catalog::spec(gpu).is_none() {
        return Err(AutoscaleError::UnknownGpu(gpu.to_string()));
    }
    let psi = planner.param_count();
    let gbs = planner.gbs() as f64;

    // candidate: cached curve when available, catalog estimate otherwise
    let key = CurveKey::new(gpu, planner.model(), planner.stage());
    let synth = if planner.cache().peek(&key).is_some() {
        None
    } else {
        Some(synthesize_curve(gpu, model, planner.stage(), live_curves.len() + 1)?)
    };
    // the preview may re-stage the admission (planner stage policy): its
    // curve set is the one matching the returned plan's stage
    let pv = planner.preview_join(gpu, synth.as_ref(), net)?;
    let post_wall = predicted_wall_s(&pv.plan, &pv.curves, &pv.net, psi)?;
    let post_rate = gbs / post_wall;

    // amortized accounting: the reshard stalls the whole cluster once,
    // and an uncached type additionally pays Algorithm 1 before its
    // first productive iteration — scored by the shared kernel
    // (`policy::amortized_score`) over a typed stall ledger
    let profile_est_s = if pv.curve_cached { 0.0 } else { profile_cost_estimate_s(&pv.curve) };
    let ledger = crate::policy::StallLedger {
        reshard_transfer_s: pv.reshard_penalty_s,
        profiling_est_s: profile_est_s,
        ..Default::default()
    };
    let stall_s = ledger.total();
    let horizon = opts.horizon_s;
    let gain_samples =
        crate::policy::amortized_gain_samples(pre_rate, post_rate, horizon, &ledger);
    let rel_gain = gain_samples / (pre_rate * horizon);

    let (decision, mut reason) = if rel_gain >= opts.min_gain {
        if pv.curve_cached {
            (
                Decision::Accept,
                format!(
                    "net gain {:.1}% over {:.0}s clears min {:.1}% (reshard {:.2}s, cached curve)",
                    rel_gain * 100.0,
                    horizon,
                    opts.min_gain * 100.0,
                    pv.reshard_penalty_s
                ),
            )
        } else {
            (
                Decision::Defer,
                format!(
                    "est. net gain {:.1}% clears min {:.1}% but the curve is a catalog \
                     estimate: profile before committing",
                    rel_gain * 100.0,
                    opts.min_gain * 100.0
                ),
            )
        }
    } else if gain_samples <= 0.0 {
        (
            Decision::Reject,
            format!(
                "stall {:.2}s (reshard {:.2}s + est. profiling {:.2}s) exceeds the gain \
                 amortized over {:.0}s",
                stall_s, pv.reshard_penalty_s, profile_est_s, horizon
            ),
        )
    } else {
        (
            Decision::Reject,
            format!(
                "net gain {:.1}% below min {:.1}%",
                rel_gain * 100.0,
                opts.min_gain * 100.0
            ),
        )
    };

    if pv.stage != planner.stage() {
        // the stage policy re-staged the admission: an offer that is a
        // stall-bound reject at the incumbent stage can clear the bar
        // this way, and the operator should see why
        reason.push_str(&format!(" [re-staged to ZeRO-{}]", pv.stage));
    }

    let price = opts.price_per_hour(gpu);
    let post_price = cluster_price_per_hour(planner, opts) + price;
    Ok(OfferDecision {
        gpu: gpu.to_string(),
        decision,
        curve_cached: pv.curve_cached,
        pre_rate,
        post_rate,
        reshard_penalty_s: pv.reshard_penalty_s,
        reshard_bytes: pv.reshard_bytes,
        profile_est_s,
        gain_samples,
        rel_gain,
        price_per_hour: price,
        cost_per_ksample: cost_per_ksample(post_price, post_rate),
        on_frontier: false,
        reason,
    })
}

/// Evaluate a batch of offers and place every post-admission operating
/// point — plus the keep-as-is baseline — on the cost/throughput
/// Pareto frontier.
pub fn evaluate_offers(
    planner: &ElasticPlanner,
    net: &NetSim,
    model: &ModelSpec,
    offers: &[String],
    opts: &AutoscaleOptions,
) -> Result<AutoscaleReport, AutoscaleError> {
    opts.validate()?;
    // one baseline for the whole batch: every offer is judged against
    // the same keep-as-is operating point
    let (baseline_rate, live_curves) = baseline(planner, net)?;
    let baseline_cost =
        cost_per_ksample(cluster_price_per_hour(planner, opts), baseline_rate);
    let mut decisions: Vec<OfferDecision> = offers
        .iter()
        .map(|gpu| decide_offer(planner, net, model, gpu, opts, baseline_rate, &live_curves))
        .collect::<Result<_, _>>()?;

    let mut points = vec![(baseline_rate, baseline_cost)];
    points.extend(decisions.iter().map(|d| (d.post_rate, d.cost_per_ksample)));
    let flags = pareto_flags(&points);
    for (d, &f) in decisions.iter_mut().zip(&flags[1..]) {
        d.on_frontier = f;
    }

    Ok(AutoscaleReport {
        horizon_s: opts.horizon_s,
        min_gain: opts.min_gain,
        baseline_rate,
        baseline_cost_per_ksample: baseline_cost,
        baseline_on_frontier: flags[0],
        decisions,
    })
}

/// Render a report as the canonical decision table — shared by the CLI
/// (`poplar autoscale`) and the golden figure (`exp::fig_autoscale`),
/// so the two can never drift apart. Baseline row first, then one row
/// per offer in offer order.
pub fn report_table(rep: &AutoscaleReport) -> Table {
    let mut table = Table::new(&[
        "offer",
        "decision",
        "curve",
        "rate_sps",
        "gain_pct",
        "reshard_s",
        "profile_est_s",
        "net_gain_pct",
        "usd_per_ksample",
        "frontier",
    ]);
    table.row(&[
        "(baseline)".into(),
        "keep".into(),
        "-".into(),
        format!("{:.1}", rep.baseline_rate),
        "-".into(),
        "-".into(),
        "-".into(),
        "-".into(),
        format!("{:.4}", rep.baseline_cost_per_ksample),
        if rep.baseline_on_frontier { "yes".into() } else { "-".into() },
    ]);
    for d in &rep.decisions {
        table.row(&[
            d.gpu.clone(),
            d.decision.label().to_string(),
            if d.curve_cached { "cached".into() } else { "estimated".into() },
            format!("{:.1}", d.post_rate),
            format!("{:+.1}", (d.post_rate / d.pre_rate - 1.0) * 100.0),
            format!("{:.3}", d.reshard_penalty_s),
            format!("{:.2}", d.profile_est_s),
            format!("{:+.1}", d.rel_gain * 100.0),
            format!("{:.4}", d.cost_per_ksample),
            if d.on_frontier { "yes".into() } else { "-".into() },
        ]);
    }
    table
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::LinkKind;
    use crate::config::model::preset;

    fn device_curve(gpu: &str, mbs: usize) -> PerfCurve {
        let g = catalog::spec_or_panic(gpu);
        let m = preset("llama-0.5b").unwrap();
        let pts: Vec<ProfiledPoint> = (1..=mbs)
            .map(|b| ProfiledPoint {
                batch: b,
                step_time_s: g.compute_time(
                    (b as u64 * m.seq) as f64,
                    m.flops_per_token(),
                    m.n_layers as usize,
                ),
            })
            .collect();
        PerfCurve::fit(pts, mbs).unwrap()
    }

    fn planner_c() -> (ElasticPlanner, NetSim) {
        let m = preset("llama-0.5b").unwrap();
        let mut p = ElasticPlanner::new(1, 2048, &m.name, m.param_count(), 16);
        for (gpu, mbs) in [
            ("A800-80G", 48),
            ("A800-80G", 48),
            ("A800-80G", 48),
            ("A800-80G", 48),
            ("V100S-32G", 16),
            ("V100S-32G", 16),
            ("V100S-32G", 16),
            ("V100S-32G", 16),
        ] {
            let slot = p.add_slot(gpu);
            if p.slots()[slot].curve.is_none() {
                p.install_curve(slot, device_curve(gpu, mbs), false).unwrap();
            }
        }
        let net = NetSim::from_link(8, LinkKind::Ib);
        p.replan(&net).unwrap();
        (p, net)
    }

    #[test]
    fn pareto_flags_drop_dominated_points() {
        // (rate, cost): b dominates a (faster, cheaper); c is the cheap
        // end, d the fast end, e dominated by d (equal rate, pricier)
        let pts = [(10.0, 5.0), (12.0, 4.0), (8.0, 1.0), (20.0, 9.0), (20.0, 10.0)];
        assert_eq!(pareto_flags(&pts), vec![false, true, true, true, false]);
        // identical points never dominate each other
        assert_eq!(pareto_flags(&[(1.0, 1.0), (1.0, 1.0)]), vec![true, true]);
        assert_eq!(pareto_flags(&[]), Vec::<bool>::new());
    }

    #[test]
    fn every_catalog_gpu_has_a_default_price() {
        for n in catalog::NAMES {
            assert!(default_price_per_hour(n).unwrap() > 0.0, "{n}");
        }
        assert!(default_price_per_hour("H100").is_none());
        // overrides win
        let opts = AutoscaleOptions {
            prices: vec![("T4".into(), 9.99)],
            ..Default::default()
        };
        assert_eq!(opts.price_per_hour("T4"), 9.99);
        assert_eq!(opts.price_per_hour("A800-80G"), 3.20);
        assert_eq!(opts.price_per_hour("made-up"), 0.0);
    }

    #[test]
    fn synthesized_curve_tracks_the_catalog_model() {
        let m = preset("llama-0.5b").unwrap();
        let c = synthesize_curve("T4", &m, 1, 9).unwrap();
        assert!(c.mbs() >= 2);
        assert!(c.peak_speed() > 0.0);
        // a faster part synthesizes a faster curve
        let fast = synthesize_curve("A100-80G", &m, 1, 9).unwrap();
        assert!(fast.peak_speed() > c.peak_speed() * 2.0);
        // unknown type is a typed error
        assert_eq!(
            synthesize_curve("H100", &m, 1, 9).unwrap_err(),
            AutoscaleError::UnknownGpu("H100".into())
        );
        // a 7B model on a T4 has no capacity at ZeRO-0
        let big = preset("llama-7b").unwrap();
        assert_eq!(
            synthesize_curve("T4", &big, 0, 2).unwrap_err(),
            AutoscaleError::NoCapacity("T4".into())
        );
    }

    #[test]
    fn cached_offer_accepts_with_zero_profiling_and_no_cache_traffic() {
        let (p, net) = planner_c();
        let m = preset("llama-0.5b").unwrap();
        let (h0, m0) = (p.cache().hits(), p.cache().misses());
        let lru0 = p.cache().lru_order().to_vec();
        let opts = AutoscaleOptions::default();
        let d = evaluate_offer(&p, &net, &m, "A800-80G", &opts).unwrap();
        assert_eq!(d.decision, Decision::Accept);
        assert!(d.curve_cached);
        assert_eq!(d.profile_est_s, 0.0, "cached candidates are decided without profiling");
        assert!(d.post_rate > d.pre_rate);
        // accepted gain, amortized over the horizon, exceeds the
        // measured reshard penalty
        assert!(
            (d.post_rate - d.pre_rate) * opts.horizon_s
                > d.post_rate * d.reshard_penalty_s
        );
        assert!(d.reshard_penalty_s > 0.0);
        assert!(d.reshard_bytes > 0);
        // the decision left no trace in the cache
        assert_eq!((p.cache().hits(), p.cache().misses()), (h0, m0));
        assert_eq!(p.cache().lru_order(), lru0.as_slice());
    }

    #[test]
    fn uncached_offer_never_accepts_outright() {
        let (p, net) = planner_c();
        let m = preset("llama-0.5b").unwrap();
        let opts = AutoscaleOptions { horizon_s: 36000.0, ..Default::default() };
        // RTX4090 is strong enough to clear any bar at a 10h horizon,
        // but its curve is synthesized: defer, not accept
        let d = evaluate_offer(&p, &net, &m, "RTX4090", &opts).unwrap();
        assert!(!d.curve_cached);
        assert!(d.profile_est_s > 0.0);
        assert_eq!(d.decision, Decision::Defer);
    }

    #[test]
    fn weak_offer_is_rejected_when_stall_exceeds_amortized_gain() {
        let (p, net) = planner_c();
        let m = preset("llama-0.5b").unwrap();
        // a very short tenure: nothing can amortize its admission
        let opts = AutoscaleOptions { horizon_s: 30.0, ..Default::default() };
        let d = evaluate_offer(&p, &net, &m, "RTX3060", &opts).unwrap();
        assert_eq!(d.decision, Decision::Reject);
        assert!(d.gain_samples <= 0.0, "stall must exceed the amortized gain");
    }

    #[test]
    fn decisions_never_mutate_planner_state() {
        let (p, net) = planner_c();
        let m = preset("llama-0.5b").unwrap();
        let slots0 = p.slots().len();
        let replans0 = p.replans();
        let manifest0 = p.manifest().unwrap().clone();
        let offers: Vec<String> = ["A800-80G", "V100S-32G", "RTX4090", "T4", "RTX3060"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let rep =
            evaluate_offers(&p, &net, &m, &offers, &AutoscaleOptions::default()).unwrap();
        assert_eq!(rep.decisions.len(), 5);
        assert_eq!(p.slots().len(), slots0);
        assert_eq!(p.replans(), replans0);
        assert!(!p.dirty());
        assert_eq!(p.manifest().unwrap(), &manifest0);
        // every decision used the same baseline
        for d in &rep.decisions {
            assert!((d.pre_rate - rep.baseline_rate).abs() < 1e-12);
        }
    }

    #[test]
    fn frontier_has_no_dominated_points_and_accepts_gain_throughput() {
        let (p, net) = planner_c();
        let m = preset("llama-0.5b").unwrap();
        let offers: Vec<String> = ["A800-80G", "V100S-32G", "RTX4090", "T4"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let opts = AutoscaleOptions::default();
        let rep = evaluate_offers(&p, &net, &m, &offers, &opts).unwrap();
        // collect all points and check the frontier flags are exactly
        // the non-dominated set
        let mut pts = vec![(rep.baseline_rate, rep.baseline_cost_per_ksample, rep.baseline_on_frontier)];
        for d in &rep.decisions {
            pts.push((d.post_rate, d.cost_per_ksample, d.on_frontier));
        }
        for (i, &(r, c, on)) in pts.iter().enumerate() {
            let dominated = pts.iter().enumerate().any(|(j, &(rj, cj, _))| {
                j != i && rj >= r && cj <= c && (rj > r || cj < c)
            });
            assert_eq!(on, !dominated, "point {i}: rate {r}, cost {c}");
        }
        assert!(pts.iter().any(|&(_, _, on)| on), "frontier cannot be empty");
        // accepting never lowers predicted throughput net of the
        // amortized penalty
        for d in rep.decisions.iter().filter(|d| d.decision == Decision::Accept) {
            assert!(d.gain_samples > 0.0, "{}: {}", d.gpu, d.reason);
            assert!(d.post_rate > d.pre_rate);
        }
    }

    #[test]
    fn bad_options_and_unknown_gpu_are_typed_errors() {
        let (p, net) = planner_c();
        let m = preset("llama-0.5b").unwrap();
        let bad = AutoscaleOptions { horizon_s: 0.0, ..Default::default() };
        assert!(matches!(
            evaluate_offer(&p, &net, &m, "T4", &bad),
            Err(AutoscaleError::BadOptions(_))
        ));
        let nan = AutoscaleOptions { min_gain: f64::NAN, ..Default::default() };
        assert!(matches!(
            evaluate_offer(&p, &net, &m, "T4", &nan),
            Err(AutoscaleError::BadOptions(_))
        ));
        assert_eq!(
            evaluate_offer(&p, &net, &m, "H100", &AutoscaleOptions::default()).unwrap_err(),
            AutoscaleError::UnknownGpu("H100".into())
        );
    }

    #[test]
    fn re_staged_offer_clears_a_bar_the_incumbent_stage_cannot() {
        // ZeRO-3 on a 2 GB/s socket link: admitting one more V100S
        // barely moves the needle because per-micro-step collectives
        // dominate, so a 15% bar rejects the offer. With the stage
        // policy on (and ZeRO-1 measured for every type), the same
        // offer re-stages to ZeRO-1, drops the per-step traffic and
        // clears the bar by a wide margin.
        let m = preset("llama-0.5b").unwrap();
        let mut p = ElasticPlanner::new(3, 2048, &m.name, m.param_count(), 32);
        for (gpu, mbs) in
            [("A800-80G", 24), ("A800-80G", 24), ("V100S-32G", 9), ("V100S-32G", 9)]
        {
            let slot = p.add_slot(gpu);
            if p.slots()[slot].curve.is_none() {
                p.install_curve(slot, device_curve(gpu, mbs), false).unwrap();
            }
        }
        // ZeRO-1 curves as measured at the post-admission group size
        // (n=5): the preview's staleness rule disqualifies anything else
        for gpu in ["A800-80G", "V100S-32G"] {
            let c = synthesize_curve(gpu, &m, 1, 5).unwrap();
            p.install_stage_curve(gpu, 1, c).unwrap();
        }
        let net = NetSim::from_link(4, LinkKind::Socket);
        p.replan(&net).unwrap();
        let opts = AutoscaleOptions { min_gain: 0.15, ..Default::default() };

        let before = evaluate_offer(&p, &net, &m, "V100S-32G", &opts).unwrap();
        assert_eq!(before.decision, Decision::Reject, "{}", before.reason);

        p.set_stage_policy(Some(crate::elastic::StagePolicy::default()));
        let after = evaluate_offer(&p, &net, &m, "V100S-32G", &opts).unwrap();
        assert_eq!(after.decision, Decision::Accept, "{}", after.reason);
        assert!(after.curve_cached);
        assert!(
            after.reason.contains("re-staged to ZeRO-1"),
            "reason must surface the migration: {}",
            after.reason
        );
        assert!(
            after.post_rate > before.post_rate * 1.5,
            "re-staging is where the gain comes from: {} vs {}",
            after.post_rate,
            before.post_rate
        );
    }

    #[test]
    fn invalid_stage_reaches_the_policy_as_a_typed_error() {
        // regression for the ZeRO-stage panic hardening: a corrupt stage
        // flows through plan/preview into the policy as InvalidStage
        let m = preset("llama-0.5b").unwrap();
        let mut p = ElasticPlanner::new(7, 256, &m.name, m.param_count(), 8);
        let slot = p.add_slot("A800-80G");
        p.install_curve(slot, device_curve("A800-80G", 48), false).unwrap();
        let net = NetSim::from_link(1, LinkKind::Ib);
        assert!(matches!(
            evaluate_offer(&p, &net, &m, "A800-80G", &AutoscaleOptions::default()),
            Err(AutoscaleError::Plan(PlanError::InvalidStage(7)))
        ));
    }
}
