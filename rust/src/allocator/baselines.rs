//! Baseline allocators the paper compares against (Figs. 3-5).
//!
//! * [`plan_uniform`] — "DeepSpeed": heterogeneity-unaware uniform
//!   micro-batches. Every rank gets the same `b`, which therefore cannot
//!   exceed the weakest rank's `mbs`. Like the paper we are generous and
//!   "manually tune" the uniform batch: sweep every feasible uniform `b`
//!   and keep the best (this is what the authors did for baseline 3).
//! * [`plan_flops_proportional`] — "Whale": hetero-aware, but driven by
//!   the *FLOPs rating* instead of measured wall time, and blind to
//!   memory-only heterogeneity (equal-FLOPs ranks get equal batches even
//!   when their memories differ — the cluster-A failure mode).

use super::{rank_compute_time, schedule, Plan, PlanError, RankPlan};
use crate::curves::PerfCurve;
use crate::netsim::NetSim;

/// Uniform (DeepSpeed-like) allocation: same micro-batch everywhere,
/// swept to the best feasible value.
pub fn plan_uniform(
    curves: &[PerfCurve],
    stage: u8,
    gbs: usize,
    net: &NetSim,
    param_count: u64,
) -> Result<Plan, PlanError> {
    if gbs == 0 {
        return Err(PlanError::EmptyBatch);
    }
    if curves.is_empty() {
        return Err(PlanError::NoRanks);
    }
    let n = curves.len();
    let min_mbs = curves.iter().map(|c| c.mbs()).min().unwrap_or(0);
    if min_mbs == 0 {
        return Err(PlanError::NoCapacity);
    }
    let t_comm = net.per_microstep_comm_time(stage, param_count)?;
    let t_iter_comm = net.iteration_comm_time(stage, param_count)?;

    let mut best: Option<(f64, usize)> = None; // (wall, b)
    for b in 1..=min_mbs {
        let msum = n * b;
        let gas = gbs.div_ceil(msum).max(1);
        // slowest rank bounds every micro-step (BSP)
        let t_step = curves.iter().map(|c| c.time_at(b as f64)).fold(0.0, f64::max);
        let wall = match stage {
            0 | 1 => t_step * gas as f64 + t_iter_comm,
            _ => (t_step + t_comm) * gas as f64 + t_iter_comm,
        };
        if best.map_or(true, |(w, _)| wall < w) {
            best = Some((wall, b));
        }
    }
    let (wall, b) = best.ok_or(PlanError::NoCapacity)?;

    // uniform share with the tail spread over the first ranks
    let base = gbs / n;
    let extra = gbs % n;
    let ranks: Vec<RankPlan> = (0..n)
        .map(|i| schedule(i, base + usize::from(i < extra), b))
        .collect();
    let plan = Plan { stage, gbs, ranks, predicted_iter_s: wall,
                      strategy: "uniform".into() };
    debug_assert_eq!(plan.total_samples(), gbs);
    Ok(plan)
}

/// FLOPs-proportional (Whale-like) allocation.
///
/// `flops[i]` is the rank's peak-TFLOPs rating. Shares are proportional
/// to the rating, capped by each rank's `mbs` (Whale knows memory limits
/// once told, but measures *capability* by FLOPs alone).
pub fn plan_flops_proportional(
    curves: &[PerfCurve],
    flops: &[f64],
    stage: u8,
    gbs: usize,
    net: &NetSim,
    param_count: u64,
) -> Result<Plan, PlanError> {
    if gbs == 0 {
        return Err(PlanError::EmptyBatch);
    }
    // an empty survivor set must be a typed error: the mbs/flops scale
    // below folds from f64::MAX and would otherwise poison every
    // downstream throughput figure
    if curves.is_empty() {
        return Err(PlanError::NoRanks);
    }
    let n = curves.len();
    assert_eq!(flops.len(), n);
    if curves.iter().all(|c| c.mbs() == 0) {
        return Err(PlanError::NoCapacity);
    }
    let total_flops: f64 = flops.iter().sum();
    if !total_flops.is_finite() || total_flops <= 0.0 {
        return Err(PlanError::NoCapacity);
    }

    // FLOPs-proportional integer shares of gbs, remainder to the
    // highest-rated ranks
    let mut shares: Vec<usize> = flops
        .iter()
        .map(|f| ((gbs as f64) * f / total_flops).floor() as usize)
        .collect();
    let mut rem = gbs - shares.iter().sum::<usize>();
    let mut order: Vec<usize> = (0..n).collect();
    // total_cmp for the same reason as the planner's min_by: a NaN rating
    // must not panic mid-replan
    order.sort_by(|&a, &b| flops[b].total_cmp(&flops[a]));
    let mut k = 0;
    while rem > 0 {
        shares[order[k % n]] += 1;
        rem -= 1;
        k += 1;
    }

    // micro batch: FLOPs-proportional too, scaled so every rank fits its
    // mbs (the "manually configured max batch consistent with its
    // strategy" of the paper's baseline 4)
    let scale = curves
        .iter()
        .zip(flops)
        .map(|(c, f)| c.mbs() as f64 / f)
        .fold(f64::MAX, f64::min);
    let micro: Vec<usize> = flops
        .iter()
        .zip(curves)
        .map(|(f, c)| (((f * scale).floor() as usize).max(1)).min(c.mbs().max(1)))
        .collect();

    let (ranks, wall) = match stage {
        0 | 1 => {
            let ranks: Vec<RankPlan> = (0..n).map(|i| schedule(i, shares[i], micro[i])).collect();
            let wall = ranks
                .iter()
                .zip(curves)
                .map(|(r, c)| rank_compute_time(r, c))
                .fold(0.0, f64::max)
                + net.iteration_comm_time(stage, param_count)?;
            (ranks, wall)
        }
        _ => {
            // shared gas, FLOPs-proportional micro-batches
            let msum: usize = micro.iter().sum();
            let gas = gbs.div_ceil(msum).max(1);
            let t_comm = net.per_microstep_comm_time(stage, param_count)?;
            let mut last: Vec<usize> = micro.clone();
            // shrink the final step so totals match gbs
            let mut excess = msum * gas - gbs;
            let mut k = 0;
            let order: Vec<usize> = (0..n).collect();
            while excess > 0 {
                let i = order[k % n];
                if last[i] > 0 {
                    let take = excess.min(last[i]).min(1);
                    last[i] -= take;
                    excess -= take;
                }
                k += 1;
            }
            let ranks: Vec<RankPlan> = (0..n)
                .map(|i| RankPlan {
                    rank: i,
                    micro_batch: micro[i],
                    samples_per_iter: micro[i] * (gas - 1) + last[i],
                    grad_accum_steps: gas,
                    last_batch: last[i],
                })
                .collect();
            let t_step = micro
                .iter()
                .zip(curves)
                .map(|(&b, c)| c.time_at(b as f64))
                .fold(0.0, f64::max);
            let wall = (t_step + t_comm) * gas as f64
                + net.iteration_comm_time(stage, param_count)?;
            (ranks, wall)
        }
    };

    let plan = Plan { stage, gbs, ranks, predicted_iter_s: wall,
                      strategy: "flops-proportional".into() };
    debug_assert_eq!(plan.total_samples(), gbs, "flops plan must cover gbs");
    Ok(plan)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{catalog, LinkKind};
    use crate::config::model::preset;
    use crate::curves::ProfiledPoint;

    fn curve(gpu: &str, mbs: usize) -> PerfCurve {
        let g = catalog::spec_or_panic(gpu);
        let m = preset("llama-0.5b").unwrap();
        let pts: Vec<ProfiledPoint> = (1..=mbs)
            .map(|b| ProfiledPoint {
                batch: b,
                step_time_s: g.compute_time(
                    (b as u64 * m.seq) as f64,
                    m.flops_per_token(),
                    m.n_layers as usize,
                ),
            })
            .collect();
        PerfCurve::fit(pts, mbs).unwrap()
    }

    fn net(n: usize) -> NetSim {
        NetSim::from_link(n, LinkKind::Ib)
    }

    #[test]
    fn uniform_covers_gbs() {
        let curves = vec![curve("A800-80G", 48), curve("V100S-32G", 16)];
        let m = preset("llama-0.5b").unwrap();
        for stage in 0..4u8 {
            let p = plan_uniform(&curves, stage, 101, &net(2), m.param_count()).unwrap();
            p.validate().unwrap();
            assert_eq!(p.total_samples(), 101);
        }
    }

    #[test]
    fn uniform_micro_bounded_by_weakest() {
        let curves = vec![curve("A800-80G", 48), curve("V100S-32G", 16)];
        let m = preset("llama-0.5b").unwrap();
        let p = plan_uniform(&curves, 2, 512, &net(2), m.param_count()).unwrap();
        for r in &p.ranks {
            assert!(r.micro_batch <= 16);
        }
        // uniform: every rank has the same micro batch
        assert!(p.ranks.windows(2).all(|w| w[0].micro_batch == w[1].micro_batch));
    }

    #[test]
    fn flops_proportional_covers_gbs() {
        let curves = vec![curve("A800-80G", 48), curve("V100S-32G", 16)];
        let flops = vec![312.0, 130.0];
        let m = preset("llama-0.5b").unwrap();
        for stage in 0..4u8 {
            let p = plan_flops_proportional(&curves, &flops, stage, 333, &net(2),
                                            m.param_count()).unwrap();
            p.validate().unwrap();
            assert_eq!(p.total_samples(), 333, "stage {stage}");
        }
    }

    #[test]
    fn flops_blind_to_memory_only_heterogeneity() {
        // cluster-A: same FLOPs, different memory -> Whale assigns equal
        // micro batches (bounded by the smaller mbs): no gain possible.
        let curves = vec![curve("A100-80G", 48), curve("A100-40G", 20)];
        let flops = vec![312.0, 312.0];
        let m = preset("llama-0.5b").unwrap();
        let p = plan_flops_proportional(&curves, &flops, 1, 256, &net(2),
                                        m.param_count()).unwrap();
        assert_eq!(p.ranks[0].samples_per_iter, p.ranks[1].samples_per_iter);
    }

    #[test]
    fn empty_survivor_set_is_typed_error() {
        let m = preset("llama-0.5b").unwrap();
        assert_eq!(
            plan_uniform(&[], 1, 64, &net(1), m.param_count()).unwrap_err(),
            PlanError::NoRanks
        );
        assert_eq!(
            plan_flops_proportional(&[], &[], 1, 64, &net(1), m.param_count()).unwrap_err(),
            PlanError::NoRanks
        );
    }

    #[test]
    fn uniform_no_capacity_error() {
        let curves = vec![curve("A800-80G", 48)];
        // fabricate a zero-mbs curve by fitting then asking for stage
        // where min_mbs=0 can't happen through fit(); instead check gbs=0
        let m = preset("llama-0.5b").unwrap();
        assert_eq!(
            plan_uniform(&curves, 0, 0, &net(1), m.param_count()).unwrap_err(),
            PlanError::EmptyBatch
        );
    }
}
