//! Offline analyzing — the paper's Algorithm 2 plus the baselines.
//!
//! Input: per-rank performance curves (from the profiler) + the global
//! batch size. Output: a [`Plan`] assigning every rank its micro-batch
//! size, gradient-accumulation schedule and last-batch size (`lbs`).
//!
//! * ZeRO-0/1 ([`plan_zero01`]) — ranks sync once per iteration, so each
//!   rank gets an independent share `gmbs_i ∝ peak speed`, the integer
//!   remainder is assigned iteratively to the least-loaded rank, and each
//!   rank covers its share with micro-steps at its peak-range batch size.
//! * ZeRO-2/3 ([`plan_zero23`]) — every micro-step ends in a collective,
//!   so the whole cluster shares the accumulation count `gas`. The
//!   search sweeps the per-micro-step time budget `t`: larger `t` means
//!   bigger batches and fewer communication rounds but more imbalance;
//!   `find(g_i, t)` inverts each curve. Wall time
//!   `(t + t_comm) * gas` is minimized exactly as in the paper.

pub mod baselines;

use crate::curves::PerfCurve;
use crate::netsim::NetSim;


/// Per-rank slice of a plan.
#[derive(Debug, Clone, PartialEq)]
pub struct RankPlan {
    /// Global rank.
    pub rank: usize,
    /// Steady-state micro-batch size.
    pub micro_batch: usize,
    /// Samples this rank processes per iteration (ZeRO-0/1: its `gmbs`).
    pub samples_per_iter: usize,
    /// Micro-steps per iteration (gradient-accumulation count).
    pub grad_accum_steps: usize,
    /// Batch size of the final micro-step (`lbs`), absorbing the
    /// integer remainder. 0 means the rank idles in the last step.
    pub last_batch: usize,
}

impl RankPlan {
    /// Total samples implied by the schedule — must equal
    /// `samples_per_iter`.
    pub fn schedule_samples(&self) -> usize {
        if self.grad_accum_steps == 0 {
            return 0;
        }
        self.micro_batch * (self.grad_accum_steps - 1) + self.last_batch
    }
}

/// A full allocation decision for one iteration.
#[derive(Debug, Clone)]
pub struct Plan {
    /// ZeRO stage the plan targets.
    pub stage: u8,
    /// Global batch size in samples.
    pub gbs: usize,
    /// Per-rank schedules, rank order.
    pub ranks: Vec<RankPlan>,
    /// Predicted iteration wall time (seconds) under the fitted curves.
    pub predicted_iter_s: f64,
    /// Which allocator produced this plan (for reports).
    pub strategy: String,
}

impl Plan {
    /// Sum of per-rank samples — must equal `gbs` for a valid plan.
    pub fn total_samples(&self) -> usize {
        self.ranks.iter().map(|r| r.samples_per_iter).sum()
    }

    /// Validate internal consistency.
    pub fn validate(&self) -> Result<(), String> {
        if self.total_samples() != self.gbs {
            return Err(format!(
                "plan covers {} samples, gbs is {}",
                self.total_samples(),
                self.gbs
            ));
        }
        for r in &self.ranks {
            if r.schedule_samples() != r.samples_per_iter {
                return Err(format!(
                    "rank {} schedule covers {} of {}",
                    r.rank,
                    r.schedule_samples(),
                    r.samples_per_iter
                ));
            }
            if r.last_batch > r.micro_batch.max(1) && r.grad_accum_steps > 1 {
                return Err(format!("rank {} lbs {} > micro {}", r.rank, r.last_batch,
                                   r.micro_batch));
            }
        }
        Ok(())
    }
}

/// Allocation failure.
#[derive(Debug, PartialEq)]
pub enum PlanError {
    /// gbs was zero.
    EmptyBatch,
    /// No rank can fit even one sample.
    NoCapacity,
    /// The curve set was empty (every rank departed in one event batch).
    NoRanks,
    /// ZeRO stage outside 0..=3 (user-controlled via config/CLI — must
    /// surface as an error, never a panic).
    InvalidStage(u8),
}

impl std::fmt::Display for PlanError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PlanError::EmptyBatch => write!(f, "global batch size is zero"),
            PlanError::NoCapacity => write!(f, "no rank can fit a single sample"),
            PlanError::NoRanks => write!(f, "no ranks to plan over (empty curve set)"),
            PlanError::InvalidStage(s) => write!(f, "invalid ZeRO stage {s} (want 0..=3)"),
        }
    }
}

impl std::error::Error for PlanError {}

/// Eq. (4): the under-utilization objective `Σ δt_i · p_i` for a set of
/// per-rank compute times and peak speeds.
pub fn objective(times: &[f64], speeds: &[f64]) -> f64 {
    let t_max = times.iter().cloned().fold(0.0, f64::max);
    times
        .iter()
        .zip(speeds)
        .map(|(t, p)| (t_max - t) * p)
        .sum()
}

/// Build a rank's gradient-accumulation schedule covering `samples` at a
/// preferred micro-batch `micro` (paper: `b_i` in the peak range, last
/// batch `lbs` absorbs the remainder).
pub(crate) fn schedule(rank: usize, samples: usize, micro: usize) -> RankPlan {
    if samples == 0 {
        return RankPlan { rank, micro_batch: micro.max(1), samples_per_iter: 0,
                          grad_accum_steps: 0, last_batch: 0 };
    }
    let micro = micro.max(1).min(samples);
    let full = samples / micro;
    let rem = samples % micro;
    let (gas, lbs) = if rem == 0 { (full, micro) } else { (full + 1, rem) };
    RankPlan { rank, micro_batch: micro, samples_per_iter: samples, grad_accum_steps: gas,
               last_batch: lbs }
}

/// ZeRO-0/1 allocation (Alg. 2, first branch).
pub fn plan_zero01(
    curves: &[PerfCurve],
    stage: u8,
    gbs: usize,
) -> Result<Plan, PlanError> {
    if stage > 1 {
        return Err(PlanError::InvalidStage(stage));
    }
    if gbs == 0 {
        return Err(PlanError::EmptyBatch);
    }
    if curves.is_empty() {
        return Err(PlanError::NoRanks);
    }
    let n = curves.len();
    let speeds: Vec<f64> = curves.iter().map(|c| c.peak_speed()).collect();
    let cluster_speed: f64 = speeds.iter().sum();
    if cluster_speed <= 0.0 || curves.iter().all(|c| c.mbs() == 0) {
        return Err(PlanError::NoCapacity);
    }
    let time_opt = gbs as f64 / cluster_speed;

    // proportional integer shares
    let mut gmbs: Vec<usize> = speeds.iter().map(|s| (time_opt * s).floor() as usize).collect();

    // distribute the remainder to the rank that finishes earliest after
    // receiving one more sample (the least-loaded rank of the paper's
    // under-utilization loop)
    let mut remaining = gbs - gmbs.iter().sum::<usize>();
    while remaining > 0 {
        let i = (0..n)
            .min_by(|&a, &b| {
                // total_cmp: a NaN time (degenerate curve) must not panic
                // the planner mid-replan — NaN sorts last and is never
                // picked while any finite candidate exists.
                let ta = (gmbs[a] + 1) as f64 / speeds[a];
                let tb = (gmbs[b] + 1) as f64 / speeds[b];
                ta.total_cmp(&tb)
            })
            // n >= 1 (NoRanks is rejected on entry), so min_by over 0..n
            // always yields a candidate
            .unwrap_or(0);
        gmbs[i] += 1;
        remaining -= 1;
    }

    // per-rank micro batch: the largest batch in the peak range, bounded
    // by mbs and the rank's share ("Poplar strives to select larger batch
    // sizes for each GPU to reduce overall communication" — and fewer
    // micro-steps also amortize launch overhead)
    let ranks: Vec<RankPlan> = curves
        .iter()
        .enumerate()
        .map(|(i, c)| {
            let micro = c.mbs().max(1);
            schedule(i, gmbs[i], micro)
        })
        .collect();

    // predicted compute time per rank: micro-step times summed
    let predicted = ranks
        .iter()
        .zip(curves)
        .map(|(r, c)| rank_compute_time(r, c))
        .fold(0.0, f64::max);

    let plan = Plan { stage, gbs, ranks, predicted_iter_s: predicted,
                      strategy: "poplar".into() };
    debug_assert_eq!(plan.total_samples(), gbs);
    Ok(plan)
}

/// Compute time a rank spends on its schedule under a fitted curve.
pub fn rank_compute_time(r: &RankPlan, c: &PerfCurve) -> f64 {
    if r.grad_accum_steps == 0 {
        return 0.0;
    }
    (r.grad_accum_steps - 1) as f64 * c.time_at(r.micro_batch as f64)
        + c.time_at(r.last_batch as f64)
}

/// ZeRO-2/3 allocation (Alg. 2, second branch): sweep the per-micro-step
/// time budget `t` over all distinct achievable step times.
pub fn plan_zero23(
    curves: &[PerfCurve],
    stage: u8,
    gbs: usize,
    net: &NetSim,
    param_count: u64,
) -> Result<Plan, PlanError> {
    if !(stage == 2 || stage == 3) {
        return Err(PlanError::InvalidStage(stage));
    }
    if gbs == 0 {
        return Err(PlanError::EmptyBatch);
    }
    if curves.is_empty() {
        return Err(PlanError::NoRanks);
    }
    if curves.iter().all(|c| c.mbs() == 0) {
        return Err(PlanError::NoCapacity);
    }
    let t_comm = net.per_microstep_comm_time(stage, param_count)?;
    let t_iter_comm = net.iteration_comm_time(stage, param_count)?;

    // candidate budgets: every rank's step time at every integer batch
    let mut candidates: Vec<f64> = Vec::new();
    for c in curves {
        for b in 1..=c.mbs() {
            candidates.push(c.time_at(b as f64));
        }
    }
    candidates.sort_by(f64::total_cmp);
    candidates.dedup_by(|a, b| (*a - *b).abs() < 1e-12);

    let mut best: Option<(f64, Vec<usize>, usize)> = None; // (wall, batches, gas)
    for &t in &candidates {
        let batches: Vec<usize> = curves.iter().map(|c| c.find(t)).collect();
        let msum: usize = batches.iter().sum();
        if msum == 0 {
            continue;
        }
        let gas = gbs.div_ceil(msum);
        // actual step time is the slowest rank's time at its batch
        let t_step = batches
            .iter()
            .zip(curves)
            .map(|(&b, c)| c.time_at(b as f64))
            .fold(0.0, f64::max);
        let wall = (t_step + t_comm) * gas as f64 + t_iter_comm;
        if best.as_ref().map_or(true, |(w, _, _)| wall < *w) {
            best = Some((wall, batches, gas));
        }
    }
    let (wall, batches, gas) = best.ok_or(PlanError::NoCapacity)?;

    // distribute gbs over the shared-gas schedule: each rank does
    // (gas-1) full micro-steps of b_i, the final step absorbs the
    // remainder proportionally (the paper's lbs).
    let msum: usize = batches.iter().sum();
    let full_cover = msum * (gas - 1);
    let mut last_total = gbs - full_cover.min(gbs);
    // cap: last step can't exceed b_i per rank; distribute greedily in
    // rank order of batch size (bigger ranks take more of the tail)
    let mut last: Vec<usize> = vec![0; curves.len()];
    let mut order: Vec<usize> = (0..curves.len()).collect();
    order.sort_by_key(|&i| std::cmp::Reverse(batches[i]));
    // proportional first pass
    for &i in &order {
        let share = ((batches[i] as f64 / msum as f64) * last_total as f64).floor() as usize;
        last[i] = share.min(batches[i]);
    }
    let mut assigned: usize = last.iter().sum();
    let mut k = 0;
    while assigned < last_total {
        let i = order[k % order.len()];
        if last[i] < batches[i] {
            last[i] += 1;
            assigned += 1;
        }
        k += 1;
        if k > order.len() * (gbs + 1) {
            break; // capacity exhausted; shouldn't happen with gas=ceil
        }
    }
    last_total = assigned;
    let _ = last_total;

    let ranks: Vec<RankPlan> = (0..curves.len())
        .map(|i| RankPlan {
            rank: i,
            micro_batch: batches[i],
            samples_per_iter: batches[i] * (gas - 1) + last[i],
            grad_accum_steps: if batches[i] == 0 && last[i] == 0 { 0 } else { gas },
            last_batch: last[i],
        })
        .collect();

    let plan = Plan { stage, gbs, ranks, predicted_iter_s: wall,
                      strategy: "poplar".into() };
    Ok(plan)
}

/// Dispatch on stage.
pub fn plan(
    curves: &[PerfCurve],
    stage: u8,
    gbs: usize,
    net: &NetSim,
    param_count: u64,
) -> Result<Plan, PlanError> {
    match stage {
        0 | 1 => plan_zero01(curves, stage, gbs),
        2 | 3 => plan_zero23(curves, stage, gbs, net, param_count),
        // reachable from the `[elastic]` config path and the CLI: a typed
        // error, not a panic
        _ => Err(PlanError::InvalidStage(stage)),
    }
}

/// Elastic re-allocation entry point: re-run Algorithm 2 for the same
/// `(stage, gbs)` as `prev` over a *surviving* curve set.
///
/// The curves are the already-fitted ones — re-planning never triggers
/// re-profiling (that decision belongs to `elastic::ElasticPlanner`,
/// which only re-measures ranks that drifted or have no cached curve).
/// `net` must reflect the post-change group size: collective costs shift
/// when ranks come and go, and the t-sweep must see the new costs.
pub fn replan(
    prev: &Plan,
    curves: &[PerfCurve],
    net: &NetSim,
    param_count: u64,
) -> Result<Plan, PlanError> {
    replan_with_stage(prev, curves, prev.stage, net, param_count)
}

/// [`replan`] with an explicit ZeRO stage: the elastic runtime's
/// stage-migration path re-plans the same `gbs` at a *different* stage
/// when the stage search decides the migration pays for itself. The
/// curves must already be fitted *at `stage`* — a stage change shifts
/// every rank's memory budget, so curves from another stage carry a
/// wrong `mbs`.
pub fn replan_with_stage(
    prev: &Plan,
    curves: &[PerfCurve],
    stage: u8,
    net: &NetSim,
    param_count: u64,
) -> Result<Plan, PlanError> {
    plan(curves, stage, prev.gbs, net, param_count)
}

/// Predicted iteration wall time of a plan under fitted curves —
/// compute of the slowest rank plus the stage's collective costs.
/// ZeRO-2/3 planners already fold communication into
/// `predicted_iter_s`; ZeRO-0/1 report compute only, so the sync-point
/// collective is added here. Shared by the autoscale policy and the
/// elastic stage search: cross-stage rate comparisons are only fair
/// with the collectives priced in.
pub fn predicted_wall_s(
    plan: &Plan,
    curves: &[PerfCurve],
    net: &NetSim,
    param_count: u64,
) -> Result<f64, PlanError> {
    match plan.stage {
        0 | 1 => {
            let t = plan
                .ranks
                .iter()
                .zip(curves)
                .map(|(r, c)| rank_compute_time(r, c))
                .fold(0.0, f64::max);
            Ok(t + net.iteration_comm_time(plan.stage, param_count)?)
        }
        2 | 3 => Ok(plan.predicted_iter_s),
        s => Err(PlanError::InvalidStage(s)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{catalog, LinkKind};
    use crate::config::model::preset;
    use crate::curves::ProfiledPoint;

    fn curve(gpu: &str, mbs: usize) -> PerfCurve {
        let g = catalog::spec_or_panic(gpu);
        let m = preset("llama-0.5b").unwrap();
        let pts: Vec<ProfiledPoint> = (1..=mbs)
            .map(|b| ProfiledPoint {
                batch: b,
                step_time_s: g.compute_time(
                    (b as u64 * m.seq) as f64,
                    m.flops_per_token(),
                    m.n_layers as usize,
                ),
            })
            .collect();
        PerfCurve::fit(pts, mbs).unwrap()
    }

    fn cluster_c_curves() -> Vec<PerfCurve> {
        let mut v = vec![];
        for _ in 0..4 {
            v.push(curve("A800-80G", 48));
        }
        for _ in 0..4 {
            v.push(curve("V100S-32G", 16));
        }
        v
    }

    fn net8() -> NetSim {
        NetSim::from_link(8, LinkKind::Ib)
    }

    #[test]
    fn zero01_covers_gbs_exactly() {
        let curves = cluster_c_curves();
        for gbs in [64usize, 100, 257, 2048] {
            let p = plan_zero01(&curves, 1, gbs).unwrap();
            p.validate().unwrap();
            assert_eq!(p.total_samples(), gbs, "gbs {gbs}");
        }
    }

    #[test]
    fn zero01_faster_ranks_get_more() {
        let curves = cluster_c_curves();
        let p = plan_zero01(&curves, 0, 512).unwrap();
        // A800 ranks (0-3) must each get more than V100S ranks (4-7)
        assert!(p.ranks[0].samples_per_iter > p.ranks[4].samples_per_iter);
    }

    #[test]
    fn zero01_balances_finish_times() {
        let curves = cluster_c_curves();
        let p = plan_zero01(&curves, 1, 1024).unwrap();
        let times: Vec<f64> = p.ranks.iter().zip(&curves)
            .map(|(r, c)| rank_compute_time(r, c)).collect();
        let t_max = times.iter().cloned().fold(0.0, f64::max);
        let t_min = times.iter().cloned().fold(f64::MAX, f64::min);
        assert!((t_max - t_min) / t_max < 0.15, "imbalance {t_max} vs {t_min}");
    }

    #[test]
    fn zero23_covers_gbs_and_shares_gas() {
        let curves = cluster_c_curves();
        let m = preset("llama-0.5b").unwrap();
        for stage in [2u8, 3] {
            let p = plan_zero23(&curves, stage, 512, &net8(), m.param_count()).unwrap();
            p.validate().unwrap();
            assert_eq!(p.total_samples(), 512);
            let gas: Vec<usize> = p.ranks.iter().filter(|r| r.grad_accum_steps > 0)
                .map(|r| r.grad_accum_steps).collect();
            assert!(gas.windows(2).all(|w| w[0] == w[1]), "shared gas {gas:?}");
        }
    }

    #[test]
    fn zero23_micro_batches_within_mbs() {
        let curves = cluster_c_curves();
        let m = preset("llama-0.5b").unwrap();
        let p = plan_zero23(&curves, 3, 1024, &net8(), m.param_count()).unwrap();
        for (r, c) in p.ranks.iter().zip(&curves) {
            assert!(r.micro_batch <= c.mbs());
            assert!(r.last_batch <= r.micro_batch.max(1));
        }
    }

    #[test]
    fn zero23_prefers_fewer_comm_rounds_on_slow_nets() {
        // On a slow network the chosen gas should not exceed what a fast
        // network would choose (bigger batches per step = fewer rounds).
        let curves = cluster_c_curves();
        let m = preset("llama-0.5b").unwrap();
        let slow = NetSim::from_link(8, LinkKind::Socket);
        let fast = NetSim::from_link(8, LinkKind::Nvlink);
        let p_slow = plan_zero23(&curves, 3, 512, &slow, m.param_count()).unwrap();
        let p_fast = plan_zero23(&curves, 3, 512, &fast, m.param_count()).unwrap();
        let gas = |p: &Plan| p.ranks.iter().map(|r| r.grad_accum_steps).max().unwrap();
        assert!(gas(&p_slow) <= gas(&p_fast), "{} vs {}", gas(&p_slow), gas(&p_fast));
    }

    #[test]
    fn objective_zero_when_balanced() {
        assert_eq!(objective(&[1.0, 1.0, 1.0], &[2.0, 3.0, 4.0]), 0.0);
        assert!(objective(&[1.0, 0.5], &[2.0, 2.0]) > 0.0);
    }

    #[test]
    fn schedule_lbs_absorbs_remainder() {
        let r = schedule(0, 10, 4);
        assert_eq!(r.grad_accum_steps, 3);
        assert_eq!(r.last_batch, 2);
        assert_eq!(r.schedule_samples(), 10);
        let exact = schedule(0, 12, 4);
        assert_eq!(exact.grad_accum_steps, 3);
        assert_eq!(exact.last_batch, 4);
    }

    #[test]
    fn replan_keeps_stage_and_gbs_over_survivors() {
        let curves = cluster_c_curves();
        let m = preset("llama-0.5b").unwrap();
        for stage in [1u8, 3] {
            let prev = plan(&curves, stage, 512, &net8(), m.param_count()).unwrap();
            // rank 5 departs: replan over the 7 survivors
            let mut survivors = curves.clone();
            survivors.remove(5);
            let net7 = NetSim::from_link(7, LinkKind::Ib);
            let p = replan(&prev, &survivors, &net7, m.param_count()).unwrap();
            p.validate().unwrap();
            assert_eq!(p.stage, stage);
            assert_eq!(p.total_samples(), 512);
            assert_eq!(p.ranks.len(), 7);
        }
    }

    #[test]
    fn zero_gbs_rejected() {
        let curves = cluster_c_curves();
        assert_eq!(plan_zero01(&curves, 0, 0).unwrap_err(), PlanError::EmptyBatch);
        let m = preset("llama-0.5b").unwrap();
        assert_eq!(
            plan_zero23(&curves, 2, 0, &net8(), m.param_count()).unwrap_err(),
            PlanError::EmptyBatch
        );
    }

    #[test]
    fn invalid_stage_is_typed_error_not_panic() {
        let curves = cluster_c_curves();
        let m = preset("llama-0.5b").unwrap();
        for bad in [4u8, 7, 255] {
            assert_eq!(
                plan(&curves, bad, 256, &net8(), m.param_count()).unwrap_err(),
                PlanError::InvalidStage(bad)
            );
        }
        assert_eq!(plan_zero01(&curves, 2, 256).unwrap_err(), PlanError::InvalidStage(2));
        assert_eq!(
            plan_zero23(&curves, 1, 256, &net8(), m.param_count()).unwrap_err(),
            PlanError::InvalidStage(1)
        );
        // replan surfaces it too (a stale plan with a corrupt stage must
        // not take the whole elastic job down with a panic)
        let mut prev = plan(&curves, 1, 256, &net8(), m.param_count()).unwrap();
        prev.stage = 9;
        assert_eq!(
            replan(&prev, &curves, &net8(), m.param_count()).unwrap_err(),
            PlanError::InvalidStage(9)
        );
    }

    #[test]
    fn empty_curve_set_is_typed_error() {
        // every rank departing in one event batch must yield NoRanks, not
        // a fold over an empty set returning f64::MAX
        let m = preset("llama-0.5b").unwrap();
        assert_eq!(plan_zero01(&[], 1, 64).unwrap_err(), PlanError::NoRanks);
        assert_eq!(
            plan_zero23(&[], 3, 64, &net8(), m.param_count()).unwrap_err(),
            PlanError::NoRanks
        );
        assert_eq!(
            plan(&[], 0, 64, &net8(), m.param_count()).unwrap_err(),
            PlanError::NoRanks
        );
    }

    #[test]
    fn nan_curves_rejected_at_fit_time() {
        // the NaN guard lives at PerfCurve::fit: a degenerate probe (NaN
        // or infinite step time) never reaches the planner's comparators
        use crate::curves::CurveError;
        let nan = vec![
            ProfiledPoint { batch: 1, step_time_s: f64::NAN },
            ProfiledPoint { batch: 2, step_time_s: 0.2 },
        ];
        assert_eq!(PerfCurve::fit(nan, 4).unwrap_err(), CurveError::InvalidPoint);
        let inf = vec![
            ProfiledPoint { batch: 1, step_time_s: 0.1 },
            ProfiledPoint { batch: 2, step_time_s: f64::INFINITY },
        ];
        assert_eq!(PerfCurve::fit(inf, 4).unwrap_err(), CurveError::InvalidPoint);
        // a 1-point "curve" (the degenerate case that used to produce a
        // NaN time downstream) is rejected before it can poison a plan
        let one = vec![ProfiledPoint { batch: 1, step_time_s: 0.1 }];
        assert_eq!(PerfCurve::fit(one, 1).unwrap_err(), CurveError::TooFewPoints);
    }

    #[test]
    fn single_rank_gets_everything() {
        let curves = vec![curve("A100-80G", 32)];
        let p = plan_zero01(&curves, 0, 100).unwrap();
        assert_eq!(p.ranks[0].samples_per_iter, 100);
        p.validate().unwrap();
    }

    #[test]
    fn heterogeneous_quantity_4_to_1() {
        // the Fig. 5 scenario: 4x V100S + 1x A800 must still cover gbs
        let mut curves = vec![];
        for _ in 0..4 {
            curves.push(curve("V100S-32G", 16));
        }
        curves.push(curve("A800-80G", 48));
        let m = preset("llama-0.5b").unwrap();
        for stage in 0..4u8 {
            let p = plan(&curves, stage, 300, &NetSim::from_link(5, LinkKind::Ib),
                         m.param_count()).unwrap();
            p.validate().unwrap();
            assert_eq!(p.total_samples(), 300, "stage {stage}");
            // the single A800 out-weighs each V100S
            assert!(p.ranks[4].samples_per_iter > p.ranks[0].samples_per_iter);
        }
    }
}
