//! # Poplar — heterogeneity-aware ZeRO training
//!
//! Reproduction of *"Poplar: Efficient Scaling of Distributed DNN Training
//! on Heterogeneous GPU Clusters"* (AAAI 2025). See `DESIGN.md` for the
//! system inventory and the substitution plan for the hardware gate.
//!
//! Layering (request path is pure rust — python only at build time):
//!
//! * **L3 (this crate)** — the paper's system: online profiler (Alg. 1),
//!   performance-curve construction, batch-allocation search (Alg. 2),
//!   ZeRO-stage BSP engine, leader/worker coordinator.
//! * **L2** — JAX Llama/BERT train step, AOT-lowered to HLO text under
//!   `artifacts/` (`python/compile/model.py` + `aot.py`).
//! * **L1** — Pallas kernels (fused SwiGLU FFN, flash attention) called by
//!   L2 (`python/compile/kernels/`).
//!
//! Module map (bottom-up):
//!
//! | module | role |
//! |---|---|
//! | [`spline`] | natural cubic spline (tridiagonal solve) |
//! | [`intern`] | interned GPU/model type names: `Copy` `TypeId` handles into a process-global table (lexicographic `Ord`, `String`-identical `Debug`), resolved to strings only at report/CLI boundaries; `bytes_interned` counter pins that hot paths stop minting strings |
//! | [`cluster`] | GPU catalog + calibrated device performance model |
//! | [`netsim`] | link topology + ring collective cost models; `BwMonitor` — measured per-link bandwidth (EWMA estimator, Startup/Degrade/Steady/Probe state machine) from which every planner-facing `NetSim` snapshot derives |
//! | [`memmodel`] | ZeRO per-stage memory accounting / mbs prediction |
//! | [`curves`] | profiled points -> performance curve -> `find(g, t)` |
//! | [`pipeline`] | virtual DP ranks: contiguous layer partitioning of a model over a *group* of small-memory GPUs (per-member Alg. 1 bound at the member's layer share + 1F1B in-flight depth), `(m + g − 1)/m` bubble pricing over `netsim` p2p hops, and a composed group `PerfCurve` consumed through the same interface as a physical GPU — the bubble formula is lint-confined here (`bubble-formula`) |
//! | [`profiler`] | Alg. 1: mbs search + stage-aware step timing |
//! | [`allocator`] | Alg. 2: ZeRO-0/1 proportional, ZeRO-2/3 t-sweep + baselines; `replan`/`replan_with_stage` for elastic re-allocation, `predicted_wall_s` cross-stage rate model |
//! | [`zero`] | ZeRO-0..3 BSP iteration engine (sim) + `DriftOracle` slowdown replay + optimizer shard-range layout |
//! | [`ckpt`] | optimizer-shard checkpointing: `ShardManifest` layouts, versioned on-disk format (`artifacts/ckpt/`), minimal-movement `reshard` + cross-stage `migrate` (`partition_point` overlap sweep, per-endpoint `EndpointLoads` pricing; partition↔partition free, →replicate priced broadcast); `MigrationIndex` validates + slot-indexes the incumbent ONCE per round and prices every candidate against it (byte-equal to the retained `migrate_reference`, property-pinned) |
//! | [`elastic`] | elastic runtime: membership + bandwidth-drift events, stage-keyed curve cache, compute- and comm-drift detection, re-planning, measured reshard penalty, non-mutating `preview_join`/`preview_round_at`/`preview_release` + the delta path `preview_round_extend` (one-joiner extension of a prior preview, bit-equal to the batch path), the round-scoped `RoundIndex` (one incumbent validation + live-slot snapshot + per-stage re-layout memo shared by every `*_with` preview of a decision round) with `PerfCounters` (`manifests_built`/`previews_priced`) pinning preview complexity, replan-time ZeRO-stage search (`StagePolicy`, `exp::fig_stage_migration`) |
//! | [`policy`] | unified amortized-decision engine: THE scoring kernel (`amortized_score` over a typed `StallLedger`), the shared `Action` vocabulary, and `decide_round` — joint offer-subset × stage admission plus cost-adjusted scale-down (`Release`); exhaustive subset search ≤ 6 offers, marginal-contribution greedy above (any batch size, `max_offers_per_round` soft cap); every other module scores through it |
//! | [`autoscale`] | cost-aware admission policy, a thin per-offer adapter over [`policy`]: predicts post-admission throughput (zero profiling on cache hits, catalog-FLOPs estimates otherwise), emits accept/defer/reject + the samples/s-vs-$/sample Pareto frontier; offers may re-stage under a `StagePolicy` |
//! | [`coordinator`] | leader/worker orchestration (OS threads) + `run_elastic_job` (snapshots shard manifests each plan; `[autoscale]` routes each iteration's offer batch through `policy::decide_round`; `allow_stage_change` migrates the ZeRO stage at replan time) |
//! | [`runtime`] | PJRT: load HLO-text artifacts, per-batch executable cache |
//! | [`train`] | real heterogeneous data-parallel training loop |
//! | [`data`] | dynamic-batch loader, synthetic + tiny-corpus LM data |
//! | [`metrics`] | FLOPs accounting, timers, report tables |
//! | [`config`] | TOML config system + paper presets |
//! | [`exp`] | experiment harness: one runner per paper table/figure |
//! | [`lint`] | in-crate invariant analyzer: masks comments/literals, tracks `#[cfg(test)]` spans, enforces panic-path / float-ordering / netsim-literal / amortized-formula / bubble-formula / determinism with reasoned `lint:allow` directives and the `lint-baseline.txt` ratchet (`poplar lint`, `tests/lint_gate.rs`, CI) |

pub mod allocator;
pub mod autoscale;
pub mod ckpt;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod curves;
pub mod data;
pub mod elastic;
pub mod exp;
pub mod intern;
pub mod lint;
pub mod memmodel;
pub mod metrics;
pub mod netsim;
pub mod pipeline;
pub mod policy;
pub mod profiler;
pub mod runtime;
pub mod spline;
pub mod train;
pub mod zero;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
