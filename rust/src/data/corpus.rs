//! Tiny bundled text corpus + byte-level tokenizer.
//!
//! Stand-in for wikitext-2 (DESIGN.md §2): loss-curve validation needs
//! real-ish token statistics, throughput does not depend on content.
//! The bundled text is public-domain English prose; the tokenizer maps
//! bytes to ids directly (vocab 256) or folds into a smaller vocab.

use super::TokenSource;

/// Public-domain English prose (opening passages of several classics) —
/// enough structure for a small LM to drive its loss down visibly.
pub const CORPUS: &str = r#"
It is a truth universally acknowledged, that a single man in possession
of a good fortune, must be in want of a wife. However little known the
feelings or views of such a man may be on his first entering a
neighbourhood, this truth is so well fixed in the minds of the
surrounding families, that he is considered as the rightful property of
some one or other of their daughters.

Call me Ishmael. Some years ago - never mind how long precisely -
having little or no money in my purse, and nothing particular to
interest me on shore, I thought I would sail about a little and see the
watery part of the world. It is a way I have of driving off the spleen,
and regulating the circulation.

It was the best of times, it was the worst of times, it was the age of
wisdom, it was the age of foolishness, it was the epoch of belief, it
was the epoch of incredulity, it was the season of Light, it was the
season of Darkness, it was the spring of hope, it was the winter of
despair, we had everything before us, we had nothing before us.

In the beginning God created the heaven and the earth. And the earth
was without form, and void; and darkness was upon the face of the deep.
And the Spirit of God moved upon the face of the waters. And God said,
Let there be light: and there was light.

Happy families are all alike; every unhappy family is unhappy in its
own way. Everything was in confusion in the Oblonskys' house. The wife
had discovered that the husband was carrying on an intrigue with a
French girl, who had been a governess in their family, and she had
announced to her husband that she could not go on living in the same
house with him.

A spectre is haunting Europe. All the powers of old Europe have entered
into a holy alliance to exorcise this spectre. Where is the party in
opposition that has not been decried as communistic by its opponents in
power? Where is the opposition that has not hurled back the branding
reproach of communism?

We the People of the United States, in Order to form a more perfect
Union, establish Justice, insure domestic Tranquility, provide for the
common defence, promote the general Welfare, and secure the Blessings
of Liberty to ourselves and our Posterity, do ordain and establish this
Constitution for the United States of America.

Four score and seven years ago our fathers brought forth on this
continent, a new nation, conceived in Liberty, and dedicated to the
proposition that all men are created equal. Now we are engaged in a
great civil war, testing whether that nation, or any nation so
conceived and so dedicated, can long endure.
"#;

/// Byte-level LM token source cycling over the bundled corpus.
#[derive(Debug, Clone)]
pub struct CorpusStream {
    bytes: Vec<u8>,
    pos: usize,
    vocab: u32,
}

impl CorpusStream {
    /// Stream over the bundled corpus folded into `vocab` ids
    /// (`vocab >= 256` keeps bytes unmodified).
    pub fn new(vocab: u32) -> Self {
        assert!(vocab >= 2);
        CorpusStream { bytes: CORPUS.as_bytes().to_vec(), pos: 0, vocab }
    }

    /// Stream over caller-provided text.
    pub fn from_text(text: &str, vocab: u32) -> Self {
        assert!(vocab >= 2);
        assert!(!text.is_empty());
        CorpusStream { bytes: text.as_bytes().to_vec(), pos: 0, vocab }
    }

    /// Corpus length in bytes.
    pub fn len(&self) -> usize {
        self.bytes.len()
    }

    /// Whether the corpus is empty (never for the bundled one).
    pub fn is_empty(&self) -> bool {
        self.bytes.is_empty()
    }

    fn next_id(&mut self) -> i32 {
        let b = self.bytes[self.pos];
        self.pos = (self.pos + 1) % self.bytes.len();
        (b as u32 % self.vocab) as i32
    }
}

impl TokenSource for CorpusStream {
    fn batch(&mut self, batch: usize, seq_plus_1: usize) -> Vec<i32> {
        (0..batch * seq_plus_1).map(|_| self.next_id()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corpus_nonempty_and_ascii() {
        assert!(CORPUS.len() > 2000);
        assert!(CORPUS.is_ascii());
    }

    #[test]
    fn ids_within_vocab() {
        let mut s = CorpusStream::new(128);
        let b = s.batch(2, 33);
        assert_eq!(b.len(), 66);
        assert!(b.iter().all(|&t| (0..128).contains(&t)));
    }

    #[test]
    fn wraps_around() {
        let mut s = CorpusStream::from_text("ab", 256);
        let b = s.batch(1, 5);
        assert_eq!(b, vec![97, 98, 97, 98, 97]);
    }

    #[test]
    fn full_byte_vocab_preserves_bytes() {
        let mut s = CorpusStream::new(256);
        let b = s.batch(1, 4);
        let expect: Vec<i32> = CORPUS.as_bytes()[..4].iter().map(|&x| x as i32).collect();
        assert_eq!(b, expect);
    }
}
