//! Data loading with *dynamic per-rank batch sizes*.
//!
//! The paper modifies the dataloader so each rank draws its own
//! micro-batch size (`b_i`), gradient-accumulation count (`gas`) and
//! last-batch size (`lbs`) while the global batch stays fixed — that is
//! exactly what [`DynamicLoader`] does over a shared token stream.
//!
//! Two sources: a deterministic synthetic LM stream (Zipf-ish token
//! draw) and a tiny bundled text corpus with a byte-level tokenizer
//! (wikitext-2 stand-in; throughput experiments are data-independent).

pub mod corpus;

use crate::allocator::Plan;

/// Deterministic xorshift token stream with a skewed (Zipf-ish)
/// distribution so the LM has learnable structure.
#[derive(Debug, Clone)]
pub struct SyntheticStream {
    state: u64,
    vocab: u32,
}

impl SyntheticStream {
    /// New stream over `vocab` tokens.
    pub fn new(seed: u64, vocab: u32) -> Self {
        SyntheticStream { state: seed.wrapping_mul(0x9E3779B97F4A7C15) | 1, vocab }
    }

    fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Next token id. Skewed: token t has weight ~ 1/(t+16); also
    /// injects a short-range repeat structure a causal LM can learn.
    pub fn next_token(&mut self) -> i32 {
        let r = self.next_u64();
        // repeat previous-ish token 25% of the time for learnable bigrams
        let u = (r >> 40) as f64 / (1u64 << 24) as f64;
        let base = if u < 0.85 {
            // power-law over the first 64 tokens
            let v = (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64;
            ((64.0f64.powf(v) - 1.0) as u32).min(self.vocab - 1)
        } else {
            (r % self.vocab as u64) as u32
        };
        base as i32
    }

    /// Fill a `[batch, seq_plus_1]` token matrix (row-major).
    pub fn fill_batch(&mut self, batch: usize, seq_plus_1: usize) -> Vec<i32> {
        (0..batch * seq_plus_1).map(|_| self.next_token()).collect()
    }
}

/// Token source abstraction for the loader.
pub trait TokenSource: Send {
    /// Produce `batch * seq_plus_1` token ids, row-major.
    fn batch(&mut self, batch: usize, seq_plus_1: usize) -> Vec<i32>;
}

impl TokenSource for SyntheticStream {
    fn batch(&mut self, batch: usize, seq_plus_1: usize) -> Vec<i32> {
        self.fill_batch(batch, seq_plus_1)
    }
}

/// A micro-batch handed to a rank.
#[derive(Debug, Clone)]
pub struct MicroBatch {
    /// Owning rank.
    pub rank: usize,
    /// Micro-step index within the iteration.
    pub step: usize,
    /// Samples in this batch (the plan's `b_i` or `lbs_i`).
    pub batch_size: usize,
    /// Token ids, `[batch_size, seq+1]` row-major.
    pub tokens: Vec<i32>,
}

/// Per-iteration loader that materializes each rank's schedule from a
/// [`Plan`].
pub struct DynamicLoader<S: TokenSource> {
    source: S,
    seq_plus_1: usize,
}

impl<S: TokenSource> DynamicLoader<S> {
    /// Wrap a token source; batches are `[b, seq+1]`.
    pub fn new(source: S, seq: usize) -> Self {
        DynamicLoader { source, seq_plus_1: seq + 1 }
    }

    /// All micro-batches of one iteration, grouped by micro-step then
    /// rank (the BSP order ZeRO-2/3 consume them in).
    pub fn iteration(&mut self, plan: &Plan) -> Vec<MicroBatch> {
        let max_gas = plan.ranks.iter().map(|r| r.grad_accum_steps).max().unwrap_or(0);
        let mut out = Vec::new();
        for step in 0..max_gas {
            for r in &plan.ranks {
                let b = if step + 1 > r.grad_accum_steps {
                    0
                } else if step + 1 == r.grad_accum_steps {
                    r.last_batch
                } else {
                    r.micro_batch
                };
                if b == 0 {
                    continue;
                }
                out.push(MicroBatch {
                    rank: r.rank,
                    step,
                    batch_size: b,
                    tokens: self.source.batch(b, self.seq_plus_1),
                });
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::allocator::RankPlan;

    fn plan2() -> Plan {
        Plan {
            stage: 1,
            gbs: 10,
            ranks: vec![
                RankPlan { rank: 0, micro_batch: 3, samples_per_iter: 7,
                           grad_accum_steps: 3, last_batch: 1 },
                RankPlan { rank: 1, micro_batch: 2, samples_per_iter: 3,
                           grad_accum_steps: 2, last_batch: 1 },
            ],
            predicted_iter_s: 0.0,
            strategy: "test".into(),
        }
    }

    #[test]
    fn loader_covers_plan_exactly() {
        let mut dl = DynamicLoader::new(SyntheticStream::new(1, 100), 8);
        let mbs = dl.iteration(&plan2());
        let total: usize = mbs.iter().map(|m| m.batch_size).sum();
        assert_eq!(total, 10);
        let r0: usize = mbs.iter().filter(|m| m.rank == 0).map(|m| m.batch_size).sum();
        assert_eq!(r0, 7);
        for m in &mbs {
            assert_eq!(m.tokens.len(), m.batch_size * 9);
        }
    }

    #[test]
    fn last_step_uses_lbs() {
        let mut dl = DynamicLoader::new(SyntheticStream::new(1, 100), 8);
        let mbs = dl.iteration(&plan2());
        let last_r0 = mbs.iter().filter(|m| m.rank == 0).last().unwrap();
        assert_eq!(last_r0.batch_size, 1);
        assert_eq!(last_r0.step, 2);
    }

    #[test]
    fn stream_is_deterministic_and_in_range() {
        let mut a = SyntheticStream::new(9, 50);
        let mut b = SyntheticStream::new(9, 50);
        for _ in 0..1000 {
            let (x, y) = (a.next_token(), b.next_token());
            assert_eq!(x, y);
            assert!((0..50).contains(&x));
        }
    }

    #[test]
    fn stream_is_skewed() {
        let mut s = SyntheticStream::new(3, 1000);
        let n = 20_000;
        let low = (0..n).map(|_| s.next_token()).filter(|&t| t < 64).count();
        assert!(low as f64 / n as f64 > 0.5, "power-law head should dominate");
    }
}
