//! Fig. 5: scaling across GPU *quantities* — the heterogeneity of number.
//!
//! Cluster-C GPU types at ratios V4 (V100S only), A4 (A800 only), then
//! A800:V100S of 4:1, 4:2, 4:3, 4:4, 3:4, 2:4, 1:4 — all ZeRO stages,
//! Poplar allocation.
//!
//! Expected shape (paper): performance grows with GPU count; removing an
//! A800 hurts much more than removing a V100S; and in ZeRO-3 the V4A4
//! group can score *below* V4A3 (added communication outweighs the extra
//! compute — the appendix's observation).

use anyhow::Result;

use super::{eval_system, gbs_samples};
use crate::cluster::cluster_c_counts;
use crate::config::model::require;
use crate::config::Strategy;
use crate::metrics::Table;

/// The figure's groups as `(label, n_a800, n_v100s)`.
pub const GROUPS: &[(&str, usize, usize)] = &[
    ("V4", 0, 4),
    ("A4", 4, 0),
    ("A4V1", 4, 1),
    ("A4V2", 4, 2),
    ("A4V3", 4, 3),
    ("A4V4", 4, 4),
    ("A3V4", 3, 4),
    ("A2V4", 2, 4),
    ("A1V4", 1, 4),
];

/// TFLOPs of one group at one stage.
pub fn cell(label: &str, n_a: usize, n_v: usize, stage: u8) -> Result<f64> {
    let model = require("llama-0.5b")?;
    let gbs = gbs_samples(&model);
    let cluster = cluster_c_counts(n_a, n_v);
    let r = eval_system(&cluster, &model, stage, Strategy::Poplar, gbs,
                        3000 + label.len() as u64 + stage as u64)?;
    Ok(r.tflops)
}

/// Run the full figure.
pub fn run() -> Result<Table> {
    let mut table = Table::new(&["group", "a800", "v100s", "stage", "tflops"]);
    for &(label, n_a, n_v) in GROUPS {
        for stage in 0..4u8 {
            let tflops = cell(label, n_a, n_v, stage)?;
            table.row(&[
                label.to_string(),
                n_a.to_string(),
                n_v.to_string(),
                format!("ZeRO-{stage}"),
                format!("{tflops:.1}"),
            ]);
        }
    }
    Ok(table)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn more_gpus_more_tflops_zero1() {
        let t_41 = cell("A4V1", 4, 1, 1).unwrap();
        let t_44 = cell("A4V4", 4, 4, 1).unwrap();
        assert!(t_44 > t_41, "{t_44} vs {t_41}");
    }

    #[test]
    fn a800_matters_more_than_v100s() {
        // dropping an A800 (4:4 -> 3:4) costs more than dropping a
        // V100S (4:4 -> 4:3)
        let base = cell("A4V4", 4, 4, 1).unwrap();
        let drop_a = cell("A3V4", 3, 4, 1).unwrap();
        let drop_v = cell("A4V3", 4, 3, 1).unwrap();
        assert!(
            base - drop_a > base - drop_v,
            "dropping A800 ({:.1}) should cost more than dropping V100S ({:.1})",
            base - drop_a,
            base - drop_v
        );
    }

    #[test]
    fn homogeneous_ends_ordered() {
        // four A800 out-compute four V100S
        assert!(cell("A4", 4, 0, 1).unwrap() > cell("V4", 0, 4, 1).unwrap());
    }

    #[test]
    fn full_grid_completes() {
        let t = run().unwrap();
        assert_eq!(t.len(), GROUPS.len() * 4);
    }
}
