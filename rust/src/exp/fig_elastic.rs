//! Elasticity experiment: throughput recovery after mid-training events.
//!
//! Cluster C (4× A800-80G + 4× V100S-32G), llama-0.5b, ZeRO-1, the
//! paper's 2M-token global batch. Two scenarios against the noise-free
//! ground-truth oracle:
//!
//! * **lost-v100s** — rank 7 (a V100S) is preempted. The *static* scheme
//!   keeps the old per-rank schedules and spreads the lost rank's
//!   samples uniformly over the survivors (what a curve-oblivious
//!   restart does); *replan* re-runs Algorithm 2 over the surviving
//!   curves ([`allocator::replan`]).
//! * **slowed-a800x2** — rank 0 (an A800) silently halves its speed.
//!   *static* keeps the stale plan; *replan* re-fits the straggler's
//!   curve (what drift-aware re-profiling measures) and re-allocates.
//!
//! Expected shape: static recovery collapses to ≈ `n_old/(n_old+1)` of
//! pre-event throughput (the naive redistribution bottlenecks the
//! slowest survivors), while Poplar re-allocation recovers ≥ 90% after
//! the loss — the cluster only lost ~7% of its aggregate speed, and the
//! re-planner re-balances to exactly that.
//!
//! The `reshard_s` / `recompute_s` columns price the one-shot
//! optimizer-state recovery: the measured minimal shard movement
//! (checkpointed shards + survivor overlap, `ckpt::reshard`) vs the
//! full-restore rebuild a checkpoint-oblivious restart pays — reshard is
//! strictly cheaper whenever anything survives.

use anyhow::{anyhow, Result};

use super::gbs_samples;
use crate::allocator::{self, schedule, Plan, RankPlan};
use crate::ckpt::{reshard, ReshardPlan, ShardManifest};
use crate::cluster::{catalog, GpuSpec, LinkKind};
use crate::config::model::{preset, ModelSpec};
use crate::curves::{PerfCurve, ProfiledPoint};
use crate::metrics::Table;
use crate::netsim::NetSim;
use crate::zero::{simulate_iteration, DeviceOracle, DriftOracle};

/// The slot lost in the preemption scenario (a V100S).
pub const LOST_SLOT: usize = 7;
/// The straggler slot and its slowdown factor.
pub const SLOW_SLOT: usize = 0;
/// Compute-time multiplier of the straggler scenario.
pub const SLOW_FACTOR: f64 = 2.0;

fn truth_curve(spec: &GpuSpec, model: &ModelSpec, mbs: usize, factor: f64) -> Result<PerfCurve> {
    let pts: Vec<ProfiledPoint> = (1..=mbs)
        .map(|b| ProfiledPoint {
            batch: b,
            step_time_s: factor
                * spec.compute_time(
                    (b as u64 * model.seq) as f64,
                    model.flops_per_token(),
                    model.n_layers as usize,
                ),
        })
        .collect();
    PerfCurve::fit(pts, mbs).map_err(|e| anyhow!("curve: {e}"))
}

/// The experiment cluster: 4× A800 (mbs 48) + 4× V100S (mbs 16).
fn cluster() -> Vec<(GpuSpec, usize)> {
    let mut out = Vec::new();
    for _ in 0..4 {
        out.push((catalog::spec_or_panic("A800-80G"), 48));
    }
    for _ in 0..4 {
        out.push((catalog::spec_or_panic("V100S-32G"), 16));
    }
    out
}

/// Static (curve-oblivious) recovery: survivors keep their schedules,
/// the lost rank's samples are spread uniformly round-robin.
fn static_after_loss(pre: &Plan, lost: usize) -> Plan {
    let lost_samples = pre.ranks[lost].samples_per_iter;
    let mut ranks: Vec<RankPlan> = pre
        .ranks
        .iter()
        .enumerate()
        .filter(|(i, _)| *i != lost)
        .map(|(_, r)| r.clone())
        .collect();
    let n = ranks.len();
    let share = lost_samples / n;
    let rem = lost_samples % n;
    for (i, r) in ranks.iter_mut().enumerate() {
        let extra = share + usize::from(i < rem);
        *r = schedule(i, r.samples_per_iter + extra, r.micro_batch);
    }
    Plan {
        stage: pre.stage,
        gbs: pre.gbs,
        ranks,
        predicted_iter_s: 0.0,
        strategy: "static".into(),
    }
}

/// One scenario cell: simulated steady-state TFLOPs plus the one-shot
/// optimizer-state recovery cost of getting there.
#[derive(Debug, Clone)]
pub struct ElasticCell {
    /// Scenario label.
    pub scenario: String,
    /// Scheme label (`static` / `replan`).
    pub scheme: String,
    /// Live rank count.
    pub ranks: usize,
    /// Steady-state cluster TFLOP/s.
    pub tflops: f64,
    /// Fraction of pre-event throughput retained.
    pub recovery: f64,
    /// Measured minimal shard-movement cost (bytes-moved derived):
    /// survivors keep their overlap, lost shards restore from the
    /// checkpoint. Zero when membership did not change.
    pub reshard_s: f64,
    /// Recompute baseline: every rank refetches its entire optimizer
    /// shard (what a checkpoint-oblivious restart pays).
    pub recompute_s: f64,
}

/// Compute all cells (pre-event baseline first).
pub fn cells() -> Result<Vec<ElasticCell>> {
    let model = preset("llama-0.5b").ok_or_else(|| anyhow!("missing preset"))?;
    let gbs = gbs_samples(&model);
    let stage = 1u8;
    let devices = cluster();
    let n = devices.len();

    let curves: Vec<PerfCurve> = devices
        .iter()
        .map(|(spec, mbs)| truth_curve(spec, &model, *mbs, 1.0))
        .collect::<Result<_>>()?;
    let specs: Vec<GpuSpec> = devices.iter().map(|(s, _)| s.clone()).collect();
    let net = NetSim::from_link(n, LinkKind::Ib);

    // pre-event baseline
    let pre_plan = allocator::plan(&curves, stage, gbs, &net, model.param_count())
        .map_err(|e| anyhow!("pre plan: {e}"))?;
    let oracle = DeviceOracle { specs: specs.clone(), model: &model };
    let pre = simulate_iteration(&pre_plan, &oracle, &net, &model)
        .map_err(|e| anyhow!("pre sim: {e}"))?;
    let mut out = vec![ElasticCell {
        scenario: "pre-event".into(),
        scheme: "poplar".into(),
        ranks: n,
        tflops: pre.tflops,
        recovery: 1.0,
        reshard_s: 0.0,
        recompute_s: 0.0,
    }];

    // --- scenario 1: RankLost (slot 7, V100S) --------------------------
    let surv_curves: Vec<PerfCurve> = curves
        .iter()
        .enumerate()
        .filter(|(i, _)| *i != LOST_SLOT)
        .map(|(_, c)| c.clone())
        .collect();
    let surv_specs: Vec<GpuSpec> = specs
        .iter()
        .enumerate()
        .filter(|(i, _)| *i != LOST_SLOT)
        .map(|(_, s)| s.clone())
        .collect();
    let net7 = NetSim::from_link(n - 1, LinkKind::Ib);
    let surv_oracle = DeviceOracle { specs: surv_specs, model: &model };

    // optimizer-state recovery cost after the loss: the measured minimal
    // shard movement (checkpointed shards, survivors keep their overlap)
    // vs the full-restore recompute a checkpoint-oblivious restart pays
    let all_slots: Vec<(usize, crate::intern::TypeId)> = devices
        .iter()
        .enumerate()
        .map(|(i, (s, _))| (i, crate::intern::intern(&s.name)))
        .collect();
    let surv_slots: Vec<(usize, crate::intern::TypeId)> = all_slots
        .iter()
        .filter(|(i, _)| *i != LOST_SLOT)
        .cloned()
        .collect();
    let pre_manifest =
        ShardManifest::build(&model.name, stage, model.param_count(), 0, &all_slots)
            .map_err(|e| anyhow!("manifest: {e}"))?;
    let post_manifest =
        ShardManifest::build(&model.name, stage, model.param_count(), 1, &surv_slots)
            .map_err(|e| anyhow!("manifest: {e}"))?;
    let moves = reshard(&pre_manifest, &post_manifest).map_err(|e| anyhow!("reshard: {e}"))?;
    let reshard_s = moves.transfer_time_s(&net7);
    let recompute_s = ReshardPlan::full_restore(&post_manifest).transfer_time_s(&net7);

    let static_plan = static_after_loss(&pre_plan, LOST_SLOT);
    static_plan.validate().map_err(|e| anyhow!("static plan: {e}"))?;
    let r = simulate_iteration(&static_plan, &surv_oracle, &net7, &model)
        .map_err(|e| anyhow!("static sim: {e}"))?;
    out.push(ElasticCell {
        scenario: "lost-v100s".into(),
        scheme: "static".into(),
        ranks: n - 1,
        tflops: r.tflops,
        recovery: r.tflops / pre.tflops,
        // a curve-oblivious restart is also checkpoint-oblivious: it
        // pays the full state rebuild
        reshard_s: recompute_s,
        recompute_s,
    });

    let replan = allocator::replan(&pre_plan, &surv_curves, &net7, model.param_count())
        .map_err(|e| anyhow!("replan: {e}"))?;
    replan.validate().map_err(|e| anyhow!("replan: {e}"))?;
    let r = simulate_iteration(&replan, &surv_oracle, &net7, &model)
        .map_err(|e| anyhow!("replan sim: {e}"))?;
    out.push(ElasticCell {
        scenario: "lost-v100s".into(),
        scheme: "replan".into(),
        ranks: n - 1,
        tflops: r.tflops,
        recovery: r.tflops / pre.tflops,
        reshard_s,
        recompute_s,
    });

    // --- scenario 2: RankSlowed (slot 0, A800, ×2) ---------------------
    let slowed_oracle = DriftOracle::healthy(
        DeviceOracle { specs: specs.clone(), model: &model },
        n,
    )
    .slow(SLOW_SLOT, SLOW_FACTOR);

    let r = simulate_iteration(&pre_plan, &slowed_oracle, &net, &model)
        .map_err(|e| anyhow!("slowed sim: {e}"))?;
    out.push(ElasticCell {
        scenario: "slowed-a800x2".into(),
        scheme: "static".into(),
        ranks: n,
        tflops: r.tflops,
        recovery: r.tflops / pre.tflops,
        // membership unchanged: the shard layout does not move
        reshard_s: 0.0,
        recompute_s: 0.0,
    });

    // drift-aware: the straggler's curve is re-measured (×factor) and
    // Algorithm 2 re-balances around it
    let mut drift_curves = curves.clone();
    drift_curves[SLOW_SLOT] =
        truth_curve(&devices[SLOW_SLOT].0, &model, devices[SLOW_SLOT].1, SLOW_FACTOR)?;
    let replan = allocator::replan(&pre_plan, &drift_curves, &net, model.param_count())
        .map_err(|e| anyhow!("drift replan: {e}"))?;
    replan.validate().map_err(|e| anyhow!("drift replan: {e}"))?;
    let r = simulate_iteration(&replan, &slowed_oracle, &net, &model)
        .map_err(|e| anyhow!("drift sim: {e}"))?;
    out.push(ElasticCell {
        scenario: "slowed-a800x2".into(),
        scheme: "replan".into(),
        ranks: n,
        tflops: r.tflops,
        recovery: r.tflops / pre.tflops,
        reshard_s: 0.0,
        recompute_s: 0.0,
    });

    Ok(out)
}

/// Run the full figure.
pub fn run() -> Result<Table> {
    let mut table = Table::new(&[
        "scenario", "scheme", "ranks", "tflops", "recovery", "reshard_s", "recompute_s",
    ]);
    for c in cells()? {
        table.row(&[
            c.scenario,
            c.scheme,
            c.ranks.to_string(),
            format!("{:.1}", c.tflops),
            format!("{:.3}", c.recovery),
            format!("{:.3}", c.reshard_s),
            format!("{:.3}", c.recompute_s),
        ]);
    }
    Ok(table)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cell<'a>(cs: &'a [ElasticCell], scenario: &str, scheme: &str) -> &'a ElasticCell {
        cs.iter()
            .find(|c| c.scenario == scenario && c.scheme == scheme)
            .unwrap()
    }

    #[test]
    fn replan_recovers_90_percent_after_rank_lost() {
        let cs = cells().unwrap();
        let replan = cell(&cs, "lost-v100s", "replan");
        let stat = cell(&cs, "lost-v100s", "static");
        assert!(
            replan.recovery >= 0.90,
            "re-allocation must recover >= 90%: got {:.3}",
            replan.recovery
        );
        assert!(
            stat.recovery < 0.90,
            "static plan must not reach 90%: got {:.3}",
            stat.recovery
        );
        assert!(replan.recovery > stat.recovery + 0.02);
    }

    #[test]
    fn replan_beats_static_under_straggler() {
        let cs = cells().unwrap();
        let replan = cell(&cs, "slowed-a800x2", "replan");
        let stat = cell(&cs, "slowed-a800x2", "static");
        assert!(
            replan.recovery > stat.recovery + 0.05,
            "rebalancing must clearly beat the stale plan: {:.3} vs {:.3}",
            replan.recovery,
            stat.recovery
        );
    }

    #[test]
    fn reshard_strictly_cheaper_than_recompute_after_rank_lost() {
        // the acceptance bar: recovery uses the measured bytes-moved
        // reshard cost, and it strictly beats a full state rebuild
        let cs = cells().unwrap();
        let replan = cell(&cs, "lost-v100s", "replan");
        assert!(replan.reshard_s > 0.0, "a loss must move some state");
        assert!(
            replan.reshard_s < replan.recompute_s,
            "reshard {:.3}s must beat recompute {:.3}s",
            replan.reshard_s,
            replan.recompute_s
        );
        // the static scheme pays the full rebuild
        let stat = cell(&cs, "lost-v100s", "static");
        assert_eq!(stat.reshard_s, stat.recompute_s);
        // no membership change -> no state movement
        let slowed = cell(&cs, "slowed-a800x2", "replan");
        assert_eq!(slowed.reshard_s, 0.0);
    }

    #[test]
    fn figure_is_deterministic_and_complete() {
        let a = run().unwrap().to_markdown();
        let b = run().unwrap().to_markdown();
        assert_eq!(a, b);
        assert_eq!(run().unwrap().len(), 5);
    }
}
