//! Fig. 1 (motivation): without load balancing, powerful GPUs finish
//! first and idle at the synchronization point.
//!
//! Reproduces the figure's story on cluster C: uniform (heterogeneity-
//! unaware) allocation vs Poplar, per-rank busy/idle seconds.

use anyhow::Result;

use super::{gbs_samples, plan_with, profile, score, NOISE_SIGMA};
use crate::cluster;
use crate::config::model::require;
use crate::config::Strategy;
use crate::metrics::Table;
use crate::netsim::NetSim;

/// Run the experiment.
pub fn run() -> Result<Table> {
    let cluster = cluster::cluster_c();
    let model = require("llama-0.5b")?;
    let gbs = gbs_samples(&model);
    let net = NetSim::from_cluster(&cluster);

    let prof = profile(&cluster, &model, 1, NOISE_SIGMA, 1)?;
    let mut table = Table::new(&["system", "rank", "gpu", "busy_s", "idle_s", "idle_frac"]);
    for strategy in [Strategy::Uniform, Strategy::Poplar] {
        let plan = plan_with(&prof, strategy, gbs, &net, &model)?;
        let rep = score(&cluster, &model, &plan)?;
        let insts = cluster.instances();
        for r in &rep.ranks {
            let total = r.busy_s + r.idle_s;
            table.row(&[
                strategy.name().to_string(),
                r.rank.to_string(),
                insts[r.rank].spec.name.clone(),
                format!("{:.3}", r.busy_s),
                format!("{:.3}", r.idle_s),
                format!("{:.3}", if total > 0.0 { r.idle_s / total } else { 0.0 }),
            ]);
        }
    }
    Ok(table)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_idles_fast_ranks_poplar_does_not() {
        let t = run().unwrap();
        let csv = t.to_csv();
        let rows: Vec<Vec<&str>> = csv.lines().skip(1).map(|l| l.split(',').collect()).collect();
        assert_eq!(rows.len(), 16);
        // uniform: A800 ranks (0-3) idle noticeably
        let uni_a800_idle: f64 = rows[0][4].parse().unwrap();
        assert!(uni_a800_idle > 0.0);
        // poplar: every rank's idle fraction is small
        for r in rows.iter().filter(|r| r[0] == "poplar") {
            let frac: f64 = r[5].parse().unwrap();
            assert!(frac < 0.12, "poplar idle frac {frac} too high: {r:?}");
        }
        // headline: uniform's worst idle fraction dwarfs poplar's
        let worst = |sys: &str| -> f64 {
            rows.iter()
                .filter(|r| r[0] == sys)
                .map(|r| r[5].parse::<f64>().unwrap())
                .fold(0.0, f64::max)
        };
        assert!(worst("uniform") > 2.0 * worst("poplar"));
    }
}
