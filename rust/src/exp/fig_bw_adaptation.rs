//! Bandwidth-adaptation experiment: the measured fabric drives replans.
//!
//! 2× A800-80G + 2× V100S-32G, llama-0.5b, the paper's 2M-token global
//! batch, a 2 GB/s socket fabric — the regime where the ZeRO stage
//! choice hinges on collective cost. One [`BwMonitor`] lives through
//! three fabric phases, and the *same* decision (a `V100S-32G` joins a
//! job pinned at ZeRO-3, warm stage cache, fixed horizon) is re-taken at
//! each phase's measured bandwidth:
//!
//! * **spec** — the monitor has converged on the spec sheet. The
//!   de-escalation migration is cheap at full bandwidth, so the stage
//!   search leaves the pinned stage.
//! * **congested** — sustained samples at [`CONGESTION_FACTOR`] × spec
//!   drive Steady → Degrade and the estimate snaps down. The *same*
//!   migration now moves its optimizer-state bytes over a 5×-slower
//!   fabric: the stall no longer amortizes inside the horizon and the
//!   search stays put — congestion flips the decision.
//! * **recovered** — spec-level samples drive Degrade → Probe → Steady
//!   and the estimate climbs back; the original decision returns.
//!
//! The horizon is *self-calibrated*: the smallest value in [`HORIZONS`]
//! that separates the spec and congested decisions (loud error if none
//! does — that would mean the migration stall never dominates and the
//! experiment's premise is broken). One row per candidate stage per
//! phase; `chosen` marks the stage the replan actually selected.

use anyhow::{anyhow, Result};

use super::gbs_samples;
use crate::cluster::LinkKind;
use crate::config::model::{preset, ModelSpec};
use crate::curves::PerfCurve;
use crate::elastic::{ElasticPlanner, StageCandidate, StagePolicy};
use crate::metrics::Table;
use crate::netsim::monitor::{BW_TOLERANCE, STARTUP_SAMPLES};
use crate::netsim::{BwMonitor, BwState};

/// The fleet every phase decides over.
pub const FLEET: &[&str] = &["A800-80G", "A800-80G", "V100S-32G", "V100S-32G"];
/// The GPU type whose join triggers the stage re-decision.
pub const JOINER: &str = "V100S-32G";
/// Stage the job is pinned at before the event.
pub const PINNED_STAGE: u8 = 3;
/// The monitored bottleneck link.
pub const LINK: LinkKind = LinkKind::Socket;
/// Ground-truth bandwidth multiplier of the congested phase (≤ 0.25 per
/// the acceptance bar: a sustained shift this deep must flip a replan).
pub const CONGESTION_FACTOR: f64 = 0.2;
/// Candidate amortization horizons (seconds) for the self-calibration.
pub const HORIZONS: &[f64] =
    &[0.5, 1.0, 2.0, 3.0, 4.0, 6.0, 8.0, 12.0, 15.0, 20.0, 30.0, 45.0, 60.0, 120.0, 300.0];

/// Ground-truth curve for `gpu` at `(model, stage, n)` — the noise-free
/// oracle the autoscale synthesizer shares with the simulator.
fn truth_curve(gpu: &str, model: &ModelSpec, stage: u8, n: usize) -> Option<PerfCurve> {
    crate::autoscale::synthesize_curve(gpu, model, stage, n).ok()
}

/// One fabric phase's outcome: what the monitor believed and what the
/// stage search decided at that belief.
#[derive(Debug, Clone)]
pub struct BwPhase {
    /// Phase label (`spec` / `congested` / `recovered`).
    pub label: String,
    /// Monitor state at decision time.
    pub state: BwState,
    /// Monitor bandwidth estimate at decision time (GB/s).
    pub est_gbs: f64,
    /// Stage the post-join replan chose.
    pub chosen: u8,
    /// All candidates as the search scored them (stage order).
    pub candidates: Vec<StageCandidate>,
}

/// The whole experiment: three phases at one calibrated horizon.
#[derive(Debug, Clone)]
pub struct Adaptation {
    /// The self-calibrated amortization horizon (seconds).
    pub horizon_s: f64,
    /// Smallest `BwShift::factor` the monitor signalled while driving
    /// the phases — the congestion depth a replan was triggered at.
    pub min_signalled_factor: f64,
    /// `spec`, `congested`, `recovered` — in that order.
    pub phases: Vec<BwPhase>,
}

/// Fresh planner pinned at [`PINNED_STAGE`] with a warm stage cache:
/// every `(type, stage)` curve at the post-join group size is measured,
/// so migration cost — not profiling cost — decides.
fn planner(model: &ModelSpec, gbs: usize) -> Result<ElasticPlanner> {
    let mut p = ElasticPlanner::new(PINNED_STAGE, gbs, &model.name, model.param_count(), 32);
    for gpu in FLEET {
        let slot = p.add_slot(gpu);
        if p.slots()[slot].curve.is_none() {
            let c = truth_curve(gpu, model, PINNED_STAGE, FLEET.len())
                .ok_or_else(|| anyhow!("{gpu} must fit at ZeRO-{PINNED_STAGE}"))?;
            p.install_curve(slot, c, false).map_err(|e| anyhow!("install: {e}"))?;
        }
    }
    let n_after = FLEET.len() + 1;
    for stage in 0..=3u8 {
        for gpu in ["A800-80G", "V100S-32G"] {
            if let Some(c) = truth_curve(gpu, model, stage, n_after) {
                p.install_stage_curve(gpu, stage, c).map_err(|e| anyhow!("seed: {e}"))?;
            }
        }
    }
    Ok(p)
}

/// Re-take the join decision at one fabric belief: plan at the monitor's
/// estimate, admit the joiner, run the stage search, replan.
fn decide(fabric: &BwMonitor, horizon_s: f64) -> Result<(u8, Vec<StageCandidate>)> {
    let model = preset("llama-0.5b").ok_or_else(|| anyhow!("missing preset"))?;
    let gbs = gbs_samples(&model);
    let mut p = planner(&model, gbs)?;
    p.replan(&fabric.snapshot(FLEET.len())).map_err(|e| anyhow!("initial plan: {e}"))?;
    p.set_stage_policy(Some(StagePolicy { horizon_s }));
    p.add_slot(JOINER);
    let net_after = fabric.snapshot(FLEET.len() + 1);
    let candidates =
        p.stage_candidates(&net_after).map_err(|e| anyhow!("candidates: {e}"))?;
    p.replan(&net_after).map_err(|e| anyhow!("post-event replan: {e}"))?;
    Ok((p.stage(), candidates))
}

/// Drive one monitor through the three phases and re-take the decision
/// at each, with the horizon self-calibrated (see module docs).
pub fn run_phases() -> Result<Adaptation> {
    let mut m = BwMonitor::new(LINK);
    let spec = m.spec_gbs();
    let mut min_factor = 1.0f64;

    // phase 1 — converge on the spec sheet
    for _ in 0..STARTUP_SAMPLES {
        if let Some(s) = m.observe(spec) {
            min_factor = min_factor.min(s.factor);
        }
    }
    if m.state() != BwState::Steady {
        return Err(anyhow!("monitor not steady after startup: {:?}", m.state()));
    }
    let spec_m = m.clone();

    // phase 2 — sustained congestion until the machine degrades
    let mut guard = 0;
    while m.state() != BwState::Degrade {
        if let Some(s) = m.observe(spec * CONGESTION_FACTOR) {
            min_factor = min_factor.min(s.factor);
        }
        guard += 1;
        if guard > 10 {
            return Err(anyhow!("monitor never degraded under sustained congestion"));
        }
    }
    let congested_m = m.clone();

    // phase 3 — spec-level samples until the probe climbs back
    let mut guard = 0;
    while m.state() != BwState::Steady || m.estimate_gbs() < spec * (1.0 - BW_TOLERANCE) {
        if let Some(s) = m.observe(spec) {
            min_factor = min_factor.min(s.factor);
        }
        guard += 1;
        if guard > 30 {
            return Err(anyhow!("monitor never recovered toward spec"));
        }
    }
    let recovered_m = m;

    // calibrate: the smallest horizon where full bandwidth migrates but
    // congested bandwidth makes the same migration a bad trade
    let mut horizon = None;
    for &h in HORIZONS {
        let (at_spec, _) = decide(&spec_m, h)?;
        let (at_congestion, _) = decide(&congested_m, h)?;
        if at_spec != PINNED_STAGE && at_congestion == PINNED_STAGE {
            horizon = Some(h);
            break;
        }
    }
    let horizon_s = horizon.ok_or_else(|| {
        anyhow!(
            "no horizon in {HORIZONS:?} separates the spec and congested decisions — \
             the congested migration stall never dominates; the experiment's \
             fabric/model constants need retuning"
        )
    })?;

    let mut phases = Vec::new();
    for (label, mon) in
        [("spec", &spec_m), ("congested", &congested_m), ("recovered", &recovered_m)]
    {
        let (chosen, candidates) = decide(mon, horizon_s)?;
        phases.push(BwPhase {
            label: label.to_string(),
            state: mon.state(),
            est_gbs: mon.estimate_gbs(),
            chosen,
            candidates,
        });
    }
    Ok(Adaptation { horizon_s, min_signalled_factor: min_factor, phases })
}

/// Run the full figure.
pub fn run() -> Result<Table> {
    let a = run_phases()?;
    let mut table = Table::new(&[
        "phase",
        "event",
        "bw_state",
        "bw_est_gbs",
        "stage",
        "feasible",
        "rate_sps",
        "migration_s",
        "score_sps",
        "chosen",
    ]);
    for ph in &a.phases {
        for c in &ph.candidates {
            table.row(&[
                ph.label.clone(),
                format!("join({JOINER}) h={:.1}s", a.horizon_s),
                ph.state.name().to_string(),
                format!("{:.2}", ph.est_gbs),
                format!("{}{}", c.stage, if c.current { "*" } else { "" }),
                if c.feasible { "yes".into() } else { "-".into() },
                format!("{:.1}", c.rate_sps),
                format!("{:.3}", c.migration_s),
                format!("{:.1}", c.score),
                if c.stage == ph.chosen { "yes".into() } else { "-".into() },
            ]);
        }
    }
    Ok(table)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn congestion_flips_the_decision_and_recovery_restores_it() {
        // the acceptance bar, both directions: a ≤ 0.25× sustained shift
        // changes the chosen action vs the spec-bandwidth plan, and the
        // recovery probe restores the original one
        let a = run_phases().unwrap();
        let (spec, congested, recovered) = (&a.phases[0], &a.phases[1], &a.phases[2]);
        assert_ne!(spec.chosen, PINNED_STAGE, "at spec bandwidth the migration must pay");
        assert_eq!(
            congested.chosen, PINNED_STAGE,
            "mid-congestion the same migration must be vetoed"
        );
        assert_eq!(recovered.chosen, spec.chosen, "recovery must restore the plan");
        assert!(
            a.min_signalled_factor <= 0.25,
            "the replan-triggering shift must be ≤ 0.25×spec, got {}",
            a.min_signalled_factor
        );
        // the flip is priced, not hard-coded: the congested migration
        // stall is a multiple of the spec-bandwidth one
        let mig = |ph: &BwPhase| {
            ph.candidates.iter().find(|c| c.stage == spec.chosen).unwrap().migration_s
        };
        assert!(
            mig(congested) > 2.0 * mig(spec),
            "congestion must inflate the migration stall: {} vs {}",
            mig(spec),
            mig(congested)
        );
    }

    #[test]
    fn estimates_track_the_phases_within_bounds() {
        let a = run_phases().unwrap();
        let spec = LINK.bandwidth_gbs();
        let (p1, p2, p3) = (&a.phases[0], &a.phases[1], &a.phases[2]);
        assert_eq!(p1.state, BwState::Steady);
        assert!((p1.est_gbs - spec).abs() < 1e-9, "noise-free startup stays at spec");
        assert_eq!(p2.state, BwState::Degrade);
        assert!(
            (p2.est_gbs - spec * CONGESTION_FACTOR).abs() < 1e-9,
            "degrade snaps to the observed level, got {}",
            p2.est_gbs
        );
        assert_eq!(p3.state, BwState::Steady);
        assert!(p3.est_gbs > spec * (1.0 - BW_TOLERANCE) && p3.est_gbs <= spec);
    }

    #[test]
    fn figure_is_deterministic_and_complete() {
        let a = run().unwrap().to_markdown();
        let b = run().unwrap().to_markdown();
        assert_eq!(a, b);
        // three phases x four candidate stages
        assert_eq!(run().unwrap().len(), 12);
    }
}
