//! Table 2 (appendix): time overhead of Poplar's preliminary phase —
//! Online Profiling seconds per ZeRO stage on T4, V100 and A800.
//!
//! The simulated probe time is the sum of every `model.step` the
//! profiler executed (exponential probe + binary search), which is the
//! quantity the paper reports. Offline analyzing is also timed (real
//! rust wall time — it is pure numeric work).

use anyhow::Result;

use super::{gbs_samples, plan_with, profile, NOISE_SIGMA};
use crate::cluster::{ClusterSpec, LinkKind};
use crate::config::model::require;
use crate::config::Strategy;
use crate::metrics::{Table, Timer};
use crate::netsim::NetSim;

/// GPUs of the table.
pub const GPUS: &[&str] = &["T4", "V100-16G", "A800-80G"];

/// Run the overhead measurement.
pub fn run() -> Result<Table> {
    let model = require("llama-0.5b")?;
    let mut table = Table::new(&["stage", "gpu", "profile_steps", "online_profile_s",
                                 "offline_analyze_s"]);
    for stage in 0..4u8 {
        for gpu in GPUS {
            // profile within an 8-rank job (as in the paper's clusters):
            // the ZeRO stage then changes the per-rank memory layout, so
            // mbs — and with it the probe path — differs per stage
            let cluster =
                ClusterSpec::new("x8", &[(gpu, 8, LinkKind::Pcie)], LinkKind::Ib);
            let prof = profile(&cluster, &model, stage, NOISE_SIGMA, 99)?;
            if prof.stage != stage {
                // stage escalated (model didn't fit) — report the stage used
                continue;
            }
            let r = &prof.ranks[0];
            let t = Timer::start();
            let net = NetSim::from_cluster(&cluster);
            let _plan = plan_with(&prof, Strategy::Poplar, gbs_samples(&model), &net, &model)?;
            let offline = t.elapsed_s();
            table.row(&[
                format!("ZeRO-{stage}"),
                gpu.to_string(),
                r.probe_steps.to_string(),
                format!("{:.1}", r.probe_time_s),
                format!("{offline:.4}"),
            ]);
        }
    }
    Ok(table)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn overheads_have_paper_shape() {
        let t = run().unwrap();
        let rows: Vec<Vec<String>> = t
            .to_csv()
            .lines()
            .skip(1)
            .map(|l| l.split(',').map(str::to_string).collect())
            .collect();
        assert!(!rows.is_empty());
        // T4 profiling takes longer than A800's at the same stage
        // (paper Table 2: 67s vs ... the weak GPU is slower per probe)
        let get = |stage: &str, gpu: &str| -> Option<f64> {
            rows.iter()
                .find(|r| r[0] == stage && r[1] == gpu)
                .map(|r| r[3].parse().unwrap())
        };
        if let (Some(t4), Some(a800)) = (get("ZeRO-1", "T4"), get("ZeRO-1", "A800-80G")) {
            assert!(t4 > 0.0 && a800 > 0.0);
        }
        // offline analyzing is orders of magnitude cheaper than online
        for r in &rows {
            let online: f64 = r[3].parse().unwrap();
            let offline: f64 = r[4].parse().unwrap();
            assert!(offline < online.max(0.5), "offline {offline} vs online {online}");
        }
    }

    #[test]
    fn probe_steps_logarithmic() {
        let t = run().unwrap();
        for line in t.to_csv().lines().skip(1) {
            let steps: usize = line.split(',').nth(2).unwrap().parse().unwrap();
            assert!(steps < 40, "probe steps {steps} should be ~2 log2(mbs)");
        }
    }
}
