//! Experiment harness: one runner per paper table/figure.
//!
//! Every runner regenerates the corresponding artifact of the paper's
//! evaluation (DESIGN.md §5) against the simulated substrate and returns
//! a [`metrics::Table`]; `run_all` writes them under `results/`.
//!
//! Evaluation protocol (matches the paper): plans are computed from
//! *noisy* profiles (Alg. 1 measurements with `noise_sigma`), then scored
//! against the noise-free ground-truth oracle — so an allocator that
//! over-fits measurement noise pays for it, exactly as on real hardware.

pub mod ablation;
pub mod fig1;
pub mod fig3;
pub mod fig4;
pub mod fig5;
pub mod fig6;
pub mod fig7;
pub mod fig8;
pub mod fig_autoscale;
pub mod fig_bw_adaptation;
pub mod fig_elastic;
pub mod fig_joint_admission;
pub mod fig_pipeline;
pub mod fig_stage_migration;
pub mod table2;

use anyhow::{anyhow, Result};

use crate::allocator::{self, baselines, Plan};
use crate::cluster::ClusterSpec;
use crate::config::model::ModelSpec;
use crate::config::Strategy;
use crate::coordinator::fit_curves;
use crate::netsim::NetSim;
use crate::profiler::{profile_cluster, ClusterProfile, Device, SimDevice};
use crate::zero::{simulate_iteration, DeviceOracle, IterationReport};

/// Default measurement noise used by all figure runners.
pub const NOISE_SIGMA: f64 = 0.015;

/// The paper's global batch: 2M tokens.
pub const GBS_TOKENS: u64 = 2 * 1024 * 1024;

/// One evaluated (cluster, model, stage, strategy) cell.
#[derive(Debug, Clone)]
pub struct SystemResult {
    /// Strategy label (`poplar`, `uniform`, …).
    pub label: String,
    /// ZeRO stage actually used (after escalation).
    pub stage: u8,
    /// Cluster TFLOP/s (the Fig. 3-5 metric).
    pub tflops: f64,
    /// Iteration wall seconds.
    pub wall_s: f64,
    /// Eq. 4 objective achieved.
    pub objective: f64,
}

/// Build simulated devices for a cluster.
pub fn sim_devices(
    cluster: &ClusterSpec,
    model: &ModelSpec,
    noise: f64,
    seed: u64,
) -> Vec<Box<dyn Device>> {
    let net = NetSim::from_cluster(cluster);
    let instances = cluster.instances();
    instances
        .iter()
        .map(|inst| {
            Box::new(SimDevice::new(
                inst.spec.clone(),
                model.clone(),
                inst.rank,
                instances.len(),
                net.clone(),
                noise,
                seed,
            )) as Box<dyn Device>
        })
        .collect()
}

/// Profile a cluster (noisy Alg. 1) starting at `stage`.
pub fn profile(
    cluster: &ClusterSpec,
    model: &ModelSpec,
    stage: u8,
    noise: f64,
    seed: u64,
) -> Result<ClusterProfile> {
    let mut devices = sim_devices(cluster, model, noise, seed);
    profile_cluster(&mut devices, stage).map_err(|e| anyhow!("profile: {e}"))
}

/// Plan with a strategy from a profile.
pub fn plan_with(
    profile_: &ClusterProfile,
    strategy: Strategy,
    gbs: usize,
    net: &NetSim,
    model: &ModelSpec,
) -> Result<Plan> {
    let curves = fit_curves(profile_)?;
    let psi = model.param_count();
    let plan = match strategy {
        Strategy::Poplar => allocator::plan(&curves, profile_.stage, gbs, net, psi)
            .map_err(|e| anyhow!("poplar: {e}"))?,
        Strategy::Uniform => {
            baselines::plan_uniform(&curves, profile_.stage, gbs, net, psi)
                .map_err(|e| anyhow!("uniform: {e}"))?
        }
        Strategy::Flops => {
            let flops: Vec<f64> = profile_.ranks.iter().map(|r| r.flops_rating).collect();
            baselines::plan_flops_proportional(&curves, &flops, profile_.stage, gbs, net, psi)
                .map_err(|e| anyhow!("flops: {e}"))?
        }
    };
    plan.validate().map_err(|e| anyhow!("plan invalid: {e}"))?;
    Ok(plan)
}

/// Score a plan against the noise-free oracle.
pub fn score(
    cluster: &ClusterSpec,
    model: &ModelSpec,
    plan: &Plan,
) -> Result<IterationReport> {
    let net = NetSim::from_cluster(cluster);
    let specs = cluster.instances().into_iter().map(|i| i.spec).collect();
    let oracle = DeviceOracle { specs, model };
    simulate_iteration(plan, &oracle, &net, model).map_err(|e| anyhow!("score: {e}"))
}

/// End-to-end cell: profile (noisy) → plan → score (truth).
pub fn eval_system(
    cluster: &ClusterSpec,
    model: &ModelSpec,
    stage: u8,
    strategy: Strategy,
    gbs: usize,
    seed: u64,
) -> Result<SystemResult> {
    let prof = profile(cluster, model, stage, NOISE_SIGMA, seed)?;
    let net = NetSim::from_cluster(cluster);
    let plan = plan_with(&prof, strategy, gbs, &net, model)?;
    let rep = score(cluster, model, &plan)?;
    Ok(SystemResult {
        label: strategy.name().to_string(),
        stage: prof.stage,
        tflops: rep.tflops,
        wall_s: rep.wall_s,
        objective: rep.objective,
    })
}

/// Homogeneous sub-cluster of group `g` only (baselines 1/2 of Fig. 3).
pub fn homogeneous_subcluster(cluster: &ClusterSpec, g: usize) -> ClusterSpec {
    let group = cluster.groups[g].clone();
    ClusterSpec { name: format!("{}-homog-{}", cluster.name, group.gpu),
                  groups: vec![group], inter_link: cluster.inter_link }
}

/// gbs in samples for a model at the paper's 2M-token global batch.
pub fn gbs_samples(model: &ModelSpec) -> usize {
    (GBS_TOKENS / model.seq) as usize
}

/// Write a table under `results/` as both markdown and CSV.
pub fn write_result(out_dir: &std::path::Path, name: &str, title: &str,
                    table: &crate::metrics::Table) -> Result<()> {
    std::fs::create_dir_all(out_dir)?;
    let md = format!("# {title}\n\n{}", table.to_markdown());
    std::fs::write(out_dir.join(format!("{name}.md")), md)?;
    std::fs::write(out_dir.join(format!("{name}.csv")), table.to_csv())?;
    Ok(())
}

/// Run every experiment, writing under `out_dir` and echoing to stdout.
pub fn run_all(out_dir: &std::path::Path) -> Result<()> {
    let runners: Vec<(&str, &str, fn() -> Result<crate::metrics::Table>)> = vec![
        ("fig1", "Fig. 1 — idle time without load balancing (motivation)", fig1::run),
        ("fig3", "Fig. 3 — main: TFLOPs on clusters A/B/C x ZeRO stages x systems", fig3::run),
        ("fig4", "Fig. 4 — different models on cluster C", fig4::run),
        ("fig5", "Fig. 5 — GPU-quantity scaling on cluster C types", fig5::run),
        ("fig6", "Fig. 6 — speed vs batch size across GPUs and models", fig6::run),
        ("fig7", "Fig. 7 — cubic-spline interpolation accuracy", fig7::run),
        ("fig8", "Fig. 8 — wall-time vs FLOPs capability measurement", fig8::run),
        ("table2", "Table 2 — profiling overhead (seconds)", table2::run),
        ("ablation", "Appendix — ablation of Poplar components", ablation::run),
        ("fig_elastic", "Elasticity — throughput recovery after membership changes",
         fig_elastic::run),
        ("fig_autoscale", "Autoscaling — cost/throughput frontier of candidate offers",
         fig_autoscale::run),
        ("fig_stage_migration", "Stage migration — replan-time ZeRO-stage re-selection",
         fig_stage_migration::run),
        ("fig_joint_admission", "Joint admission + scale-down — the unified decision round",
         fig_joint_admission::run),
        ("fig_bw_adaptation", "Bandwidth adaptation — measured fabric flips and restores a replan",
         fig_bw_adaptation::run),
        ("fig_pipeline", "Pipeline grouping — virtual DP ranks from memory-starved GPUs",
         fig_pipeline::run),
    ];
    for (name, title, f) in runners {
        eprintln!("[exp] running {name}…");
        let t = f()?;
        println!("\n## {title}\n\n{}", t.to_markdown());
        write_result(out_dir, name, title, &t)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster;
    use crate::config::model::preset;

    #[test]
    fn eval_system_cell() {
        let c = cluster::cluster_b();
        let m = preset("tiny").unwrap();
        let r = eval_system(&c, &m, 1, Strategy::Poplar, 64, 5).unwrap();
        assert!(r.tflops > 0.0);
        assert_eq!(r.stage, 1);
    }

    #[test]
    fn homogeneous_subcluster_extracts_group() {
        let c = cluster::cluster_a();
        let weak = homogeneous_subcluster(&c, 1);
        assert_eq!(weak.n_gpus(), 4);
        assert_eq!(weak.groups[0].gpu, "A100-40G");
    }

    #[test]
    fn gbs_is_2m_tokens() {
        let m = preset("llama-0.5b").unwrap();
        assert_eq!(gbs_samples(&m), 2048);
        let b = preset("bert-1.1b").unwrap();
        assert_eq!(gbs_samples(&b), 4096);
    }
}
