//! Fig. 8 (appendix): measuring compute capability by wall time (Poplar)
//! vs by FLOPs rating (Whale), normalized to the T4, against the actual
//! runtime ratio.
//!
//! The paper's point: FLOPs ratings systematically mispredict real
//! relative speed (they ignore memory bandwidth, efficiency ceilings and
//! launch overheads), while Poplar's measured wall times match reality
//! by construction.

use anyhow::Result;

use super::{profile, NOISE_SIGMA};
use crate::cluster::{catalog, ClusterSpec, LinkKind};
use crate::config::model::require;
use crate::coordinator::fit_curves;
use crate::metrics::Table;

/// GPUs compared (normalized to T4 = 1.0).
pub const GPUS: &[&str] = &["T4", "V100-16G", "V100S-32G", "A100-40G", "A100-80G", "A800-80G"];

/// Run the comparison.
pub fn run() -> Result<Table> {
    let model = require("llama-0.5b")?;

    // actual + poplar-measured peak speeds per GPU (each at its own mbs,
    // exactly the paper's protocol: "each GPU performs five iterations
    // at its respective mbs")
    let mut actual = Vec::new();
    let mut measured = Vec::new();
    let mut flops = Vec::new();
    for gpu in GPUS {
        let spec = catalog::spec_or_panic(gpu);
        let cluster = ClusterSpec::new("solo", &[(gpu, 1, LinkKind::Pcie)], LinkKind::Ib);
        let prof = profile(&cluster, &model, 1, NOISE_SIGMA, 88)?;
        let curve = &fit_curves(&prof)?[0];
        measured.push(curve.peak_speed());
        // ground truth at the same mbs
        let mbs = curve.mbs();
        let t = spec.compute_time(
            (mbs as u64 * model.seq) as f64,
            model.flops_per_token(),
            model.n_layers as usize,
        );
        actual.push(mbs as f64 / t);
        flops.push(spec.flops_rating());
    }

    let norm = |v: &[f64]| -> Vec<f64> { v.iter().map(|x| x / v[0]).collect() };
    let (actual, measured, flops) = (norm(&actual), norm(&measured), norm(&flops));

    let mut table = Table::new(&["gpu", "actual_rel", "poplar_rel", "whale_flops_rel",
                                 "poplar_err", "whale_err"]);
    for (i, gpu) in GPUS.iter().enumerate() {
        table.row(&[
            gpu.to_string(),
            format!("{:.2}", actual[i]),
            format!("{:.2}", measured[i]),
            format!("{:.2}", flops[i]),
            format!("{:.3}", (measured[i] - actual[i]).abs() / actual[i]),
            format!("{:.3}", (flops[i] - actual[i]).abs() / actual[i]),
        ]);
    }
    Ok(table)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poplar_measurement_closer_than_flops() {
        let t = run().unwrap();
        let rows: Vec<Vec<String>> = t
            .to_csv()
            .lines()
            .skip(1)
            .map(|l| l.split(',').map(str::to_string).collect())
            .collect();
        let mut poplar_total = 0.0;
        let mut whale_total = 0.0;
        for r in &rows {
            poplar_total += r[4].parse::<f64>().unwrap();
            whale_total += r[5].parse::<f64>().unwrap();
        }
        assert!(
            poplar_total < whale_total * 0.5,
            "poplar err {poplar_total:.3} should be far below whale {whale_total:.3}"
        );
    }

    #[test]
    fn flops_overrates_big_gpus() {
        // A100's FLOPs ratio vs T4 (4.8x) exceeds its wall-time ratio
        let t = run().unwrap();
        let row: Vec<String> = t
            .to_csv()
            .lines()
            .find(|l| l.starts_with("A100-80G"))
            .unwrap()
            .split(',')
            .map(str::to_string)
            .collect();
        let actual: f64 = row[1].parse().unwrap();
        let flops: f64 = row[3].parse().unwrap();
        assert!(flops < actual, "flops rel {flops} vs actual {actual} — \
                 T4's wall-time penalty exceeds its FLOPs penalty");
    }
}
