//! Stage-migration experiment: replan-time ZeRO-stage re-selection.
//!
//! 2× A800-80G + 2× V100S-32G, llama-0.5b, the paper's 2M-token global
//! batch, a 2 GB/s socket fabric — the regime where ZeRO-3's three
//! per-micro-step collectives dominate the iteration. The job is pinned
//! at ZeRO-3 (the stage a memory-tight startup escalation leaves
//! behind), then one `RankJoined` event fires and the stage search
//! re-decides. Two scenarios, identical fleet and event:
//!
//! * **warm-cache** (`horizon 300 s`) — every `(type, stage)` curve is
//!   already measured. ZeRO-1 drops the per-step collective traffic for
//!   a multiple of ZeRO-3's rate, and because the partitioned stages
//!   share the optimizer tiling the migration costs only the join's
//!   membership reshard → **migrate** (the amortized score of the
//!   chosen stage strictly beats staying).
//! * **cold-cache** (`horizon 4 s` — a short spot tenure) — only ZeRO-3
//!   is measured. The candidates' rates are catalog-FLOPs estimates,
//!   and the Alg. 1 cost of measuring them exceeds the entire tenure:
//!   every alternative's amortized score collapses to zero, so
//!   **staying is optimal** even though ZeRO-1's raw rate is higher —
//!   the stall, not the steady state, decides.
//!
//! One row per candidate stage per scenario; `chosen` marks the stage
//! the replan actually selected.

use anyhow::{anyhow, Result};

use super::gbs_samples;
use crate::cluster::LinkKind;
use crate::config::model::{preset, ModelSpec};
use crate::curves::PerfCurve;
use crate::elastic::{ElasticPlanner, StageCandidate, StagePolicy};
use crate::metrics::Table;
use crate::netsim::NetSim;

/// The fleet both scenarios start from.
pub const FLEET: &[&str] = &["A800-80G", "A800-80G", "V100S-32G", "V100S-32G"];
/// The GPU type that joins and triggers the re-decision.
pub const JOINER: &str = "V100S-32G";
/// Stage the job is pinned at before the event.
pub const PINNED_STAGE: u8 = 3;
/// Amortization horizon of the warm-cache scenario (seconds).
pub const WARM_HORIZON_S: f64 = 300.0;
/// Amortization horizon of the cold-cache scenario (seconds) — a spot
/// tenure too short to amortize any Alg. 1 run.
pub const COLD_HORIZON_S: f64 = 4.0;

/// Ground-truth curve for `gpu` at the memory-model `mbs` of
/// `(model, stage, n)` — what a noise-free Alg. 1 would measure. The
/// catalog-FLOPs "estimate" IS the simulator's ground truth (the
/// `SimDevice` times the same device model), so the autoscale
/// synthesizer doubles as the shared noise-free oracle; `None` when the
/// card cannot fit the two samples a curve needs.
fn truth_curve(gpu: &str, model: &ModelSpec, stage: u8, n: usize) -> Option<PerfCurve> {
    crate::autoscale::synthesize_curve(gpu, model, stage, n).ok()
}

/// One scenario's outcome: the candidate table of the post-event stage
/// search plus what the replan chose.
#[derive(Debug, Clone)]
pub struct MigrationScenario {
    /// Scenario label.
    pub label: String,
    /// Amortization horizon used.
    pub horizon_s: f64,
    /// Stage before the event.
    pub stage_before: u8,
    /// Stage the post-event replan chose.
    pub stage_after: u8,
    /// All four candidates as the search scored them (stage order).
    pub candidates: Vec<StageCandidate>,
}

fn planner(model: &ModelSpec, gbs: usize) -> Result<ElasticPlanner> {
    let mut p = ElasticPlanner::new(PINNED_STAGE, gbs, &model.name, model.param_count(), 32);
    for gpu in FLEET {
        let slot = p.add_slot(gpu);
        if p.slots()[slot].curve.is_none() {
            let c = truth_curve(gpu, model, PINNED_STAGE, FLEET.len())
                .ok_or_else(|| anyhow!("{gpu} must fit at ZeRO-{PINNED_STAGE}"))?;
            p.install_curve(slot, c, false).map_err(|e| anyhow!("install: {e}"))?;
        }
    }
    Ok(p)
}

/// Run one scenario: pin at ZeRO-3, seed the cache (`warm` = all
/// stages, cold = only the pinned one), fire the join, search, replan.
fn scenario(label: &str, horizon_s: f64, warm: bool) -> Result<MigrationScenario> {
    let model = preset("llama-0.5b").ok_or_else(|| anyhow!("missing preset"))?;
    let gbs = gbs_samples(&model);
    let mut p = planner(&model, gbs)?;
    let net = NetSim::from_link(FLEET.len(), LinkKind::Socket);
    p.replan(&net).map_err(|e| anyhow!("initial plan: {e}"))?;

    if warm {
        // every (type, stage) pair measured — what a fleet that has
        // migrated before holds in its stage-keyed cache
        let n_after = FLEET.len() + 1;
        for stage in 0..=3u8 {
            for gpu in ["A800-80G", "V100S-32G"] {
                if let Some(c) = truth_curve(gpu, &model, stage, n_after) {
                    p.install_stage_curve(gpu, stage, c)
                        .map_err(|e| anyhow!("seed: {e}"))?;
                }
            }
        }
    }
    p.set_stage_policy(Some(StagePolicy { horizon_s }));

    let stage_before = p.stage();
    p.add_slot(JOINER);
    let net_after = NetSim::from_link(FLEET.len() + 1, LinkKind::Socket);
    // the candidate table the search saw at decision time
    let candidates = p
        .stage_candidates(&net_after)
        .map_err(|e| anyhow!("candidates: {e}"))?;
    p.replan(&net_after).map_err(|e| anyhow!("post-event replan: {e}"))?;

    Ok(MigrationScenario {
        label: label.to_string(),
        horizon_s,
        stage_before,
        stage_after: p.stage(),
        candidates,
    })
}

/// Both scenarios, warm first.
pub fn scenarios() -> Result<Vec<MigrationScenario>> {
    Ok(vec![
        scenario("warm-cache", WARM_HORIZON_S, true)?,
        scenario("cold-cache", COLD_HORIZON_S, false)?,
    ])
}

/// Run the full figure.
pub fn run() -> Result<Table> {
    let mut table = Table::new(&[
        "scenario",
        "event",
        "stage",
        "feasible",
        "curves",
        "rate_sps",
        "migration_s",
        "profile_est_s",
        "score_sps",
        "chosen",
    ]);
    for s in scenarios()? {
        for c in &s.candidates {
            table.row(&[
                s.label.clone(),
                format!("join({JOINER}) h={:.0}s", s.horizon_s),
                format!("{}{}", c.stage, if c.current { "*" } else { "" }),
                if c.feasible { "yes".into() } else { "-".into() },
                if c.curves_cached { "measured".into() } else { "estimated".into() },
                format!("{:.1}", c.rate_sps),
                format!("{:.3}", c.migration_s),
                format!("{:.2}", c.profile_est_s),
                format!("{:.1}", c.score),
                if c.stage == s.stage_after { "yes".into() } else { "-".into() },
            ]);
        }
    }
    Ok(table)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cand(s: &MigrationScenario, stage: u8) -> &StageCandidate {
        s.candidates.iter().find(|c| c.stage == stage).unwrap()
    }

    #[test]
    fn warm_cache_migration_beats_staying() {
        // the acceptance bar: >= 1 event where migrating the stage beats
        // keeping it — amortized score of the chosen stage strictly
        // above the incumbent's
        let s = &scenarios().unwrap()[0];
        assert_eq!(s.stage_before, PINNED_STAGE);
        assert_ne!(s.stage_after, PINNED_STAGE, "the search must migrate");
        let chosen = cand(s, s.stage_after);
        let incumbent = cand(s, PINNED_STAGE);
        assert!(
            chosen.score > incumbent.score,
            "chosen {:.1} must beat incumbent {:.1}",
            chosen.score,
            incumbent.score
        );
        // de-escalation to a sync-once stage on a 2 GB/s fabric
        assert!(s.stage_after <= 1);
        assert!(chosen.rate_sps > incumbent.rate_sps * 1.5);
        assert!(chosen.curves_cached, "only measured stages are switchable");
        // partitioned -> partitioned: the migration is just the join's
        // membership movement, far below the full 12ψ state
        let psi = preset("llama-0.5b").unwrap().param_count();
        assert!(chosen.migration_bytes < 12 * psi);
    }

    #[test]
    fn cold_cache_stall_makes_staying_optimal() {
        // the acceptance bar: >= 1 event where the stall makes staying
        // optimal — a candidate with a higher raw rate loses on the
        // amortized score because profiling cannot pay for itself
        let s = &scenarios().unwrap()[1];
        assert_eq!(s.stage_before, PINNED_STAGE);
        assert_eq!(s.stage_after, PINNED_STAGE, "the search must stay");
        let incumbent = cand(s, PINNED_STAGE);
        let z1 = cand(s, 1);
        assert!(
            z1.rate_sps > incumbent.rate_sps,
            "ZeRO-1 is genuinely faster steady-state: {:.1} vs {:.1}",
            z1.rate_sps,
            incumbent.rate_sps
        );
        assert!(!z1.curves_cached, "cold cache: the rate is an estimate");
        assert!(z1.profile_est_s > 0.0);
        assert!(
            z1.score < incumbent.score,
            "the stall must make staying optimal: {:.1} vs {:.1}",
            z1.score,
            incumbent.score
        );
        assert_eq!(z1.score, 0.0, "Alg. 1 alone exceeds the {COLD_HORIZON_S} s tenure");
        assert!(incumbent.score > 0.0);
    }

    #[test]
    fn figure_is_deterministic_and_complete() {
        let a = run().unwrap().to_markdown();
        let b = run().unwrap().to_markdown();
        assert_eq!(a, b);
        // two scenarios x four candidate stages
        assert_eq!(run().unwrap().len(), 8);
    }
}
