//! Pipeline-grouping experiment: virtual DP ranks turn memory-starved
//! GPUs from hard rejects into throughput (ROADMAP item 3).
//!
//! The stressor is the `longctx-0.4b` preset: a modest 0.4B-parameter
//! model whose seq-4096 activations (~31 GB/sample) overflow every
//! mid-tier card at ANY ZeRO stage — sharding optimizer state cannot
//! help when one sample's activations alone exceed the card. Four
//! sections, one table:
//!
//! * **solo-reject** — T4, V100S-32G and V100-16G each show
//!   `true_mbs = 0` at every ZeRO stage 0..=3: the Alg. 1 memory bound
//!   rejects them outright, no matter how far state is sharded.
//! * **pack** — [`crate::pipeline::pack_groups`] over an 8-card pool
//!   (6× T4 + 2× V100S-32G) forms two anchor-first quads: the V100S
//!   anchors the last pipeline stage (one micro-batch in flight), the
//!   weakest T4s take the early stages and few layers.
//! * **fleet** — both quads join an [`ElasticPlanner`] as virtual
//!   ranks ([`crate::elastic::ElasticPlanner::add_group_slot`]) and
//!   the ordinary ZeRO-DP replan drives them to a strictly positive
//!   fleet rate: the model no single member card can host trains.
//! * **round** — [`crate::policy::decide_round`] with
//!   `allow_pipeline` sees four more starved offers and proposes a
//!   third quad as a [`crate::policy::GroupAdmission`], while the
//!   member offers stay declined as solo ranks.

use anyhow::{anyhow, Result};

use super::gbs_samples;
use crate::cluster::{catalog, LinkKind};
use crate::config::model::{preset, ModelSpec};
use crate::elastic::ElasticPlanner;
use crate::memmodel;
use crate::metrics::Table;
use crate::netsim::NetSim;
use crate::pipeline::{self, GroupPlan};
use crate::policy::{self, RoundOptions};

/// Cards the solo-reject section prices (all mid-tier memory classes).
pub const SOLO_CARDS: &[&str] = &["T4", "V100S-32G", "V100-16G"];
/// The bootstrap pool the pack section carves into groups.
pub const POOL: &[&str] =
    &["T4", "T4", "T4", "T4", "T4", "T4", "V100S-32G", "V100S-32G"];
/// The follow-on offer batch of the round section.
pub const ROUND_OFFERS: &[&str] = &["T4", "T4", "T4", "V100S-32G"];
/// ZeRO stage every section runs at.
pub const STAGE: u8 = 3;

fn model() -> Result<ModelSpec> {
    preset("longctx-0.4b").ok_or_else(|| anyhow!("missing longctx-0.4b preset"))
}

/// Pack the bootstrap pool and plan each group at the fleet's virtual
/// group count.
pub fn bootstrap_groups(net: &NetSim) -> Result<Vec<GroupPlan>> {
    let m = model()?;
    let psi = m.param_count();
    let (groups, leftovers) =
        pipeline::pack_groups(POOL_STRINGS().as_slice(), &m, psi, STAGE, 4);
    if !leftovers.is_empty() {
        return Err(anyhow!("pool leaves {} cards ungrouped", leftovers.len()));
    }
    let n_virtual = groups.len();
    groups
        .iter()
        .map(|g| {
            pipeline::plan_group(g, &m, psi, STAGE, n_virtual, net)
                .map_err(|e| anyhow!("planning {:?}: {e}", g))
        })
        .collect()
}

#[allow(non_snake_case)]
fn POOL_STRINGS() -> Vec<String> {
    POOL.iter().map(|s| s.to_string()).collect()
}

fn fmt_ks(ks: &[u64]) -> String {
    ks.iter().map(|k| k.to_string()).collect::<Vec<_>>().join("+")
}

/// Run the full figure.
pub fn run() -> Result<Table> {
    let m = model()?;
    let psi = m.param_count();
    let gbs = gbs_samples(&m);
    let mut t = Table::new(&[
        "scenario", "subject", "stage", "layers", "chunk", "bubble_eff", "rate_sps",
        "note",
    ]);

    // ---- solo-reject: every ZeRO stage bounces every card ----
    for gpu in SOLO_CARDS {
        let spec = catalog::spec(gpu).ok_or_else(|| anyhow!("unknown GPU {gpu}"))?;
        let worst: usize = (0u8..=3)
            .map(|st| memmodel::true_mbs(&m, psi, st, POOL.len(), spec.mem_bytes()))
            .max()
            .unwrap_or(0);
        if worst != 0 {
            return Err(anyhow!("{gpu} unexpectedly hosts {} samples", worst));
        }
        t.row(&[
            "solo-reject".into(),
            (*gpu).to_string(),
            "0..=3".into(),
            "-".into(),
            "-".into(),
            "-".into(),
            "0.00".into(),
            "infeasible at every ZeRO stage: one sample's activations overflow the card"
                .into(),
        ]);
    }

    // ---- pack: anchor-first grouping of the 8-card pool ----
    let net = NetSim::from_link(2, LinkKind::Ib);
    let plans = bootstrap_groups(&net)?;
    for gp in &plans {
        let micro = pipeline::micro_batches(gbs.min(8), gp.chunk);
        t.row(&[
            "pack".into(),
            gp.label.clone(),
            format!("{}", gp.stage),
            fmt_ks(&gp.ks),
            gp.chunk.to_string(),
            format!("{:.2}", pipeline::bubble_efficiency(micro, gp.members.len())),
            format!("{:.2}", gp.curve.peak_speed()),
            "anchor-first quad: weakest cards take the early stages".into(),
        ]);
    }

    // ---- fleet: both quads train as ordinary ZeRO-DP ranks ----
    let mut planner = ElasticPlanner::new(STAGE, gbs, &m.name, psi, 32);
    for gp in &plans {
        planner.add_group_slot(gp);
    }
    planner.replan(&net).map_err(|e| anyhow!("fleet replan: {e}"))?;
    let curves = planner.active_curves().map_err(|e| anyhow!("curves: {e}"))?;
    let plan = planner.plan().ok_or_else(|| anyhow!("fleet replan left no plan"))?;
    let wall = crate::allocator::predicted_wall_s(plan, &curves, &net, psi)
        .map_err(|e| anyhow!("wall: {e}"))?;
    if !(wall.is_finite() && wall > 0.0) {
        return Err(anyhow!("fleet wall time is not positive: {wall}"));
    }
    let fleet_rate = gbs as f64 / wall;
    for (gp, r) in plans.iter().zip(&plan.ranks) {
        t.row(&[
            "fleet".into(),
            gp.label.clone(),
            format!("{STAGE}"),
            fmt_ks(&gp.ks),
            gp.chunk.to_string(),
            "-".into(),
            format!("{:.2}", gp.curve.speed_at(r.micro_batch.max(1) as f64)),
            format!("virtual rank carries {} samples/iter", r.samples_per_iter),
        ]);
    }
    t.row(&[
        "fleet".into(),
        "(fleet)".into(),
        format!("{STAGE}"),
        "-".into(),
        "-".into(),
        "-".into(),
        format!("{:.2}", fleet_rate),
        "a model NO member card hosts solo trains at a positive rate".into(),
    ]);

    // ---- round: the decision engine proposes a third quad ----
    let offers: Vec<String> = ROUND_OFFERS.iter().map(|s| s.to_string()).collect();
    let opts = RoundOptions { allow_pipeline: true, min_gain: 0.01, ..Default::default() };
    let round = policy::decide_round(&planner, &net, &m, &offers, &opts)
        .map_err(|e| anyhow!("round: {e}"))?;
    for v in &round.offers {
        t.row(&[
            "round".into(),
            v.gpu.clone(),
            "-".into(),
            "-".into(),
            "-".into(),
            "-".into(),
            "-".into(),
            format!("{} — {}", v.action.label(), v.reason),
        ]);
    }
    let gr = round
        .grouping
        .as_ref()
        .ok_or_else(|| anyhow!("round failed to group the starved offers"))?;
    t.row(&[
        "round".into(),
        gr.label.clone(),
        format!("{}", gr.stage),
        fmt_ks(&gr.ks),
        gr.chunk.to_string(),
        "-".into(),
        format!("{:.2}", gr.rate),
        format!(
            "group-admit as a third virtual rank: {:+.1}% amortized over one \
             {:.3}s stall",
            gr.rel_gain * 100.0,
            gr.ledger.total()
        ),
    ]);
    Ok(t)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn solo_cards_are_infeasible_at_every_stage() {
        let m = model().unwrap();
        let psi = m.param_count();
        for gpu in SOLO_CARDS {
            let spec = catalog::spec(gpu).unwrap();
            for stage in 0u8..=3 {
                // even at a generous shard count the activations alone
                // overflow: sharding state cannot rescue these cards
                assert_eq!(
                    memmodel::true_mbs(&m, psi, stage, 64, spec.mem_bytes()),
                    0,
                    "{gpu} must not host longctx-0.4b at ZeRO-{stage}"
                );
            }
        }
    }

    #[test]
    fn pool_packs_into_two_anchored_quads() {
        let net = NetSim::from_link(2, LinkKind::Ib);
        let plans = bootstrap_groups(&net).unwrap();
        assert_eq!(plans.len(), 2);
        let m = model().unwrap();
        for gp in &plans {
            assert_eq!(gp.members.len(), 4);
            // the big card anchors the LAST pipeline stage
            assert_eq!(gp.members.last().map(String::as_str), Some("V100S-32G"));
            assert_eq!(gp.ks.iter().sum::<u64>(), m.n_layers);
            assert!(gp.curve.peak_speed() > 0.0);
        }
    }

    #[test]
    fn figure_is_deterministic_and_complete() {
        let a = run().unwrap().to_markdown();
        let b = run().unwrap().to_markdown();
        assert_eq!(a, b);
        // 3 solo rejects + 2 packed quads + (2 fleet ranks + 1 fleet
        // total) + (4 offer verdicts + 1 group admission) = 13 rows
        assert_eq!(run().unwrap().len(), 13);
        // the acceptance bar in one place: the fleet row must show a
        // strictly positive rate for a model no solo card can host,
        // and the round must propose a pipeline group
        let md = a;
        assert!(md.contains("a model NO member card hosts solo"), "{md}");
        assert!(md.contains("group-admit"), "{md}");
    }
}
