//! Joint-admission + scale-down experiment: the unified decision round
//! (`policy::decide_round`) against the greedy one-at-a-time rule.
//!
//! Two scenarios, one table (the per-round rows are rendered by
//! [`crate::policy::round_rows`], shared with `poplar autoscale
//! --joint` so the figure and the CLI can never drift):
//!
//! * **joint-admission** — cluster C (4× A800-80G + 4× V100S-32G,
//!   llama-0.5b, ZeRO-1, IB), offers `[A800-80G, T4]`, T4's curve
//!   already measured (cached), `min_gain = 5%`. One at a time the rule
//!   *splits* the batch: the A800 clears the bar easily (accept) but
//!   the T4's solo gain (~2%) sits below it (reject) — every solo
//!   admission must amortize its own reshard stall. The joint round
//!   prices the batch as ONE admission paying ONE combined reshard:
//!   the T4's marginal contribution inside the batch is strictly
//!   positive, so **both** are admitted and the round's score beats
//!   the sequential replay's. Both scores appear in the table.
//! * **scale-down** — 4× A800-80G + 1× V100S-32G whose spot price
//!   spiked to $6/hr (a `prices` override). Keeping it still adds
//!   throughput, but on the cost-adjusted axis the rank is dominated:
//!   releasing it raises amortized samples-per-dollar by ~30% even
//!   after paying the measured shard re-absorption stall → a
//!   [`crate::policy::Action::Release`] with strictly positive gain.

use anyhow::{anyhow, Result};

use super::gbs_samples;
use crate::cluster::LinkKind;
use crate::config::model::{preset, ModelSpec};
use crate::curves::PerfCurve;
use crate::elastic::ElasticPlanner;
use crate::metrics::Table;
use crate::netsim::NetSim;
use crate::policy::{self, RoundOptions, RoundPlan};

/// The offer batch of the joint-admission scenario.
pub const JOINT_OFFERS: &[&str] = &["A800-80G", "T4"];
/// Acceptance bar of the joint-admission scenario: above the T4's solo
/// gain, far below the A800's — so the greedy rule must split.
pub const JOINT_MIN_GAIN: f64 = 0.05;
/// The spiked $/hr of the V100S in the scale-down scenario.
pub const RELEASE_PRICE_SPIKE: f64 = 6.0;

/// Ground-truth curve (noise-free Alg. 1): on the simulated substrate
/// the catalog-FLOPs synthesizer IS the ground truth.
fn truth_curve(gpu: &str, model: &ModelSpec, stage: u8, n: usize) -> Result<PerfCurve> {
    crate::autoscale::synthesize_curve(gpu, model, stage, n)
        .map_err(|e| anyhow!("truth curve {gpu}: {e}"))
}

fn planner_with(
    model: &ModelSpec,
    gbs: usize,
    fleet: &[&str],
) -> Result<(ElasticPlanner, NetSim)> {
    let mut p = ElasticPlanner::new(1, gbs, &model.name, model.param_count(), 32);
    for gpu in fleet {
        let slot = p.add_slot(gpu);
        if p.slots()[slot].curve.is_none() {
            p.install_curve(slot, truth_curve(gpu, model, 1, fleet.len())?, false)
                .map_err(|e| anyhow!("install: {e}"))?;
        }
    }
    let net = NetSim::from_link(fleet.len(), LinkKind::Ib);
    p.replan(&net).map_err(|e| anyhow!("initial plan: {e}"))?;
    Ok((p, net))
}

/// The joint-admission round.
pub fn joint_round() -> Result<RoundPlan> {
    let model = preset("llama-0.5b").ok_or_else(|| anyhow!("missing preset"))?;
    let gbs = gbs_samples(&model);
    let fleet = [
        "A800-80G", "A800-80G", "A800-80G", "A800-80G", "V100S-32G", "V100S-32G",
        "V100S-32G", "V100S-32G",
    ];
    let (mut p, net) = planner_with(&model, gbs, &fleet)?;
    // the T4 type ran here before: its ZeRO-1 curve is cached, so both
    // offers are decided on measured curves with zero profiling
    p.install_stage_curve("T4", 1, truth_curve("T4", &model, 1, fleet.len() + 2)?)
        .map_err(|e| anyhow!("seeding T4 curve: {e}"))?;
    let opts = RoundOptions {
        min_gain: JOINT_MIN_GAIN,
        with_sequential: true,
        ..Default::default()
    };
    let offers: Vec<String> = JOINT_OFFERS.iter().map(|s| s.to_string()).collect();
    policy::decide_round(&p, &net, &model, &offers, &opts).map_err(|e| anyhow!("round: {e}"))
}

/// The scale-down round.
pub fn release_round() -> Result<RoundPlan> {
    let model = preset("llama-0.5b").ok_or_else(|| anyhow!("missing preset"))?;
    let gbs = gbs_samples(&model);
    let fleet = ["A800-80G", "A800-80G", "A800-80G", "A800-80G", "V100S-32G"];
    let (p, net) = planner_with(&model, gbs, &fleet)?;
    let opts = RoundOptions {
        consider_release: true,
        prices: vec![("V100S-32G".to_string(), RELEASE_PRICE_SPIKE)],
        ..Default::default()
    };
    policy::decide_round(&p, &net, &model, &[], &opts).map_err(|e| anyhow!("round: {e}"))
}

/// Run the full figure: one scenario-prefixed block of round rows each.
pub fn run() -> Result<Table> {
    let mut cols: Vec<&str> = vec!["scenario"];
    cols.extend_from_slice(policy::ROUND_COLUMNS);
    let mut table = Table::new(&cols);
    for (label, round) in
        [("joint-admission", joint_round()?), ("scale-down", release_round()?)]
    {
        for row in policy::round_rows(&round) {
            let mut r = vec![label.to_string()];
            r.extend(row);
            table.row(&r);
        }
    }
    Ok(table)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autoscale::Decision;
    use crate::policy::Action;

    #[test]
    fn joint_round_admits_the_batch_the_greedy_rule_splits() {
        // the acceptance bar: >= 1 jointly-accepted offer batch that
        // the sequential greedy rule splits into accept + reject, with
        // both scores shown
        let r = joint_round().unwrap();
        let a800 = &r.offers[0];
        let t4 = &r.offers[1];
        // greedy, one at a time: accept + reject
        assert_eq!(a800.solo.as_ref().unwrap().decision, Decision::Accept);
        let t4_solo = t4.solo.as_ref().unwrap();
        assert_eq!(t4_solo.decision, Decision::Reject, "{}", t4_solo.reason);
        assert!(t4_solo.rel_gain < JOINT_MIN_GAIN, "solo gain must sit below the bar");
        assert!(t4_solo.rel_gain > 0.0, "…while still contributing positively");
        // joint: both admitted on measured curves, round clears the bar
        assert!(matches!(a800.action, Action::Admit { .. }));
        assert!(matches!(t4.action, Action::Admit { .. }), "{}", t4.reason);
        assert_eq!(r.admitted, vec!["A800-80G".to_string(), "T4".to_string()]);
        assert!(r.rel_gain >= JOINT_MIN_GAIN);
        // the sequential replay splits exactly like the solo verdicts,
        // and the joint round strictly beats its amortized score
        let seq = r.sequential.as_ref().expect("with_sequential is set");
        assert_eq!(seq.admitted, vec!["A800-80G".to_string()]);
        assert!(
            r.score > seq.score,
            "joint {:.1} must beat sequential {:.1}",
            r.score,
            seq.score
        );
        assert!(r.ledger.total() > 0.0, "one shared reshard stall is priced");
        assert_eq!(r.stage, r.stage_before, "no stage policy in this scenario");
    }

    #[test]
    fn scale_down_releases_the_dominated_rank_with_positive_gain() {
        // the acceptance bar: >= 1 Release event with strictly positive
        // amortized (samples-per-dollar) gain
        let r = release_round().unwrap();
        let rel = r.release.as_ref().expect("the spiked V100S must be released");
        assert_eq!(rel.gpu, "V100S-32G");
        assert!(rel.rel_gain_per_dollar > 0.0, "{}", rel.reason);
        assert!(rel.rel_gain_per_dollar >= r.min_gain);
        assert!(rel.cost_per_ksample_after < rel.cost_per_ksample_before);
        assert!(rel.rate_after < r.pre_rate, "scale-down trades rate for dollars");
        assert!(rel.price_after_per_hour < rel.price_before_per_hour);
        assert!(r.actions.iter().any(|a| matches!(a, Action::Release { .. })));
        // releasing pays a measured shard re-absorption stall
        assert!(rel.stall.total() > 0.0);
    }

    #[test]
    fn figure_is_deterministic_and_complete() {
        let a = run().unwrap().to_markdown();
        let b = run().unwrap().to_markdown();
        assert_eq!(a, b);
        // joint: baseline + 2 offers + round + sequential = 5 rows;
        // scale-down (no offers, so no replay): baseline + round +
        // release = 3 rows
        assert_eq!(run().unwrap().len(), 8);
    }
}
