//! Fig. 4: model generality — Llama-0.5B vs Llama-1.1B vs BERT-1.1B on
//! cluster C, all ZeRO stages, DeepSpeed vs Whale vs Poplar.
//!
//! Expected shape (paper): Poplar up to ~2.27x over DeepSpeed on
//! Llama-1.1B and up to ~3.92x on BERT-1.1B (bigger models stress the
//! weak GPUs' memory, so heterogeneity-aware batching matters more).

use anyhow::Result;

use super::{eval_system, gbs_samples};
use crate::cluster;
use crate::config::model::{preset, require};
use crate::config::Strategy;
use crate::metrics::Table;

/// Models of the figure.
pub const MODELS: &[&str] = &["llama-0.5b", "llama-1.1b", "bert-1.1b"];

/// Run the full figure.
pub fn run() -> Result<Table> {
    let cluster = cluster::cluster_c();
    let mut table =
        Table::new(&["model", "stage_req", "stage_used", "system", "tflops", "vs_deepspeed"]);
    for model_name in MODELS {
        let model = require(model_name)?;
        let gbs = gbs_samples(&model);
        for stage in 0..4u8 {
            let mut cells = Vec::new();
            for (label, strategy) in [
                ("deepspeed", Strategy::Uniform),
                ("whale", Strategy::Flops),
                ("poplar", Strategy::Poplar),
            ] {
                let r = eval_system(&cluster, &model, stage, strategy, gbs,
                                    2000 + stage as u64)?;
                cells.push((label, r.stage, r.tflops));
            }
            let ds = cells[0].2;
            for (label, used, tflops) in cells {
                table.row(&[
                    model_name.to_string(),
                    format!("ZeRO-{stage}"),
                    format!("ZeRO-{used}"),
                    label.to_string(),
                    format!("{tflops:.1}"),
                    format!("{:.2}x", tflops / ds),
                ]);
            }
        }
    }
    Ok(table)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bigger_models_bigger_poplar_gain() {
        // the Fig. 4 trend: poplar's edge over deepspeed grows from
        // 0.5B to the 1.1B models (memory pressure on the weak GPUs)
        let cluster = cluster::cluster_c();
        let gain = |model_name: &str, stage: u8| -> f64 {
            let model = preset(model_name).unwrap();
            let gbs = gbs_samples(&model);
            let p = eval_system(&cluster, &model, stage, Strategy::Poplar, gbs, 5).unwrap();
            let d = eval_system(&cluster, &model, stage, Strategy::Uniform, gbs, 5).unwrap();
            p.tflops / d.tflops
        };
        let g05 = gain("llama-0.5b", 2);
        let g11 = gain("llama-1.1b", 2);
        assert!(g05 >= 0.99 && g11 >= 0.99);
        assert!(g11 > g05 * 0.95, "1.1B gain {g11:.2} vs 0.5B gain {g05:.2}");
    }

    #[test]
    fn all_models_all_stages_complete() {
        let t = run().unwrap();
        // 3 models x 4 stages x 3 systems
        assert_eq!(t.len(), 36);
    }
}
