//! Fig. 6 (appendix): the speed-vs-batch-size relationship that the
//! whole batch-allocation approach rests on — throughput rises with
//! batch size, then plateaus (cuBLAS/MXU tile quantization).
//!
//! Four GPUs (A100-80G, V100-32G≈V100S, RTX4090, RTX3060) × three
//! models (GPT-2 345M, Llama-7B where it fits, CogVLM stand-in:
//! llama-0.5b).

use anyhow::Result;

use crate::cluster::catalog;
use crate::config::model::require;
use crate::metrics::Table;

/// GPUs of the figure.
pub const GPUS: &[&str] = &["A100-80G", "V100S-32G", "RTX4090", "RTX3060"];

/// Models of the figure (CogVLM-224 has no public config; llama-0.5b is
/// the closest dense stand-in — DESIGN.md §2).
pub const MODELS: &[&str] = &["gpt2-345m", "llama-0.5b", "llama-7b"];

/// Run the sweep.
pub fn run() -> Result<Table> {
    let mut table = Table::new(&["gpu", "model", "batch", "samples_per_s", "norm_speed"]);
    for gpu in GPUS {
        let spec = catalog::spec_or_panic(gpu);
        for model_name in MODELS {
            let model = require(model_name)?;
            let mut speeds = Vec::new();
            for b in [1usize, 2, 4, 8, 12, 16, 24, 32, 48, 64] {
                let t = spec.compute_time(
                    (b as u64 * model.seq) as f64,
                    model.flops_per_token(),
                    model.n_layers as usize,
                );
                speeds.push((b, b as f64 / t));
            }
            let peak = speeds.iter().map(|(_, s)| *s).fold(0.0, f64::max);
            for (b, s) in speeds {
                table.row(&[
                    gpu.to_string(),
                    model_name.to_string(),
                    b.to_string(),
                    format!("{s:.3}"),
                    format!("{:.3}", s / peak),
                ]);
            }
        }
    }
    Ok(table)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn curves_rise_then_plateau() {
        let t = run().unwrap();
        let rows: Vec<Vec<String>> = t
            .to_csv()
            .lines()
            .skip(1)
            .map(|l| l.split(',').map(str::to_string).collect())
            .collect();
        for gpu in GPUS {
            for model in MODELS {
                let series: Vec<f64> = rows
                    .iter()
                    .filter(|r| r[0] == *gpu && r[1] == *model)
                    .map(|r| r[3].parse().unwrap())
                    .collect();
                assert_eq!(series.len(), 10);
                // rising: batch 8 beats batch 1 by a lot
                assert!(series[3] > series[0] * 1.3, "{gpu}/{model}");
                // plateau: last doubling gains < 15%
                let last = series[9] / series[8];
                assert!(last < 1.15, "{gpu}/{model}: late gain {last}");
            }
        }
    }

    #[test]
    fn consumer_cards_slower_than_datacenter() {
        let t = run().unwrap();
        let peak = |gpu: &str| -> f64 {
            t.to_csv()
                .lines()
                .skip(1)
                .map(|l| l.split(',').collect::<Vec<_>>())
                .filter(|r| r[0] == gpu && r[1] == "llama-0.5b")
                .map(|r| r[3].parse::<f64>().unwrap())
                .fold(0.0, f64::max)
        };
        assert!(peak("A100-80G") > peak("RTX3060") * 2.0);
    }
}
