//! Fig. 3 (main result): cluster TFLOPs on clusters A/B/C, ZeRO-0..3,
//! five systems — weak-homogeneous, strong-homogeneous, DeepSpeed
//! (uniform), Whale (FLOPs-proportional), Poplar.
//!
//! Expected shape (paper): Poplar >= all baselines everywhere;
//! 1.02-3.92x over DeepSpeed; Whale ≈ DeepSpeed on cluster A (equal
//! FLOPs ratings hide the memory gap); biggest wins in ZeRO-2/3.

use anyhow::{anyhow, Result};

use super::{eval_system, gbs_samples, homogeneous_subcluster};
use crate::cluster::{self, ClusterSpec};
use crate::config::model::require;
use crate::config::Strategy;
use crate::metrics::Table;

/// The five systems of the figure, in presentation order.
pub const SYSTEMS: &[&str] = &["weak-homog", "strong-homog", "deepspeed", "whale", "poplar"];

/// Evaluate one (cluster, stage) column: TFLOPs of the five systems.
pub fn column(cluster: &ClusterSpec, stage: u8, seed: u64) -> Result<Vec<(String, f64)>> {
    let model = require("llama-0.5b")?;
    let gbs = gbs_samples(&model);
    let mut out = Vec::new();

    // group 1 is the weaker GPU in all paper clusters (catalog ordering)
    let weak = homogeneous_subcluster(cluster, 1);
    let strong = homogeneous_subcluster(cluster, 0);
    let r = eval_system(&weak, &model, stage, Strategy::Poplar, gbs, seed)?;
    out.push(("weak-homog".to_string(), r.tflops));
    let r = eval_system(&strong, &model, stage, Strategy::Poplar, gbs, seed)?;
    out.push(("strong-homog".to_string(), r.tflops));
    let r = eval_system(cluster, &model, stage, Strategy::Uniform, gbs, seed)?;
    out.push(("deepspeed".to_string(), r.tflops));
    let r = eval_system(cluster, &model, stage, Strategy::Flops, gbs, seed)?;
    out.push(("whale".to_string(), r.tflops));
    let r = eval_system(cluster, &model, stage, Strategy::Poplar, gbs, seed)?;
    out.push(("poplar".to_string(), r.tflops));
    Ok(out)
}

/// Run the full figure.
pub fn run() -> Result<Table> {
    let mut table = Table::new(&["cluster", "stage", "system", "tflops", "vs_deepspeed"]);
    for cluster in [cluster::cluster_a(), cluster::cluster_b(), cluster::cluster_c()] {
        for stage in 0..4u8 {
            let col = column(&cluster, stage, 1000 + stage as u64)?;
            let ds = col
                .iter()
                .find(|(s, _)| s == "deepspeed")
                .ok_or_else(|| anyhow!("column is missing the deepspeed baseline"))?
                .1;
            for (system, tflops) in &col {
                table.row(&[
                    cluster.name.clone(),
                    format!("ZeRO-{stage}"),
                    system.clone(),
                    format!("{tflops:.1}"),
                    format!("{:.2}x", tflops / ds),
                ]);
            }
        }
    }
    Ok(table)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tflops_of(col: &[(String, f64)], sys: &str) -> f64 {
        col.iter().find(|(s, _)| s == sys).unwrap().1
    }

    #[test]
    fn poplar_wins_on_cluster_c_all_stages() {
        let c = cluster::cluster_c();
        for stage in 0..4u8 {
            let col = column(&c, stage, 7).unwrap();
            let pop = tflops_of(&col, "poplar");
            for sys in ["deepspeed", "whale"] {
                let other = tflops_of(&col, sys);
                assert!(
                    pop >= other * 0.99,
                    "stage {stage}: poplar {pop:.1} vs {sys} {other:.1}"
                );
            }
        }
    }

    #[test]
    fn speedup_band_over_deepspeed() {
        // the paper's headline: 1.02 ~ 3.92x over DeepSpeed
        let mut ratios = vec![];
        for cluster in [cluster::cluster_a(), cluster::cluster_b(), cluster::cluster_c()] {
            for stage in [1u8, 3] {
                let col = column(&cluster, stage, 11).unwrap();
                ratios.push(tflops_of(&col, "poplar") / tflops_of(&col, "deepspeed"));
            }
        }
        let max = ratios.iter().cloned().fold(0.0, f64::max);
        let min = ratios.iter().cloned().fold(f64::MAX, f64::min);
        assert!(min >= 0.99, "poplar should never lose: min {min:.3}");
        assert!(max > 1.15, "poplar should clearly win somewhere: max {max:.3}");
    }

    #[test]
    fn whale_close_to_deepspeed_on_cluster_a() {
        // equal FLOPs ratings on cluster A -> Whale can't see the
        // memory-only heterogeneity (paper's observation)
        let col = column(&cluster::cluster_a(), 1, 13).unwrap();
        let whale = tflops_of(&col, "whale");
        let ds = tflops_of(&col, "deepspeed");
        assert!((whale / ds - 1.0).abs() < 0.15, "whale {whale:.1} vs ds {ds:.1}");
        // while poplar exploits it
        assert!(tflops_of(&col, "poplar") > ds);
    }

    #[test]
    fn hetero_poplar_beats_both_homogeneous_halves() {
        let col = column(&cluster::cluster_c(), 1, 17).unwrap();
        let pop = tflops_of(&col, "poplar");
        assert!(pop > tflops_of(&col, "weak-homog"));
        assert!(pop > tflops_of(&col, "strong-homog"));
    }
}
