//! Autoscaling experiment: the cost/throughput frontier of candidate
//! GPU offers against the running cluster.
//!
//! Cluster C (4× A800-80G + 4× V100S-32G), llama-0.5b, ZeRO-1, the
//! paper's 2M-token global batch, noise-free truth curves. Four
//! candidate types are offered to the default policy
//! (`horizon_s = 300`, `min_gain = 2%`):
//!
//! * **A800-80G** — cached curve (the type is live): decided with zero
//!   profiling calls, large gain → **accept**, and its operating point
//!   sits on the cost/throughput frontier;
//! * **V100S-32G** — cached, moderate gain → **accept**, but its
//!   operating point is *dominated* (the RTX4090 estimate gives more
//!   samples/s per dollar): throughput-positive is not the same as
//!   cost-efficient;
//! * **RTX4090** — no cached curve: the prediction runs on a
//!   catalog-FLOPs-scaled estimate, clears the bar → **defer**
//!   (profile before committing), never an outright accept;
//! * **T4** — weak and uncached: the admission stall (measured
//!   `ckpt::reshard` movement + estimated Alg. 1 time) exceeds the
//!   gain amortized over the 300 s horizon → **reject**.
//!
//! The `frontier` column marks the Pareto set over (samples/s,
//! $/1000 samples), baseline row included.

use anyhow::{anyhow, Result};

use super::gbs_samples;
use crate::autoscale::{self, AutoscaleOptions, AutoscaleReport};
use crate::cluster::{catalog, GpuSpec, LinkKind};
use crate::config::model::{preset, ModelSpec};
use crate::curves::{PerfCurve, ProfiledPoint};
use crate::elastic::ElasticPlanner;
use crate::metrics::Table;
use crate::netsim::NetSim;

/// The candidate GPU types offered, in presentation order.
pub const OFFERS: &[&str] = &["A800-80G", "V100S-32G", "RTX4090", "T4"];

fn truth_curve(spec: &GpuSpec, model: &ModelSpec, mbs: usize) -> Result<PerfCurve> {
    let pts: Vec<ProfiledPoint> = (1..=mbs)
        .map(|b| ProfiledPoint {
            batch: b,
            step_time_s: spec.compute_time(
                (b as u64 * model.seq) as f64,
                model.flops_per_token(),
                model.n_layers as usize,
            ),
        })
        .collect();
    PerfCurve::fit(pts, mbs).map_err(|e| anyhow!("curve: {e}"))
}

/// Evaluate the four offers against the cluster-C planner.
pub fn report() -> Result<AutoscaleReport> {
    let model = preset("llama-0.5b").ok_or_else(|| anyhow!("missing preset"))?;
    let gbs = gbs_samples(&model);
    let mut planner = ElasticPlanner::new(1, gbs, &model.name, model.param_count(), 16);
    for (gpu, mbs) in [
        ("A800-80G", 48usize),
        ("A800-80G", 48),
        ("A800-80G", 48),
        ("A800-80G", 48),
        ("V100S-32G", 16),
        ("V100S-32G", 16),
        ("V100S-32G", 16),
        ("V100S-32G", 16),
    ] {
        let slot = planner.add_slot(gpu);
        if planner.slots()[slot].curve.is_none() {
            let spec = catalog::spec_or_panic(gpu);
            planner
                .install_curve(slot, truth_curve(&spec, &model, mbs)?, false)
                .map_err(|e| anyhow!("install: {e}"))?;
        }
    }
    let net = NetSim::from_link(8, LinkKind::Ib);
    planner.replan(&net).map_err(|e| anyhow!("initial plan: {e}"))?;

    let offers: Vec<String> = OFFERS.iter().map(|s| s.to_string()).collect();
    autoscale::evaluate_offers(&planner, &net, &model, &offers, &AutoscaleOptions::default())
        .map_err(|e| anyhow!("autoscale: {e}"))
}

/// Run the full figure (rendering shared with `poplar autoscale` via
/// [`autoscale::report_table`]).
pub fn run() -> Result<Table> {
    Ok(autoscale::report_table(&report()?))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autoscale::Decision;

    #[test]
    fn at_least_one_accept_and_one_stall_bound_reject() {
        let rep = report().unwrap();
        let accepts: Vec<_> =
            rep.decisions.iter().filter(|d| d.decision == Decision::Accept).collect();
        assert!(!accepts.is_empty(), "need >= 1 accepted offer");
        // the acceptance bar: every accepted offer's amortized gain
        // exceeds its measured ckpt::reshard penalty, off a cached curve
        // with zero profiling
        for d in &accepts {
            assert!(d.curve_cached, "{}: accepts must use measured curves", d.gpu);
            assert_eq!(d.profile_est_s, 0.0, "{}: zero profiling calls", d.gpu);
            assert!(d.gain_samples > 0.0);
            assert!(
                (d.post_rate - d.pre_rate) * rep.horizon_s
                    > d.post_rate * d.reshard_penalty_s,
                "{}: amortized gain must exceed the reshard penalty",
                d.gpu
            );
        }
        // and at least one offer is declined because its stall exceeds
        // the amortized gain (the T4 at a 300 s tenure)
        let rejects: Vec<_> =
            rep.decisions.iter().filter(|d| d.decision == Decision::Reject).collect();
        assert!(!rejects.is_empty(), "need >= 1 declined offer");
        assert!(
            rejects.iter().any(|d| d.gain_samples <= 0.0),
            "some reject must be stall-bound: {rejects:?}"
        );
    }

    #[test]
    fn uncached_candidates_never_accept_outright() {
        let rep = report().unwrap();
        for d in rep.decisions.iter().filter(|d| !d.curve_cached) {
            assert_ne!(
                d.decision,
                Decision::Accept,
                "{}: estimate-based decisions must defer or reject",
                d.gpu
            );
            assert!(d.profile_est_s > 0.0, "{}: uncached admission prices Alg. 1", d.gpu);
        }
    }

    #[test]
    fn frontier_is_pareto_and_contains_an_accept() {
        let rep = report().unwrap();
        let mut pts =
            vec![(rep.baseline_rate, rep.baseline_cost_per_ksample, rep.baseline_on_frontier)];
        for d in &rep.decisions {
            pts.push((d.post_rate, d.cost_per_ksample, d.on_frontier));
        }
        for (i, &(r, c, on)) in pts.iter().enumerate() {
            let dominated = pts.iter().enumerate().any(|(j, &(rj, cj, _))| {
                j != i && rj >= r && cj <= c && (rj > r || cj < c)
            });
            assert_eq!(on, !dominated, "point {i}");
        }
        // the strongest accepted offer has the highest rate of all
        // points, so it must sit on the frontier
        assert!(
            rep.decisions
                .iter()
                .any(|d| d.decision == Decision::Accept && d.on_frontier),
            "an accepted offer should be Pareto-optimal"
        );
    }

    #[test]
    fn figure_is_deterministic_and_complete() {
        let a = run().unwrap().to_markdown();
        let b = run().unwrap().to_markdown();
        assert_eq!(a, b);
        assert_eq!(run().unwrap().len(), 1 + OFFERS.len());
    }
}
