//! Fig. 7 (appendix): cubic-spline interpolation accuracy — the gap
//! between the interpolated performance curve and dense ground truth is
//! "almost zero" on the A800 running the 0.5B Llama.
//!
//! We profile with Alg. 1 (sparse, noisy points), fit the spline, and
//! compare against the noise-free device model at *every* batch size.

use anyhow::Result;

use super::{profile, NOISE_SIGMA};
use crate::cluster::{catalog, ClusterSpec, LinkKind};
use crate::config::model::require;
use crate::coordinator::fit_curves;
use crate::metrics::Table;

/// Run the accuracy check.
pub fn run() -> Result<Table> {
    let model = require("llama-0.5b")?;
    let cluster = ClusterSpec::new("a800-solo", &[("A800-80G", 1, LinkKind::Nvlink)],
                                   LinkKind::Ib);
    let prof = profile(&cluster, &model, 1, NOISE_SIGMA, 77)?;
    let curves = fit_curves(&prof)?;
    let curve = &curves[0];
    let spec = catalog::spec_or_panic("A800-80G");

    let mut table = Table::new(&["batch", "true_time_s", "spline_time_s", "rel_err",
                                 "is_knot"]);
    let mut errs = Vec::new();
    for b in 1..=curve.mbs() {
        let truth = spec.compute_time(
            (b as u64 * model.seq) as f64,
            model.flops_per_token(),
            model.n_layers as usize,
        );
        let est = curve.time_at(b as f64);
        let rel = (est - truth).abs() / truth;
        errs.push(rel);
        let is_knot = curve.points().iter().any(|p| p.batch == b);
        table.row(&[
            b.to_string(),
            format!("{truth:.4}"),
            format!("{est:.4}"),
            format!("{rel:.4}"),
            is_knot.to_string(),
        ]);
    }
    let mean = errs.iter().sum::<f64>() / errs.len() as f64;
    table.row(&["mean".into(), String::new(), String::new(), format!("{mean:.4}"),
                String::new()]);
    Ok(table)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn interpolation_gap_is_small() {
        let t = run().unwrap();
        let last = t.to_csv();
        let mean_line = last.lines().last().unwrap();
        let mean: f64 = mean_line.split(',').nth(3).unwrap().parse().unwrap();
        // the paper says "almost zero"; with 1.5% measurement noise on
        // the knots, a few percent mean relative error is that regime
        assert!(mean < 0.03, "mean rel err {mean}");
    }
}
