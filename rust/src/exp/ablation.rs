//! Appendix ablation: which of Poplar's ingredients buy the speedup?
//!
//! Variants, all evaluated on cluster C (ZeRO-1 for the gmbs path,
//! ZeRO-3 for the t-sweep path):
//!
//! * `poplar-full`     — everything (Alg. 1 + spline + Alg. 2);
//! * `no-spline`       — curves replaced by nearest-profiled-point
//!   lookup (no interpolation between the sparse probes);
//! * `no-finegrained`  — wall-time measurement replaced by the FLOPs
//!   rating (keeps the rest of Alg. 2) — isolates the Fig. 8 effect;
//! * `no-tsweep`       — ZeRO-2/3 t-sweep replaced by the single largest
//!   feasible micro-batch (maximum imbalance tolerance, fewest rounds);
//! * `uniform`         — the DeepSpeed baseline for reference.

use anyhow::{anyhow, Result};

use super::{gbs_samples, plan_with, profile, score, NOISE_SIGMA};
use crate::allocator::{self, Plan, RankPlan};
use crate::cluster::{self, ClusterSpec};
use crate::config::model::ModelSpec;
use crate::config::{model::preset, model::require, Strategy};
use crate::coordinator::fit_curves;
use crate::curves::{PerfCurve, ProfiledPoint};
use crate::metrics::Table;
use crate::netsim::NetSim;
use crate::profiler::ClusterProfile;

/// Curves degraded to a single probe (the `no-spline` variant): without
/// curve construction a system can only extrapolate linearly from its
/// one-batch measurement — constant per-sample speed, no saturation
/// model. That distorts *relative* GPU speeds (each GPU saturates
/// differently; the whole point of Fig. 6/7).
fn degrade_to_single_probe(profile_: &ClusterProfile) -> Result<Vec<PerfCurve>> {
    profile_
        .ranks
        .iter()
        .map(|r| {
            let p1 = r.points.first().copied().ok_or_else(|| anyhow!("no probes"))?;
            let pts = vec![
                p1,
                ProfiledPoint {
                    batch: r.mbs.max(p1.batch + 1),
                    step_time_s: p1.step_time_s
                        * (r.mbs.max(p1.batch + 1) as f64 / p1.batch as f64),
                },
            ];
            PerfCurve::fit(pts, r.mbs).map_err(|e| anyhow!("degrade: {e}"))
        })
        .collect()
}

/// The `no-tsweep` variant for ZeRO-2/3: everyone runs at mbs, gas
/// follows.
fn plan_max_batch(curves: &[PerfCurve], stage: u8, gbs: usize, net: &NetSim,
                  psi: u64) -> Result<Plan> {
    let batches: Vec<usize> = curves.iter().map(|c| c.mbs()).collect();
    let msum: usize = batches.iter().sum();
    let gas = gbs.div_ceil(msum);
    let mut last: Vec<usize> = batches.clone();
    let mut excess = msum * gas - gbs;
    let mut k = 0;
    while excess > 0 {
        let i = k % batches.len();
        if last[i] > 0 {
            last[i] -= 1;
            excess -= 1;
        }
        k += 1;
    }
    let t_step = batches
        .iter()
        .zip(curves)
        .map(|(&b, c)| c.time_at(b as f64))
        .fold(0.0, f64::max);
    let comm = net
        .per_microstep_comm_time(stage, psi)
        .map_err(|e| anyhow!("no-tsweep comm: {e}"))?;
    let wall = (t_step + comm) * gas as f64;
    Ok(Plan {
        stage,
        gbs,
        ranks: (0..curves.len())
            .map(|i| RankPlan {
                rank: i,
                micro_batch: batches[i],
                samples_per_iter: batches[i] * (gas - 1) + last[i],
                grad_accum_steps: gas,
                last_batch: last[i],
            })
            .collect(),
        predicted_iter_s: wall,
        strategy: "no-tsweep".into(),
    })
}

/// Evaluate all ablation variants at one stage.
pub fn column(cluster: &ClusterSpec, model: &ModelSpec, stage: u8) -> Result<Vec<(String, f64)>> {
    let gbs = gbs_samples(model);
    let net = NetSim::from_cluster(cluster);
    let psi = model.param_count();
    let prof = profile(cluster, model, stage, NOISE_SIGMA, 4000 + stage as u64)?;
    let stage = prof.stage;
    let mut out = Vec::new();

    // full poplar
    let plan = plan_with(&prof, Strategy::Poplar, gbs, &net, model)?;
    out.push(("poplar-full".to_string(), score(cluster, model, &plan)?.tflops));

    // no-spline
    let curves = degrade_to_single_probe(&prof)?;
    let plan = allocator::plan(&curves, stage, gbs, &net, psi)
        .map_err(|e| anyhow!("no-spline plan: {e}"))?;
    out.push(("no-spline".to_string(), score(cluster, model, &plan)?.tflops));

    // no-finegrained (FLOPs-driven shares, poplar's machinery otherwise)
    let plan = plan_with(&prof, Strategy::Flops, gbs, &net, model)?;
    out.push(("no-finegrained".to_string(), score(cluster, model, &plan)?.tflops));

    // no-tsweep (only different for stages 2/3)
    if stage >= 2 {
        let curves = fit_curves(&prof)?;
        let plan = plan_max_batch(&curves, stage, gbs, &net, psi)?;
        plan.validate().map_err(|e| anyhow!("no-tsweep: {e}"))?;
        out.push(("no-tsweep".to_string(), score(cluster, model, &plan)?.tflops));
    }

    // uniform reference
    let plan = plan_with(&prof, Strategy::Uniform, gbs, &net, model)?;
    out.push(("uniform".to_string(), score(cluster, model, &plan)?.tflops));
    Ok(out)
}

/// Run the ablation on cluster C, stages 1 and 3.
pub fn run() -> Result<Table> {
    let cluster = cluster::cluster_c();
    let model = require("llama-0.5b")?;
    let mut table = Table::new(&["stage", "variant", "tflops", "vs_full"]);
    for stage in [1u8, 3] {
        let col = column(&cluster, &model, stage)?;
        let full = col[0].1;
        for (variant, tflops) in &col {
            table.row(&[
                format!("ZeRO-{stage}"),
                variant.clone(),
                format!("{tflops:.1}"),
                format!("{:.3}", tflops / full),
            ]);
        }
    }
    Ok(table)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_poplar_is_best_or_tied() {
        let cluster = cluster::cluster_c();
        let model = preset("llama-0.5b").unwrap();
        for stage in [1u8, 3] {
            let col = column(&cluster, &model, stage).unwrap();
            let full = col[0].1;
            for (variant, tflops) in &col[1..] {
                assert!(
                    full >= tflops * 0.98,
                    "stage {stage}: {variant} ({tflops:.1}) beat full ({full:.1})"
                );
            }
        }
    }

    #[test]
    fn each_component_contributes_somewhere() {
        let cluster = cluster::cluster_c();
        let model = preset("llama-0.5b").unwrap();
        let col = column(&cluster, &model, 3).unwrap();
        let full = col[0].1;
        // at ZeRO-3 at least one ablated variant must be clearly worse
        let worst = col[1..].iter().map(|(_, t)| *t).fold(f64::MAX, f64::min);
        assert!(worst < full * 0.97, "ablations should hurt at ZeRO-3");
    }
}
