//! Runtime integration tests against the real AOT artifacts
//! (`artifacts/tiny`). `make artifacts` builds them; if they are absent
//! (e.g. a bare `cargo test` before `make artifacts`) the tests skip
//! with a notice rather than fail, matching the Makefile's ordering.

use poplar::data::corpus::CorpusStream;
use poplar::data::TokenSource;
use poplar::runtime::{artifacts_dir, load_init_params, Engine};
use poplar::train::{decompose_batch, Trainer, VirtualGpu};
use std::path::PathBuf;

fn tiny_dir() -> Option<PathBuf> {
    // tests run from the crate root; also accept the parent (workspace)
    for cand in [artifacts_dir("tiny"), PathBuf::from("../artifacts/tiny")] {
        if cand.join("meta.txt").exists() {
            return Some(cand);
        }
    }
    eprintln!("SKIP: artifacts/tiny missing — run `make artifacts` first");
    None
}

#[test]
fn meta_and_params_roundtrip() {
    let Some(dir) = tiny_dir() else { return };
    let engine = Engine::open(&dir).unwrap();
    let meta = engine.meta();
    assert_eq!(meta.preset, "tiny");
    assert!(meta.use_pallas, "artifacts must embed the Pallas kernels");
    assert!(meta.batch_variants.contains(&1));
    let params = load_init_params(&dir, meta).unwrap();
    assert_eq!(params.len(), meta.params.len());
    let total: usize = params.iter().map(Vec::len).sum();
    assert_eq!(total, meta.param_count);
    // embed is scaled-normal: mean ~0, nontrivial variance
    let embed = &params[0];
    let mean: f32 = embed.iter().sum::<f32>() / embed.len() as f32;
    assert!(mean.abs() < 0.01);
}

#[test]
fn fused_step_decreases_loss() {
    let Some(dir) = tiny_dir() else { return };
    let mut engine = Engine::open(&dir).unwrap();
    let meta = engine.meta().clone();
    let mut params = load_init_params(&dir, &meta).unwrap();
    let mut momenta: Vec<Vec<f32>> = params.iter().map(|p| vec![0.0; p.len()]).collect();
    let mut src = CorpusStream::new(meta.vocab as u32);
    let b = meta.batch_variants[0];
    let tokens = src.batch(b, meta.seq + 1);
    let mut losses = vec![];
    for _ in 0..4 {
        let out = engine.run_fused_step(b, &mut params, &mut momenta, &tokens).unwrap();
        losses.push(out.loss);
    }
    assert!(
        losses.last().unwrap() < losses.first().unwrap(),
        "losses {losses:?}"
    );
    // initial loss near ln(vocab): the model starts uniform
    let ln_v = (meta.vocab as f32).ln();
    assert!((losses[0] - ln_v).abs() < 1.5, "loss {} vs ln(vocab) {ln_v}", losses[0]);
}

#[test]
fn grad_plus_apply_matches_fused_step() {
    // the multi-rank path (grad + weighted average of ONE rank + apply)
    // must reproduce the fused single-rank executable bit-for-bit-ish
    let Some(dir) = tiny_dir() else { return };
    let mut engine = Engine::open(&dir).unwrap();
    let meta = engine.meta().clone();
    let mut src = CorpusStream::new(meta.vocab as u32);
    let b = meta.batch_variants[0];
    let tokens = src.batch(b, meta.seq + 1);

    let params0 = load_init_params(&dir, &meta).unwrap();
    let momenta0: Vec<Vec<f32>> = params0.iter().map(|p| vec![0.0; p.len()]).collect();

    // path A: fused
    let mut p_a = params0.clone();
    let mut m_a = momenta0.clone();
    let loss_a = engine.run_fused_step(b, &mut p_a, &mut m_a, &tokens).unwrap().loss;

    // path B: grad + apply
    let mut p_b = params0.clone();
    let mut m_b = momenta0;
    let out = engine.run_grad_step(b, &p_b, &tokens).unwrap();
    engine.run_apply_update(&mut p_b, &mut m_b, &out.grads).unwrap();

    assert!((loss_a - out.loss).abs() < 1e-5, "{loss_a} vs {}", out.loss);
    for (a, b_) in p_a.iter().zip(&p_b) {
        for (x, y) in a.iter().zip(b_) {
            assert!((x - y).abs() < 1e-5, "param divergence {x} vs {y}");
        }
    }
}

#[test]
fn heterogeneous_weighted_training_runs() {
    // two virtual GPUs with different speeds/memory; plan + 3 iterations
    let Some(dir) = tiny_dir() else { return };
    let mut trainer = Trainer::open(&dir).unwrap();
    let meta = trainer.engine().meta().clone();
    let max_b = *meta.batch_variants.iter().max().unwrap();
    let vgpus = vec![
        VirtualGpu { name: "fast".into(), slowdown: 1.0, max_batch: max_b },
        VirtualGpu { name: "slow".into(), slowdown: 3.0, max_batch: 2 },
    ];
    let mut src = CorpusStream::new(meta.vocab as u32);
    let curves = trainer.profile_virtual(&vgpus, &mut src, 1).unwrap();
    assert!(curves[0].peak_speed() > curves[1].peak_speed());

    let net = poplar::netsim::NetSim::from_link(2, poplar::cluster::LinkKind::Pcie);
    let plan = poplar::allocator::plan(&curves, 1, 6, &net, meta.param_count as u64).unwrap();
    // the fast rank must get the lion's share
    assert!(plan.ranks[0].samples_per_iter > plan.ranks[1].samples_per_iter);

    let logs = trainer.train(&plan, &vgpus, &mut src, 3, 0).unwrap();
    assert_eq!(logs.len(), 3);
    assert!(logs.iter().all(|l| l.loss.is_finite() && l.loss > 0.0));
    assert!(logs[2].loss < logs[0].loss + 0.1, "{logs:?}");
}

#[test]
fn batch_variant_errors_are_clear() {
    let Some(dir) = tiny_dir() else { return };
    let mut engine = Engine::open(&dir).unwrap();
    let meta = engine.meta().clone();
    let params = load_init_params(&dir, &meta).unwrap();
    let bogus_b = 1000;
    let tokens = vec![0i32; bogus_b * (meta.seq + 1)];
    let err = engine.run_grad_step(bogus_b, &params, &tokens).unwrap_err();
    assert!(err.to_string().contains("no compiled variant"), "{err}");
}

#[test]
fn decompose_respects_compiled_variants() {
    let Some(dir) = tiny_dir() else { return };
    let engine = Engine::open(&dir).unwrap();
    let variants = &engine.meta().batch_variants;
    for b in 1..=2 * variants.iter().max().unwrap() {
        let parts = decompose_batch(b, variants);
        assert_eq!(parts.iter().sum::<usize>(), b);
        assert!(parts.iter().all(|p| variants.contains(p)));
    }
}
