//! Property tests for the virtual-rank pipeline layer: the per-member
//! Alg. 1 memory bound must survive arbitrary membership events, the
//! composed group curve must not punish adding an equal member, and the
//! grouping arm must be a strict no-op on fleets where every card hosts
//! the model solo (the pre-pipeline paths stay byte-identical).

use poplar::autoscale::synthesize_curve;
use poplar::cluster::{catalog, LinkKind};
use poplar::config::model::preset;
use poplar::elastic::ElasticPlanner;
use poplar::exp::fig_pipeline;
use poplar::netsim::NetSim;
use poplar::pipeline;
use poplar::policy::{self, RoundOptions};

/// Deterministic degradation sequence over the longctx fleet: after
/// every membership event each alive group slot still satisfies the
/// group-aware memory bound at the current stage and fleet size, every
/// re-planned layer partition respects each member's
/// `member_max_layers` bound, and once the group falls below
/// `MIN_GROUP_SIZE` the whole virtual rank dissolves as one unit.
#[test]
fn group_slots_keep_member_bounds_through_membership_events() {
    let m = preset("longctx-0.4b").unwrap();
    let psi = m.param_count();
    let net = NetSim::from_link(2, LinkKind::Ib);
    let plans = fig_pipeline::bootstrap_groups(&net).unwrap();
    let gbs = poplar::exp::gbs_samples(&m);
    let mut p = ElasticPlanner::new(3, gbs, &m.name, psi, 32);
    for gp in &plans {
        p.add_group_slot(gp);
    }
    p.replan(&net).unwrap();

    let check_invariants = |p: &ElasticPlanner| {
        let n_active = p.slots().iter().filter(|s| s.alive).count();
        for s in p.slots().iter().filter(|s| s.alive && !s.members.is_empty()) {
            assert!(
                pipeline::group_feasible(&s.members, &m, psi, p.stage(), n_active),
                "slot {} ({}) violates the group memory bound",
                s.slot,
                s.gpu
            );
        }
    };
    check_invariants(&p);

    // slot 0 loses its weakest member twice (quad -> trio -> pair); the
    // survivors are re-planned in place and the slot stays alive
    for expect_members in [3usize, 2] {
        let gp = p.lose_group_member(0, 0, &net).unwrap().expect("group must survive");
        assert_eq!(gp.members.len(), expect_members);
        assert_eq!(gp.ks.len(), expect_members);
        assert_eq!(gp.ks.iter().sum::<u64>(), m.n_layers);
        let gsize = gp.members.len();
        for (i, (name, &k)) in gp.members.iter().zip(&gp.ks).enumerate() {
            let spec = catalog::spec(name).unwrap();
            let bound = pipeline::member_max_layers(
                &spec,
                &m,
                psi,
                gp.stage,
                gp.n_virtual,
                gp.chunk,
                gsize - i,
            );
            assert!(k <= bound, "{name} holds {k} layers over its bound {bound}");
        }
        assert_eq!(p.slots()[0].members.len(), expect_members);
        assert!(p.slots()[0].alive);
        check_invariants(&p);
        p.replan(&net).unwrap();
    }

    // a pair losing a member leaves one card — below MIN_GROUP_SIZE the
    // virtual rank leaves the job whole, and the fleet replans around it
    assert!(p.lose_group_member(0, 0, &net).unwrap().is_none());
    assert!(!p.slots()[0].alive);
    check_invariants(&p);
    p.replan(&net).unwrap();
    assert_eq!(p.plan().unwrap().ranks.len(), 1);
}

/// Adding an equal member to a balanced group must not reduce its
/// speed: each member's layer share (and so the straggler slot time)
/// shrinks faster than the fill/drain overhead grows.
#[test]
fn composed_curve_speed_is_monotone_in_member_count() {
    let m = preset("llama-0.5b").unwrap();
    let net = NetSim::from_link(2, LinkKind::Ib);
    let mut last = 0.0f64;
    for gsize in [2usize, 3, 4] {
        let specs: Vec<_> = (0..gsize).map(|_| catalog::spec("T4").unwrap()).collect();
        let ks: Vec<u64> = vec![m.n_layers / gsize as u64; gsize];
        let curve = pipeline::compose_curve(&specs, &ks, &m, 1, &net).unwrap();
        let speed = curve.speed_at(8.0);
        assert!(speed > 0.0);
        assert!(
            speed >= last,
            "adding an equal member must not slow the group: \
             {gsize} members at {speed} vs {last}"
        );
        last = speed;
    }
}

/// On a fleet where every offer hosts the model solo, arming
/// `allow_pipeline` must change nothing: no grouping is proposed and
/// the round report is byte-identical to the singleton path. Ordinary
/// slots carry no members.
#[test]
fn allow_pipeline_is_identity_on_a_solo_feasible_fleet() {
    let m = preset("llama-0.5b").unwrap();
    let stage = 1u8;
    let mut p = ElasticPlanner::new(stage, 16, &m.name, m.param_count(), 64);
    for gpu in ["A800-80G", "V100S-32G"] {
        let slot = p.add_slot(gpu);
        assert!(p.slots()[slot].members.is_empty(), "single-GPU slots carry no members");
        if p.slots()[slot].curve.is_none() {
            let c = synthesize_curve(gpu, &m, stage, 2).unwrap();
            p.install_curve(slot, c, false).unwrap();
        }
    }
    for gpu in ["A800-80G", "V100S-32G", "T4"] {
        let c = synthesize_curve(gpu, &m, stage, 2).unwrap();
        p.install_stage_curve(gpu, stage, c).unwrap();
    }
    let net = NetSim::from_link(2, LinkKind::Ib);
    p.replan(&net).unwrap();

    let offers: Vec<String> = ["T4", "A800-80G"].iter().map(|s| s.to_string()).collect();
    let off = policy::decide_round(&p, &net, &m, &offers, &RoundOptions::default()).unwrap();
    let on = policy::decide_round(
        &p,
        &net,
        &m,
        &offers,
        &RoundOptions { allow_pipeline: true, ..Default::default() },
    )
    .unwrap();
    assert!(off.grouping.is_none(), "no grouping without the flag");
    assert!(on.grouping.is_none(), "solo-feasible offers must never be grouped");
    assert_eq!(policy::round_rows(&off), policy::round_rows(&on));
}
