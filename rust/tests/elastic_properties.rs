//! Property tests over the elastic-runtime invariants (same discipline
//! as `properties.rs`: deterministic xorshift over many seeds, seeds
//! printed on failure). The invariants:
//!
//! 1. after ANY feasible event sequence the active plan `validate()`s
//!    and covers `gbs` exactly;
//! 2. no plan ever references a departed rank;
//! 3. a re-join of a known `(gpu, model, stage)` skips re-profiling
//!    (curve-cache hit);
//! 4. a rank that slows down never gains samples after the replan;
//! 5. cache eviction never drops a curve backing a live rank;
//! 6. with a stage policy installed, the stage chosen by ANY replan
//!    satisfies the Alg. 1 memory bound for every live rank at the new
//!    group size, and the plan still validates and covers `gbs`;
//! 7. ANY interleaving of bandwidth-drift and membership events keeps
//!    every replanned plan valid, covering `gbs`, memory-bound-clean —
//!    and a `BwDrift` event alone never dirties the plan (only the
//!    monitor's sustained observations may);
//! 8. the `BwMonitor` estimate stays inside `[min observed, spec]`
//!    under ANY sample stream, and a single outlier between steady
//!    spec-level samples never moves it or signals.

use std::collections::HashSet;

use poplar::cluster::catalog;
use poplar::config::model::{preset, ModelSpec};
use poplar::curves::{PerfCurve, ProfiledPoint};
use poplar::elastic::{CurveCache, CurveKey, ElasticError, ElasticPlanner, StagePolicy, XorShift};
use poplar::memmodel;
use poplar::netsim::NetSim;
use poplar::cluster::LinkKind;

const GPUS: &[&str] = &["A100-80G", "A100-40G", "A800-80G", "V100-16G", "V100S-32G", "T4"];

/// Ground-truth curve for a GPU type, optionally slowed by `factor`.
fn device_curve(gpu: &str, mbs: usize, factor: f64) -> PerfCurve {
    let g = catalog::spec_or_panic(gpu);
    let m = preset("llama-0.5b").unwrap();
    let pts: Vec<ProfiledPoint> = (1..=mbs)
        .map(|b| ProfiledPoint {
            batch: b,
            step_time_s: factor
                * g.compute_time(
                    (b as u64 * m.seq) as f64,
                    m.flops_per_token(),
                    m.n_layers as usize,
                ),
        })
        .collect();
    PerfCurve::fit(pts, mbs).unwrap()
}

fn mbs_for(rng: &mut XorShift) -> usize {
    rng.range(6, 48) as usize
}

/// Build a planner with `n` profiled ranks of random GPU types.
fn random_planner(rng: &mut XorShift, n: usize, stage: u8, gbs: usize) -> ElasticPlanner {
    let m = preset("llama-0.5b").unwrap();
    let mut p = ElasticPlanner::new(stage, gbs, &m.name, m.param_count(), 16);
    for _ in 0..n {
        let gpu = GPUS[(rng.next() as usize) % GPUS.len()];
        let slot = p.add_slot(gpu);
        if p.needs_profile().contains(&slot) {
            let c = device_curve(gpu, mbs_for(rng), 1.0);
            p.install_curve(slot, c, false).unwrap();
        }
    }
    p
}

/// Simulate the profiling the leader would do for curve-less slots.
fn profile_missing(rng: &mut XorShift, p: &mut ElasticPlanner) {
    for slot in p.needs_profile() {
        let gpu = p.slots()[slot].gpu.clone();
        let c = device_curve(&gpu, mbs_for(rng), 1.0);
        p.install_curve(slot, c, false).unwrap();
    }
}

#[test]
fn prop_plan_valid_and_covers_gbs_after_any_event_sequence() {
    for seed in 0..60u64 {
        let mut rng = XorShift::new(seed);
        let stage = (seed % 4) as u8;
        let n = rng.range(2, 5) as usize;
        let gbs = rng.range(16, 1024) as usize;
        let mut p = random_planner(&mut rng, n, stage, gbs);

        for step in 0..rng.range(1, 8) {
            // random event: 0 = lose, 1 = join, 2 = slow (drift override)
            match rng.range(0, 2) {
                0 => {
                    let active = p.active_slots();
                    let victim = active[(rng.next() as usize) % active.len()];
                    // losing the last rank must fail loudly, not corrupt state
                    let _ = p.lose_slot(victim);
                }
                1 => {
                    let gpu = GPUS[(rng.next() as usize) % GPUS.len()];
                    p.add_slot(gpu);
                    profile_missing(&mut rng, &mut p);
                }
                _ => {
                    let active = p.active_slots();
                    let slot = active[(rng.next() as usize) % active.len()];
                    let gpu = p.slots()[slot].gpu.clone();
                    let factor = 1.5 + rng.uniform() * 2.0;
                    p.install_curve(slot, device_curve(&gpu, mbs_for(&mut rng), factor), true)
                        .unwrap();
                }
            }
            let n_active = p.active_slots().len();
            let net = NetSim::from_link(n_active, LinkKind::Ib);
            let plan = p
                .replan(&net)
                .unwrap_or_else(|e| panic!("seed {seed} step {step}: {e}"))
                .clone();
            plan.validate().unwrap_or_else(|e| panic!("seed {seed} step {step}: {e}"));
            assert_eq!(plan.total_samples(), gbs, "seed {seed} step {step}");
            assert_eq!(plan.ranks.len(), n_active, "seed {seed} step {step}");
        }
    }
}

#[test]
fn prop_no_plan_references_departed_rank() {
    for seed in 0..60u64 {
        let mut rng = XorShift::new(seed + 500);
        let n = rng.range(3, 6) as usize;
        let mut p = random_planner(&mut rng, n, 1, 256);
        let mut departed: HashSet<usize> = HashSet::new();

        for _ in 0..rng.range(2, 10) {
            let active = p.active_slots();
            if rng.uniform() < 0.5 && active.len() > 1 {
                let victim = active[(rng.next() as usize) % active.len()];
                if p.lose_slot(victim).is_ok() {
                    departed.insert(victim);
                }
            } else {
                let gpu = GPUS[(rng.next() as usize) % GPUS.len()];
                p.add_slot(gpu);
                profile_missing(&mut rng, &mut p);
            }
            let n_active = p.active_slots().len();
            let net = NetSim::from_link(n_active, LinkKind::Ib);
            let plan = p.replan(&net).unwrap().clone();
            // the compact-rank -> slot mapping must never touch a departed slot
            assert_eq!(p.slot_map().len(), plan.ranks.len(), "seed {seed}");
            for &slot in p.slot_map() {
                assert!(
                    !departed.contains(&slot),
                    "seed {seed}: plan references departed slot {slot}"
                );
                assert!(p.slots()[slot].alive, "seed {seed}: slot {slot} not alive");
            }
        }
    }
}

#[test]
fn prop_rejoin_of_known_type_always_hits_cache() {
    for seed in 0..40u64 {
        let mut rng = XorShift::new(seed + 1000);
        let n = rng.range(2, 5) as usize;
        let mut p = random_planner(&mut rng, n, 2, 128);
        let seen: HashSet<String> =
            p.slots().iter().map(|s| s.gpu.to_string()).collect();

        for _ in 0..rng.range(1, 6) {
            // rejoin a type the planner has already profiled at this stage
            let types: Vec<&String> = seen.iter().collect();
            let gpu = types[(rng.next() as usize) % types.len()].clone();
            let slot = p.add_slot(&gpu);
            assert!(
                !p.needs_profile().contains(&slot),
                "seed {seed}: rejoin of known type {gpu} required re-profiling"
            );
        }
        assert!(p.cache().hits() >= 1, "seed {seed}");
    }
}

#[test]
fn prop_slowed_rank_never_gains_samples_after_replan() {
    for seed in 0..60u64 {
        let mut rng = XorShift::new(seed + 2000);
        let stage = (seed % 2) as u8; // ZeRO-0/1: per-rank independent shares
        let n = rng.range(2, 6) as usize;
        let gbs = (n as u64 * rng.range(32, 256)) as usize;
        let mut p = random_planner(&mut rng, n, stage, gbs);
        let net = NetSim::from_link(n, LinkKind::Ib);
        p.replan(&net).unwrap();

        let active = p.active_slots();
        let slot = active[(rng.next() as usize) % active.len()];
        let idx = p.slot_map().iter().position(|&s| s == slot).unwrap();
        let before = p.plan().unwrap().ranks[idx].samples_per_iter;

        // the straggler's curve is re-measured `factor` slower
        let gpu = p.slots()[slot].gpu.clone();
        let mbs = p.slots()[slot].curve.as_ref().unwrap().mbs();
        let factor = 1.5 + rng.uniform() * 2.5;
        p.install_curve(slot, device_curve(&gpu, mbs, factor), true).unwrap();
        p.replan(&net).unwrap();

        let idx = p.slot_map().iter().position(|&s| s == slot).unwrap();
        let after = p.plan().unwrap().ranks[idx].samples_per_iter;
        assert!(
            after <= before,
            "seed {seed}: slowed slot {slot} gained samples ({before} -> {after})"
        );
        assert_eq!(p.plan().unwrap().total_samples(), gbs, "seed {seed}");
    }
}

/// Ground-truth curve for `gpu` at the memory-model `mbs` of
/// `(model, stage, n)`; `None` when fewer than two samples fit (no
/// curve is fittable there). On the simulated substrate the
/// catalog-FLOPs synthesizer IS the noise-free ground truth.
fn model_curve(gpu: &str, model: &ModelSpec, stage: u8, n: usize) -> Option<PerfCurve> {
    poplar::autoscale::synthesize_curve(gpu, model, stage, n).ok()
}

#[test]
fn prop_chosen_stage_always_satisfies_memory_bound() {
    // bert-1.1b makes the search space genuinely constrained: ZeRO-0
    // replicates 16ψ ≈ 21.5 GB and cannot fit the 16 GiB cards, and the
    // partitioned stages get tight at small group sizes — so a wrong
    // feasibility check would surface as a chosen stage whose bound is
    // broken for some live rank.
    let m = preset("bert-1.1b").unwrap();
    let psi = m.param_count();
    const GPUS4: &[&str] = &["A100-80G", "A800-80G", "V100S-32G", "T4"];
    for seed in 0..30u64 {
        let mut rng = XorShift::new(seed + 4000);
        let mut p = ElasticPlanner::new(3, 64, &m.name, psi, 32);
        p.set_stage_policy(Some(StagePolicy::default()));
        for _ in 0..rng.range(2, 4) {
            p.add_slot(GPUS4[(rng.next() as usize) % GPUS4.len()]);
        }
        // profile the initial fleet at the final initial group size
        // (every card fits ZeRO-3 at n >= 2)
        let n0 = p.active_slots().len();
        for slot in p.needs_profile() {
            let gpu = p.slots()[slot].gpu.clone();
            let c = model_curve(&gpu, &m, p.stage(), n0)
                .expect("every card fits ZeRO-3 at n >= 2");
            p.install_curve(slot, c, false).unwrap();
        }

        for step in 0..rng.range(2, 10) {
            // random membership event
            if rng.uniform() < 0.4 && p.active_slots().len() > 2 {
                let active = p.active_slots();
                let victim = active[(rng.next() as usize) % active.len()];
                let _ = p.lose_slot(victim);
            } else {
                let gpu = GPUS4[(rng.next() as usize) % GPUS4.len()];
                let slot = p.add_slot(gpu);
                if p.needs_profile().contains(&slot) {
                    // mimic the leader: a joiner that cannot fit (or fit
                    // a curve) at the current stage is evicted
                    let n = p.active_slots().len();
                    match model_curve(gpu, &m, p.stage(), n) {
                        Some(c) => p.install_curve(slot, c, false).unwrap(),
                        None => {
                            p.lose_slot(slot).unwrap();
                        }
                    }
                }
            }
            let n_active = p.active_slots().len();
            // mimic the leader's (2c): measure every fittable
            // (type, stage) pair at the CURRENT group size, so the
            // search is free to move anywhere the memory model allows
            // (stale-at-another-n entries are re-measured, like (2c)
            // re-profiles what stage_profile_requests names)
            for stage in 0..=3u8 {
                for gpu in GPUS4 {
                    if let Some(c) = model_curve(gpu, &m, stage, n_active) {
                        p.install_stage_curve(gpu, stage, c).unwrap();
                    }
                }
            }
            let net = NetSim::from_link(n_active, LinkKind::Ib);
            let plan = p
                .replan(&net)
                .unwrap_or_else(|e| panic!("seed {seed} step {step}: {e}"))
                .clone();
            plan.validate().unwrap_or_else(|e| panic!("seed {seed} step {step}: {e}"));
            assert_eq!(plan.total_samples(), 64, "seed {seed} step {step}");
            assert_eq!(plan.stage, p.stage(), "seed {seed} step {step}");

            // THE invariant: whatever stage the search kept or migrated
            // to, every live rank satisfies the Alg. 1 memory bound at
            // the new group size
            for slot in p.active_slots() {
                let gpu = p.slots()[slot].gpu.clone();
                let spec = catalog::spec(&gpu).unwrap();
                let mbs =
                    memmodel::true_mbs(&m, psi, p.stage(), n_active, spec.mem_bytes());
                assert!(
                    mbs >= 1,
                    "seed {seed} step {step}: ZeRO-{} breaks the bound for {gpu} \
                     (n={n_active})",
                    p.stage()
                );
            }
            // and the manifest migrated with the stage
            assert_eq!(p.manifest().unwrap().stage, p.stage());
        }
    }
}

#[test]
fn prop_joiner_unfit_at_current_stage_admitted_at_feasible_stage() {
    // unified-engine satellite: a joiner that cannot fit the *current*
    // stage is no longer evicted before the stage search runs. Whenever
    // some feasible stage is measured for every live type at the new
    // group size, the replan migrates there and admits the joiner off
    // the stage-keyed cache; the plan stays valid and covers gbs.
    let m = preset("bert-1.1b").unwrap();
    const BIG: &[&str] = &["A100-80G", "A800-80G"];
    const SMALL: &[&str] = &["T4", "V100-16G"];
    for seed in 0..30u64 {
        let mut rng = XorShift::new(seed + 9000);
        let n_big = rng.range(2, 4) as usize;
        let mut p = ElasticPlanner::new(0, 32, &m.name, m.param_count(), 32);
        p.set_stage_policy(Some(StagePolicy::default()));
        for _ in 0..n_big {
            let gpu = BIG[(rng.next() as usize) % BIG.len()];
            let slot = p.add_slot(gpu);
            if p.needs_profile().contains(&slot) {
                // ZeRO-0 memory is n-independent, so any n works here
                let c = model_curve(gpu, &m, 0, n_big).expect("big cards fit z0");
                p.install_curve(slot, c, false).unwrap();
            }
        }
        let n0 = p.active_slots().len();
        p.replan(&NetSim::from_link(n0, LinkKind::Ib)).unwrap();
        assert_eq!(p.stage(), 0, "seed {seed}: nothing forces a move yet");

        // a joiner that cannot fit ZeRO-0 (16ψ > 16 GiB), plus full
        // ZeRO-3 measured coverage at the post-join group size
        let joiner = SMALL[(rng.next() as usize) % SMALL.len()];
        let n_after = n0 + 1;
        for gpu in BIG.iter().chain(SMALL.iter()) {
            if let Some(c) = model_curve(gpu, &m, 3, n_after) {
                p.install_stage_curve(gpu, 3, c).unwrap();
            }
        }
        let slot = p.add_slot(joiner);
        assert!(p.needs_profile().contains(&slot), "seed {seed}");
        let net = NetSim::from_link(n_after, LinkKind::Ib);
        let plan = p
            .replan(&net)
            .unwrap_or_else(|e| panic!("seed {seed}: {e}"))
            .clone();
        plan.validate().unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        assert_eq!(plan.total_samples(), 32, "seed {seed}");
        assert_eq!(plan.ranks.len(), n_after, "seed {seed}: joiner admitted, not evicted");
        assert!(p.stage() > 0, "seed {seed}: must have migrated off ZeRO-0");
        assert!(p.slots()[slot].curve.is_some(), "seed {seed}");
        assert_eq!(p.manifest().unwrap().stage, p.stage(), "seed {seed}");
        // the chosen stage's memory bound holds for every live rank
        for s in p.active_slots() {
            let gpu = p.slots()[s].gpu.clone();
            let spec = catalog::spec(&gpu).unwrap();
            assert!(
                memmodel::true_mbs(&m, m.param_count(), p.stage(), n_after, spec.mem_bytes())
                    >= 1,
                "seed {seed}: ZeRO-{} breaks the bound for {gpu}",
                p.stage()
            );
        }
    }
}

#[test]
fn preview_round_rejects_mismatched_fallback_len() {
    // satellite: the old debug_assert_eq! vanished in release builds and
    // let a short fallbacks slice silently mean "no fallback" for the
    // tail of the batch — now it is a typed error in every build profile
    let mut rng = XorShift::new(0);
    let mut p = random_planner(&mut rng, 3, 1, 128);
    let net = NetSim::from_link(3, LinkKind::Ib);
    p.replan(&net).unwrap();
    let gpus = vec!["T4".to_string(), "A100-80G".to_string()];
    let short = vec![None];
    match p.preview_round_at(1, &gpus, &short, &net) {
        Err(ElasticError::FallbackLen { gpus: 2, fallbacks: 1 }) => {}
        other => panic!("expected FallbackLen {{ gpus: 2, fallbacks: 1 }}, got {other:?}"),
    }
    // and the empty-fallbacks shorthand is gone too: parallel or error
    match p.preview_round_at(1, &gpus, &[], &net) {
        Err(ElasticError::FallbackLen { gpus: 2, fallbacks: 0 }) => {}
        other => panic!("expected FallbackLen {{ gpus: 2, fallbacks: 0 }}, got {other:?}"),
    }
}

#[test]
fn prop_previewed_manifest_matches_admission() {
    // the round preview's predicted shard layout must be byte-identical
    // (slots, ranges, snapshot id) to the manifest the planner actually
    // builds after admitting the same batch — including across dead-slot
    // gaps in the slot table
    for seed in 0..40u64 {
        let mut rng = XorShift::new(seed + 6000);
        let stage = (seed % 4) as u8;
        let n = rng.range(3, 6) as usize;
        let gbs = rng.range(64, 512) as usize;
        let mut p = random_planner(&mut rng, n, stage, gbs);
        if seed % 2 == 0 {
            // leave a hole in the slot table so predicted joiner ids
            // (slots.len() + i) are exercised against a sparse live set
            let active = p.active_slots();
            let victim = active[(rng.next() as usize) % active.len()];
            let _ = p.lose_slot(victim);
        }
        let n_active = p.active_slots().len();
        p.replan(&NetSim::from_link(n_active, LinkKind::Ib)).unwrap();

        let k = rng.range(1, 4) as usize;
        let batch: Vec<String> = (0..k)
            .map(|_| GPUS[(rng.next() as usize) % GPUS.len()].to_string())
            .collect();
        let fallbacks: Vec<Option<PerfCurve>> =
            batch.iter().map(|g| Some(device_curve(g, 8, 1.0))).collect();
        let net_after = NetSim::from_link(n_active + k, LinkKind::Ib);
        let pv = p
            .preview_round_at(stage, &batch, &fallbacks, &net_after)
            .unwrap_or_else(|e| panic!("seed {seed}: preview: {e}"));

        // admit the identical batch for real and replan
        for (g, f) in batch.iter().zip(&fallbacks) {
            let slot = p.add_slot(g);
            if p.needs_profile().contains(&slot) {
                p.install_curve(slot, f.clone().unwrap(), false).unwrap();
            }
        }
        p.replan(&net_after)
            .unwrap_or_else(|e| panic!("seed {seed}: admit replan: {e}"));
        assert_eq!(
            p.manifest().unwrap(),
            &pv.manifest,
            "seed {seed}: previewed manifest diverges from the built one"
        );
    }
}

#[test]
fn prop_extend_chain_matches_batch_preview() {
    // delta-pricing equivalence: folding joiners one at a time through
    // preview_round_extend must land on exactly the preview_round_at
    // result for the full batch — same manifest, same moved bytes, same
    // seconds, same plan — at the incumbent stage AND across a stage
    // change (where migration_only_s is live)
    for seed in 0..40u64 {
        let mut rng = XorShift::new(seed + 7000);
        let stage = (seed % 4) as u8;
        let n = rng.range(3, 6) as usize;
        let gbs = rng.range(64, 512) as usize;
        let mut p = random_planner(&mut rng, n, stage, gbs);
        if seed % 3 == 0 {
            let active = p.active_slots();
            let victim = active[(rng.next() as usize) % active.len()];
            let _ = p.lose_slot(victim);
        }
        let n_active = p.active_slots().len();
        p.replan(&NetSim::from_link(n_active, LinkKind::Ib)).unwrap();

        let k = rng.range(2, 5) as usize;
        let batch: Vec<String> = (0..k)
            .map(|_| GPUS[(rng.next() as usize) % GPUS.len()].to_string())
            .collect();
        let fallbacks: Vec<Option<PerfCurve>> =
            batch.iter().map(|g| Some(device_curve(g, 8, 1.0))).collect();
        let net = NetSim::from_link(n_active + k, LinkKind::Ib);

        // a non-incumbent stage needs full measured coverage (fallbacks
        // are incumbent-only); install it so odd seeds cross stages
        let target = if seed % 2 == 1 { (stage + 1) % 4 } else { stage };
        if target != stage {
            for gpu in GPUS {
                p.install_stage_curve(gpu, target, device_curve(gpu, 8, 1.0)).unwrap();
            }
        }

        let full = p
            .preview_round_at(target, &batch, &fallbacks, &net)
            .unwrap_or_else(|e| panic!("seed {seed}: batch preview: {e}"));
        let mut acc = p
            .preview_round_at(target, &batch[..1], &fallbacks[..1], &net)
            .unwrap_or_else(|e| panic!("seed {seed}: seed preview: {e}"));
        for i in 1..k {
            acc = p
                .preview_round_extend(&acc, &batch[i], fallbacks[i].as_ref(), &net)
                .unwrap_or_else(|e| panic!("seed {seed}: extend {i}: {e}"));
        }

        assert_eq!(acc.manifest, full.manifest, "seed {seed}: manifests diverge");
        assert_eq!(acc.curves.len(), full.curves.len(), "seed {seed}");
        assert_eq!(acc.joiner_cached, full.joiner_cached, "seed {seed}");
        assert_eq!(
            acc.reshard_bytes, full.reshard_bytes,
            "seed {seed}: moved bytes diverge"
        );
        assert!(
            (acc.reshard_penalty_s - full.reshard_penalty_s).abs() < 1e-12,
            "seed {seed}: reshard seconds diverge ({} vs {})",
            acc.reshard_penalty_s,
            full.reshard_penalty_s
        );
        assert!(
            (acc.migration_only_s - full.migration_only_s).abs() < 1e-12,
            "seed {seed}: migration itemization diverges ({} vs {})",
            acc.migration_only_s,
            full.migration_only_s
        );
        assert_eq!(
            acc.plan.predicted_iter_s, full.plan.predicted_iter_s,
            "seed {seed}: plans diverge"
        );
    }
}

#[test]
fn prop_bw_drift_interleaved_with_membership_keeps_plans_valid() {
    // invariant 7: bandwidth drift is just another event stream — no
    // interleaving with losses/joins may produce an invalid plan, a
    // short-covered batch, or a rank whose memory bound breaks; and the
    // announcement itself (ground truth, like RankSlowed) never replans
    use poplar::elastic::ElasticEvent;
    use poplar::netsim::BwMonitor;
    let m = preset("llama-0.5b").unwrap();
    for seed in 0..50u64 {
        let mut rng = XorShift::new(seed + 11_000);
        let stage = (seed % 4) as u8;
        let n = rng.range(2, 5) as usize;
        let gbs = rng.range(32, 512) as usize;
        let mut p = random_planner(&mut rng, n, stage, gbs);
        let mut monitor = BwMonitor::new(LinkKind::Ib);
        let spec = monitor.spec_gbs();
        let mut true_factor = 1.0f64;

        for step in 0..rng.range(2, 12) {
            match rng.range(0, 3) {
                0 => {
                    let active = p.active_slots();
                    let victim = active[(rng.next() as usize) % active.len()];
                    let _ = p.lose_slot(victim);
                }
                1 => {
                    let gpu = GPUS[(rng.next() as usize) % GPUS.len()];
                    p.add_slot(gpu);
                    profile_missing(&mut rng, &mut p);
                }
                2 => {
                    // ground-truth fabric shift: the planner sees only a
                    // validated no-op — the monitor must discover it
                    true_factor = 0.05 + rng.uniform() * 0.95;
                    let ev =
                        ElasticEvent::BwDrift { link: "ib".into(), factor: true_factor };
                    let dirty_before = p.dirty();
                    p.apply(&ev).unwrap_or_else(|e| panic!("seed {seed} step {step}: {e}"));
                    assert_eq!(
                        p.dirty(),
                        dirty_before,
                        "seed {seed} step {step}: the event alone dirtied the plan"
                    );
                }
                _ => {} // calm iteration: just another sample below
            }
            monitor.observe(spec * true_factor);
            assert!(
                monitor.estimate_gbs() <= monitor.spec_gbs() + 1e-9
                    && monitor.estimate_gbs() >= monitor.min_observed_gbs() - 1e-9,
                "seed {seed} step {step}: estimate {} outside [{}, {}]",
                monitor.estimate_gbs(),
                monitor.min_observed_gbs(),
                monitor.spec_gbs()
            );

            let n_active = p.active_slots().len();
            let net = monitor.snapshot(n_active);
            let plan = p
                .replan(&net)
                .unwrap_or_else(|e| panic!("seed {seed} step {step}: {e}"))
                .clone();
            plan.validate().unwrap_or_else(|e| panic!("seed {seed} step {step}: {e}"));
            assert_eq!(plan.total_samples(), gbs, "seed {seed} step {step}");
            assert_eq!(plan.ranks.len(), n_active, "seed {seed} step {step}");
            for slot in p.active_slots() {
                let gpu = p.slots()[slot].gpu.clone();
                let spec_gpu = catalog::spec(&gpu).unwrap();
                assert!(
                    memmodel::true_mbs(
                        &m,
                        m.param_count(),
                        plan.stage,
                        n_active,
                        spec_gpu.mem_bytes()
                    ) >= 1,
                    "seed {seed} step {step}: ZeRO-{} breaks the bound for {gpu}",
                    plan.stage
                );
            }
        }
    }
}

#[test]
fn prop_monitor_estimate_always_within_min_observed_and_spec() {
    // invariant 8, on raw sample streams: congestion, recovery,
    // above-spec noise and extreme outliers in any order
    use poplar::netsim::{BwMonitor, BwState};
    for seed in 0..80u64 {
        let mut rng = XorShift::new(seed + 12_000);
        let mut m = BwMonitor::new(LinkKind::Ib);
        let spec = m.spec_gbs();
        for step in 0..rng.range(5, 60) {
            let sample = match rng.range(0, 3) {
                0 => spec * (0.02 + rng.uniform()),       // plausible drift
                1 => spec * (1.0 + 2.0 * rng.uniform()),  // above spec: clamps
                2 => spec * 0.01 * rng.uniform().max(1e-3), // extreme low
                _ => spec,
            };
            m.observe(sample);
            assert!(
                m.estimate_gbs() <= spec + 1e-9
                    && m.estimate_gbs() >= m.min_observed_gbs() - 1e-9,
                "seed {seed} step {step}: estimate {} outside [{}, {}]",
                m.estimate_gbs(),
                m.min_observed_gbs(),
                spec
            );
            assert!(m.min_observed_gbs() <= spec + 1e-9, "seed {seed} step {step}");
        }

        // and a single outlier between steady spec-level samples never
        // moves the estimate or signals a replan
        let mut m2 = BwMonitor::new(LinkKind::Ib);
        for _ in 0..5 {
            m2.observe(spec);
        }
        assert_eq!(m2.state(), BwState::Steady, "seed {seed}");
        let before = m2.estimate_gbs();
        let outlier = spec * (0.01 + rng.uniform() * 0.5);
        assert!(m2.observe(outlier).is_none(), "seed {seed}: outlier {outlier} signalled");
        assert_eq!(m2.estimate_gbs(), before, "seed {seed}: outlier moved the estimate");
    }
}

#[test]
fn prop_cache_eviction_never_drops_live_keys() {
    for seed in 0..80u64 {
        let mut rng = XorShift::new(seed + 3000);
        let cap = rng.range(1, 4) as usize;
        let mut cache = CurveCache::new(cap);
        // the live set: up to `cap + 2` keys (may exceed cap — the cache
        // must grow rather than drop them)
        let n_live = rng.range(1, cap as u64 + 2) as usize;
        let live: Vec<CurveKey> = (0..n_live)
            .map(|i| CurveKey::new(GPUS[i % GPUS.len()], "llama-0.5b", (i % 4) as u8))
            .collect();
        for k in &live {
            cache.insert(k.clone(), device_curve(&k.gpu, 8, 1.0), &live);
        }
        // hammer with random cold inserts
        for _ in 0..rng.range(3, 20) {
            let gpu = GPUS[(rng.next() as usize) % GPUS.len()];
            let stage = rng.range(0, 3) as u8;
            let key = CurveKey::new(gpu, "llama-1.1b", stage); // different model: never live
            cache.insert(key, device_curve(gpu, 8, 1.0), &live);
            for k in &live {
                assert!(cache.contains(k), "seed {seed}: live key {k:?} evicted");
            }
        }
    }
}
