//! Property tests for the cost-aware autoscaling policy:
//!
//! * accepting an offer never lowers predicted throughput net of the
//!   amortized admission stall;
//! * declined (and evaluated-in-any-way) offers never mutate planner or
//!   curve-cache state — counters and LRU order included;
//! * the reported frontier is actually Pareto: no dominated points;
//! * `preview_join` leaves the cache hit-path intact: a real join after
//!   any number of previews still scores exactly one hit;
//! * an invalid ZeRO stage surfaces as a typed error through `plan`,
//!   `replan` and `preview_join` — never a panic.

use poplar::allocator::{self, PlanError};
use poplar::autoscale::{self, AutoscaleOptions, Decision};
use poplar::cluster::{catalog, LinkKind};
use poplar::config::model::preset;
use poplar::curves::{PerfCurve, ProfiledPoint};
use poplar::elastic::{ElasticError, ElasticPlanner};
use poplar::netsim::NetSim;

fn device_curve(gpu: &str, mbs: usize) -> PerfCurve {
    let g = catalog::spec_or_panic(gpu);
    let m = preset("llama-0.5b").unwrap();
    let pts: Vec<ProfiledPoint> = (1..=mbs)
        .map(|b| ProfiledPoint {
            batch: b,
            step_time_s: g.compute_time(
                (b as u64 * m.seq) as f64,
                m.flops_per_token(),
                m.n_layers as usize,
            ),
        })
        .collect();
    PerfCurve::fit(pts, mbs).unwrap()
}

fn planner_c(stage: u8, gbs: usize) -> (ElasticPlanner, NetSim) {
    let m = preset("llama-0.5b").unwrap();
    let mut p = ElasticPlanner::new(stage, gbs, &m.name, m.param_count(), 16);
    for (gpu, mbs) in [
        ("A800-80G", 48usize),
        ("A800-80G", 48),
        ("A800-80G", 48),
        ("A800-80G", 48),
        ("V100S-32G", 16),
        ("V100S-32G", 16),
        ("V100S-32G", 16),
        ("V100S-32G", 16),
    ] {
        let slot = p.add_slot(gpu);
        if p.slots()[slot].curve.is_none() {
            p.install_curve(slot, device_curve(gpu, mbs), false).unwrap();
        }
    }
    let net = NetSim::from_link(8, LinkKind::Ib);
    p.replan(&net).unwrap();
    (p, net)
}

#[derive(PartialEq, Debug)]
struct PlannerFingerprint {
    n_slots: usize,
    replans: usize,
    dirty: bool,
    cache_len: usize,
    cache_hits: u64,
    cache_misses: u64,
    lru: Vec<poplar::elastic::CurveKey>,
}

fn fingerprint(p: &ElasticPlanner) -> PlannerFingerprint {
    PlannerFingerprint {
        n_slots: p.slots().len(),
        replans: p.replans(),
        dirty: p.dirty(),
        cache_len: p.cache().len(),
        cache_hits: p.cache().hits(),
        cache_misses: p.cache().misses(),
        lru: p.cache().lru_order().to_vec(),
    }
}

#[test]
fn accepted_offers_always_pay_off_across_horizons_and_stages() {
    let m = preset("llama-0.5b").unwrap();
    let offers = ["A800-80G", "V100S-32G", "RTX4090", "T4", "RTX3060"];
    for stage in [0u8, 1, 2, 3] {
        let (p, net) = planner_c(stage, 2048);
        for horizon in [30.0f64, 300.0, 3600.0] {
            let opts = AutoscaleOptions { horizon_s: horizon, ..Default::default() };
            for gpu in offers {
                let d = match autoscale::evaluate_offer(&p, &net, &m, gpu, &opts) {
                    Ok(d) => d,
                    // a candidate that cannot fit a sample at this stage
                    // is a typed rejection, not a property violation
                    Err(autoscale::AutoscaleError::NoCapacity(_)) => continue,
                    Err(e) => panic!("stage {stage} {gpu}: {e}"),
                };
                if d.decision == Decision::Accept {
                    // net of the amortized stall, throughput strictly wins
                    assert!(
                        d.gain_samples > 0.0,
                        "stage {stage} {gpu} h={horizon}: accepted but gain {} <= 0",
                        d.gain_samples
                    );
                    assert!(d.post_rate > d.pre_rate);
                    assert!(
                        (d.post_rate - d.pre_rate) * horizon
                            > d.post_rate * d.reshard_penalty_s,
                        "stage {stage} {gpu}: gain must exceed the reshard penalty"
                    );
                    // accepts only ever run on measured curves
                    assert!(d.curve_cached);
                    assert_eq!(d.profile_est_s, 0.0);
                }
                if d.decision == Decision::Defer {
                    assert!(!d.curve_cached, "defer means estimate-based");
                }
            }
        }
    }
}

#[test]
fn evaluating_offers_mutates_nothing_whatever_the_verdict() {
    let m = preset("llama-0.5b").unwrap();
    for stage in [1u8, 3] {
        let (p, net) = planner_c(stage, 2048);
        let manifest0 = p.manifest().unwrap().clone();
        let plan0 = p.plan().unwrap().predicted_iter_s;
        let fp0 = fingerprint(&p);
        let offers: Vec<String> = ["A800-80G", "V100S-32G", "RTX4090", "T4", "RTX3060"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        for horizon in [30.0f64, 300.0, 3600.0] {
            let opts = AutoscaleOptions { horizon_s: horizon, ..Default::default() };
            let rep = match autoscale::evaluate_offers(&p, &net, &m, &offers, &opts) {
                Ok(r) => r,
                Err(autoscale::AutoscaleError::NoCapacity(_)) => continue,
                Err(e) => panic!("stage {stage}: {e}"),
            };
            assert_eq!(rep.decisions.len(), offers.len());
        }
        assert_eq!(fingerprint(&p), fp0, "stage {stage}: policy must be read-only");
        assert_eq!(p.manifest().unwrap(), &manifest0);
        assert_eq!(p.plan().unwrap().predicted_iter_s, plan0);
    }
}

#[test]
fn frontier_never_reports_a_dominated_point() {
    let m = preset("llama-0.5b").unwrap();
    // no RTX3060 here: at ZeRO-0 its 12 GB cannot hold the replicated
    // 16ψ model states, and evaluate_offers fails fast on NoCapacity
    let offers: Vec<String> = ["A800-80G", "V100S-32G", "RTX4090", "T4"]
        .iter()
        .map(|s| s.to_string())
        .collect();
    for stage in [0u8, 1, 2] {
        let (p, net) = planner_c(stage, 2048);
        let rep = autoscale::evaluate_offers(&p, &net, &m, &offers, &AutoscaleOptions::default())
            .unwrap();
        let mut pts =
            vec![(rep.baseline_rate, rep.baseline_cost_per_ksample, rep.baseline_on_frontier)];
        for d in &rep.decisions {
            pts.push((d.post_rate, d.cost_per_ksample, d.on_frontier));
        }
        for (i, &(r, c, on)) in pts.iter().enumerate() {
            let dominated = pts.iter().enumerate().any(|(j, &(rj, cj, _))| {
                j != i && rj >= r && cj <= c && (rj > r || cj < c)
            });
            assert_eq!(
                on, !dominated,
                "stage {stage} point {i}: rate {r:.2}, cost {c:.5}"
            );
        }
        assert!(pts.iter().any(|&(_, _, on)| on), "stage {stage}: empty frontier");
    }
}

#[test]
fn preview_join_preserves_the_cache_hit_path() {
    let (mut p, net) = planner_c(1, 2048);
    let fp0 = fingerprint(&p);
    // hammer previews: cached type, estimated type, and an error path
    let est = device_curve("T4", 8);
    for _ in 0..10 {
        p.preview_join("A800-80G", None, &net).unwrap();
        p.preview_join("T4", Some(&est), &net).unwrap();
        assert!(matches!(
            p.preview_join("T4", None, &net),
            Err(ElasticError::NoCurve(_))
        ));
    }
    assert_eq!(fingerprint(&p), fp0, "previews must not perturb cache state");

    // the real join afterwards behaves exactly as if no preview happened:
    // one hit, curve installed, no profiling needed
    let slot = p.add_slot("V100S-32G");
    assert_eq!(p.cache().hits(), fp0.cache_hits + 1);
    assert_eq!(p.cache().misses(), fp0.cache_misses);
    assert!(p.slots()[slot].curve.is_some());
    assert!(p.needs_profile().is_empty());
}

#[test]
fn invalid_stage_is_typed_everywhere_on_the_autoscale_path() {
    let m = preset("llama-0.5b").unwrap();
    let curves = vec![device_curve("A800-80G", 48), device_curve("V100S-32G", 16)];
    let net = NetSim::from_link(2, LinkKind::Ib);
    // plan + replan (regression for the netsim panic: stage reaches the
    // comm-time model through both)
    for bad in [4u8, 9, 255] {
        assert_eq!(
            allocator::plan(&curves, bad, 256, &net, m.param_count()).unwrap_err(),
            PlanError::InvalidStage(bad)
        );
        let mut prev = allocator::plan(&curves, 1, 256, &net, m.param_count()).unwrap();
        prev.stage = bad;
        assert_eq!(
            allocator::replan(&prev, &curves, &net, m.param_count()).unwrap_err(),
            PlanError::InvalidStage(bad)
        );
    }
    // preview_join on a corrupt-stage planner
    let mut p = ElasticPlanner::new(6, 256, &m.name, m.param_count(), 8);
    let slot = p.add_slot("A800-80G");
    p.install_curve(slot, device_curve("A800-80G", 48), false).unwrap();
    assert!(matches!(
        p.preview_join("A800-80G", Some(&device_curve("A800-80G", 48)), &net),
        Err(ElasticError::Plan(PlanError::InvalidStage(6)))
    ));
    // and the policy wraps it, typed
    assert!(matches!(
        autoscale::evaluate_offer(&p, &net, &m, "A800-80G", &AutoscaleOptions::default()),
        Err(autoscale::AutoscaleError::Plan(PlanError::InvalidStage(6)))
    ));
}
