//! Golden-table regression tests: the deterministic experiment runners
//! (fixed seeds, no wall-clock inputs) must render byte-identical
//! markdown across runs and across refactors — an allocator change that
//! shifts a paper figure must show up as a diff here, not silently.
//!
//! Protocol: each table is rendered twice in-process (determinism
//! check), then compared byte-for-byte against the committed snapshot
//! under `tests/golden/`. If the snapshot does not exist yet (fresh
//! checkout bootstrapping), it is materialized and the test passes with
//! a notice — commit the generated file to arm the regression check.
//! To intentionally update a snapshot, delete it and re-run the tests.

use std::fs;
use std::path::PathBuf;

fn golden_dir() -> PathBuf {
    // tests run with cwd = crate root (rust/)
    PathBuf::from("tests").join("golden")
}

fn check_golden(name: &str, render: impl Fn() -> String) {
    let first = render();
    let second = render();
    assert_eq!(first, second, "{name}: output is not deterministic within one process");
    assert!(!first.trim().is_empty(), "{name}: empty table");

    let path = golden_dir().join(format!("{name}.md"));
    if path.exists() {
        let want = fs::read_to_string(&path).unwrap();
        assert_eq!(
            want, first,
            "{name}: output drifted from the committed golden snapshot \
             ({}). If the change is intentional, delete the snapshot and \
             re-run to regenerate it.",
            path.display()
        );
    } else {
        fs::create_dir_all(golden_dir()).unwrap();
        fs::write(&path, &first).unwrap();
        eprintln!("NOTE: materialized golden snapshot {} — commit it", path.display());
    }
}

#[test]
fn golden_fig3_main_result() {
    check_golden("fig3", || poplar::exp::fig3::run().unwrap().to_markdown());
}

#[test]
fn golden_fig5_quantity_scaling() {
    check_golden("fig5", || poplar::exp::fig5::run().unwrap().to_markdown());
}

#[test]
fn golden_fig_elastic_recovery() {
    check_golden("fig_elastic", || {
        poplar::exp::fig_elastic::run().unwrap().to_markdown()
    });
}

#[test]
fn golden_fig_autoscale_frontier() {
    check_golden("fig_autoscale", || {
        poplar::exp::fig_autoscale::run().unwrap().to_markdown()
    });
}

#[test]
fn golden_fig_stage_migration_decisions() {
    check_golden("fig_stage_migration", || {
        poplar::exp::fig_stage_migration::run().unwrap().to_markdown()
    });
}

#[test]
fn golden_fig_joint_admission_rounds() {
    check_golden("fig_joint_admission", || {
        poplar::exp::fig_joint_admission::run().unwrap().to_markdown()
    });
}

#[test]
fn golden_fig_bw_adaptation_decisions() {
    check_golden("fig_bw_adaptation", || {
        poplar::exp::fig_bw_adaptation::run().unwrap().to_markdown()
    });
}

#[test]
fn golden_fig_pipeline_grouping() {
    check_golden("fig_pipeline", || {
        poplar::exp::fig_pipeline::run().unwrap().to_markdown()
    });
}
