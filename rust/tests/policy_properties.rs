//! Property tests for the unified decision engine (`poplar::policy`):
//!
//! 1. `decide_round`'s joint plan is never worse than the best
//!    single-offer *sequential* order — the joint subset search
//!    subsumes every greedy admission sequence;
//! 2. `Release` never fires with a non-positive amortized
//!    samples-per-dollar gain (and always clears `min_gain`);
//! 3. the engine is read-only w.r.t. the planner fingerprint (the PR-3
//!    harness: slots, replans, dirty flag, cache counters AND LRU
//!    order), whatever the verdict.

use poplar::autoscale::synthesize_curve;
use poplar::cluster::LinkKind;
use poplar::config::model::preset;
use poplar::curves::PerfCurve;
use poplar::elastic::{CurveKey, ElasticPlanner, XorShift};
use poplar::netsim::NetSim;
use poplar::policy::{self, Action, RoundOptions, SearchMode};

fn truth(gpu: &str, stage: u8, n: usize) -> PerfCurve {
    let m = preset("llama-0.5b").unwrap();
    synthesize_curve(gpu, &m, stage, n).unwrap()
}

fn planner_with(stage: u8, gbs: usize, fleet: &[&str]) -> (ElasticPlanner, NetSim) {
    let m = preset("llama-0.5b").unwrap();
    let mut p = ElasticPlanner::new(stage, gbs, &m.name, m.param_count(), 32);
    for gpu in fleet {
        let slot = p.add_slot(gpu);
        if p.slots()[slot].curve.is_none() {
            p.install_curve(slot, truth(gpu, stage, fleet.len()), false).unwrap();
        }
    }
    let net = NetSim::from_link(fleet.len(), LinkKind::Ib);
    p.replan(&net).unwrap();
    (p, net)
}

fn cluster_c(stage: u8) -> (ElasticPlanner, NetSim) {
    planner_with(
        stage,
        2048,
        &[
            "A800-80G", "A800-80G", "A800-80G", "A800-80G", "V100S-32G", "V100S-32G",
            "V100S-32G", "V100S-32G",
        ],
    )
}

#[derive(PartialEq, Debug)]
struct PlannerFingerprint {
    n_slots: usize,
    replans: usize,
    dirty: bool,
    cache_len: usize,
    cache_hits: u64,
    cache_misses: u64,
    lru: Vec<CurveKey>,
}

fn fingerprint(p: &ElasticPlanner) -> PlannerFingerprint {
    PlannerFingerprint {
        n_slots: p.slots().len(),
        replans: p.replans(),
        dirty: p.dirty(),
        cache_len: p.cache().len(),
        cache_hits: p.cache().hits(),
        cache_misses: p.cache().misses(),
        lru: p.cache().lru_order().to_vec(),
    }
}

/// Every permutation of a small slice (naive recursion — fine for the
/// <= 3-element orders used here).
fn permutations(items: &[String]) -> Vec<Vec<String>> {
    match items.len() {
        0 => vec![vec![]],
        _ => {
            let mut out = Vec::new();
            for (i, x) in items.iter().enumerate() {
                let mut rest = items.to_vec();
                rest.remove(i);
                for mut tail in permutations(&rest) {
                    let mut v = vec![x.clone()];
                    v.append(&mut tail);
                    out.push(v);
                }
            }
            out
        }
    }
}

#[test]
fn prop_joint_round_never_worse_than_any_sequential_order() {
    let m = preset("llama-0.5b").unwrap();
    let offer_sets: &[&[&str]] = &[
        &["A800-80G"],
        &["A800-80G", "T4"],
        &["V100S-32G", "RTX4090"],
        &["A800-80G", "V100S-32G", "T4"],
    ];
    for stage in [1u8, 2] {
        let (mut p, net) = cluster_c(stage);
        // a cached T4 makes the weak-offer-rides-along case reachable
        p.install_stage_curve("T4", stage, truth("T4", stage, 10)).unwrap();
        for &offers in offer_sets {
            let offers: Vec<String> = offers.iter().map(|s| s.to_string()).collect();
            for min_gain in [0.01f64, 0.05] {
                let opts = RoundOptions { min_gain, ..Default::default() };
                let round = policy::decide_round(&p, &net, &m, &offers, &opts)
                    .unwrap_or_else(|e| panic!("stage {stage} {offers:?}: {e}"));
                for order in permutations(&offers) {
                    let seq = policy::sequential_round(&p, &net, &m, &order, &opts)
                        .unwrap_or_else(|e| panic!("stage {stage} {order:?}: {e}"));
                    assert!(
                        round.score >= seq.score - 1e-9 * seq.score.abs().max(1.0),
                        "stage {stage} {offers:?} order {order:?}: joint {:.3} worse \
                         than sequential {:.3}",
                        round.score,
                        seq.score
                    );
                }
                // the round never scores below the keep-as-is baseline
                assert!(round.score >= round.pre_rate - 1e-9 * round.pre_rate);
            }
        }
    }
}

#[test]
fn prop_greedy_matches_exhaustive_on_small_batches() {
    // tentpole acceptance: on every batch the exhaustive search can
    // still afford (k <= MAX_EXHAUSTIVE_OFFERS) the greedy
    // marginal-contribution search must (a) never beat the exhaustive
    // optimum, (b) stay within the documented GREEDY_BOUND of it, and
    // (c) never fall below any singleton round — the singletons are its
    // seeds, so losing to one would mean the search is broken, not
    // merely approximate.
    let m = preset("llama-0.5b").unwrap();
    const POOL: &[&str] = &["A800-80G", "V100S-32G", "T4", "RTX4090"];
    for stage in [1u8, 2] {
        let (mut p, net) = cluster_c(stage);
        p.install_stage_curve("T4", stage, truth("T4", stage, 10)).unwrap();
        let mut rng = XorShift::new(42 + stage as u64);
        for case in 0..16 {
            let k = rng.range(1, policy::MAX_EXHAUSTIVE_OFFERS as u64) as usize;
            let offers: Vec<String> = (0..k)
                .map(|_| POOL[(rng.next() as usize) % POOL.len()].to_string())
                .collect();
            let ex_opts = RoundOptions { search: SearchMode::Exhaustive, ..Default::default() };
            let gr_opts = RoundOptions { search: SearchMode::Greedy, ..Default::default() };
            let ex = policy::decide_round(&p, &net, &m, &offers, &ex_opts)
                .unwrap_or_else(|e| panic!("stage {stage} case {case} {offers:?}: {e}"));
            let gr = policy::decide_round(&p, &net, &m, &offers, &gr_opts)
                .unwrap_or_else(|e| panic!("stage {stage} case {case} {offers:?}: {e}"));
            let eps = 1e-9 * ex.score.abs().max(1.0);
            assert!(
                gr.score <= ex.score + eps,
                "stage {stage} case {case} {offers:?}: greedy {} beat exhaustive {}",
                gr.score,
                ex.score
            );
            assert!(
                gr.score >= policy::GREEDY_BOUND * ex.score - eps,
                "stage {stage} case {case} {offers:?}: greedy {} fell below \
                 {} x exhaustive {}",
                gr.score,
                policy::GREEDY_BOUND,
                ex.score
            );
            for g in &offers {
                let solo = policy::decide_round(&p, &net, &m, &[g.clone()], &ex_opts)
                    .unwrap_or_else(|e| panic!("stage {stage} solo {g}: {e}"));
                assert!(
                    gr.score >= solo.score - eps,
                    "stage {stage} case {case}: greedy {} lost to singleton {g} at {}",
                    gr.score,
                    solo.score
                );
            }
        }
    }
}

#[test]
fn prop_release_never_fires_with_nonpositive_gain() {
    let m = preset("llama-0.5b").unwrap();
    let fleets: &[&[&str]] = &[
        &["A800-80G", "A800-80G", "A800-80G", "A800-80G"],
        &["A800-80G", "A800-80G", "A800-80G", "A800-80G", "V100S-32G"],
        &["A800-80G", "A800-80G", "V100S-32G", "T4"],
    ];
    let price_sets: &[Vec<(String, f64)>] = &[
        Vec::new(),
        vec![("V100S-32G".to_string(), 6.0)],
        vec![("T4".to_string(), 4.0)],
        vec![("A800-80G".to_string(), 0.4)],
    ];
    for &fleet in fleets {
        let (p, net) = planner_with(1, 1024, fleet);
        for prices in price_sets {
            for horizon in [30.0f64, 300.0, 3600.0] {
                let opts = RoundOptions {
                    consider_release: true,
                    horizon_s: horizon,
                    prices: prices.clone(),
                    ..Default::default()
                };
                let round = policy::decide_round(&p, &net, &m, &[], &opts)
                    .unwrap_or_else(|e| panic!("{fleet:?} {prices:?}: {e}"));
                if let Some(r) = &round.release {
                    // THE invariant: a release only ever fires with a
                    // strictly positive amortized per-dollar gain that
                    // clears the bar
                    assert!(
                        r.rel_gain_per_dollar > 0.0,
                        "{fleet:?} {prices:?} h={horizon}: released {} at gain {}",
                        r.gpu,
                        r.rel_gain_per_dollar
                    );
                    assert!(r.rel_gain_per_dollar >= opts.min_gain);
                    // the per-dollar arithmetic is consistent: amortized
                    // value strictly improves
                    let value_pre = round.pre_rate / r.price_before_per_hour;
                    let value_post = r.score_after / r.price_after_per_hour;
                    assert!(value_post > value_pre, "{fleet:?} {prices:?}");
                    assert!(r.cost_per_ksample_after.is_finite());
                    // a release is mutually exclusive with admissions
                    assert!(round.admitted.is_empty());
                }
            }
        }
    }
}

#[test]
fn prop_decide_round_is_read_only_whatever_the_verdict() {
    let m = preset("llama-0.5b").unwrap();
    for stage in [1u8, 3] {
        let (mut p, net) = cluster_c(stage);
        p.install_stage_curve("T4", stage, truth("T4", stage, 10)).unwrap();
        let manifest0 = p.manifest().unwrap().clone();
        let plan0 = p.plan().unwrap().predicted_iter_s;
        let fp0 = fingerprint(&p);
        let offers: Vec<String> = ["A800-80G", "T4", "RTX4090", "RTX3060"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        for consider_release in [false, true] {
            for min_gain in [0.01f64, 0.05, 0.2] {
                let opts = RoundOptions {
                    min_gain,
                    consider_release,
                    ..Default::default()
                };
                let round = policy::decide_round(&p, &net, &m, &offers, &opts)
                    .unwrap_or_else(|e| panic!("stage {stage}: {e}"));
                assert_eq!(round.offers.len(), offers.len());
                // actions vocabulary: every offer maps to exactly one of
                // the three admission verdicts
                for v in &round.offers {
                    assert!(matches!(
                        v.action,
                        Action::Admit { .. } | Action::Defer { .. } | Action::Decline { .. }
                    ));
                }
            }
        }
        assert_eq!(fingerprint(&p), fp0, "stage {stage}: the engine must be read-only");
        assert_eq!(p.manifest().unwrap(), &manifest0);
        assert_eq!(p.plan().unwrap().predicted_iter_s, plan0);
    }
}
