//! Tier-1 gate for the in-crate invariant analyzer.
//!
//! `poplar lint` replaced the CI shell greps; this test makes the same
//! pass part of `cargo test`, so the invariants hold even for workflows
//! that never touch CI. A failure message tells you exactly which site
//! to fix, allow (with a reason), or — for stale baseline entries —
//! which command regenerates the shrunken baseline.

use std::path::Path;

use poplar::lint;

fn crate_root() -> &'static Path {
    // anchored to the manifest, not the cwd: `cargo test` may run from
    // the workspace root or from rust/
    Path::new(env!("CARGO_MANIFEST_DIR"))
}

#[test]
fn lint_runs_clean_on_the_tree() {
    let report = lint::run_crate(crate_root()).expect("analyzer must run");
    assert!(report.files_scanned > 50, "walk found only {} files", report.files_scanned);
    if !report.is_clean() {
        let mut msg = String::new();
        for d in &report.new {
            msg.push_str(&format!("{d}\n"));
        }
        for s in &report.stale {
            msg.push_str(&format!(
                "stale baseline: {} {} freezes {} but {} remain — run \
                 `cargo run --bin poplar -- lint --write-baseline` and commit the shrink\n",
                s.rule, s.path, s.frozen, s.actual
            ));
        }
        panic!("poplar lint failed ({} baselined):\n{msg}", report.baselined);
    }
}

#[test]
fn baseline_only_ever_shrinks() {
    // the ratchet pin: the frozen total may go DOWN over time, never up.
    // When you burn debt down, lower FROZEN_TOTAL in the same PR.
    // Hit zero in PR 10 (elastic/mod.rs panic paths burned); it stays there.
    const FROZEN_TOTAL: usize = 0;
    let baseline = lint::load_baseline(crate_root()).expect("baseline parses");
    let total: usize = baseline.values().sum();
    assert!(
        total <= FROZEN_TOTAL,
        "lint-baseline.txt grew to {total} frozen diagnostics (max {FROZEN_TOTAL}); \
         a new panic path must be fixed or carry a reasoned allow, never baselined"
    );
    // only panic-path may carry frozen debt — every other rule ships clean
    for (rule, path) in baseline.keys() {
        assert_eq!(rule, "panic-path", "{rule} {path} must not carry frozen debt");
    }
}
